# Convenience targets. Tier-1 (`make test`) runs on a bare checkout:
# artifact-dependent integration tests skip with a clear message until
# `make artifacts` has produced the AOT bundles (requires jax) and the
# `xla` path dependency points at real PJRT bindings (see Cargo.toml).

.PHONY: artifacts test bench bench-json tables optimize optimize-varlen trace run

artifacts:
	cd python && python -m compile.aot --all --out ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hot_paths && cargo bench --bench paper_tables

# machine-readable optimizer + varlen-rebalancer + executor-transport +
# checkpoint-strategy + host-kernel results -> BENCH_optimizer.json +
# BENCH_varlen.json + BENCH_executor.json + BENCH_ckpt.json +
# BENCH_kernels.json, tracked across PRs (CI runs this and uploads all
# five as workflow artifacts). The executor rows run the real threaded
# executor with null kernels (clone-vs-Arc send path A/B); pass
# `--skip-exec` to repro bench to omit them. The ckpt rows run the joint
# checkpoint x prefetch search at 64K tokens plus a HostRef-executed twin
# per strategy. The kernel rows time scalar vs tiled vs multi-threaded
# flash kernels; CI gates tiled >= 5x scalar at one thread.
bench-json:
	cargo run --release --bin repro -- bench --json --out BENCH_optimizer.json --varlen-out BENCH_varlen.json --exec-out BENCH_executor.json --ckpt-out BENCH_ckpt.json --kernels-out BENCH_kernels.json

# measured-vs-simulated per-op trace table (host-kernel executor)
trace:
	cargo run --release --bin repro -- trace --p 8

# spec-driven Session pipeline smoke (host kernels, traced)
run:
	cargo run --release --bin repro -- run

tables:
	cargo run --release --bin repro -- tables

optimize:
	cargo run --release --bin repro -- optimize --cluster 2x8

# token-level rebalancing of a Zipf-packed document batch vs pad-to-max
optimize-varlen:
	cargo run --release --bin repro -- optimize --varlen --cluster 2x8
