# Convenience targets. Tier-1 (`make test`) runs on a bare checkout:
# artifact-dependent integration tests skip with a clear message until
# `make artifacts` has produced the AOT bundles (requires jax) and the
# `xla` path dependency points at real PJRT bindings (see Cargo.toml).

.PHONY: artifacts test bench tables

artifacts:
	cd python && python -m compile.aot --all --out ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hot_paths && cargo bench --bench paper_tables

tables:
	cargo run --release --bin repro -- tables
