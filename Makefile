# Convenience targets. Tier-1 (`make test`) runs on a bare checkout:
# artifact-dependent integration tests skip with a clear message until
# `make artifacts` has produced the AOT bundles (requires jax) and the
# `xla` path dependency points at real PJRT bindings (see Cargo.toml).

.PHONY: artifacts test bench bench-json tables optimize optimize-varlen trace run chaos serve

artifacts:
	cd python && python -m compile.aot --all --out ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hot_paths && cargo bench --bench paper_tables

# machine-readable optimizer + varlen-rebalancer + executor-transport +
# checkpoint-strategy + host-kernel + fault-overhead + recovery + serving
# results -> BENCH_optimizer.json + BENCH_varlen.json + BENCH_executor.json +
# BENCH_ckpt.json + BENCH_kernels.json + BENCH_faults.json +
# BENCH_recovery.json + BENCH_serve.json, tracked across PRs (CI runs
# this and uploads all eight as workflow artifacts). The executor rows
# run the real threaded executor with null kernels (clone-vs-Arc send
# path A/B); pass `--skip-exec` to repro bench to omit them. The ckpt
# rows run the joint checkpoint x prefetch search at 64K tokens plus a
# HostRef-executed twin per strategy. The kernel rows time scalar vs
# tiled vs multi-threaded flash kernels; CI gates tiled >= 5x scalar at
# one thread. The fault rows A/B the zero-fault instrumented comm path
# (armed all-zero FaultSpec) against the uninstrumented baseline; CI
# gates the overhead at <= 5%. The recovery rows crash one rank mid-run
# under each policy and time the supervised restart against the
# fault-free baseline; CI gates recovered <= 2.5x fault-free and
# bit-identical outputs. The serve rows run continuous-batching vs
# serial decode on the 2x8-dev preset; CI gates continuous >= 2x serial
# tokens/sec, simulated and executed.
bench-json:
	cargo run --release --bin repro -- bench --json --out BENCH_optimizer.json --varlen-out BENCH_varlen.json --exec-out BENCH_executor.json --ckpt-out BENCH_ckpt.json --kernels-out BENCH_kernels.json --faults-out BENCH_faults.json --recovery-out BENCH_recovery.json --serve-out BENCH_serve.json

# measured-vs-simulated per-op trace table (host-kernel executor)
trace:
	cargo run --release --bin repro -- trace --p 8

# spec-driven Session pipeline smoke (host kernels, traced)
run:
	cargo run --release --bin repro -- run

# seeded fault classes end to end: predicted vs executed makespan
# degradation, plus the optimizer queried under a pinned straggler
chaos:
	cargo run --release --bin repro -- chaos --p 4

# continuous-batching decode serving on the schedule IR (Poisson
# arrivals, paged KV-caches, bit-exact full-prefill oracle check)
serve:
	cargo run --release --bin repro -- serve

tables:
	cargo run --release --bin repro -- tables

optimize:
	cargo run --release --bin repro -- optimize --cluster 2x8

# token-level rebalancing of a Zipf-packed document batch vs pad-to-max
optimize-varlen:
	cargo run --release --bin repro -- optimize --varlen --cluster 2x8
