//! Long-context scaling study: how far each system stretches before OOM
//! and what an iteration costs along the way — the motivation story of the
//! paper's intro, regenerated from the models.
//!
//!     cargo run --offline --example long_context_scaling [-- llama-7b 2x8]

use distflash::baselines::distflash::DistFlashAttn;
use distflash::baselines::megatron::Megatron;
use distflash::baselines::ring_attention::RingAttention;
use distflash::baselines::rsa::RingSelfAttention;
use distflash::baselines::ulysses::Ulysses;
use distflash::baselines::SystemModel;
use distflash::config::{ClusterSpec, PaperModel};
use distflash::memory::{fmt_seq, max_total_seq_pow2};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = PaperModel::by_name(args.first().map(String::as_str).unwrap_or("llama-7b"))
        .expect("unknown model");
    let cluster = match args.get(1).map(String::as_str) {
        Some("1x8") => ClusterSpec::dgx_1x8(),
        Some("16x40g") => ClusterSpec::cluster_16x40g(),
        _ => ClusterSpec::dgx_2x8(),
    };
    let systems: Vec<Box<dyn SystemModel>> = vec![
        Box::new(DistFlashAttn::default()),
        Box::new(RingAttention),
        Box::new(Ulysses),
        Box::new(Megatron::tp()),
        Box::new(RingSelfAttention),
    ];

    println!(
        "== {} on {}x{} A100 ==",
        model.name, cluster.n_nodes, cluster.gpus_per_node
    );
    println!("{:<44} {:>10}  iteration time at total sequence length:", "system", "max seq");
    let probes: Vec<usize> = [65536usize, 131072, 262144, 524288].to_vec();
    print!("{:<56}", "");
    for p in &probes {
        print!("{:>10}", fmt_seq(*p));
    }
    println!();
    for sys in &systems {
        let max = max_total_seq_pow2(sys.as_ref(), &model, &cluster);
        print!("{:<44} {:>10}  ", sys.name(), fmt_seq(max));
        for &total in &probes {
            let per_gpu = total / cluster.n_gpus();
            let it = sys.iteration(&model, &cluster, per_gpu);
            if it.fits(&cluster) {
                print!("{:>9.1}s", it.total_s());
            } else {
                print!("{:>10}", "OOM");
            }
        }
        println!();
    }
    println!("\n(see `repro tables` for the paper-table comparisons)");
}
