//! Schedule explorer: prints the ring vs load-balanced plans for any
//! worker count and the idle/speedup numbers behind Figure 1, plus a
//! simulated timeline (Figure 2) on a chosen cluster.
//!
//!     cargo run --offline --example schedule_explorer -- 8 2x8

use distflash::baselines::distflash::DistFlashAttn;
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{ComputeOp, Schedule, ScheduleKind};

fn render(s: &Schedule) {
    println!("{:?}: {} steps", s.kind, s.n_steps());
    for w in 0..s.n_workers {
        let mut line = format!("  w{w:<2} ");
        for row in &s.steps {
            line.push_str(&match row[w].compute {
                Some(ComputeOp::Diag) => " D  ".to_string(),
                Some(ComputeOp::Own { kv_from }) => format!("O{kv_from:<2} "),
                Some(ComputeOp::Help { owner }) => format!("H{owner:<2} "),
                None => " .  ".to_string(),
            });
        }
        println!("{line}");
    }
    println!(
        "  idle slots {} / {}  ideal speedup {:.2}x\n",
        s.idle_slots(),
        s.n_steps() * s.n_workers,
        s.ideal_speedup()
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let cluster = match args.get(1).map(String::as_str) {
        Some("2x8") => ClusterSpec::dgx_2x8(),
        _ => ClusterSpec::dgx_1x8(),
    };

    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let s = Schedule::build(kind, p);
        s.validate().expect("schedule invariant violated");
        render(&s);
    }

    // simulated per-step timeline on LLaMA-7B chunks (Fig. 2 flavor)
    let model = PaperModel::llama_7b();
    let seq = 8192;
    println!("simulated attention timing ({} @ {} tokens/GPU):", model.name, seq);
    for (label, sys) in [
        ("balanced + overlap   ", DistFlashAttn::default()),
        ("ring + overlap       ", DistFlashAttn { schedule: ScheduleKind::Ring, ..DistFlashAttn::default() }),
        ("balanced, no overlap ", DistFlashAttn { overlap: false, ..DistFlashAttn::default() }),
        ("ring, no overlap     ", DistFlashAttn { schedule: ScheduleKind::Ring, overlap: false, ..DistFlashAttn::default() }),
    ] {
        let sim = sys.attn_sim(&model, &cluster, seq, false);
        println!(
            "  {label} total {:>7.2} ms   idle {:>4.1}%   comm {:.1} MB",
            sim.total_s * 1e3,
            sim.idle_fraction() * 100.0,
            sim.comm_bytes / 1e6
        );
    }
}
