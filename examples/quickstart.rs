//! Quickstart: run DISTFLASHATTN distributed attention over 4 workers with
//! real PJRT kernels, check it against the monolithic oracle, then show the
//! schedule that made it fast.
//!
//!     make artifacts && cargo run --offline --example quickstart

use distflash::coordinator::{RunSpec, Schedule, ScheduleKind, Session};
use distflash::runtime::{Runtime, Tensor, Value};
use distflash::util::Rng;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }

    // 1. load the artifact bundle and build random multi-head inputs
    let rt = Runtime::load(&dir)?;
    let c = rt.manifest().config.clone();
    println!(
        "model: {} | {} workers x {} tokens | {} heads x d{}",
        c.name, c.n_workers, c.chunk_len, c.n_heads, c.head_dim
    );
    let mut rng = Rng::new(0);
    let q = Tensor::new(vec![c.n_heads, c.seq_len, c.head_dim],
        rng.normal_vec(c.n_heads * c.seq_len * c.head_dim));
    let k = Tensor::new(vec![c.n_kv_heads, c.seq_len, c.head_dim],
        rng.normal_vec(c.n_kv_heads * c.seq_len * c.head_dim));
    let v = Tensor::new(vec![c.n_kv_heads, c.seq_len, c.head_dim],
        rng.normal_vec(c.n_kv_heads * c.seq_len * c.head_dim));

    // 2. the monolithic oracle (one device, full attention)
    let oracle = rt.run("full_attn_ref",
        &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())])?;

    // 3. DISTFLASHATTN: P worker threads, chunked sequence, P2P channels —
    //    one declarative RunSpec per schedule, driven through the Session
    //    pipeline (the workload comes from the manifest loaded above)
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let mut spec = RunSpec::pjrt(&dir, kind);
        spec.workload = Some(distflash::coordinator::Workload::new(
            c.n_heads,
            c.n_kv_heads,
            c.head_dim,
            c.chunk_len,
        ));
        spec.n_workers = c.n_workers;
        let mut session = Session::new(spec)?;
        session.execute_with(&q, &k, &v, None)?;
        let res = session.take_run().expect("executed").result;
        println!(
            "{kind:?}: max|Δ| vs oracle = {:.2e}, comm = {} bytes",
            res.o.max_abs_diff(&oracle[0]),
            res.comm_bytes
        );
    }

    // 4. why balanced wins: the schedules side by side
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let s = Schedule::build(kind, c.n_workers);
        println!(
            "{kind:?}: {} timesteps, {} idle slots, ideal speedup {:.2}x",
            s.n_steps(),
            s.idle_slots(),
            s.ideal_speedup()
        );
    }
    Ok(())
}
