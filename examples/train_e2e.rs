//! End-to-end distributed training driver (the EXPERIMENTS.md run).
//!
//! Trains a GPT-style model with sequence parallelism over P worker
//! threads: local QKV/MLP, *distributed* flash attention between them,
//! ring all-reduced gradients, Adam, rematerialization-aware gradient
//! checkpointing — the whole paper stack on a real (CPU PJRT) runtime.
//!
//!     make artifacts                         # exports train20m too
//!     cargo run --offline --release --example train_e2e -- train20m 200
//!
//! Arg 1 = artifact config (tiny | train20m | train100m), arg 2 = steps,
//! arg 3 (optional) = hf|remat checkpointing.

use distflash::coordinator::CkptStrategy;
use distflash::train::{train, AdamConfig, TrainConfig};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let config = args.first().map(String::as_str).unwrap_or("train20m").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let ckpt: CkptStrategy = args
        .get(2)
        .map(|s| s.parse().expect("ckpt = hf|remat"))
        .unwrap_or(CkptStrategy::RematAware);

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&config);
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/{config} missing — run `make artifacts`");
        return Ok(());
    }

    let cfg = TrainConfig {
        steps,
        ckpt,
        adam: AdamConfig { lr: 1e-3, ..Default::default() },
        seed: 7,
        log_every: 10,
        ..TrainConfig::new(&dir)
    };
    println!("== train_e2e: {config}, {steps} steps, ckpt={} ==", cfg.ckpt.name());
    let report = train(&cfg)?;

    let mut csv = String::from("step,loss,grad_norm,wall_s\n");
    for log in &report.logs {
        csv.push_str(&format!(
            "{},{:.6},{:.4},{:.3}\n",
            log.step, log.loss, log.grad_norm, log.wall_s
        ));
        if log.step % cfg.log_every == 0 || log.step + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  |g| {:.3}  {:.2}s/step",
                log.step, log.loss, log.grad_norm, log.wall_s
            );
        }
    }
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join(format!("loss_curve_{config}.csv"));
    std::fs::write(&out, csv)?;

    let first = report.logs.first().unwrap().loss;
    let last = report.logs.last().unwrap().loss;
    println!(
        "\nloss {first:.4} -> {last:.4} over {steps} steps \
         ({:.1}s wall, {:.0}% in kernels); curve written to {}",
        report.total_s,
        report.kernel_s / report.total_s * 100.0,
        out.display()
    );
    Ok(())
}
