//! Fixed-width text tables (the offline stand-in for a plotting stack).

#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header(&mut self, cols: Vec<String>) -> &mut Self {
        self.header = cols;
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain([self.header.len()])
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for row in std::iter::once(&self.header).chain(self.rows.iter()) {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |row: &[String]| -> String {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        let sep = format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("+")
        );
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        if !self.header.is_empty() {
            out.push_str(&line(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T");
        t.header(vec!["sys".into(), "time".into()]);
        t.row(vec!["ours".into(), "1.0".into()]);
        t.row(vec!["megatron-lm".into(), "2.0".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        let lines: Vec<&str> = s.lines().collect();
        // all table lines same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }
}
