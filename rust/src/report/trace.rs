//! Measured-vs-simulated: align a merged runtime trace ([`MergedTrace`])
//! with the event engine's per-op predictions — the first *measured*
//! validation of the repo's timing claims (ROADMAP: "Real-runtime event
//! trace").
//!
//! The executor and the simulator consume the same [`Plan`], so every
//! traced span has exactly one predicted op to compare against. Absolute
//! seconds are not comparable (host threads vs a modeled GPU cluster);
//! instead the cost model is *calibrated from the trace itself* — each
//! kernel class (diag / full / rescale) priced at its measured mean, and
//! transfers priced near zero (an in-process zero-copy send has no wire) —
//! and the event engine then replays the plan under that calibrated cost
//! on an idealized one-node cluster. What remains is a pure test of the
//! *scheduling structure*: do the plan's dependency edges, stream
//! disciplines, and barriers predict where time actually went? Reported
//! per op (duration spread and start-time skew) and in total (makespan
//! relative error).

use crate::config::{ClusterSpec, GpuSpec};
use crate::coordinator::executor::MergedTrace;
use crate::coordinator::plan::{Kernel, Plan, PlanOp};
use crate::report::Table;
use crate::simulator::{simulate_plan, AttnCost, EventOpts, EventResult};

/// Kernel classes the calibration distinguishes (transfers excluded: an
/// in-process send has no measurable wire time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Class {
    Diag,
    Full,
    Rescale,
}

fn class_of(plan: &Plan, op: usize) -> Option<Class> {
    match &plan.ops[op].op {
        PlanOp::Compute { kernel, pair } => match kernel {
            Kernel::AttnDiag => Some(Class::Diag),
            Kernel::AttnFull => Some(Class::Full),
            Kernel::AttnTok { .. } => match pair {
                Some((q, kv)) if q == kv => Some(Class::Diag),
                _ => Some(Class::Full),
            },
            Kernel::Rescale | Kernel::RescaleTok { .. } => Some(Class::Rescale),
            // decode attention prices off the full-pair class; kv-cache
            // bookkeeping off the rescale class (see `Kernel::seconds`)
            Kernel::DecodeAttn { .. } => Some(Class::Full),
            Kernel::KvAppend { .. } | Kernel::KvLookup { .. } => Some(Class::Rescale),
            Kernel::Accum | Kernel::KvEvict | Kernel::Raw(_) => None,
        },
        PlanOp::Xfer { .. } => None,
    }
}

/// Per-kernel-class measured/predicted aggregates.
#[derive(Clone, Debug)]
pub struct ClassStats {
    pub name: &'static str,
    pub count: usize,
    /// Mean measured kernel seconds (also the calibrated sim cost).
    pub measured_mean_s: f64,
    /// Mean |measured - calibrated| / calibrated across the class's ops —
    /// the per-op duration spread the single-cost model cannot express.
    pub duration_rel_err: f64,
}

/// One plan's trace-vs-sim alignment (see module docs).
#[derive(Clone, Debug)]
pub struct TraceComparison {
    pub classes: Vec<ClassStats>,
    /// Measured wall-clock: first traced start to last traced end.
    pub measured_total_s: f64,
    /// Event-engine makespan under the trace-calibrated cost.
    pub sim_total_s: f64,
    /// |measured - sim| / measured.
    pub total_rel_err: f64,
    /// Mean |measured op duration - predicted| / predicted over compute
    /// ops (duplicates the per-class spread, aggregated).
    pub per_op_duration_rel_err: f64,
    /// Worst per-op duration error.
    pub per_op_duration_max_err: f64,
    /// Mean |measured start - predicted start| / measured makespan —
    /// how well the schedule structure predicts *when* ops run.
    pub start_skew_frac: f64,
    pub n_ops_compared: usize,
}

/// Idealized cluster for calibrated replay: every rank on one node,
/// links effectively infinite (the in-process fabric has no wire).
fn host_cluster(p: usize) -> ClusterSpec {
    ClusterSpec {
        n_nodes: 1,
        gpus_per_node: p.max(1),
        gpu: GpuSpec::a100_80g(),
        intra_bw: 1e18,
        intra_lat: 0.0,
        inter_bw: 1e18,
        inter_lat: 0.0,
    }
}

/// The `(cost class, scale)` a kernel is actually *priced* with by
/// [`Kernel::seconds`] — the fit must invert exactly that mapping.
/// Token-scaled kernels price off the full/rescale class at their scale
/// (even on the diagonal), unlike the *reporting* buckets of `class_of`.
fn pricing_class(kernel: &Kernel) -> Option<(Class, f64)> {
    match kernel {
        Kernel::AttnDiag => Some((Class::Diag, 1.0)),
        Kernel::AttnFull => Some((Class::Full, 1.0)),
        Kernel::AttnTok { scale } => Some((Class::Full, *scale)),
        Kernel::Rescale => Some((Class::Rescale, 1.0)),
        Kernel::RescaleTok { scale } => Some((Class::Rescale, *scale)),
        Kernel::DecodeAttn { scale } => Some((Class::Full, *scale)),
        Kernel::KvAppend { scale } | Kernel::KvLookup { scale } => {
            Some((Class::Rescale, *scale))
        }
        Kernel::Accum | Kernel::KvEvict | Kernel::Raw(_) => None,
    }
}

/// Cost model calibrated from the measured kernel durations: each class is
/// fitted scale-normalized — `class_s = Σ duration / Σ scale` over the ops
/// priced with that class — so `scale × class_s` reproduces the measured
/// total exactly even on ragged (token-scaled) plans. Transfer payloads
/// are priced at one byte (≈ zero seconds on the idealized cluster) — the
/// sim then answers "given the measured kernel times, when would the
/// plan's structure run each op?".
pub fn calibrate_cost(plan: &Plan, trace: &MergedTrace) -> AttnCost {
    let mut dur = [0.0f64; 3];
    let mut scale = [0.0f64; 3];
    for op in 0..plan.ops.len() {
        if !trace.covered[op] {
            continue;
        }
        if let PlanOp::Compute { kernel, .. } = &plan.ops[op].op {
            if let Some((c, s)) = pricing_class(kernel) {
                dur[c as usize] += trace.op_duration(op);
                scale[c as usize] += s;
            }
        }
    }
    let fit = |i: usize| if scale[i] > 0.0 { dur[i] / scale[i] } else { 0.0 };
    AttnCost {
        pair_diag_s: fit(Class::Diag as usize),
        pair_full_s: fit(Class::Full as usize),
        rescale_s: fit(Class::Rescale as usize),
        kv_bytes: 1.0,
        q_bytes: 1.0,
        result_bytes: 1.0,
        overlap: true,
    }
}

/// Per-op measured durations for every covered, class-priced compute op:
/// `(op index, traced seconds)` pairs suitable for
/// [`crate::simulator::PlanSim::set_op_cost`]. This is the per-op
/// refinement of [`calibrate_cost`]: instead of collapsing the trace into
/// three class means, each op keeps its own duration — valid only while
/// the plan's op stream matches the traced plan's (the indices are
/// positional). Transfers are left out for the same reason
/// [`calibrate_cost`] prices them at one byte: the in-process fabric has
/// no measurable wire.
pub fn per_op_costs(plan: &Plan, trace: &MergedTrace) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    for op in 0..plan.ops.len() {
        if !trace.covered[op] {
            continue;
        }
        if let PlanOp::Compute { kernel, .. } = &plan.ops[op].op {
            if pricing_class(kernel).is_some() {
                out.push((op, trace.op_duration(op)));
            }
        }
    }
    out
}

/// Trace-calibrated cost model with a *modeled* transfer story: kernel
/// classes priced at their measured per-class means (exactly
/// [`calibrate_cost`]), byte classes carried over from `base`. The
/// in-process fabric measures no wire, so the modeled payload sizes remain
/// the honest transfer cost when the calibrated model is fed back into the
/// plan optimizer — this is what `Session::calibrate` installs before a
/// measured-cost `optimize()` pass.
pub fn calibrate_cost_with_bytes(plan: &Plan, trace: &MergedTrace, base: &AttnCost) -> AttnCost {
    let measured = calibrate_cost(plan, trace);
    AttnCost {
        pair_diag_s: measured.pair_diag_s,
        pair_full_s: measured.pair_full_s,
        rescale_s: measured.rescale_s,
        kv_bytes: base.kv_bytes,
        q_bytes: base.q_bytes,
        result_bytes: base.result_bytes,
        overlap: base.overlap,
    }
}

/// Compare a measured trace against the calibrated event-engine replay.
pub fn compare(plan: &Plan, trace: &MergedTrace) -> TraceComparison {
    let cost = calibrate_cost(plan, trace);
    let cluster = host_cluster(plan.n_workers);
    let sim: EventResult =
        simulate_plan(plan, &cluster, &cost, &EventOpts::for_plan(plan));

    // shift measured timestamps so both timelines start at zero
    let mut t0 = f64::INFINITY;
    for op in 0..plan.ops.len() {
        if trace.covered[op] {
            t0 = t0.min(trace.start_s[op]);
        }
    }
    if !t0.is_finite() {
        t0 = 0.0;
    }
    let measured_total_s = trace.makespan_s();

    let mut classes: Vec<(Class, &'static str, Vec<usize>)> = vec![
        (Class::Diag, "attn diag", Vec::new()),
        (Class::Full, "attn full", Vec::new()),
        (Class::Rescale, "rescale", Vec::new()),
    ];
    for op in 0..plan.ops.len() {
        if !trace.covered[op] {
            continue;
        }
        if let Some(c) = class_of(plan, op) {
            classes.iter_mut().find(|(k, _, _)| *k == c).unwrap().2.push(op);
        }
    }

    let mut dur_err_sum = 0.0;
    let mut dur_err_max = 0.0f64;
    let mut start_skew_sum = 0.0;
    let mut n = 0usize;
    let mut out_classes = Vec::new();
    for (_, name, ops) in &classes {
        if ops.is_empty() {
            continue;
        }
        let mut meas_sum = 0.0;
        let mut err_sum = 0.0;
        for &op in ops {
            let meas = trace.op_duration(op);
            let pred = sim.op_duration(op);
            meas_sum += meas;
            let err = if pred > 0.0 { (meas - pred).abs() / pred } else { 0.0 };
            err_sum += err;
            dur_err_sum += err;
            dur_err_max = dur_err_max.max(err);
            if measured_total_s > 0.0 {
                start_skew_sum +=
                    ((trace.start_s[op] - t0) - sim.op_start[op]).abs() / measured_total_s;
            }
            n += 1;
        }
        out_classes.push(ClassStats {
            name,
            count: ops.len(),
            measured_mean_s: meas_sum / ops.len() as f64,
            duration_rel_err: err_sum / ops.len() as f64,
        });
    }

    let total_rel_err = if measured_total_s > 0.0 {
        (measured_total_s - sim.total_s).abs() / measured_total_s
    } else {
        0.0
    };
    TraceComparison {
        classes: out_classes,
        measured_total_s,
        sim_total_s: sim.total_s,
        total_rel_err,
        per_op_duration_rel_err: if n > 0 { dur_err_sum / n as f64 } else { 0.0 },
        per_op_duration_max_err: dur_err_max,
        start_skew_frac: if n > 0 { start_skew_sum / n as f64 } else { 0.0 },
        n_ops_compared: n,
    }
}

/// Render one or more labeled comparisons (typically fwd + bwd of one
/// call) as the `repro trace` table.
pub fn render(title: &str, rows: &[(&str, &TraceComparison)]) -> String {
    let mut t = Table::new(title);
    t.header(
        [
            "pass", "class", "ops", "measured mean", "dur err", "start skew",
            "measured total", "sim total", "total err",
        ]
        .map(String::from)
        .to_vec(),
    );
    for (label, c) in rows {
        for (i, cs) in c.classes.iter().enumerate() {
            let (tail_meas, tail_sim, tail_err, skew) = if i == 0 {
                (
                    format!("{:.2} ms", c.measured_total_s * 1e3),
                    format!("{:.2} ms", c.sim_total_s * 1e3),
                    format!("{:.1}%", c.total_rel_err * 100.0),
                    format!("{:.1}%", c.start_skew_frac * 100.0),
                )
            } else {
                (String::new(), String::new(), String::new(), String::new())
            };
            t.row(vec![
                if i == 0 { (*label).to_string() } else { String::new() },
                cs.name.to_string(),
                format!("{}", cs.count),
                format!("{:.3} ms", cs.measured_mean_s * 1e3),
                format!("{:.1}%", cs.duration_rel_err * 100.0),
                skew,
                tail_meas,
                tail_sim,
                tail_err,
            ]);
        }
    }
    t.render()
}

/// Per-layer timeline rows for a stacked (multi-call) traced run: one row
/// per labeled trace (layer × pass) with start offset, end, and makespan
/// relative to the earliest span across all rows — the layer-level view
/// `repro trace --layers` and the trainer's trace sink surface.
pub fn layer_timeline(title: &str, rows: &[(String, &MergedTrace)]) -> String {
    let mut t0 = f64::INFINITY;
    for (_, tr) in rows {
        for i in 0..tr.covered.len() {
            if tr.covered[i] {
                t0 = t0.min(tr.start_s[i]);
            }
        }
    }
    if !t0.is_finite() {
        t0 = 0.0;
    }
    let mut t = Table::new(title);
    t.header(
        ["span", "start (ms)", "end (ms)", "makespan (ms)", "ops"]
            .map(String::from)
            .to_vec(),
    );
    for (label, tr) in rows {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut n = 0usize;
        for i in 0..tr.covered.len() {
            if tr.covered[i] {
                lo = lo.min(tr.start_s[i]);
                hi = hi.max(tr.end_s[i]);
                n += 1;
            }
        }
        if n == 0 {
            continue;
        }
        t.row(vec![
            label.clone(),
            format!("{:.3}", (lo - t0) * 1e3),
            format!("{:.3}", (hi - t0) * 1e3),
            format!("{:.3}", (hi - lo) * 1e3),
            format!("{n}"),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Schedule;
    use crate::coordinator::plan::Pass;

    /// A synthetic trace that replays the simulator's own timeline must
    /// align with ~zero error — the comparison is exact on its fixed
    /// point.
    #[test]
    fn self_consistent_trace_has_zero_error() {
        let plan = Plan::from_schedule(&Schedule::balanced(4), Pass::Forward);
        let cost = AttnCost {
            pair_full_s: 2e-3,
            pair_diag_s: 1e-3,
            rescale_s: 1e-4,
            kv_bytes: 1.0,
            q_bytes: 1.0,
            result_bytes: 1.0,
            overlap: true,
        };
        let cluster = host_cluster(plan.n_workers);
        let sim = simulate_plan(&plan, &cluster, &cost, &EventOpts::for_plan(&plan));
        let mut trace = MergedTrace {
            start_s: sim.op_start.clone(),
            end_s: sim.op_finish.clone(),
            covered: vec![false; plan.n_ops()],
            ops_per_step: MergedTrace::step_counts(&plan),
            threads: 1,
            tiles: None,
        };
        for (op, node) in plan.ops.iter().enumerate() {
            if matches!(node.op, PlanOp::Compute { .. }) {
                trace.covered[op] = true;
            }
        }
        let c = compare(&plan, &trace);
        assert!(c.n_ops_compared > 0);
        assert!(c.total_rel_err < 1e-9, "total err {}", c.total_rel_err);
        assert!(c.per_op_duration_rel_err < 1e-9);
        assert!(c.start_skew_frac < 1e-9, "skew {}", c.start_skew_frac);
        let s = render("trace", &[("fwd", &c)]);
        assert!(s.contains("attn full") && s.contains("total err"));

        // the byte-preserving calibration keeps the modeled transfer
        // classes while adopting the measured kernel means
        let cal = calibrate_cost_with_bytes(&plan, &trace, &cost);
        assert_eq!(cal.kv_bytes, cost.kv_bytes);
        assert_eq!(cal.q_bytes, cost.q_bytes);
        assert!((cal.pair_full_s - cost.pair_full_s).abs() < 1e-12);

        // the per-op refinement returns every covered class-priced compute
        // at its traced duration verbatim
        let oc = per_op_costs(&plan, &trace);
        assert!(!oc.is_empty());
        for &(op, s) in &oc {
            assert!(trace.covered[op]);
            assert!((s - trace.op_duration(op)).abs() < 1e-15);
        }

        // and the same trace renders as a (single-row) layer timeline
        let tl = layer_timeline("layers", &[("L0 fwd".to_string(), &trace)]);
        assert!(tl.contains("L0 fwd") && tl.contains("makespan"));
    }
}
