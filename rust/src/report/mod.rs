//! Table / figure rendering for the paper reproductions: fixed-width text
//! tables matching the rows the paper prints, plus simple ASCII series for
//! the figures.

pub mod paper;
pub mod table;
pub mod trace;

pub use table::Table;

/// Render an (x, series...) dataset as aligned columns — the "figure"
/// format for Fig. 1/4/7 reproductions in a terminal.
pub fn render_series(
    title: &str,
    x_label: &str,
    xs: &[String],
    series: &[(&str, Vec<f64>)],
    unit: &str,
) -> String {
    let mut t = Table::new(title);
    let mut header = vec![x_label.to_string()];
    header.extend(series.iter().map(|(n, _)| format!("{n} ({unit})")));
    t.header(header);
    for (i, x) in xs.iter().enumerate() {
        let mut row = vec![x.clone()];
        for (_, ys) in series {
            row.push(format!("{:.3}", ys[i]));
        }
        t.row(row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn series_renders() {
        let s = super::render_series(
            "Fig X",
            "seq",
            &["4K".into(), "8K".into()],
            &[("ours", vec![1.0, 2.0]), ("base", vec![2.0, 4.0])],
            "s",
        );
        assert!(s.contains("Fig X"));
        assert!(s.contains("4K"));
        assert!(s.contains("2.000"));
    }
}
