//! Regeneration of every table and figure in the paper's evaluation
//! (§4, Appendix C/D), from the analytic models in `baselines` + the
//! schedule simulator. Each function renders our measured/modeled numbers
//! next to the paper's published ones so the reproduction gap is explicit.
//!
//! Used by `repro tables|figures` and by `cargo bench --bench paper_tables`.

use std::sync::Arc;

use crate::baselines::distflash::DistFlashAttn;
use crate::baselines::megatron::{pp_stage_memory, Megatron};
use crate::baselines::ring_attention::RingAttention;
use crate::baselines::rsa::RingSelfAttention;
use crate::baselines::ulysses::Ulysses;
use crate::baselines::{attn_cost_bwd, attn_cost_fwd, fsdp_param_bytes, SystemModel};
use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::coordinator::optimize::{autotune_depth, optimize_ckpt, OptimizeOpts};
use crate::coordinator::{
    BackendSpec, CkptStrategy, CrashSpec, FaultSpec, OptimizePolicy, Pass, Plan, RecoveryPolicy,
    RunSpec, Schedule, ScheduleKind, Session, VarlenSpec, Workload,
};
use crate::memory::{fmt_bytes, fmt_seq, max_total_seq_pow2};
use crate::report::Table;
use crate::runtime::{HostKernels, Kernels, Tensor, Value};
use crate::simulator::{simulate_plan, EventOpts, EventResult};

fn k(tokens: usize) -> String {
    fmt_seq(tokens)
}

/// Table 1: per-iteration wall-clock, DISTFLASHATTN vs Megatron-LM on
/// LLaMA-7B / LLaMA-GQA / LLaMA-33H, 1×8 and 2×8, 8K–32K per GPU.
pub fn table1() -> String {
    // paper numbers (seconds): [model][cluster][seq] -> (megatron, ours)
    let paper: &[(&str, &str, usize, f64, f64)] = &[
        ("LLaMA-7B", "1x8", 8192, 6.81, 5.98),
        ("LLaMA-7B", "1x8", 16384, 20.93, 17.26),
        ("LLaMA-7B", "1x8", 32768, 72.75, 58.46),
        ("LLaMA-7B", "2x8", 8192, 14.26, 12.75),
        ("LLaMA-7B", "2x8", 16384, 43.44, 30.21),
        ("LLaMA-7B", "2x8", 32768, 147.06, 106.37),
        ("LLaMA-GQA", "1x8", 8192, 6.60, 5.61),
        ("LLaMA-GQA", "1x8", 16384, 20.53, 16.86),
        ("LLaMA-GQA", "1x8", 32768, 71.93, 57.01),
        ("LLaMA-GQA", "2x8", 8192, 14.21, 9.74),
        ("LLaMA-GQA", "2x8", 16384, 43.20, 28.49),
        ("LLaMA-GQA", "2x8", 32768, 146.38, 102.34),
        ("LLaMA-33H", "1x8", 8192, 8.37, 6.08),
        ("LLaMA-33H", "1x8", 16384, 25.75, 17.77),
        ("LLaMA-33H", "1x8", 32768, 90.21, 59.96),
        ("LLaMA-33H", "2x8", 8192, 20.63, 13.12),
        ("LLaMA-33H", "2x8", 16384, 62.78, 31.33),
        ("LLaMA-33H", "2x8", 32768, 216.70, 107.76),
    ];
    let mut t = Table::new("Table 1 — per-iteration time (s): DISTFLASHATTN vs Megatron-LM");
    t.header(
        ["model", "cluster", "seq/GPU", "megatron(s)", "ours(s)", "speedup", "paper-mg", "paper-ours", "paper-spd"]
            .map(String::from)
            .to_vec(),
    );
    for &(mname, cl, seq, pm, po) in paper {
        let model = PaperModel::by_name(mname).unwrap();
        let cluster = if cl == "1x8" { ClusterSpec::dgx_1x8() } else { ClusterSpec::dgx_2x8() };
        let mg = Megatron::tp().iteration(&model, &cluster, seq).total_s();
        let ours = DistFlashAttn::default().iteration(&model, &cluster, seq).total_s();
        t.row(vec![
            mname.into(),
            cl.into(),
            k(seq),
            format!("{mg:.2}"),
            format!("{ours:.2}"),
            format!("{:.2}x", mg / ours),
            format!("{pm:.2}"),
            format!("{po:.2}"),
            format!("{:.2}x", pm / po),
        ]);
    }
    t.render()
}

/// Table 2: max sequence length on 16×A100-40GB for the fewer-heads family
/// under Megatron TP+DP / TP+PP / DISTFLASHATTN.
pub fn table2() -> String {
    let cluster = ClusterSpec::cluster_16x40g();
    // paper totals: (model, tp_dp, tp_pp, ours) — "" = not reported
    let paper: &[(&str, &str, &str, &str)] = &[
        ("llama-16h", "512K", "512K", "512K"),
        ("llama-8h", "256K", "256K", "512K"),
        ("llama-4h", "128K", "256K", "512K"),
        ("llama-2h", "64K", "128K", "512K"),
    ];
    let mut t = Table::new("Table 2 — max total sequence on 16xA100-40GB");
    t.header(
        ["model", "TP+DP", "TP+PP", "ours", "paper TP+DP", "paper TP+PP", "paper ours"]
            .map(String::from)
            .to_vec(),
    );
    for &(name, p1, p2, p3) in paper {
        let model = PaperModel::by_name(name).unwrap();
        let a = max_total_seq_pow2(&Megatron::tp_dp(), &model, &cluster);
        let b = max_total_seq_pow2(&Megatron::tp_pp(), &model, &cluster);
        let c = max_total_seq_pow2(&DistFlashAttn::default(), &model, &cluster);
        t.row(vec![
            model.name.into(),
            k(a),
            k(b),
            k(c),
            p1.into(),
            p2.into(),
            p3.into(),
        ]);
    }
    t.render()
}

/// Table 3: max sequence + per-iteration time vs Ring Self-Attention.
pub fn table3() -> String {
    let model = PaperModel::llama_7b();
    let mut t = Table::new("Table 3 — vs Ring Self-Attention (LLaMA-7B, DGX)");
    t.header(
        ["cluster", "RSA max", "ours max", "RSA iter(s)", "ours iter(s)", "speedup", "paper"]
            .map(String::from)
            .to_vec(),
    );
    for (cl, cluster, paper_rsa_max, paper_note) in [
        ("1 node", ClusterSpec::dgx_1x8(), 32 * 1024usize, "max 32K vs >256K; 5.64x @32K"),
        ("2 nodes", ClusterSpec::dgx_2x8(), 64 * 1024usize, "max 64K vs >512K; 4.45x @64K"),
    ] {
        let rsa_max = max_total_seq_pow2(&RingSelfAttention, &model, &cluster);
        let ours_max = max_total_seq_pow2(&DistFlashAttn::default(), &model, &cluster);
        // iteration time at RSA's paper max
        let seq_gpu = paper_rsa_max / cluster.n_gpus();
        let slow = RingSelfAttention.iteration(&model, &cluster, seq_gpu).total_s();
        let fast = DistFlashAttn::default().iteration(&model, &cluster, seq_gpu).total_s();
        t.row(vec![
            cl.into(),
            k(rsa_max),
            format!(">{}", k(ours_max)),
            format!("{slow:.2}"),
            format!("{fast:.2}"),
            format!("{:.2}x", slow / fast),
            paper_note.into(),
        ]);
    }
    t.render()
}

/// Table 4: vs DeepSpeed-Ulysses (LLaMA-7B and LLaMA-33H, 2×8).
pub fn table4() -> String {
    let cluster = ClusterSpec::dgx_2x8();
    let paper: &[(&str, usize, f64, f64)] = &[
        ("llama-7b", 16384, 37.53, 30.21),
        ("llama-7b", 32768, 134.09, 106.37),
        ("llama-33h", 16384, 56.63, 31.33),
        ("llama-33h", 32768, 202.89, 107.76),
    ];
    let mut t = Table::new("Table 4 — vs DeepSpeed-Ulysses (2x8)");
    t.header(
        ["model", "seq/GPU", "ulysses(s)", "ours(s)", "speedup", "paper-uly", "paper-ours", "paper-spd"]
            .map(String::from)
            .to_vec(),
    );
    for &(name, seq, pu, po) in paper {
        let model = PaperModel::by_name(name).unwrap();
        let u = Ulysses.iteration(&model, &cluster, seq).total_s();
        let o = DistFlashAttn::default().iteration(&model, &cluster, seq).total_s();
        t.row(vec![
            model.name.into(),
            k(seq),
            format!("{u:.2}"),
            format!("{o:.2}"),
            format!("{:.2}x", u / o),
            format!("{pu:.2}"),
            format!("{po:.2}"),
            format!("{:.2}x", pu / po),
        ]);
    }
    t.render()
}

/// Table 5: rematerialization-aware vs HF checkpointing, 1K–32K per GPU.
pub fn table5() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let paper: &[(usize, Option<f64>, f64)] = &[
        (1024, None, 0.84),
        (2048, Some(1.29), 1.36),
        (4096, Some(2.64), 2.50),
        (8192, Some(6.93), 5.98),
        (16384, Some(21.44), 17.26),
        (32768, Some(76.38), 58.46),
    ];
    let ours_sys = DistFlashAttn::default();
    let hf_sys = DistFlashAttn { ckpt: CkptStrategy::HfStyle, ..ours_sys };
    let mut t = Table::new("Table 5 — checkpointing strategies (8xA100, LLaMA-7B)");
    t.header(
        ["seq/GPU", "HF ckpt(s)", "ours(s)", "speedup", "paper-HF", "paper-ours", "paper-spd"]
            .map(String::from)
            .to_vec(),
    );
    for &(seq, phf, pours) in paper {
        let hf = hf_sys.iteration(&model, &cluster, seq).total_s();
        let ours = ours_sys.iteration(&model, &cluster, seq).total_s();
        t.row(vec![
            k(seq),
            format!("{hf:.2}"),
            format!("{ours:.2}"),
            format!("{:.2}x", hf / ours),
            phf.map(|x| format!("{x:.2}")).unwrap_or_default(),
            format!("{pours:.2}"),
            phf.map(|x| format!("{:.2}x", x / pours)).unwrap_or_default(),
        ]);
    }
    t.render()
}

/// Table 6 (Appendix C): Megatron TP+PP per-stage memory, LLaMA-2H @ 128K.
pub fn table6() -> String {
    let model = PaperModel::llama_nh(2);
    let cluster = ClusterSpec::cluster_16x40g();
    let seq_per_gpu = 128 * 1024 / cluster.n_gpus();
    let stages = pp_stage_memory(&model, &cluster, seq_per_gpu, 2, 8);
    let paper = [
        [31.5, 31.4, 28.7, 28.7, 26.0, 26.0, 24.6, 24.6],
        [21.8, 21.8, 20.5, 20.5, 17.9, 17.8, 32.0, 32.1],
    ];
    let mut t = Table::new("Table 6 — Megatron TP2+PP8 per-stage memory, LLaMA-2H @128K");
    t.header(
        ["stage", "modeled", "paper node1", "paper node2"]
            .map(String::from)
            .to_vec(),
    );
    for (i, s) in stages.iter().enumerate() {
        t.row(vec![
            format!("{i}"),
            fmt_bytes(*s),
            format!("{}GB", paper[0][i]),
            format!("{}GB", paper[1][i]),
        ]);
    }
    t.render()
}

/// Figure 1: idle fraction of ring vs balanced scheduling as P grows.
pub fn fig1() -> String {
    let ps = [2usize, 4, 7, 8, 15, 16, 32, 64];
    let xs: Vec<String> = ps.iter().map(|p| p.to_string()).collect();
    let ring: Vec<f64> = ps
        .iter()
        .map(|&p| crate::coordinator::schedule::ring_idle_fraction(p))
        .collect();
    let bal: Vec<f64> = ps
        .iter()
        .map(|&p| crate::coordinator::schedule::balanced_idle_fraction_eq2(p))
        .collect();
    crate::report::render_series(
        "Figure 1 — idle fraction (ring -> 1/2, balanced -> 0)",
        "P",
        &xs,
        &[("ring (unbalanced)", ring), ("load-balanced (ours)", bal)],
        "fraction",
    )
}

/// Figure 2: per-step timeline of worker roles under the balanced schedule
/// with overlap, 8 workers (a textual rendition of the paper's diagram).
pub fn fig2() -> String {
    let s = Schedule::balanced(8);
    let mut out = String::from("## Figure 2 — balanced schedule timeline (P=8)\n");
    out.push_str("rows = workers, cols = timesteps; D=diag, O<r>=own(kv from r), H<o>=help(for o), .=idle\n");
    for w in 0..8 {
        let mut line = format!("w{w}: ");
        for row in &s.steps {
            let cell = match row[w].compute {
                Some(crate::coordinator::ComputeOp::Diag) => "D   ".to_string(),
                Some(crate::coordinator::ComputeOp::Own { kv_from }) => format!("O{kv_from}  "),
                Some(crate::coordinator::ComputeOp::Help { owner }) => format!("H{owner}  "),
                None => ".   ".to_string(),
            };
            line.push_str(&cell);
        }
        out.push_str(&line);
        out.push('\n');
    }
    out
}

/// Figure 4 left: attention speedup vs single-GPU FlashAttention, balanced
/// vs unbalanced, total sequence 4K → 256K on 8 GPUs.
pub fn fig4_left() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let totals = [4096usize, 8192, 16384, 32768, 65536, 131072, 262144];
    let xs: Vec<String> = totals.iter().map(|&t| k(t)).collect();
    let mut bal = Vec::new();
    let mut ring = Vec::new();
    for &total in &totals {
        let c = total / 8;
        let single = cluster.compute_time(
            model.attn_pair_flops(total as f64, total as f64, true),
            cluster.gpu.mfu_attn,
        );
        let b = DistFlashAttn::default().attn_sim(&model, &cluster, c, false);
        let r = DistFlashAttn {
            schedule: ScheduleKind::Ring,
            ..DistFlashAttn::default()
        }
        .attn_sim(&model, &cluster, c, false);
        bal.push(single / b.total_s);
        ring.push(single / r.total_s);
    }
    crate::report::render_series(
        "Figure 4 (left) — attention speedup vs 1-GPU flash (paper: ring->4.5x, balanced->7.5x)",
        "total seq",
        &xs,
        &[("balanced (ours)", bal), ("unbalanced ring", ring)],
        "x",
    )
}

/// Figure 4 right: communication overhead with/without overlap (2×8).
pub fn fig4_right() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_2x8();
    let totals = [32768usize, 65536, 131072, 262144, 524288];
    let xs: Vec<String> = totals.iter().map(|&t| k(t)).collect();
    let mut with = Vec::new();
    let mut without = Vec::new();
    for &total in &totals {
        let c = total / 16;
        let sys = DistFlashAttn::default();
        let on = sys.attn_sim(&model, &cluster, c, false);
        let off = DistFlashAttn { overlap: false, ..sys }.attn_sim(&model, &cluster, c, false);
        // compute-only baseline: same schedule with zero comm bytes
        let base = {
            let schedule = Schedule::balanced(16);
            let mut cost = attn_cost_fwd(&model, &cluster, c as f64);
            cost.kv_bytes = 0.0;
            cost.q_bytes = 0.0;
            cost.result_bytes = 0.0;
            crate::simulator::simulate_attention(&schedule, &cluster, &cost).total_s
        };
        with.push((on.total_s - base) / base * 100.0);
        without.push((off.total_s - base) / base * 100.0);
    }
    crate::report::render_series(
        "Figure 4 (right) — comm overhead % (paper @128K: 105% -> 44%)",
        "total seq",
        &xs,
        &[("no overlap", without), ("overlap (ours)", with)],
        "%",
    )
}

/// Figure 7: forward-pass time breakdown, attention vs the rest, one GPU.
pub fn fig7() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let seqs = [1024usize, 2048, 4096, 8192, 16384, 32768, 65536];
    let xs: Vec<String> = seqs.iter().map(|&s| k(s)).collect();
    let mut attn_ms = Vec::new();
    let mut other_ms = Vec::new();
    let mut frac = Vec::new();
    for &n in &seqs {
        let a = cluster.compute_time(
            model.attn_pair_flops(n as f64, n as f64, true),
            cluster.gpu.mfu_attn,
        ) * 1e3;
        let o = cluster.compute_time(model.layer_linear_flops(n as f64), cluster.gpu.mfu_gemm) * 1e3;
        attn_ms.push(a);
        other_ms.push(o);
        frac.push(a / (a + o) * 100.0);
    }
    crate::report::render_series(
        "Figure 7 — per-layer fwd time: attention dominates at long seq (paper: ~230ms @64K)",
        "seq",
        &xs,
        &[
            ("attention (ms)", attn_ms),
            ("other modules (ms)", other_ms),
            ("attention share (%)", frac),
        ],
        "",
    )
}

/// Executed schedules: one event engine, four plans through the same IR —
/// our two lowered schedules plus the Ring Attention and Ulysses dataflow
/// plans. This is the executed-timing counterpart of the closed-form
/// baseline tables (LLaMA-7B, one DGX, 8K tokens/GPU, forward).
pub fn executed_schedules() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let seq = 8192usize;
    let cost = attn_cost_fwd(&model, &cluster, seq as f64);
    let opts = EventOpts::default();
    let bal_plan = Schedule::balanced(8).lower(Pass::Forward);
    let ring_plan = Schedule::ring(8).lower(Pass::Forward);
    let ra_plan = RingAttention::plan(8);
    let uly_plan = Ulysses::attn_plan(&model, &cluster, seq);
    let rows: Vec<(&str, EventResult)> = vec![
        (
            "balanced (ours, Alg. 2)",
            simulate_plan(&bal_plan, &cluster, &cost, &opts),
        ),
        ("ring (Alg. 1)", simulate_plan(&ring_plan, &cluster, &cost, &opts)),
        (
            "ring-attention pipeline",
            simulate_plan(&ra_plan, &cluster, &cost, &opts),
        ),
        (
            "ulysses all-to-all",
            simulate_plan(&uly_plan, &cluster, &cost, &opts),
        ),
    ];
    // autotuned prefetch depth per plan — depth 1 alone was a blind spot:
    // comm-bound plans keep improving past it and the knee is the honest
    // "what the system would run" number
    let plans: Vec<&crate::coordinator::Plan> = vec![&bal_plan, &ring_plan, &ra_plan, &uly_plan];
    let base = rows[0].1.total_s;
    let mut t = Table::new("Executed schedules — event engine over one IR (LLaMA-7B, 1x8, 8K/GPU fwd)");
    t.header(
        ["plan", "attn fwd (ms)", "vs ours", "comm (MB)", "idle %", "auto (ms)", "depth*"]
            .map(String::from)
            .to_vec(),
    );
    for ((name, r), &plan) in rows.iter().zip(&plans) {
        let (depth, auto_s) = autotune_depth(plan, &cluster, &cost, &OptimizeOpts::default());
        t.row(vec![
            (*name).into(),
            format!("{:.2}", r.total_s * 1e3),
            format!("{:.2}x", r.total_s / base),
            format!("{:.1}", r.comm_bytes / 1e6),
            format!("{:.1}", r.idle_fraction() * 100.0),
            format!("{:.2}", auto_s * 1e3),
            format!("{depth}"),
        ]);
    }
    t.render()
}

/// One row of the plan-optimizer comparison grid — shared by the
/// `optimized_schedules` table and `repro bench --json`
/// (`BENCH_optimizer.json`), so the perf trajectory is tracked in one
/// machine-readable place across PRs.
#[derive(Clone, Debug)]
pub struct OptRow {
    pub model: &'static str,
    pub cluster: &'static str,
    pub seq_per_gpu: usize,
    pub pass: &'static str,
    pub default_s: f64,
    pub optimized_s: f64,
    pub prefetch_depth: usize,
    pub flipped_steps: usize,
    pub moved_ranks: usize,
    /// Event-engine passes the stage spent, including the session's
    /// acceptance scoring (from [`crate::coordinator::StageAudit`]).
    pub sim_calls: usize,
    /// Whether the session's accept-only-if-not-worse rule kept the
    /// optimized candidate.
    pub accepted: bool,
}

impl OptRow {
    pub fn speedup(&self) -> f64 {
        self.default_s / self.optimized_s
    }
}

/// Run the optimizer over a representative (model, cluster, seq, pass)
/// grid: the homogeneous box (where the default lowering is already
/// near-optimal and the optimizer must not pessimize), the paper's 2×8
/// InfiniBand setup, and the bandwidth-starved dev cluster — with the GQA
/// model exercising the role-flipping pass and backward passes exercising
/// the fat (q, o, lse, do) bundles.
///
/// Each cell drives the full [`Session`] pipeline (plan → optimize) so
/// the published numbers carry the session's acceptance rule and audited
/// sim-call budget, not a bare optimizer invocation.
pub fn optimizer_rows() -> Vec<OptRow> {
    let grid: &[(&'static str, &'static str, usize, &'static str)] = &[
        ("llama-7b", "1x8", 8192, "fwd"),
        ("llama-7b", "2x8", 8192, "fwd"),
        ("llama-gqa", "2x8", 2048, "fwd"),
        ("llama-gqa", "2x8", 2048, "bwd"),
        ("llama-gqa", "16x40g", 4096, "fwd"),
        ("llama-gqa", "16x40g", 4096, "bwd"),
    ];
    let mut out = Vec::new();
    for &(mname, cname, seq, pass_name) in grid {
        let model = PaperModel::by_name(mname).unwrap();
        let cluster = match cname {
            "1x8" => ClusterSpec::dgx_1x8(),
            "2x8" => ClusterSpec::dgx_2x8(),
            _ => ClusterSpec::cluster_16x40g(),
        };
        let p = cluster.n_gpus();
        let pass = match pass_name {
            "fwd" => Pass::Forward,
            _ => Pass::Backward,
        };
        let fwd_cost = attn_cost_fwd(&model, &cluster, seq as f64);
        let bwd_cost = attn_cost_bwd(&model, &cluster, seq as f64);
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, p);
        spec.workload =
            Some(Workload::new(model.n_heads, model.n_kv_heads, model.head_dim, seq));
        spec.cluster = cluster;
        spec.optimize = OptimizePolicy::Schedule(OptimizeOpts::default());
        let mut session = Session::new(spec).expect("bench spec is valid");
        session.set_costs(fwd_cost, bwd_cost);
        session.optimize().expect("bench grid optimizes");
        let a = session
            .audits()
            .iter()
            .find(|a| a.pass == pass)
            .expect("optimize() audits both passes")
            .clone();
        out.push(OptRow {
            model: mname,
            cluster: cname,
            seq_per_gpu: seq,
            pass: pass_name,
            default_s: a.default_s,
            optimized_s: a.optimized_s,
            prefetch_depth: a.prefetch_depth,
            flipped_steps: a.flipped_steps.len(),
            moved_ranks: a.moved_ranks,
            sim_calls: a.sim_calls,
            accepted: a.accepted,
        });
    }
    out
}

/// Optimized schedules: default lowering vs the plan optimizer's output
/// per (model, cluster, seq) — the executed-timing evidence that deriving
/// the plan for the machine beats reproducing the paper's plan verbatim.
pub fn optimized_schedules() -> String {
    let mut t = Table::new(
        "Optimized schedules — plan optimizer vs default lowering (balanced, event engine)",
    );
    t.header(
        ["model", "cluster", "seq/GPU", "pass", "default (ms)", "optimized (ms)", "speedup", "depth*", "flips", "moves"]
            .map(String::from)
            .to_vec(),
    );
    for r in optimizer_rows() {
        t.row(vec![
            r.model.into(),
            r.cluster.into(),
            k(r.seq_per_gpu),
            r.pass.into(),
            format!("{:.2}", r.default_s * 1e3),
            format!("{:.2}", r.optimized_s * 1e3),
            format!("{:.2}x", r.speedup()),
            format!("{}", r.prefetch_depth),
            format!("{}", r.flipped_steps),
            format!("{}", r.moved_ranks),
        ]);
    }
    t.render()
}

/// One row of the varlen (document-packed) comparison grid — shared by
/// the `varlen_schedules` table and `repro bench --json`
/// (`BENCH_varlen.json`), tracking the token-level rebalancer's win over
/// pad-to-max across PRs.
#[derive(Clone, Debug)]
pub struct VarlenRow {
    pub model: &'static str,
    pub cluster: &'static str,
    pub n_docs: usize,
    pub zipf_alpha: f64,
    /// Average tokens per GPU (total packed tokens / P).
    pub seq_per_gpu: usize,
    pub pass: &'static str,
    pub pad_s: f64,
    pub equal_s: f64,
    pub optimized_s: f64,
    pub prefetch_depth: usize,
    pub flipped_pairs: usize,
    pub moved_boundaries: usize,
    /// Event-engine passes the stage spent, including the session's
    /// joint-acceptance scoring (from [`crate::coordinator::StageAudit`]).
    pub sim_calls: usize,
    pub incremental_rescores: usize,
    /// Whether the session kept the rebalanced `(fwd, bwd)` pair.
    pub accepted: bool,
}

impl VarlenRow {
    pub fn speedup_vs_pad(&self) -> f64 {
        self.pad_s / self.optimized_s
    }

    pub fn speedup_vs_equal(&self) -> f64 {
        self.equal_s / self.optimized_s
    }
}

/// Run the token-level rebalancer over a representative grid of
/// Zipf-packed batches: the paper's 2×8 InfiniBand setup (fwd + bwd, GQA
/// for the flip-heavy regime) plus the homogeneous box. Deterministic
/// (fixed packing seed), so the JSON baseline is comparable PR-over-PR.
///
/// Each cell drives the full [`Session`] varlen pipeline, so fwd and bwd
/// share one chunking under the joint accept-only-if-not-worse rule and
/// the published sim-call budget is the audited one.
pub fn varlen_rows() -> Vec<VarlenRow> {
    let grid: &[(&'static str, &'static str, usize, f64, usize, &'static str)] = &[
        ("llama-7b", "2x8", 64, 1.1, 2048, "fwd"),
        ("llama-7b", "2x8", 64, 1.1, 2048, "bwd"),
        ("llama-gqa", "2x8", 64, 1.1, 2048, "fwd"),
        ("llama-7b", "1x8", 32, 1.2, 4096, "fwd"),
    ];
    let mut out = Vec::new();
    for &(mname, cname, n_docs, alpha, seq, pass_name) in grid {
        let model = PaperModel::by_name(mname).unwrap();
        let cluster = match cname {
            "1x8" => ClusterSpec::dgx_1x8(),
            "2x8" => ClusterSpec::dgx_2x8(),
            _ => ClusterSpec::cluster_16x40g(),
        };
        let p = cluster.n_gpus();
        let vspec = VarlenSpec::pack_zipf(n_docs, seq * p, alpha, 17, p);
        let pass = match pass_name {
            "fwd" => Pass::Forward,
            _ => Pass::Backward,
        };
        let fwd_cost = attn_cost_fwd(&model, &cluster, seq as f64);
        let bwd_cost = attn_cost_bwd(&model, &cluster, seq as f64);
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, p);
        spec.workload =
            Some(Workload::new(model.n_heads, model.n_kv_heads, model.head_dim, seq));
        spec.varlen = Some(vspec);
        spec.cluster = cluster;
        spec.optimize = OptimizePolicy::Varlen(OptimizeOpts::default());
        let mut session = Session::new(spec).expect("bench spec is valid");
        session.set_costs(fwd_cost, bwd_cost);
        session.optimize().expect("bench grid optimizes");
        let a = session
            .audits()
            .iter()
            .find(|a| a.pass == pass)
            .expect("the varlen stage audits both passes")
            .clone();
        out.push(VarlenRow {
            model: mname,
            cluster: cname,
            n_docs,
            zipf_alpha: alpha,
            seq_per_gpu: seq,
            pass: pass_name,
            pad_s: a.pad_s,
            equal_s: a.equal_s,
            optimized_s: a.optimized_s,
            prefetch_depth: a.prefetch_depth,
            flipped_pairs: a.flipped_pairs,
            moved_boundaries: a.moved_boundaries,
            sim_calls: a.sim_calls,
            incremental_rescores: a.incremental_rescores,
            accepted: a.accepted,
        });
    }
    out
}

/// Varlen schedules: pad-to-max equal chunks vs equal-token varlen vs the
/// token-level rebalancer, on Zipf-packed batches — the evidence that the
/// headline technique survives realistic document packing.
pub fn varlen_schedules() -> String {
    let mut t = Table::new(
        "Varlen schedules — token-level rebalancer vs pad-to-max (Zipf-packed, balanced, event engine)",
    );
    t.header(
        ["model", "cluster", "docs", "seq/GPU", "pass", "pad (ms)", "equal (ms)", "rebal (ms)", "vs pad", "vs equal", "flips", "cuts", "sims"]
            .map(String::from)
            .to_vec(),
    );
    for r in varlen_rows() {
        t.row(vec![
            r.model.into(),
            r.cluster.into(),
            format!("{}", r.n_docs),
            k(r.seq_per_gpu),
            r.pass.into(),
            format!("{:.2}", r.pad_s * 1e3),
            format!("{:.2}", r.equal_s * 1e3),
            format!("{:.2}", r.optimized_s * 1e3),
            format!("{:.2}x", r.speedup_vs_pad()),
            format!("{:.2}x", r.speedup_vs_equal()),
            format!("{}", r.flipped_pairs),
            format!("{}", r.moved_boundaries),
            format!("{}", r.sim_calls),
        ]);
    }
    t.render()
}

/// One row of the host-kernel micro-bench — shared by the
/// `kernel_bench_table` and `repro bench --json` (`BENCH_kernels.json`).
/// Three arms over identical inputs: the scalar oracle
/// ([`HostKernels::scalar`]), the tiled/vectorized path at one thread
/// (the executor's default kernels), and the tiled path at `threads`
/// workers. The acceptance gate is tiled >= 5x scalar at a single thread
/// on the paper-scale `d = 128` geometry.
#[derive(Clone, Debug)]
pub struct KernelBenchRow {
    pub kernel: &'static str,
    pub heads: usize,
    pub kv_heads: usize,
    /// q rows == kv cols (one square chunk pair per head).
    pub chunk: usize,
    pub head_dim: usize,
    /// Worker threads in the multi-thread arm (available parallelism,
    /// capped at 4 so shared runners measure the same arm).
    pub threads: usize,
    /// Median wall-clock of the scalar oracle.
    pub scalar_s: f64,
    /// Median wall-clock of the tiled path at one thread.
    pub tiled_s: f64,
    /// Median wall-clock of the tiled path at `threads` threads.
    pub tiled_mt_s: f64,
}

impl KernelBenchRow {
    pub fn speedup_tiled(&self) -> f64 {
        self.scalar_s / self.tiled_s
    }

    pub fn speedup_mt(&self) -> f64 {
        self.scalar_s / self.tiled_mt_s
    }
}

/// Median kernel wall-clock for one arm (1 warmup + `iters` measured).
fn kernel_bench_arm(
    kk: &HostKernels,
    kernel: &'static str,
    inputs: &[Value],
    iters: usize,
) -> f64 {
    let s = crate::util::bench::bench(kernel, 1, iters, || {
        crate::util::bench::black_box(kk.run(kernel, inputs).expect("bench kernel runs"));
    });
    s.p50_ns / 1e9
}

/// Run the host-kernel micro-bench: streaming-softmax forward and FA2
/// backward chunks at LLaMA-GQA head geometry (`d = 128`, grouped kv
/// heads), identical inputs across arms. The backward arm's `(o, lse)`
/// come from a real forward so its numerics are representative.
pub fn kernel_bench_rows() -> Vec<KernelBenchRow> {
    let (h, kvh, c, d) = (8, 2, 512, 128);
    let threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
    let iters = 3;
    let mut rng = crate::util::Rng::new(11);
    let q = Tensor::new(vec![h, c, d], rng.normal_vec(h * c * d));
    let kt = Tensor::new(vec![kvh, c, d], rng.normal_vec(kvh * c * d));
    let v = Tensor::new(vec![kvh, c, d], rng.normal_vec(kvh * c * d));
    let do_ = Tensor::new(vec![h, c, d], rng.normal_vec(h * c * d));
    let o0 = Tensor::zeros(&[h, c, d]);
    let m0 = Tensor::new(vec![h, c], vec![f32::NEG_INFINITY; h * c]);
    let l0 = Tensor::zeros(&[h, c]);
    let fwd = HostKernels::tiled(1)
        .run("full_attn_ref", &[q.clone().into(), kt.clone().into(), v.clone().into()])
        .expect("bench forward runs");
    let fwd_inputs: Vec<Value> = vec![
        q.clone().into(),
        kt.clone().into(),
        v.clone().into(),
        o0.into(),
        m0.into(),
        l0.into(),
    ];
    let bwd_inputs: Vec<Value> = vec![
        q.into(),
        kt.into(),
        v.into(),
        fwd[0].clone().into(),
        fwd[1].clone().into(),
        do_.into(),
    ];
    let mut out = Vec::new();
    for (kernel, inputs) in [("attn_fwd_full", fwd_inputs), ("attn_bwd_diag", bwd_inputs)] {
        let scalar_s = kernel_bench_arm(&HostKernels::scalar(), kernel, &inputs, iters);
        let tiled_s = kernel_bench_arm(&HostKernels::tiled(1), kernel, &inputs, iters);
        let tiled_mt_s = kernel_bench_arm(&HostKernels::tiled(threads), kernel, &inputs, iters);
        out.push(KernelBenchRow {
            kernel,
            heads: h,
            kv_heads: kvh,
            chunk: c,
            head_dim: d,
            threads,
            scalar_s,
            tiled_s,
            tiled_mt_s,
        });
    }
    out
}

/// Kernel micro-bench as a table (the human-readable side of
/// `BENCH_kernels.json`).
pub fn kernel_bench_table(rows: &[KernelBenchRow]) -> String {
    let mut t = Table::new(
        "Host kernel micro-bench — scalar oracle vs tiled/vectorized (d=128 GQA geometry)",
    );
    t.header(
        ["kernel", "H/KVH", "chunk", "d", "scalar (ms)", "tiled (ms)", "speedup", "mt (ms)", "threads", "mt speedup"]
            .map(String::from)
            .to_vec(),
    );
    for r in rows {
        t.row(vec![
            r.kernel.into(),
            format!("{}/{}", r.heads, r.kv_heads),
            k(r.chunk),
            format!("{}", r.head_dim),
            format!("{:.2}", r.scalar_s * 1e3),
            format!("{:.2}", r.tiled_s * 1e3),
            format!("{:.2}x", r.speedup_tiled()),
            format!("{:.2}", r.tiled_mt_s * 1e3),
            format!("{}", r.threads),
            format!("{:.2}x", r.speedup_mt()),
        ]);
    }
    t.render()
}

/// One row of the executor transport micro-bench — shared by the
/// `executor_bench` table and `repro bench --json`
/// (`BENCH_executor.json`). Both arms run the *real* threaded executor
/// (fwd + bwd) with the zero-work kernel echo, so kernel time is identical
/// by construction and the measured delta is purely the runtime fabric:
/// deep-copy sends + fully blocking receives (the pre-zero-copy executor)
/// vs Arc-backed zero-copy sends + posted receives at the plan's depth.
#[derive(Clone, Debug)]
pub struct ExecBenchRow {
    pub preset: &'static str,
    pub p: usize,
    pub heads: usize,
    pub kv_heads: usize,
    /// Tokens per chunk (per worker).
    pub chunk: usize,
    pub head_dim: usize,
    /// Median wall-clock, deep-copy sends + depth-0 blocking receives.
    pub baseline_s: f64,
    /// Median wall-clock, zero-copy sends + posted receives.
    pub zero_copy_s: f64,
}

impl ExecBenchRow {
    pub fn speedup(&self) -> f64 {
        if self.zero_copy_s > 0.0 {
            self.baseline_s / self.zero_copy_s
        } else {
            1.0
        }
    }
}

/// Median executor wall-clock (fwd + bwd) over `iters` runs of one arm —
/// each run a `Session` over the given plans with the Null backend.
fn exec_bench_arm(
    fwd: &Arc<Plan>,
    bwd: &Arc<Plan>,
    q: &crate::runtime::Tensor,
    kv: &crate::runtime::Tensor,
    do_: &crate::runtime::Tensor,
    deep: bool,
    iters: usize,
) -> f64 {
    let s = crate::util::bench::bench("exec", 1, iters, || {
        let mut spec = RunSpec::for_plans(fwd, BackendSpec::Null, q, kv);
        spec.deep_copy_sends = deep;
        Session::with_plans(spec, fwd.clone(), bwd.clone())
            .and_then(|mut s| {
                s.execute_with(q, kv, kv, Some(do_))?;
                Ok(())
            })
            .expect("executor bench run failed");
    });
    s.p50_ns / 1e9
}

/// Run the executor micro-bench grid. The headline row is the 2x8 dev
/// preset (16 ranks, LLaMA-ish head geometry): the acceptance gate is a
/// >= 1.5x wall-clock win for zero-copy sends + posted receives over the
/// pre-PR deep-copy/blocking executor on that row.
pub fn executor_bench_rows() -> Vec<ExecBenchRow> {
    let grid: &[(&'static str, usize, usize, usize, usize, usize)] = &[
        ("1x8-dev", 8, 8, 8, 1024, 64),
        ("2x8-dev", 16, 8, 8, 1024, 64),
    ];
    // median of 5: the expected gap (multi-GB of memcpy vs refcount
    // bumps) is far wider than shared-runner noise, but singleton medians
    // of a 16-thread bench are not
    let iters = 5;
    let mut out = Vec::new();
    for &(preset, p, h, kvh, chunk, d) in grid {
        let (fwd, bwd) = Session::new(RunSpec::plans_only(ScheduleKind::Balanced, p))
            .and_then(|mut s| s.plans())
            .expect("plans");
        // depth-0 twins: the fully blocking pre-PR receive path
        let mut f0 = (*fwd).clone();
        f0.prefetch_depth = 0;
        let mut b0 = (*bwd).clone();
        b0.prefetch_depth = 0;
        let (f0, b0) = (Arc::new(f0), Arc::new(b0));
        let n = p * chunk;
        // values are irrelevant to the transport layer (Null kernels):
        // zeros keep setup cheap and deterministic
        let q = crate::runtime::Tensor::zeros(&[h, n, d]);
        let kv = crate::runtime::Tensor::zeros(&[kvh, n, d]);
        let do_ = crate::runtime::Tensor::zeros(&[h, n, d]);
        let baseline_s = exec_bench_arm(&f0, &b0, &q, &kv, &do_, true, iters);
        let zero_copy_s = exec_bench_arm(&fwd, &bwd, &q, &kv, &do_, false, iters);
        out.push(ExecBenchRow {
            preset,
            p,
            heads: h,
            kv_heads: kvh,
            chunk,
            head_dim: d,
            baseline_s,
            zero_copy_s,
        });
    }
    out
}

/// Executor micro-bench as a table (the human-readable side of
/// `BENCH_executor.json`).
pub fn executor_bench_table(rows: &[ExecBenchRow]) -> String {
    let mut t = Table::new(
        "Executor transport micro-bench — deep-copy/blocking vs zero-copy/prefetch (fwd+bwd, null kernels)",
    );
    t.header(
        ["preset", "P", "H/KVH", "chunk", "d", "baseline (ms)", "zero-copy (ms)", "speedup"]
            .map(String::from)
            .to_vec(),
    );
    for r in rows {
        t.row(vec![
            r.preset.into(),
            format!("{}", r.p),
            format!("{}/{}", r.heads, r.kv_heads),
            k(r.chunk),
            format!("{}", r.head_dim),
            format!("{:.2}", r.baseline_s * 1e3),
            format!("{:.2}", r.zero_copy_s * 1e3),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t.render()
}

/// One row of the fault-tolerance overhead bench — shared by the
/// `fault_overhead` table and `repro bench --json` (`BENCH_faults.json`).
/// Both arms run the real threaded executor (fwd + bwd, null kernels) so
/// the measured delta is purely the instrumented comm path: per-send
/// injection draws, dedup sequence numbers, deadline-armed receives, and
/// step-boundary abort checks — with every fault probability at zero, so
/// nothing actually fails. CI gates `instrumented_s / baseline_s <= 1.05`
/// on the 2x8 dev preset.
#[derive(Clone, Debug)]
pub struct FaultBenchRow {
    pub preset: &'static str,
    pub p: usize,
    pub heads: usize,
    pub kv_heads: usize,
    /// Tokens per chunk (per worker).
    pub chunk: usize,
    pub head_dim: usize,
    /// Median wall-clock, faults unarmed (the pre-PR fast path).
    pub baseline_s: f64,
    /// Median wall-clock, zero-probability `FaultSpec` armed.
    pub instrumented_s: f64,
}

impl FaultBenchRow {
    /// Instrumentation overhead ratio (1.0 = free).
    pub fn overhead(&self) -> f64 {
        if self.baseline_s > 0.0 {
            self.instrumented_s / self.baseline_s
        } else {
            1.0
        }
    }
}

/// Median executor wall-clock (fwd + bwd) over `iters` runs of one
/// fault-bench arm.
fn fault_bench_arm(
    fwd: &Arc<Plan>,
    bwd: &Arc<Plan>,
    q: &Tensor,
    kv: &Tensor,
    do_: &Tensor,
    faults: &Option<FaultSpec>,
    iters: usize,
) -> f64 {
    let s = crate::util::bench::bench("fault_overhead", 1, iters, || {
        let mut spec = RunSpec::for_plans(fwd, BackendSpec::Null, q, kv);
        spec.faults = faults.clone();
        Session::with_plans(spec, fwd.clone(), bwd.clone())
            .and_then(|mut s| {
                s.execute_with(q, kv, kv, Some(do_))?;
                Ok(())
            })
            .expect("fault bench run failed");
    });
    s.p50_ns / 1e9
}

/// Run the zero-fault overhead bench on the 2x8 dev preset (the CI gate's
/// row), mirroring the executor micro-bench geometry.
pub fn fault_bench_rows() -> Vec<FaultBenchRow> {
    let grid: &[(&'static str, usize, usize, usize, usize, usize)] =
        &[("2x8-dev", 16, 8, 8, 1024, 64)];
    let iters = 5;
    let mut out = Vec::new();
    for &(preset, p, h, kvh, chunk, d) in grid {
        let (fwd, bwd) = Session::new(RunSpec::plans_only(ScheduleKind::Balanced, p))
            .and_then(|mut s| s.plans())
            .expect("plans");
        let n = p * chunk;
        let q = Tensor::zeros(&[h, n, d]);
        let kv = Tensor::zeros(&[kvh, n, d]);
        let do_ = Tensor::zeros(&[h, n, d]);
        let baseline_s = fault_bench_arm(&fwd, &bwd, &q, &kv, &do_, &None, iters);
        // zero-probability spec: arms rng draws, seq numbers, deadlines,
        // and abort checks without injecting a single fault
        let armed = Some(FaultSpec::default());
        let instrumented_s = fault_bench_arm(&fwd, &bwd, &q, &kv, &do_, &armed, iters);
        out.push(FaultBenchRow {
            preset,
            p,
            heads: h,
            kv_heads: kvh,
            chunk,
            head_dim: d,
            baseline_s,
            instrumented_s,
        });
    }
    out
}

/// Fault-tolerance overhead bench as a table (the human-readable side of
/// `BENCH_faults.json`).
pub fn fault_bench_table(rows: &[FaultBenchRow]) -> String {
    let mut t = Table::new(
        "Fault-tolerance zero-fault overhead — uninstrumented vs armed comm path (fwd+bwd, null kernels)",
    );
    t.header(
        ["preset", "P", "H/KVH", "chunk", "d", "baseline (ms)", "instrumented (ms)", "overhead"]
            .map(String::from)
            .to_vec(),
    );
    for r in rows {
        t.row(vec![
            r.preset.into(),
            format!("{}", r.p),
            format!("{}/{}", r.heads, r.kv_heads),
            k(r.chunk),
            format!("{}", r.head_dim),
            format!("{:.2}", r.baseline_s * 1e3),
            format!("{:.2}", r.instrumented_s * 1e3),
            format!("{:.3}x", r.overhead()),
        ]);
    }
    t.render()
}

/// One row of the crash-recovery bench — shared by the `recovery` table
/// and `repro bench --json` (`BENCH_recovery.json`). A mid-run rank crash
/// is injected on the 2x8 dev HostRef preset and driven to completion by
/// the supervised recovery loop under each policy; CI gates
/// `recovered_total_s / fault_free_s <= 2.5` and `bit_identical` on the
/// respawn row.
#[derive(Clone, Debug)]
pub struct RecoveryBenchRow {
    pub preset: &'static str,
    pub p: usize,
    pub heads: usize,
    pub kv_heads: usize,
    /// Tokens per chunk (per worker).
    pub chunk: usize,
    pub head_dim: usize,
    pub layers: usize,
    /// `"respawn"` or `"elastic"`.
    pub policy: &'static str,
    /// Median fault-free wall-clock (the gate's denominator).
    pub fault_free_s: f64,
    /// Total wall-clock of the crashed run: detection, restart planning,
    /// and checkpoint-replay included.
    pub recovered_total_s: f64,
    /// First (failed) attempt start -> recovered attempt success.
    pub time_to_recover_s: f64,
    /// Injection -> structured failure surfaced by the watchdog.
    pub detect_s: f64,
    pub replayed_ops: usize,
    pub skipped_ops: usize,
    /// Layer boundary the replay resumed from.
    pub resume_layer: usize,
    /// Recovered output bit-identical to the fault-free run.
    pub bit_identical: bool,
}

impl RecoveryBenchRow {
    /// Recovered-run slowdown vs fault-free (1.0 = the crash was free).
    pub fn overhead(&self) -> f64 {
        if self.fault_free_s > 0.0 {
            self.recovered_total_s / self.fault_free_s
        } else {
            1.0
        }
    }
}

/// Run the crash-recovery bench on the 2x8 dev HostRef preset: a seeded
/// mid-run crash under `Respawn` and `Elastic`, each compared against the
/// fault-free run for wall-clock and bit-identity. Geometry stays small —
/// the measured quantity is the *relative* recovery overhead, which
/// survives any geometry.
pub fn recovery_bench_rows() -> Vec<RecoveryBenchRow> {
    let (preset, p, h, kvh, chunk, d, layers) = ("2x8-dev", 16usize, 4usize, 2usize, 32usize, 16usize, 2usize);
    let n = p * chunk;
    let mut rng = crate::util::Rng::new(11);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let kt = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let vt = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let make_spec = |faults: Option<FaultSpec>, recovery: RecoveryPolicy| {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(h, kvh, d, chunk));
        spec.layers = layers;
        spec.faults = faults;
        spec.recovery = recovery;
        spec
    };

    // fault-free baseline: one run for the reference output (after a warm
    // run so thread-spawn costs are not charged), then the median wall
    let mut base = Session::new(make_spec(None, RecoveryPolicy::FailFast)).expect("spec");
    base.execute_with(&q, &kt, &vt, Some(&do_)).expect("fault-free run");
    let o_base = base.result().expect("fault-free result").o.clone();
    let s = crate::util::bench::bench("recovery-baseline", 1, 3, || {
        Session::new(make_spec(None, RecoveryPolicy::FailFast))
            .and_then(|mut s| {
                s.execute_with(&q, &kt, &vt, Some(&do_))?;
                Ok(())
            })
            .expect("fault-free run");
    });
    let fault_free_s = s.p50_ns / 1e9;

    let crash = FaultSpec {
        seed: 11,
        crash: Some(CrashSpec { rank: p / 2, step: 2, pass: Pass::Forward }),
        ..FaultSpec::default()
    };
    let mut out = Vec::new();
    for (policy_name, policy) in [
        ("respawn", RecoveryPolicy::respawn()),
        ("elastic", RecoveryPolicy::Elastic { min_workers: 2 }),
    ] {
        let mut session =
            Session::new(make_spec(Some(crash.clone()), policy)).expect("spec");
        let t0 = std::time::Instant::now();
        session
            .execute_supervised_with(&q, &kt, &vt, Some(&do_))
            .expect("supervised run recovered");
        let recovered_total_s = t0.elapsed().as_secs_f64();
        let report = session.recovery_report().cloned().unwrap_or_default();
        let bit_identical = session.result().map(|r| r.o == o_base).unwrap_or(false);
        out.push(RecoveryBenchRow {
            preset,
            p,
            heads: h,
            kv_heads: kvh,
            chunk,
            head_dim: d,
            layers,
            policy: policy_name,
            fault_free_s,
            recovered_total_s,
            time_to_recover_s: report.time_to_recover_s,
            detect_s: report.detect_s,
            replayed_ops: report.replayed_ops,
            skipped_ops: report.skipped_ops,
            resume_layer: report.resume_layer,
            bit_identical,
        });
    }
    out
}

/// Crash-recovery bench as a table (the human-readable side of
/// `BENCH_recovery.json`).
pub fn recovery_bench_table(rows: &[RecoveryBenchRow]) -> String {
    let mut t = Table::new(
        "Crash recovery — mid-run rank crash driven to bit-identical completion (HostRef, fwd+bwd)",
    );
    t.header(
        [
            "preset", "P", "policy", "fault-free (ms)", "recovered (ms)", "overhead",
            "detect (ms)", "resume", "replayed", "skipped", "bit-identical",
        ]
        .map(String::from)
        .to_vec(),
    );
    for r in rows {
        t.row(vec![
            r.preset.into(),
            format!("{}", r.p),
            r.policy.into(),
            format!("{:.2}", r.fault_free_s * 1e3),
            format!("{:.2}", r.recovered_total_s * 1e3),
            format!("{:.2}x", r.overhead()),
            format!("{:.2}", r.detect_s * 1e3),
            format!("L{}", r.resume_layer),
            format!("{}", r.replayed_ops),
            format!("{}", r.skipped_ops),
            format!("{}", r.bit_identical),
        ]);
    }
    t.render()
}

/// One arm of the checkpoint trade-off grid — shared by the
/// `ckpt_tradeoff` table and `repro bench --json` (`BENCH_ckpt.json`).
/// The §3.3 strategies are priced by the joint checkpoint × prefetch
/// search (`optimize_ckpt`) on the paper's 64K-token 2×8 A100-40G
/// backward regime, then each lowering is also *executed* on HostRef at a
/// small dev geometry so the HfStyle recompute prefix shows up as real
/// replayed kernels and transfers, not just simulated seconds.
#[derive(Clone, Debug)]
pub struct CkptBenchRow {
    /// `CkptStrategy::name()` — "hf" or "remat-aware".
    pub strategy: &'static str,
    /// Did the joint search pick this arm?
    pub chosen: bool,
    /// Depth knee under the arm's remaining staging headroom.
    pub prefetch_depth: usize,
    /// Simulated one-layer backward makespan at 64K total tokens
    /// (recompute prefix included for HfStyle).
    pub sim_bwd_s: f64,
    /// Memory-timeline high-water mark per worker: resident floor (+
    /// checkpoint bytes for RematAware) plus live staged payloads.
    pub peak_bytes: f64,
    /// Whether the peak fits in `GpuSpec::mem_bytes` (40GB here).
    pub fits: bool,
    /// Median HostRef-executed fwd+bwd wall-clock of the same lowering on
    /// the 2x8-dev preset (16 ranks, small head geometry).
    pub exec_wall_s: f64,
}

/// Median HostRef fwd+bwd wall-clock of one strategy's lowering on the
/// 16-rank dev preset. Sizes stay small because the recompute prefix is
/// real kernel work on the reference backend; the point is the *relative*
/// cost of replaying the attention forward, which survives any geometry.
fn ckpt_exec_arm(strategy: CkptStrategy, p: usize) -> f64 {
    let s = crate::util::bench::bench("ckpt-exec", 1, 3, || {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(2, 2, 16, 64));
        spec.backward = true;
        spec.ckpt = strategy;
        Session::new(spec)
            .and_then(|mut s| {
                s.execute()?;
                Ok(())
            })
            .expect("ckpt exec arm failed");
    });
    s.p50_ns / 1e9
}

/// Run the checkpoint trade-off: both §3.3 strategies through the joint
/// checkpoint × prefetch search at the paper's 64K-token 2×8 regime
/// (LLaMA-7B backward), plus a HostRef-executed twin per arm.
pub fn ckpt_tradeoff_rows() -> Vec<CkptBenchRow> {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::cluster_16x40g();
    let p = cluster.n_gpus();
    let chunk = 65536 / p; // 4K/GPU -> 64K total, the paper's 2x8 regime
    let cost = attn_cost_bwd(&model, &cluster, chunk as f64);
    // per-worker resident floor both strategies share: the FSDP weight
    // shard plus every layer's checkpointed input chunk
    let resident = fsdp_param_bytes(&model, p)
        + (model.n_layers * chunk * model.d_model) as f64 * ELEM_BYTES;
    // RematAware additionally pins each layer's (o, lse) pair
    let extra = model.n_layers as f64
        * CkptStrategy::RematAware.extra_saved_floats(model.n_heads, chunk, model.head_dim)
            as f64
        * ELEM_BYTES;
    let o = optimize_ckpt(
        &Schedule::balanced(p),
        &cluster,
        &cost,
        &OptimizeOpts::default(),
        resident,
        extra,
    );
    o.arms
        .iter()
        .map(|arm| CkptBenchRow {
            strategy: arm.strategy.name(),
            chosen: arm.strategy == o.choice,
            prefetch_depth: arm.prefetch_depth,
            sim_bwd_s: arm.total_s,
            peak_bytes: arm.peak_bytes,
            fits: arm.fits,
            exec_wall_s: ckpt_exec_arm(arm.strategy, p),
        })
        .collect()
}

/// Checkpointing in the IR: HF-style recompute prefix vs
/// rematerialization-aware, simulated at the paper's 64K-token scale and
/// executed on HostRef, with the event engine's memory-timeline peak per
/// arm (the human-readable side of `BENCH_ckpt.json`).
pub fn ckpt_tradeoff() -> String {
    let rows = ckpt_tradeoff_rows();
    let mut t = Table::new(
        "Checkpoint trade-off — HF-style recompute prefix vs remat-aware (LLaMA-7B, 2x8 A100-40G, 64K tokens bwd)",
    );
    t.header(
        ["strategy", "sim bwd (ms)", "peak mem", "fits 40GB", "depth*", "exec fwd+bwd (ms)", "chosen"]
            .map(String::from)
            .to_vec(),
    );
    for r in &rows {
        t.row(vec![
            r.strategy.into(),
            format!("{:.2}", r.sim_bwd_s * 1e3),
            fmt_bytes(r.peak_bytes),
            if r.fits { "yes" } else { "no" }.into(),
            format!("{}", r.prefetch_depth),
            format!("{:.2}", r.exec_wall_s * 1e3),
            if r.chosen { "yes" } else { "-" }.into(),
        ]);
    }
    t.render()
}

/// One serving-bench arm: the dev preset run with continuous batching
/// on vs the serial one-request-at-a-time baseline, simulated and
/// executed (the row set behind `BENCH_serve.json`).
pub struct ServeBenchRow {
    /// `"continuous"` or `"serial"`.
    pub mode: &'static str,
    /// Serving ranks.
    pub p: usize,
    pub requests: usize,
    /// Decode steps in the lowered plan.
    pub steps: usize,
    /// Event-engine throughput over the lowered plan.
    pub sim_tokens_per_s: f64,
    pub sim_p99_s: f64,
    /// Measured throughput of the hostref replay (tokens over the span
    /// makespan).
    pub exec_tokens_per_s: f64,
    pub exec_total_s: f64,
    /// Decode values compared bit-for-bit against the full-prefill
    /// oracle (all must match — `serve` fails otherwise).
    pub checked_values: usize,
    /// |measured − calibrated sim| / measured after fitting the cost
    /// model to the executed trace.
    pub calib_rel_err: f64,
}

impl ServeBenchRow {
    /// Simulated speedup of this row over a baseline row.
    pub fn sim_speedup_over(&self, base: &ServeBenchRow) -> f64 {
        self.sim_tokens_per_s / base.sim_tokens_per_s.max(1e-30)
    }

    /// Executed speedup of this row over a baseline row.
    pub fn exec_speedup_over(&self, base: &ServeBenchRow) -> f64 {
        self.exec_tokens_per_s / base.exec_tokens_per_s.max(1e-30)
    }
}

fn serve_bench_arm(mode: &'static str, batching: bool) -> ServeBenchRow {
    let spec = crate::serving::ServeSpec { batching, ..crate::serving::ServeSpec::dev() };
    let out = crate::serving::serve(&spec).expect("dev serving preset must run");
    let ex = out.exec.as_ref().expect("dev preset executes on hostref");
    ServeBenchRow {
        mode,
        p: spec.n_workers,
        requests: out.requests.len(),
        steps: out.log.steps.len(),
        sim_tokens_per_s: out.sim.tokens_per_s,
        sim_p99_s: out.sim.p99_latency_s,
        exec_tokens_per_s: ex.score.tokens_per_s,
        exec_total_s: ex.score.total_s,
        checked_values: ex.checked_values,
        calib_rel_err: ex.calibration_rel_err,
    }
}

/// The serving bench grid: continuous batching vs the serial baseline
/// on [`crate::serving::ServeSpec::dev`], both simulated and executed.
/// Continuous first, serial second (the CI gate's comparison order).
pub fn serve_bench_rows() -> Vec<ServeBenchRow> {
    vec![serve_bench_arm("continuous", true), serve_bench_arm("serial", false)]
}

/// Serving throughput table — continuous batching vs serial decode on
/// the 2x8-dev preset (the human-readable side of `BENCH_serve.json`).
pub fn serve_bench_table(rows: &[ServeBenchRow]) -> String {
    let mut t = Table::new(
        "Serving throughput — continuous batching vs serial decode (2x8-dev, Poisson arrivals, hostref-executed)",
    );
    t.header(
        ["mode", "ranks", "reqs", "steps", "sim tok/s", "sim p99 (ms)", "exec tok/s", "exec (ms)", "oracle vals", "calib err"]
            .map(String::from)
            .to_vec(),
    );
    for r in rows {
        t.row(vec![
            r.mode.into(),
            format!("{}", r.p),
            format!("{}", r.requests),
            format!("{}", r.steps),
            format!("{:.1}", r.sim_tokens_per_s),
            format!("{:.3}", r.sim_p99_s * 1e3),
            format!("{:.1}", r.exec_tokens_per_s),
            format!("{:.3}", r.exec_total_s * 1e3),
            format!("{}", r.checked_values),
            format!("{:.1}%", r.calib_rel_err * 100.0),
        ]);
    }
    t.render()
}

/// §4.3's Ring Attention comparison as a one-line summary table.
pub fn ring_attention_summary() -> String {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let seq = 32768;
    let ra = RingAttention.iteration(&model, &cluster, seq).total_s();
    let ours = DistFlashAttn::default().iteration(&model, &cluster, seq).total_s();
    let mut t = Table::new("§4.3 — vs Ring Attention (8 GPUs, LLaMA-7B, 32K/GPU)");
    t.header(["system", "iter(s)", "speedup", "paper"].map(String::from).to_vec());
    t.row(vec!["Ring Attention".into(), format!("{ra:.2}"), "1.00x".into(), "1.00x".into()]);
    t.row(vec![
        "DISTFLASHATTN".into(),
        format!("{ours:.2}"),
        format!("{:.2}x", ra / ours),
        "1.67x".into(),
    ]);
    t.render()
}

/// All tables + figures, concatenated (the `repro tables --all` output).
pub fn all_reports() -> String {
    [
        table1(),
        table2(),
        table3(),
        table4(),
        ring_attention_summary(),
        executed_schedules(),
        optimized_schedules(),
        varlen_schedules(),
        table5(),
        ckpt_tradeoff(),
        serve_bench_table(&serve_bench_rows()),
        table6(),
        fig1(),
        fig2(),
        fig4_left(),
        fig4_right(),
        fig7(),
    ]
    .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_report_renders() {
        for (name, s) in [
            ("t1", table1()),
            ("t2", table2()),
            ("t3", table3()),
            ("t4", table4()),
            ("t5", table5()),
            ("t6", table6()),
            ("f1", fig1()),
            ("f2", fig2()),
            ("f4l", fig4_left()),
            ("f4r", fig4_right()),
            ("f7", fig7()),
            ("ra", ring_attention_summary()),
            ("exec", executed_schedules()),
            ("opt", optimized_schedules()),
            ("varlen", varlen_schedules()),
            ("ckpt", ckpt_tradeoff()),
            ("serve", serve_bench_table(&serve_bench_rows())),
        ] {
            assert!(s.len() > 100, "{name} too short:\n{s}");
            assert!(!s.contains("NaN"), "{name} has NaN:\n{s}");
            assert!(!s.contains("inf"), "{name} has inf:\n{s}");
        }
    }

    #[test]
    fn optimizer_rows_never_pessimize_and_win_somewhere() {
        let rows = optimizer_rows();
        for r in &rows {
            assert!(
                r.optimized_s <= r.default_s * (1.0 + 1e-9),
                "{} {} {}: optimizer pessimized {} -> {}",
                r.model,
                r.cluster,
                r.pass,
                r.default_s,
                r.optimized_s
            );
        }
        // the heterogeneous GQA rows must show a real win (flips + depth)
        let gqa = rows
            .iter()
            .find(|r| r.model == "llama-gqa" && r.cluster == "2x8" && r.pass == "fwd")
            .unwrap();
        assert!(
            gqa.optimized_s < gqa.default_s * 0.95,
            "expected >5% win on GQA 2x8 fwd, got {:.3}x",
            gqa.speedup()
        );
        assert!(gqa.flipped_steps > 0, "role flipping should fire on GQA 2x8");
    }

    #[test]
    fn varlen_rows_hit_the_acceptance_bar() {
        let rows = varlen_rows();
        for r in &rows {
            // never worse than the equal-token default, by construction
            assert!(
                r.optimized_s <= r.equal_s * (1.0 + 1e-9),
                "{} {} {}: rebalancer pessimized {} -> {}",
                r.model,
                r.cluster,
                r.pass,
                r.equal_s,
                r.optimized_s
            );
            // the enlarged search stays in PR 2's sim budget order
            assert!(
                r.sim_calls < 2500,
                "{} {} {}: {} sim calls blow the budget",
                r.model,
                r.cluster,
                r.pass,
                r.sim_calls
            );
        }
        // acceptance: on the skewed Zipf 2x8 preset the rebalancer beats
        // pad-to-max by >= 1.2x
        for r in rows.iter().filter(|r| r.cluster == "2x8") {
            assert!(
                r.speedup_vs_pad() >= 1.2,
                "{} {} {}: only {:.2}x vs pad-to-max",
                r.model,
                r.cluster,
                r.pass,
                r.speedup_vs_pad()
            );
        }
    }

    #[test]
    fn ckpt_rows_tell_the_paper_story() {
        let rows = ckpt_tradeoff_rows();
        assert_eq!(rows.len(), 2);
        let hf = rows.iter().find(|r| r.strategy == "hf").unwrap();
        let ra = rows.iter().find(|r| r.strategy == "remat-aware").unwrap();
        // §3.3's claim at the 64K regime: remat-aware wins the step, both
        // simulated (no recompute prefix in the plan) and executed (no
        // replayed kernels on HostRef)
        assert!(
            ra.sim_bwd_s < hf.sim_bwd_s,
            "sim: remat {} vs hf {}",
            ra.sim_bwd_s,
            hf.sim_bwd_s
        );
        assert!(
            ra.exec_wall_s < hf.exec_wall_s,
            "exec: remat {} vs hf {}",
            ra.exec_wall_s,
            hf.exec_wall_s
        );
        assert!(ra.chosen && !hf.chosen, "joint search must pick remat-aware here");
        // HF-style's reason to exist: the strictly lower memory peak
        assert!(
            hf.peak_bytes < ra.peak_bytes,
            "hf peak {} must undercut remat peak {}",
            hf.peak_bytes,
            ra.peak_bytes
        );
        // accepted arms stay within the device
        let mem = ClusterSpec::cluster_16x40g().gpu.mem_bytes;
        for r in &rows {
            assert!(r.fits, "{}: arm must fit at 64K on 40GB", r.strategy);
            assert!(r.peak_bytes <= mem, "{}: peak exceeds device", r.strategy);
        }
    }

    #[test]
    fn serve_rows_show_the_batching_win() {
        let rows = serve_bench_rows();
        assert_eq!(rows.len(), 2);
        let (cont, serial) = (&rows[0], &rows[1]);
        assert_eq!(cont.mode, "continuous");
        assert_eq!(serial.mode, "serial");
        // the acceptance bar: continuous batching >= 2x serial decode
        // on the event engine (the executed 2x gate lives in CI over
        // BENCH_serve.json, where the run isn't sharing a test harness)
        assert!(
            cont.sim_speedup_over(serial) >= 2.0,
            "sim: continuous {} vs serial {} tok/s",
            cont.sim_tokens_per_s,
            serial.sim_tokens_per_s
        );
        assert!(
            cont.exec_speedup_over(serial) > 1.0,
            "exec: continuous {} vs serial {} tok/s",
            cont.exec_tokens_per_s,
            serial.exec_tokens_per_s
        );
        // both arms oracle-check the same decode rows
        assert_eq!(cont.checked_values, serial.checked_values);
        assert!(cont.checked_values > 0);
        for r in &rows {
            assert!(r.calib_rel_err.is_finite(), "{}: calib err not finite", r.mode);
            assert!(r.sim_p99_s > 0.0 && r.exec_total_s > 0.0, "{}: degenerate times", r.mode);
        }
    }

    #[test]
    fn table1_speedups_in_band() {
        // every modeled speedup must favor us, within a loose band of the
        // paper's 1.14-2.01x
        let s = table1();
        for line in s.lines().skip(3) {
            if let Some(col) = line.split('|').nth(6) {
                let v: f64 = col.trim().trim_end_matches('x').parse().unwrap_or(1.0);
                assert!((0.95..3.0).contains(&v), "speedup out of band: {line}");
            }
        }
    }

    #[test]
    fn fig4_right_overlap_reduces_overhead() {
        let s = fig4_right();
        // last data line: overlap column < no-overlap column
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        for l in &lines[2..] {
            let cols: Vec<&str> = l.split('|').map(str::trim).collect();
            let no: f64 = cols[2].parse().unwrap();
            let yes: f64 = cols[3].parse().unwrap();
            assert!(yes <= no + 1e-9, "{l}");
        }
    }
}
