//! Ring Self-Attention baseline (Li et al. 2021) — the first sequence
//! parallelism system, predating memory-efficient attention.
//!
//! Two structural handicaps vs DISTFLASHATTN (§4.3):
//! 1. No FlashAttention: every worker materializes its (c × N) attention
//!    score matrix per head for the backward pass — the memory term that
//!    caps RSA at 8x shorter sequences in Table 3.
//! 2. Unfused, non-causal-aware ring: P full rounds of kv exchange
//!    (2Nd forward volume, no causal skip), unoverlapped, and the
//!    attention math runs at memory-bound efficiency.

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::simulator::collective::p2p;

use super::{fsdp_param_bytes, IterBreakdown, SystemModel};

/// Effective MFU of unfused attention (separate matmul/softmax/dropout
/// kernels bouncing through HBM).
const RSA_ATTN_MFU: f64 = 0.11;

#[derive(Clone, Copy, Debug, Default)]
pub struct RingSelfAttention;

impl SystemModel for RingSelfAttention {
    fn name(&self) -> String {
        "Ring Self-Attention".into()
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        let p = cluster.n_gpus();
        let c = seq_per_gpu as f64;
        let n = c * p as f64;
        let l = model.n_layers as f64;
        let e = model.d_model as f64;

        let lin = cluster.compute_time(model.layer_linear_flops(c), cluster.gpu.mfu_gemm);
        // P ring rounds, full (unmasked) pair each round, low MFU
        let attn_round = cluster.compute_time(
            model.attn_pair_flops(c, c, false),
            RSA_ATTN_MFU,
        );
        // kv hop each round; RSA overlaps nothing
        let worst_link = {
            let (bw, lat) = cluster.ring_bottleneck(p);
            p2p(model.kv_bytes(c), bw, lat)
        };
        let attn_fwd = p as f64 * (attn_round + worst_link);
        let head_s =
            cluster.compute_time(2.0 * c * e * model.vocab as f64, cluster.gpu.mfu_gemm);

        let fwd = l * (lin + attn_fwd) + head_s;
        // unfused attention backward: ~2.5x forward (plus the same ring)
        let bwd = l * (2.0 * lin + 2.5 * attn_fwd) + 2.0 * head_s;
        let recompute = l * (lin + attn_fwd); // HF-style checkpoints

        // --- memory: the killer term — materialized scores (c × N) per
        // head, with ~3 live copies (scores, softmax, grad) during bwd ---
        let scores = model.n_heads as f64 * c * n * ELEM_BYTES * 3.0;
        let stored = l * c * e * ELEM_BYTES;
        let working = 6.0 * c * e * ELEM_BYTES + 3.0 * c * model.d_ff as f64 * ELEM_BYTES;
        let logits = c * model.vocab as f64 * ELEM_BYTES;
        let peak = fsdp_param_bytes(model, p) + scores + stored + working + logits;

        IterBreakdown {
            fwd_compute_s: fwd,
            bwd_compute_s: bwd,
            recompute_s: recompute,
            exposed_comm_s: 0.0, // already serialized into attn_fwd
            peak_mem_bytes: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::distflash::DistFlashAttn;

    #[test]
    fn rsa_max_seq_8x_shorter() {
        // Table 3: RSA caps at 32K total on one DGX node; ours > 256K
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let rsa = RingSelfAttention.max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        let ours =
            DistFlashAttn::default().max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        let rsa_total = rsa * 8;
        let ours_total = ours * 8;
        assert!(
            (16 * 1024..=64 * 1024).contains(&rsa_total),
            "RSA total {rsa_total}"
        );
        assert!(ours_total / rsa_total >= 8, "{ours_total} / {rsa_total}");
    }

    #[test]
    fn rsa_iteration_much_slower() {
        // Table 3: 5.64x at 32K total / 1 node
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let seq = 4096; // 32K / 8
        let slow = RingSelfAttention.iteration(&model, &cluster, seq).total_s();
        let fast = DistFlashAttn::default()
            .iteration(&model, &cluster, seq)
            .total_s();
        let ratio = slow / fast;
        assert!((3.5..8.0).contains(&ratio), "RSA slowdown {ratio}");
    }
}
