//! DeepSpeed-Ulysses baseline (Jacobs et al. 2023).
//!
//! Sequence parallel everywhere except attention, which is head-parallel:
//! four all-to-alls per layer forward (q, k, v in; o out) re-shard tokens
//! to heads and back, four more in backward, four again when checkpointing
//! recomputes the forward. Head-parallelism inherits Megatron's padding
//! problem on irregular head counts (§4.4: 1.81-1.88x slower on LLaMA-33H)
//! and its max parallel degree is the head count.

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::coordinator::Plan;
use crate::simulator::collective::all_to_all;
use crate::simulator::{simulate_plan, EventOpts, EventResult};

use super::megatron::Megatron;
use super::{attn_cost_fwd, fsdp_param_bytes, IterBreakdown, SystemModel};

#[derive(Clone, Copy, Debug, Default)]
pub struct Ulysses;

impl Ulysses {
    /// The attention phase (a2a in, head-parallel attention, a2a out) as a
    /// schedule-IR dataflow plan — executed by the event engine instead of
    /// summed as closed-form collective costs. Uses the whole cluster; use
    /// [`Ulysses::attn_plan_p`] for an explicit parallel degree.
    pub fn attn_plan(model: &PaperModel, cluster: &ClusterSpec, seq_per_gpu: usize) -> Plan {
        Self::attn_plan_p(model, cluster, seq_per_gpu, cluster.n_gpus())
    }

    /// [`Ulysses::attn_plan`] at an explicit parallel degree `p` (so CLI
    /// comparisons can hold the worker count fixed across systems).
    pub fn attn_plan_p(
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
        p: usize,
    ) -> Plan {
        let c = seq_per_gpu as f64;
        let n = c * p as f64;
        let pad = Megatron::pad_factor(model, p);
        let attn_s = cluster.compute_time(
            model.attn_pair_flops(n, n, true) * pad / p as f64,
            cluster.gpu.mfu_attn,
        );
        let q_bytes = c * model.d_model as f64 * ELEM_BYTES;
        let kv_bytes = c * (model.n_kv_heads * model.head_dim) as f64 * ELEM_BYTES;
        // per-pair shards: q + k + v in, o out
        let in_msg = (q_bytes + 2.0 * kv_bytes) / p as f64;
        let out_msg = q_bytes / p as f64;
        Plan::ulysses(p, attn_s, in_msg, out_msg)
    }

    /// Event-engine execution of one attention forward.
    pub fn executed_attn(
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> EventResult {
        let plan = Self::attn_plan(model, cluster, seq_per_gpu);
        let cost = attn_cost_fwd(model, cluster, seq_per_gpu as f64);
        simulate_plan(&plan, cluster, &cost, &EventOpts::default())
    }
}

impl SystemModel for Ulysses {
    fn name(&self) -> String {
        "DeepSpeed-Ulysses".into()
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        let p = cluster.n_gpus();
        let c = seq_per_gpu as f64; // local tokens
        let n = c * p as f64; // full sequence
        let l = model.n_layers as f64;
        let e = model.d_model as f64;
        let pad = Megatron::pad_factor(model, p);

        // --- compute ---
        // linear parts on local c tokens; attention: padded heads / p over
        // the full sequence (causal, flash)
        let lin = cluster.compute_time(model.layer_linear_flops(c), cluster.gpu.mfu_gemm);
        let attn = cluster.compute_time(
            model.attn_pair_flops(n, n, true) * pad / p as f64,
            cluster.gpu.mfu_attn,
        );
        let head_s =
            cluster.compute_time(2.0 * c * e * model.vocab as f64, cluster.gpu.mfu_gemm);

        // --- comm: 4 a2a fwd + 4 bwd + 4 recompute on (c·E)-ish tensors;
        // kv a2a shrink under GQA ---
        let (bw, lat) = cluster.collective_bottleneck(p);
        let q_bytes = c * e * ELEM_BYTES;
        let kv_bytes = c * (model.n_kv_heads * model.head_dim) as f64 * ELEM_BYTES;
        let a2a_set = all_to_all(q_bytes, p, bw, lat) * 2.0 // q in, o out
            + all_to_all(kv_bytes, p, bw, lat) * 2.0; // k, v in
        let comm_per_layer = 3.0 * a2a_set; // fwd + bwd + ckpt recompute

        let fwd = l * (lin + attn) + head_s;
        // FA2 backward is ~2.5x its forward; GEMM backward is 2x
        let bwd = l * (2.0 * lin + 2.5 * attn) + 2.0 * head_s;
        let recompute = l * (lin + attn);
        let exposed = l * comm_per_layer;

        // --- memory: like ours but layer-boundary checkpoints (no extra
        // saved attention outputs) and full-N heads working set ---
        let stored = l * c * e * ELEM_BYTES;
        let padded_heads = (model.n_heads as f64 * pad) / p as f64;
        let attn_working = 4.0 * n * padded_heads * model.head_dim as f64 * ELEM_BYTES;
        let working = 6.0 * c * e * ELEM_BYTES
            + 3.0 * c * model.d_ff as f64 * ELEM_BYTES
            + attn_working;
        let logits = c * model.vocab as f64 * ELEM_BYTES;
        let peak = fsdp_param_bytes(model, p) + stored + working + logits;

        IterBreakdown {
            fwd_compute_s: fwd,
            bwd_compute_s: bwd,
            recompute_s: recompute,
            exposed_comm_s: exposed,
            peak_mem_bytes: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::distflash::DistFlashAttn;

    #[test]
    fn executed_a2a_matches_closed_form() {
        // on a uniform-link cluster the event engine's receiver-serialized
        // pairwise messages reduce exactly to the ring a2a closed form:
        // the executed plan and the analytic formula must agree to 1e-9
        let cluster = ClusterSpec::dgx_1x8();
        let p = cluster.n_gpus();
        let (attn_s, in_msg, out_msg) = (1e-3, 2e6, 1e6);
        let plan = Plan::ulysses(p, attn_s, in_msg, out_msg);
        let cost = attn_cost_fwd(&PaperModel::llama_7b(), &cluster, 1024.0);
        let r = simulate_plan(&plan, &cluster, &cost, &EventOpts::default());
        let (bw, lat) = (cluster.intra_bw, cluster.intra_lat);
        let expect = all_to_all(in_msg * p as f64, p, bw, lat)
            + attn_s
            + all_to_all(out_msg * p as f64, p, bw, lat);
        let rel = (r.total_s - expect).abs() / expect;
        assert!(rel < 1e-9, "executed {} vs closed form {expect}", r.total_s);
        assert!((r.comm_bytes - (p * (p - 1)) as f64 * (in_msg + out_msg)).abs() < 1.0);
    }

    #[test]
    fn executed_a2a_exposure_grows_across_nodes() {
        // the a2a phases cannot hide under the attention kernel (strict
        // phase dependency in the dataflow), so crossing to InfiniBand
        // must inflate the executed comm share — per-link topology is
        // emergent in the event engine, unlike the closed-form model
        let model = PaperModel::llama_7b();
        let seq = 8192;
        // comm share of wall-clock: 1 - avg per-worker compute / total
        let share = |r: &EventResult| 1.0 - (r.busy_s / r.n_workers as f64) / r.total_s;
        let one = share(&Ulysses::executed_attn(&model, &ClusterSpec::dgx_1x8(), seq));
        let two = share(&Ulysses::executed_attn(&model, &ClusterSpec::dgx_2x8(), seq));
        assert!(
            two > 2.0 * one && two > 0.05,
            "inter-node share {two} should dwarf intra-node {one}"
        );
    }

    #[test]
    fn irregular_heads_hurt_ulysses_more() {
        let cluster = ClusterSpec::dgx_2x8();
        let ours = DistFlashAttn::default();
        let uly = Ulysses;
        let seq = 16384;
        let r7b = uly
            .iteration(&PaperModel::llama_7b(), &cluster, seq)
            .total_s()
            / ours
                .iteration(&PaperModel::llama_7b(), &cluster, seq)
                .total_s();
        let r33 = uly
            .iteration(&PaperModel::llama_33h(), &cluster, seq)
            .total_s()
            / ours
                .iteration(&PaperModel::llama_33h(), &cluster, seq)
                .total_s();
        assert!(r33 > r7b, "33H ratio {r33} should exceed 7B ratio {r7b}");
        // paper Table 4: 1.21-1.26x (7B) and 1.81-1.88x (33H)
        assert!((1.05..1.6).contains(&r7b), "7B ratio {r7b}");
        assert!((1.5..2.4).contains(&r33), "33H ratio {r33}");
    }
}
