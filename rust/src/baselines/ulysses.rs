//! DeepSpeed-Ulysses baseline (Jacobs et al. 2023).
//!
//! Sequence parallel everywhere except attention, which is head-parallel:
//! four all-to-alls per layer forward (q, k, v in; o out) re-shard tokens
//! to heads and back, four more in backward, four again when checkpointing
//! recomputes the forward. Head-parallelism inherits Megatron's padding
//! problem on irregular head counts (§4.4: 1.81-1.88x slower on LLaMA-33H)
//! and its max parallel degree is the head count.

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::simulator::collective::all_to_all;

use super::{fsdp_param_bytes, IterBreakdown, SystemModel};
use super::megatron::Megatron;

#[derive(Clone, Copy, Debug, Default)]
pub struct Ulysses;

impl SystemModel for Ulysses {
    fn name(&self) -> String {
        "DeepSpeed-Ulysses".into()
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        let p = cluster.n_gpus();
        let c = seq_per_gpu as f64; // local tokens
        let n = c * p as f64; // full sequence
        let l = model.n_layers as f64;
        let e = model.d_model as f64;
        let pad = Megatron::pad_factor(model, p);

        // --- compute ---
        // linear parts on local c tokens; attention: padded heads / p over
        // the full sequence (causal, flash)
        let lin = cluster.compute_time(model.layer_linear_flops(c), cluster.gpu.mfu_gemm);
        let attn = cluster.compute_time(
            model.attn_pair_flops(n, n, true) * pad / p as f64,
            cluster.gpu.mfu_attn,
        );
        let head_s =
            cluster.compute_time(2.0 * c * e * model.vocab as f64, cluster.gpu.mfu_gemm);

        // --- comm: 4 a2a fwd + 4 bwd + 4 recompute on (c·E)-ish tensors;
        // kv a2a shrink under GQA ---
        let (bw, lat) = cluster.collective_bottleneck(p);
        let q_bytes = c * e * ELEM_BYTES;
        let kv_bytes = c * (model.n_kv_heads * model.head_dim) as f64 * ELEM_BYTES;
        let a2a_set = all_to_all(q_bytes, p, bw, lat) * 2.0 // q in, o out
            + all_to_all(kv_bytes, p, bw, lat) * 2.0; // k, v in
        let comm_per_layer = 3.0 * a2a_set; // fwd + bwd + ckpt recompute

        let fwd = l * (lin + attn) + head_s;
        // FA2 backward is ~2.5x its forward; GEMM backward is 2x
        let bwd = l * (2.0 * lin + 2.5 * attn) + 2.0 * head_s;
        let recompute = l * (lin + attn);
        let exposed = l * comm_per_layer;

        // --- memory: like ours but layer-boundary checkpoints (no extra
        // saved attention outputs) and full-N heads working set ---
        let stored = l * c * e * ELEM_BYTES;
        let padded_heads = (model.n_heads as f64 * pad) / p as f64;
        let attn_working = 4.0 * n * padded_heads * model.head_dim as f64 * ELEM_BYTES;
        let working = 6.0 * c * e * ELEM_BYTES
            + 3.0 * c * model.d_ff as f64 * ELEM_BYTES
            + attn_working;
        let logits = c * model.vocab as f64 * ELEM_BYTES;
        let peak = fsdp_param_bytes(model, p) + stored + working + logits;

        IterBreakdown {
            fwd_compute_s: fwd,
            bwd_compute_s: bwd,
            recompute_s: recompute,
            exposed_comm_s: exposed,
            peak_mem_bytes: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::distflash::DistFlashAttn;

    #[test]
    fn irregular_heads_hurt_ulysses_more() {
        let cluster = ClusterSpec::dgx_2x8();
        let ours = DistFlashAttn::default();
        let uly = Ulysses;
        let seq = 16384;
        let r7b = uly
            .iteration(&PaperModel::llama_7b(), &cluster, seq)
            .total_s()
            / ours
                .iteration(&PaperModel::llama_7b(), &cluster, seq)
                .total_s();
        let r33 = uly
            .iteration(&PaperModel::llama_33h(), &cluster, seq)
            .total_s()
            / ours
                .iteration(&PaperModel::llama_33h(), &cluster, seq)
                .total_s();
        assert!(r33 > r7b, "33H ratio {r33} should exceed 7B ratio {r7b}");
        // paper Table 4: 1.21-1.26x (7B) and 1.81-1.88x (33H)
        assert!((1.05..1.6).contains(&r7b), "7B ratio {r7b}");
        assert!((1.5..2.4).contains(&r33), "33H ratio {r33}");
    }
}
