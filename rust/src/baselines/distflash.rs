//! DISTFLASHATTN (our system) cost model.
//!
//! Sequence parallel over all GPUs; attention timed by the lock-step
//! schedule simulator (balanced/ring × overlap on/off — the ablation axes
//! of Figure 4); rematerialization-aware or HF-style checkpointing
//! (Table 5); FSDP parameter sharding like the paper's experimental setup.

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::coordinator::{CkptStrategy, Schedule, ScheduleKind};
use crate::simulator::{simulate_attention, AttnCost, SimResult};

use super::{fsdp_param_bytes, IterBreakdown, SystemModel};

#[derive(Clone, Copy, Debug)]
pub struct DistFlashAttn {
    pub schedule: ScheduleKind,
    pub overlap: bool,
    pub ckpt: CkptStrategy,
    pub fsdp: bool,
}

impl Default for DistFlashAttn {
    fn default() -> Self {
        DistFlashAttn {
            schedule: ScheduleKind::Balanced,
            overlap: true,
            ckpt: CkptStrategy::RematAware,
            fsdp: true,
        }
    }
}

impl DistFlashAttn {
    /// The paper's ablation baseline: ring + no overlap + HF checkpoints —
    /// what §4.3/§4.5 treat as a PyTorch Ring Attention equivalent.
    pub fn unoptimized() -> Self {
        DistFlashAttn {
            schedule: ScheduleKind::Ring,
            overlap: false,
            ckpt: CkptStrategy::HfStyle,
            fsdp: true,
        }
    }

    /// Forward attention cost parameters for one layer.
    fn attn_cost(&self, model: &PaperModel, cluster: &ClusterSpec, c: f64, bwd: bool) -> AttnCost {
        let full_flops = model.attn_pair_flops(c, c, false);
        let diag_flops = model.attn_pair_flops(c, c, true);
        // FA2 backward does ~2.5x the forward matmul work
        let mult = if bwd { 2.5 } else { 1.0 };
        let (kv, q, result) = if bwd {
            // kv fetch + (dk, dv) return; helper bundle (q, o, lse, do); dq
            (
                2.0 * model.kv_bytes(c),
                3.0 * model.q_bytes(c),
                model.q_bytes(c),
            )
        } else {
            // kv fetch; q to helper; (o, m, l) partial back
            (
                model.kv_bytes(c),
                model.q_bytes(c),
                model.q_bytes(c) * 1.1,
            )
        };
        AttnCost {
            pair_full_s: cluster.compute_time(full_flops * mult, cluster.gpu.mfu_attn),
            pair_diag_s: cluster.compute_time(diag_flops * mult, cluster.gpu.mfu_attn),
            rescale_s: cluster.compute_time(
                (c * (model.n_heads * model.head_dim) as f64) * 4.0,
                0.05, // elementwise, memory-bound
            ),
            kv_bytes: kv,
            q_bytes: q,
            result_bytes: result,
            overlap: self.overlap,
        }
    }

    /// Simulated attention timing for one layer (exposed separately for the
    /// Figure 4 ablations).
    pub fn attn_sim(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
        bwd: bool,
    ) -> SimResult {
        let schedule = Schedule::build(self.schedule, cluster.n_gpus());
        let cost = self.attn_cost(model, cluster, seq_per_gpu as f64, bwd);
        simulate_attention(&schedule, cluster, &cost)
    }

    fn fsdp_exposed_s(&self, model: &PaperModel, cluster: &ClusterSpec, hideable_s: f64) -> f64 {
        if !self.fsdp {
            return 0.0;
        }
        let g = cluster.n_gpus();
        let (bw, lat) = cluster.collective_bottleneck(g);
        let layer_bytes = model.n_params() / model.n_layers as f64 * 2.0;
        // per layer: gather weights in fwd + gather in bwd + reduce-scatter
        // grads; prefetched on a side stream, exposed beyond compute only
        let per_layer = 2.0 * crate::simulator::collective::all_gather(layer_bytes / g as f64, g, bw, lat)
            + crate::simulator::collective::reduce_scatter(layer_bytes, g, bw, lat);
        let total = per_layer * model.n_layers as f64;
        (total - hideable_s).max(0.0)
    }
}

impl SystemModel for DistFlashAttn {
    fn name(&self) -> String {
        format!(
            "DistFlashAttn[{:?},{},{}]",
            self.schedule,
            if self.overlap { "overlap" } else { "no-overlap" },
            self.ckpt.name()
        )
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        let p = cluster.n_gpus();
        let c = seq_per_gpu as f64;
        let l = model.n_layers as f64;
        let e = model.d_model as f64;

        // --- per-layer compute ---
        let lin_fwd = cluster.compute_time(model.layer_linear_flops(c), cluster.gpu.mfu_gemm);
        let attn_fwd = self.attn_sim(model, cluster, seq_per_gpu, false);
        let attn_bwd = self.attn_sim(model, cluster, seq_per_gpu, true);
        // head + embed (once, not per layer)
        let head_s = cluster.compute_time(
            2.0 * c * e * model.vocab as f64,
            cluster.gpu.mfu_gemm,
        );

        let fwd_per_layer = lin_fwd + attn_fwd.total_s;
        let bwd_per_layer = 2.0 * lin_fwd + attn_bwd.total_s;
        let recompute_per_layer = match self.ckpt {
            // HF-style redoes part1 + distributed attention fwd (incl. comm)
            CkptStrategy::HfStyle => lin_fwd + attn_fwd.total_s,
            // ours: only the cheap linear projections
            CkptStrategy::RematAware => lin_fwd * 0.4, // qkv+ln share of linear
        };

        let fwd = l * fwd_per_layer + head_s;
        let bwd = l * bwd_per_layer + 2.0 * head_s;
        let recompute = l * recompute_per_layer;
        let exposed = self.fsdp_exposed_s(model, cluster, l * lin_fwd * 2.0);

        // --- memory ---
        let kv_dim = (model.n_kv_heads * model.head_dim) as f64;
        let stored_per_layer = c * e * ELEM_BYTES
            + self.ckpt.extra_saved_floats(model.n_heads, seq_per_gpu, model.head_dim) as f64
                * ELEM_BYTES;
        // bwd working set: x, qkv, attn buffers, two in-flight remote kv
        // chunks (current + prefetch), mlp intermediates
        let working = c * e * ELEM_BYTES * 6.0
            + 3.0 * c * (model.d_ff as f64) * ELEM_BYTES
            + 4.0 * c * kv_dim * ELEM_BYTES;
        let logits = c * model.vocab as f64 * ELEM_BYTES;
        let peak = fsdp_param_bytes(model, p) + l * stored_per_layer + working + logits;

        IterBreakdown {
            fwd_compute_s: fwd,
            bwd_compute_s: bwd,
            recompute_s: recompute,
            exposed_comm_s: exposed
                + (attn_fwd.total_s - attn_fwd.step_s.len() as f64 * 0.0) * 0.0, // already inside sim
            peak_mem_bytes: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_overlap_beats_unoptimized() {
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ours = DistFlashAttn::default().iteration(&model, &cluster, 8192);
        let base = DistFlashAttn::unoptimized().iteration(&model, &cluster, 8192);
        assert!(ours.total_s() < base.total_s());
    }

    #[test]
    fn remat_aware_saves_attention_recompute() {
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ours = DistFlashAttn::default();
        let hf = DistFlashAttn { ckpt: CkptStrategy::HfStyle, ..ours };
        let a = ours.iteration(&model, &cluster, 32768);
        let b = hf.iteration(&model, &cluster, 32768);
        // paper Table 5: 1.31x at 32K/GPU
        let speedup = b.total_s() / a.total_s();
        assert!(
            (1.15..1.6).contains(&speedup),
            "ckpt speedup {speedup} out of band"
        );
    }

    #[test]
    fn supports_paper_scale_sequences() {
        // Table 3: >256K total on 1 DGX node, >512K on 2 (80GB)
        let model = PaperModel::llama_7b();
        let ours = DistFlashAttn::default();
        let one = ours.max_seq_per_gpu(&model, &ClusterSpec::dgx_1x8(), 1024, 1 << 20);
        assert!(
            one * 8 >= 256 * 1024,
            "1-node max total {} < 256K",
            one * 8
        );
        let two = ours.max_seq_per_gpu(&model, &ClusterSpec::dgx_2x8(), 1024, 1 << 20);
        assert!(two * 16 >= 512 * 1024, "2-node max total {}", two * 16);
    }

    #[test]
    fn fig4_left_speedups() {
        // attention-only speedup vs a single GPU: unbalanced saturates
        // near 4.5x, balanced near 7.5x (Fig. 4 left, 8 GPUs)
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let c = 32768; // long enough to saturate
        let ours = DistFlashAttn::default();
        let ring = DistFlashAttn { schedule: ScheduleKind::Ring, ..ours };
        let single_pair = ours.attn_cost(&model, &cluster, c as f64, false);
        // single-GPU flash time over the same total sequence (8c tokens):
        // causal attention = half of full 8c x 8c
        let single_s = cluster.compute_time(
            model.attn_pair_flops((8 * c) as f64, (8 * c) as f64, true),
            cluster.gpu.mfu_attn,
        );
        let bal_s = ours.attn_sim(&model, &cluster, c, false).total_s;
        let ring_s = ring.attn_sim(&model, &cluster, c, false).total_s;
        let _ = single_pair;
        let sp_bal = single_s / bal_s;
        let sp_ring = single_s / ring_s;
        assert!((4.0..5.0).contains(&sp_ring), "ring speedup {sp_ring}");
        assert!((6.8..8.0).contains(&sp_bal), "balanced speedup {sp_bal}");
    }
}
