//! Megatron-LM tensor-parallel baseline (+DP / +PP variants for Table 2).
//!
//! Communication volumes follow paper §D: per layer, 6 all-gathers + 4
//! reduce-scatters on (N/g)·d tensors across fwd+bwd (10Nd), plus the
//! forward collectives again under gradient checkpointing (14Nd total).
//! Head padding: Megatron requires heads divisible by the TP degree; with
//! H=33 on g=16 it pads to 48 heads — 45.5% wasted attention/qkv compute
//! (§4.2). Memory model uses sequence-parallel activations (Korthikanti
//! et al.) with full recompute.

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::simulator::collective::{all_gather, reduce_scatter};

use super::{IterBreakdown, SystemModel, OPT_BYTES_PER_PARAM};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MegatronMode {
    /// Tensor parallel across all GPUs (Table 1 baseline).
    Tp,
    /// TP limited to the head count, data parallel elsewhere (Table 2).
    TpDp,
    /// TP limited to the head count, pipeline parallel elsewhere (Table 2).
    TpPp,
}

#[derive(Clone, Copy, Debug)]
pub struct Megatron {
    pub mode: MegatronMode,
}

impl Megatron {
    pub fn tp() -> Self {
        Megatron { mode: MegatronMode::Tp }
    }

    pub fn tp_dp() -> Self {
        Megatron { mode: MegatronMode::TpDp }
    }

    pub fn tp_pp() -> Self {
        Megatron { mode: MegatronMode::TpPp }
    }

    /// TP degree and (DP-or-PP) degree for a model on a cluster.
    pub fn degrees(&self, model: &PaperModel, cluster: &ClusterSpec) -> (usize, usize) {
        let n = cluster.n_gpus();
        match self.mode {
            MegatronMode::Tp => (n, 1),
            // TP cannot exceed head count without padding every head away;
            // Table 2 runs TP = heads and spreads the rest
            MegatronMode::TpDp | MegatronMode::TpPp => {
                let g = model.n_heads.min(n);
                (g, n / g)
            }
        }
    }

    /// Padded-heads waste factor: ceil(H/g)·g / H (1.0 when divisible).
    pub fn pad_factor(model: &PaperModel, g: usize) -> f64 {
        let h = model.n_heads;
        let padded = h.div_ceil(g) * g;
        padded as f64 / h as f64
    }

    /// Context length given `seq_per_gpu`: every table reports
    /// seq_per_gpu × n_gpus as the context. Under TP(+DP) the WHOLE context
    /// lives on one TP group (data parallelism trains other sequences; it
    /// cannot split this one — the paper's §4.2 point), so the TP group
    /// processes all N tokens.
    fn seq_total(&self, cluster: &ClusterSpec, seq_per_gpu: usize) -> f64 {
        (seq_per_gpu * cluster.n_gpus()) as f64
    }
}

impl SystemModel for Megatron {
    fn name(&self) -> String {
        match self.mode {
            MegatronMode::Tp => "Megatron-LM (TP)".into(),
            MegatronMode::TpDp => "Megatron-LM (TP+DP)".into(),
            MegatronMode::TpPp => "Megatron-LM (TP+PP)".into(),
        }
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        let (g, rest) = self.degrees(model, cluster);
        let dp = if self.mode == MegatronMode::TpDp { rest } else { 1 };
        let pp = if self.mode == MegatronMode::TpPp { rest } else { 1 };
        let n = self.seq_total(cluster, seq_per_gpu);
        let l = model.n_layers as f64;
        let pad = Self::pad_factor(model, g);

        // --- compute (per GPU): layer flops / g, attention+qkv padded ---
        let lin = cluster.compute_time(
            model.layer_linear_flops(n) * pad / g as f64,
            cluster.gpu.mfu_gemm,
        );
        let attn = cluster.compute_time(
            model.attn_pair_flops(n, n, true) * pad / g as f64,
            cluster.gpu.mfu_attn,
        );
        let head_s = cluster.compute_time(
            2.0 * n * model.d_model as f64 * model.vocab as f64 / g as f64,
            cluster.gpu.mfu_gemm,
        );
        let fwd_layer = lin + attn;

        // --- §D comm: fwd 2AG+2RS, bwd 4 more, recompute fwd again ---
        let (bw, lat) = cluster.collective_bottleneck(g);
        let shard_bytes = n * model.d_model as f64 * ELEM_BYTES / g as f64;
        let ag = all_gather(shard_bytes, g, bw, lat);
        let rs = reduce_scatter(shard_bytes * g as f64, g, bw, lat);
        let comm_fwd_layer = 2.0 * ag + 2.0 * rs;
        let comm_bwd_layer = 4.0 * ag + 2.0 * rs; // 6AG+4RS total fwd+bwd
        let comm_per_layer = comm_fwd_layer * 2.0 + comm_bwd_layer; // + recompute

        // pipeline bubble: (pp-1)/m with m microbatches; paper runs few
        // microbatches at batch 1 — model m = pp (modest bubble)
        let bubble = if pp > 1 { (pp - 1) as f64 / pp as f64 } else { 0.0 };
        let layers_here = l / pp as f64;

        let fwd = layers_here * fwd_layer + head_s;
        // FA2 backward is ~2.5x its forward; GEMM backward is 2x
        let bwd = layers_here * (2.0 * lin + 2.5 * attn) + 2.0 * head_s;
        let recompute = layers_here * fwd_layer;
        let exposed = layers_here * comm_per_layer
            + bubble * (fwd + bwd + recompute);

        // --- memory ---
        // batch size 1: a single sequence cannot be microbatched, so PP
        // keeps one in-flight activation set; DP shards only optimizer
        // state (Megatron distributed optimizer / ZeRO-1)
        let params_here = model.n_params() / (g * pp) as f64;
        let param_bytes = params_here * 4.0
            + model.n_params() * 12.0 / (g * pp * dp.max(1)) as f64;
        // sequence-parallel checkpointed input per layer: N·E/g, plus the
        // recompute working set of one layer (~6 activations of N·E/g and
        // 3 of N·F/g), flash attention => no N² term
        let e = model.d_model as f64;
        let stored = layers_here * n * e * ELEM_BYTES / g as f64;
        let working = 6.0 * n * e * ELEM_BYTES / g as f64
            + 3.0 * n * model.d_ff as f64 * ELEM_BYTES / g as f64;
        // vocab-parallel cross-entropy: fp32 logits; the last PP stage
        // additionally keeps a softmax copy (the Table 6 jump)
        let logits = n * model.vocab as f64 * (if pp > 1 { 8.0 } else { 4.0 })
            / g as f64;
        let peak = param_bytes + stored + working + logits;

        IterBreakdown {
            fwd_compute_s: fwd,
            bwd_compute_s: bwd,
            recompute_s: recompute,
            exposed_comm_s: exposed,
            peak_mem_bytes: peak,
        }
    }
}

/// Per-stage memory for Megatron TP+PP (Table 6's uneven distribution):
/// stage i of S holds (S - i) in-flight microbatch activations (1F1B) plus
/// its layer shard; stage 0 adds the embedding, the last adds head+loss.
pub fn pp_stage_memory(
    model: &PaperModel,
    cluster: &ClusterSpec,
    seq_per_gpu: usize,
    tp: usize,
    pp: usize,
) -> Vec<f64> {
    let n = (seq_per_gpu * cluster.n_gpus()) as f64;
    let e = model.d_model as f64;
    let l = model.n_layers as f64 / pp as f64;
    let emb_bytes = model.vocab as f64 * e * OPT_BYTES_PER_PARAM / tp as f64;
    let layer_params =
        (model.n_params() - 2.0 * model.vocab as f64 * e) / model.n_layers as f64;
    (0..pp)
        .map(|i| {
            let in_flight = (pp - i) as f64;
            let stored = l * n * e * ELEM_BYTES / tp as f64 * in_flight;
            let params = l * layer_params * OPT_BYTES_PER_PARAM / tp as f64;
            let ends = if i == 0 {
                emb_bytes
            } else if i == pp - 1 {
                // LM head + fp32 logits + softmax/loss copies — the jump
                // Table 6 shows on the last stage (17.9GB -> 32GB)
                emb_bytes + n * model.vocab as f64 * (4.0 + 4.0) / tp as f64
            } else {
                0.0
            };
            params + stored + ends
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_factor_matches_paper() {
        // 33 heads on TP=16 → pad to 48 → 45.5% waste (§4.2)
        let m = PaperModel::llama_33h();
        let f = Megatron::pad_factor(&m, 16);
        assert!((f - 48.0 / 33.0).abs() < 1e-12);
        assert!(((f - 1.0) * 100.0 - 45.45).abs() < 0.1);
        // divisible → no waste
        assert_eq!(Megatron::pad_factor(&PaperModel::llama_7b(), 8), 1.0);
    }

    #[test]
    fn degrees_respect_head_limit() {
        let cluster = ClusterSpec::cluster_16x40g();
        let m2 = PaperModel::llama_nh(2);
        assert_eq!(Megatron::tp_dp().degrees(&m2, &cluster), (2, 8));
        assert_eq!(Megatron::tp().degrees(&m2, &cluster), (16, 1));
    }

    #[test]
    fn pp_memory_uneven_first_heaviest_activations() {
        let m = PaperModel::llama_nh(2);
        let cluster = ClusterSpec::cluster_16x40g();
        let stages = pp_stage_memory(&m, &cluster, 8192, 2, 8);
        assert_eq!(stages.len(), 8);
        // Table 6 shape: early stages heavier than middle, last jumps up
        assert!(stages[0] > stages[5]);
        assert!(stages[7] > stages[5]);
        let spread = stages.iter().cloned().fold(0.0, f64::max)
            / stages.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.3, "spread {spread}");
    }
}
