//! Cost + memory models for every system in the paper's evaluation:
//!
//! * [`distflash`] — DISTFLASHATTN (ours): balanced schedule, overlap,
//!   rematerialization-aware checkpointing, FSDP weights.
//! * [`megatron`] — Megatron-LM tensor parallelism (+DP/+PP variants),
//!   comm volumes from paper §D, head padding for irregular head counts.
//! * [`ulysses`] — DeepSpeed-Ulysses all-to-all head parallelism.
//! * [`rsa`] — Ring Self-Attention (Li et al. 2021): sequence parallel but
//!   no memory-efficient attention (materializes score matrices).
//! * [`ring_attention`] — Ring Attention (Liu et al. 2023): blockwise and
//!   memory-efficient, but causally unbalanced (2× attention work) and
//!   layer-boundary checkpointing.
//!
//! Every model returns an [`IterBreakdown`] so tables can show and compare
//! the same decomposition the paper discusses.

pub mod distflash;
pub mod megatron;
pub mod ring_attention;
pub mod rsa;
pub mod ulysses;

use crate::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use crate::simulator::AttnCost;

/// Forward-pass attention cost classes for a chunked schedule — the shared
/// resolution of the IR's `Kernel`/`Payload` classes used by the executed
/// (event-driven) baselines and the reports.
pub fn attn_cost_fwd(model: &PaperModel, cluster: &ClusterSpec, chunk_tokens: f64) -> AttnCost {
    attn_cost_from_dims(
        cluster,
        chunk_tokens,
        model.n_heads,
        model.n_kv_heads,
        model.head_dim,
    )
}

/// The canonical forward cost-class resolution, from raw dimensions — for
/// callers that only have a resolved workload (a `Session` over an
/// artifact manifest, verify) rather than a [`PaperModel`].
/// [`attn_cost_fwd`] is a thin delegate, so there is exactly one
/// definition of these formulas.
pub fn attn_cost_from_dims(
    cluster: &ClusterSpec,
    chunk_tokens: f64,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
) -> AttnCost {
    let c = chunk_tokens;
    let full_flops = 4.0 * c * c * (n_heads * head_dim) as f64;
    let q_bytes = c * (n_heads * head_dim) as f64 * ELEM_BYTES;
    AttnCost {
        pair_full_s: cluster.compute_time(full_flops, cluster.gpu.mfu_attn),
        pair_diag_s: cluster.compute_time(full_flops / 2.0, cluster.gpu.mfu_attn),
        rescale_s: cluster.compute_time(
            c * (n_heads * head_dim) as f64 * 4.0,
            0.05, // elementwise, memory-bound
        ),
        kv_bytes: 2.0 * c * (n_kv_heads * head_dim) as f64 * ELEM_BYTES,
        q_bytes,
        result_bytes: q_bytes * 1.1,
        overlap: true,
    }
}

/// Backward-pass cost classes for the same chunked schedule. The flash
/// backward kernel replays the pair matmuls plus the four gradient matmuls
/// (≈ 2.5× forward FLOPs); the q bundle carries (q, o, lse, do) — about 3×
/// the forward q payload — while the kv chunk (and the mirrored (dk, dv)
/// return) is sized by `n_kv_heads` exactly as in forward. Under
/// grouped-query attention the q-bundle/kv byte ratio therefore widens by
/// another 3×, which is what makes the optimizer's role-flipping pass fire
/// hardest on backward plans.
pub fn attn_cost_bwd(model: &PaperModel, cluster: &ClusterSpec, chunk_tokens: f64) -> AttnCost {
    bwd_cost_from_fwd(&attn_cost_fwd(model, cluster, chunk_tokens), model.head_dim)
}

/// Derive the backward cost classes from already-resolved forward classes —
/// the single definition of the bwd/fwd relationship, shared by
/// [`attn_cost_bwd`] and dimension-only callers (the `Session`, which
/// resolves a workload instead of a `PaperModel`).
pub fn bwd_cost_from_fwd(fwd: &AttnCost, head_dim: usize) -> AttnCost {
    AttnCost {
        pair_full_s: 2.5 * fwd.pair_full_s,
        pair_diag_s: 2.5 * fwd.pair_diag_s,
        // dq accumulate — same elementwise footprint as the fwd rescale
        rescale_s: fwd.rescale_s,
        kv_bytes: fwd.kv_bytes,
        // (q, o, do) + lse
        q_bytes: 3.0 * fwd.q_bytes + fwd.q_bytes / head_dim as f64,
        // dq partial
        result_bytes: fwd.q_bytes,
        overlap: true,
    }
}

/// One training iteration, decomposed (seconds), plus peak memory (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub fwd_compute_s: f64,
    pub bwd_compute_s: f64,
    /// Gradient-checkpointing recomputation.
    pub recompute_s: f64,
    /// Communication time NOT hidden under compute.
    pub exposed_comm_s: f64,
    pub peak_mem_bytes: f64,
}

impl IterBreakdown {
    pub fn total_s(&self) -> f64 {
        self.fwd_compute_s + self.bwd_compute_s + self.recompute_s + self.exposed_comm_s
    }

    pub fn fits(&self, cluster: &ClusterSpec) -> bool {
        // NCCL buffers / fragmentation headroom
        self.peak_mem_bytes <= cluster.gpu.mem_bytes * 0.92
    }
}

/// Common interface over all five systems (used by the table harness and
/// the max-sequence solver).
pub trait SystemModel {
    fn name(&self) -> String;

    /// Estimate one iteration at `seq_per_gpu` tokens per GPU.
    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown;

    /// Largest per-GPU sequence length (in tokens) that fits in memory,
    /// searched over multiples of `granularity`.
    fn max_seq_per_gpu(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        granularity: usize,
        cap: usize,
    ) -> usize {
        let mut best = 0;
        let mut lo = 1usize;
        let mut hi = cap / granularity;
        while lo <= hi {
            let mid = (lo + hi) / 2;
            let est = self.iteration(model, cluster, mid * granularity);
            if est.fits(cluster) {
                best = mid * granularity;
                lo = mid + 1;
            } else {
                hi = mid - 1;
            }
        }
        best
    }
}

/// Mixed-precision Adam footprint per parameter: bf16 weight + bf16 grad +
/// f32 master + f32 m + f32 v.
pub const OPT_BYTES_PER_PARAM: f64 = 2.0 + 2.0 + 4.0 + 4.0 + 4.0;

/// Per-GPU parameter-state bytes under full-shard FSDP/ZeRO-3 (plus the
/// transient fully-gathered working copy of one layer).
pub fn fsdp_param_bytes(model: &PaperModel, n_gpus: usize) -> f64 {
    let p = model.n_params();
    let per_layer = p / model.n_layers as f64;
    p * OPT_BYTES_PER_PARAM / n_gpus as f64 + 2.0 * per_layer * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total() {
        let b = IterBreakdown {
            fwd_compute_s: 1.0,
            bwd_compute_s: 2.0,
            recompute_s: 0.5,
            exposed_comm_s: 0.25,
            peak_mem_bytes: 1e9,
        };
        assert!((b.total_s() - 3.75).abs() < 1e-12);
    }

    #[test]
    fn fsdp_shards_optimizer() {
        let m = PaperModel::llama_7b();
        let one = fsdp_param_bytes(&m, 1);
        let sixteen = fsdp_param_bytes(&m, 16);
        assert!(one > 10.0 * sixteen);
        // 7B on 16 GPUs: ~6.7GB sharded state + ~0.8GB gathered layer
        assert!(sixteen < 10e9, "{sixteen:e}");
    }
}
