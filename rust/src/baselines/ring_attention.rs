//! Ring Attention baseline (Liu et al. 2023).
//!
//! Blockwise and memory-efficient (like ours) but: (1) causally unbalanced
//! — the ring runs P rounds and every worker traverses the masked pairs
//! too, ~2× the causal work; (2) layer-boundary checkpointing, so the
//! distributed attention forward is recomputed in backward. §4.3 treats
//! the paper's own ring/no-balance ablation as the PyTorch-comparable Ring
//! Attention: 4.5× vs 7.5× attention speedup over one GPU, 1.67× e2e.
//!
//! Two views of the system live here:
//! * the `as_distflash`-based [`SystemModel`] — the analytic end-to-end
//!   iteration model (unchanged);
//! * [`RingAttention::plan`] / [`RingAttention::executed_attn`] — the
//!   rotating-kv pipeline expressed in the schedule IR and *executed* by
//!   the event engine, so the comparison against our schedules is a run
//!   of one engine over two plans, not two disconnected formulas.

use crate::config::{ClusterSpec, PaperModel};
use crate::coordinator::{CkptStrategy, Plan, ScheduleKind};
use crate::simulator::{simulate_plan, EventOpts, EventResult};

use super::distflash::DistFlashAttn;
use super::{attn_cost_fwd, IterBreakdown, SystemModel};

#[derive(Clone, Copy, Debug, Default)]
pub struct RingAttention;

impl RingAttention {
    /// Ring Attention ≡ DISTFLASHATTN minus balancing minus remat-aware
    /// checkpointing (it does overlap its ring sends).
    fn as_distflash() -> DistFlashAttn {
        DistFlashAttn {
            schedule: ScheduleKind::Ring,
            overlap: true,
            ckpt: CkptStrategy::HfStyle,
            fsdp: true,
        }
    }

    /// The rotating-kv dataflow plan (P rounds, masked pairs included).
    pub fn plan(p: usize) -> Plan {
        Plan::ring_attention(p)
    }

    /// Event-engine execution of one attention forward at `seq_per_gpu`
    /// tokens per worker.
    pub fn executed_attn(
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> EventResult {
        let plan = Self::plan(cluster.n_gpus());
        let cost = attn_cost_fwd(model, cluster, seq_per_gpu as f64);
        simulate_plan(&plan, cluster, &cost, &EventOpts::default())
    }
}

impl SystemModel for RingAttention {
    fn name(&self) -> String {
        "Ring Attention".into()
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        Self::as_distflash().iteration(model, cluster, seq_per_gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Pass, Schedule};

    #[test]
    fn ours_faster_end_to_end() {
        // §4.3: 1.67x over Ring Attention in the 8-GPU setting
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ra = RingAttention.iteration(&model, &cluster, 32768).total_s();
        let ours = DistFlashAttn::default()
            .iteration(&model, &cluster, 32768)
            .total_s();
        let ratio = ra / ours;
        assert!((1.3..2.1).contains(&ratio), "ring-attention ratio {ratio}");
    }

    #[test]
    fn same_memory_class_as_ours() {
        // both are memory-efficient: max seq within 2x of each other
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ra = RingAttention.max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        let ours = DistFlashAttn::default().max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        assert!(ra * 2 >= ours, "ra {ra} ours {ours}");
    }

    #[test]
    fn executed_matches_causal_ring_wallclock_but_doubles_bytes() {
        // compute-bound regime: the rotating pipeline's wall-clock equals
        // the causal ring schedule's (what §4.3 exploits), yet it ships
        // exactly 2x the kv bytes (no causal skipping)
        let cluster = ClusterSpec::dgx_1x8();
        let model = PaperModel::llama_7b();
        let mut cost = attn_cost_fwd(&model, &cluster, 4096.0);
        cost.kv_bytes = 1e3;
        cost.q_bytes = 1e3;
        cost.result_bytes = 1e3;
        let opts = EventOpts::default();
        let ra = simulate_plan(&RingAttention::plan(8), &cluster, &cost, &opts);
        let causal = Schedule::ring(8).lower(Pass::Forward);
        let ring = simulate_plan(&causal, &cluster, &cost, &opts);
        let rel = (ra.total_s - ring.total_s).abs() / ring.total_s;
        assert!(rel < 1e-9, "ra {} vs causal ring {}", ra.total_s, ring.total_s);
        assert!(
            (ra.comm_bytes - 2.0 * ring.comm_bytes).abs() < 1.0,
            "bytes {} vs 2x {}",
            ra.comm_bytes,
            ring.comm_bytes
        );
    }

    #[test]
    fn executed_balanced_beats_ring_attention() {
        // the paper's headline at the executed level: balanced timeline
        // (P/2 + 1 steps) vs the P-round ring -> ~0.6x at P=8
        let cluster = ClusterSpec::dgx_1x8();
        let model = PaperModel::llama_7b();
        let cost = attn_cost_fwd(&model, &cluster, 4096.0);
        let opts = EventOpts::default();
        let ra = simulate_plan(&RingAttention::plan(8), &cluster, &cost, &opts);
        let bal = simulate_plan(
            &Schedule::balanced(8).lower(Pass::Forward),
            &cluster,
            &cost,
            &opts,
        );
        let ratio = bal.total_s / ra.total_s;
        assert!((0.5..0.7).contains(&ratio), "balanced/ring-attention {ratio}");
    }
}
