//! Ring Attention baseline (Liu et al. 2023).
//!
//! Blockwise and memory-efficient (like ours) but: (1) causally unbalanced
//! — the ring runs P rounds and workers with early chunks idle (equivalent
//! wall-clock to computing the masked pairs, ~2× the causal work); (2)
//! layer-boundary checkpointing, so the distributed attention forward is
//! recomputed in backward. §4.3 treats the paper's own ring/no-balance
//! ablation as the PyTorch-comparable Ring Attention: 4.5× vs 7.5×
//! attention speedup over one GPU, 1.67× end-to-end.

use crate::config::{ClusterSpec, PaperModel};
use crate::coordinator::{CkptStrategy, ScheduleKind};

use super::distflash::DistFlashAttn;
use super::{IterBreakdown, SystemModel};

#[derive(Clone, Copy, Debug, Default)]
pub struct RingAttention;

impl RingAttention {
    /// Ring Attention ≡ DISTFLASHATTN minus balancing minus remat-aware
    /// checkpointing (it does overlap its ring sends).
    fn as_distflash() -> DistFlashAttn {
        DistFlashAttn {
            schedule: ScheduleKind::Ring,
            overlap: true,
            ckpt: CkptStrategy::HfStyle,
            fsdp: true,
        }
    }
}

impl SystemModel for RingAttention {
    fn name(&self) -> String {
        "Ring Attention".into()
    }

    fn iteration(
        &self,
        model: &PaperModel,
        cluster: &ClusterSpec,
        seq_per_gpu: usize,
    ) -> IterBreakdown {
        Self::as_distflash().iteration(model, cluster, seq_per_gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_faster_end_to_end() {
        // §4.3: 1.67x over Ring Attention in the 8-GPU setting
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ra = RingAttention.iteration(&model, &cluster, 32768).total_s();
        let ours = DistFlashAttn::default()
            .iteration(&model, &cluster, 32768)
            .total_s();
        let ratio = ra / ours;
        assert!((1.3..2.1).contains(&ratio), "ring-attention ratio {ratio}");
    }

    #[test]
    fn same_memory_class_as_ours() {
        // both are memory-efficient: max seq within 2x of each other
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let ra = RingAttention.max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        let ours = DistFlashAttn::default().max_seq_per_gpu(&model, &cluster, 1024, 1 << 20);
        assert!(ra * 2 >= ours, "ra {ra} ours {ours}");
    }
}
