//! Inference serving: continuous-batching decode on the schedule IR.
//!
//! The serving subsystem reuses the training stack's
//! plan → simulate → execute → trace spine for the *other* half of an
//! LLM's life: a [`ServeSpec`] (the [`crate::coordinator::RunSpec`]
//! sibling) declares a workload, an arrival process ([`Arrivals`]), and
//! the batching/backpressure knobs; [`serve`] then
//!
//! 1. draws the request stream ([`scheduler::gen_requests`]),
//! 2. runs the TGI-shaped continuous-batching loop on a virtual clock
//!    ([`scheduler::schedule`]) — admit from a bounded queue, filter
//!    finished requests out of the running batch, concatenate waiting
//!    prefills into the decode batch under a token budget, with the
//!    varlen rebalancer spreading prefill waves across ranks,
//! 3. lowers the step log to a lockstep [`crate::coordinator::Pass::Decode`]
//!    plan ([`scheduler::lower`]) over the `KvAppend` / `KvLookup` /
//!    `KvEvict` / `DecodeAttn` op kinds,
//! 4. scores it with the event engine (tokens/sec, p50/p99 latency —
//!    [`ServeScore`]), and
//! 5. on the hostref backend, replays the log with real kernels over
//!    per-rank paged KV-caches ([`PagedKvCache`]), checks every decode
//!    row bit-for-bit against a one-shot full-prefill oracle, and
//!    calibrates the measured trace back through the simulator
//!    ([`ServeExec`]).
//!
//! The executed replay runs the admission schedule as fast as the host
//! allows (arrival gaps are not slept), so its latency quantiles are
//! completion times since run start; measured tokens/sec is the
//! throughput gate (`repro bench --serve-out`, `BENCH_serve.json`).
//! The serial no-batching baseline (`batching: false`) is the same loop
//! restricted to one request in flight — the 2x comparison arm.

pub mod kvcache;
pub mod scheduler;

pub use kvcache::{PageTable, PagedKvCache};
pub use scheduler::{
    gen_requests, quantile, rank_ops, Executed, Lowered, OpRole, Request, ServeLog, ServeScore,
    StepLog,
};

use anyhow::{anyhow, bail, ensure, Result};

use crate::baselines::attn_cost_from_dims;
use crate::config::ClusterSpec;
use crate::coordinator::executor::MergedTrace;
use crate::coordinator::session::{
    cluster_from_json, cluster_to_json, opt_bool, opt_f64, opt_usize, u64_from_json, u64_to_json,
    BackendSpec, Workload,
};
use crate::report::trace as trace_report;
use crate::runtime::kernel::tiled::autotune;
use crate::runtime::Tiles;
use crate::util::Json;

/// Request arrival process.
#[derive(Clone, Debug, PartialEq)]
pub enum Arrivals {
    /// Open-loop Poisson stream: exponential inter-arrival gaps with the
    /// given mean rate (requests per virtual second).
    Poisson { rate: f64 },
    /// Trace replay: explicit absolute arrival times, one per request,
    /// sorted non-decreasing.
    Replay { times_s: Vec<f64> },
}

/// Everything one serving run depends on, declared up front — the
/// serving sibling of [`crate::coordinator::RunSpec`]. Construct with
/// [`ServeSpec::dev`] and override fields with struct-update syntax;
/// serialize with [`ServeSpec::to_json`] / [`ServeSpec::from_json`]
/// (the `repro serve --spec` contract).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    /// Head geometry; `chunk_tokens` is the reference chunk the cost
    /// classes are resolved at (serving scales every op by
    /// `tokens / chunk_tokens`).
    pub workload: Workload,
    /// Serving ranks: each owns a paged KV-cache and runs its share of
    /// the batch.
    pub n_workers: usize,
    /// Topology the cost classes price against.
    pub cluster: ClusterSpec,
    /// `HostRef` executes + oracle-checks; `Null` stops after
    /// simulation. `Pjrt` is rejected — decode artifacts don't exist.
    pub backend: BackendSpec,
    pub arrivals: Arrivals,
    pub n_requests: usize,
    /// Maximum prompt length; actual prompts are uniform on
    /// `[(1 - prompt_spread) * prompt_tokens, prompt_tokens]`.
    pub prompt_tokens: usize,
    /// Prompt-length jitter in `[0, 1]` (0 = every prompt is exactly
    /// `prompt_tokens`).
    pub prompt_spread: f64,
    /// Tokens generated per request (one per decode step).
    pub decode_tokens: usize,
    /// Token budget over the whole running batch: a request's full
    /// lifetime context (`prompt + decode`) is reserved at admission.
    pub max_batch_tokens: usize,
    /// Bounded waiting-queue capacity; arrivals beyond it are deferred.
    pub queue_cap: usize,
    /// KV-cache page size (token slots per page).
    pub page_size: usize,
    /// KV-cache pages *per rank*.
    pub n_pages: usize,
    /// `true` = continuous batching; `false` = the serial no-batching
    /// baseline (one request in flight, ever).
    pub batching: bool,
    /// Host-kernel worker threads per rank (clamped to the machine's
    /// available parallelism at execution).
    pub threads: usize,
    /// Pick decode/prefill tile geometry with the cached startup sweep
    /// ([`autotune`]) instead of the default tiles; the effective pick
    /// is recorded in the executed trace.
    pub autotune_tiles: bool,
    /// Seed for the arrival draw and per-request synthetic tensors.
    pub seed: u64,
}

impl ServeSpec {
    /// Small, fast preset on the 2×8-dev cluster: 4 ranks, a bursty
    /// Poisson stream (mean inter-arrival = 1/16 of a reference-chunk
    /// attention pair, so the batch fills quickly), and dims small
    /// enough to execute in well under a second.
    pub fn dev() -> ServeSpec {
        let workload = Workload::new(4, 2, 16, 12);
        let cluster = ClusterSpec::cluster_16x40g();
        let cost = attn_cost_from_dims(
            &cluster,
            workload.chunk_tokens as f64,
            workload.n_heads,
            workload.n_kv_heads,
            workload.head_dim,
        );
        let rate = 16.0 / cost.pair_full_s.max(1e-30);
        ServeSpec {
            workload,
            n_workers: 4,
            cluster,
            backend: BackendSpec::HostRef,
            arrivals: Arrivals::Poisson { rate },
            n_requests: 12,
            prompt_tokens: 12,
            prompt_spread: 0.5,
            decode_tokens: 6,
            max_batch_tokens: 256,
            queue_cap: 16,
            page_size: 8,
            n_pages: 12,
            batching: true,
            threads: 1,
            autotune_tiles: false,
            seed: 7,
        }
    }

    pub fn validate(&self) -> Result<()> {
        let w = &self.workload;
        ensure!(
            w.n_heads >= 1 && w.n_kv_heads >= 1 && w.head_dim >= 1 && w.chunk_tokens >= 1,
            "workload dims must all be >= 1 (got {w:?})"
        );
        ensure!(
            w.n_heads % w.n_kv_heads == 0,
            "{} query heads not divisible by {} kv heads",
            w.n_heads,
            w.n_kv_heads
        );
        for (name, v) in [
            ("n_workers", self.n_workers),
            ("n_requests", self.n_requests),
            ("prompt_tokens", self.prompt_tokens),
            ("decode_tokens", self.decode_tokens),
            ("queue_cap", self.queue_cap),
            ("page_size", self.page_size),
            ("n_pages", self.n_pages),
            ("threads", self.threads),
        ] {
            ensure!(v >= 1, "{name} must be >= 1");
        }
        ensure!(
            self.prompt_spread.is_finite() && (0.0..=1.0).contains(&self.prompt_spread),
            "prompt_spread must be in [0, 1] (got {})",
            self.prompt_spread
        );
        // progress guarantees: the largest possible request must fit an
        // empty rank's pages and the token budget alone, so admission
        // can never wedge
        let max_ctx = self.prompt_tokens + self.decode_tokens;
        ensure!(
            max_ctx.div_ceil(self.page_size) <= self.n_pages,
            "a full request ({max_ctx} tokens = {} pages of {}) exceeds the {} pages per rank",
            max_ctx.div_ceil(self.page_size),
            self.page_size,
            self.n_pages
        );
        ensure!(
            max_ctx <= self.max_batch_tokens,
            "a full request ({max_ctx} tokens) exceeds max_batch_tokens = {}",
            self.max_batch_tokens
        );
        match &self.arrivals {
            Arrivals::Poisson { rate } => {
                ensure!(
                    rate.is_finite() && *rate > 0.0,
                    "poisson arrival rate must be positive and finite (got {rate})"
                );
            }
            Arrivals::Replay { times_s } => {
                ensure!(
                    times_s.len() == self.n_requests,
                    "replay has {} arrival times for {} requests",
                    times_s.len(),
                    self.n_requests
                );
                for (i, t) in times_s.iter().enumerate() {
                    ensure!(
                        t.is_finite() && *t >= 0.0,
                        "replay time {i} must be finite and non-negative (got {t})"
                    );
                    ensure!(
                        i == 0 || times_s[i - 1] <= *t,
                        "replay times must be sorted non-decreasing (index {i})"
                    );
                }
            }
        }
        if let BackendSpec::Pjrt(_) = &self.backend {
            bail!("serving has no PJRT decode artifacts; use the hostref or null backend");
        }
        Ok(())
    }

    /// Serialize to the `repro serve --spec` JSON document. Floats print
    /// in Rust's shortest round-trip form, so `from_json(to_json(s)) == s`
    /// exactly.
    pub fn to_json(&self) -> String {
        let w = &self.workload;
        let workload = format!(
            "{{\"n_heads\": {}, \"n_kv_heads\": {}, \"head_dim\": {}, \"chunk_tokens\": {}}}",
            w.n_heads, w.n_kv_heads, w.head_dim, w.chunk_tokens
        );
        let cluster = cluster_to_json(&self.cluster);
        let backend = match &self.backend {
            BackendSpec::HostRef => "\"hostref\"",
            BackendSpec::Null => "\"null\"",
            BackendSpec::Pjrt(_) => "\"pjrt-unsupported\"",
        };
        let arrivals = match &self.arrivals {
            Arrivals::Poisson { rate } => format!("{{\"poisson\": {{\"rate\": {rate}}}}}"),
            Arrivals::Replay { times_s } => {
                let parts: Vec<String> = times_s.iter().map(|t| t.to_string()).collect();
                format!("{{\"replay\": {{\"times_s\": [{}]}}}}", parts.join(", "))
            }
        };
        format!(
            "{{\n  \"workload\": {workload},\n  \"n_workers\": {},\n  \"cluster\": {cluster},\n  \
             \"backend\": {backend},\n  \"arrivals\": {arrivals},\n  \"n_requests\": {},\n  \
             \"prompt_tokens\": {},\n  \"prompt_spread\": {},\n  \"decode_tokens\": {},\n  \
             \"max_batch_tokens\": {},\n  \"queue_cap\": {},\n  \"page_size\": {},\n  \
             \"n_pages\": {},\n  \"batching\": {},\n  \"threads\": {},\n  \
             \"autotune_tiles\": {},\n  \"seed\": {}\n}}\n",
            self.n_workers,
            self.n_requests,
            self.prompt_tokens,
            self.prompt_spread,
            self.decode_tokens,
            self.max_batch_tokens,
            self.queue_cap,
            self.page_size,
            self.n_pages,
            self.batching,
            self.threads,
            self.autotune_tiles,
            u64_to_json(self.seed),
        )
    }

    /// Parse a `repro serve --spec` document. Missing optional fields
    /// fall back to the [`ServeSpec::dev`] preset; the `cluster` field
    /// also accepts a preset name (`"1x8"`, `"2x8"`, `"dev"`).
    pub fn from_json(s: &str) -> Result<ServeSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("bad ServeSpec JSON: {e}"))?;
        let d = ServeSpec::dev();
        let workload = match j.get("workload") {
            None | Some(Json::Null) => d.workload.clone(),
            Some(w) => Workload {
                n_heads: w
                    .at("n_heads")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.n_heads must be an integer"))?,
                n_kv_heads: w
                    .at("n_kv_heads")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.n_kv_heads must be an integer"))?,
                head_dim: w
                    .at("head_dim")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.head_dim must be an integer"))?,
                chunk_tokens: w
                    .at("chunk_tokens")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.chunk_tokens must be an integer"))?,
            },
        };
        let backend = match j.get("backend") {
            None | Some(Json::Null) => BackendSpec::HostRef,
            Some(Json::Str(s)) => match s.as_str() {
                "hostref" | "host" => BackendSpec::HostRef,
                "null" => BackendSpec::Null,
                other => bail!("unknown serving backend {other:?} (hostref | null)"),
            },
            Some(_) => bail!("serving backend must be a string (hostref | null)"),
        };
        let arrivals = match j.get("arrivals") {
            None | Some(Json::Null) => d.arrivals.clone(),
            Some(a) => {
                if let Some(p) = a.get("poisson") {
                    Arrivals::Poisson {
                        rate: p
                            .at("rate")
                            .as_f64()
                            .ok_or_else(|| anyhow!("arrivals.poisson.rate must be a number"))?,
                    }
                } else if let Some(r) = a.get("replay") {
                    let arr = r.at("times_s").as_arr().ok_or_else(|| {
                        anyhow!("arrivals.replay.times_s must be an array of numbers")
                    })?;
                    let mut times_s = Vec::with_capacity(arr.len());
                    for (i, t) in arr.iter().enumerate() {
                        times_s.push(t.as_f64().ok_or_else(|| {
                            anyhow!("arrivals.replay.times_s[{i}] must be a number")
                        })?);
                    }
                    Arrivals::Replay { times_s }
                } else {
                    bail!("arrivals must be {{\"poisson\": ...}} or {{\"replay\": ...}}")
                }
            }
        };
        Ok(ServeSpec {
            workload,
            n_workers: opt_usize(&j, "n_workers", "", d.n_workers)?,
            cluster: cluster_from_json(j.get("cluster"), d.cluster.clone())?,
            backend,
            arrivals,
            n_requests: opt_usize(&j, "n_requests", "", d.n_requests)?,
            prompt_tokens: opt_usize(&j, "prompt_tokens", "", d.prompt_tokens)?,
            prompt_spread: opt_f64(&j, "prompt_spread", "", d.prompt_spread)?,
            decode_tokens: opt_usize(&j, "decode_tokens", "", d.decode_tokens)?,
            max_batch_tokens: opt_usize(&j, "max_batch_tokens", "", d.max_batch_tokens)?,
            queue_cap: opt_usize(&j, "queue_cap", "", d.queue_cap)?,
            page_size: opt_usize(&j, "page_size", "", d.page_size)?,
            n_pages: opt_usize(&j, "n_pages", "", d.n_pages)?,
            batching: opt_bool(&j, "batching", "", d.batching)?,
            threads: opt_usize(&j, "threads", "", d.threads)?,
            autotune_tiles: opt_bool(&j, "autotune_tiles", "", d.autotune_tiles)?,
            seed: u64_from_json(j.at("seed"), "seed")?.unwrap_or(d.seed),
        })
    }
}

/// The executed leg of a serving run (hostref backend only).
pub struct ServeExec {
    /// Measured score: tokens/sec over the span makespan; latency
    /// quantiles are completion times since run start (the replay does
    /// not sleep through arrival gaps).
    pub score: ServeScore,
    /// Rank-merged per-op timeline (threads + tiles recorded).
    pub trace: MergedTrace,
    /// Decode output values compared bit-for-bit against the one-shot
    /// full-prefill oracle.
    pub checked_values: usize,
    pub mismatched_values: usize,
    /// Event-engine makespan under the trace-calibrated cost.
    pub calibrated_total_s: f64,
    /// |measured − calibrated sim| / measured — the same self-consistency
    /// figure the training trace report renders.
    pub calibration_rel_err: f64,
}

/// Everything one [`serve`] call produces.
pub struct ServeOutcome {
    pub spec: ServeSpec,
    pub requests: Vec<Request>,
    /// The virtual-clock schedule (step log, per-request finish steps,
    /// queue stats).
    pub log: ServeLog,
    /// The lowered decode plan plus its op maps.
    pub lowered: Lowered,
    /// Event-engine score of the lowered plan (matches the virtual
    /// clock to ~1e-9 — the plan is lockstep with no transfers).
    pub sim: ServeScore,
    /// Executed + oracle-checked leg; `None` on the null backend.
    pub exec: Option<ServeExec>,
}

/// Run one serving workload end to end: generate arrivals, schedule,
/// lower, simulate, and (hostref) execute + oracle-check + calibrate.
pub fn serve(spec: &ServeSpec) -> Result<ServeOutcome> {
    spec.validate()?;
    let w = &spec.workload;
    let cost = attn_cost_from_dims(
        &spec.cluster,
        w.chunk_tokens as f64,
        w.n_heads,
        w.n_kv_heads,
        w.head_dim,
    );
    let requests = scheduler::gen_requests(spec);
    let log = scheduler::schedule(spec, &requests, &cost)?;
    let lowered = scheduler::lower(spec, requests.len(), &log);
    lowered.plan.validate()?;
    let sim = scheduler::simulate(spec, &requests, &lowered, &cost)?;
    let exec = if matches!(spec.backend, BackendSpec::HostRef) {
        let tiles = if spec.autotune_tiles { autotune() } else { Tiles::default() };
        let ex = scheduler::execute(spec, &requests, &log, &lowered, tiles)?;
        ensure!(
            ex.mismatched_values == 0,
            "decode outputs diverged from the full-prefill oracle on {} of {} values",
            ex.mismatched_values,
            ex.checked_values
        );
        // completion times relative to the first traced span
        let t0 = ex
            .trace
            .start_s
            .iter()
            .zip(&ex.trace.covered)
            .filter(|&(_, &c)| c)
            .map(|(s, _)| *s)
            .fold(f64::INFINITY, f64::min);
        let mut lats: Vec<f64> = requests.iter().map(|r| ex.finish_s[r.id] - t0).collect();
        lats.sort_by(f64::total_cmp);
        let tokens: usize = requests.iter().map(|r| r.decode).sum();
        let score = ServeScore {
            total_s: ex.total_s,
            tokens_per_s: if ex.total_s > 0.0 { tokens as f64 / ex.total_s } else { 0.0 },
            p50_latency_s: quantile(&lats, 0.5),
            p99_latency_s: quantile(&lats, 0.99),
        };
        let cmp = trace_report::compare(&lowered.plan, &ex.trace);
        Some(ServeExec {
            score,
            trace: ex.trace,
            checked_values: ex.checked_values,
            mismatched_values: ex.mismatched_values,
            calibrated_total_s: cmp.sim_total_s,
            calibration_rel_err: cmp.total_rel_err,
        })
    } else {
        None
    };
    Ok(ServeOutcome { spec: spec.clone(), requests, log, lowered, sim, exec })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_round_trips_exactly() {
        let specs = [
            ServeSpec::dev(),
            ServeSpec {
                arrivals: Arrivals::Replay {
                    times_s: vec![0.0, 0.25, 0.25, 1e-3 + 1.0, 2.5],
                },
                n_requests: 5,
                batching: false,
                backend: BackendSpec::Null,
                autotune_tiles: true,
                seed: (1u64 << 60) + 3,
                ..ServeSpec::dev()
            },
        ];
        for s in specs {
            let parsed = ServeSpec::from_json(&s.to_json()).unwrap();
            assert_eq!(parsed, s);
        }
    }

    #[test]
    fn empty_spec_is_the_dev_preset() {
        assert_eq!(ServeSpec::from_json("{}").unwrap(), ServeSpec::dev());
    }

    #[test]
    fn validate_rejects_wedgeable_specs() {
        // request that can never fit the per-rank pages
        let s = ServeSpec { n_pages: 1, ..ServeSpec::dev() };
        assert!(s.validate().is_err());
        // request that can never fit the token budget
        let s = ServeSpec { max_batch_tokens: 4, ..ServeSpec::dev() };
        assert!(s.validate().is_err());
        // bad arrival processes
        let s = ServeSpec { arrivals: Arrivals::Poisson { rate: 0.0 }, ..ServeSpec::dev() };
        assert!(s.validate().is_err());
        let s = ServeSpec {
            arrivals: Arrivals::Replay { times_s: vec![0.0, 1.0] },
            ..ServeSpec::dev()
        };
        assert!(s.validate().is_err()); // wrong length
        let s = ServeSpec {
            arrivals: Arrivals::Replay { times_s: vec![3.0; 12] },
            ..ServeSpec::dev()
        };
        assert!(s.validate().is_ok());
        let mut times = vec![3.0; 12];
        times[5] = 2.0;
        let s = ServeSpec { arrivals: Arrivals::Replay { times_s: times }, ..ServeSpec::dev() };
        assert!(s.validate().is_err()); // unsorted
        // GQA must divide
        let s = ServeSpec { workload: Workload::new(4, 3, 8, 12), ..ServeSpec::dev() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn serve_runs_end_to_end_on_the_null_backend() {
        let spec = ServeSpec { backend: BackendSpec::Null, ..ServeSpec::dev() };
        let out = serve(&spec).unwrap();
        assert!(out.exec.is_none());
        assert_eq!(out.requests.len(), spec.n_requests);
        assert!(out.sim.tokens_per_s > 0.0);
        assert!(out.sim.p99_latency_s >= out.sim.p50_latency_s);
    }
}
