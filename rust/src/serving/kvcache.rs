//! Paged KV-cache allocator — fixed-size pages over a flat slot slab.
//!
//! The serving analogue of vLLM/TGI block allocation: the cache owns
//! `n_pages` pages of `page_size` token slots each, hands pages out from
//! a LIFO free list, and keeps a per-request page table mapping token
//! positions to slots. Storage is two flat `f32` slabs (k and v) laid
//! out `[slot][kv_head][head_dim]` — exactly the addressing the
//! [`crate::runtime::kernel::decode`] kernel expects (`slots` input =
//! the page-table walk, gathered in position order).
//!
//! Everything here is deterministic: the free list is seeded in
//! descending page order so allocation hands out page 0 first, pops are
//! LIFO, and eviction returns a request's pages in reverse allocation
//! order — so the next allocation reuses the most recently freed page.
//! Two caches driven through the same call sequence produce identical
//! slot assignments (pinned by `rust/tests/serving_properties.rs`).
//!
//! Invariants (pinned by the property tests):
//! * **no aliasing** — live requests never share a slot;
//! * **conservation** — `free_pages() + used_pages() == n_pages` after
//!   every operation;
//! * **reuse** — pages freed by [`PagedKvCache::evict`] are handed out
//!   again before any never-used page.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

/// One request's resident KV state: the pages it owns, in allocation
/// order, and how many token positions are filled.
#[derive(Clone, Debug)]
pub struct PageTable {
    pub pages: Vec<usize>,
    pub len: usize,
}

/// Fixed-size-page slot allocator plus the flat k/v slabs it indexes.
#[derive(Clone, Debug)]
pub struct PagedKvCache {
    page_size: usize,
    n_pages: usize,
    kvh: usize,
    d: usize,
    /// LIFO free list (top = `last()`); seeded descending so the first
    /// pops hand out pages 0, 1, 2, ...
    free: Vec<usize>,
    /// Live request id → page table. `BTreeMap` keeps iteration (and
    /// therefore debugging output) deterministic.
    tables: BTreeMap<usize, PageTable>,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl PagedKvCache {
    pub fn new(page_size: usize, n_pages: usize, kvh: usize, d: usize) -> PagedKvCache {
        assert!(page_size >= 1 && n_pages >= 1 && kvh >= 1 && d >= 1);
        let slots = n_pages * page_size;
        PagedKvCache {
            page_size,
            n_pages,
            kvh,
            d,
            free: (0..n_pages).rev().collect(),
            tables: BTreeMap::new(),
            k: vec![0.0; slots * kvh * d],
            v: vec![0.0; slots * kvh * d],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    pub fn n_slots(&self) -> usize {
        self.n_pages * self.page_size
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.tables.values().map(|t| t.pages.len()).sum()
    }

    pub fn live_requests(&self) -> usize {
        self.tables.len()
    }

    /// Resident token count of a request (0 if absent).
    pub fn len(&self, req: usize) -> usize {
        self.tables.get(&req).map(|t| t.len).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.tables.is_empty()
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Would appending `tokens` more positions to `req` (which may not
    /// exist yet) succeed without exhausting the free list?
    pub fn fits(&self, req: usize, tokens: usize) -> bool {
        let (have_pages, have_len) = match self.tables.get(&req) {
            Some(t) => (t.pages.len(), t.len),
            None => (0, 0),
        };
        let need = self.pages_for(have_len + tokens);
        need <= have_pages + self.free.len()
    }

    /// Append `tokens` new positions to `req`, writing their kv rows.
    /// `k_rows`/`v_rows` are `tokens × kvh × d` values in position-major
    /// order — position `p`'s kv head `g` at `(p * kvh + g) * d`, the
    /// same layout the slab stores per slot. Allocates pages on demand;
    /// fails (without partial mutation) when the free list runs dry.
    pub fn append(&mut self, req: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        let row = self.kvh * self.d;
        ensure!(
            !k_rows.is_empty() && k_rows.len() == v_rows.len() && k_rows.len() % row == 0,
            "append(req {req}): k/v rows must be equal non-empty multiples of kvh*d = {row} \
             (got {} and {})",
            k_rows.len(),
            v_rows.len()
        );
        let tokens = k_rows.len() / row;
        if !self.fits(req, tokens) {
            bail!(
                "append(req {req}): {tokens} token(s) need more pages than the {} free \
                 (page_size {}, {} live requests)",
                self.free.len(),
                self.page_size,
                self.tables.len()
            );
        }
        let table = self
            .tables
            .entry(req)
            .or_insert_with(|| PageTable { pages: Vec::new(), len: 0 });
        for p in 0..tokens {
            let pos = table.len + p;
            let page_idx = pos / self.page_size;
            if page_idx == table.pages.len() {
                table.pages.push(self.free.pop().expect("fits() checked above"));
            }
            let slot = table.pages[page_idx] * self.page_size + pos % self.page_size;
            self.k[slot * row..(slot + 1) * row].copy_from_slice(&k_rows[p * row..(p + 1) * row]);
            self.v[slot * row..(slot + 1) * row].copy_from_slice(&v_rows[p * row..(p + 1) * row]);
        }
        table.len += tokens;
        Ok(())
    }

    /// Slot ids of a request's resident positions, in position order —
    /// the decode kernel's `slots` row.
    pub fn slots(&self, req: usize) -> Result<Vec<usize>> {
        let Some(t) = self.tables.get(&req) else {
            bail!("slots(req {req}): not resident");
        };
        Ok((0..t.len)
            .map(|pos| t.pages[pos / self.page_size] * self.page_size + pos % self.page_size)
            .collect())
    }

    /// Release a request's pages back to the free list (reverse
    /// allocation order, so the most recently allocated page is reused
    /// first). Returns how many pages were freed.
    pub fn evict(&mut self, req: usize) -> Result<usize> {
        let Some(t) = self.tables.remove(&req) else {
            bail!("evict(req {req}): not resident");
        };
        let n = t.pages.len();
        self.free.extend(t.pages.into_iter().rev());
        Ok(n)
    }

    /// The k slab, `[n_slots][kvh][d]` flattened.
    pub fn k_slab(&self) -> &[f32] {
        &self.k
    }

    /// The v slab, `[n_slots][kvh][d]` flattened.
    pub fn v_slab(&self) -> &[f32] {
        &self.v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_lookup_roundtrip_across_pages() {
        let (kvh, d) = (2, 4);
        let mut c = PagedKvCache::new(4, 8, kvh, d);
        // 6 tokens spans two pages
        let k: Vec<f32> = (0..6 * kvh * d).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..6 * kvh * d).map(|i| -(i as f32)).collect();
        c.append(7, &k, &v).unwrap();
        assert_eq!(c.len(7), 6);
        assert_eq!(c.used_pages(), 2);
        let slots = c.slots(7).unwrap();
        assert_eq!(slots.len(), 6);
        let row = kvh * d;
        for (pos, &s) in slots.iter().enumerate() {
            assert_eq!(c.k_slab()[s * row..(s + 1) * row], k[pos * row..(pos + 1) * row]);
            assert_eq!(c.v_slab()[s * row..(s + 1) * row], v[pos * row..(pos + 1) * row]);
        }
    }

    #[test]
    fn out_of_pages_is_an_error_and_mutates_nothing() {
        let mut c = PagedKvCache::new(2, 2, 1, 1);
        c.append(0, &[1.0; 3], &[1.0; 3]).unwrap(); // 2 pages
        assert_eq!(c.free_pages(), 0);
        assert!(!c.fits(1, 1));
        assert!(c.append(1, &[2.0], &[2.0]).is_err());
        assert_eq!(c.live_requests(), 1);
        assert_eq!(c.len(0), 3);
        // growing the resident request also fails: both its pages are full
        assert!(c.append(0, &[3.0; 2], &[3.0; 2]).is_err());
        // ...but the last slot of its second page is still appendable
        assert!(c.fits(0, 1));
        c.append(0, &[4.0], &[4.0]).unwrap();
        assert_eq!(c.len(0), 4);
    }

    #[test]
    fn evict_rejects_unknown_requests() {
        let mut c = PagedKvCache::new(2, 2, 1, 1);
        assert!(c.evict(3).is_err());
        c.append(3, &[1.0], &[1.0]).unwrap();
        assert_eq!(c.evict(3).unwrap(), 1);
        assert!(c.evict(3).is_err());
    }
}
