//! Continuous-batching decode scheduler: admit → filter → prefill →
//! decode, lowered onto the schedule IR.
//!
//! The loop has the TGI router's shape: arrivals land in a **bounded
//! waiting queue**; each step first **filters** finished requests out of
//! the running batch (evicting their pages), then **admits** waiting
//! requests under a token budget and page backpressure, **concatenating**
//! their prefills into the running decode batch; every resident request
//! then decodes one token. Admitted prefill waves are spread across
//! ranks by the *varlen rebalancer* ([`VarlenSpec::equal_split`]) — the
//! same equal-token splitter the training pipeline uses to balance
//! ragged documents balances prompt tokens here.
//!
//! The scheduler runs on a **virtual clock** priced by the same
//! [`Kernel::seconds`] cost classes the event engine charges; because
//! the lowered plan is lockstep with no transfers, the event engine's
//! makespan reproduces the scheduler's clock exactly (pinned at 1e-9 by
//! `rust/tests/serving_properties.rs`). [`lower`] turns the step log
//! into a [`Pass::Decode`] plan — per rank and step: `KvEvict`,
//! prefill `KvAppend` + `AttnTok`, decode `KvAppend` + `KvLookup` +
//! `DecodeAttn` — and [`execute`] replays that log with real host
//! kernels over per-rank [`PagedKvCache`]s, checking every decode row
//! bit-for-bit against a one-shot full-prefill oracle.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use super::kvcache::PagedKvCache;
use super::{Arrivals, ServeSpec};
use crate::coordinator::executor::{MergedTrace, RunTrace};
use crate::coordinator::plan::{Kernel, OpId, Pass, Plan, PlanOp};
use crate::coordinator::schedule::VarlenSpec;
use crate::coordinator::session::BackendSpec;
use crate::runtime::hostref::{HostKernels, Kernels};
use crate::runtime::kernel::Tiles;
use crate::runtime::tensor::{Tensor, Value};
use crate::simulator::{simulate_plan, AttnCost, EventOpts};
use crate::util::Rng;

/// One serving request: arrival time plus prompt/decode token counts.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: usize,
    pub arrival_s: f64,
    /// Prompt (prefill) tokens.
    pub prompt: usize,
    /// Tokens to generate, one per decode step.
    pub decode: usize,
}

/// Draw the arrival process and per-request prompt lengths from the
/// spec. Poisson arrivals use inverse-CDF exponential gaps; prompt
/// lengths are uniform on `[(1 - spread) * prompt_tokens, prompt_tokens]`.
/// Deterministic in `spec.seed`.
pub fn gen_requests(spec: &ServeSpec) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed ^ 0x5e7e_5e7e);
    let times: Vec<f64> = match &spec.arrivals {
        Arrivals::Poisson { rate } => {
            let mut t = 0.0f64;
            (0..spec.n_requests)
                .map(|_| {
                    let u = rng.f32() as f64;
                    t += -(1.0 - u).ln() / rate;
                    t
                })
                .collect()
        }
        Arrivals::Replay { times_s } => times_s.clone(),
    };
    times
        .into_iter()
        .enumerate()
        .map(|(id, arrival_s)| {
            let hi = spec.prompt_tokens;
            let lo = (((1.0 - spec.prompt_spread) * hi as f64).round() as usize).clamp(1, hi);
            let prompt = lo + rng.below(hi - lo + 1);
            Request { id, arrival_s, prompt, decode: spec.decode_tokens }
        })
        .collect()
}

/// One scheduler step: who evicts, prefills, and decodes on each rank,
/// plus the per-rank aggregates the cost classes are scaled by.
#[derive(Clone, Debug)]
pub struct StepLog {
    /// Virtual start time of the step.
    pub start_s: f64,
    /// Virtual duration: max over ranks of the rank's summed op seconds.
    pub dur_s: f64,
    /// Per rank: requests evicted at the top of the step (filter).
    pub evict: Vec<Vec<usize>>,
    /// Per rank: requests whose prompts prefill this step (admission).
    pub prefill: Vec<Vec<usize>>,
    /// Per rank: running requests decoding one token, in batch-row order.
    pub decode: Vec<Vec<usize>>,
    /// Per rank: Σ prompt tokens prefilled.
    pub prefill_tokens: Vec<usize>,
    /// Per rank: Σ causal pairs over prefilled prompts (`p(p+1)/2`).
    pub prefill_pairs: Vec<f64>,
    /// Per rank: Σ post-append context length over the decode batch.
    pub decode_ctx: Vec<usize>,
}

impl StepLog {
    fn empty(p: usize, start_s: f64) -> StepLog {
        StepLog {
            start_s,
            dur_s: 0.0,
            evict: vec![Vec::new(); p],
            prefill: vec![Vec::new(); p],
            decode: vec![Vec::new(); p],
            prefill_tokens: vec![0; p],
            prefill_pairs: vec![0.0; p],
            decode_ctx: vec![0; p],
        }
    }
}

/// What one rank does in one step, in emission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpRole {
    Evict,
    PrefillAppend,
    PrefillAttn,
    DecodeAppend,
    DecodeLookup,
    DecodeAttn,
}

/// The ops rank `w` runs in `step`, with their cost-class kernels — the
/// single definition shared by the virtual clock ([`schedule`]) and the
/// plan lowering ([`lower`]), so priced and lowered step times cannot
/// drift apart.
pub fn rank_ops(step: &StepLog, w: usize, c_ref: f64) -> Vec<(OpRole, Kernel)> {
    let mut ops = Vec::new();
    if !step.evict[w].is_empty() {
        ops.push((OpRole::Evict, Kernel::KvEvict));
    }
    if step.prefill_tokens[w] > 0 {
        ops.push((
            OpRole::PrefillAppend,
            Kernel::KvAppend { scale: step.prefill_tokens[w] as f64 / c_ref },
        ));
        ops.push((
            OpRole::PrefillAttn,
            Kernel::AttnTok { scale: step.prefill_pairs[w] / (c_ref * c_ref) },
        ));
    }
    let b = step.decode[w].len();
    if b > 0 {
        ops.push((OpRole::DecodeAppend, Kernel::KvAppend { scale: b as f64 / c_ref }));
        ops.push((
            OpRole::DecodeLookup,
            Kernel::KvLookup { scale: step.decode_ctx[w] as f64 / c_ref },
        ));
        ops.push((
            OpRole::DecodeAttn,
            Kernel::DecodeAttn { scale: step.decode_ctx[w] as f64 / (c_ref * c_ref) },
        ));
    }
    ops
}

/// The full schedule of one serving run on the virtual clock.
#[derive(Clone, Debug)]
pub struct ServeLog {
    pub n_workers: usize,
    pub steps: Vec<StepLog>,
    /// Rank each request ran on.
    pub home: Vec<usize>,
    /// Step index whose decode produced each request's last token.
    pub finish_step: Vec<usize>,
    /// Virtual makespan.
    pub total_s: f64,
    /// Largest waiting-queue occupancy observed.
    pub peak_queue: usize,
    /// Most arrivals simultaneously held out of the bounded queue.
    pub max_deferred: usize,
}

struct Run {
    req: usize,
    rank: usize,
    /// Tokens appended to the cache so far (prompt, then +1 per decode).
    ctx: usize,
    produced: usize,
    done: bool,
}

/// Run the continuous-batching loop (or, with `spec.batching == false`,
/// the serial no-batching baseline: at most one request in flight) on
/// the virtual clock. Requests must be arrival-sorted with ids `0..n`.
pub fn schedule(spec: &ServeSpec, requests: &[Request], cost: &AttnCost) -> Result<ServeLog> {
    let p = spec.n_workers;
    let n = requests.len();
    ensure!(n >= 1, "schedule: no requests");
    for (i, r) in requests.iter().enumerate() {
        ensure!(r.id == i, "schedule: request ids must be dense 0..n");
        ensure!(i == 0 || requests[i - 1].arrival_s <= r.arrival_s, "schedule: arrivals unsorted");
    }
    let c_ref = spec.workload.chunk_tokens as f64;
    let pages_for = |tokens: usize| tokens.div_ceil(spec.page_size);
    let final_ctx: Vec<usize> = requests.iter().map(|r| r.prompt + r.decode).collect();

    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut running: Vec<Run> = Vec::new();
    let mut rank_tokens = vec![0usize; p];
    let mut rank_free_pages = vec![spec.n_pages; p];
    let mut finished = 0usize;
    let mut now = 0.0f64;
    let mut steps: Vec<StepLog> = Vec::new();
    let mut home = vec![usize::MAX; n];
    let mut finish_step = vec![usize::MAX; n];
    let mut peak_queue = 0usize;
    let mut max_deferred = 0usize;

    // hard progress bound: every request costs one prefill step, `decode`
    // decode steps, one evict step, plus at most one idle jump
    let step_budget = n * (spec.decode_tokens + 3) + 8;
    let mut iters = 0usize;

    while finished < n {
        iters += 1;
        if iters > step_budget {
            bail!("scheduler stalled after {iters} iterations ({finished}/{n} finished)");
        }

        // ingest arrivals into the bounded queue
        while next_arrival < n
            && requests[next_arrival].arrival_s <= now + 1e-12
            && waiting.len() < spec.queue_cap
        {
            waiting.push_back(next_arrival);
            next_arrival += 1;
        }
        let mut due = 0usize;
        while next_arrival + due < n && requests[next_arrival + due].arrival_s <= now + 1e-12 {
            due += 1;
        }
        max_deferred = max_deferred.max(due);
        peak_queue = peak_queue.max(waiting.len());

        // idle: nothing resident, nothing admissible — jump to the next
        // arrival (one exists, else everything would have finished)
        if running.is_empty() && waiting.is_empty() {
            now = now.max(requests[next_arrival].arrival_s);
            continue;
        }

        let mut step = StepLog::empty(p, now);

        // filter: drop finished requests from the running batch, evict
        // their pages
        running.retain(|r| {
            if r.done {
                step.evict[r.rank].push(r.req);
                rank_tokens[r.rank] -= final_ctx[r.req];
                rank_free_pages[r.rank] += pages_for(final_ctx[r.req]);
                false
            } else {
                true
            }
        });
        let pre_existing = running.len();

        // admit: pull from the queue front under the token budget (whole
        // lifetime context is reserved up front) and page backpressure
        let mut batch_tokens: usize = running.iter().map(|r| final_ctx[r.req]).sum();
        let mut wave: Vec<usize> = Vec::new();
        while let Some(&rid) = waiting.front() {
            if !spec.batching && (!running.is_empty() || !wave.is_empty()) {
                break; // serial baseline: one request in flight, ever
            }
            if batch_tokens + final_ctx[rid] > spec.max_batch_tokens {
                break;
            }
            let need = pages_for(final_ctx[rid]);
            if !(0..p).any(|w| rank_free_pages[w] >= need) {
                break;
            }
            waiting.pop_front();
            wave.push(rid);
            batch_tokens += final_ctx[rid];
        }

        // place the wave: the varlen rebalancer cuts the wave's packed
        // prompt tokens into ≤ p equal-token groups; heaviest group goes
        // to the least-loaded rank (pages permitting)
        let mut pushed_back: Vec<usize> = Vec::new();
        if !wave.is_empty() {
            let prompts: Vec<usize> = wave.iter().map(|&r| requests[r].prompt).collect();
            let g = p.min(wave.len());
            let vs = VarlenSpec::equal_split(prompts.clone(), g);
            // assign each request to the balanced chunk holding its
            // token midpoint
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); g];
            let mut cum = 0usize;
            for (i, &plen) in prompts.iter().enumerate() {
                let mid = cum + plen / 2;
                let grp = (0..g).find(|&j| mid < vs.boundaries[j + 1]).unwrap_or(g - 1);
                groups[grp].push(wave[i]);
                cum += plen;
            }
            let weight = |grp: &Vec<usize>| -> f64 {
                grp.iter().map(|&r| requests[r].prompt as f64).sum()
            };
            let mut order: Vec<usize> = (0..g).filter(|&j| !groups[j].is_empty()).collect();
            order.sort_by(|&a, &b| {
                weight(&groups[b]).total_cmp(&weight(&groups[a])).then(a.cmp(&b))
            });
            for gi in order {
                // least-loaded rank for the whole group (deterministic
                // tie-break: lowest rank id)
                let target = (0..p).min_by_key(|&w| (rank_tokens[w], w)).unwrap();
                for &rid in &groups[gi] {
                    let need = pages_for(final_ctx[rid]);
                    let rank = if rank_free_pages[target] >= need {
                        Some(target)
                    } else {
                        // fall back per request: least-loaded rank with
                        // page room
                        (0..p)
                            .filter(|&w| rank_free_pages[w] >= need)
                            .min_by_key(|&w| (rank_tokens[w], w))
                    };
                    match rank {
                        Some(w) => {
                            home[rid] = w;
                            rank_tokens[w] += final_ctx[rid];
                            rank_free_pages[w] -= need;
                            step.prefill[w].push(rid);
                            let plen = requests[rid].prompt;
                            step.prefill_tokens[w] += plen;
                            step.prefill_pairs[w] += (plen * (plen + 1)) as f64 / 2.0;
                            running.push(Run {
                                req: rid,
                                rank: w,
                                ctx: plen,
                                produced: 0,
                                done: false,
                            });
                        }
                        None => pushed_back.push(rid),
                    }
                }
            }
            for &rid in pushed_back.iter().rev() {
                waiting.push_front(rid);
            }
        }

        // decode: every request resident before this step's admissions
        // generates one token (append its kv row, then attend over the
        // grown context)
        for r in running[..pre_existing].iter_mut() {
            r.ctx += 1;
            r.produced += 1;
            step.decode[r.rank].push(r.req);
            step.decode_ctx[r.rank] += r.ctx;
            if r.produced == requests[r.req].decode {
                r.done = true;
                finish_step[r.req] = steps.len();
                finished += 1;
            }
        }

        // price the step: lockstep barrier = max over ranks of summed
        // op seconds
        step.dur_s = (0..p)
            .map(|w| {
                rank_ops(&step, w, c_ref).iter().map(|(_, k)| k.seconds(cost)).sum::<f64>()
            })
            .fold(0.0, f64::max);
        now += step.dur_s;
        steps.push(step);
    }

    // trailing filter: the last finishers still hold pages
    if !running.is_empty() {
        let mut step = StepLog::empty(p, now);
        for r in &running {
            debug_assert!(r.done);
            step.evict[r.rank].push(r.req);
        }
        steps.push(step);
    }

    Ok(ServeLog {
        n_workers: p,
        steps,
        home,
        finish_step,
        total_s: now,
        peak_queue,
        max_deferred,
    })
}

/// Per-rank op ids of one lowered step, by role.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankOpIds {
    pub evict: Option<OpId>,
    pub prefill_append: Option<OpId>,
    pub prefill_attn: Option<OpId>,
    pub decode_append: Option<OpId>,
    pub decode_lookup: Option<OpId>,
    pub decode_attn: Option<OpId>,
}

/// A [`ServeLog`] lowered to the schedule IR, plus the op maps the
/// executor and the latency scoring need.
pub struct Lowered {
    pub plan: Plan,
    /// `step_ops[step][rank]` — op ids in emission order.
    pub step_ops: Vec<Vec<RankOpIds>>,
    /// Each request's final `DecodeAttn` op (its completion marker).
    pub last_decode_op: Vec<Option<OpId>>,
}

/// Lower the step log to a lockstep, transfer-free [`Pass::Decode`]
/// plan: per rank and step the [`rank_ops`] kernels, dependency-chained
/// per rank.
pub fn lower(spec: &ServeSpec, n_requests: usize, log: &ServeLog) -> Lowered {
    let p = log.n_workers;
    let c_ref = spec.workload.chunk_tokens as f64;
    let name = if spec.batching { "serve/continuous" } else { "serve/serial" };
    let mut plan = Plan::new(name, p, log.steps.len().max(1), true, false, Pass::Decode);
    let mut last_op: Vec<Option<OpId>> = vec![None; p];
    let mut last_decode_op: Vec<Option<OpId>> = vec![None; n_requests];
    let mut step_ops = Vec::with_capacity(log.steps.len());
    for (s, step) in log.steps.iter().enumerate() {
        let mut row = Vec::with_capacity(p);
        for w in 0..p {
            let mut ids = RankOpIds::default();
            for (role, kernel) in rank_ops(step, w, c_ref) {
                let deps: Vec<OpId> = last_op[w].iter().copied().collect();
                let id = plan.push(w, s, PlanOp::Compute { kernel, pair: None }, deps);
                last_op[w] = Some(id);
                match role {
                    OpRole::Evict => ids.evict = Some(id),
                    OpRole::PrefillAppend => ids.prefill_append = Some(id),
                    OpRole::PrefillAttn => ids.prefill_attn = Some(id),
                    OpRole::DecodeAppend => ids.decode_append = Some(id),
                    OpRole::DecodeLookup => ids.decode_lookup = Some(id),
                    OpRole::DecodeAttn => {
                        ids.decode_attn = Some(id);
                        // the last assignment a request sees is its
                        // finishing step's op
                        for &req in &step.decode[w] {
                            last_decode_op[req] = Some(id);
                        }
                    }
                }
            }
            row.push(ids);
        }
        step_ops.push(row);
    }
    Lowered { plan, step_ops, last_decode_op }
}

/// Throughput + latency summary of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct ServeScore {
    /// Makespan (virtual, simulated, or measured — per producer).
    pub total_s: f64,
    /// Generated (decode) tokens per second of makespan.
    pub tokens_per_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
}

/// Empirical upper quantile: the smallest latency ≥ a `q` fraction of
/// the sample (`sorted` ascending).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
    sorted[idx]
}

/// Score a run from absolute per-request finish times.
pub fn score(requests: &[Request], finish_s: &[f64], total_s: f64) -> ServeScore {
    let mut lats: Vec<f64> = requests.iter().map(|r| finish_s[r.id] - r.arrival_s).collect();
    lats.sort_by(f64::total_cmp);
    let tokens: usize = requests.iter().map(|r| r.decode).sum();
    ServeScore {
        total_s,
        tokens_per_s: if total_s > 0.0 { tokens as f64 / total_s } else { 0.0 },
        p50_latency_s: quantile(&lats, 0.5),
        p99_latency_s: quantile(&lats, 0.99),
    }
}

/// Event-engine score of a lowered plan: per-request completion is its
/// last `DecodeAttn` op's simulated finish.
pub fn simulate(
    spec: &ServeSpec,
    requests: &[Request],
    low: &Lowered,
    cost: &AttnCost,
) -> Result<ServeScore> {
    let res = simulate_plan(&low.plan, &spec.cluster, cost, &EventOpts::for_plan(&low.plan));
    let mut finish = vec![0.0f64; requests.len()];
    for r in requests {
        let op = low.last_decode_op[r.id]
            .ok_or_else(|| anyhow!("request {} never decoded", r.id))?;
        finish[r.id] = res.op_finish[op];
    }
    Ok(score(requests, &finish, res.total_s))
}

/// One executed serving run: the rank-merged timeline, measured score
/// inputs, and the oracle check tally.
pub struct Executed {
    pub trace: MergedTrace,
    /// Measured absolute finish time per request (its last `DecodeAttn`
    /// span end).
    pub finish_s: Vec<f64>,
    /// Span makespan (excludes the post-run oracle pass).
    pub total_s: f64,
    /// Decode output values compared / differing vs the one-shot
    /// full-prefill oracle (bitwise).
    pub checked_values: usize,
    pub mismatched_values: usize,
}

/// Per-request synthetic tensors, seeded by request id: the full
/// `prompt + decode` sequence in both kernel layouts, plus the decode
/// rows produced so far.
struct ReqData {
    l: usize,
    /// `[h][L][d]`
    q: Vec<f32>,
    /// `[kvh][L][d]` — oracle / prefill layout.
    k_full: Vec<f32>,
    v_full: Vec<f32>,
    /// `[L][kvh][d]` — cache append layout (same values).
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    /// One `[h][d]` row per generated token.
    decode_o: Vec<Vec<f32>>,
}

impl ReqData {
    fn generate(seed: u64, r: &Request, h: usize, kvh: usize, d: usize) -> ReqData {
        let l = r.prompt + r.decode;
        let mut rng =
            Rng::new(seed ^ (r.id as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let q = rng.normal_vec(h * l * d);
        let k_full = rng.normal_vec(kvh * l * d);
        let v_full = rng.normal_vec(kvh * l * d);
        let mut k_rows = vec![0.0f32; kvh * l * d];
        let mut v_rows = vec![0.0f32; kvh * l * d];
        for t in 0..l {
            for g in 0..kvh {
                let src = (g * l + t) * d;
                let dst = (t * kvh + g) * d;
                k_rows[dst..dst + d].copy_from_slice(&k_full[src..src + d]);
                v_rows[dst..dst + d].copy_from_slice(&v_full[src..src + d]);
            }
        }
        ReqData { l, q, k_full, v_full, k_rows, v_rows, decode_o: Vec::new() }
    }

    /// First `plen` positions in oracle layout, per tensor.
    fn prefix(&self, plen: usize, heads: usize, d: usize, src: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(heads * plen * d);
        for hh in 0..heads {
            out.extend_from_slice(&src[hh * self.l * d..hh * self.l * d + plen * d]);
        }
        out
    }
}

/// Replay the step log with real host kernels: per-rank threads over
/// per-rank paged caches, a step barrier mirroring the plan's lockstep
/// barrier, spans stamped per plan op. After the replay each rank
/// checks its decode rows bit-for-bit against `full_attn_ref` run once
/// over each request's full sequence (outside the timed spans).
pub fn execute(
    spec: &ServeSpec,
    requests: &[Request],
    log: &ServeLog,
    low: &Lowered,
    tiles: Tiles,
) -> Result<Executed> {
    ensure!(
        matches!(spec.backend, BackendSpec::HostRef),
        "serving executes on the hostref backend (got {:?})",
        spec.backend
    );
    let p = spec.n_workers;
    let eff_threads = spec
        .threads
        .clamp(1, std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(1));
    let barrier = Arc::new(Barrier::new(p));
    let epoch = Instant::now();

    struct RankOut {
        trace: RunTrace,
        checked: usize,
        mismatched: usize,
    }

    #[allow(clippy::too_many_arguments)]
    fn run_rank(
        rank: usize,
        spec: &ServeSpec,
        requests: &[Request],
        log: &ServeLog,
        low: &Lowered,
        tiles: Tiles,
        threads: usize,
        barrier: &Barrier,
        epoch: Instant,
    ) -> Result<RankOut> {
        let wl = &spec.workload;
        let (h, kvh, d) = (wl.n_heads, wl.n_kv_heads, wl.head_dim);
        let kernels = HostKernels::with_tiles(threads, tiles);
        let mut cache = PagedKvCache::new(spec.page_size, spec.n_pages, kvh, d);
        let mut data: BTreeMap<usize, ReqData> = BTreeMap::new();
        let mut ctx: BTreeMap<usize, usize> = BTreeMap::new();
        let mut trace = RunTrace::default();
        let now = |epoch: &Instant| epoch.elapsed().as_secs_f64();

        for (s, step) in log.steps.iter().enumerate() {
            barrier.wait();
            let ids = &low.step_ops[s][rank];
            if let Some(op) = ids.evict {
                let t0 = now(&epoch);
                for &req in &step.evict[rank] {
                    cache.evict(req)?;
                }
                trace.spans.push((op, t0, now(&epoch)));
            }
            if let Some(op) = ids.prefill_append {
                let t0 = now(&epoch);
                for &req in &step.prefill[rank] {
                    let rd = data.entry(req).or_insert_with(|| {
                        ReqData::generate(spec.seed, &requests[req], h, kvh, d)
                    });
                    let plen = requests[req].prompt;
                    cache.append(
                        req,
                        &rd.k_rows[..plen * kvh * d],
                        &rd.v_rows[..plen * kvh * d],
                    )?;
                    ctx.insert(req, plen);
                }
                trace.spans.push((op, t0, now(&epoch)));
            }
            if let Some(op) = ids.prefill_attn {
                let t0 = now(&epoch);
                for &req in &step.prefill[rank] {
                    let rd = &data[&req];
                    let plen = requests[req].prompt;
                    let q = Tensor::new(vec![h, plen, d], rd.prefix(plen, h, d, &rd.q));
                    let k = Tensor::new(vec![kvh, plen, d], rd.prefix(plen, kvh, d, &rd.k_full));
                    let v = Tensor::new(vec![kvh, plen, d], rd.prefix(plen, kvh, d, &rd.v_full));
                    kernels.run(
                        "full_attn_ref",
                        &[Value::F32(q), Value::F32(k), Value::F32(v)],
                    )?;
                }
                trace.spans.push((op, t0, now(&epoch)));
            }
            if let Some(op) = ids.decode_append {
                let t0 = now(&epoch);
                for &req in &step.decode[rank] {
                    let c = ctx
                        .get_mut(&req)
                        .ok_or_else(|| anyhow!("decode before prefill for request {req}"))?;
                    let t = *c;
                    let rd = &data[&req];
                    cache.append(
                        req,
                        &rd.k_rows[t * kvh * d..(t + 1) * kvh * d],
                        &rd.v_rows[t * kvh * d..(t + 1) * kvh * d],
                    )?;
                    *c += 1;
                }
                trace.spans.push((op, t0, now(&epoch)));
            }
            let mut gathered: Option<(Vec<f32>, Vec<f32>, usize)> = None;
            if let Some(op) = ids.decode_lookup {
                let t0 = now(&epoch);
                let b = step.decode[rank].len();
                let max_ctx =
                    step.decode[rank].iter().map(|r| ctx[r]).max().expect("b > 0");
                let mut slots_f = vec![0.0f32; b * max_ctx];
                let mut lens_f = vec![0.0f32; b];
                for (i, &req) in step.decode[rank].iter().enumerate() {
                    let sl = cache.slots(req)?;
                    for (j, &slot) in sl.iter().enumerate() {
                        slots_f[i * max_ctx + j] = slot as f32;
                    }
                    lens_f[i] = sl.len() as f32;
                }
                gathered = Some((slots_f, lens_f, max_ctx));
                trace.spans.push((op, t0, now(&epoch)));
            }
            if let Some(op) = ids.decode_attn {
                let t0 = now(&epoch);
                let (slots_f, lens_f, max_ctx) =
                    gathered.take().ok_or_else(|| anyhow!("decode_attn without lookup"))?;
                let b = step.decode[rank].len();
                let mut qb = vec![0.0f32; h * b * d];
                for (i, &req) in step.decode[rank].iter().enumerate() {
                    let t = ctx[&req] - 1;
                    let rd = &data[&req];
                    for hh in 0..h {
                        qb[(hh * b + i) * d..(hh * b + i + 1) * d]
                            .copy_from_slice(&rd.q[(hh * rd.l + t) * d..(hh * rd.l + t + 1) * d]);
                    }
                }
                let out = kernels.run(
                    "decode_attn",
                    &[
                        Value::F32(Tensor::new(vec![h, b, d], qb)),
                        Value::F32(Tensor::new(
                            vec![cache.n_slots(), kvh, d],
                            cache.k_slab().to_vec(),
                        )),
                        Value::F32(Tensor::new(
                            vec![cache.n_slots(), kvh, d],
                            cache.v_slab().to_vec(),
                        )),
                        Value::F32(Tensor::new(vec![b, max_ctx], slots_f)),
                        Value::F32(Tensor::new(vec![b], lens_f)),
                    ],
                )?;
                let o = out[0].data();
                for (i, &req) in step.decode[rank].iter().enumerate() {
                    let mut row = vec![0.0f32; h * d];
                    for hh in 0..h {
                        row[hh * d..(hh + 1) * d]
                            .copy_from_slice(&o[(hh * b + i) * d..(hh * b + i + 1) * d]);
                    }
                    data.get_mut(&req).expect("decoded request has data").decode_o.push(row);
                }
                trace.spans.push((op, t0, now(&epoch)));
            }
        }

        // oracle: one-shot full prefill over each request's whole
        // sequence; decode row g must equal oracle row prompt + g
        // bit-for-bit (untimed — after the replayed spans)
        let mut checked = 0usize;
        let mut mismatched = 0usize;
        for (&req, rd) in &data {
            let q = Tensor::new(vec![h, rd.l, d], rd.q.clone());
            let k = Tensor::new(vec![kvh, rd.l, d], rd.k_full.clone());
            let v = Tensor::new(vec![kvh, rd.l, d], rd.v_full.clone());
            let out =
                kernels.run("full_attn_ref", &[Value::F32(q), Value::F32(k), Value::F32(v)])?;
            let oracle = out[0].data();
            let plen = requests[req].prompt;
            ensure!(
                rd.decode_o.len() == requests[req].decode,
                "request {req} decoded {} of {} tokens",
                rd.decode_o.len(),
                requests[req].decode
            );
            for (g, row) in rd.decode_o.iter().enumerate() {
                let t = plen + g;
                for hh in 0..h {
                    for j in 0..d {
                        checked += 1;
                        if row[hh * d + j].to_bits() != oracle[(hh * rd.l + t) * d + j].to_bits()
                        {
                            mismatched += 1;
                        }
                    }
                }
            }
        }
        Ok(RankOut { trace, checked, mismatched })
    }

    let outs: Vec<Result<RankOut>> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let barrier = Arc::clone(&barrier);
                sc.spawn(move || {
                    run_rank(
                        rank, spec, requests, log, low, tiles, eff_threads, &barrier, epoch,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|hd| {
                hd.join().unwrap_or_else(|_| Err(anyhow!("serving rank thread panicked")))
            })
            .collect()
    });

    let mut traces = Vec::with_capacity(p);
    let mut checked = 0usize;
    let mut mismatched = 0usize;
    for out in outs {
        let o = out?;
        checked += o.checked;
        mismatched += o.mismatched;
        traces.push(o.trace);
    }
    let mut trace = MergedTrace::merge(&low.plan, &traces);
    trace.threads = eff_threads;
    trace.tiles = Some((tiles.q, tiles.k));
    let total_s = trace.makespan_s();
    let mut finish_s = vec![0.0f64; requests.len()];
    for r in requests {
        let op = low.last_decode_op[r.id]
            .ok_or_else(|| anyhow!("request {} never decoded", r.id))?;
        ensure!(trace.covered[op], "request {}'s final decode op has no span", r.id);
        finish_s[r.id] = trace.end_s[op];
    }
    Ok(Executed {
        trace,
        finish_s,
        total_s,
        checked_values: checked,
        mismatched_values: mismatched,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::attn_cost_from_dims;
    use crate::config::ClusterSpec;

    fn dev_spec() -> ServeSpec {
        ServeSpec::dev()
    }

    fn dev_cost(spec: &ServeSpec) -> AttnCost {
        let w = &spec.workload;
        attn_cost_from_dims(
            &spec.cluster,
            w.chunk_tokens as f64,
            w.n_heads,
            w.n_kv_heads,
            w.head_dim,
        )
    }

    #[test]
    fn schedule_serves_every_request_exactly_once() {
        let spec = dev_spec();
        let requests = gen_requests(&spec);
        let log = schedule(&spec, &requests, &dev_cost(&spec)).unwrap();
        let mut prefills = vec![0usize; requests.len()];
        let mut decoded = vec![0usize; requests.len()];
        let mut evicted = vec![0usize; requests.len()];
        for step in &log.steps {
            for w in 0..log.n_workers {
                for &r in &step.prefill[w] {
                    prefills[r] += 1;
                    assert_eq!(log.home[r], w);
                }
                for &r in &step.decode[w] {
                    decoded[r] += 1;
                    assert_eq!(log.home[r], w);
                }
                for &r in &step.evict[w] {
                    evicted[r] += 1;
                }
            }
        }
        for r in &requests {
            assert_eq!(prefills[r.id], 1, "request {} prefilled once", r.id);
            assert_eq!(decoded[r.id], r.decode, "request {} decoded fully", r.id);
            assert_eq!(evicted[r.id], 1, "request {} evicted once", r.id);
            assert!(log.finish_step[r.id] < log.steps.len());
        }
    }

    #[test]
    fn event_engine_reproduces_the_virtual_clock() {
        for batching in [true, false] {
            let spec = ServeSpec { batching, ..dev_spec() };
            let cost = dev_cost(&spec);
            let requests = gen_requests(&spec);
            let log = schedule(&spec, &requests, &cost).unwrap();
            let low = lower(&spec, requests.len(), &log);
            low.plan.validate().unwrap();
            let sim = simulate(&spec, &requests, &low, &cost).unwrap();
            let rel = (sim.total_s - log.total_s).abs() / log.total_s.max(1e-30);
            assert!(
                rel < 1e-9,
                "lockstep sim {} vs virtual clock {} (batching={batching})",
                sim.total_s,
                log.total_s
            );
        }
    }

    #[test]
    fn continuous_batching_beats_serial_throughput() {
        let spec = dev_spec();
        let cost = dev_cost(&spec);
        let requests = gen_requests(&spec);
        let cont = schedule(&spec, &requests, &cost).unwrap();
        let serial_spec = ServeSpec { batching: false, ..dev_spec() };
        let serial = schedule(&serial_spec, &requests, &cost).unwrap();
        assert!(
            serial.total_s >= 2.0 * cont.total_s,
            "serial {} vs continuous {}",
            serial.total_s,
            cont.total_s
        );
    }

    #[test]
    fn serial_baseline_never_batches() {
        let spec = ServeSpec { batching: false, ..dev_spec() };
        let requests = gen_requests(&spec);
        let log = schedule(&spec, &requests, &dev_cost(&spec)).unwrap();
        for step in &log.steps {
            let in_flight: usize =
                (0..log.n_workers).map(|w| step.prefill[w].len() + step.decode[w].len()).sum();
            assert!(in_flight <= 1, "serial step ran {in_flight} requests");
        }
    }

    #[test]
    fn quantile_picks_the_ceil_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.5), 2.0);
        assert_eq!(quantile(&xs, 0.99), 4.0);
        assert_eq!(quantile(&xs[..1], 0.99), 1.0);
    }
}
