//! `repro` — DISTFLASHATTN reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   tables   [--id N]                       regenerate paper tables (default all)
//!   figures  [--id N]                       regenerate paper figures
//!   run      [--spec FILE.json]             spec-driven Session pipeline: plan ->
//!                                           optimize -> execute -> trace, from a
//!                                           serialized RunSpec (default: a host-
//!                                           kernel smoke spec)
//!   verify   [--config tiny] [--schedule S] distributed attention vs oracle
//!   train    [--config tiny] [--steps N] [--ckpt hf|remat] [--schedule S]
//!            [--lr F] [--seed N]            run the distributed trainer
//!            [--optimize [--cluster C]]     (with optimizer-derived plans)
//!            [--trace]                      (per-layer attention timelines)
//!            [--state-dir DIR]              persist survivable per-step state
//!                                           and resume from the last completed
//!                                           step found there
//!   simulate --model M --cluster C --seq N  one-off iteration estimate
//!   plans    [--p N] [--cluster C] [--seq N] executed schedule-IR timings
//!            [--model M]                    (event engine, prefetch sweep)
//!   optimize [--model M] [--cluster C] [--seq N] [--p N] [--schedule S]
//!            [--pass fwd|bwd|both] [--seed N] cost-model plan optimizer:
//!            placement + GQA role flipping + prefetch autotune
//!            [--varlen [--docs N] [--zipf A] [--pack-seed N]]
//!            token-level rebalancing of a Zipf-packed document batch
//!   bench    [--json] [--out FILE] [--varlen-out FILE] [--exec-out FILE]
//!            [--ckpt-out FILE] [--kernels-out FILE] [--faults-out FILE]
//!            [--recovery-out FILE] [--serve-out FILE]
//!            [--skip-exec]                  optimizer + varlen grids (driven
//!                                           through Session), the executor
//!                                           transport micro-bench, the
//!                                           checkpoint-strategy trade-off, the
//!                                           host-kernel micro-bench, the
//!                                           zero-fault overhead gate, the
//!                                           crash-recovery gate, and the
//!                                           continuous-batching serving gate;
//!                                           --json writes BENCH_optimizer.json,
//!                                           BENCH_varlen.json, BENCH_executor.json,
//!                                           BENCH_ckpt.json, BENCH_kernels.json,
//!                                           BENCH_faults.json, BENCH_recovery.json,
//!                                           BENCH_serve.json
//!   chaos    [--p N] [--chunk N] [--heads N] [--kv-heads N] [--dim N]
//!            [--schedule S] [--seed N] [--stall F] [--layers L] [--seeds N]
//!                                           seeded fault injection on the real
//!                                           host executor: per fault class
//!                                           (delay / drop / chaos / stall /
//!                                           crash), executed makespan
//!                                           degradation vs the event engine's
//!                                           prediction; the same crash driven
//!                                           to bit-identical completion by the
//!                                           recovery supervisor (respawn +
//!                                           elastic); --seeds N sweeps per-class
//!                                           worst-case detection latency and
//!                                           recovery overhead; plus the
//!                                           optimizer's best plan under a
//!                                           pinned straggler
//!   trace    [--p N] [--chunk N] [--heads N] [--kv-heads N] [--dim N]
//!            [--schedule S] [--depth N] [--seed N] [--layers L] [--threads T]
//!                                           run the real executor (host kernels)
//!                                           with per-op tracing and align the
//!                                           measured timeline against the event
//!                                           engine; --layers L stacks L calls and
//!                                           prints a per-layer timeline
//!   serve    [--spec FILE.json] [--serial] [--requests N] [--threads N]
//!            [--autotune-tiles] [--no-exec] [--seed N]
//!                                           continuous-batching decode serving on
//!                                           the schedule IR: Poisson / trace-replay
//!                                           arrivals through the TGI-shaped
//!                                           scheduler over per-rank paged KV-caches,
//!                                           lowered to a lockstep decode plan,
//!                                           event-engine scored (tokens/sec,
//!                                           p50/p99 latency) and hostref-executed
//!                                           with a bit-exact full-prefill oracle
//!                                           check (--serial = one request in
//!                                           flight; --no-exec = simulate only)
//!   inspect  [--config tiny]                print an artifact manifest
//!
//! Arg parsing is hand-rolled (offline environment, no clap). Every
//! executing subcommand is a thin `RunSpec` construction driven through
//! `coordinator::Session`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use distflash::baselines::distflash::DistFlashAttn;
use distflash::baselines::megatron::Megatron;
use distflash::baselines::ring_attention::RingAttention;
use distflash::baselines::rsa::RingSelfAttention;
use distflash::baselines::ulysses::Ulysses;
use distflash::baselines::SystemModel;
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{
    BackendSpec, CkptStrategy, CrashSpec, FaultSpec, OptimizeOpts, OptimizePolicy, Pass, Plan,
    RecoveryPolicy, RunSpec, Schedule, ScheduleKind, Session, VarlenSpec, Workload,
};
use distflash::report::{paper, trace};
use distflash::runtime::{HostKernels, Kernels, Runtime, Tensor, Value};
use distflash::serving::ServeSpec;
use distflash::simulator::{simulate_plan, AttnCost, EventOpts, PlanSim};
use distflash::train::{train, AdamConfig, TrainConfig};
use distflash::util::Rng;

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(raw: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < raw.len() {
            if let Some(name) = raw[i].strip_prefix("--") {
                let val = if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    i += 1;
                    raw[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            }
            i += 1;
        }
        Args { flags }
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn f32(&self, name: &str, default: f32) -> f32 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn artifact_dir(cfg: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(cfg)
}

fn schedule_kind(s: &str) -> ScheduleKind {
    match s {
        "ring" | "unbalanced" => ScheduleKind::Ring,
        _ => ScheduleKind::Balanced,
    }
}

fn cluster_by_name(s: &str) -> ClusterSpec {
    ClusterSpec::by_name(s).unwrap_or_else(|| {
        eprintln!("unknown cluster {s:?}, using 1x8");
        ClusterSpec::dgx_1x8()
    })
}

/// The shared model/cluster/shape argument block every cost-model
/// subcommand used to re-parse by hand: one `RunSpec` (Null backend — the
/// caller picks backend/policy) plus the resolved `PaperModel`.
fn spec_from_args(
    args: &Args,
    default_model: &str,
    default_cluster: &str,
    default_seq: usize,
) -> anyhow::Result<(PaperModel, RunSpec)> {
    let model = PaperModel::by_name(&args.get("model", default_model))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = cluster_by_name(&args.get("cluster", default_cluster));
    let p = args.usize("p", cluster.n_gpus());
    let seq = args.usize("seq", default_seq);
    let mut spec = RunSpec::plans_only(schedule_kind(&args.get("schedule", "balanced")), p);
    spec.workload = Some(Workload::new(
        model.n_heads,
        model.n_kv_heads,
        model.head_dim,
        seq,
    ));
    spec.cluster = cluster;
    spec.seed = args.usize("seed", 0) as u64;
    Ok((model, spec))
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let id = args.get("id", "all");
    let out = match id.as_str() {
        "1" => paper::table1(),
        "2" => paper::table2(),
        "3" => paper::table3(),
        "4" => paper::table4(),
        "5" => paper::table5(),
        "6" => paper::table6(),
        "ra" => paper::ring_attention_summary(),
        "exec" => paper::executed_schedules(),
        "opt" => paper::optimized_schedules(),
        "varlen" => paper::varlen_schedules(),
        "ckpt" => paper::ckpt_tradeoff(),
        _ => [
            paper::table1(),
            paper::table2(),
            paper::table3(),
            paper::table4(),
            paper::ring_attention_summary(),
            paper::executed_schedules(),
            paper::optimized_schedules(),
            paper::varlen_schedules(),
            paper::table5(),
            paper::ckpt_tradeoff(),
            paper::table6(),
        ]
        .join("\n"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let id = args.get("id", "all");
    let out = match id.as_str() {
        "1" => paper::fig1(),
        "2" => paper::fig2(),
        "4" => [paper::fig4_left(), paper::fig4_right()].join("\n"),
        "7" => paper::fig7(),
        _ => [
            paper::fig1(),
            paper::fig2(),
            paper::fig4_left(),
            paper::fig4_right(),
            paper::fig7(),
        ]
        .join("\n"),
    };
    println!("{out}");
    Ok(())
}

/// `repro run`: the whole Session pipeline from a serialized `RunSpec`
/// (plan -> optimize (per policy) -> execute -> trace/report). Without
/// `--spec` a host-kernel smoke spec runs, so the command works on a bare
/// checkout.
fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let spec = match args.flags.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            RunSpec::from_json(&text)?
        }
        None => {
            let mut spec = RunSpec::host(ScheduleKind::Balanced, 8, Workload::new(4, 2, 32, 64));
            spec.trace = true;
            spec
        }
    };
    let mut session = Session::new(spec)?;
    session.execute()?;
    print!("{}", session.report());
    if session.spec().trace {
        let tr = session.trace()?;
        println!(
            "{}",
            tr.render("Trace vs sim — measured executor timeline vs event engine")
        );
        if let Some(tl) = tr.layer_timeline("Per-layer timeline — stacked attention calls") {
            println!("{tl}");
        }
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let cfg = args.get("config", "tiny");
    let kind = schedule_kind(&args.get("schedule", "balanced"));
    let dir = artifact_dir(&cfg);
    let rt = Runtime::load(&dir)?;
    let mc = rt.manifest().config.clone();
    let (h, kvh, n, d, p) = (mc.n_heads, mc.n_kv_heads, mc.seq_len, mc.head_dim, mc.n_workers);
    println!(
        "verify: config={cfg} schedule={kind:?} P={p} N={n} heads={h}/{kvh} d={d}"
    );
    let mut rng = Rng::new(args.usize("seed", 0) as u64);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let oracle = rt.run(
        "full_attn_ref",
        &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
    )?;
    // fill the workload from the manifest already loaded above so the
    // session does not probe the runtime a second time
    let mut spec = RunSpec::pjrt(&dir, kind);
    spec.workload = Some(Workload::new(h, kvh, d, mc.chunk_len));
    spec.n_workers = p;
    let mut session = Session::new(spec)?;
    session.execute_with(&q, &k, &v, Some(&do_))?;
    let res = session.take_run().expect("execute stored a run").result;
    println!("  forward  max|Δo|   = {:.3e}", res.o.max_abs_diff(&oracle[0]));
    println!("  forward  max|Δlse| = {:.3e}", res.lse.max_abs_diff(&oracle[1]));
    let (dq, dk, dv) = res.grads.unwrap();
    println!(
        "  backward |dq|={:.4} |dk|={:.4} |dv|={:.4} (finite: {})",
        dq.l2_norm(),
        dk.l2_norm(),
        dv.l2_norm(),
        dq.data().iter().chain(dk.data()).chain(dv.data()).all(|x| x.is_finite())
    );
    println!("  comm bytes = {}", res.comm_bytes);
    println!("verify OK");
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg_name = args.get("config", "tiny");
    let seed = args.usize("seed", 42) as u64;
    let mut run = RunSpec::pjrt(
        &artifact_dir(&cfg_name),
        schedule_kind(&args.get("schedule", "balanced")),
    );
    if args.get("optimize", "false") == "true" {
        run.cluster = cluster_by_name(&args.get("cluster", "1x8"));
        run.optimize = OptimizePolicy::Schedule(OptimizeOpts { seed, ..Default::default() });
    }
    run.trace = args.get("trace", "false") == "true";
    let cfg = TrainConfig {
        run,
        ckpt: args
            .get("ckpt", "remat")
            .parse::<CkptStrategy>()
            .map_err(|e| anyhow::anyhow!("--ckpt: {e}"))?,
        steps: args.usize("steps", 30),
        adam: AdamConfig { lr: args.f32("lr", 3e-3), ..Default::default() },
        seed,
        log_every: args.usize("log-every", 1),
        state_dir: args.flags.get("state-dir").map(PathBuf::from),
    };
    println!(
        "train: config={cfg_name} schedule={:?} ckpt={} steps={}",
        cfg.run.schedule,
        cfg.ckpt.name(),
        cfg.steps
    );
    let report = train(&cfg)?;
    for log in &report.logs {
        if log.step % cfg.log_every == 0 || log.step + 1 == cfg.steps {
            println!(
                "  step {:>4}  loss {:.4}  |g| {:.3}  {:.2}s  comm {:.1}MB",
                log.step,
                log.loss,
                log.grad_norm,
                log.wall_s,
                log.comm_bytes as f64 / 1e6
            );
        }
    }
    if !report.layer_traces.is_empty() {
        let rows: Vec<_> = report
            .layer_traces
            .iter()
            .map(|lt| (format!("L{} {}", lt.layer, lt.pass), &lt.trace))
            .collect();
        println!(
            "{}",
            trace::layer_timeline(
                "Per-layer attention timeline — final training step (shared epoch)",
                &rows
            )
        );
    }
    println!(
        "done: {:.1}s total, {} kernel calls ({:.1}s in kernels, {:.0}% of wall)",
        report.total_s,
        report.kernel_calls,
        report.kernel_s,
        report.kernel_s / report.total_s * 100.0
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = PaperModel::by_name(&args.get("model", "llama-7b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = cluster_by_name(&args.get("cluster", "2x8"));
    let seq = args.usize("seq", 16384);
    let systems: Vec<Box<dyn SystemModel>> = vec![
        Box::new(DistFlashAttn::default()),
        Box::new(DistFlashAttn::unoptimized()),
        Box::new(Megatron::tp()),
        Box::new(Ulysses),
        Box::new(RingAttention),
        Box::new(RingSelfAttention),
    ];
    println!(
        "simulate: {} on {}x{} GPUs, seq/GPU={seq}",
        model.name, cluster.n_nodes, cluster.gpus_per_node
    );
    println!(
        "{:<44} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "system", "fwd(s)", "bwd(s)", "rec(s)", "comm(s)", "total(s)", "mem(GB)"
    );
    for sys in &systems {
        let it = sys.iteration(&model, &cluster, seq);
        println!(
            "{:<44} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>8.1}{}",
            sys.name(),
            it.fwd_compute_s,
            it.bwd_compute_s,
            it.recompute_s,
            it.exposed_comm_s,
            it.total_s(),
            it.peak_mem_bytes / 1e9,
            if it.fits(&cluster) { "" } else { "  OOM!" }
        );
    }
    Ok(())
}

fn cmd_plans(args: &Args) -> anyhow::Result<()> {
    let (model, spec) = spec_from_args(args, "llama-7b", "1x8", 8192)?;
    let (cluster, p) = (spec.cluster, spec.n_workers);
    let seq = spec.workload.as_ref().expect("spec_from_args sets a workload").chunk_tokens;
    let cost = distflash::baselines::attn_cost_fwd(&model, &cluster, seq as f64);
    println!(
        "executed schedule-IR plans: {} P={p} seq/GPU={seq} (event engine; fwd cost classes)",
        model.name
    );
    let plans: Vec<(&str, Plan)> = vec![
        ("balanced-fwd", Schedule::balanced(p).lower(Pass::Forward)),
        ("balanced-bwd", Schedule::balanced(p).lower(Pass::Backward)),
        ("ring-fwd", Schedule::ring(p).lower(Pass::Forward)),
        ("ring-attention", RingAttention::plan(p)),
        ("ulysses-a2a", Ulysses::attn_plan_p(&model, &cluster, seq, p)),
    ];
    println!(
        "{:<16} {:>7} {:>11} {:>11} {:>11} {:>10} {:>7}",
        "plan", "ops", "d0 (ms)", "d1 (ms)", "d4 (ms)", "comm(MB)", "idle%"
    );
    for (name, plan) in &plans {
        plan.validate()
            .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        let run = |d: usize| simulate_plan(plan, &cluster, &cost, &EventOpts { prefetch_depth: d });
        let r1 = run(1);
        println!(
            "{:<16} {:>7} {:>11.2} {:>11.2} {:>11.2} {:>10.1} {:>7.1}",
            name,
            plan.n_ops(),
            run(0).total_s * 1e3,
            r1.total_s * 1e3,
            run(4).total_s * 1e3,
            r1.comm_bytes / 1e6,
            r1.idle_fraction() * 100.0
        );
    }
    println!("(d<N> = prefetch depth N; d0 = no overlap)");
    Ok(())
}

fn cmd_optimize(args: &Args) -> anyhow::Result<()> {
    let (model, mut spec) = spec_from_args(args, "llama-gqa", "2x8", 2048)?;
    let (cluster, p) = (spec.cluster, spec.n_workers);
    let seq = spec.workload.as_ref().expect("spec_from_args sets a workload").chunk_tokens;
    if p > cluster.n_gpus() {
        eprintln!(
            "note: P={p} exceeds the cluster's {} GPUs; ranks beyond it are priced as if on \
             additional nodes of the same shape (virtual-node semantics)",
            cluster.n_gpus()
        );
    }
    let opts = OptimizeOpts { seed: spec.seed, ..Default::default() };
    let varlen = args.get("varlen", "false") == "true";
    if varlen {
        let n_docs = args.usize("docs", 64);
        let alpha = args.f32("zipf", 1.1) as f64;
        let pack_seed = args.usize("pack-seed", 17) as u64;
        let vspec = VarlenSpec::pack_zipf(n_docs, seq * p, alpha, pack_seed, p);
        println!(
            "optimize --varlen: {} {:?} P={p} on {}x{} GPUs, {n_docs} Zipf({alpha:.2}) docs, \
             {} tokens packed (pad-to-max would cost x{:.1} tokens/chunk)",
            model.name,
            spec.schedule,
            cluster.n_nodes,
            cluster.gpus_per_node,
            seq * p,
            vspec.pad_factor()
        );
        spec.varlen = Some(vspec);
        spec.optimize = OptimizePolicy::Varlen(opts);
    } else {
        println!(
            "optimize: {} {:?} P={p} on {}x{} GPUs, seq/GPU={seq} (seed {})",
            model.name, spec.schedule, cluster.n_nodes, cluster.gpus_per_node, spec.seed
        );
        spec.optimize = OptimizePolicy::Schedule(opts);
    }
    let mut session = Session::new(spec)?;
    session.optimize()?;
    let want = args.get("pass", "both");
    if want != "both" {
        println!(
            "(--pass {want} filters the table; the session optimizes both passes — one spec \
             yields one fwd/bwd plan pair)"
        );
    }
    let shown = session
        .audits()
        .iter()
        .filter(|a| want == "both" || a.pass.name() == want);
    if varlen {
        println!(
            "{:<5} {:>10} {:>11} {:>11} {:>8} {:>9} {:>7} {:>6} {:>6} {:>6}",
            "pass", "pad (ms)", "equal (ms)", "rebal (ms)", "vs pad", "vs equal", "depth*",
            "flips", "cuts", "sims"
        );
        for a in shown {
            println!(
                "{:<5} {:>10.2} {:>11.2} {:>11.2} {:>7.2}x {:>8.2}x {:>7} {:>6} {:>6} {:>6}{}",
                a.pass.name(),
                a.pad_s * 1e3,
                a.equal_s * 1e3,
                a.optimized_s * 1e3,
                if a.optimized_s > 0.0 { a.pad_s / a.optimized_s } else { 1.0 },
                if a.optimized_s > 0.0 { a.equal_s / a.optimized_s } else { 1.0 },
                a.prefetch_depth,
                a.flipped_pairs,
                a.moved_boundaries,
                a.sim_calls,
                if a.accepted { "" } else { "  (candidate rejected — prior plan kept)" }
            );
        }
        println!(
            "(pad = pad-to-max equal chunks; equal = equal-token varlen; rebal = token-level \
             rebalancer; boundaries rebalanced on fwd and shared with bwd — one sharding \
             feeds both passes)"
        );
    } else {
        println!(
            "{:<5} {:>13} {:>15} {:>8} {:>7} {:>6} {:>6} {:>6}",
            "pass", "default (ms)", "optimized (ms)", "speedup", "depth*", "flips", "moves", "sims"
        );
        for a in shown {
            println!(
                "{:<5} {:>13.2} {:>15.2} {:>7.2}x {:>7} {:>6} {:>6} {:>6}{}",
                a.pass.name(),
                a.default_s * 1e3,
                a.optimized_s * 1e3,
                if a.optimized_s > 0.0 { a.default_s / a.optimized_s } else { 1.0 },
                a.prefetch_depth,
                a.flipped_steps.len(),
                a.moved_ranks,
                a.sim_calls,
                if a.accepted { "" } else { "  (candidate rejected — prior plan kept)" }
            );
            if a.accepted && !a.flipped_steps.is_empty() {
                println!(
                    "      flipped steps: {:?} (helper pairs computed owner-side)",
                    a.flipped_steps
                );
            }
        }
        let (fwd, _) = session.plans()?;
        if fwd.placement.iter().enumerate().any(|(i, &g)| i != g) {
            println!("      placement: {:?}", fwd.placement);
        }
    }
    println!("(depth* = autotuned prefetch knee; default column is identity placement, no flips, depth 1)");
    Ok(())
}

/// `repro trace`: run the real threaded executor (pure-host reference
/// kernels, so it works on a bare checkout) with per-op tracing, then
/// align the measured timeline against the event engine's predictions
/// under a trace-calibrated cost model — the measured validation of the
/// simulator's per-op error (fwd and bwd). `--layers L` stacks L calls
/// and adds a per-layer timeline.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let p = args.usize("p", 8);
    let chunk = args.usize("chunk", 96);
    let h = args.usize("heads", 4);
    let kvh = args.usize("kv-heads", 2);
    let d = args.usize("dim", 32);
    let depth = args.usize("depth", 1);
    let layers = args.usize("layers", 1);
    let threads = args.usize("threads", 1);
    let kind = schedule_kind(&args.get("schedule", "balanced"));
    let n = p * chunk;
    println!(
        "trace: {kind:?} P={p} N={n} heads={h}/{kvh} d={d} depth={depth} layers={layers} \
         threads={threads} (host kernels)"
    );
    let mut spec = RunSpec::host(kind, p, Workload::new(h, kvh, d, chunk));
    spec.trace = true;
    spec.prefetch_depth = Some(depth);
    spec.layers = layers;
    spec.threads = threads;
    spec.seed = args.usize("seed", 0) as u64;

    let mut rng = Rng::new(spec.seed);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));

    // warm run (thread spawn + allocator) — one call regardless of
    // --layers — then the measured stacked run
    let mut warm_spec = spec.clone();
    warm_spec.layers = 1;
    Session::new(warm_spec)?.execute_with(&q, &k, &v, Some(&do_))?;
    let mut session = Session::new(spec)?;
    session.execute_with(&q, &k, &v, Some(&do_))?;

    // numerics sanity against the host oracle while we are here
    let oracle = HostKernels::default().run(
        "full_attn_ref",
        &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
    )?;
    {
        let res = session.result()?;
        println!(
            "  numerics: max|Δo| = {:.3e}  max|Δlse| = {:.3e}  (vs host full_attn_ref)",
            res.o.max_abs_diff(&oracle[0]),
            res.lse.max_abs_diff(&oracle[1])
        );
    }

    let tr = session.trace()?;
    println!(
        "{}",
        tr.render(&format!(
            "Trace vs sim — measured executor timeline vs event engine (P={p}, depth {depth})"
        ))
    );
    if let Some(tl) = tr.layer_timeline(&format!(
        "Per-layer timeline — {layers} stacked attention calls (shared epoch; last layer \
         feeds the calibration above)"
    )) {
        println!("{tl}");
    }
    println!(
        "(dur err = mean per-op |measured - calibrated| / calibrated; start skew = mean \
         |measured - predicted| start offset as a fraction of the measured makespan; total \
         err = makespan relative error. Cost model calibrated from the trace's per-class \
         means — the comparison isolates the *scheduling structure*.)"
    );
    Ok(())
}

/// `repro chaos`: seeded fault injection on the real threaded executor
/// (host kernels, bare checkout). One run per fault class — message delay,
/// message drop, both ("chaos"), a pinned straggler, and a mid-plan rank
/// crash — each compared against the event engine's predicted makespan.
/// Message-level classes are *predicted* free (at-least-once delivery plus
/// dedup is exactly-once, and retransmits hide under compute), so their
/// rows pin the outputs bit-identical instead; the stall class degrades
/// the sim via [`PlanSim::set_worker_slowdown`] and must degrade the
/// executed wall-clock in the same direction; the crash class must be
/// *detected* (structured error within the watchdog budget), not hung.
/// Ends with the degradation-aware planning query: the optimizer's best
/// plan when one rank is pinned `--stall` slow.
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    let p = args.usize("p", 4).max(2);
    let chunk = args.usize("chunk", 128);
    let h = args.usize("heads", 4);
    let kvh = args.usize("kv-heads", 2);
    let d = args.usize("dim", 16);
    let layers = args.usize("layers", 2);
    let seed = args.usize("seed", 7) as u64;
    let stall = (args.f32("stall", 1.5) as f64).max(1.0);
    let kind = schedule_kind(&args.get("schedule", "balanced"));
    let n = p * chunk;
    let straggler = p - 1;
    println!(
        "chaos: {kind:?} P={p} N={n} heads={h}/{kvh} d={d} layers={layers} seed={seed} \
         (host kernels; stall factor {stall:.2}x on rank {straggler})"
    );

    // event-engine predictions: a host-flavored cost model over the same
    // plans the runs execute (absolute scale is irrelevant — the table
    // reports degradation ratios)
    let (fwd, bwd) = Session::new(RunSpec::plans_only(kind, p))?.plans()?;
    let flops = (2 * h * chunk * chunk * d) as f64;
    let cost = AttnCost {
        pair_full_s: flops / 1e12,
        pair_diag_s: 0.6 * flops / 1e12,
        rescale_s: (h * chunk * d) as f64 / 1e12,
        kv_bytes: (2 * kvh * chunk * d * 4) as f64,
        q_bytes: (h * chunk * d * 4) as f64,
        result_bytes: ((h * chunk * d + 2 * h * chunk) * 4) as f64,
        overlap: true,
    };
    let cluster = ClusterSpec::dgx_1x8();
    let identity: Vec<usize> = (0..p).collect();
    let predict = |slow: &[(usize, f64)]| -> f64 {
        [&fwd, &bwd]
            .into_iter()
            .map(|plan| {
                let mut sim = PlanSim::new(plan, &cost);
                for &(w, f) in slow {
                    sim.set_worker_slowdown(w, f);
                }
                sim.total_s(&cluster, &identity, 1)
            })
            .sum()
    };

    let classes: Vec<(&str, Option<FaultSpec>)> = vec![
        ("none", None),
        (
            "delay",
            Some(FaultSpec { seed, delay_prob: 0.3, delay_sends: 3, ..FaultSpec::default() }),
        ),
        (
            "drop",
            Some(FaultSpec { seed, drop_prob: 0.25, max_retransmits: 3, ..FaultSpec::default() }),
        ),
        ("chaos", Some(FaultSpec::chaos(seed))),
        (
            "stall",
            Some(FaultSpec { seed, stalls: vec![(straggler, stall)], ..FaultSpec::default() }),
        ),
        (
            "crash",
            Some(FaultSpec {
                seed,
                crash: Some(CrashSpec {
                    rank: p / 2,
                    step: 2.min(p - 1),
                    pass: Pass::Forward,
                }),
                ..FaultSpec::default()
            }),
        ),
    ];

    let mut rng = Rng::new(seed);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let make_spec = |faults: Option<FaultSpec>| {
        let mut spec = RunSpec::host(kind, p, Workload::new(h, kvh, d, chunk));
        spec.layers = layers;
        spec.faults = faults;
        spec
    };
    // warm run (thread spawn + allocator) so the fault-free row is not
    // charged the process's first-touch costs
    Session::new(make_spec(None))?.execute_with(&q, &k, &v, Some(&do_))?;

    let sim_base = predict(&[]);
    let mut wall_base = 0.0f64;
    let mut o_base: Option<Tensor> = None;
    println!(
        "{:<7} {:>10} {:>7} {:>10} {:>7}  {}",
        "class", "sim (ms)", "sim x", "exec (ms)", "exec x", "outcome"
    );
    for (name, faults) in classes {
        let sim_s = match &faults {
            Some(f) if !f.stalls.is_empty() => predict(&f.stalls),
            _ => sim_base,
        };
        let mut session = Session::new(make_spec(faults.clone()))?;
        let t0 = std::time::Instant::now();
        let run = session.execute_with(&q, &k, &v, Some(&do_)).map(|_| ());
        let wall = t0.elapsed().as_secs_f64();
        let events = session.fault_events().len();
        let outcome = match run {
            Ok(()) => {
                let res = session.result()?;
                let bitwise = match &o_base {
                    None => {
                        wall_base = wall;
                        o_base = Some(res.o.clone());
                        "baseline".to_string()
                    }
                    Some(base) if res.o == *base => "outputs bit-identical".to_string(),
                    Some(base) => {
                        format!("OUTPUTS DIVERGED (max|d|={:.2e})", res.o.max_abs_diff(base))
                    }
                };
                format!("{bitwise}, {events} injected events")
            }
            Err(e) => {
                let root = session
                    .failure_report()
                    .and_then(|r| r.root_cause())
                    .map(|c| format!("{c}"))
                    .unwrap_or_else(|| format!("{e}"));
                format!("detected: {root} ({events} injected events)")
            }
        };
        let base = if wall_base > 0.0 { wall_base } else { wall };
        println!(
            "{:<7} {:>10.2} {:>6.2}x {:>10.2} {:>6.2}x  {}",
            name,
            sim_s * layers as f64 * 1e3,
            sim_s / sim_base,
            wall * 1e3,
            wall / base,
            outcome
        );
    }
    println!(
        "(sim = event-engine makespan x layers; message classes predict 1.00x by design — \
         exactly-once delivery hides under compute — and must keep outputs bit-identical; \
         the crash row must *fail fast* with a named root cause, never hang)"
    );

    // crash -> recover end to end: the same seeded crash, now driven to
    // completion by the recovery supervisor under both policies
    let crash_spec = FaultSpec {
        seed,
        crash: Some(CrashSpec { rank: p / 2, step: 2.min(p - 1), pass: Pass::Forward }),
        ..FaultSpec::default()
    };
    println!("supervised recovery (same crash, driven to completion):");
    for (pname, policy) in [
        ("respawn", RecoveryPolicy::respawn()),
        ("elastic", RecoveryPolicy::Elastic { min_workers: 2 }),
    ] {
        let mut spec = make_spec(Some(crash_spec.clone()));
        spec.recovery = policy;
        let mut session = Session::new(spec)?;
        let t0 = std::time::Instant::now();
        let run = session.execute_supervised_with(&q, &k, &v, Some(&do_)).map(|_| ());
        let wall = t0.elapsed().as_secs_f64();
        match run {
            Ok(()) => {
                let bitwise = match (&o_base, session.result()) {
                    (Some(base), Ok(res)) if res.o == *base => {
                        "outputs bit-identical to fault-free"
                    }
                    (Some(_), Ok(_)) => "OUTPUTS DIVERGED",
                    _ => "no fault-free baseline",
                };
                let summary = session
                    .recovery_report()
                    .map(|r| r.summary())
                    .unwrap_or_else(|| "no recovery report".to_string());
                println!(
                    "  {pname:<8} {:.2} ms ({:.2}x fault-free)  {bitwise}",
                    wall * 1e3,
                    wall / wall_base.max(1e-12)
                );
                println!("           {summary}");
            }
            Err(e) => println!("  {pname:<8} FAILED to recover: {e:#}"),
        }
    }

    // --seeds N: sweep every fault class across N seeds under the respawn
    // supervisor and report the per-class worst case
    let seeds = args.usize("seeds", 1).max(1);
    if seeds > 1 {
        let class_spec = |class: &str, s: u64| -> FaultSpec {
            match class {
                "delay" => {
                    FaultSpec { seed: s, delay_prob: 0.3, delay_sends: 3, ..FaultSpec::default() }
                }
                "drop" => FaultSpec {
                    seed: s,
                    drop_prob: 0.25,
                    max_retransmits: 3,
                    ..FaultSpec::default()
                },
                "chaos" => FaultSpec::chaos(s),
                "stall" => {
                    FaultSpec { seed: s, stalls: vec![(straggler, stall)], ..FaultSpec::default() }
                }
                _ => FaultSpec {
                    seed: s,
                    crash: Some(CrashSpec {
                        rank: p / 2,
                        step: 2.min(p - 1),
                        pass: Pass::Forward,
                    }),
                    ..FaultSpec::default()
                },
            }
        };
        println!("seed sweep x{seeds} (supervised, respawn policy; worst case per class):");
        println!(
            "{:<7} {:>11} {:>14} {:>9}  {}",
            "class", "worst (ms)", "detect (ms)", "overhead", "outcome"
        );
        for class in ["delay", "drop", "chaos", "stall", "crash"] {
            let mut worst_wall = 0.0f64;
            let mut worst_detect = 0.0f64;
            let mut recovered_all = true;
            let mut identical_all = true;
            for i in 0..seeds {
                let mut spec = make_spec(Some(class_spec(class, seed + i as u64)));
                spec.recovery = RecoveryPolicy::respawn();
                let mut session = Session::new(spec)?;
                let t0 = std::time::Instant::now();
                let run =
                    session.execute_supervised_with(&q, &k, &v, Some(&do_)).map(|_| ());
                worst_wall = worst_wall.max(t0.elapsed().as_secs_f64());
                if let Some(r) = session.recovery_report() {
                    worst_detect = worst_detect.max(r.detect_s);
                }
                match run {
                    Ok(()) => {
                        if let (Some(base), Ok(res)) = (&o_base, session.result()) {
                            if res.o != *base {
                                identical_all = false;
                            }
                        }
                    }
                    Err(_) => recovered_all = false,
                }
            }
            println!(
                "{:<7} {:>11.2} {:>14.2} {:>8.2}x  {}",
                class,
                worst_wall * 1e3,
                worst_detect * 1e3,
                worst_wall / wall_base.max(1e-12),
                match (recovered_all, identical_all) {
                    (true, true) => "all recovered, outputs bit-identical",
                    (true, false) => "all recovered, OUTPUTS DIVERGED",
                    _ => "RECOVERY FAILED for at least one seed",
                }
            );
        }
    }

    // degradation-aware planning: the optimizer queried for the best plan
    // under the pinned straggler
    let mut ospec = RunSpec::plans_only(kind, p);
    ospec.workload = Some(Workload::new(h, kvh, d, chunk));
    ospec.optimize = OptimizePolicy::Schedule(OptimizeOpts {
        seed,
        slowdowns: vec![(straggler, stall)],
        ..Default::default()
    });
    let mut osession = Session::new(ospec)?;
    osession.optimize()?;
    println!("degradation-aware planning (rank {straggler} pinned {stall:.2}x slow):");
    for a in osession.audits() {
        println!(
            "  {:<4} default {:.2} ms -> optimized {:.2} ms ({:.2}x) depth {} flips {} moves {}{}",
            a.pass.name(),
            a.default_s * 1e3,
            a.optimized_s * 1e3,
            if a.optimized_s > 0.0 { a.default_s / a.optimized_s } else { 1.0 },
            a.prefetch_depth,
            a.flipped_steps.len(),
            a.moved_ranks,
            if a.accepted { "" } else { "  (candidate rejected — prior plan kept)" }
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut spec = match args.flags.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading serve spec {path}: {e}"))?;
            ServeSpec::from_json(&text)?
        }
        None => ServeSpec::dev(),
    };
    if args.get("serial", "false") == "true" {
        spec.batching = false;
    }
    if args.get("autotune-tiles", "false") == "true" {
        spec.autotune_tiles = true;
    }
    if args.get("no-exec", "false") == "true" {
        spec.backend = BackendSpec::Null;
    }
    spec.n_requests = args.usize("requests", spec.n_requests);
    spec.threads = args.usize("threads", spec.threads);
    if let Some(seed) = args.flags.get("seed").and_then(|v| v.parse::<u64>().ok()) {
        spec.seed = seed;
    }
    if let distflash::serving::Arrivals::Replay { times_s } = &spec.arrivals {
        if times_s.len() != spec.n_requests {
            anyhow::bail!(
                "--requests {} conflicts with the spec's {} replay arrival times",
                spec.n_requests,
                times_s.len()
            );
        }
    }

    let out = distflash::serving::serve(&spec)?;
    let tokens: usize = out.requests.iter().map(|r| r.decode).sum();
    println!(
        "serve: {} requests ({} decode tokens) on {} ranks, {} {} steps, plan {}",
        out.requests.len(),
        tokens,
        spec.n_workers,
        out.log.steps.len(),
        if spec.batching { "continuous-batching" } else { "serial" },
        out.lowered.plan.name,
    );
    println!(
        "  queue: peak {} waiting (cap {}), {} arrival(s) deferred at the cap",
        out.log.peak_queue, spec.queue_cap, out.log.max_deferred
    );
    println!(
        "  sim : {:>9.1} tok/s   total {:>9.3} ms   p50 {:>8.3} ms   p99 {:>8.3} ms",
        out.sim.tokens_per_s,
        out.sim.total_s * 1e3,
        out.sim.p50_latency_s * 1e3,
        out.sim.p99_latency_s * 1e3,
    );
    match &out.exec {
        Some(ex) => {
            println!(
                "  exec: {:>9.1} tok/s   total {:>9.3} ms   p50 {:>8.3} ms   p99 {:>8.3} ms   \
                 ({} thread(s)/rank{})",
                ex.score.tokens_per_s,
                ex.score.total_s * 1e3,
                ex.score.p50_latency_s * 1e3,
                ex.score.p99_latency_s * 1e3,
                ex.trace.threads,
                match ex.trace.tiles {
                    Some((q, k)) => format!(", tiles {q}x{k}"),
                    None => String::new(),
                },
            );
            println!(
                "  oracle: {} decode values bit-identical to the one-shot full-prefill reference",
                ex.checked_values
            );
            println!(
                "  calibration: measured {:.3} ms vs re-simulated {:.3} ms ({:.1}% rel err)",
                ex.score.total_s * 1e3,
                ex.calibrated_total_s * 1e3,
                ex.calibration_rel_err * 100.0,
            );
        }
        None => println!("  exec: skipped (null backend)"),
    }
    Ok(())
}

use distflash::util::json::escape as json_escape;

/// Write one bench JSON document (`{"bench": ..., "schedule": "balanced",
/// "results": [...]}`); `rows` are pre-rendered JSON objects. One emitter
/// for all three bench grids so the envelope cannot drift.
fn write_bench_json(path: &str, bench: &str, rows: &[String]) -> anyhow::Result<()> {
    let mut buf = format!(
        "{{\n  \"bench\": \"{bench}\",\n  \"schedule\": \"balanced\",\n  \"results\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        buf.push_str("    ");
        buf.push_str(r);
        buf.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    buf.push_str("  ]\n}\n");
    std::fs::write(path, &buf)?;
    println!("wrote {} {bench} results to {path}", rows.len());
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let rows = paper::optimizer_rows();
    if args.get("json", "false") == "true" {
        let jrows: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"model\": \"{}\", \"cluster\": \"{}\", \"seq_per_gpu\": {}, \"pass\": \"{}\", \
                     \"default_s\": {:.9}, \"optimized_s\": {:.9}, \"speedup\": {:.4}, \
                     \"prefetch_depth\": {}, \"flipped_steps\": {}, \"moved_ranks\": {}, \
                     \"sim_calls\": {}, \"accepted\": {}}}",
                    json_escape(r.model),
                    json_escape(r.cluster),
                    r.seq_per_gpu,
                    json_escape(r.pass),
                    r.default_s,
                    r.optimized_s,
                    r.speedup(),
                    r.prefetch_depth,
                    r.flipped_steps,
                    r.moved_ranks,
                    r.sim_calls,
                    r.accepted,
                )
            })
            .collect();
        write_bench_json(&args.get("out", "BENCH_optimizer.json"), "optimizer", &jrows)?;

        // token-level rebalancer grid -> BENCH_varlen.json
        let jrows: Vec<String> = paper::varlen_rows()
            .iter()
            .map(|r| {
                format!(
                    "{{\"model\": \"{}\", \"cluster\": \"{}\", \"n_docs\": {}, \"zipf_alpha\": {:.2}, \
                     \"seq_per_gpu\": {}, \"pass\": \"{}\", \"pad_s\": {:.9}, \"equal_s\": {:.9}, \
                     \"optimized_s\": {:.9}, \"speedup_vs_pad\": {:.4}, \"speedup_vs_equal\": {:.4}, \
                     \"prefetch_depth\": {}, \"flipped_pairs\": {}, \"moved_boundaries\": {}, \
                     \"sim_calls\": {}, \"incremental_rescores\": {}, \"accepted\": {}}}",
                    json_escape(r.model),
                    json_escape(r.cluster),
                    r.n_docs,
                    r.zipf_alpha,
                    r.seq_per_gpu,
                    json_escape(r.pass),
                    r.pad_s,
                    r.equal_s,
                    r.optimized_s,
                    r.speedup_vs_pad(),
                    r.speedup_vs_equal(),
                    r.prefetch_depth,
                    r.flipped_pairs,
                    r.moved_boundaries,
                    r.sim_calls,
                    r.incremental_rescores,
                    r.accepted,
                )
            })
            .collect();
        write_bench_json(&args.get("varlen-out", "BENCH_varlen.json"), "varlen", &jrows)?;

        // executor transport micro-bench -> BENCH_executor.json
        if args.get("skip-exec", "false") != "true" {
            let erows = paper::executor_bench_rows();
            let jrows: Vec<String> = erows
                .iter()
                .map(|r| {
                    format!(
                        "{{\"preset\": \"{}\", \"p\": {}, \"heads\": {}, \"kv_heads\": {}, \
                         \"chunk\": {}, \"head_dim\": {}, \"baseline_s\": {:.9}, \
                         \"zero_copy_s\": {:.9}, \"speedup\": {:.4}}}",
                        json_escape(r.preset),
                        r.p,
                        r.heads,
                        r.kv_heads,
                        r.chunk,
                        r.head_dim,
                        r.baseline_s,
                        r.zero_copy_s,
                        r.speedup(),
                    )
                })
                .collect();
            write_bench_json(&args.get("exec-out", "BENCH_executor.json"), "executor", &jrows)?;
            println!("{}", paper::executor_bench_table(&erows));

            // zero-fault overhead gate -> BENCH_faults.json
            let frows = paper::fault_bench_rows();
            let jrows: Vec<String> = frows
                .iter()
                .map(|r| {
                    format!(
                        "{{\"preset\": \"{}\", \"p\": {}, \"heads\": {}, \"kv_heads\": {}, \
                         \"chunk\": {}, \"head_dim\": {}, \"baseline_s\": {:.9}, \
                         \"instrumented_s\": {:.9}, \"overhead\": {:.4}}}",
                        json_escape(r.preset),
                        r.p,
                        r.heads,
                        r.kv_heads,
                        r.chunk,
                        r.head_dim,
                        r.baseline_s,
                        r.instrumented_s,
                        r.overhead(),
                    )
                })
                .collect();
            write_bench_json(&args.get("faults-out", "BENCH_faults.json"), "faults", &jrows)?;
            println!("{}", paper::fault_bench_table(&frows));

            // crash-recovery gate -> BENCH_recovery.json
            let rrows = paper::recovery_bench_rows();
            let jrows: Vec<String> = rrows
                .iter()
                .map(|r| {
                    format!(
                        "{{\"preset\": \"{}\", \"p\": {}, \"heads\": {}, \"kv_heads\": {}, \
                         \"chunk\": {}, \"head_dim\": {}, \"layers\": {}, \"policy\": \"{}\", \
                         \"fault_free_s\": {:.9}, \"recovered_total_s\": {:.9}, \
                         \"time_to_recover_s\": {:.9}, \"detect_s\": {:.9}, \
                         \"replayed_ops\": {}, \"skipped_ops\": {}, \"resume_layer\": {}, \
                         \"overhead\": {:.4}, \"bit_identical\": {}}}",
                        json_escape(r.preset),
                        r.p,
                        r.heads,
                        r.kv_heads,
                        r.chunk,
                        r.head_dim,
                        r.layers,
                        json_escape(r.policy),
                        r.fault_free_s,
                        r.recovered_total_s,
                        r.time_to_recover_s,
                        r.detect_s,
                        r.replayed_ops,
                        r.skipped_ops,
                        r.resume_layer,
                        r.overhead(),
                        r.bit_identical,
                    )
                })
                .collect();
            write_bench_json(
                &args.get("recovery-out", "BENCH_recovery.json"),
                "recovery",
                &jrows,
            )?;
            println!("{}", paper::recovery_bench_table(&rrows));

            // continuous-batching serving gate -> BENCH_serve.json
            let srows = paper::serve_bench_rows();
            let jrows: Vec<String> = srows
                .iter()
                .map(|r| {
                    format!(
                        "{{\"mode\": \"{}\", \"p\": {}, \"requests\": {}, \"steps\": {}, \
                         \"sim_tokens_per_s\": {:.4}, \"sim_p99_s\": {:.9}, \
                         \"exec_tokens_per_s\": {:.4}, \"exec_total_s\": {:.9}, \
                         \"checked_values\": {}, \"calib_rel_err\": {:.6}}}",
                        json_escape(r.mode),
                        r.p,
                        r.requests,
                        r.steps,
                        r.sim_tokens_per_s,
                        r.sim_p99_s,
                        r.exec_tokens_per_s,
                        r.exec_total_s,
                        r.checked_values,
                        r.calib_rel_err,
                    )
                })
                .collect();
            write_bench_json(&args.get("serve-out", "BENCH_serve.json"), "serve", &jrows)?;
            println!("{}", paper::serve_bench_table(&srows));
        }

        // checkpoint strategy micro-bench -> BENCH_ckpt.json
        let crows = paper::ckpt_tradeoff_rows();
        let jrows: Vec<String> = crows
            .iter()
            .map(|r| {
                format!(
                    "{{\"strategy\": \"{}\", \"chosen\": {}, \"prefetch_depth\": {}, \
                     \"sim_bwd_s\": {:.9}, \"peak_bytes\": {:.1}, \"fits\": {}, \
                     \"exec_wall_s\": {:.9}}}",
                    json_escape(r.strategy),
                    r.chosen,
                    r.prefetch_depth,
                    r.sim_bwd_s,
                    r.peak_bytes,
                    r.fits,
                    r.exec_wall_s,
                )
            })
            .collect();
        write_bench_json(&args.get("ckpt-out", "BENCH_ckpt.json"), "ckpt", &jrows)?;

        // host-kernel micro-bench -> BENCH_kernels.json
        let krows = paper::kernel_bench_rows();
        let jrows: Vec<String> = krows
            .iter()
            .map(|r| {
                format!(
                    "{{\"kernel\": \"{}\", \"heads\": {}, \"kv_heads\": {}, \"chunk\": {}, \
                     \"head_dim\": {}, \"threads\": {}, \"scalar_s\": {:.9}, \"tiled_s\": {:.9}, \
                     \"tiled_mt_s\": {:.9}, \"speedup_tiled\": {:.4}, \"speedup_mt\": {:.4}}}",
                    json_escape(r.kernel),
                    r.heads,
                    r.kv_heads,
                    r.chunk,
                    r.head_dim,
                    r.threads,
                    r.scalar_s,
                    r.tiled_s,
                    r.tiled_mt_s,
                    r.speedup_tiled(),
                    r.speedup_mt(),
                )
            })
            .collect();
        write_bench_json(&args.get("kernels-out", "BENCH_kernels.json"), "kernels", &jrows)?;
        println!("{}", paper::kernel_bench_table(&krows));
    } else {
        println!("{}", paper::optimized_schedules());
        println!("{}", paper::varlen_schedules());
        if args.get("skip-exec", "false") != "true" {
            println!("{}", paper::executor_bench_table(&paper::executor_bench_rows()));
            println!("{}", paper::fault_bench_table(&paper::fault_bench_rows()));
            println!("{}", paper::recovery_bench_table(&paper::recovery_bench_rows()));
            println!("{}", paper::serve_bench_table(&paper::serve_bench_rows()));
        }
        println!("{}", paper::ckpt_tradeoff());
        println!("{}", paper::kernel_bench_table(&paper::kernel_bench_rows()));
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let cfg = args.get("config", "tiny");
    let rt = Runtime::load(&artifact_dir(&cfg))?;
    let m = rt.manifest();
    println!(
        "config {}: {} layers, d_model {}, heads {}/{}, chunk {} x {} workers, {} params",
        m.config.name,
        m.config.n_layers,
        m.config.d_model,
        m.config.n_heads,
        m.config.n_kv_heads,
        m.config.chunk_len,
        m.config.n_workers,
        m.config.n_params
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {:<22} {} inputs -> {} outputs  ({})",
            name,
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    Ok(())
}

fn help() {
    println!(
        "repro — DISTFLASHATTN reproduction\n\
         usage: repro <tables|figures|run|verify|train|simulate|plans|optimize|trace|bench|chaos|serve|inspect> [--flag value]...\n\
         `tables`, `run`, `simulate`, `plans`, `optimize`, `trace`, `bench`, `chaos`, and `serve` run on a bare checkout\n\
         (`run`/`trace`/`chaos`/`serve` and the executor micro-bench use the pure-host kernel backends);\n\
         `verify`/`train` need AOT artifacts (`make artifacts`) and a real PJRT `xla` crate.\n\
         `run --spec FILE.json` drives the whole Session pipeline from a serialized RunSpec.\n\
         `chaos` injects seeded faults (delay/drop/stall/crash) into the real executor,\n\
         compares executed vs event-engine-predicted makespan degradation per fault class,\n\
         and drives the crash to bit-identical completion via the recovery supervisor\n\
         (`--seeds N` sweeps worst-case detection latency and recovery overhead).\n\
         `serve [--spec FILE.json]` runs continuous-batching decode serving on the schedule IR\n\
         (Poisson/replay arrivals, paged KV-caches, bit-exact full-prefill oracle check;\n\
         `--serial` for the one-request baseline, `--no-exec` to stop after simulation)."
    );
}

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = raw.first().cloned() else {
        help();
        return ExitCode::SUCCESS;
    };
    let args = Args::parse(&raw[1..]);
    let result = match cmd.as_str() {
        "tables" => cmd_tables(&args),
        "figures" => cmd_figures(&args),
        "run" => cmd_run(&args),
        "verify" => cmd_verify(&args),
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "plans" => cmd_plans(&args),
        "optimize" => cmd_optimize(&args),
        "trace" => cmd_trace(&args),
        "bench" => cmd_bench(&args),
        "chaos" => cmd_chaos(&args),
        "serve" => cmd_serve(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}");
            help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
