//! Model and cluster presets for the analytic cost models.
//!
//! These mirror the paper's §4 experimental setup: LLaMA-7B and variants
//! (GQA, 33 irregular heads, fewer-heads 16H/8H/4H/2H) on one or two
//! A100 DGX boxes (NVLink intra-node, 100 Gbps InfiniBand inter-node) plus
//! the in-house 2×8 A100-40GB development cluster.
//!
//! All sizes in *elements* unless suffixed `_bytes`; times in seconds;
//! bandwidths in bytes/second. Training data type is bf16 (2 bytes), the
//! paper's setting.

/// Bytes per activation/weight element in the perf model (bf16).
pub const ELEM_BYTES: f64 = 2.0;

/// A transformer configuration as the cost models see it.
#[derive(Clone, Debug)]
pub struct PaperModel {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub head_dim: usize,
    pub vocab: usize,
}

impl PaperModel {
    pub fn llama_7b() -> Self {
        PaperModel {
            name: "LLaMA-7B",
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            d_ff: 11008,
            head_dim: 128,
            vocab: 32000,
        }
    }

    /// LLaMA-7B with 8 kv heads shared by groups of 4 queries (§4 GQA).
    pub fn llama_gqa() -> Self {
        PaperModel { name: "LLaMA-GQA", n_kv_heads: 8, ..Self::llama_7b() }
    }

    /// 33 attention heads (irregular, non-power-of-two; §4.2).
    pub fn llama_33h() -> Self {
        PaperModel {
            name: "LLaMA-33H",
            n_heads: 33,
            n_kv_heads: 33,
            d_model: 33 * 128,
            ..Self::llama_7b()
        }
    }

    /// Fewer-heads family (Liu et al. recipe, §4 model setup): heads ∈
    /// {16, 8, 4, 2}, hidden = heads·128, layers scaled to keep ~7B params,
    /// FFN width kept at 11008.
    pub fn llama_nh(heads: usize) -> Self {
        let (name, layers) = match heads {
            16 => ("LLaMA-16H", 64),
            8 => ("LLaMA-8H", 128),
            4 => ("LLaMA-4H", 256),
            2 => ("LLaMA-2H", 512),
            _ => panic!("llama_nh supports 2/4/8/16 heads"),
        };
        PaperModel {
            name,
            n_layers: layers,
            d_model: heads * 128,
            n_heads: heads,
            n_kv_heads: heads,
            d_ff: 11008,
            head_dim: 128,
            vocab: 32000,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama-7b" | "LLaMA-7B" => Some(Self::llama_7b()),
            "llama-gqa" | "LLaMA-GQA" => Some(Self::llama_gqa()),
            "llama-33h" | "LLaMA-33H" => Some(Self::llama_33h()),
            "llama-16h" | "LLaMA-16H" => Some(Self::llama_nh(16)),
            "llama-8h" | "LLaMA-8H" => Some(Self::llama_nh(8)),
            "llama-4h" | "LLaMA-4H" => Some(Self::llama_nh(4)),
            "llama-2h" | "LLaMA-2H" => Some(Self::llama_nh(2)),
            _ => None,
        }
    }

    /// Parameter count (RMSNorm + untied embeddings included).
    pub fn n_params(&self) -> f64 {
        let e = self.d_model as f64;
        let f = self.d_ff as f64;
        let kv = (self.n_kv_heads * self.head_dim) as f64;
        let per_layer = e + e * e + 2.0 * e * kv + e * e + e + 3.0 * e * f;
        self.n_layers as f64 * per_layer + e + 2.0 * self.vocab as f64 * e
    }

    /// Linear (non-attention) forward FLOPs for `tokens` tokens, per layer.
    pub fn layer_linear_flops(&self, tokens: f64) -> f64 {
        let e = self.d_model as f64;
        let f = self.d_ff as f64;
        let kv = (self.n_kv_heads * self.head_dim) as f64;
        // qkv + out proj + swiglu (w1, w3, w2)
        2.0 * tokens * (e * e + 2.0 * e * kv + e * e + 3.0 * e * f)
    }

    /// Attention score+value FLOPs between a q span and a kv span (per
    /// layer, all heads, both matmuls). `causal_half` halves it for the
    /// masked diagonal region.
    pub fn attn_pair_flops(&self, q_tokens: f64, kv_tokens: f64, causal_half: bool) -> f64 {
        let per = 4.0 * q_tokens * kv_tokens * (self.n_heads * self.head_dim) as f64;
        if causal_half {
            per / 2.0
        } else {
            per
        }
    }

    /// kv bytes for a token span (what DISTFLASHATTN ships between workers).
    pub fn kv_bytes(&self, tokens: f64) -> f64 {
        2.0 * tokens * (self.n_kv_heads * self.head_dim) as f64 * ELEM_BYTES
    }

    /// q (or attention-output) bytes for a token span.
    pub fn q_bytes(&self, tokens: f64) -> f64 {
        tokens * (self.n_heads * self.head_dim) as f64 * ELEM_BYTES
    }
}

/// One GPU's roofline for the analytic model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    /// Dense bf16 peak, FLOP/s.
    pub peak_flops: f64,
    /// Achievable fraction of peak for fused flash-attention kernels.
    pub mfu_attn: f64,
    /// Achievable fraction of peak for big GEMMs.
    pub mfu_gemm: f64,
    /// HBM capacity, bytes.
    pub mem_bytes: f64,
}

impl GpuSpec {
    pub fn a100_80g() -> Self {
        GpuSpec {
            peak_flops: 312e12,
            mfu_attn: 0.45, // FA2 reaches ~0.4-0.5 on A100
            mfu_gemm: 0.55,
            mem_bytes: 80e9,
        }
    }

    pub fn a100_40g() -> Self {
        GpuSpec { mem_bytes: 40e9, ..Self::a100_80g() }
    }
}

/// Cluster topology: `n_nodes` boxes of `gpus_per_node`, NVLink inside,
/// InfiniBand between.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    pub n_nodes: usize,
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
    /// NVLink per-GPU unidirectional bandwidth, B/s.
    pub intra_bw: f64,
    pub intra_lat: f64,
    /// Inter-node (per node pair) bandwidth, B/s.
    pub inter_bw: f64,
    pub inter_lat: f64,
}

impl ClusterSpec {
    /// One A100-80GB DGX box (§4 cluster setup 1).
    pub fn dgx_1x8() -> Self {
        ClusterSpec {
            n_nodes: 1,
            gpus_per_node: 8,
            gpu: GpuSpec::a100_80g(),
            intra_bw: 250e9, // NVLink3 effective unidirectional
            intra_lat: 5e-6,
            inter_bw: 12.5e9, // 100 Gbps IB
            inter_lat: 15e-6,
        }
    }

    /// Two DGX boxes over 100 Gbps InfiniBand (§4 default setup).
    pub fn dgx_2x8() -> Self {
        ClusterSpec { n_nodes: 2, ..Self::dgx_1x8() }
    }

    /// In-house 2×8 A100 40GB development cluster (§4 cluster setup 3).
    pub fn dev_2x8_40g() -> Self {
        ClusterSpec {
            gpu: GpuSpec::a100_40g(),
            inter_bw: 6e9, // "unstable inter-node bandwidth"
            ..Self::dgx_2x8()
        }
    }

    /// 16 GPU A100-40GB cluster used by Table 2 / Table 3.
    pub fn cluster_16x40g() -> Self {
        Self::dev_2x8_40g()
    }

    /// Preset lookup by the CLI / `RunSpec` JSON names.
    pub fn by_name(name: &str) -> Option<ClusterSpec> {
        match name {
            "1x8" => Some(Self::dgx_1x8()),
            "2x8" => Some(Self::dgx_2x8()),
            "16x40g" | "dev" | "2x8-dev" => Some(Self::cluster_16x40g()),
            _ => None,
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.gpus_per_node
    }

    /// (bandwidth, latency) of the path between two GPUs.
    pub fn link(&self, a: usize, b: usize) -> (f64, f64) {
        if self.node_of(a) == self.node_of(b) {
            (self.intra_bw, self.intra_lat)
        } else {
            (self.inter_bw, self.inter_lat)
        }
    }

    /// Bottleneck link of a ring over GPUs `0..g`: inter-node if the group
    /// spans nodes, NVLink otherwise. Single-flow (one NIC) — what a P2P
    /// chunk fetch sees.
    pub fn ring_bottleneck(&self, group: usize) -> (f64, f64) {
        if group > self.gpus_per_node {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        }
    }

    /// Effective bandwidth for *collectives* spanning nodes: NCCL stripes
    /// the data over one ring channel per NIC (8 per DGX), so the
    /// aggregate inter-node bandwidth is gpus_per_node × per-NIC bw.
    pub fn collective_bottleneck(&self, group: usize) -> (f64, f64) {
        if group > self.gpus_per_node {
            (
                self.inter_bw * self.gpus_per_node.min(8) as f64,
                self.inter_lat,
            )
        } else {
            (self.intra_bw, self.intra_lat)
        }
    }

    /// Compute time for `flops` at the given MFU.
    pub fn compute_time(&self, flops: f64, mfu: f64) -> f64 {
        flops / (self.gpu.peak_flops * mfu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_param_count() {
        let p = PaperModel::llama_7b().n_params();
        assert!((6.5e9..7.5e9).contains(&p), "got {p:e}");
    }

    #[test]
    fn nh_family_keeps_params_comparable() {
        let base = PaperModel::llama_nh(16).n_params();
        for h in [8, 4, 2] {
            let p = PaperModel::llama_nh(h).n_params();
            // FFN width fixed => params grow a bit as layers double, but
            // stay within ~2.5x of the 16H reference (paper: "comparable")
            assert!(p / base < 2.5 && p / base > 0.7, "{h}H: {p:e} vs {base:e}");
        }
    }

    #[test]
    fn head_dim_consistency() {
        for m in [
            PaperModel::llama_7b(),
            PaperModel::llama_33h(),
            PaperModel::llama_nh(4),
        ] {
            assert_eq!(m.n_heads * m.head_dim, m.d_model, "{}", m.name);
        }
    }

    #[test]
    fn gqa_reduces_kv_bytes_4x() {
        let mha = PaperModel::llama_7b();
        let gqa = PaperModel::llama_gqa();
        assert_eq!(mha.kv_bytes(1024.0) / gqa.kv_bytes(1024.0), 4.0);
        // but same attention FLOPs (kv replicated before matmul)
        assert_eq!(
            mha.attn_pair_flops(8.0, 8.0, false),
            gqa.attn_pair_flops(8.0, 8.0, false)
        );
    }

    #[test]
    fn cluster_links() {
        let c = ClusterSpec::dgx_2x8();
        assert_eq!(c.n_gpus(), 16);
        assert_eq!(c.link(0, 7).0, c.intra_bw);
        assert_eq!(c.link(0, 8).0, c.inter_bw);
        assert_eq!(c.ring_bottleneck(8).0, c.intra_bw);
        assert_eq!(c.ring_bottleneck(16).0, c.inter_bw);
    }
}
