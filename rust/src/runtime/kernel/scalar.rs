//! The original row-at-a-time host kernels, kept verbatim as the
//! correctness oracle for the tiled path (`HostKernels::scalar()`).
//!
//! One full-width score pass per q row with naive serial reductions — slow
//! on purpose: this is the code every earlier numeric pin was built on, so
//! `rust/tests/kernel_equivalence.rs` checks the [`super::tiled`] kernels
//! against it directly.

use anyhow::{ensure, Result};

use super::{dims3, f32t, gqa_group};
use crate::runtime::tensor::{Tensor, Value};

/// Streaming-softmax chunk forward: fold the `(q, k, v)` block into the
/// running `(o, m, l)` accumulators — the paper's `attn(·)` kernel.
/// `causal` marks the diagonal chunk pair (in-block lower-triangular mask).
#[allow(clippy::too_many_arguments)]
pub fn chunk_fwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o0: &Tensor,
    m0: &Tensor,
    l0: &Tensor,
    causal: bool,
) -> Result<Vec<Tensor>> {
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk) = dims3(name, k)?;
    ensure!(d == dk && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o0.shape == q.shape && m0.shape == [h, cq] && l0.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut o = o0.data().to_vec();
    let mut m = m0.data().to_vec();
    let mut l = l0.data().to_vec();
    let mut s_row = vec![0.0f32; ck];
    for hh in 0..h {
        let g = hh / group;
        for i in 0..cq {
            let qrow = &qd[(hh * cq + i) * d..(hh * cq + i + 1) * d];
            let jmax = if causal { i + 1 } else { ck };
            let mut smax = f32::NEG_INFINITY;
            for (j, s) in s_row.iter_mut().enumerate().take(jmax) {
                let krow = &kd[(g * ck + j) * d..(g * ck + j + 1) * d];
                let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                *s = dot * scale;
                if *s > smax {
                    smax = *s;
                }
            }
            let ri = hh * cq + i;
            let m_new = m[ri].max(smax);
            // exp(-inf - finite) is 0, but -inf - -inf is NaN: the initial
            // accumulator carries zero weight either way
            let alpha = if m[ri] == f32::NEG_INFINITY { 0.0 } else { (m[ri] - m_new).exp() };
            let orow = &mut o[ri * d..(ri + 1) * d];
            for x in orow.iter_mut() {
                *x *= alpha;
            }
            let mut lsum = 0.0f32;
            for (j, s) in s_row.iter().enumerate().take(jmax) {
                let p = (s - m_new).exp();
                lsum += p;
                let vrow = &vd[(g * ck + j) * d..(g * ck + j + 1) * d];
                for (x, vv) in orow.iter_mut().zip(vrow) {
                    *x += p * vv;
                }
            }
            l[ri] = l[ri] * alpha + lsum;
            m[ri] = m_new;
        }
    }
    Ok(vec![
        Tensor::new(q.shape.clone(), o),
        Tensor::new(vec![h, cq], m),
        Tensor::new(vec![h, cq], l),
    ])
}

/// The paper's `rescale(·)`: merge two partial `(o, m, l)` triples (the
/// helper's shipped partial into the owner's accumulator).
pub fn rescale(name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
    let o1 = f32t(name, inputs, 0)?;
    let m1 = f32t(name, inputs, 1)?;
    let l1 = f32t(name, inputs, 2)?;
    let o2 = f32t(name, inputs, 3)?;
    let m2 = f32t(name, inputs, 4)?;
    let l2 = f32t(name, inputs, 5)?;
    ensure!(o1.shape == o2.shape && m1.shape == m2.shape && l1.shape == l2.shape);
    let (h, c, d) = dims3(name, o1)?;
    ensure!(m1.shape == [h, c] && l1.shape == [h, c]);
    let mut o = vec![0.0f32; h * c * d];
    let mut m = vec![0.0f32; h * c];
    let mut l = vec![0.0f32; h * c];
    let (o1d, m1d, l1d) = (o1.data(), m1.data(), l1.data());
    let (o2d, m2d, l2d) = (o2.data(), m2.data(), l2.data());
    for ri in 0..h * c {
        let mx = m1d[ri].max(m2d[ri]);
        let a1 = if m1d[ri] == f32::NEG_INFINITY { 0.0 } else { (m1d[ri] - mx).exp() };
        let a2 = if m2d[ri] == f32::NEG_INFINITY { 0.0 } else { (m2d[ri] - mx).exp() };
        m[ri] = mx;
        l[ri] = l1d[ri] * a1 + l2d[ri] * a2;
        for t in 0..d {
            o[ri * d + t] = o1d[ri * d + t] * a1 + o2d[ri * d + t] * a2;
        }
    }
    Ok(vec![
        Tensor::new(o1.shape.clone(), o),
        Tensor::new(m1.shape.clone(), m),
        Tensor::new(l1.shape.clone(), l),
    ])
}

/// The paper's `last = True` epilogue: normalize and emit the logsumexp.
pub fn finalize(name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 3, "{name}: expected 3 inputs");
    let o = f32t(name, inputs, 0)?;
    let m = f32t(name, inputs, 1)?;
    let l = f32t(name, inputs, 2)?;
    let (h, c, d) = dims3(name, o)?;
    ensure!(m.shape == [h, c] && l.shape == [h, c]);
    let (od, md, ld) = (o.data(), m.data(), l.data());
    let mut out = vec![0.0f32; h * c * d];
    let mut lse = vec![0.0f32; h * c];
    for ri in 0..h * c {
        ensure!(ld[ri] > 0.0, "{name}: empty softmax row {ri}");
        let inv = 1.0 / ld[ri];
        for t in 0..d {
            out[ri * d + t] = od[ri * d + t] * inv;
        }
        lse[ri] = md[ri] + ld[ri].ln();
    }
    Ok(vec![Tensor::new(o.shape.clone(), out), Tensor::new(m.shape.clone(), lse)])
}

/// FA2-style chunk-pair backward from the saved `o`/`lse` — no forward
/// recompute (the §3.3 rematerialization-aware payoff). Returns
/// `(dq, dk, dv)`; dk/dv are grouped to the kv heads (GQA grads sum over
/// each query group).
#[allow(clippy::too_many_arguments)]
pub fn chunk_bwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    lse: &Tensor,
    do_: &Tensor,
    causal: bool,
) -> Result<Vec<Tensor>> {
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk_) = dims3(name, k)?;
    ensure!(d == dk_ && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o.shape == q.shape && do_.shape == q.shape && lse.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let (od, ld, dod) = (o.data(), lse.data(), do_.data());
    let mut dq = vec![0.0f32; h * cq * d];
    let mut dkv_k = vec![0.0f32; kvh * ck * d];
    let mut dkv_v = vec![0.0f32; kvh * ck * d];
    for hh in 0..h {
        let g = hh / group;
        for i in 0..cq {
            let ri = hh * cq + i;
            let qrow = &qd[ri * d..(ri + 1) * d];
            let orow = &od[ri * d..(ri + 1) * d];
            let dorow = &dod[ri * d..(ri + 1) * d];
            let delta: f32 = dorow.iter().zip(orow).map(|(a, b)| a * b).sum();
            let jmax = if causal { i + 1 } else { ck };
            for j in 0..jmax {
                let cj = g * ck + j;
                let krow = &kd[cj * d..(cj + 1) * d];
                let vrow = &vd[cj * d..(cj + 1) * d];
                let s: f32 =
                    qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                let p = (s - ld[ri]).exp();
                let dp: f32 = dorow.iter().zip(vrow).map(|(a, b)| a * b).sum();
                let ds = p * (dp - delta);
                let dqrow = &mut dq[ri * d..(ri + 1) * d];
                for (x, kk) in dqrow.iter_mut().zip(krow) {
                    *x += ds * scale * kk;
                }
                let dkrow = &mut dkv_k[cj * d..(cj + 1) * d];
                for (x, qq) in dkrow.iter_mut().zip(qrow) {
                    *x += ds * scale * qq;
                }
                let dvrow = &mut dkv_v[cj * d..(cj + 1) * d];
                for (x, dd) in dvrow.iter_mut().zip(dorow) {
                    *x += p * dd;
                }
            }
        }
    }
    Ok(vec![
        Tensor::new(q.shape.clone(), dq),
        Tensor::new(k.shape.clone(), dkv_k),
        Tensor::new(v.shape.clone(), dkv_v),
    ])
}

/// Monolithic causal attention over the whole sequence — the oracle the
/// distributed executor is checked against. Returns `(o, lse)`.
pub fn full_attn_ref(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
) -> Result<Vec<Tensor>> {
    let (h, n, _d) = dims3(name, q)?;
    let o0 = Tensor::zeros(&q.shape);
    let m0 = Tensor::full(&[h, n], f32::NEG_INFINITY);
    let l0 = Tensor::zeros(&[h, n]);
    let oml = chunk_fwd(name, q, k, v, &o0, &m0, &l0, true)?;
    finalize(
        name,
        &[
            Value::F32(oml[0].clone()),
            Value::F32(oml[1].clone()),
            Value::F32(oml[2].clone()),
        ],
    )
}
