//! Host flash-attention kernel implementations.
//!
//! Two interchangeable implementations of the same kernel contracts sit
//! behind [`crate::runtime::HostKernels`]:
//!
//! * [`scalar`] — the original row-at-a-time reference: one full-width
//!   score pass per q row, naive `zip().map().sum()` dot products. Kept
//!   verbatim as the correctness oracle (`HostKernels::scalar()`), so the
//!   fast path is always checked against the code every earlier pin was
//!   built on.
//! * [`tiled`] — the throughput path (`HostKernels::tiled(threads)`):
//!   cache-blocked q×kv tiles with a blocked online softmax, fixed-width
//!   accumulator arrays the compiler auto-vectorizes on stable Rust, and a
//!   scoped-thread worker pool over independent (head, q-tile) units.
//!   Tile geometry is a runtime value ([`tiled::Tiles`], default = the
//!   original compile-time pick) with an opt-in cached startup sweep
//!   ([`tiled::autotune`]).
//! * [`decode`] — the serving decode kernel: one query row per running
//!   request against its paged KV-cache, scalar oracle + tiled default,
//!   bit-identical per path to the matching `full_attn_ref` rows.
//!
//! The tiled kernels are deterministic *per thread count and across
//! thread counts*: every floating-point reduction (a q row's online
//! softmax over kv tiles, a kv column's gradient sum over query heads)
//! runs in a fixed order that does not depend on how units were
//! partitioned across workers. `threads=1` therefore reproduces
//! `threads=8` bit-for-bit, and a pinned thread count reproduces a traced
//! run exactly.

pub mod decode;
pub mod scalar;
pub mod tiled;

pub use tiled::{Tiles, MAX_TILE_K, MAX_TILE_Q};

use anyhow::{bail, ensure, Result};

use super::tensor::{Tensor, Value};

/// Fixed accumulator width for the vectorized inner loops. Eight f32
/// lanes map onto one AVX2 register (or two NEON/SSE registers) and, more
/// importantly, break the serial float-add dependency chain a naive
/// `sum()` reduction compiles to.
pub const LANES: usize = 8;

pub(crate) fn f32t<'a>(name: &str, inputs: &'a [Value], i: usize) -> Result<&'a Tensor> {
    match inputs.get(i) {
        Some(Value::F32(t)) => Ok(t),
        Some(Value::I32(_)) => bail!("{name}: input {i} must be f32"),
        None => bail!("{name}: missing input {i}"),
    }
}

pub(crate) fn dims3(name: &str, t: &Tensor) -> Result<(usize, usize, usize)> {
    ensure!(t.shape.len() == 3, "{name}: expected rank-3, got {:?}", t.shape);
    Ok((t.shape[0], t.shape[1], t.shape[2]))
}

/// q-head-group width for GQA: query head `h` reads kv head `h / group`.
pub(crate) fn gqa_group(name: &str, h: usize, kvh: usize) -> Result<usize> {
    ensure!(
        kvh >= 1 && h % kvh == 0,
        "{name}: {h} query heads not divisible by {kvh} kv heads"
    );
    Ok(h / kvh)
}

/// Dot product with [`LANES`] independent partial accumulators. A plain
/// `iter().zip().map().sum()` is a single serial chain of float adds the
/// compiler may not reorder; the fixed-width accumulator array vectorizes
/// and pipelines on stable Rust with no intrinsics.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let tail: f32 = ca
        .remainder()
        .iter()
        .zip(cb.remainder())
        .map(|(x, y)| x * y)
        .sum();
    for (xa, xb) in ca.zip(cb) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let head =
        ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    head + tail
}

/// `y += a * x`, stride-1 — independent elementwise ops, auto-vectorized.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy += a * xx;
    }
}

/// `y += x`, stride-1.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy += xx;
    }
}

/// `y *= a`, stride-1.
#[inline]
pub fn scale_row(y: &mut [f32], a: f32) {
    for v in y.iter_mut() {
        *v *= a;
    }
}

/// Contiguous unit ranges per worker, balanced by per-unit cost. Returns
/// at most `threads` non-empty ranges covering `0..costs.len()` in order —
/// contiguity is what lets callers hand each worker one `split_at_mut`
/// slice of the output instead of sharing it.
pub(crate) fn partition(costs: &[f64], threads: usize) -> Vec<std::ops::Range<usize>> {
    let n = costs.len();
    if n == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, n);
    let total: f64 = costs.iter().sum();
    let mut out = Vec::with_capacity(t);
    let mut start = 0usize;
    let mut acc = 0.0f64;
    for (i, c) in costs.iter().enumerate() {
        acc += c;
        let groups_left = t - out.len();
        let units_left = n - i - 1;
        if groups_left <= 1 || units_left == 0 {
            continue; // the final group takes everything through n
        }
        // close at the running fair share, or when every remaining unit
        // must open its own group to reach t
        if acc >= total * (out.len() + 1) as f64 / t as f64 || units_left < groups_left {
            out.push(start..i + 1);
            start = i + 1;
        }
    }
    out.push(start..n);
    out
}

/// Even row ranges for uniform-cost elementwise stages (rescale,
/// finalize): at most `threads` non-empty contiguous ranges over `0..n`.
pub(crate) fn even_ranges(n: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, n);
    (0..t).map(|g| g * n / t..(g + 1) * n / t).filter(|r| !r.is_empty()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_on_awkward_lengths() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 33] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 0.5 - (i as f32) * 0.125).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn partition_covers_in_order_and_respects_thread_cap() {
        for n in [1usize, 2, 5, 17] {
            for t in [1usize, 2, 3, 8, 64] {
                let costs: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
                let ranges = partition(&costs, t);
                assert!(ranges.len() <= t.min(n));
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, n);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                    assert!(!w[0].is_empty() && !w[1].is_empty());
                }
            }
        }
    }

    #[test]
    fn even_ranges_cover_everything() {
        for n in [1usize, 3, 10] {
            for t in [1usize, 2, 4, 16] {
                let rs = even_ranges(n, t);
                let covered: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(covered, n);
                assert!(rs.len() <= t.min(n));
            }
        }
    }
}
