//! Cache-blocked, vectorized, optionally multi-threaded host flash
//! kernels — the default `HostKernels` path.
//!
//! Three stacked optimizations over [`super::scalar`]:
//!
//! 1. **Tiling** — q rows × kv columns in `TILE_Q` × `TILE_K` blocks with
//!    a blocked online softmax (running `(o, m, l)` per q row, one
//!    max/rescale per kv tile instead of per full-width row pass), so a
//!    kv tile (`TILE_K · d` floats) is reused from cache across a whole q
//!    tile.
//! 2. **Vectorization** — every inner reduction runs through
//!    [`super::dot`]'s fixed-width accumulator array and every update
//!    through stride-1 [`super::axpy`]/[`super::scale_row`] loops, which
//!    stable Rust auto-vectorizes (no `std::simd`, no intrinsics).
//! 3. **Parallelism** — a `std::thread::scope` worker pool partitions
//!    independent (head, q-tile) units (forward) or heads (backward)
//!    into contiguous, cost-balanced groups; each worker owns a disjoint
//!    `split_at_mut` slice of the output, so the partition needs no
//!    locks and no unsafe.
//!
//! Determinism: each q row's kv reduction happens inside one unit in a
//! fixed tile order, and kv-head gradients are accumulated into
//! per-query-head partials that are reduced sequentially in head order
//! after the pool joins — so results are bit-identical for every thread
//! count. (They differ from [`super::scalar`] in rounding only: the
//! blocked softmax rescales per tile where the scalar path rescales once
//! per row.)

use std::mem;
use std::ops::Range;
use std::sync::OnceLock;
use std::thread;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::{add_assign, axpy, dims3, dot, even_ranges, f32t, gqa_group, partition, scale_row};
use crate::runtime::tensor::{Tensor, Value};

/// q rows per tile: one tile's running state (o rows + m + l) stays
/// cache-resident while a kv tile streams past it.
pub(crate) const TILE_Q: usize = 32;
/// kv columns per tile: `TILE_K · d` floats of k (and v) per tile — 32 KiB
/// at d=128, sized for L1/L2 reuse across the whole q tile.
pub(crate) const TILE_K: usize = 64;

/// Largest q-tile the fixed stack buffers can hold (the sweep's ceiling).
pub const MAX_TILE_Q: usize = 64;
/// Largest kv-tile the fixed stack buffers can hold (the sweep's ceiling).
pub const MAX_TILE_K: usize = 128;

/// Runtime-selected tile geometry for the blocked kernels. The default is
/// the original compile-time pick (`TILE_Q` × `TILE_K`), so runs that
/// never opt into the autotune sweep stay bit-identical to every earlier
/// pin. Different tile shapes are *not* bit-identical to each other (the
/// blocked softmax rescales at tile boundaries), which is why the sweep
/// is opt-in (`RunSpec::autotune_tiles`) and the effective pick is
/// recorded in the trace — but any fixed `Tiles` is still bit-identical
/// across thread counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiles {
    /// q rows per tile (1..=`MAX_TILE_Q`).
    pub q: usize,
    /// kv columns per tile (1..=`MAX_TILE_K`).
    pub k: usize,
}

impl Default for Tiles {
    fn default() -> Self {
        Tiles { q: TILE_Q, k: TILE_K }
    }
}

impl Tiles {
    /// Startup-sweep candidates, default geometry first (ties keep it).
    pub const CANDIDATES: [Tiles; 9] = [
        Tiles { q: 32, k: 64 },
        Tiles { q: 16, k: 32 },
        Tiles { q: 16, k: 64 },
        Tiles { q: 16, k: 128 },
        Tiles { q: 32, k: 32 },
        Tiles { q: 32, k: 128 },
        Tiles { q: 64, k: 32 },
        Tiles { q: 64, k: 64 },
        Tiles { q: 64, k: 128 },
    ];

    /// Clamp into the stack buffers' capacity — callers may deserialize
    /// arbitrary geometry, the kernels must never index past `MAX_TILE_*`.
    pub fn clamped(self) -> Tiles {
        Tiles { q: self.q.clamp(1, MAX_TILE_Q), k: self.k.clamp(1, MAX_TILE_K) }
    }
}

/// One-shot cached tile sweep: time the causal forward over
/// [`Tiles::CANDIDATES`] on a small synthetic workload at one thread and
/// keep the fastest. Cached per process (`OnceLock`), so the cost is paid
/// at first kernel use only — the ROADMAP's "per-machine cached choice".
pub fn autotune() -> Tiles {
    static TUNED: OnceLock<Tiles> = OnceLock::new();
    *TUNED.get_or_init(|| {
        let (h, kvh, n, d) = (4usize, 2usize, 192usize, 64usize);
        let mut rng = crate::util::Rng::new(0x7113);
        let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
        let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
        let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
        let o0 = Tensor::zeros(&q.shape);
        let m0 = Tensor::full(&[h, n], f32::NEG_INFINITY);
        let l0 = Tensor::zeros(&[h, n]);
        let mut best = Tiles::default();
        let mut best_s = f64::INFINITY;
        for &cand in Tiles::CANDIDATES.iter() {
            // best-of-3 so one scheduler hiccup cannot flip the pick; the
            // sweep needs a stable relative order, not absolute seconds
            let mut s = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                let _ = chunk_fwd("autotune", &q, &k, &v, &o0, &m0, &l0, true, 1, cand);
                s = s.min(t0.elapsed().as_secs_f64());
            }
            if s < best_s {
                best_s = s;
                best = cand;
            }
        }
        best
    })
}

/// Run one closure per task — inline when there is a single task, on a
/// scoped worker pool otherwise. Tasks own disjoint output slices, so the
/// pool needs no synchronization beyond the scope join.
pub(crate) fn run_tasks<T: Send, F: Fn(T) + Sync>(tasks: Vec<T>, f: F) {
    if tasks.len() <= 1 {
        for t in tasks {
            f(t);
        }
        return;
    }
    thread::scope(|s| {
        for t in tasks {
            let f = &f;
            s.spawn(move || f(t));
        }
    });
}

/// Split `(o, m, l)` into per-group contiguous row slices (`rows·d` floats
/// of `o`, `rows` of `m`/`l` per group).
fn split3<'a>(
    mut o: &'a mut [f32],
    mut m: &'a mut [f32],
    mut l: &'a mut [f32],
    row_counts: &[usize],
    d: usize,
) -> Vec<(&'a mut [f32], &'a mut [f32], &'a mut [f32])> {
    let mut out = Vec::with_capacity(row_counts.len());
    for &rows in row_counts {
        let (og, rest) = mem::take(&mut o).split_at_mut(rows * d);
        o = rest;
        let (mg, rest) = mem::take(&mut m).split_at_mut(rows);
        m = rest;
        let (lg, rest) = mem::take(&mut l).split_at_mut(rows);
        l = rest;
        out.push((og, mg, lg));
    }
    out
}

/// One (head, q-tile) unit of forward work: rows `i_lo..i_hi` of head
/// `hh`, a contiguous block of the `(o, m, l)` outputs.
struct FwdUnit {
    hh: usize,
    i_lo: usize,
    i_hi: usize,
}

#[allow(clippy::too_many_arguments)]
fn fwd_unit(
    u: &FwdUnit,
    qd: &[f32],
    kd: &[f32],
    vd: &[f32],
    group: usize,
    cq: usize,
    ck: usize,
    d: usize,
    causal: bool,
    scale: f32,
    tile_k: usize,
    o_u: &mut [f32],
    m_u: &mut [f32],
    l_u: &mut [f32],
) {
    let kbase = (u.hh / group) * ck;
    let jlim = if causal { u.i_hi } else { ck };
    let mut s_buf = [0.0f32; MAX_TILE_K];
    let mut j0 = 0usize;
    while j0 < jlim {
        let jt = (j0 + tile_k).min(jlim);
        for (r, i) in (u.i_lo..u.i_hi).enumerate() {
            let jmax = if causal { i + 1 } else { ck };
            if j0 >= jmax {
                continue;
            }
            let jhi = jt.min(jmax);
            let qrow = &qd[(u.hh * cq + i) * d..][..d];
            let mut smax = f32::NEG_INFINITY;
            for j in j0..jhi {
                let s = dot(qrow, &kd[(kbase + j) * d..][..d]) * scale;
                s_buf[j - j0] = s;
                if s > smax {
                    smax = s;
                }
            }
            let m_old = m_u[r];
            let m_new = m_old.max(smax);
            // exp(-inf - finite) is 0, but -inf - -inf is NaN: the initial
            // accumulator carries zero weight either way
            let alpha = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
            let orow = &mut o_u[r * d..(r + 1) * d];
            if alpha != 1.0 {
                scale_row(orow, alpha);
            }
            let mut lsum = 0.0f32;
            for j in j0..jhi {
                let p = (s_buf[j - j0] - m_new).exp();
                lsum += p;
                axpy(orow, p, &vd[(kbase + j) * d..][..d]);
            }
            l_u[r] = l_u[r] * alpha + lsum;
            m_u[r] = m_new;
        }
        j0 = jt;
    }
}

/// Tiled streaming-softmax chunk forward — the contract of
/// [`super::scalar::chunk_fwd`], blocked and parallel over (head, q-tile)
/// units.
#[allow(clippy::too_many_arguments)]
pub fn chunk_fwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o0: &Tensor,
    m0: &Tensor,
    l0: &Tensor,
    causal: bool,
    threads: usize,
    tiles: Tiles,
) -> Result<Vec<Tensor>> {
    let tiles = tiles.clamped();
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk) = dims3(name, k)?;
    ensure!(d == dk && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o0.shape == q.shape && m0.shape == [h, cq] && l0.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut o = o0.data().to_vec();
    let mut m = m0.data().to_vec();
    let mut l = l0.data().to_vec();

    let mut units = Vec::new();
    let mut costs = Vec::new();
    for hh in 0..h {
        let mut i_lo = 0usize;
        while i_lo < cq {
            let i_hi = (i_lo + tiles.q).min(cq);
            // score-element count: the causal lower triangle makes late
            // q tiles heavier, so the partition balances by work, not rows
            let cost: f64 = if causal {
                (i_lo..i_hi).map(|i| (i + 1) as f64).sum()
            } else {
                ((i_hi - i_lo) * ck) as f64
            };
            units.push(FwdUnit { hh, i_lo, i_hi });
            costs.push(cost);
            i_lo = i_hi;
        }
    }
    let groups = partition(&costs, threads);
    let row_counts: Vec<usize> = groups
        .iter()
        .map(|g| units[g.clone()].iter().map(|u| u.i_hi - u.i_lo).sum())
        .collect();
    let slices = split3(&mut o, &mut m, &mut l, &row_counts, d);
    let tasks: Vec<(&[FwdUnit], (&mut [f32], &mut [f32], &mut [f32]))> = groups
        .iter()
        .zip(slices)
        .map(|(g, s)| (&units[g.clone()], s))
        .collect();
    run_tasks(tasks, |(units, (o_g, m_g, l_g))| {
        let mut row0 = 0usize;
        for u in units {
            let rows = u.i_hi - u.i_lo;
            fwd_unit(
                u,
                qd,
                kd,
                vd,
                group,
                cq,
                ck,
                d,
                causal,
                scale,
                tiles.k,
                &mut o_g[row0 * d..(row0 + rows) * d],
                &mut m_g[row0..row0 + rows],
                &mut l_g[row0..row0 + rows],
            );
            row0 += rows;
        }
    });
    Ok(vec![
        Tensor::new(q.shape.clone(), o),
        Tensor::new(vec![h, cq], m),
        Tensor::new(vec![h, cq], l),
    ])
}

#[allow(clippy::too_many_arguments)]
fn bwd_head(
    hh: usize,
    qd: &[f32],
    kd: &[f32],
    vd: &[f32],
    od: &[f32],
    ld: &[f32],
    dod: &[f32],
    group: usize,
    cq: usize,
    ck: usize,
    d: usize,
    causal: bool,
    scale: f32,
    tiles: Tiles,
    dq_h: &mut [f32],
    pk_h: &mut [f32],
    pv_h: &mut [f32],
) {
    let kbase = (hh / group) * ck;
    let mut delta = [0.0f32; MAX_TILE_Q];
    let mut i0 = 0usize;
    while i0 < cq {
        let it = (i0 + tiles.q).min(cq);
        for (r, i) in (i0..it).enumerate() {
            let ri = hh * cq + i;
            delta[r] = dot(&dod[ri * d..][..d], &od[ri * d..][..d]);
        }
        let jlim = if causal { it } else { ck };
        let mut j0 = 0usize;
        while j0 < jlim {
            let jt = (j0 + tiles.k).min(jlim);
            for (r, i) in (i0..it).enumerate() {
                let jmax = if causal { i + 1 } else { ck };
                if j0 >= jmax {
                    continue;
                }
                let jhi = jt.min(jmax);
                let ri = hh * cq + i;
                let qrow = &qd[ri * d..][..d];
                let dorow = &dod[ri * d..][..d];
                let lse_i = ld[ri];
                for j in j0..jhi {
                    let krow = &kd[(kbase + j) * d..][..d];
                    let vrow = &vd[(kbase + j) * d..][..d];
                    let s = dot(qrow, krow) * scale;
                    let p = (s - lse_i).exp();
                    let dp = dot(dorow, vrow);
                    let ds = p * (dp - delta[r]);
                    let c = ds * scale;
                    axpy(&mut dq_h[i * d..(i + 1) * d], c, krow);
                    axpy(&mut pk_h[j * d..(j + 1) * d], c, qrow);
                    axpy(&mut pv_h[j * d..(j + 1) * d], p, dorow);
                }
            }
            j0 = jt;
        }
        i0 = it;
    }
}

/// Tiled FA2-style chunk-pair backward — the contract of
/// [`super::scalar::chunk_bwd`], parallel over query heads. Each head
/// accumulates its kv gradients into a private partial; the partials are
/// reduced sequentially in head order after the pool joins, so the GQA
/// group sum has one fixed floating-point order for every thread count.
#[allow(clippy::too_many_arguments)]
pub fn chunk_bwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    lse: &Tensor,
    do_: &Tensor,
    causal: bool,
    threads: usize,
    tiles: Tiles,
) -> Result<Vec<Tensor>> {
    let tiles = tiles.clamped();
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk_) = dims3(name, k)?;
    ensure!(d == dk_ && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o.shape == q.shape && do_.shape == q.shape && lse.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let (od, ld, dod) = (o.data(), lse.data(), do_.data());
    let mut dq = vec![0.0f32; h * cq * d];
    let mut dkv_k = vec![0.0f32; kvh * ck * d];
    let mut dkv_v = vec![0.0f32; kvh * ck * d];
    // per-query-head kv-grad partials (always, even single-threaded, so
    // the reduction order is one fixed thing rather than two code paths)
    let mut pk = vec![0.0f32; h * ck * d];
    let mut pv = vec![0.0f32; h * ck * d];

    let groups = partition(&vec![1.0; h], threads);
    let tasks: Vec<(Range<usize>, &mut [f32], &mut [f32], &mut [f32])> = {
        let (mut dq_r, mut pk_r, mut pv_r) = (&mut dq[..], &mut pk[..], &mut pv[..]);
        let mut out = Vec::with_capacity(groups.len());
        for g in &groups {
            let heads = g.len();
            let (dq_g, rest) = mem::take(&mut dq_r).split_at_mut(heads * cq * d);
            dq_r = rest;
            let (pk_g, rest) = mem::take(&mut pk_r).split_at_mut(heads * ck * d);
            pk_r = rest;
            let (pv_g, rest) = mem::take(&mut pv_r).split_at_mut(heads * ck * d);
            pv_r = rest;
            out.push((g.clone(), dq_g, pk_g, pv_g));
        }
        out
    };
    run_tasks(tasks, |(heads, dq_g, pk_g, pv_g)| {
        for (n, hh) in heads.clone().enumerate() {
            bwd_head(
                hh,
                qd,
                kd,
                vd,
                od,
                ld,
                dod,
                group,
                cq,
                ck,
                d,
                causal,
                scale,
                tiles,
                &mut dq_g[n * cq * d..(n + 1) * cq * d],
                &mut pk_g[n * ck * d..(n + 1) * ck * d],
                &mut pv_g[n * ck * d..(n + 1) * ck * d],
            );
        }
    });
    for hh in 0..h {
        let g = hh / group;
        add_assign(
            &mut dkv_k[g * ck * d..(g + 1) * ck * d],
            &pk[hh * ck * d..(hh + 1) * ck * d],
        );
        add_assign(
            &mut dkv_v[g * ck * d..(g + 1) * ck * d],
            &pv[hh * ck * d..(hh + 1) * ck * d],
        );
    }
    Ok(vec![
        Tensor::new(q.shape.clone(), dq),
        Tensor::new(k.shape.clone(), dkv_k),
        Tensor::new(v.shape.clone(), dkv_v),
    ])
}

/// Vectorized `rescale(·)` merge — the contract of
/// [`super::scalar::rescale`], parallel over contiguous row ranges.
pub fn rescale(name: &str, inputs: &[Value], threads: usize) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
    let o1 = f32t(name, inputs, 0)?;
    let m1 = f32t(name, inputs, 1)?;
    let l1 = f32t(name, inputs, 2)?;
    let o2 = f32t(name, inputs, 3)?;
    let m2 = f32t(name, inputs, 4)?;
    let l2 = f32t(name, inputs, 5)?;
    ensure!(o1.shape == o2.shape && m1.shape == m2.shape && l1.shape == l2.shape);
    let (h, c, d) = dims3(name, o1)?;
    ensure!(m1.shape == [h, c] && l1.shape == [h, c]);
    let rows = h * c;
    let mut o = vec![0.0f32; rows * d];
    let mut m = vec![0.0f32; rows];
    let mut l = vec![0.0f32; rows];
    let (o1d, m1d, l1d) = (o1.data(), m1.data(), l1.data());
    let (o2d, m2d, l2d) = (o2.data(), m2.data(), l2.data());
    let ranges = even_ranges(rows, threads);
    let row_counts: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
    let slices = split3(&mut o, &mut m, &mut l, &row_counts, d);
    let tasks: Vec<(Range<usize>, (&mut [f32], &mut [f32], &mut [f32]))> =
        ranges.into_iter().zip(slices).collect();
    run_tasks(tasks, |(range, (o_g, m_g, l_g))| {
        let r0 = range.start;
        for ri in range {
            let mx = m1d[ri].max(m2d[ri]);
            let a1 = if m1d[ri] == f32::NEG_INFINITY { 0.0 } else { (m1d[ri] - mx).exp() };
            let a2 = if m2d[ri] == f32::NEG_INFINITY { 0.0 } else { (m2d[ri] - mx).exp() };
            m_g[ri - r0] = mx;
            l_g[ri - r0] = l1d[ri] * a1 + l2d[ri] * a2;
            let out = &mut o_g[(ri - r0) * d..(ri - r0 + 1) * d];
            let x1 = &o1d[ri * d..(ri + 1) * d];
            let x2 = &o2d[ri * d..(ri + 1) * d];
            for t in 0..d {
                out[t] = x1[t] * a1 + x2[t] * a2;
            }
        }
    });
    Ok(vec![
        Tensor::new(o1.shape.clone(), o),
        Tensor::new(m1.shape.clone(), m),
        Tensor::new(l1.shape.clone(), l),
    ])
}

/// Vectorized finalize epilogue — the contract of
/// [`super::scalar::finalize`], parallel over contiguous row ranges. Empty
/// rows are rejected up front so the workers stay infallible.
pub fn finalize(name: &str, inputs: &[Value], threads: usize) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 3, "{name}: expected 3 inputs");
    let o = f32t(name, inputs, 0)?;
    let m = f32t(name, inputs, 1)?;
    let l = f32t(name, inputs, 2)?;
    let (h, c, d) = dims3(name, o)?;
    ensure!(m.shape == [h, c] && l.shape == [h, c]);
    let (od, md, ld) = (o.data(), m.data(), l.data());
    let rows = h * c;
    for (ri, lv) in ld.iter().enumerate() {
        ensure!(*lv > 0.0, "{name}: empty softmax row {ri}");
    }
    let mut out = vec![0.0f32; rows * d];
    let mut lse = vec![0.0f32; rows];
    let ranges = even_ranges(rows, threads);
    let tasks: Vec<(Range<usize>, &mut [f32], &mut [f32])> = {
        let (mut o_r, mut s_r) = (&mut out[..], &mut lse[..]);
        let mut tasks = Vec::with_capacity(ranges.len());
        for r in ranges {
            let (og, rest) = mem::take(&mut o_r).split_at_mut(r.len() * d);
            o_r = rest;
            let (sg, rest) = mem::take(&mut s_r).split_at_mut(r.len());
            s_r = rest;
            tasks.push((r, og, sg));
        }
        tasks
    };
    run_tasks(tasks, |(range, o_g, s_g)| {
        let r0 = range.start;
        for ri in range {
            let inv = 1.0 / ld[ri];
            let dst = &mut o_g[(ri - r0) * d..(ri - r0 + 1) * d];
            let src = &od[ri * d..(ri + 1) * d];
            for t in 0..d {
                dst[t] = src[t] * inv;
            }
            s_g[ri - r0] = md[ri] + ld[ri].ln();
        }
    });
    Ok(vec![Tensor::new(o.shape.clone(), out), Tensor::new(m.shape.clone(), lse)])
}

/// Monolithic causal oracle on the tiled path — the contract of
/// [`super::scalar::full_attn_ref`]. Returns `(o, lse)`.
pub fn full_attn_ref(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    threads: usize,
    tiles: Tiles,
) -> Result<Vec<Tensor>> {
    let (h, n, _d) = dims3(name, q)?;
    let o0 = Tensor::zeros(&q.shape);
    let m0 = Tensor::full(&[h, n], f32::NEG_INFINITY);
    let l0 = Tensor::zeros(&[h, n]);
    let oml = chunk_fwd(name, q, k, v, &o0, &m0, &l0, true, threads, tiles)?;
    finalize(
        name,
        &[
            Value::F32(oml[0].clone()),
            Value::F32(oml[1].clone()),
            Value::F32(oml[2].clone()),
        ],
        threads,
    )
}
