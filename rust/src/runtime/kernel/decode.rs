//! Decode-pass attention over a paged KV-cache — the serving twin of the
//! training chunk kernels.
//!
//! A decode step computes **one query row per running request** against
//! that request's resident KV, addressed through a slot list gathered
//! from its page table (`crate::serving::kvcache`). The workload class is
//! therefore the transpose of prefill: tiny q (one row), long kv, and an
//! indirection on every kv row.
//!
//! Two paths mirror the training kernels exactly:
//!
//! * **scalar** — one full-width score pass per row with naive serial
//!   reductions, the same rounding order as [`super::scalar::chunk_fwd`]
//!   followed by [`super::scalar::finalize`]. The correctness oracle.
//! * **tiled** — blocked online softmax over `Tiles::k`-wide slot tiles
//!   with the vectorized [`super::dot`]/[`super::axpy`] loops, the same
//!   rounding order as [`super::tiled::fwd_unit`] + finalize.
//!
//! Because causal row `t` of the monolithic `full_attn_ref` depends only
//! on positions `0..=t` and its kv-tile boundaries are multiples of the
//! kv tile width from zero, a decode row at position `t` (context length
//! `t + 1`, slots gathered in position order) reproduces oracle row `t`
//! **bit-for-bit** on the matching path — the serving pipeline's
//! one-shot-prefill oracle check relies on this.
//!
//! Threading partitions independent `(head, request)` rows into
//! contiguous cost-balanced groups (cost = context length); each row's
//! reduction runs wholly inside one worker in fixed slot order, so
//! results are bit-identical at every thread count, like the training
//! kernels.

use anyhow::{ensure, Result};

use super::tiled::{Tiles, MAX_TILE_K};
use super::{axpy, dot, f32t, gqa_group, partition, scale_row};
use crate::runtime::tensor::{Tensor, Value};

/// Decode one batch: `inputs = [q, k_slab, v_slab, slots, lens]`.
///
/// * `q`: `[h, b, d]` — one query row per request per head.
/// * `k_slab`/`v_slab`: `[n_slots, kvh, d]` — the paged cache storage;
///   slot `s`, kv head `g` lives at `(s * kvh + g) * d`.
/// * `slots`: `[b, max_ctx]` — per-request slot ids in position order
///   (f32-encoded integers; exact below 2^24), row `r` valid for
///   `lens[r]` entries.
/// * `lens`: `[b]` — per-request context lengths (≥ 1).
///
/// Returns `(o, lse)` with `o: [h, b, d]`, `lse: [h, b]` — finalized,
/// exactly like `full_attn_ref`.
pub fn decode_attn(
    name: &str,
    inputs: &[Value],
    tiled_mode: bool,
    threads: usize,
    tiles: Tiles,
) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 5, "{name}: expected 5 inputs");
    let q = f32t(name, inputs, 0)?;
    let k_slab = f32t(name, inputs, 1)?;
    let v_slab = f32t(name, inputs, 2)?;
    let slots = f32t(name, inputs, 3)?;
    let lens = f32t(name, inputs, 4)?;
    let tiles = tiles.clamped();

    ensure!(q.shape.len() == 3, "{name}: q must be [h, b, d], got {:?}", q.shape);
    let (h, b, d) = (q.shape[0], q.shape[1], q.shape[2]);
    ensure!(
        k_slab.shape.len() == 3 && k_slab.shape == v_slab.shape,
        "{name}: k/v slabs must be rank-3 and identical, got {:?} vs {:?}",
        k_slab.shape,
        v_slab.shape
    );
    let (n_slots, kvh, dk) = (k_slab.shape[0], k_slab.shape[1], k_slab.shape[2]);
    ensure!(d == dk, "{name}: head dim mismatch (q {d}, kv {dk})");
    let group = gqa_group(name, h, kvh)?;
    ensure!(
        slots.shape.len() == 2 && slots.shape[0] == b,
        "{name}: slots must be [b, max_ctx], got {:?}",
        slots.shape
    );
    let max_ctx = slots.shape[1];
    ensure!(lens.shape == [b], "{name}: lens must be [b], got {:?}", lens.shape);

    let lens_d = lens.data();
    let slots_d = slots.data();
    let mut ctx = Vec::with_capacity(b);
    for (r, &lf) in lens_d.iter().enumerate() {
        let len = lf as usize;
        ensure!(
            lf >= 1.0 && lf.fract() == 0.0 && len <= max_ctx,
            "{name}: request {r} context length {lf} out of [1, {max_ctx}]"
        );
        for &sf in &slots_d[r * max_ctx..r * max_ctx + len] {
            let slot = sf as usize;
            ensure!(
                sf >= 0.0 && sf.fract() == 0.0 && slot < n_slots,
                "{name}: request {r} slot {sf} out of [0, {n_slots})"
            );
        }
        ctx.push(len);
    }

    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k_slab.data(), v_slab.data());
    let mut o = vec![0.0f32; h * b * d];
    let mut lse = vec![0.0f32; h * b];

    // independent (head, request) rows, cost = context length
    let rows = h * b;
    let costs: Vec<f64> = (0..rows).map(|ri| ctx[ri % b] as f64).collect();
    let groups = partition(&costs, if tiled_mode { threads } else { 1 });
    let tasks: Vec<(std::ops::Range<usize>, &mut [f32], &mut [f32])> = {
        let (mut o_r, mut s_r) = (&mut o[..], &mut lse[..]);
        let mut tasks = Vec::with_capacity(groups.len());
        for g in groups {
            let (og, rest) = std::mem::take(&mut o_r).split_at_mut(g.len() * d);
            o_r = rest;
            let (sg, rest) = std::mem::take(&mut s_r).split_at_mut(g.len());
            s_r = rest;
            tasks.push((g, og, sg));
        }
        tasks
    };
    super::tiled::run_tasks(tasks, |(range, o_g, s_g)| {
        let r0 = range.start;
        for ri in range {
            let (hh, r) = (ri / b, ri % b);
            let g = hh / group;
            let qrow = &qd[ri * d..(ri + 1) * d];
            let slot_row = &slots_d[r * max_ctx..r * max_ctx + ctx[r]];
            let orow = &mut o_g[(ri - r0) * d..(ri - r0 + 1) * d];
            let (m, l) = if tiled_mode {
                decode_row_tiled(qrow, kd, vd, slot_row, g, kvh, d, scale, tiles.k, orow)
            } else {
                decode_row_scalar(qrow, kd, vd, slot_row, g, kvh, d, scale, orow)
            };
            // finalize inline: l > 0 is guaranteed by ctx[r] >= 1
            let inv = 1.0 / l;
            for x in orow.iter_mut() {
                *x *= inv;
            }
            s_g[ri - r0] = m + l.ln();
        }
    });
    Ok(vec![Tensor::new(vec![h, b, d], o), Tensor::new(vec![h, b], lse)])
}

/// One decode row on the tiled path — the per-row loop of
/// [`super::tiled::fwd_unit`] with slot-gathered kv rows. Returns the
/// pre-finalize `(m, l)`.
#[allow(clippy::too_many_arguments)]
fn decode_row_tiled(
    qrow: &[f32],
    kd: &[f32],
    vd: &[f32],
    slot_row: &[f32],
    g: usize,
    kvh: usize,
    d: usize,
    scale: f32,
    tile_k: usize,
    orow: &mut [f32],
) -> (f32, f32) {
    let len = slot_row.len();
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut s_buf = [0.0f32; MAX_TILE_K];
    let mut j0 = 0usize;
    while j0 < len {
        let jt = (j0 + tile_k).min(len);
        let mut smax = f32::NEG_INFINITY;
        for j in j0..jt {
            let slot = slot_row[j] as usize;
            let s = dot(qrow, &kd[(slot * kvh + g) * d..][..d]) * scale;
            s_buf[j - j0] = s;
            if s > smax {
                smax = s;
            }
        }
        let m_new = m.max(smax);
        // exp(-inf - finite) is 0, but -inf - -inf is NaN: the initial
        // accumulator carries zero weight either way
        let alpha = if m == f32::NEG_INFINITY { 0.0 } else { (m - m_new).exp() };
        if alpha != 1.0 {
            scale_row(orow, alpha);
        }
        let mut lsum = 0.0f32;
        for j in j0..jt {
            let p = (s_buf[j - j0] - m_new).exp();
            lsum += p;
            let slot = slot_row[j] as usize;
            axpy(orow, p, &vd[(slot * kvh + g) * d..][..d]);
        }
        l = l * alpha + lsum;
        m = m_new;
        j0 = jt;
    }
    (m, l)
}

/// One decode row on the scalar path — the per-row loop of
/// [`super::scalar::chunk_fwd`] (naive serial dot, one full-width score
/// pass) with slot-gathered kv rows. Returns the pre-finalize `(m, l)`.
#[allow(clippy::too_many_arguments)]
fn decode_row_scalar(
    qrow: &[f32],
    kd: &[f32],
    vd: &[f32],
    slot_row: &[f32],
    g: usize,
    kvh: usize,
    d: usize,
    scale: f32,
    orow: &mut [f32],
) -> (f32, f32) {
    let len = slot_row.len();
    let mut s_row = vec![0.0f32; len];
    let mut smax = f32::NEG_INFINITY;
    for (j, s) in s_row.iter_mut().enumerate() {
        let slot = slot_row[j] as usize;
        let krow = &kd[(slot * kvh + g) * d..(slot * kvh + g) * d + d];
        let naive: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
        *s = naive * scale;
        if *s > smax {
            smax = *s;
        }
    }
    // m starts at -inf with a zeroed accumulator, so the scalar path's
    // alpha-rescale of the empty orow is a no-op exactly as in chunk_fwd
    let m_new = smax;
    let mut lsum = 0.0f32;
    for (j, s) in s_row.iter().enumerate() {
        let p = (s - m_new).exp();
        lsum += p;
        let slot = slot_row[j] as usize;
        let vrow = &vd[(slot * kvh + g) * d..(slot * kvh + g) * d + d];
        for (x, vv) in orow.iter_mut().zip(vrow) {
            *x += p * vv;
        }
    }
    (m_new, lsum)
}
