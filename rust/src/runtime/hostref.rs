//! Kernel backends for the distributed executor.
//!
//! The executor walks a plan and calls named kernels; [`Kernels`] is the
//! seam between that walk and what actually computes them:
//!
//! * [`Runtime`] (the PJRT client) — real AOT artifacts, needs
//!   `make artifacts` plus the real `xla` bindings;
//! * [`HostKernels`] — pure-Rust implementations of the exact kernel
//!   contracts the artifacts export (streaming-softmax chunk
//!   forward/backward, rescale merge, finalize, and the monolithic
//!   `full_attn_ref` oracle), GQA-aware. Two interchangeable paths live
//!   behind it (see [`crate::runtime::kernel`]): the tiled/vectorized
//!   throughput path (default, optionally multi-threaded) and the
//!   original scalar oracle (`HostKernels::scalar()`). Runs on a bare
//!   checkout, so the prefetch-engine stress tests, `repro trace`, and
//!   the executor micro-bench all execute the *real* executor end to end;
//! * [`NullKernels`] — zero-work shape echo (outputs are refcount bumps of
//!   correctly-shaped inputs). Used by the transport micro-bench: kernel
//!   time is identical across send-path variants by construction, so the
//!   measured delta is purely the fabric.
//!
//! The host math mirrors `python/compile/kernels/flash_chunk.py`
//! (scale `1/sqrt(D)`, running `(o, m, l)` accumulators, FA2 backward from
//! the saved `o`/`lse`) and the GQA mapping of `compile/model.py`
//! (`repeat_kv`: query head `h` reads kv head `h / (H / KVH)`; kv grads
//! sum over each query group).

use anyhow::{bail, ensure, Result};

use super::client::Runtime;
use super::kernel::{decode, f32t, scalar, tiled, Tiles};
use super::tensor::{Tensor, Value};

/// Anything that can execute a named attention kernel. The threaded
/// executor is written against this, so one plan walk drives PJRT
/// artifacts, the host reference kernels, or the zero-work echo.
pub trait Kernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>>;
}

impl Kernels for Runtime {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        Runtime::run(self, name, inputs)
    }
}

/// Which host implementation a [`HostKernels`] instance dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// The original row-at-a-time reference — the correctness oracle.
    Scalar,
    /// Cache-blocked, vectorized, optionally multi-threaded.
    Tiled,
}

/// Pure-Rust host backend (see module docs). Defaults to the tiled path
/// at one thread, which keeps runs deterministic while being several
/// times faster than the scalar oracle.
#[derive(Clone, Copy, Debug)]
pub struct HostKernels {
    mode: KernelMode,
    threads: usize,
    tiles: Tiles,
}

impl Default for HostKernels {
    fn default() -> Self {
        Self::tiled(1)
    }
}

impl HostKernels {
    /// The scalar oracle — the exact code every earlier numeric pin was
    /// built on. Single-threaded by construction.
    pub fn scalar() -> Self {
        Self { mode: KernelMode::Scalar, threads: 1, tiles: Tiles::default() }
    }

    /// The tiled/vectorized path on `threads` workers (clamped to ≥ 1) at
    /// the default tile geometry. Results are bit-identical across thread
    /// counts — see [`crate::runtime::kernel`].
    pub fn tiled(threads: usize) -> Self {
        Self::with_tiles(threads, Tiles::default())
    }

    /// The tiled path at an explicit tile geometry (clamped into the
    /// kernels' stack-buffer capacity). Any fixed geometry is still
    /// bit-identical across thread counts; different geometries are not
    /// bit-identical to each other.
    pub fn with_tiles(threads: usize, tiles: Tiles) -> Self {
        Self { mode: KernelMode::Tiled, threads: threads.max(1), tiles: tiles.clamped() }
    }

    /// The tiled path at the startup-sweep pick ([`tiled::autotune`],
    /// cached per process) — `RunSpec::autotune_tiles`' backend.
    pub fn autotuned(threads: usize) -> Self {
        Self::with_tiles(threads, tiled::autotune())
    }

    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Effective tile geometry (what a trace records).
    pub fn tiles(&self) -> Tiles {
        self.tiles
    }
}

impl Kernels for HostKernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t = |i: usize| f32t(name, inputs, i);
        let tiled_mode = self.mode == KernelMode::Tiled;
        match name {
            "attn_fwd_diag" | "attn_fwd_full" => {
                ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
                let causal = name == "attn_fwd_diag";
                if tiled_mode {
                    tiled::chunk_fwd(
                        name,
                        t(0)?,
                        t(1)?,
                        t(2)?,
                        t(3)?,
                        t(4)?,
                        t(5)?,
                        causal,
                        self.threads,
                        self.tiles,
                    )
                } else {
                    scalar::chunk_fwd(name, t(0)?, t(1)?, t(2)?, t(3)?, t(4)?, t(5)?, causal)
                }
            }
            "attn_rescale" => {
                if tiled_mode {
                    tiled::rescale(name, inputs, self.threads)
                } else {
                    scalar::rescale(name, inputs)
                }
            }
            "attn_finalize" => {
                if tiled_mode {
                    tiled::finalize(name, inputs, self.threads)
                } else {
                    scalar::finalize(name, inputs)
                }
            }
            "attn_bwd_diag" | "attn_bwd_full" => {
                ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
                let causal = name == "attn_bwd_diag";
                if tiled_mode {
                    tiled::chunk_bwd(
                        name,
                        t(0)?,
                        t(1)?,
                        t(2)?,
                        t(3)?,
                        t(4)?,
                        t(5)?,
                        causal,
                        self.threads,
                        self.tiles,
                    )
                } else {
                    scalar::chunk_bwd(name, t(0)?, t(1)?, t(2)?, t(3)?, t(4)?, t(5)?, causal)
                }
            }
            "full_attn_ref" => {
                ensure!(inputs.len() == 3, "{name}: expected 3 inputs");
                if tiled_mode {
                    tiled::full_attn_ref(name, t(0)?, t(1)?, t(2)?, self.threads, self.tiles)
                } else {
                    scalar::full_attn_ref(name, t(0)?, t(1)?, t(2)?)
                }
            }
            "decode_attn" => {
                decode::decode_attn(name, inputs, tiled_mode, self.threads, self.tiles)
            }
            other => bail!("HostKernels: unknown kernel {other:?}"),
        }
    }
}

/// Zero-work backend: echoes correctly-shaped inputs as outputs (refcount
/// bumps only), so the executor micro-bench isolates the transport layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullKernels;

impl Kernels for NullKernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t = |i: usize| f32t(name, inputs, i).cloned();
        match name {
            // (q, k, v, o, m, l) -> (o, m, l)
            "attn_fwd_diag" | "attn_fwd_full" => Ok(vec![t(3)?, t(4)?, t(5)?]),
            // (o, m, l, o2, m2, l2) -> (o, m, l)
            "attn_rescale" => Ok(vec![t(0)?, t(1)?, t(2)?]),
            // (o, m, l) -> (o, lse)
            "attn_finalize" => Ok(vec![t(0)?, t(1)?]),
            // (q, k, v, o, lse, do) -> (dq, dk, dv)
            "attn_bwd_diag" | "attn_bwd_full" => Ok(vec![t(0)?, t(1)?, t(2)?]),
            other => bail!("NullKernels: unknown kernel {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand3(rng: &mut Rng, shape: [usize; 3]) -> Tensor {
        Tensor::new(shape.to_vec(), rng.normal_vec(shape.iter().product()))
    }

    /// Streaming chunked accumulate + rescale merges must reproduce the
    /// monolithic oracle exactly in structure (and to fp tolerance in
    /// value), including the GQA head grouping.
    #[test]
    fn chunked_forward_matches_oracle() {
        let (h, kvh, p, c, d) = (4usize, 2usize, 4usize, 5usize, 3usize);
        let n = p * c;
        let mut rng = Rng::new(7);
        let q = rand3(&mut rng, [h, n, d]);
        let k = rand3(&mut rng, [kvh, n, d]);
        let v = rand3(&mut rng, [kvh, n, d]);
        let kk = HostKernels::default();
        let oracle = kk
            .run("full_attn_ref", &[q.clone().into(), k.clone().into(), v.clone().into()])
            .unwrap();

        let qs = q.chunk_axis1(p);
        let ks = k.chunk_axis1(p);
        let vs = v.chunk_axis1(p);
        let mut o_parts = Vec::new();
        let mut lse_parts = Vec::new();
        for w in 0..p {
            let mut acc = vec![
                Tensor::zeros(&[h, c, d]),
                Tensor::full(&[h, c], f32::NEG_INFINITY),
                Tensor::zeros(&[h, c]),
            ];
            // diag first, then earlier chunks: even via owner-path
            // accumulate, odd via a helper partial merged with rescale
            let args = |acc: &[Tensor], r: usize| {
                vec![
                    qs[w].clone().into(),
                    ks[r].clone().into(),
                    vs[r].clone().into(),
                    acc[0].clone().into(),
                    acc[1].clone().into(),
                    acc[2].clone().into(),
                ]
            };
            acc = kk.run("attn_fwd_diag", &args(&acc, w)).unwrap();
            for r in 0..w {
                if r % 2 == 0 {
                    acc = kk.run("attn_fwd_full", &args(&acc, r)).unwrap();
                } else {
                    let fresh = vec![
                        Tensor::zeros(&[h, c, d]),
                        Tensor::full(&[h, c], f32::NEG_INFINITY),
                        Tensor::zeros(&[h, c]),
                    ];
                    let part = kk.run("attn_fwd_full", &args(&fresh, r)).unwrap();
                    acc = kk
                        .run(
                            "attn_rescale",
                            &[
                                acc[0].clone().into(),
                                acc[1].clone().into(),
                                acc[2].clone().into(),
                                part[0].clone().into(),
                                part[1].clone().into(),
                                part[2].clone().into(),
                            ],
                        )
                        .unwrap();
                }
            }
            let fin = kk
                .run(
                    "attn_finalize",
                    &[acc[0].clone().into(), acc[1].clone().into(), acc[2].clone().into()],
                )
                .unwrap();
            o_parts.push(fin[0].clone());
            lse_parts.push(fin[1].reshape(vec![h, c, 1]));
        }
        let o = Tensor::cat_axis1(&o_parts);
        let lse = Tensor::cat_axis1(&lse_parts).reshape(vec![h, n]);
        assert!(o.max_abs_diff(&oracle[0]) < 1e-5, "{}", o.max_abs_diff(&oracle[0]));
        assert!(lse.max_abs_diff(&oracle[1]) < 1e-5);
    }

    /// Distributed per-pair backward partials must sum to the monolithic
    /// whole-sequence causal backward (one `attn_bwd_diag` over N).
    #[test]
    fn chunked_backward_matches_monolithic() {
        let (h, kvh, p, c, d) = (4usize, 2usize, 3usize, 4usize, 3usize);
        let n = p * c;
        let mut rng = Rng::new(11);
        let q = rand3(&mut rng, [h, n, d]);
        let k = rand3(&mut rng, [kvh, n, d]);
        let v = rand3(&mut rng, [kvh, n, d]);
        let do_ = rand3(&mut rng, [h, n, d]);
        let kk = HostKernels::default();
        let fwd = kk
            .run("full_attn_ref", &[q.clone().into(), k.clone().into(), v.clone().into()])
            .unwrap();
        let (o, lse) = (&fwd[0], &fwd[1]);
        let mono = kk
            .run(
                "attn_bwd_diag",
                &[
                    q.clone().into(),
                    k.clone().into(),
                    v.clone().into(),
                    o.clone().into(),
                    lse.clone().into(),
                    do_.clone().into(),
                ],
            )
            .unwrap();

        let qs = q.chunk_axis1(p);
        let ks = k.chunk_axis1(p);
        let vs = v.chunk_axis1(p);
        let os = o.chunk_axis1(p);
        let ls = lse.reshape(vec![h, n, 1]).chunk_axis1(p);
        let dos = do_.chunk_axis1(p);
        let mut dq: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[h, c, d])).collect();
        let mut dk: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[kvh, c, d])).collect();
        let mut dv: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[kvh, c, d])).collect();
        for w in 0..p {
            for r in 0..=w {
                let kernel = if r == w { "attn_bwd_diag" } else { "attn_bwd_full" };
                let g = kk
                    .run(
                        kernel,
                        &[
                            qs[w].clone().into(),
                            ks[r].clone().into(),
                            vs[r].clone().into(),
                            os[w].clone().into(),
                            ls[w].reshape(vec![h, c]).into(),
                            dos[w].clone().into(),
                        ],
                    )
                    .unwrap();
                dq[w].add_assign(&g[0]);
                dk[r].add_assign(&g[1]);
                dv[r].add_assign(&g[2]);
            }
        }
        assert!(Tensor::cat_axis1(&dq).max_abs_diff(&mono[0]) < 1e-5);
        assert!(Tensor::cat_axis1(&dk).max_abs_diff(&mono[1]) < 1e-5);
        assert!(Tensor::cat_axis1(&dv).max_abs_diff(&mono[2]) < 1e-5);
    }

    #[test]
    fn host_kernels_ctors_pin_mode_and_thread_floor() {
        assert_eq!(HostKernels::default().mode(), KernelMode::Tiled);
        assert_eq!(HostKernels::default().threads(), 1);
        assert_eq!(HostKernels::scalar().mode(), KernelMode::Scalar);
        assert_eq!(HostKernels::tiled(0).threads(), 1, "threads clamp to >= 1");
        assert_eq!(HostKernels::tiled(6).threads(), 6);
    }

    #[test]
    fn null_kernels_echo_shapes_zero_copy() {
        let q = Tensor::zeros(&[2, 4, 3]);
        let k = Tensor::zeros(&[1, 4, 3]);
        let m = Tensor::zeros(&[2, 4]);
        let out = NullKernels
            .run(
                "attn_fwd_full",
                &[
                    q.clone().into(),
                    k.clone().into(),
                    k.clone().into(),
                    q.clone().into(),
                    m.clone().into(),
                    m.clone().into(),
                ],
            )
            .unwrap();
        assert_eq!(out[0].shape, q.shape);
        assert!(out[0].shares_buffer(&q), "null kernel outputs are refcount bumps");
        assert!(NullKernels.run("nope", &[]).is_err());
    }
}
