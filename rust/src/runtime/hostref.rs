//! Kernel backends for the distributed executor.
//!
//! The executor walks a plan and calls named kernels; [`Kernels`] is the
//! seam between that walk and what actually computes them:
//!
//! * [`Runtime`] (the PJRT client) — real AOT artifacts, needs
//!   `make artifacts` plus the real `xla` bindings;
//! * [`HostKernels`] — pure-Rust reference implementations of the exact
//!   kernel contracts the artifacts export (streaming-softmax chunk
//!   forward/backward, rescale merge, finalize, and the monolithic
//!   `full_attn_ref` oracle), GQA-aware. Runs on a bare checkout, so the
//!   prefetch-engine stress tests, `repro trace`, and the executor
//!   micro-bench all execute the *real* executor end to end;
//! * [`NullKernels`] — zero-work shape echo (outputs are refcount bumps of
//!   correctly-shaped inputs). Used by the transport micro-bench: kernel
//!   time is identical across send-path variants by construction, so the
//!   measured delta is purely the fabric.
//!
//! The host math mirrors `python/compile/kernels/flash_chunk.py`
//! (scale `1/sqrt(D)`, running `(o, m, l)` accumulators, FA2 backward from
//! the saved `o`/`lse`) and the GQA mapping of `compile/model.py`
//! (`repeat_kv`: query head `h` reads kv head `h / (H / KVH)`; kv grads
//! sum over each query group).

use anyhow::{bail, ensure, Result};

use super::client::Runtime;
use super::tensor::{Tensor, Value};

/// Anything that can execute a named attention kernel. The threaded
/// executor is written against this, so one plan walk drives PJRT
/// artifacts, the host reference kernels, or the zero-work echo.
pub trait Kernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>>;
}

impl Kernels for Runtime {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        Runtime::run(self, name, inputs)
    }
}

fn f32t<'a>(name: &str, inputs: &'a [Value], i: usize) -> Result<&'a Tensor> {
    match inputs.get(i) {
        Some(Value::F32(t)) => Ok(t),
        Some(Value::I32(_)) => bail!("{name}: input {i} must be f32"),
        None => bail!("{name}: missing input {i}"),
    }
}

fn dims3(name: &str, t: &Tensor) -> Result<(usize, usize, usize)> {
    ensure!(t.shape.len() == 3, "{name}: expected rank-3, got {:?}", t.shape);
    Ok((t.shape[0], t.shape[1], t.shape[2]))
}

/// q-head-group width for GQA: query head `h` reads kv head `h / group`.
fn gqa_group(name: &str, h: usize, kvh: usize) -> Result<usize> {
    ensure!(
        kvh >= 1 && h % kvh == 0,
        "{name}: {h} query heads not divisible by {kvh} kv heads"
    );
    Ok(h / kvh)
}

/// Streaming-softmax chunk forward: fold the `(q, k, v)` block into the
/// running `(o, m, l)` accumulators — the paper's `attn(·)` kernel.
/// `causal` marks the diagonal chunk pair (in-block lower-triangular mask).
#[allow(clippy::too_many_arguments)]
fn chunk_fwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o0: &Tensor,
    m0: &Tensor,
    l0: &Tensor,
    causal: bool,
) -> Result<Vec<Tensor>> {
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk) = dims3(name, k)?;
    ensure!(d == dk && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o0.shape == q.shape && m0.shape == [h, cq] && l0.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut o = o0.data().to_vec();
    let mut m = m0.data().to_vec();
    let mut l = l0.data().to_vec();
    let mut s_row = vec![0.0f32; ck];
    for hh in 0..h {
        let g = hh / group;
        for i in 0..cq {
            let qrow = &qd[(hh * cq + i) * d..(hh * cq + i + 1) * d];
            let jmax = if causal { i + 1 } else { ck };
            let mut smax = f32::NEG_INFINITY;
            for (j, s) in s_row.iter_mut().enumerate().take(jmax) {
                let krow = &kd[(g * ck + j) * d..(g * ck + j + 1) * d];
                let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                *s = dot * scale;
                if *s > smax {
                    smax = *s;
                }
            }
            let ri = hh * cq + i;
            let m_new = m[ri].max(smax);
            // exp(-inf - finite) is 0, but -inf - -inf is NaN: the initial
            // accumulator carries zero weight either way
            let alpha = if m[ri] == f32::NEG_INFINITY { 0.0 } else { (m[ri] - m_new).exp() };
            let orow = &mut o[ri * d..(ri + 1) * d];
            for x in orow.iter_mut() {
                *x *= alpha;
            }
            let mut lsum = 0.0f32;
            for (j, s) in s_row.iter().enumerate().take(jmax) {
                let p = (s - m_new).exp();
                lsum += p;
                let vrow = &vd[(g * ck + j) * d..(g * ck + j + 1) * d];
                for (x, vv) in orow.iter_mut().zip(vrow) {
                    *x += p * vv;
                }
            }
            l[ri] = l[ri] * alpha + lsum;
            m[ri] = m_new;
        }
    }
    Ok(vec![
        Tensor::new(q.shape.clone(), o),
        Tensor::new(vec![h, cq], m),
        Tensor::new(vec![h, cq], l),
    ])
}

/// The paper's `rescale(·)`: merge two partial `(o, m, l)` triples (the
/// helper's shipped partial into the owner's accumulator).
fn rescale(name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
    let o1 = f32t(name, inputs, 0)?;
    let m1 = f32t(name, inputs, 1)?;
    let l1 = f32t(name, inputs, 2)?;
    let o2 = f32t(name, inputs, 3)?;
    let m2 = f32t(name, inputs, 4)?;
    let l2 = f32t(name, inputs, 5)?;
    ensure!(o1.shape == o2.shape && m1.shape == m2.shape && l1.shape == l2.shape);
    let (h, c, d) = dims3(name, o1)?;
    ensure!(m1.shape == [h, c] && l1.shape == [h, c]);
    let mut o = vec![0.0f32; h * c * d];
    let mut m = vec![0.0f32; h * c];
    let mut l = vec![0.0f32; h * c];
    let (o1d, m1d, l1d) = (o1.data(), m1.data(), l1.data());
    let (o2d, m2d, l2d) = (o2.data(), m2.data(), l2.data());
    for ri in 0..h * c {
        let mx = m1d[ri].max(m2d[ri]);
        let a1 = if m1d[ri] == f32::NEG_INFINITY { 0.0 } else { (m1d[ri] - mx).exp() };
        let a2 = if m2d[ri] == f32::NEG_INFINITY { 0.0 } else { (m2d[ri] - mx).exp() };
        m[ri] = mx;
        l[ri] = l1d[ri] * a1 + l2d[ri] * a2;
        for t in 0..d {
            o[ri * d + t] = o1d[ri * d + t] * a1 + o2d[ri * d + t] * a2;
        }
    }
    Ok(vec![
        Tensor::new(o1.shape.clone(), o),
        Tensor::new(m1.shape.clone(), m),
        Tensor::new(l1.shape.clone(), l),
    ])
}

/// The paper's `last = True` epilogue: normalize and emit the logsumexp.
fn finalize(name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 3, "{name}: expected 3 inputs");
    let o = f32t(name, inputs, 0)?;
    let m = f32t(name, inputs, 1)?;
    let l = f32t(name, inputs, 2)?;
    let (h, c, d) = dims3(name, o)?;
    ensure!(m.shape == [h, c] && l.shape == [h, c]);
    let (od, md, ld) = (o.data(), m.data(), l.data());
    let mut out = vec![0.0f32; h * c * d];
    let mut lse = vec![0.0f32; h * c];
    for ri in 0..h * c {
        ensure!(ld[ri] > 0.0, "{name}: empty softmax row {ri}");
        let inv = 1.0 / ld[ri];
        for t in 0..d {
            out[ri * d + t] = od[ri * d + t] * inv;
        }
        lse[ri] = md[ri] + ld[ri].ln();
    }
    Ok(vec![Tensor::new(o.shape.clone(), out), Tensor::new(m.shape.clone(), lse)])
}

/// FA2-style chunk-pair backward from the saved `o`/`lse` — no forward
/// recompute (the §3.3 rematerialization-aware payoff). Returns
/// `(dq, dk, dv)`; dk/dv are grouped to the kv heads (GQA grads sum over
/// each query group).
#[allow(clippy::too_many_arguments)]
fn chunk_bwd(
    name: &str,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    lse: &Tensor,
    do_: &Tensor,
    causal: bool,
) -> Result<Vec<Tensor>> {
    let (h, cq, d) = dims3(name, q)?;
    let (kvh, ck, dk_) = dims3(name, k)?;
    ensure!(d == dk_ && k.shape == v.shape, "{name}: k/v shape mismatch");
    ensure!(!causal || cq == ck, "{name}: causal needs square chunk pair");
    ensure!(o.shape == q.shape && do_.shape == q.shape && lse.shape == [h, cq]);
    let group = gqa_group(name, h, kvh)?;
    let scale = 1.0 / (d as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let (od, ld, dod) = (o.data(), lse.data(), do_.data());
    let mut dq = vec![0.0f32; h * cq * d];
    let mut dkv_k = vec![0.0f32; kvh * ck * d];
    let mut dkv_v = vec![0.0f32; kvh * ck * d];
    for hh in 0..h {
        let g = hh / group;
        for i in 0..cq {
            let ri = hh * cq + i;
            let qrow = &qd[ri * d..(ri + 1) * d];
            let orow = &od[ri * d..(ri + 1) * d];
            let dorow = &dod[ri * d..(ri + 1) * d];
            let delta: f32 = dorow.iter().zip(orow).map(|(a, b)| a * b).sum();
            let jmax = if causal { i + 1 } else { ck };
            for j in 0..jmax {
                let cj = g * ck + j;
                let krow = &kd[cj * d..(cj + 1) * d];
                let vrow = &vd[cj * d..(cj + 1) * d];
                let s: f32 =
                    qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                let p = (s - ld[ri]).exp();
                let dp: f32 = dorow.iter().zip(vrow).map(|(a, b)| a * b).sum();
                let ds = p * (dp - delta);
                let dqrow = &mut dq[ri * d..(ri + 1) * d];
                for (x, kk) in dqrow.iter_mut().zip(krow) {
                    *x += ds * scale * kk;
                }
                let dkrow = &mut dkv_k[cj * d..(cj + 1) * d];
                for (x, qq) in dkrow.iter_mut().zip(qrow) {
                    *x += ds * scale * qq;
                }
                let dvrow = &mut dkv_v[cj * d..(cj + 1) * d];
                for (x, dd) in dvrow.iter_mut().zip(dorow) {
                    *x += p * dd;
                }
            }
        }
    }
    Ok(vec![
        Tensor::new(q.shape.clone(), dq),
        Tensor::new(k.shape.clone(), dkv_k),
        Tensor::new(v.shape.clone(), dkv_v),
    ])
}

/// Monolithic causal attention over the whole sequence — the oracle the
/// distributed executor is checked against. Returns `(o, lse)`.
fn full_attn_ref(name: &str, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Vec<Tensor>> {
    let (h, n, _d) = dims3(name, q)?;
    let o0 = Tensor::zeros(&q.shape);
    let m0 = Tensor::full(&[h, n], f32::NEG_INFINITY);
    let l0 = Tensor::zeros(&[h, n]);
    let oml = chunk_fwd(name, q, k, v, &o0, &m0, &l0, true)?;
    finalize(
        name,
        &[
            Value::F32(oml[0].clone()),
            Value::F32(oml[1].clone()),
            Value::F32(oml[2].clone()),
        ],
    )
}

/// Pure-Rust reference backend (see module docs). Stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct HostKernels;

impl Kernels for HostKernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t = |i: usize| f32t(name, inputs, i);
        match name {
            "attn_fwd_diag" | "attn_fwd_full" => {
                ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
                chunk_fwd(
                    name,
                    t(0)?,
                    t(1)?,
                    t(2)?,
                    t(3)?,
                    t(4)?,
                    t(5)?,
                    name == "attn_fwd_diag",
                )
            }
            "attn_rescale" => rescale(name, inputs),
            "attn_finalize" => finalize(name, inputs),
            "attn_bwd_diag" | "attn_bwd_full" => {
                ensure!(inputs.len() == 6, "{name}: expected 6 inputs");
                chunk_bwd(
                    name,
                    t(0)?,
                    t(1)?,
                    t(2)?,
                    t(3)?,
                    t(4)?,
                    t(5)?,
                    name == "attn_bwd_diag",
                )
            }
            "full_attn_ref" => {
                ensure!(inputs.len() == 3, "{name}: expected 3 inputs");
                full_attn_ref(name, t(0)?, t(1)?, t(2)?)
            }
            other => bail!("HostKernels: unknown kernel {other:?}"),
        }
    }
}

/// Zero-work backend: echoes correctly-shaped inputs as outputs (refcount
/// bumps only), so the executor micro-bench isolates the transport layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullKernels;

impl Kernels for NullKernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t = |i: usize| f32t(name, inputs, i).cloned();
        match name {
            // (q, k, v, o, m, l) -> (o, m, l)
            "attn_fwd_diag" | "attn_fwd_full" => Ok(vec![t(3)?, t(4)?, t(5)?]),
            // (o, m, l, o2, m2, l2) -> (o, m, l)
            "attn_rescale" => Ok(vec![t(0)?, t(1)?, t(2)?]),
            // (o, m, l) -> (o, lse)
            "attn_finalize" => Ok(vec![t(0)?, t(1)?]),
            // (q, k, v, o, lse, do) -> (dq, dk, dv)
            "attn_bwd_diag" | "attn_bwd_full" => Ok(vec![t(0)?, t(1)?, t(2)?]),
            other => bail!("NullKernels: unknown kernel {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand3(rng: &mut Rng, shape: [usize; 3]) -> Tensor {
        Tensor::new(shape.to_vec(), rng.normal_vec(shape.iter().product()))
    }

    /// Streaming chunked accumulate + rescale merges must reproduce the
    /// monolithic oracle exactly in structure (and to fp tolerance in
    /// value), including the GQA head grouping.
    #[test]
    fn chunked_forward_matches_oracle() {
        let (h, kvh, p, c, d) = (4usize, 2usize, 4usize, 5usize, 3usize);
        let n = p * c;
        let mut rng = Rng::new(7);
        let q = rand3(&mut rng, [h, n, d]);
        let k = rand3(&mut rng, [kvh, n, d]);
        let v = rand3(&mut rng, [kvh, n, d]);
        let kk = HostKernels;
        let oracle = kk
            .run("full_attn_ref", &[q.clone().into(), k.clone().into(), v.clone().into()])
            .unwrap();

        let qs = q.chunk_axis1(p);
        let ks = k.chunk_axis1(p);
        let vs = v.chunk_axis1(p);
        let mut o_parts = Vec::new();
        let mut lse_parts = Vec::new();
        for w in 0..p {
            let mut acc = vec![
                Tensor::zeros(&[h, c, d]),
                Tensor::full(&[h, c], f32::NEG_INFINITY),
                Tensor::zeros(&[h, c]),
            ];
            // diag first, then earlier chunks: even via owner-path
            // accumulate, odd via a helper partial merged with rescale
            let args = |acc: &[Tensor], r: usize| {
                vec![
                    qs[w].clone().into(),
                    ks[r].clone().into(),
                    vs[r].clone().into(),
                    acc[0].clone().into(),
                    acc[1].clone().into(),
                    acc[2].clone().into(),
                ]
            };
            acc = kk.run("attn_fwd_diag", &args(&acc, w)).unwrap();
            for r in 0..w {
                if r % 2 == 0 {
                    acc = kk.run("attn_fwd_full", &args(&acc, r)).unwrap();
                } else {
                    let fresh = vec![
                        Tensor::zeros(&[h, c, d]),
                        Tensor::full(&[h, c], f32::NEG_INFINITY),
                        Tensor::zeros(&[h, c]),
                    ];
                    let part = kk.run("attn_fwd_full", &args(&fresh, r)).unwrap();
                    acc = kk
                        .run(
                            "attn_rescale",
                            &[
                                acc[0].clone().into(),
                                acc[1].clone().into(),
                                acc[2].clone().into(),
                                part[0].clone().into(),
                                part[1].clone().into(),
                                part[2].clone().into(),
                            ],
                        )
                        .unwrap();
                }
            }
            let fin = kk
                .run(
                    "attn_finalize",
                    &[acc[0].clone().into(), acc[1].clone().into(), acc[2].clone().into()],
                )
                .unwrap();
            o_parts.push(fin[0].clone());
            lse_parts.push(fin[1].reshape(vec![h, c, 1]));
        }
        let o = Tensor::cat_axis1(&o_parts);
        let lse = Tensor::cat_axis1(&lse_parts).reshape(vec![h, n]);
        assert!(o.max_abs_diff(&oracle[0]) < 1e-5, "{}", o.max_abs_diff(&oracle[0]));
        assert!(lse.max_abs_diff(&oracle[1]) < 1e-5);
    }

    /// Distributed per-pair backward partials must sum to the monolithic
    /// whole-sequence causal backward (one `attn_bwd_diag` over N).
    #[test]
    fn chunked_backward_matches_monolithic() {
        let (h, kvh, p, c, d) = (4usize, 2usize, 3usize, 4usize, 3usize);
        let n = p * c;
        let mut rng = Rng::new(11);
        let q = rand3(&mut rng, [h, n, d]);
        let k = rand3(&mut rng, [kvh, n, d]);
        let v = rand3(&mut rng, [kvh, n, d]);
        let do_ = rand3(&mut rng, [h, n, d]);
        let kk = HostKernels;
        let fwd = kk
            .run("full_attn_ref", &[q.clone().into(), k.clone().into(), v.clone().into()])
            .unwrap();
        let (o, lse) = (&fwd[0], &fwd[1]);
        let mono = kk
            .run(
                "attn_bwd_diag",
                &[
                    q.clone().into(),
                    k.clone().into(),
                    v.clone().into(),
                    o.clone().into(),
                    lse.clone().into(),
                    do_.clone().into(),
                ],
            )
            .unwrap();

        let qs = q.chunk_axis1(p);
        let ks = k.chunk_axis1(p);
        let vs = v.chunk_axis1(p);
        let os = o.chunk_axis1(p);
        let ls = lse.reshape(vec![h, n, 1]).chunk_axis1(p);
        let dos = do_.chunk_axis1(p);
        let mut dq: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[h, c, d])).collect();
        let mut dk: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[kvh, c, d])).collect();
        let mut dv: Vec<Tensor> = (0..p).map(|_| Tensor::zeros(&[kvh, c, d])).collect();
        for w in 0..p {
            for r in 0..=w {
                let kernel = if r == w { "attn_bwd_diag" } else { "attn_bwd_full" };
                let g = kk
                    .run(
                        kernel,
                        &[
                            qs[w].clone().into(),
                            ks[r].clone().into(),
                            vs[r].clone().into(),
                            os[w].clone().into(),
                            ls[w].reshape(vec![h, c]).into(),
                            dos[w].clone().into(),
                        ],
                    )
                    .unwrap();
                dq[w].add_assign(&g[0]);
                dk[r].add_assign(&g[1]);
                dv[r].add_assign(&g[2]);
            }
        }
        assert!(Tensor::cat_axis1(&dq).max_abs_diff(&mono[0]) < 1e-5);
        assert!(Tensor::cat_axis1(&dk).max_abs_diff(&mono[1]) < 1e-5);
        assert!(Tensor::cat_axis1(&dv).max_abs_diff(&mono[2]) < 1e-5);
    }

    #[test]
    fn null_kernels_echo_shapes_zero_copy() {
        let q = Tensor::zeros(&[2, 4, 3]);
        let k = Tensor::zeros(&[1, 4, 3]);
        let m = Tensor::zeros(&[2, 4]);
        let out = NullKernels
            .run(
                "attn_fwd_full",
                &[
                    q.clone().into(),
                    k.clone().into(),
                    k.clone().into(),
                    q.clone().into(),
                    m.clone().into(),
                    m.clone().into(),
                ],
            )
            .unwrap();
        assert_eq!(out[0].shape, q.shape);
        assert!(out[0].shares_buffer(&q), "null kernel outputs are refcount bumps");
        assert!(NullKernels.run("nope", &[]).is_err());
    }
}
