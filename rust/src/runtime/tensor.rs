//! Host tensors: the currency of the coordinator.
//!
//! Workers exchange these over channels (the NCCL-P2P substitute) and feed
//! them to PJRT executables. Everything on the coordinator hot path is
//! `f32`; token ids are `i32` (the only integer inputs any artifact takes).

use xla::Literal;

/// Dense row-major f32 host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Filled with `v` (e.g. `f32::NEG_INFINITY` for the `m` statistic).
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn as_scalar(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "not a scalar: shape {:?}", self.shape);
        self.data[0]
    }

    /// Elementwise accumulate (gradient reduction on the host).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Max |a - b|; panics on shape mismatch. Used by verification paths.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "max_abs_diff shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Split axis-0 into `n` equal chunks (sequence sharding).
    pub fn chunk0(&self, n: usize) -> Vec<Tensor> {
        assert!(!self.shape.is_empty() && self.shape[0] % n == 0);
        let rows = self.shape[0] / n;
        let stride: usize = self.shape[1..].iter().product::<usize>().max(1) * rows;
        let mut shape = self.shape.clone();
        shape[0] = rows;
        (0..n)
            .map(|i| Tensor::new(shape.clone(), self.data[i * stride..(i + 1) * stride].to_vec()))
            .collect()
    }

    /// Concatenate along axis 0 (inverse of `chunk0`).
    pub fn cat0(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|t| t.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(p.shape[1..], parts[0].shape[1..], "cat0 trailing dims differ");
            data.extend_from_slice(&p.data);
        }
        Tensor::new(shape, data)
    }

    /// Split axis-1 of a rank-3 tensor (H, N, D) into `n` chunks of the N
    /// axis — the layout used to shard per-head q/k/v across workers.
    pub fn chunk_axis1(&self, n: usize) -> Vec<Tensor> {
        assert_eq!(self.shape.len(), 3);
        let (h, c, d) = (self.shape[0], self.shape[1], self.shape[2]);
        assert_eq!(c % n, 0);
        let rows = c / n;
        let mut out = vec![Vec::with_capacity(h * rows * d); n];
        for hh in 0..h {
            for i in 0..n {
                let start = hh * c * d + i * rows * d;
                out[i].extend_from_slice(&self.data[start..start + rows * d]);
            }
        }
        out.into_iter()
            .map(|data| Tensor::new(vec![h, rows, d], data))
            .collect()
    }

    /// Ragged split of axis 1 at explicit token boundaries — the varlen
    /// (document-packed) sharding. `bounds` holds `n + 1` monotone offsets
    /// covering the axis exactly; chunk `i` gets rows
    /// `bounds[i]..bounds[i+1]`. `cat_axis1` is the inverse.
    pub fn chunk_axis1_at(&self, bounds: &[usize]) -> Vec<Tensor> {
        assert_eq!(self.shape.len(), 3);
        let (h, c, d) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(bounds.len() >= 2);
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), c);
        let n = bounds.len() - 1;
        let mut out: Vec<Vec<f32>> = bounds
            .windows(2)
            .map(|w| Vec::with_capacity(h * (w[1] - w[0]) * d))
            .collect();
        for hh in 0..h {
            for i in 0..n {
                let start = hh * c * d + bounds[i] * d;
                let end = hh * c * d + bounds[i + 1] * d;
                out[i].extend_from_slice(&self.data[start..end]);
            }
        }
        out.into_iter()
            .enumerate()
            .map(|(i, data)| Tensor::new(vec![h, bounds[i + 1] - bounds[i], d], data))
            .collect()
    }

    /// Concatenate rank-3 tensors along axis 1 (inverse of `chunk_axis1`).
    pub fn cat_axis1(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let h = parts[0].shape[0];
        let d = parts[0].shape[2];
        let c: usize = parts.iter().map(|t| t.shape[1]).sum();
        let mut data = Vec::with_capacity(h * c * d);
        for hh in 0..h {
            for p in parts {
                let rows = p.shape[1];
                let start = hh * rows * d;
                data.extend_from_slice(&p.data[start..start + rows * d]);
            }
        }
        Tensor::new(vec![h, c, d], data)
    }

    pub fn to_literal(&self) -> xla::Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        if dims.is_empty() {
            return Ok(Literal::scalar(self.data[0]));
        }
        Literal::vec1(&self.data).reshape(&dims)
    }

    pub fn from_literal(lit: &Literal) -> xla::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }
}

/// Dense row-major i32 host tensor (token ids / targets).
#[derive(Clone, Debug, PartialEq)]
pub struct ITensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl ITensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        ITensor { shape, data }
    }

    pub fn chunk0(&self, n: usize) -> Vec<ITensor> {
        assert!(self.shape.len() == 1 && self.shape[0] % n == 0);
        let rows = self.shape[0] / n;
        (0..n)
            .map(|i| ITensor::new(vec![rows], self.data[i * rows..(i + 1) * rows].to_vec()))
            .collect()
    }

    pub fn to_literal(&self) -> xla::Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(&self.data).reshape(&dims)
    }
}

/// An input value for an artifact call.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn to_literal(&self) -> xla::Result<Literal> {
        match self {
            Value::F32(t) => t.to_literal(),
            Value::I32(t) => t.to_literal(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<ITensor> for Value {
    fn from(t: ITensor) -> Self {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_cat_roundtrip() {
        let t = Tensor::new(vec![4, 3], (0..12).map(|x| x as f32).collect());
        let parts = t.chunk0(2);
        assert_eq!(parts[0].shape, vec![2, 3]);
        assert_eq!(parts[1].data[0], 6.0);
        assert_eq!(Tensor::cat0(&parts), t);
    }

    #[test]
    fn chunk_axis1_roundtrip() {
        // (2 heads, 4 tokens, 3 dim)
        let t = Tensor::new(vec![2, 4, 3], (0..24).map(|x| x as f32).collect());
        let parts = t.chunk_axis1(2);
        assert_eq!(parts[0].shape, vec![2, 2, 3]);
        // head 0 rows 0-1 then head 1 rows 0-1
        assert_eq!(parts[0].data[0], 0.0);
        assert_eq!(parts[0].data[6], 12.0);
        assert_eq!(Tensor::cat_axis1(&parts), t);
    }

    #[test]
    fn add_assign_and_diff() {
        let mut a = Tensor::zeros(&[2, 2]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        a.add_assign(&b);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        a.scale(2.0);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
