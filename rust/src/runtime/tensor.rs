//! Host tensors: the currency of the coordinator.
//!
//! Workers exchange these over channels (the NCCL-P2P substitute) and feed
//! them to PJRT executables. Everything on the coordinator hot path is
//! `f32`; token ids are `i32` (the only integer inputs any artifact takes).
//!
//! ## Zero-copy fabric
//!
//! `Tensor` storage is a shared `Arc<Vec<f32>>`, so `clone()` is a
//! refcount bump plus the (tiny) shape vector — `WorkerComm::send` of a
//! whole (k, v) chunk allocates nothing and copies nothing. Mutation goes
//! through [`Tensor::data_mut`], which is copy-on-write (`Arc::make_mut`):
//! a tensor whose buffer is shared, or which is a borrowed *view* of a
//! larger buffer, privatizes its window first, so aliasing is never
//! observable through the public API.
//!
//! Views are contiguous windows (`off .. off + numel`) of a parent buffer:
//! [`Tensor::chunk0`], [`Tensor::flat_view`], [`Tensor::reshape`], and the
//! axis-1 chunkers when the head axis is 1 all return non-materializing
//! slices. Axis-1 chunks of a multi-head tensor interleave head-major rows
//! and are necessarily copies.
//!
//! ## Panics
//!
//! Shape/rank preconditions on these methods are *caller bugs* and panic
//! with the offending shapes in the message. Runtime failures (peer loss,
//! recv timeouts, kernel errors) are `Result`s at the comm/executor layer
//! (`coordinator::fault`), never tensor panics. View window arithmetic is
//! an internal invariant held by construction and only `debug_assert`ed.

use std::sync::Arc;

use xla::Literal;

/// Dense row-major f32 host tensor backed by shared, copy-on-write storage
/// (see the module docs).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    buf: Arc<Vec<f32>>,
    off: usize,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data len {}",
            data.len()
        );
        Tensor { shape, buf: Arc::new(data), off: 0 }
    }

    /// Window of `buf` starting at `off`, sized by `shape`. In-bounds by
    /// construction at every call site (internal invariant).
    fn view_of(buf: Arc<Vec<f32>>, shape: Vec<usize>, off: usize) -> Self {
        debug_assert!(
            off + shape.iter().product::<usize>() <= buf.len(),
            "view window {off}..+{shape:?} out of bounds for buffer of {} (internal invariant)",
            buf.len()
        );
        Tensor { shape, buf, off }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor::new(shape.to_vec(), vec![0.0; n])
    }

    /// Filled with `v` (e.g. `f32::NEG_INFINITY` for the `m` statistic).
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor::new(shape.to_vec(), vec![v; n])
    }

    pub fn scalar(v: f32) -> Self {
        Tensor::new(vec![], vec![v])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// The elements, row-major. Always contiguous (views are windows).
    pub fn data(&self) -> &[f32] {
        &self.buf[self.off..self.off + self.numel()]
    }

    /// Mutable elements — copy-on-write: a shared or view-backed buffer is
    /// privatized first, so writes never alias another tensor.
    pub fn data_mut(&mut self) -> &mut [f32] {
        let n = self.numel();
        if self.off != 0 || self.buf.len() != n {
            // view of a larger buffer: materialize just the window
            let owned: Vec<f32> = self.data().to_vec();
            self.buf = Arc::new(owned);
            self.off = 0;
        }
        Arc::make_mut(&mut self.buf).as_mut_slice()
    }

    /// Force a private, tightly-sized allocation. Models the pre-zero-copy
    /// send path in the executor micro-bench, and detaches a small view
    /// from a large parent buffer it would otherwise keep alive.
    pub fn deep_clone(&self) -> Tensor {
        Tensor::new(self.shape.clone(), self.data().to_vec())
    }

    /// Whether two tensors share one underlying allocation (zero-copy
    /// assertions in tests and benches).
    pub fn shares_buffer(&self, other: &Tensor) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// Zero-copy reshape: same elements, new shape.
    pub fn reshape(&self, shape: Vec<usize>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        Tensor::view_of(self.buf.clone(), shape, self.off)
    }

    /// Zero-copy rank-1 window over the row-major elements.
    pub fn flat_view(&self, range: std::ops::Range<usize>) -> Tensor {
        assert!(range.start <= range.end && range.end <= self.numel());
        Tensor::view_of(
            self.buf.clone(),
            vec![range.end - range.start],
            self.off + range.start,
        )
    }

    pub fn as_scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "not a scalar: shape {:?}", self.shape);
        self.data()[0]
    }

    /// Elementwise accumulate (gradient reduction on the host).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data_mut() {
            *a *= s;
        }
    }

    /// Max |a - b|; panics on shape mismatch. Used by verification paths.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "max_abs_diff shape mismatch");
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn l2_norm(&self) -> f32 {
        self.data().iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Split axis-0 into `n` equal chunks (sequence sharding) — zero-copy
    /// views into the parent buffer.
    pub fn chunk0(&self, n: usize) -> Vec<Tensor> {
        assert!(
            !self.shape.is_empty() && n > 0 && self.shape[0] % n == 0,
            "chunk0: cannot split axis 0 of shape {:?} into {n} equal chunks",
            self.shape
        );
        let rows = self.shape[0] / n;
        let stride: usize = self.shape[1..].iter().product::<usize>().max(1) * rows;
        let mut shape = self.shape.clone();
        shape[0] = rows;
        (0..n)
            .map(|i| Tensor::view_of(self.buf.clone(), shape.clone(), self.off + i * stride))
            .collect()
    }

    /// Concatenate along axis 0 (inverse of `chunk0`).
    pub fn cat0(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "cat0 of zero tensors");
        assert!(!parts[0].shape.is_empty(), "cat0 needs rank >= 1 parts, got a scalar");
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|t| t.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(
                p.shape[1..],
                parts[0].shape[1..],
                "cat0: trailing dims of {:?} differ from {:?}",
                p.shape,
                parts[0].shape
            );
            data.extend_from_slice(p.data());
        }
        Tensor::new(shape, data)
    }

    /// Split axis-1 of a rank-3 tensor (H, N, D) into `n` chunks of the N
    /// axis — the layout used to shard per-head q/k/v across workers.
    /// Zero-copy when H == 1 (the chunks are contiguous windows).
    pub fn chunk_axis1(&self, n: usize) -> Vec<Tensor> {
        assert_eq!(
            self.shape.len(),
            3,
            "chunk_axis1 needs a rank-3 (H, N, D) tensor, got shape {:?}",
            self.shape
        );
        let (h, c, d) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(
            n > 0 && c % n == 0,
            "chunk_axis1: axis 1 of shape {:?} does not split into {n} equal chunks",
            self.shape
        );
        let rows = c / n;
        if h == 1 {
            return (0..n)
                .map(|i| {
                    Tensor::view_of(
                        self.buf.clone(),
                        vec![1, rows, d],
                        self.off + i * rows * d,
                    )
                })
                .collect();
        }
        let src = self.data();
        let mut out = vec![Vec::with_capacity(h * rows * d); n];
        for hh in 0..h {
            for (i, chunk) in out.iter_mut().enumerate() {
                let start = hh * c * d + i * rows * d;
                chunk.extend_from_slice(&src[start..start + rows * d]);
            }
        }
        out.into_iter()
            .map(|data| Tensor::new(vec![h, rows, d], data))
            .collect()
    }

    /// Ragged split of axis 1 at explicit token boundaries — the varlen
    /// (document-packed) sharding. `bounds` holds `n + 1` monotone offsets
    /// covering the axis exactly; chunk `i` gets rows
    /// `bounds[i]..bounds[i+1]`. `cat_axis1` is the inverse. Zero-copy
    /// when H == 1.
    pub fn chunk_axis1_at(&self, bounds: &[usize]) -> Vec<Tensor> {
        assert_eq!(
            self.shape.len(),
            3,
            "chunk_axis1_at needs a rank-3 (H, N, D) tensor, got shape {:?}",
            self.shape
        );
        let (h, c, d) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(
            bounds.len() >= 2 && bounds[0] == 0 && bounds[bounds.len() - 1] == c,
            "chunk_axis1_at: bounds {bounds:?} must run 0..={c} over axis 1 of shape {:?}",
            self.shape
        );
        assert!(
            bounds.windows(2).all(|w| w[0] <= w[1]),
            "chunk_axis1_at: bounds {bounds:?} must be monotone non-decreasing"
        );
        let n = bounds.len() - 1;
        if h == 1 {
            return bounds
                .windows(2)
                .map(|w| {
                    Tensor::view_of(
                        self.buf.clone(),
                        vec![1, w[1] - w[0], d],
                        self.off + w[0] * d,
                    )
                })
                .collect();
        }
        let src = self.data();
        let mut out: Vec<Vec<f32>> = bounds
            .windows(2)
            .map(|w| Vec::with_capacity(h * (w[1] - w[0]) * d))
            .collect();
        for hh in 0..h {
            for (i, chunk) in out.iter_mut().enumerate() {
                let start = hh * c * d + bounds[i] * d;
                let end = hh * c * d + bounds[i + 1] * d;
                chunk.extend_from_slice(&src[start..end]);
            }
        }
        out.into_iter()
            .enumerate()
            .map(|(i, data)| Tensor::new(vec![h, bounds[i + 1] - bounds[i], d], data))
            .collect()
    }

    /// Concatenate rank-3 tensors along axis 1 (inverse of `chunk_axis1`).
    pub fn cat_axis1(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "cat_axis1 of zero tensors");
        assert_eq!(
            parts[0].shape.len(),
            3,
            "cat_axis1 needs rank-3 (H, N, D) parts, got shape {:?}",
            parts[0].shape
        );
        let h = parts[0].shape[0];
        let d = parts[0].shape[2];
        for p in parts {
            assert!(
                p.shape.len() == 3 && p.shape[0] == h && p.shape[2] == d,
                "cat_axis1: part shape {:?} disagrees with {:?} on (H, _, D)",
                p.shape,
                parts[0].shape
            );
        }
        let c: usize = parts.iter().map(|t| t.shape[1]).sum();
        let mut data = Vec::with_capacity(h * c * d);
        for hh in 0..h {
            for p in parts {
                let rows = p.shape[1];
                let start = hh * rows * d;
                data.extend_from_slice(&p.data()[start..start + rows * d]);
            }
        }
        Tensor::new(vec![h, c, d], data)
    }

    pub fn to_literal(&self) -> xla::Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        if dims.is_empty() {
            return Ok(Literal::scalar(self.data()[0]));
        }
        Literal::vec1(self.data()).reshape(&dims)
    }

    pub fn from_literal(lit: &Literal) -> xla::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }
}

/// Dense row-major i32 host tensor (token ids / targets).
#[derive(Clone, Debug, PartialEq)]
pub struct ITensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl ITensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "ITensor shape {shape:?} does not match data len {}",
            data.len()
        );
        ITensor { shape, data }
    }

    pub fn chunk0(&self, n: usize) -> Vec<ITensor> {
        assert!(
            self.shape.len() == 1 && n > 0 && self.shape[0] % n == 0,
            "ITensor::chunk0: cannot split shape {:?} into {n} equal chunks",
            self.shape
        );
        let rows = self.shape[0] / n;
        (0..n)
            .map(|i| ITensor::new(vec![rows], self.data[i * rows..(i + 1) * rows].to_vec()))
            .collect()
    }

    pub fn to_literal(&self) -> xla::Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(&self.data).reshape(&dims)
    }
}

/// An input value for an artifact call.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn to_literal(&self) -> xla::Result<Literal> {
        match self {
            Value::F32(t) => t.to_literal(),
            Value::I32(t) => t.to_literal(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<ITensor> for Value {
    fn from(t: ITensor) -> Self {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_cat_roundtrip() {
        let t = Tensor::new(vec![4, 3], (0..12).map(|x| x as f32).collect());
        let parts = t.chunk0(2);
        assert_eq!(parts[0].shape, vec![2, 3]);
        assert_eq!(parts[1].data()[0], 6.0);
        assert_eq!(Tensor::cat0(&parts), t);
    }

    #[test]
    fn chunk_axis1_roundtrip() {
        // (2 heads, 4 tokens, 3 dim)
        let t = Tensor::new(vec![2, 4, 3], (0..24).map(|x| x as f32).collect());
        let parts = t.chunk_axis1(2);
        assert_eq!(parts[0].shape, vec![2, 2, 3]);
        // head 0 rows 0-1 then head 1 rows 0-1
        assert_eq!(parts[0].data()[0], 0.0);
        assert_eq!(parts[0].data()[6], 12.0);
        assert_eq!(Tensor::cat_axis1(&parts), t);
    }

    #[test]
    fn add_assign_and_diff() {
        let mut a = Tensor::zeros(&[2, 2]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        a.add_assign(&b);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        a.scale(2.0);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn clone_is_zero_copy_and_cow_unshares() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = a.clone();
        assert!(a.shares_buffer(&b), "clone must share storage");
        b.data_mut()[0] = 9.0;
        assert!(!a.shares_buffer(&b), "write must privatize");
        assert_eq!(a.data()[0], 1.0, "original untouched by CoW write");
        assert_eq!(b.data()[0], 9.0);
        assert!(!a.deep_clone().shares_buffer(&a));
    }

    #[test]
    fn chunk0_views_share_until_written() {
        let t = Tensor::new(vec![4, 3], (0..12).map(|x| x as f32).collect());
        let mut parts = t.chunk0(2);
        assert!(parts[0].shares_buffer(&t));
        assert_eq!(parts[1].data(), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        parts[1].data_mut()[0] = -1.0;
        assert!(!parts[1].shares_buffer(&t), "mutated view privatizes");
        assert_eq!(t.data()[6], 6.0, "parent untouched");
        assert_eq!(parts[1].data()[0], -1.0);
        assert_eq!(parts[1].numel(), 6);
    }

    #[test]
    fn single_head_axis1_chunks_are_views() {
        let t = Tensor::new(vec![1, 6, 2], (0..12).map(|x| x as f32).collect());
        let parts = t.chunk_axis1(3);
        assert!(parts.iter().all(|p| p.shares_buffer(&t)));
        assert_eq!(Tensor::cat_axis1(&parts), t);
        let ragged = t.chunk_axis1_at(&[0, 1, 4, 6]);
        assert!(ragged.iter().all(|p| p.shares_buffer(&t)));
        assert_eq!(ragged[1].shape, vec![1, 3, 2]);
        assert_eq!(Tensor::cat_axis1(&ragged), t);
    }

    #[test]
    fn reshape_and_flat_view() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(vec![3, 2]);
        assert!(r.shares_buffer(&t));
        assert_eq!(r.data(), t.data());
        let w = t.flat_view(2..5);
        assert!(w.shares_buffer(&t));
        assert_eq!(w.shape, vec![3]);
        assert_eq!(w.data(), &[2.0, 3.0, 4.0]);
        // view of a view composes
        assert_eq!(w.flat_view(1..3).data(), &[3.0, 4.0]);
    }
}
