//! Artifact manifest: the shape/dtype contract between the python AOT
//! pipeline (`python/compile/aot.py`) and the rust runtime.
//!
//! `manifest.json` is the only thing rust ever reads from python land; the
//! HLO files it references are opaque blobs handed to PJRT. Parsed with the
//! in-tree JSON parser (`util::json`) — the environment is offline, serde
//! is unavailable.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Mirror of `ModelConfig.to_json()` on the python side.
#[derive(Debug, Clone)]
pub struct ModelConfigJson {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub chunk_len: usize,
    pub n_workers: usize,
    pub block: usize,
    pub head_dim: usize,
    pub seq_len: usize,
    pub n_params: usize,
    pub export_ref_grads: bool,
}

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub sha256: String,
}

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfigJson,
    pub layer_params: Vec<ParamMeta>,
    pub global_params: Vec<ParamMeta>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.at(key)
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: missing/invalid integer field {key:?}"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.at(key)
        .as_str()
        .ok_or_else(|| anyhow!("manifest: missing string field {key:?}"))?
        .to_string())
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let name = req_str(j, "name")?;
    let shape = j.at("shape").as_usize_vec().ok_or_else(|| {
        anyhow!("manifest: tensor {name:?} has a bad shape (want an array of non-negative ints)")
    })?;
    let dtype = req_str(j, "dtype")?;
    Ok(TensorMeta { name, shape, dtype })
}

fn param_meta(j: &Json) -> Result<ParamMeta> {
    let name = req_str(j, "name")?;
    let shape = j
        .at("shape")
        .as_usize_vec()
        .ok_or_else(|| anyhow!("manifest: param {name:?} has a bad shape"))?;
    Ok(ParamMeta { name, shape })
}

impl Manifest {
    /// Load `dir/manifest.json`; `dir` is e.g. `artifacts/tiny`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let c = j.at("config");
        let config = ModelConfigJson {
            name: req_str(c, "name")?,
            vocab: req_usize(c, "vocab")?,
            n_layers: req_usize(c, "n_layers")?,
            d_model: req_usize(c, "d_model")?,
            n_heads: req_usize(c, "n_heads")?,
            n_kv_heads: req_usize(c, "n_kv_heads")?,
            d_ff: req_usize(c, "d_ff")?,
            chunk_len: req_usize(c, "chunk_len")?,
            n_workers: req_usize(c, "n_workers")?,
            block: req_usize(c, "block")?,
            head_dim: req_usize(c, "head_dim")?,
            seq_len: req_usize(c, "seq_len")?,
            n_params: req_usize(c, "n_params")?,
            export_ref_grads: c.at("export_ref_grads").as_bool().unwrap_or(false),
        };

        let mut layer_params = Vec::new();
        for (i, p) in j
            .at("layer_params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: layer_params not an array"))?
            .iter()
            .enumerate()
        {
            layer_params.push(param_meta(p).with_context(|| format!("layer_params[{i}]"))?);
        }
        let mut global_params = Vec::new();
        for (i, p) in j
            .at("global_params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: global_params not an array"))?
            .iter()
            .enumerate()
        {
            global_params.push(param_meta(p).with_context(|| format!("global_params[{i}]"))?);
        }

        let mut artifacts = BTreeMap::new();
        let arts = j
            .at("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: artifacts not an object"))?;
        for (name, a) in arts {
            let mut inputs = Vec::new();
            for t in a.at("inputs").as_arr().unwrap_or(&[]) {
                inputs.push(tensor_meta(t).with_context(|| format!("artifact {name:?} inputs"))?);
            }
            let mut outputs = Vec::new();
            for t in a.at("outputs").as_arr().unwrap_or(&[]) {
                outputs
                    .push(tensor_meta(t).with_context(|| format!("artifact {name:?} outputs"))?);
            }
            if inputs.is_empty() || outputs.is_empty() {
                bail!("manifest: artifact {name:?} missing inputs/outputs");
            }
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: req_str(a, "file").with_context(|| format!("artifact {name:?}"))?,
                    inputs,
                    outputs,
                    // advisory: absent in hand-written fixtures
                    sha256: a.at("sha256").as_str().unwrap_or("").to_string(),
                },
            );
        }

        Ok(Manifest {
            config,
            layer_params,
            global_params,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({:?})", self.dir))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Flat parameter table in the order `full_model_*` oracles expect:
    /// every layer's params (manifest order) then the global params.
    pub fn flat_param_table(&self) -> Vec<ParamMeta> {
        let mut out = Vec::new();
        for i in 0..self.config.n_layers {
            for p in &self.layer_params {
                out.push(ParamMeta {
                    name: format!("L{i}.{}", p.name),
                    shape: p.shape.clone(),
                });
            }
        }
        out.extend(self.global_params.iter().cloned());
        out
    }
}

// ---------------------------------------------------------------------------
// Step-state persistence (the trainer's survivable checkpoint)
// ---------------------------------------------------------------------------

use crate::runtime::Tensor;

/// Magic header of the little-endian f32 tensor container written by
/// [`save_tensor_bin`].
const TENSOR_MAGIC: &[u8; 4] = b"DFT0";

/// Write one tensor: `"DFT0"`, u32 rank, u64 dims, then the f32 payload,
/// all little-endian. The format is deliberately dumb — a crashed run's
/// state must be readable by a fresh process with no context.
pub fn save_tensor_bin(path: &Path, t: &Tensor) -> Result<()> {
    let mut buf = Vec::with_capacity(4 + 4 + 8 * t.shape.len() + 4 * t.numel());
    buf.extend_from_slice(TENSOR_MAGIC);
    buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
    for &d in &t.shape {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for &x in t.data() {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, buf).with_context(|| format!("writing tensor to {path:?}"))
}

/// Read a [`save_tensor_bin`] container back, bit-exact.
pub fn load_tensor_bin(path: &Path) -> Result<Tensor> {
    let buf = std::fs::read(path).with_context(|| format!("reading tensor from {path:?}"))?;
    let fail = |what: &str| anyhow!("{path:?}: {what}");
    if buf.len() < 8 || &buf[..4] != TENSOR_MAGIC {
        bail!(fail("not a DFT0 tensor file"));
    }
    let rank = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let mut off = 8;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        let end = off + 8;
        if buf.len() < end {
            bail!(fail("truncated shape header"));
        }
        shape.push(u64::from_le_bytes(buf[off..end].try_into().unwrap()) as usize);
        off = end;
    }
    let numel: usize = shape.iter().product();
    if buf.len() != off + 4 * numel {
        bail!(fail("payload length does not match the declared shape"));
    }
    let mut data = Vec::with_capacity(numel);
    for i in 0..numel {
        let b = off + 4 * i;
        data.push(f32::from_le_bytes(buf[b..b + 4].try_into().unwrap()));
    }
    Ok(Tensor::new(shape, data))
}

/// One training step's survivable state: the step index plus named
/// tensors — parameters, optimizer moments, and the `RematAware`
/// `(o, lse)` attention artifacts the ckpt IR marks as what a restarted
/// rank needs. Written as a `state.json` index plus one `.bin` container
/// per tensor, so a respawned process resumes from `step + 1` with
/// bit-identical state.
#[derive(Debug, Clone, Default)]
pub struct StepState {
    /// Last fully completed optimizer step.
    pub step: usize,
    /// `(name, tensor)` in save order; names follow the ckpt IR
    /// (`param.{i}`, `adam.m.{i}`, `adam.v.{i}`, `adam.t`,
    /// `ckpt.L{layer}.o`, `ckpt.L{layer}.lse`).
    pub tensors: Vec<(String, Tensor)>,
}

impl StepState {
    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Persist into `dir` (created if missing). The JSON index is written
    /// last, to a temp file renamed into place, so a crash mid-save never
    /// leaves a loadable-but-torn state behind.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating state dir {dir:?}"))?;
        let mut entries = Vec::with_capacity(self.tensors.len());
        for (i, (name, t)) in self.tensors.iter().enumerate() {
            let file = format!("t{i}.bin");
            save_tensor_bin(&dir.join(&file), t)?;
            entries.push(format!(
                "{{\"name\": \"{}\", \"file\": \"{file}\"}}",
                crate::util::json::escape(name)
            ));
        }
        let index = format!(
            "{{\n  \"step\": {},\n  \"tensors\": [{}]\n}}\n",
            self.step,
            entries.join(", ")
        );
        let tmp = dir.join("state.json.tmp");
        std::fs::write(&tmp, index).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, dir.join("state.json"))
            .with_context(|| format!("publishing {:?}", dir.join("state.json")))
    }

    /// Load the state saved in `dir`; `Ok(None)` when no state was ever
    /// published there (a fresh run, not an error).
    pub fn load(dir: &Path) -> Result<Option<StepState>> {
        let index = dir.join("state.json");
        if !index.exists() {
            return Ok(None);
        }
        let text =
            std::fs::read_to_string(&index).with_context(|| format!("reading {index:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {index:?}"))?;
        let step = req_usize(&j, "step").context("state.json")?;
        let mut tensors = Vec::new();
        for (i, e) in j
            .at("tensors")
            .as_arr()
            .ok_or_else(|| anyhow!("state.json: tensors not an array"))?
            .iter()
            .enumerate()
        {
            let name = req_str(e, "name").with_context(|| format!("state.json tensors[{i}]"))?;
            let file = req_str(e, "file").with_context(|| format!("state.json tensors[{i}]"))?;
            tensors.push((name, load_tensor_bin(&dir.join(&file))?));
        }
        Ok(Some(StepState { step, tensors }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name":"t","vocab":16,"n_layers":2,"d_model":8,"n_heads":2,
                 "n_kv_heads":2,"d_ff":16,"chunk_len":4,"n_workers":2,
                 "block":4,"head_dim":4,"seq_len":8,"n_params":123},
      "layer_params": [{"name":"ln1_g","shape":[8]}],
      "global_params": [{"name":"w_emb","shape":[16,8]}],
      "artifacts": {
        "f": {"file":"f.hlo.txt","inputs":[{"name":"x","shape":[4,8],"dtype":"f32"}],
              "outputs":[{"name":"out0","shape":[4,8],"dtype":"f32"}],"sha256":"x"}
      }
    }"#;

    fn sample() -> Manifest {
        let dir = std::env::temp_dir().join("distflash-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parse_manifest() {
        let m = sample();
        assert_eq!(m.config.n_workers, 2);
        assert!(!m.config.export_ref_grads);
        assert_eq!(m.artifacts["f"].inputs[0].shape, vec![4, 8]);
        assert_eq!(m.hlo_path("f").unwrap().file_name().unwrap(), "f.hlo.txt");
        assert!(m.artifact("missing").is_err());
    }

    #[test]
    fn flat_param_table_order() {
        let m = sample();
        let table = m.flat_param_table();
        assert_eq!(table.len(), 3); // 2 layers x 1 + 1 global
        assert_eq!(table[0].name, "L0.ln1_g");
        assert_eq!(table[1].name, "L1.ln1_g");
        assert_eq!(table[2].name, "w_emb");
    }

    #[test]
    fn step_state_roundtrips_bit_exact() {
        let dir = std::env::temp_dir().join("distflash-step-state-test");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(StepState::load(&dir).unwrap().is_none(), "no state yet");
        let state = StepState {
            step: 7,
            tensors: vec![
                ("param.0".to_string(), Tensor::new(vec![2, 3], vec![0.5, -1.25, 3.0, 0.0, 1e-8, -7.5])),
                ("adam.t".to_string(), Tensor::new(vec![1], vec![7.0])),
                ("ckpt.L0.o".to_string(), Tensor::new(vec![1, 2, 2], vec![1.0, 2.0, 3.0, 4.0])),
            ],
        };
        state.save(&dir).unwrap();
        let back = StepState::load(&dir).unwrap().expect("published state loads");
        assert_eq!(back.step, 7);
        assert_eq!(back.tensors.len(), 3);
        for (name, t) in &state.tensors {
            let b = back.tensor(name).expect("name survives");
            assert_eq!(b.shape, t.shape);
            // bit-exact, not approximately equal: resume must replay the
            // run the crashed process was on
            let eq = b.data().iter().zip(t.data()).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(eq, "{name} payload must round trip bit-exact");
        }
        assert!(back.tensor("missing").is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_tensor_bin_rejects_torn_files() {
        let dir = std::env::temp_dir().join("distflash-torn-tensor-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load_tensor_bin(&p).is_err());
        // right magic, truncated payload
        save_tensor_bin(&p, &Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0])).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 2]).unwrap();
        assert!(load_tensor_bin(&p).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
