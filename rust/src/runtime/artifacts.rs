//! Artifact manifest: the shape/dtype contract between the python AOT
//! pipeline (`python/compile/aot.py`) and the rust runtime.
//!
//! `manifest.json` is the only thing rust ever reads from python land; the
//! HLO files it references are opaque blobs handed to PJRT. Parsed with the
//! in-tree JSON parser (`util::json`) — the environment is offline, serde
//! is unavailable.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Mirror of `ModelConfig.to_json()` on the python side.
#[derive(Debug, Clone)]
pub struct ModelConfigJson {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub chunk_len: usize,
    pub n_workers: usize,
    pub block: usize,
    pub head_dim: usize,
    pub seq_len: usize,
    pub n_params: usize,
    pub export_ref_grads: bool,
}

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub sha256: String,
}

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfigJson,
    pub layer_params: Vec<ParamMeta>,
    pub global_params: Vec<ParamMeta>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.at(key)
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: missing/invalid integer field {key:?}"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.at(key)
        .as_str()
        .ok_or_else(|| anyhow!("manifest: missing string field {key:?}"))?
        .to_string())
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let name = req_str(j, "name")?;
    let shape = j.at("shape").as_usize_vec().ok_or_else(|| {
        anyhow!("manifest: tensor {name:?} has a bad shape (want an array of non-negative ints)")
    })?;
    let dtype = req_str(j, "dtype")?;
    Ok(TensorMeta { name, shape, dtype })
}

fn param_meta(j: &Json) -> Result<ParamMeta> {
    let name = req_str(j, "name")?;
    let shape = j
        .at("shape")
        .as_usize_vec()
        .ok_or_else(|| anyhow!("manifest: param {name:?} has a bad shape"))?;
    Ok(ParamMeta { name, shape })
}

impl Manifest {
    /// Load `dir/manifest.json`; `dir` is e.g. `artifacts/tiny`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let c = j.at("config");
        let config = ModelConfigJson {
            name: req_str(c, "name")?,
            vocab: req_usize(c, "vocab")?,
            n_layers: req_usize(c, "n_layers")?,
            d_model: req_usize(c, "d_model")?,
            n_heads: req_usize(c, "n_heads")?,
            n_kv_heads: req_usize(c, "n_kv_heads")?,
            d_ff: req_usize(c, "d_ff")?,
            chunk_len: req_usize(c, "chunk_len")?,
            n_workers: req_usize(c, "n_workers")?,
            block: req_usize(c, "block")?,
            head_dim: req_usize(c, "head_dim")?,
            seq_len: req_usize(c, "seq_len")?,
            n_params: req_usize(c, "n_params")?,
            export_ref_grads: c.at("export_ref_grads").as_bool().unwrap_or(false),
        };

        let mut layer_params = Vec::new();
        for (i, p) in j
            .at("layer_params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: layer_params not an array"))?
            .iter()
            .enumerate()
        {
            layer_params.push(param_meta(p).with_context(|| format!("layer_params[{i}]"))?);
        }
        let mut global_params = Vec::new();
        for (i, p) in j
            .at("global_params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: global_params not an array"))?
            .iter()
            .enumerate()
        {
            global_params.push(param_meta(p).with_context(|| format!("global_params[{i}]"))?);
        }

        let mut artifacts = BTreeMap::new();
        let arts = j
            .at("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: artifacts not an object"))?;
        for (name, a) in arts {
            let mut inputs = Vec::new();
            for t in a.at("inputs").as_arr().unwrap_or(&[]) {
                inputs.push(tensor_meta(t).with_context(|| format!("artifact {name:?} inputs"))?);
            }
            let mut outputs = Vec::new();
            for t in a.at("outputs").as_arr().unwrap_or(&[]) {
                outputs
                    .push(tensor_meta(t).with_context(|| format!("artifact {name:?} outputs"))?);
            }
            if inputs.is_empty() || outputs.is_empty() {
                bail!("manifest: artifact {name:?} missing inputs/outputs");
            }
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: req_str(a, "file").with_context(|| format!("artifact {name:?}"))?,
                    inputs,
                    outputs,
                    // advisory: absent in hand-written fixtures
                    sha256: a.at("sha256").as_str().unwrap_or("").to_string(),
                },
            );
        }

        Ok(Manifest {
            config,
            layer_params,
            global_params,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({:?})", self.dir))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Flat parameter table in the order `full_model_*` oracles expect:
    /// every layer's params (manifest order) then the global params.
    pub fn flat_param_table(&self) -> Vec<ParamMeta> {
        let mut out = Vec::new();
        for i in 0..self.config.n_layers {
            for p in &self.layer_params {
                out.push(ParamMeta {
                    name: format!("L{i}.{}", p.name),
                    shape: p.shape.clone(),
                });
            }
        }
        out.extend(self.global_params.iter().cloned());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name":"t","vocab":16,"n_layers":2,"d_model":8,"n_heads":2,
                 "n_kv_heads":2,"d_ff":16,"chunk_len":4,"n_workers":2,
                 "block":4,"head_dim":4,"seq_len":8,"n_params":123},
      "layer_params": [{"name":"ln1_g","shape":[8]}],
      "global_params": [{"name":"w_emb","shape":[16,8]}],
      "artifacts": {
        "f": {"file":"f.hlo.txt","inputs":[{"name":"x","shape":[4,8],"dtype":"f32"}],
              "outputs":[{"name":"out0","shape":[4,8],"dtype":"f32"}],"sha256":"x"}
      }
    }"#;

    fn sample() -> Manifest {
        let dir = std::env::temp_dir().join("distflash-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parse_manifest() {
        let m = sample();
        assert_eq!(m.config.n_workers, 2);
        assert!(!m.config.export_ref_grads);
        assert_eq!(m.artifacts["f"].inputs[0].shape, vec![4, 8]);
        assert_eq!(m.hlo_path("f").unwrap().file_name().unwrap(), "f.hlo.txt");
        assert!(m.artifact("missing").is_err());
    }

    #[test]
    fn flat_param_table_order() {
        let m = sample();
        let table = m.flat_param_table();
        assert_eq!(table.len(), 3); // 2 layers x 1 + 1 global
        assert_eq!(table[0].name, "L0.ln1_g");
        assert_eq!(table[1].name, "L1.ln1_g");
        assert_eq!(table[2].name, "w_emb");
    }
}
