//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! One `Runtime` per worker thread — `PjRtClient` is `Rc`-based (not
//! `Send`), which conveniently mirrors the real deployment: one process per
//! GPU, each owning its own device context, communicating through
//! host-visible buffers (here: channels).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`); see
//! DESIGN.md §3 for why serialized protos don't work with xla_extension
//! 0.5.1.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, ensure, Result};
use xla::{PjRtClient, PjRtLoadedExecutable};

use super::artifacts::Manifest;
use super::tensor::{Tensor, Value};

/// Cumulative executable-invocation statistics (perf accounting).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub kernel_nanos: u64,
}

pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a runtime over `artifacts/<config>/`; executables are compiled
    /// lazily on first use and cached for the lifetime of the runtime.
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile a set of artifacts (so timing loops exclude JIT).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with `inputs`, returning all outputs as f32
    /// host tensors. Inputs are validated against the manifest shapes.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let meta = self.manifest.artifact(name)?;
        ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: got {} inputs, manifest says {}",
            inputs.len(),
            meta.inputs.len()
        );
        for (v, m) in inputs.iter().zip(&meta.inputs) {
            ensure!(
                v.shape() == &m.shape[..],
                "{name}: input {:?} shape {:?} != manifest {:?}",
                m.name,
                v.shape(),
                m.shape
            );
        }
        // Build device buffers ourselves and use `execute_b`: the crate's
        // `execute` (literal path) leaks every input buffer it creates
        // internally (xla_rs.cc `release()`s them and never frees) — with
        // our call volume that's ~50 MB/step. Caller-owned `PjRtBuffer`s
        // drop correctly.
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|v| match v {
                Value::F32(t) => self.client.buffer_from_host_buffer(t.data(), &t.shape, None),
                Value::I32(t) => self.client.buffer_from_host_buffer(&t.data, &t.shape, None),
            })
            .collect::<xla::Result<_>>()
            .map_err(|e| anyhow!("{name}: uploading inputs: {e}"))?;

        let t0 = std::time::Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("compiled above");
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e}"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.calls += 1;
            s.kernel_nanos += t0.elapsed().as_nanos() as u64;
        }
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("{name}: untupling result: {e}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in &parts {
            out.push(
                Tensor::from_literal(p)
                    .map_err(|e| anyhow!("{name}: reading output: {e}"))?,
            );
        }
        ensure!(
            out.len() == meta.outputs.len(),
            "{name}: got {} outputs, manifest says {}",
            out.len(),
            meta.outputs.len()
        );
        Ok(out)
    }
}
