//! PJRT runtime layer: host tensors, the artifact manifest contract, and the
//! compile-once/execute-many client wrapper.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.

pub mod artifacts;
pub mod client;
pub mod tensor;

pub use artifacts::{Manifest, ModelConfigJson};
pub use client::{Runtime, RuntimeStats};
pub use tensor::{ITensor, Tensor, Value};
