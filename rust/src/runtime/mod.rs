//! PJRT runtime layer: host tensors, the artifact manifest contract, the
//! compile-once/execute-many client wrapper, and the kernel-backend seam
//! ([`Kernels`]) with the pure-host reference/null implementations.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.

pub mod artifacts;
pub mod client;
pub mod hostref;
pub mod kernel;
pub mod tensor;

pub use artifacts::{load_tensor_bin, save_tensor_bin, Manifest, ModelConfigJson, StepState};
pub use client::{Runtime, RuntimeStats};
pub use hostref::{HostKernels, KernelMode, Kernels, NullKernels};
pub use kernel::Tiles;
pub use tensor::{ITensor, Tensor, Value};
