//! DISTFLASHATTN reproduction — distributed memory-efficient attention for
//! long-context LLM training (Li & Shao et al., 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//! * L1/L2 (python, build-time only): Pallas flash-attention chunk kernels
//!   and the split transformer graph, AOT-lowered to `artifacts/*.hlo.txt`.
//! * L3 (this crate): schedules, the multi-worker executor, the cluster
//!   simulator with every paper baseline, the memory model, and the
//!   sequence-parallel trainer.
//!
//! Public API tour:
//! * [`coordinator::Session`] + [`coordinator::RunSpec`] — the front door:
//!   one declarative spec (workload / cluster / schedule / backend /
//!   optimize / trace) lowered once and driven through plan → optimize →
//!   execute → trace → calibrate. The pre-`Session` free functions in
//!   [`coordinator::harness`] are deprecated shims over this pipeline.
//! * [`coordinator::plan::Plan`] — the schedule IR: one op DAG consumed by
//!   the executor, the simulators, and the baseline comparisons alike.
//! * [`coordinator::optimize`] — the cost-model-driven plan optimizer:
//!   topology-aware rank→GPU placement, GQA-aware owner/helper role
//!   flipping, prefetch-depth autotuning, and token-level varlen
//!   rebalancing, every pass scored by the event engine and never worse
//!   than the default lowering.
//! * [`train::train`] — end-to-end sequence-parallel training with both
//!   checkpointing strategies, planned through the same `Session`.
//! * [`simulator`] — the lock-step reference engine plus the event-driven
//!   engine (per-worker compute/comm streams, per-link topology,
//!   configurable prefetch depth) over lowered plans.
//! * [`baselines`] — analytic iteration models for every system in the
//!   paper's evaluation, plus executed (event-engine) Ring Attention and
//!   Ulysses plans in the same IR.
//! * [`memory`] — activation/weight accounting and max-sequence solver.
//! * [`serving`] — continuous-batching decode on the same schedule IR:
//!   [`serving::ServeSpec`] → TGI-shaped scheduler over paged per-rank
//!   KV-caches → lockstep `Pass::Decode` plans scored by the event engine
//!   and replayed bit-exactly against a full-prefill oracle.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod memory;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod simulator;
pub mod train;
pub mod util;

pub use coordinator::{CkptStrategy, Pass, Plan, Schedule, ScheduleKind};
pub use runtime::{Manifest, Runtime, Tensor};
