//! End-to-end sequence-parallel training on the real runtime: synthetic
//! corpus, Adam, and the distributed trainer with both checkpointing
//! strategies. The numerics are pinned to the `full_model_grads` oracle in
//! `rust/tests/trainer_integration.rs`.

pub mod data;
pub mod optimizer;
pub mod trainer;

pub use data::MarkovCorpus;
pub use optimizer::{Adam, AdamConfig};
pub use trainer::{oracle_first_step, train, LayerTrace, StepLog, TrainConfig, TrainReport};
