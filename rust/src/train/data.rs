//! Synthetic corpus: a low-entropy order-1 Markov chain over the vocab.
//!
//! Structured enough that a causal LM's loss falls well below ln(V) within
//! a few hundred steps, deterministic by seed so every worker can generate
//! the same stream locally (no data broadcast needed — exactly how the
//! verification math wants it).

use crate::util::Rng;

pub struct MarkovCorpus {
    vocab: usize,
    /// next[token] = most likely successor.
    next: Vec<usize>,
    /// Probability of following the chain (vs a uniform random token).
    p_follow: f64,
    rng: Rng,
    state: usize,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let mut next = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            next.push(rng.below(vocab));
        }
        MarkovCorpus { vocab, next, p_follow: 0.9, rng, state: 0 }
    }

    /// Next `n + 1` tokens; `(inputs, targets)` = (t[..n], t[1..]).
    pub fn sample(&mut self, n: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(n + 1);
        toks.push(self.state as i32);
        for _ in 0..n {
            self.state = if (self.rng.f32() as f64) < self.p_follow {
                self.next[self.state]
            } else {
                self.rng.below(self.vocab)
            };
            toks.push(self.state as i32);
        }
        let inputs = toks[..n].to_vec();
        let targets = toks[1..].to_vec();
        (inputs, targets)
    }

    /// Entropy floor of the chain in nats (loss can't go below this).
    pub fn entropy_floor(&self) -> f64 {
        let p = self.p_follow + (1.0 - self.p_follow) / self.vocab as f64;
        let q = (1.0 - self.p_follow) / self.vocab as f64;
        -(p * p.ln() + (self.vocab - 1) as f64 * q * q.ln())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let (a, ta) = MarkovCorpus::new(256, 7).sample(100);
        let (b, tb) = MarkovCorpus::new(256, 7).sample(100);
        assert_eq!(a, b);
        assert_eq!(ta, tb);
        assert!(a.iter().all(|&t| (0..256).contains(&t)));
        // targets are inputs shifted by one
        assert_eq!(&a[1..], &ta[..99]);
        assert_eq!(tb.len(), 100);
    }

    #[test]
    fn chain_is_learnable() {
        let c = MarkovCorpus::new(256, 0);
        let floor = c.entropy_floor();
        let uniform = (256f64).ln();
        assert!(floor < uniform * 0.25, "floor {floor} vs uniform {uniform}");
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = MarkovCorpus::new(256, 1).sample(50);
        let (b, _) = MarkovCorpus::new(256, 2).sample(50);
        assert_ne!(a, b);
    }
}
