//! Sequence-parallel distributed trainer: the full DISTFLASHATTN training
//! loop over the AOT layer artifacts.
//!
//! P worker threads each own one sequence chunk and a full parameter
//! replica. Per layer: local `part1` (LN + QKV) → *distributed* attention
//! (the paper's contribution, over the channel fabric) → local `part2`
//! (proj + MLP). Gradients are summed with a ring all-reduce and Adam runs
//! identically everywhere (replicated params stay bit-identical — FSDP
//! sharding is modeled in `baselines`, not materialized here, since memory
//! pressure is not what the CPU testbed measures).
//!
//! The attention pipeline is configured by the [`RunSpec`] embedded in
//! [`TrainConfig::run`] and lowered through the same [`Session`] every
//! other entry point uses: schedule kind, optimizer policy (plans the
//! workers execute), document-packed batches (`RunSpec::varlen` — batch
//! token slices follow the spec's chunk boundaries), and per-layer
//! tracing (`RunSpec::trace` — every `attn_call` records spans against a
//! shared epoch, merged into [`TrainReport::layer_traces`]).
//!
//! Checkpointing strategies (paper §3.3) are lowered into the plan IR:
//! [`TrainConfig::ckpt`] is routed into `RunSpec::ckpt`, so the same
//! `Session` lowering every other entry point uses decides what backward
//! replays:
//! * `HfStyle`   — store layer input x; the backward plan carries a
//!   recompute prefix (`Plan::recompute_ops`) and the worker replays the
//!   distributed attention forward — same kernels, same wire traffic —
//!   before part2's backward consumes the rebuilt (o, lse).
//! * `RematAware` — additionally store (o, lse) at the FlashAttention
//!   output; the backward plan is prefix-free and re-runs only part1. No
//!   attention forward, no forward communication. Numerically identical
//!   (asserted in tests).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::comm::{build_network_placed, WorkerComm};
use crate::coordinator::executor::{AttnCtx, MergedTrace, PlanIndex, RunTrace, ATTN_ARTIFACTS};
use crate::coordinator::plan::Plan;
use crate::coordinator::session::{BackendSpec, RunSpec, Session, Workload};
use crate::coordinator::CkptStrategy;
use crate::runtime::{ITensor, Runtime, StepState, Tensor, Value};
use crate::train::data::MarkovCorpus;
use crate::train::optimizer::{Adam, AdamConfig};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// The attention pipeline spec: Pjrt backend (artifact dir), schedule
    /// kind, cluster + optimize policy, varlen batch layout, tracing.
    /// Workload and worker count resolve from the artifact manifest.
    pub run: RunSpec,
    pub ckpt: CkptStrategy,
    pub steps: usize,
    pub adam: AdamConfig,
    pub seed: u64,
    pub log_every: usize,
    /// When set, rank 0 persists survivable per-step state into this
    /// directory after every optimizer step — parameters, Adam moments,
    /// and the RematAware `(o, lse)` attention artifacts, named by the
    /// ckpt IR (`param.{i}`, `adam.m.{i}`, `adam.v.{i}`, `adam.t`,
    /// `ckpt.L{layer}.o`, `ckpt.L{layer}.lse`) — and [`train`] resumes
    /// from the last completed step found there. A resumed trajectory is
    /// bit-identical to an uninterrupted run.
    pub state_dir: Option<PathBuf>,
}

impl TrainConfig {
    pub fn new(artifact_dir: &Path) -> Self {
        TrainConfig {
            run: RunSpec::pjrt(artifact_dir, crate::coordinator::ScheduleKind::Balanced),
            ckpt: CkptStrategy::RematAware,
            steps: 20,
            adam: AdamConfig::default(),
            seed: 0,
            log_every: 1,
            state_dir: None,
        }
    }

    /// The artifact directory the embedded spec points at.
    pub fn artifact_dir(&self) -> Result<&Path> {
        match &self.run.backend {
            BackendSpec::Pjrt(dir) => Ok(dir),
            other => Err(anyhow!("the trainer needs a Pjrt backend, got {other:?}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub wall_s: f64,
    /// Global bytes moved during this step (attention + all-reduce).
    pub comm_bytes: u64,
}

/// One merged per-op timeline from the trainer's trace sink: attention
/// call of `layer` during the final training step, one row per pass.
#[derive(Clone, Debug)]
pub struct LayerTrace {
    pub layer: usize,
    /// `"fwd"`, `"bwd"`, or `"recompute"` (HF-style checkpointing only).
    pub pass: &'static str,
    pub trace: MergedTrace,
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub logs: Vec<StepLog>,
    pub kernel_calls: u64,
    pub kernel_s: f64,
    pub total_s: f64,
    /// Per-layer attention timelines of the final step, present when
    /// `TrainConfig::run.trace` is set.
    pub layer_traces: Vec<LayerTrace>,
}

/// Parameter layout helper: layer params in manifest order, then globals.
struct ParamLayout {
    n_layers: usize,
    per_layer: usize,
}

impl ParamLayout {
    fn layer(&self, l: usize, i: usize) -> usize {
        l * self.per_layer + i
    }

    fn global(&self, i: usize) -> usize {
        self.n_layers * self.per_layer + i
    }
}

/// Deterministic parameter init (every worker computes the same tensors).
fn init_params(rt: &Runtime, seed: u64) -> Vec<Tensor> {
    let m = rt.manifest();
    let cfg = &m.config;
    let mut rng = Rng::new(seed ^ 0x9A7A);
    let mut out = Vec::new();
    let std_scale = 0.02f32;
    for _l in 0..cfg.n_layers {
        for p in &m.layer_params {
            let n: usize = p.shape.iter().product();
            let t = if p.name.starts_with("ln") {
                Tensor::full(&p.shape, 1.0)
            } else {
                let mut data = rng.normal_vec(n);
                let s = if p.name == "w2" {
                    std_scale / (2.0 * cfg.n_layers as f32).sqrt()
                } else {
                    std_scale
                };
                for x in &mut data {
                    *x *= s;
                }
                Tensor::new(p.shape.clone(), data)
            };
            out.push(t);
        }
    }
    for p in &m.global_params {
        let n: usize = p.shape.iter().product();
        let t = if p.name.starts_with("ln") {
            Tensor::full(&p.shape, 1.0)
        } else {
            let mut data = rng.normal_vec(n);
            for x in &mut data {
                *x *= std_scale;
            }
            Tensor::new(p.shape.clone(), data)
        };
        out.push(t);
    }
    out
}

fn v(t: &Tensor) -> Value {
    Value::F32(t.clone())
}

/// Saved forward state for one layer (per checkpoint strategy).
struct LayerCkpt {
    x: Tensor,
    /// Present only under RematAware.
    attn: Option<(Tensor, Tensor)>, // (o, lse)
}

/// One span record pushed by a worker's `attn_call` into the shared sink.
struct LayerSpanRec {
    layer: usize,
    pass: &'static str,
    trace: RunTrace,
}

type TraceSink = Arc<Mutex<Vec<LayerSpanRec>>>;

struct Worker {
    rank: usize,
    runtime: Runtime,
    comm: WorkerComm,
    /// Lowered schedule IR, shared with the simulators (one per pass).
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    /// Pre-resolved op walks for this rank — built once, reused by every
    /// layer of every training step.
    fwd_idx: PlanIndex,
    bwd_idx: PlanIndex,
    cfg: TrainConfig,
    params: Vec<Tensor>,
    layout: ParamLayout,
    /// Shared tracing epoch (set iff `cfg.run.trace`): every attention
    /// call records per-op spans against it.
    trace_epoch: Option<Instant>,
    /// Where recorded spans go, keyed by (layer, pass) at merge time.
    trace_sink: Option<TraceSink>,
    /// Only this step's spans are recorded (the final step — warmed up).
    record_step: usize,
}

impl Worker {
    /// Names of per-layer params in manifest order (indices into layout).
    const LN1: usize = 0;
    const WQ: usize = 1;
    const WK: usize = 2;
    const WV: usize = 3;
    const WO: usize = 4;
    const LN2: usize = 5;
    const W1: usize = 6;
    const W3: usize = 7;
    const W2: usize = 8;
    const W_EMB: usize = 0;
    const LN_F: usize = 1;
    const W_HEAD: usize = 2;

    fn lp(&self, l: usize, i: usize) -> &Tensor {
        &self.params[self.layout.layer(l, i)]
    }

    fn gp(&self, i: usize) -> &Tensor {
        &self.params[self.layout.global(i)]
    }

    /// One distributed attention call: plan/index selection by pass, call
    /// id derived from (step, layer, pass), spans recorded against the
    /// shared epoch and pushed to the trace sink on the recorded step.
    fn attn_call(
        &mut self,
        step: usize,
        layer: usize,
        pass: Pass,
        f: impl FnOnce(&mut AttnCtx, &PlanIndex) -> Result<Vec<Tensor>>,
    ) -> Result<Vec<Tensor>> {
        // recompute walks the *backward* plan's prefix — the replayed
        // forward lives in the bwd lowering under HF-style checkpointing
        let (plan, idx) = if matches!(pass, Pass::Bwd | Pass::Recompute) {
            (self.bwd_plan.clone(), &self.bwd_idx)
        } else {
            (self.fwd_plan.clone(), &self.fwd_idx)
        };
        // stamp spans only on the recorded (final) step — earlier steps
        // would pay the clock reads just to throw the spans away
        let recording = step == self.record_step;
        let mut ctx = AttnCtx {
            rank: self.rank,
            runtime: &self.runtime,
            comm: &mut self.comm,
            plan: &plan,
            call_id: call_id(step, layer, pass),
            epoch: if recording { self.trace_epoch } else { None },
            trace: RunTrace::default(),
        };
        let out = f(&mut ctx, idx)?;
        let trace = ctx.trace;
        if recording {
            if let Some(sink) = &self.trace_sink {
                sink.lock()
                    .expect("trace sink poisoned")
                    .push(LayerSpanRec { layer, pass: pass.name(), trace });
            }
        }
        Ok(out)
    }

    /// One full forward over the local chunk; returns (loss_local, ckpts,
    /// final x) — loss_local already carries the 1/N global normalizer.
    fn forward(
        &mut self,
        step: usize,
        ids: &ITensor,
        targets: &ITensor,
        inv_total: f32,
    ) -> Result<(f32, Vec<LayerCkpt>, Tensor)> {
        let n_layers = self.layout.n_layers;
        let mut x = self
            .runtime
            .run("embed_fwd", &[Value::I32(ids.clone()), v(self.gp(Self::W_EMB))])?
            .remove(0);
        let mut ckpts = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let qkv = self.runtime.run(
                "part1_fwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                ],
            )?;
            let (q, k, vv) = (&qkv[0], &qkv[1], &qkv[2]);
            let out = self.attn_call(step, l, Pass::Fwd, |ctx, idx| {
                let (o, lse) = ctx.forward_indexed(idx, q, k, vv)?;
                Ok(vec![o, lse])
            })?;
            let (o, lse) = (out[0].clone(), out[1].clone());
            let y = self
                .runtime
                .run(
                    "part2_fwd",
                    &[
                        v(&x),
                        v(&o),
                        v(self.lp(l, Self::WO)),
                        v(self.lp(l, Self::LN2)),
                        v(self.lp(l, Self::W1)),
                        v(self.lp(l, Self::W3)),
                        v(self.lp(l, Self::W2)),
                    ],
                )?
                .remove(0);
            ckpts.push(LayerCkpt {
                x: x.clone(),
                attn: match self.cfg.ckpt {
                    CkptStrategy::RematAware => Some((o, lse)),
                    CkptStrategy::HfStyle => None,
                },
            });
            x = y;
        }
        let loss = self
            .runtime
            .run(
                "head_loss_fwd",
                &[
                    v(&x),
                    v(self.gp(Self::LN_F)),
                    v(self.gp(Self::W_HEAD)),
                    Value::I32(targets.clone()),
                    Value::F32(Tensor::scalar(inv_total)),
                ],
            )?[0]
            .as_scalar();
        Ok((loss, ckpts, x))
    }

    /// Full backward; returns grads aligned with `params`.
    fn backward(
        &mut self,
        step: usize,
        ids: &ITensor,
        targets: &ITensor,
        inv_total: f32,
        ckpts: Vec<LayerCkpt>,
        x_final: Tensor,
    ) -> Result<Vec<Tensor>> {
        let n_layers = self.layout.n_layers;
        let mut grads: Vec<Tensor> =
            self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        // head
        let head = self.runtime.run(
            "head_loss_bwd",
            &[
                v(&x_final),
                v(self.gp(Self::LN_F)),
                v(self.gp(Self::W_HEAD)),
                Value::I32(targets.clone()),
                Value::F32(Tensor::scalar(inv_total)),
            ],
        )?;
        // outputs: (loss, dx, dln_f, dw_head)
        let mut dy = head[1].clone();
        grads[self.layout.global(Self::LN_F)].add_assign(&head[2]);
        grads[self.layout.global(Self::W_HEAD)].add_assign(&head[3]);

        for l in (0..n_layers).rev() {
            let ck = &ckpts[l];
            let x = ck.x.clone();
            // part1 recompute (cheap; both strategies)
            let qkv = self.runtime.run(
                "part1_fwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                ],
            )?;
            let (q, k, vv) = (qkv[0].clone(), qkv[1].clone(), qkv[2].clone());
            // attention output: saved (ours) or rebuilt by replaying the
            // backward plan's recompute prefix with full comm (HF)
            let (o, lse) = match &ck.attn {
                Some((o, lse)) => (o.clone(), lse.clone()),
                None => {
                    let out = self.attn_call(step, l, Pass::Recompute, |ctx, idx| {
                        let (o, lse) = ctx.recompute_indexed(idx, &q, &k, &vv)?;
                        Ok(vec![o, lse])
                    })?;
                    (out[0].clone(), out[1].clone())
                }
            };
            // part2 backward
            let p2 = self.runtime.run(
                "part2_bwd",
                &[
                    v(&x),
                    v(&o),
                    v(self.lp(l, Self::WO)),
                    v(self.lp(l, Self::LN2)),
                    v(self.lp(l, Self::W1)),
                    v(self.lp(l, Self::W3)),
                    v(self.lp(l, Self::W2)),
                    v(&dy),
                ],
            )?;
            // outputs: (dx, d_attn_o, dwo, dln2, dw1, dw3, dw2)
            let dx_p2 = p2[0].clone();
            let d_o = p2[1].clone();
            grads[self.layout.layer(l, Self::WO)].add_assign(&p2[2]);
            grads[self.layout.layer(l, Self::LN2)].add_assign(&p2[3]);
            grads[self.layout.layer(l, Self::W1)].add_assign(&p2[4]);
            grads[self.layout.layer(l, Self::W3)].add_assign(&p2[5]);
            grads[self.layout.layer(l, Self::W2)].add_assign(&p2[6]);
            // distributed attention backward body (the recompute prefix,
            // when the plan has one, already ran above — §3.3)
            let attn_grads = self.attn_call(step, l, Pass::Bwd, |ctx, idx| {
                let (dq, dk, dv) =
                    ctx.backward_body_indexed(idx, &q, &k, &vv, &o, &lse, &d_o)?;
                Ok(vec![dq, dk, dv])
            })?;
            // part1 backward
            let p1 = self.runtime.run(
                "part1_bwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                    v(&attn_grads[0]),
                    v(&attn_grads[1]),
                    v(&attn_grads[2]),
                ],
            )?;
            // outputs: (dx, dln1, dwq, dwk, dwv)
            grads[self.layout.layer(l, Self::LN1)].add_assign(&p1[1]);
            grads[self.layout.layer(l, Self::WQ)].add_assign(&p1[2]);
            grads[self.layout.layer(l, Self::WK)].add_assign(&p1[3]);
            grads[self.layout.layer(l, Self::WV)].add_assign(&p1[4]);
            // dL/dx = residual path (part2's dx) + part1 path
            dy = dx_p2;
            dy.add_assign(&p1[0]);
        }

        // embedding
        let demb = self
            .runtime
            .run("embed_bwd", &[Value::I32(ids.clone()), v(&dy)])?
            .remove(0);
        grads[self.layout.global(Self::W_EMB)].add_assign(&demb);
        Ok(grads)
    }
}

#[derive(Clone, Copy)]
enum Pass {
    Fwd,
    Bwd,
    Recompute,
}

impl Pass {
    fn name(self) -> &'static str {
        match self {
            Pass::Fwd => "fwd",
            Pass::Bwd => "bwd",
            Pass::Recompute => "recompute",
        }
    }
}

/// Unique attention call id per (step, layer, pass) — keeps channel tags
/// from colliding across the whole run.
fn call_id(step: usize, layer: usize, pass: Pass) -> u32 {
    let p = match pass {
        Pass::Fwd => 0,
        Pass::Bwd => 1,
        Pass::Recompute => 2,
    };
    ((step as u32) << 12) | ((layer as u32) << 2) | p
}

/// Run distributed training; returns the rank-0 report.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let dir = cfg.artifact_dir()?.to_path_buf();
    let probe = Runtime::load(&dir)?;
    let mc = probe.manifest().config.clone();
    drop(probe);

    // one Session lowers (and, per the spec's policy, optimizes) the plans
    // every worker executes; fill the workload from the manifest we already
    // probed so Session::new does not load the runtime a second time
    let mut run_spec = cfg.run.clone();
    // the checkpoint strategy is part of the lowering now: route it into
    // the spec so the backward plan carries (or omits) the recompute prefix
    run_spec.ckpt = cfg.ckpt;
    if run_spec.workload.is_none() {
        run_spec.workload =
            Some(Workload::new(mc.n_heads, mc.n_kv_heads, mc.head_dim, mc.chunk_len));
    }
    if run_spec.n_workers == 0 {
        run_spec.n_workers = mc.n_workers;
    }
    let mut session = Session::new(run_spec)?;
    let p = session.n_workers();
    if p != mc.n_workers {
        bail!(
            "run spec declares {p} workers but the artifacts were compiled for {}",
            mc.n_workers
        );
    }
    let n = mc.seq_len;
    let (fwd_plan, bwd_plan) = session.plans()?;
    // per-rank token slices: manifest-equal chunks, or the document-packed
    // layout *the lowered plan actually carries* — a varlen optimize
    // policy may have rebalanced the cuts, and the data sharding must
    // follow the plan, not the spec it started from. Uniform cuts only:
    // the AOT artifacts compile one fixed chunk shape (document-masked
    // pair skipping still applies).
    let boundaries: Vec<usize> = match fwd_plan.varlen.as_deref() {
        Some(vspec) => {
            if vspec.total_tokens() != n {
                bail!(
                    "varlen spec covers {} tokens but the model trains on {n}",
                    vspec.total_tokens()
                );
            }
            let c0 = vspec.chunk_tokens(0);
            if !(1..p).all(|w| vspec.chunk_tokens(w) == c0) {
                bail!(
                    "ragged varlen boundaries need per-chunk AOT artifacts; pack with uniform \
                     boundaries (zero-weight chunk pairs are still skipped)"
                );
            }
            vspec.boundaries.clone()
        }
        None => (0..=p).map(|r| r * mc.chunk_len).collect(),
    };
    // bind rank i to the optimized plan's GPU slot (identity when not
    // optimizing) — the trainer-side analogue of the launcher consuming
    // `Plan::placement`
    let comms = build_network_placed(p, &fwd_plan.placement);

    // shared tracing epoch + sink: every worker's attn_call stamps spans
    // against the same clock, so per-layer timelines merge across ranks
    let trace_epoch = cfg.run.trace.then(Instant::now);
    let sink: TraceSink = Arc::new(Mutex::new(Vec::new()));
    let record_step = cfg.steps.saturating_sub(1);

    // survivable-state resume: if a previous (crashed) run persisted a
    // completed step, every rank restores the same replicated state, so
    // the resumed trajectory is bit-identical to an uninterrupted run
    let resume: Arc<Option<StepState>> = Arc::new(match &cfg.state_dir {
        Some(d) => StepState::load(d)
            .with_context(|| format!("loading persisted trainer state from {d:?}"))?,
        None => None,
    });
    let start_step = match resume.as_ref() {
        Some(st) => st.step + 1,
        None => 0,
    };

    let mut handles = Vec::new();
    for (rank, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        let fwd_plan = fwd_plan.clone();
        let bwd_plan = bwd_plan.clone();
        let boundaries = boundaries.clone();
        let trace_sink = cfg.run.trace.then(|| sink.clone());
        let resume = resume.clone();
        handles.push(thread::spawn(move || -> Result<Option<TrainReport>> {
            let runtime = Runtime::load(cfg.artifact_dir()?)?;
            runtime.precompile(ATTN_ARTIFACTS)?;
            runtime.precompile(&[
                "embed_fwd",
                "embed_bwd",
                "part1_fwd",
                "part1_bwd",
                "part2_fwd",
                "part2_bwd",
                "head_loss_fwd",
                "head_loss_bwd",
            ])?;
            let params = init_params(&runtime, cfg.seed);
            let layout = ParamLayout {
                n_layers: runtime.manifest().config.n_layers,
                per_layer: runtime.manifest().layer_params.len(),
            };
            // pre-resolve both plan walks once; every layer of every step
            // reuses them (dep lookups never repeat)
            let fwd_idx =
                PlanIndex::new(&fwd_plan, rank, crate::coordinator::plan::Pass::Forward)?;
            let bwd_idx =
                PlanIndex::new(&bwd_plan, rank, crate::coordinator::plan::Pass::Backward)?;
            let mut w = Worker {
                rank,
                runtime,
                comm,
                fwd_plan,
                bwd_plan,
                fwd_idx,
                bwd_idx,
                cfg: cfg.clone(),
                params,
                layout,
                trace_epoch,
                trace_sink,
                record_step,
            };
            let mut adam = match resume.as_ref() {
                Some(st) => restore_worker_state(st, &mut w.params, cfg.adam)?,
                None => Adam::new(cfg.adam, &w.params),
            };
            let mut corpus = MarkovCorpus::new(
                w.runtime.manifest().config.vocab,
                cfg.seed,
            );
            // a resumed run must see the batch sequence an uninterrupted
            // run would: fast-forward past the consumed samples
            for _ in 0..start_step {
                corpus.sample(n);
            }
            let inv_total = 1.0 / n as f32;
            let mut logs = Vec::new();
            let t_start = std::time::Instant::now();
            let persist = rank == 0 && cfg.state_dir.is_some();

            for step in start_step..cfg.steps {
                let t0 = std::time::Instant::now();
                // every worker generates the identical sequence, takes its
                // token slice (equal chunks, or the varlen boundaries)
                let (ids_full, tgts_full) = corpus.sample(n);
                let (lo, hi) = (boundaries[rank], boundaries[rank + 1]);
                let ids = ITensor::new(vec![hi - lo], ids_full[lo..hi].to_vec());
                let tgts = ITensor::new(vec![hi - lo], tgts_full[lo..hi].to_vec());

                let (loss_local, ckpts, x_final) =
                    w.forward(step, &ids, &tgts, inv_total)?;
                // harvest the RematAware (o, lse) artifacts before
                // backward consumes the checkpoint table (clones are
                // Arc-backed, not copies)
                let saved_attn: Vec<(usize, (Tensor, Tensor))> = if persist {
                    ckpts
                        .iter()
                        .enumerate()
                        .filter_map(|(l, c)| c.attn.clone().map(|a| (l, a)))
                        .collect()
                } else {
                    Vec::new()
                };
                let mut grads =
                    w.backward(step, &ids, &tgts, inv_total, ckpts, x_final)?;

                // global loss + gradient all-reduce
                let mut loss_t = Tensor::scalar(loss_local);
                let round_base = (step as u32) << 16;
                w.comm
                    .all_reduce_sum(round_base, &mut loss_t)
                    .map_err(|e| anyhow::anyhow!("rank {rank}: loss all-reduce failed: {e}"))?;
                for (i, g) in grads.iter_mut().enumerate() {
                    w.comm.all_reduce_sum(round_base + 1 + i as u32, g).map_err(|e| {
                        anyhow::anyhow!("rank {rank}: grad all-reduce {i} failed: {e}")
                    })?;
                }
                let gnorm = Adam::grad_norm(&grads);
                adam.step(&mut w.params, &grads);

                if persist {
                    let dir = cfg.state_dir.as_ref().expect("persist implies state_dir");
                    let mut tensors = Vec::new();
                    for (i, p) in w.params.iter().enumerate() {
                        tensors.push((format!("param.{i}"), p.clone()));
                    }
                    let (t_adam, ms, vs) = adam.state();
                    for (i, mt) in ms.iter().enumerate() {
                        tensors.push((format!("adam.m.{i}"), mt.clone()));
                    }
                    for (i, vt) in vs.iter().enumerate() {
                        tensors.push((format!("adam.v.{i}"), vt.clone()));
                    }
                    tensors.push(("adam.t".to_string(), Tensor::scalar(t_adam as f32)));
                    for (l, (o, lse)) in &saved_attn {
                        tensors.push((format!("ckpt.L{l}.o"), o.clone()));
                        tensors.push((format!("ckpt.L{l}.lse"), lse.clone()));
                    }
                    StepState { step, tensors }
                        .save(dir)
                        .with_context(|| format!("persisting step {step} state to {dir:?}"))?;
                }

                if rank == 0 {
                    logs.push(StepLog {
                        step,
                        loss: loss_t.as_scalar(),
                        grad_norm: gnorm,
                        wall_s: t0.elapsed().as_secs_f64(),
                        comm_bytes: w.comm.bytes_sent_global(),
                    });
                }
            }

            if rank == 0 {
                let stats = w.runtime.stats();
                Ok(Some(TrainReport {
                    logs,
                    kernel_calls: stats.calls,
                    kernel_s: stats.kernel_nanos as f64 / 1e9,
                    total_s: t_start.elapsed().as_secs_f64(),
                    layer_traces: Vec::new(),
                }))
            } else {
                Ok(None)
            }
        }));
    }

    let mut report = None;
    for h in handles {
        let joined = h
            .join()
            .map_err(|_| anyhow!("trainer worker panicked"))
            .and_then(|r| r.context("trainer worker failed"));
        let r = match joined {
            Ok(r) => r,
            Err(e) => {
                // a failed run is restartable when survivable state
                // exists: name the resume step so the operator (or the
                // recovery supervisor) can rerun with the same state dir
                if let Some(dir) = &cfg.state_dir {
                    if let Ok(Some(st)) = StepState::load(dir) {
                        return Err(e.context(format!(
                            "restartable: step {} state is persisted at {dir:?} — rerun \
                             with the same state_dir to resume from step {}",
                            st.step,
                            st.step + 1
                        )));
                    }
                }
                return Err(e);
            }
        };
        if let Some(r) = r {
            report = Some(r);
        }
    }
    let mut report = report.ok_or_else(|| anyhow!("no report from rank 0"))?;

    if cfg.run.trace {
        let recs: Vec<LayerSpanRec> =
            std::mem::take(&mut *sink.lock().expect("trace sink poisoned"));
        let pass_rank = |p: &str| match p {
            "fwd" => 0usize,
            "bwd" => 1,
            _ => 2,
        };
        let mut keys: Vec<(usize, &'static str)> =
            recs.iter().map(|r| (r.layer, r.pass)).collect();
        keys.sort_by_key(|&(l, p)| (l, pass_rank(p)));
        keys.dedup();
        for (layer, pass) in keys {
            let traces: Vec<RunTrace> = recs
                .iter()
                .filter(|r| r.layer == layer && r.pass == pass)
                .map(|r| r.trace.clone())
                .collect();
            // recompute spans carry *backward-plan* op ids (the prefix
            // lives in the bwd lowering), so only "fwd" merges against the
            // forward plan
            let plan = if pass == "fwd" { &fwd_plan } else { &bwd_plan };
            report.layer_traces.push(LayerTrace {
                layer,
                pass,
                trace: MergedTrace::merge(plan, &traces),
            });
        }
    }
    Ok(report)
}

/// Restore replicated worker state — parameters plus Adam moments — from
/// a persisted [`StepState`]; every tensor must exist under its ckpt-IR
/// name and match the live parameter table's shape.
fn restore_worker_state(
    st: &StepState,
    params: &mut [Tensor],
    acfg: AdamConfig,
) -> Result<Adam> {
    let fetch = |name: String, shape: &[usize]| -> Result<Tensor> {
        let t = st
            .tensor(&name)
            .ok_or_else(|| anyhow!("persisted state lacks tensor {name:?}"))?;
        if t.shape != shape {
            bail!(
                "persisted {name:?} has shape {:?} but the live model expects {shape:?}",
                t.shape
            );
        }
        Ok(t.clone())
    };
    let mut m = Vec::with_capacity(params.len());
    let mut v = Vec::with_capacity(params.len());
    for (i, p) in params.iter_mut().enumerate() {
        let shape = p.shape.clone();
        *p = fetch(format!("param.{i}"), &shape)?;
        m.push(fetch(format!("adam.m.{i}"), &shape)?);
        v.push(fetch(format!("adam.v.{i}"), &shape)?);
    }
    let t = st
        .tensor("adam.t")
        .ok_or_else(|| anyhow!("persisted state lacks tensor \"adam.t\""))?
        .as_scalar() as i32;
    Ok(Adam::restore(acfg, t, m, v))
}

/// Evaluate the monolithic `full_model_grads` oracle with the same
/// deterministic init + first corpus sample; returns (loss, grads).
/// Only available for configs exported with `export_ref_grads`.
pub fn oracle_first_step(cfg: &TrainConfig) -> Result<(f32, Vec<Tensor>)> {
    let rt = Runtime::load(cfg.artifact_dir()?)?;
    let mc = rt.manifest().config.clone();
    anyhow::ensure!(
        mc.export_ref_grads,
        "config {} lacks the full_model_grads oracle",
        mc.name
    );
    let params = init_params(&rt, cfg.seed);
    let mut corpus = MarkovCorpus::new(mc.vocab, cfg.seed);
    let (ids, tgts) = corpus.sample(mc.seq_len);
    let mut inputs: Vec<Value> = vec![
        Value::I32(ITensor::new(vec![mc.seq_len], ids)),
        Value::I32(ITensor::new(vec![mc.seq_len], tgts)),
    ];
    inputs.extend(params.iter().map(|t| Value::F32(t.clone())));
    let mut out = rt.run("full_model_grads", &inputs)?;
    let loss = out.remove(0).as_scalar();
    Ok((loss, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn restore_round_trips_params_and_adam() {
        // snapshot exactly the way the trainer persists it, restore into
        // a fresh table, and the two trajectories must stay in lockstep
        let p0 = vec![
            Tensor::new(vec![2], vec![1.0, 2.0]),
            Tensor::new(vec![3], vec![3.0, 4.0, 5.0]),
        ];
        let g: Vec<Tensor> = p0.iter().map(|p| Tensor::full(&p.shape, 0.1)).collect();
        let mut params = p0.clone();
        let mut adam = Adam::new(AdamConfig::default(), &params);
        adam.step(&mut params, &g);
        let mut tensors = Vec::new();
        for (i, p) in params.iter().enumerate() {
            tensors.push((format!("param.{i}"), p.clone()));
        }
        let (t, m, v) = adam.state();
        for (i, mt) in m.iter().enumerate() {
            tensors.push((format!("adam.m.{i}"), mt.clone()));
        }
        for (i, vt) in v.iter().enumerate() {
            tensors.push((format!("adam.v.{i}"), vt.clone()));
        }
        tensors.push(("adam.t".to_string(), Tensor::scalar(t as f32)));
        let st = StepState { step: 0, tensors };

        let mut fresh = p0.clone();
        let mut restored =
            restore_worker_state(&st, &mut fresh, AdamConfig::default()).unwrap();
        assert_eq!(fresh[0], params[0]);
        assert_eq!(fresh[1], params[1]);
        adam.step(&mut params, &g);
        restored.step(&mut fresh, &g);
        assert_eq!(fresh[0], params[0]);
        assert_eq!(fresh[1], params[1]);

        // shape drift is rejected, missing tensors are rejected
        let mut wrong = vec![Tensor::zeros(&[5]), Tensor::zeros(&[3])];
        assert!(restore_worker_state(&st, &mut wrong, AdamConfig::default()).is_err());
        let mut extra = vec![Tensor::zeros(&[2]); 3];
        assert!(restore_worker_state(&st, &mut extra, AdamConfig::default()).is_err());
    }

    #[test]
    fn call_ids_unique() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..16 {
            for layer in 0..8 {
                for pass in [Pass::Fwd, Pass::Bwd, Pass::Recompute] {
                    assert!(seen.insert(call_id(step, layer, pass)));
                }
            }
        }
    }
}
