//! Sequence-parallel distributed trainer: the full DISTFLASHATTN training
//! loop over the AOT layer artifacts.
//!
//! P worker threads each own one sequence chunk and a full parameter
//! replica. Per layer: local `part1` (LN + QKV) → *distributed* attention
//! (the paper's contribution, over the channel fabric) → local `part2`
//! (proj + MLP). Gradients are summed with a ring all-reduce and Adam runs
//! identically everywhere (replicated params stay bit-identical — FSDP
//! sharding is modeled in `baselines`, not materialized here, since memory
//! pressure is not what the CPU testbed measures).
//!
//! Checkpointing strategies (paper §3.3) are implemented exactly as the
//! data-flow dictates:
//! * `HfStyle`   — store layer input x; backward re-runs part1 AND the
//!   distributed attention forward (with all its communication).
//! * `RematAware` — additionally store (o, lse) at the FlashAttention
//!   output; backward re-runs only part1. No attention forward, no
//!   forward communication. Numerically identical (asserted in tests).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::config::ClusterSpec;
use crate::coordinator::comm::{build_network_placed, WorkerComm};
use crate::coordinator::executor::{AttnCtx, PlanIndex, RunTrace, ATTN_ARTIFACTS};
use crate::baselines::{attn_cost_from_dims, bwd_cost_from_fwd};
use crate::coordinator::harness::{build_plans, build_plans_optimized};
use crate::coordinator::optimize::OptimizeOpts;
use crate::coordinator::plan::Plan;
use crate::coordinator::{CkptStrategy, ScheduleKind};
use crate::runtime::{ITensor, Runtime, Tensor, Value};
use crate::train::data::MarkovCorpus;
use crate::train::optimizer::{Adam, AdamConfig};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact_dir: PathBuf,
    pub schedule: ScheduleKind,
    pub ckpt: CkptStrategy,
    pub steps: usize,
    pub adam: AdamConfig,
    pub seed: u64,
    pub log_every: usize,
    /// When set, run the plan optimizer (`coordinator::optimize`) against
    /// this cluster before training: the workers then execute the
    /// cost-optimal flipped/placed plans instead of the default lowering.
    /// Numerics are identical either way (same pair coverage).
    pub optimize_for: Option<ClusterSpec>,
}

impl TrainConfig {
    pub fn new(artifact_dir: &Path) -> Self {
        TrainConfig {
            artifact_dir: artifact_dir.to_path_buf(),
            schedule: ScheduleKind::Balanced,
            ckpt: CkptStrategy::RematAware,
            steps: 20,
            adam: AdamConfig::default(),
            seed: 0,
            log_every: 1,
            optimize_for: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub wall_s: f64,
    /// Global bytes moved during this step (attention + all-reduce).
    pub comm_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub logs: Vec<StepLog>,
    pub kernel_calls: u64,
    pub kernel_s: f64,
    pub total_s: f64,
}

/// Parameter layout helper: layer params in manifest order, then globals.
struct ParamLayout {
    n_layers: usize,
    per_layer: usize,
}

impl ParamLayout {
    fn layer(&self, l: usize, i: usize) -> usize {
        l * self.per_layer + i
    }

    fn global(&self, i: usize) -> usize {
        self.n_layers * self.per_layer + i
    }
}

/// Deterministic parameter init (every worker computes the same tensors).
fn init_params(rt: &Runtime, seed: u64) -> Vec<Tensor> {
    let m = rt.manifest();
    let cfg = &m.config;
    let mut rng = Rng::new(seed ^ 0x9A7A);
    let mut out = Vec::new();
    let std_scale = 0.02f32;
    for _l in 0..cfg.n_layers {
        for p in &m.layer_params {
            let n: usize = p.shape.iter().product();
            let t = if p.name.starts_with("ln") {
                Tensor::full(&p.shape, 1.0)
            } else {
                let mut data = rng.normal_vec(n);
                let s = if p.name == "w2" {
                    std_scale / (2.0 * cfg.n_layers as f32).sqrt()
                } else {
                    std_scale
                };
                for x in &mut data {
                    *x *= s;
                }
                Tensor::new(p.shape.clone(), data)
            };
            out.push(t);
        }
    }
    for p in &m.global_params {
        let n: usize = p.shape.iter().product();
        let t = if p.name.starts_with("ln") {
            Tensor::full(&p.shape, 1.0)
        } else {
            let mut data = rng.normal_vec(n);
            for x in &mut data {
                *x *= std_scale;
            }
            Tensor::new(p.shape.clone(), data)
        };
        out.push(t);
    }
    out
}

fn v(t: &Tensor) -> Value {
    Value::F32(t.clone())
}

/// Saved forward state for one layer (per checkpoint strategy).
struct LayerCkpt {
    x: Tensor,
    /// Present only under RematAware.
    attn: Option<(Tensor, Tensor)>, // (o, lse)
}

struct Worker {
    rank: usize,
    runtime: Runtime,
    comm: WorkerComm,
    /// Lowered schedule IR, shared with the simulators (one per pass).
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    /// Pre-resolved op walks for this rank — built once, reused by every
    /// layer of every training step.
    fwd_idx: PlanIndex,
    bwd_idx: PlanIndex,
    cfg: TrainConfig,
    params: Vec<Tensor>,
    layout: ParamLayout,
}

impl Worker {
    /// Names of per-layer params in manifest order (indices into layout).
    const LN1: usize = 0;
    const WQ: usize = 1;
    const WK: usize = 2;
    const WV: usize = 3;
    const WO: usize = 4;
    const LN2: usize = 5;
    const W1: usize = 6;
    const W3: usize = 7;
    const W2: usize = 8;
    const W_EMB: usize = 0;
    const LN_F: usize = 1;
    const W_HEAD: usize = 2;

    fn lp(&self, l: usize, i: usize) -> &Tensor {
        &self.params[self.layout.layer(l, i)]
    }

    fn gp(&self, i: usize) -> &Tensor {
        &self.params[self.layout.global(i)]
    }

    fn attn_call(
        &mut self,
        call_id: u32,
        backward: bool,
        f: impl FnOnce(&mut AttnCtx, &PlanIndex) -> Result<Vec<Tensor>>,
    ) -> Result<Vec<Tensor>> {
        let (plan, idx) = if backward {
            (self.bwd_plan.clone(), &self.bwd_idx)
        } else {
            (self.fwd_plan.clone(), &self.fwd_idx)
        };
        let mut ctx = AttnCtx {
            rank: self.rank,
            runtime: &self.runtime,
            comm: &mut self.comm,
            plan: &plan,
            call_id,
            epoch: None,
            trace: RunTrace::default(),
        };
        f(&mut ctx, idx)
    }

    /// One full forward over the local chunk; returns (loss_local, ckpts,
    /// final x) — loss_local already carries the 1/N global normalizer.
    fn forward(
        &mut self,
        step: usize,
        ids: &ITensor,
        targets: &ITensor,
        inv_total: f32,
    ) -> Result<(f32, Vec<LayerCkpt>, Tensor)> {
        let n_layers = self.layout.n_layers;
        let mut x = self
            .runtime
            .run("embed_fwd", &[Value::I32(ids.clone()), v(self.gp(Self::W_EMB))])?
            .remove(0);
        let mut ckpts = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let qkv = self.runtime.run(
                "part1_fwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                ],
            )?;
            let (q, k, vv) = (&qkv[0], &qkv[1], &qkv[2]);
            let call = call_id(step, l, Pass::Fwd);
            let out = self.attn_call(call, false, |ctx, idx| {
                let (o, lse) = ctx.forward_indexed(idx, q, k, vv)?;
                Ok(vec![o, lse])
            })?;
            let (o, lse) = (out[0].clone(), out[1].clone());
            let y = self
                .runtime
                .run(
                    "part2_fwd",
                    &[
                        v(&x),
                        v(&o),
                        v(self.lp(l, Self::WO)),
                        v(self.lp(l, Self::LN2)),
                        v(self.lp(l, Self::W1)),
                        v(self.lp(l, Self::W3)),
                        v(self.lp(l, Self::W2)),
                    ],
                )?
                .remove(0);
            ckpts.push(LayerCkpt {
                x: x.clone(),
                attn: match self.cfg.ckpt {
                    CkptStrategy::RematAware => Some((o, lse)),
                    CkptStrategy::HfStyle => None,
                },
            });
            x = y;
        }
        let loss = self
            .runtime
            .run(
                "head_loss_fwd",
                &[
                    v(&x),
                    v(self.gp(Self::LN_F)),
                    v(self.gp(Self::W_HEAD)),
                    Value::I32(targets.clone()),
                    Value::F32(Tensor::scalar(inv_total)),
                ],
            )?[0]
            .as_scalar();
        Ok((loss, ckpts, x))
    }

    /// Full backward; returns grads aligned with `params`.
    fn backward(
        &mut self,
        step: usize,
        ids: &ITensor,
        targets: &ITensor,
        inv_total: f32,
        ckpts: Vec<LayerCkpt>,
        x_final: Tensor,
    ) -> Result<Vec<Tensor>> {
        let n_layers = self.layout.n_layers;
        let mut grads: Vec<Tensor> =
            self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        // head
        let head = self.runtime.run(
            "head_loss_bwd",
            &[
                v(&x_final),
                v(self.gp(Self::LN_F)),
                v(self.gp(Self::W_HEAD)),
                Value::I32(targets.clone()),
                Value::F32(Tensor::scalar(inv_total)),
            ],
        )?;
        // outputs: (loss, dx, dln_f, dw_head)
        let mut dy = head[1].clone();
        grads[self.layout.global(Self::LN_F)].add_assign(&head[2]);
        grads[self.layout.global(Self::W_HEAD)].add_assign(&head[3]);

        for l in (0..n_layers).rev() {
            let ck = &ckpts[l];
            let x = ck.x.clone();
            // part1 recompute (cheap; both strategies)
            let qkv = self.runtime.run(
                "part1_fwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                ],
            )?;
            let (q, k, vv) = (qkv[0].clone(), qkv[1].clone(), qkv[2].clone());
            // attention output: saved (ours) or recomputed with full comm (HF)
            let (o, lse) = match &ck.attn {
                Some((o, lse)) => (o.clone(), lse.clone()),
                None => {
                    let call = call_id(step, l, Pass::Recompute);
                    let out = self.attn_call(call, false, |ctx, idx| {
                        let (o, lse) = ctx.forward_indexed(idx, &q, &k, &vv)?;
                        Ok(vec![o, lse])
                    })?;
                    (out[0].clone(), out[1].clone())
                }
            };
            // part2 backward
            let p2 = self.runtime.run(
                "part2_bwd",
                &[
                    v(&x),
                    v(&o),
                    v(self.lp(l, Self::WO)),
                    v(self.lp(l, Self::LN2)),
                    v(self.lp(l, Self::W1)),
                    v(self.lp(l, Self::W3)),
                    v(self.lp(l, Self::W2)),
                    v(&dy),
                ],
            )?;
            // outputs: (dx, d_attn_o, dwo, dln2, dw1, dw3, dw2)
            let dx_p2 = p2[0].clone();
            let d_o = p2[1].clone();
            grads[self.layout.layer(l, Self::WO)].add_assign(&p2[2]);
            grads[self.layout.layer(l, Self::LN2)].add_assign(&p2[3]);
            grads[self.layout.layer(l, Self::W1)].add_assign(&p2[4]);
            grads[self.layout.layer(l, Self::W3)].add_assign(&p2[5]);
            grads[self.layout.layer(l, Self::W2)].add_assign(&p2[6]);
            // distributed attention backward (no fwd recompute — §3.3)
            let call = call_id(step, l, Pass::Bwd);
            let attn_grads = self.attn_call(call, true, |ctx, idx| {
                let (dq, dk, dv) = ctx.backward_indexed(idx, &q, &k, &vv, &o, &lse, &d_o)?;
                Ok(vec![dq, dk, dv])
            })?;
            // part1 backward
            let p1 = self.runtime.run(
                "part1_bwd",
                &[
                    v(&x),
                    v(self.lp(l, Self::LN1)),
                    v(self.lp(l, Self::WQ)),
                    v(self.lp(l, Self::WK)),
                    v(self.lp(l, Self::WV)),
                    v(&attn_grads[0]),
                    v(&attn_grads[1]),
                    v(&attn_grads[2]),
                ],
            )?;
            // outputs: (dx, dln1, dwq, dwk, dwv)
            grads[self.layout.layer(l, Self::LN1)].add_assign(&p1[1]);
            grads[self.layout.layer(l, Self::WQ)].add_assign(&p1[2]);
            grads[self.layout.layer(l, Self::WK)].add_assign(&p1[3]);
            grads[self.layout.layer(l, Self::WV)].add_assign(&p1[4]);
            // dL/dx = residual path (part2's dx) + part1 path
            dy = dx_p2;
            dy.add_assign(&p1[0]);
        }

        // embedding
        let demb = self
            .runtime
            .run("embed_bwd", &[Value::I32(ids.clone()), v(&dy)])?
            .remove(0);
        grads[self.layout.global(Self::W_EMB)].add_assign(&demb);
        Ok(grads)
    }
}

#[derive(Clone, Copy)]
enum Pass {
    Fwd,
    Bwd,
    Recompute,
}

/// Unique attention call id per (step, layer, pass) — keeps channel tags
/// from colliding across the whole run.
fn call_id(step: usize, layer: usize, pass: Pass) -> u32 {
    let p = match pass {
        Pass::Fwd => 0,
        Pass::Bwd => 1,
        Pass::Recompute => 2,
    };
    ((step as u32) << 12) | ((layer as u32) << 2) | p
}

/// Run distributed training; returns the rank-0 report.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let probe = Runtime::load(&cfg.artifact_dir)?;
    let mc = probe.manifest().config.clone();
    let p = mc.n_workers;
    let n = mc.seq_len;
    drop(probe);

    let (fwd_plan, bwd_plan) = match &cfg.optimize_for {
        Some(cluster) => {
            let fwd_cost = attn_cost_from_dims(
                cluster,
                mc.chunk_len as f64,
                mc.n_heads,
                mc.n_kv_heads,
                mc.head_dim,
            );
            let bwd_cost = bwd_cost_from_fwd(&fwd_cost, mc.head_dim);
            build_plans_optimized(
                cfg.schedule,
                p,
                cluster,
                &fwd_cost,
                &bwd_cost,
                &OptimizeOpts { seed: cfg.seed, ..Default::default() },
            )?
        }
        None => build_plans(cfg.schedule, p)?,
    };
    // bind rank i to the optimized plan's GPU slot (identity when not
    // optimizing) — the trainer-side analogue of the launcher consuming
    // `Plan::placement`
    let comms = build_network_placed(p, &fwd_plan.placement);

    let mut handles = Vec::new();
    for (rank, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        let fwd_plan = fwd_plan.clone();
        let bwd_plan = bwd_plan.clone();
        handles.push(thread::spawn(move || -> Result<Option<TrainReport>> {
            let runtime = Runtime::load(&cfg.artifact_dir)?;
            runtime.precompile(ATTN_ARTIFACTS)?;
            runtime.precompile(&[
                "embed_fwd",
                "embed_bwd",
                "part1_fwd",
                "part1_bwd",
                "part2_fwd",
                "part2_bwd",
                "head_loss_fwd",
                "head_loss_bwd",
            ])?;
            let params = init_params(&runtime, cfg.seed);
            let layout = ParamLayout {
                n_layers: runtime.manifest().config.n_layers,
                per_layer: runtime.manifest().layer_params.len(),
            };
            // pre-resolve both plan walks once; every layer of every step
            // reuses them (dep lookups never repeat)
            let fwd_idx =
                PlanIndex::new(&fwd_plan, rank, crate::coordinator::plan::Pass::Forward)?;
            let bwd_idx =
                PlanIndex::new(&bwd_plan, rank, crate::coordinator::plan::Pass::Backward)?;
            let mut w = Worker {
                rank,
                runtime,
                comm,
                fwd_plan,
                bwd_plan,
                fwd_idx,
                bwd_idx,
                cfg: cfg.clone(),
                params,
                layout,
            };
            let mut adam = Adam::new(cfg.adam, &w.params);
            let mut corpus = MarkovCorpus::new(
                w.runtime.manifest().config.vocab,
                cfg.seed,
            );
            let chunk = w.runtime.manifest().config.chunk_len;
            let inv_total = 1.0 / n as f32;
            let mut logs = Vec::new();
            let t_start = std::time::Instant::now();

            for step in 0..cfg.steps {
                let t0 = std::time::Instant::now();
                // every worker generates the identical sequence, takes its
                // chunk
                let (ids_full, tgts_full) = corpus.sample(n);
                let ids = ITensor::new(
                    vec![chunk],
                    ids_full[rank * chunk..(rank + 1) * chunk].to_vec(),
                );
                let tgts = ITensor::new(
                    vec![chunk],
                    tgts_full[rank * chunk..(rank + 1) * chunk].to_vec(),
                );

                let (loss_local, ckpts, x_final) =
                    w.forward(step, &ids, &tgts, inv_total)?;
                let mut grads =
                    w.backward(step, &ids, &tgts, inv_total, ckpts, x_final)?;

                // global loss + gradient all-reduce
                let mut loss_t = Tensor::scalar(loss_local);
                let round_base = (step as u32) << 16;
                w.comm.all_reduce_sum(round_base, &mut loss_t);
                for (i, g) in grads.iter_mut().enumerate() {
                    w.comm.all_reduce_sum(round_base + 1 + i as u32, g);
                }
                let gnorm = Adam::grad_norm(&grads);
                adam.step(&mut w.params, &grads);

                if rank == 0 {
                    logs.push(StepLog {
                        step,
                        loss: loss_t.as_scalar(),
                        grad_norm: gnorm,
                        wall_s: t0.elapsed().as_secs_f64(),
                        comm_bytes: w.comm.bytes_sent_global(),
                    });
                }
            }

            if rank == 0 {
                let stats = w.runtime.stats();
                Ok(Some(TrainReport {
                    logs,
                    kernel_calls: stats.calls,
                    kernel_s: stats.kernel_nanos as f64 / 1e9,
                    total_s: t_start.elapsed().as_secs_f64(),
                }))
            } else {
                Ok(None)
            }
        }));
    }

    let mut report = None;
    for h in handles {
        let r = h
            .join()
            .map_err(|_| anyhow!("trainer worker panicked"))?
            .context("trainer worker failed")?;
        if let Some(r) = r {
            report = Some(r);
        }
    }
    report.ok_or_else(|| anyhow!("no report from rank 0"))
}

/// Evaluate the monolithic `full_model_grads` oracle with the same
/// deterministic init + first corpus sample; returns (loss, grads).
/// Only available for configs exported with `export_ref_grads`.
pub fn oracle_first_step(cfg: &TrainConfig) -> Result<(f32, Vec<Tensor>)> {
    let rt = Runtime::load(&cfg.artifact_dir)?;
    let mc = rt.manifest().config.clone();
    anyhow::ensure!(
        mc.export_ref_grads,
        "config {} lacks the full_model_grads oracle",
        mc.name
    );
    let params = init_params(&rt, cfg.seed);
    let mut corpus = MarkovCorpus::new(mc.vocab, cfg.seed);
    let (ids, tgts) = corpus.sample(mc.seq_len);
    let mut inputs: Vec<Value> = vec![
        Value::I32(ITensor::new(vec![mc.seq_len], ids)),
        Value::I32(ITensor::new(vec![mc.seq_len], tgts)),
    ];
    inputs.extend(params.iter().map(|t| Value::F32(t.clone())));
    let mut out = rt.run("full_model_grads", &inputs)?;
    let loss = out.remove(0).as_scalar();
    Ok((loss, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn call_ids_unique() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..16 {
            for layer in 0..8 {
                for pass in [Pass::Fwd, Pass::Bwd, Pass::Recompute] {
                    assert!(seen.insert(call_id(step, layer, pass)));
                }
            }
        }
    }
}
