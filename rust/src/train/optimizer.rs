//! Adam (Kingma & Ba) over host tensors, with bias correction and optional
//! gradient clipping. Runs identically on every worker after the gradient
//! all-reduce, keeping replicated parameters bit-identical — the property
//! the trainer's determinism tests pin down.

use crate::runtime::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub grad_clip: Option<f32>,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, grad_clip: Some(1.0) }
    }
}

pub struct Adam {
    cfg: AdamConfig,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: i32,
}

impl Adam {
    pub fn new(cfg: AdamConfig, params: &[Tensor]) -> Adam {
        Adam {
            cfg,
            m: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
            v: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
            t: 0,
        }
    }

    /// Optimizer state for persistence: `(t, first moments, second
    /// moments)` — what a crashed run needs to resume bit-identically.
    pub fn state(&self) -> (i32, &[Tensor], &[Tensor]) {
        (self.t, &self.m, &self.v)
    }

    /// Rebuild an optimizer from persisted state (the inverse of
    /// [`Adam::state`]); `m` and `v` must align with the parameter table
    /// the optimizer will step.
    pub fn restore(cfg: AdamConfig, t: i32, m: Vec<Tensor>, v: Vec<Tensor>) -> Adam {
        assert_eq!(m.len(), v.len(), "moment tables must align");
        Adam { cfg, m, v, t }
    }

    /// Global gradient L2 norm (for clipping / logging).
    pub fn grad_norm(grads: &[Tensor]) -> f32 {
        grads
            .iter()
            .map(|g| g.data().iter().map(|x| x * x).sum::<f32>())
            .sum::<f32>()
            .sqrt()
    }

    /// One update step; `params` and `grads` must align with construction.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let scale = match self.cfg.grad_clip {
            Some(c) => {
                let norm = Self::grad_norm(grads);
                if norm > c {
                    c / norm
                } else {
                    1.0
                }
            }
            None => 1.0,
        };
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let pd = p.data_mut();
            let gd = g.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                let gi = gd[i] * scale;
                md[i] = b1 * md[i] + (1.0 - b1) * gi;
                vd[i] = b2 * vd[i] + (1.0 - b2) * gi * gi;
                let mhat = md[i] / bc1;
                let vhat = vd[i] / bc2;
                pd[i] -= self.cfg.lr * mhat / (vhat.sqrt() + self.cfg.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // minimize f(x) = ||x - 3||^2
        let mut params = vec![Tensor::zeros(&[4])];
        let mut adam = Adam::new(
            AdamConfig { lr: 0.1, grad_clip: None, ..Default::default() },
            &params,
        );
        for _ in 0..200 {
            let grads = vec![Tensor::new(
                vec![4],
                params[0].data().iter().map(|x| 2.0 * (x - 3.0)).collect(),
            )];
            adam.step(&mut params, &grads);
        }
        for &x in params[0].data() {
            assert!((x - 3.0).abs() < 0.05, "converged to {x}");
        }
    }

    #[test]
    fn clipping_bounds_update() {
        let mut params = vec![Tensor::zeros(&[2])];
        let mut adam = Adam::new(
            AdamConfig { lr: 0.1, grad_clip: Some(1.0), ..Default::default() },
            &params,
        );
        let huge = vec![Tensor::new(vec![2], vec![1e6, 1e6])];
        adam.step(&mut params, &huge);
        // first-step Adam update magnitude ≈ lr regardless, but clipped
        // grads keep m/v sane; just assert finiteness and small step
        assert!(params[0].data().iter().all(|x| x.is_finite() && x.abs() < 0.2));
    }

    #[test]
    fn restore_resumes_bit_identically() {
        // run 6 steps straight vs 3 steps, persist, restore, 3 more: the
        // parameter trajectories must match bit for bit
        let p0 = vec![Tensor::new(vec![3], vec![1.0, -2.0, 0.5])];
        let g = vec![Tensor::new(vec![3], vec![0.3, 0.1, -0.7])];
        let cfg = AdamConfig::default();
        let mut straight = p0.clone();
        let mut os = Adam::new(cfg, &straight);
        for _ in 0..6 {
            os.step(&mut straight, &g);
        }
        let mut resumed = p0.clone();
        let mut oa = Adam::new(cfg, &resumed);
        for _ in 0..3 {
            oa.step(&mut resumed, &g);
        }
        let (t, m, v) = oa.state();
        assert_eq!(t, 3);
        let mut ob = Adam::restore(cfg, t, m.to_vec(), v.to_vec());
        for _ in 0..3 {
            ob.step(&mut resumed, &g);
        }
        assert_eq!(resumed[0], straight[0]);
    }

    #[test]
    fn deterministic_across_instances() {
        let p0 = vec![Tensor::new(vec![3], vec![1.0, -2.0, 0.5])];
        let g = vec![Tensor::new(vec![3], vec![0.3, 0.1, -0.7])];
        let mut a = p0.clone();
        let mut b = p0.clone();
        let mut oa = Adam::new(AdamConfig::default(), &a);
        let mut ob = Adam::new(AdamConfig::default(), &b);
        for _ in 0..5 {
            oa.step(&mut a, &g);
            ob.step(&mut b, &g);
        }
        assert_eq!(a[0], b[0]);
    }
}
