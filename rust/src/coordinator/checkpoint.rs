//! Gradient-checkpointing strategies (paper §3.3).
//!
//! Both strategies store the layer *input* x. The difference is whether the
//! attention output `o` and logsumexp `lse` are also saved:
//!
//! * `HfStyle` (Wolf et al. layer-boundary checkpoints): backward first
//!   re-runs part1 AND the full distributed attention forward (compute and
//!   inter-worker communication!) to rebuild `o`/`lse`, then runs the
//!   backward pieces.
//! * `RematAware` (ours): `o`/`lse` are checkpointed at the FlashAttention
//!   output, so backward re-runs only the cheap part1 linear projections;
//!   the attention forward — the dominant O(N²/P) term — is never
//!   recomputed and its forward communication is never repeated.
//!
//! Both strategies now exist *in the plan IR*, not just in trainer
//! numerics. Backward lowering under `HfStyle`
//! (`LowerOpts { ckpt: Some(CkptStrategy::HfStyle), .. }`) prepends the
//! recompute subgraph — the attention forward's computes and kv transfers
//! replayed before the backward ops:
//!
//! ```text
//!   HfStyle backward plan (one layer, steps on the x-axis):
//!
//!   step:   0 .. T-1         |  T .. 2T-1            | 2T
//!           recompute prefix |  original backward    | accum
//!           kv xfer ─▶ attn  |  kv/q xfers ─▶ d(attn)| dk/dv
//!           (rebuild o, lse) |  (uses rebuilt o/lse) | drains
//!
//!   RematAware backward plan: no prefix — o/lse were checkpointed at the
//!   FlashAttention output, costing `extra_saved_floats` resident bytes.
//! ```
//!
//! Numerically the two are identical (the paper's claim; asserted at the
//! plan level by `rust/tests/ckpt_properties.rs`, which executes the
//! HfStyle recompute subgraph on HostRef and checks it bit-identical to
//! the no-checkpoint path and to the `full_attn_ref` oracle, and
//! end-to-end by `rust/tests/trainer_integration.rs`); they differ only
//! in time and in stored bytes. The accounting helpers below feed the
//! simulator's Table 5 reproduction and the `ckpt_tradeoff` report.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptStrategy {
    /// HuggingFace-style: checkpoint at Transformer layer boundaries.
    HfStyle,
    /// Rematerialization-aware: checkpoint at the FlashAttention output.
    RematAware,
}

impl CkptStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            CkptStrategy::HfStyle => "hf",
            CkptStrategy::RematAware => "remat-aware",
        }
    }

    /// Does the backward pass recompute the distributed attention forward?
    pub fn recomputes_attention_fwd(&self) -> bool {
        matches!(self, CkptStrategy::HfStyle)
    }

    /// Extra checkpointed floats per layer per worker beyond the layer
    /// input: (o: H·C·D = C·E) + (lse: H·C).
    pub fn extra_saved_floats(&self, n_heads: usize, chunk: usize, head_dim: usize) -> usize {
        match self {
            CkptStrategy::HfStyle => 0,
            CkptStrategy::RematAware => n_heads * chunk * head_dim + n_heads * chunk,
        }
    }
}

impl std::str::FromStr for CkptStrategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "hf" | "hf-style" | "layer" => Ok(CkptStrategy::HfStyle),
            "remat" | "remat-aware" | "ours" => Ok(CkptStrategy::RematAware),
            other => Err(format!(
                "unknown checkpoint strategy {other:?}; accepted (case-insensitive): \
                 \"hf\", \"hf-style\", \"layer\", \"remat\", \"remat-aware\", \"ours\""
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_props() {
        let hf: CkptStrategy = "hf".parse().unwrap();
        let ours: CkptStrategy = "remat-aware".parse().unwrap();
        assert!(hf.recomputes_attention_fwd());
        assert!(!ours.recomputes_attention_fwd());
        assert_eq!(hf.extra_saved_floats(4, 32, 16), 0);
        assert_eq!(ours.extra_saved_floats(4, 32, 16), 4 * 32 * 16 + 4 * 32);
        let err = "bogus".parse::<CkptStrategy>().unwrap_err();
        for spelling in ["hf", "hf-style", "layer", "remat", "remat-aware", "ours"] {
            assert!(err.contains(spelling), "error must list {spelling:?}: {err}");
        }
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!("HF".parse::<CkptStrategy>().unwrap(), CkptStrategy::HfStyle);
        assert_eq!("Hf-Style".parse::<CkptStrategy>().unwrap(), CkptStrategy::HfStyle);
        assert_eq!("Remat".parse::<CkptStrategy>().unwrap(), CkptStrategy::RematAware);
        assert_eq!(
            "REMAT-AWARE".parse::<CkptStrategy>().unwrap(),
            CkptStrategy::RematAware
        );
    }
}
