//! Gradient-checkpointing strategies (paper §3.3).
//!
//! Both strategies store the layer *input* x. The difference is whether the
//! attention output `o` and logsumexp `lse` are also saved:
//!
//! * `HfStyle` (Wolf et al. layer-boundary checkpoints): backward first
//!   re-runs part1 AND the full distributed attention forward (compute and
//!   inter-worker communication!) to rebuild `o`/`lse`, then runs the
//!   backward pieces.
//! * `RematAware` (ours): `o`/`lse` are checkpointed at the FlashAttention
//!   output, so backward re-runs only the cheap part1 linear projections;
//!   the attention forward — the dominant O(N²/P) term — is never
//!   recomputed and its forward communication is never repeated.
//!
//! Numerically the two are identical (the paper's claim; asserted by
//! `rust/tests/trainer_integration.rs`); they differ only in time and in
//! stored bytes. The accounting helpers below feed the simulator's Table 5
//! reproduction.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptStrategy {
    /// HuggingFace-style: checkpoint at Transformer layer boundaries.
    HfStyle,
    /// Rematerialization-aware: checkpoint at the FlashAttention output.
    RematAware,
}

impl CkptStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            CkptStrategy::HfStyle => "hf",
            CkptStrategy::RematAware => "remat-aware",
        }
    }

    /// Does the backward pass recompute the distributed attention forward?
    pub fn recomputes_attention_fwd(&self) -> bool {
        matches!(self, CkptStrategy::HfStyle)
    }

    /// Extra checkpointed floats per layer per worker beyond the layer
    /// input: (o: H·C·D = C·E) + (lse: H·C).
    pub fn extra_saved_floats(&self, n_heads: usize, chunk: usize, head_dim: usize) -> usize {
        match self {
            CkptStrategy::HfStyle => 0,
            CkptStrategy::RematAware => n_heads * chunk * head_dim + n_heads * chunk,
        }
    }
}

impl std::str::FromStr for CkptStrategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hf" | "hf-style" | "layer" => Ok(CkptStrategy::HfStyle),
            "remat" | "remat-aware" | "ours" => Ok(CkptStrategy::RematAware),
            other => Err(format!("unknown checkpoint strategy {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_props() {
        let hf: CkptStrategy = "hf".parse().unwrap();
        let ours: CkptStrategy = "remat-aware".parse().unwrap();
        assert!(hf.recomputes_attention_fwd());
        assert!(!ours.recomputes_attention_fwd());
        assert_eq!(hf.extra_saved_floats(4, 32, 16), 0);
        assert_eq!(ours.extra_saved_floats(4, 32, 16), 4 * 32 * 16 + 4 * 32);
        assert!("bogus".parse::<CkptStrategy>().is_err());
    }
}
