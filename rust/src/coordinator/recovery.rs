//! Supervised recovery: turn a [`FailureReport`] into an executable
//! restart plan and drive it to bit-identical completion.
//!
//! PR 8 built *detection* — seeded fault injection, recv deadlines, abort
//! poison, a typed per-rank [`FailureReport`] — but a failure still ended
//! the run. This module closes the loop with three pillars:
//!
//! 1. **Checkpoint-replay** — while a recovery policy is armed, every
//!    worker records its per-layer `(o, lse)` pair (the exact artifacts
//!    the `RematAware` ckpt IR names as survivable, §3.3) into a shared
//!    [`CkptStore`]. After a failure, the longest layer prefix completed
//!    by *every* rank is skipped on replay — the step restarts from the
//!    last completed boundary, not from scratch — and the replayed
//!    outputs are verified against the checkpointed artifacts.
//! 2. **Elastic re-lowering** — when a rank's device slot is permanently
//!    lost, [`relower_elastic`] re-lowers the schedule over the P−1
//!    survivors, redistributing the dead rank's token chunk through the
//!    varlen boundary rebalancer (`optimize_varlen`) and scoring the
//!    degraded cluster with `PlanSim::set_worker_slowdown`. The executed
//!    replay keeps the original P-chunk plans (different cut points would
//!    change the online-softmax merge grouping and break bit-identity);
//!    the re-lowered pair is the steady-state plan for *subsequent* steps.
//! 3. **Policy + supervision** — [`RecoveryPolicy`] rides `RunSpec`
//!    (`fail_fast` | `respawn` | `elastic`), applied by the retry/backoff
//!    loop in [`Session::execute_supervised`]. Every recovery attempt is
//!    audited in a [`RecoveryReport`]: attempts, replayed vs skipped ops,
//!    time-to-recover, artifact verification.
//!
//! The recovery state machine:
//!
//! ```text
//! detect ──▶ report ──▶ restart plan ──▶ replay ──▶ verify
//!   │           │            │              │          │
//!  watchdog  FailureReport  RestartPlan   skip ckpt'd  replayed chunks
//!  + abort   (root cause,   (action +     layer prefix == stored (o,lse)
//!  poison    partial traces) predicted s)
//! ```
//!
//! Injected crashes are modeled as *transient, one-shot* faults: the
//! crash already fired (and is recorded in the fault events), so a
//! respawned rank replays with the crash cleared from its `FaultSpec`
//! while every other armed fault class (delay, drop, stalls) stays live.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::CkptStrategy;
use super::fault::{FailureReport, FaultSpec};
use super::optimize::{optimize_varlen, OptimizeOpts};
use super::plan::{LowerOpts, Pass, Plan};
use super::schedule::{Schedule, ScheduleKind, VarlenSpec};
use super::session::Session;
use crate::config::ClusterSpec;
use crate::runtime::Tensor;
use crate::simulator::{AttnCost, PlanSim};
use crate::util::Json;

// ---------------------------------------------------------------------------
// Policy
// ---------------------------------------------------------------------------

/// What the supervisor does when an `execute*()` fails. Rides
/// `RunSpec::recovery`; the default (`FailFast`) preserves the PR 8
/// fail-fast contract exactly.
#[derive(Clone, Debug, PartialEq)]
pub enum RecoveryPolicy {
    /// Surface the failure unchanged (PR 8 behavior).
    FailFast,
    /// Respawn the failed rank on its own device and replay from the last
    /// completed layer boundary, up to `max_retries` times with
    /// exponential backoff starting at `backoff_s`.
    Respawn { max_retries: usize, backoff_s: f64 },
    /// The failed rank's device slot is permanently lost: remap its
    /// logical rank onto a surviving buddy for the replay, and re-lower
    /// the plan over the P−1 survivors for subsequent steps. Refuses to
    /// recover below `min_workers`.
    Elastic { min_workers: usize },
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy::FailFast
    }
}

impl RecoveryPolicy {
    /// A sane respawn default: 3 retries, 50 ms initial backoff.
    pub fn respawn() -> RecoveryPolicy {
        RecoveryPolicy::Respawn { max_retries: 3, backoff_s: 0.05 }
    }

    pub fn is_fail_fast(&self) -> bool {
        matches!(self, RecoveryPolicy::FailFast)
    }

    /// Policy-level sanity, mirrored by `RunSpec::validate` (which passes
    /// `usize::MAX` for manifest-resolved specs whose worker count is not
    /// yet known).
    pub fn validate(&self, n_workers: usize) -> Result<()> {
        match self {
            RecoveryPolicy::FailFast => Ok(()),
            RecoveryPolicy::Respawn { max_retries, backoff_s } => {
                if *max_retries == 0 {
                    bail!("recovery.respawn.max_retries must be >= 1");
                }
                if !backoff_s.is_finite() || *backoff_s < 0.0 {
                    bail!("recovery.respawn.backoff_s must be finite and >= 0, got {backoff_s}");
                }
                Ok(())
            }
            RecoveryPolicy::Elastic { min_workers } => {
                if *min_workers < 2 {
                    bail!(
                        "recovery.elastic.min_workers must be >= 2 (a distributed plan needs \
                         at least two workers)"
                    );
                }
                if n_workers != usize::MAX && *min_workers >= n_workers {
                    bail!(
                        "recovery.elastic.min_workers ({min_workers}) must be below the worker \
                         count ({n_workers}) — losing a rank must leave enough survivors"
                    );
                }
                Ok(())
            }
        }
    }

    /// One-line JSON value (the `RunSpec::to_json` embedding): exact
    /// round trip through [`RecoveryPolicy::from_json`].
    pub fn to_json(&self) -> String {
        match self {
            RecoveryPolicy::FailFast => "\"fail_fast\"".to_string(),
            RecoveryPolicy::Respawn { max_retries, backoff_s } => format!(
                "{{\"respawn\": {{\"max_retries\": {max_retries}, \"backoff_s\": {backoff_s:?}}}}}"
            ),
            RecoveryPolicy::Elastic { min_workers } => {
                format!("{{\"elastic\": {{\"min_workers\": {min_workers}}}}}")
            }
        }
    }

    /// Parse the `to_json` form. Missing inner knobs take the
    /// [`RecoveryPolicy::respawn`] defaults; wrong-typed fields are
    /// errors, never silent defaults.
    pub fn from_json(j: &Json) -> Result<RecoveryPolicy> {
        match j {
            Json::Str(s) if s == "fail_fast" => Ok(RecoveryPolicy::FailFast),
            Json::Str(other) => bail!(
                "unknown recovery policy {other:?} (fail_fast | {{\"respawn\": ...}} | \
                 {{\"elastic\": ...}})"
            ),
            _ => {
                if let Some(r) = j.get("respawn") {
                    let max_retries = match r.get("max_retries") {
                        None | Some(Json::Null) => 3,
                        Some(v) => v.as_usize().ok_or_else(|| {
                            anyhow!("recovery.respawn.max_retries must be a non-negative integer")
                        })?,
                    };
                    let backoff_s = match r.get("backoff_s") {
                        None | Some(Json::Null) => 0.05,
                        Some(Json::Num(n)) => *n,
                        Some(_) => bail!("recovery.respawn.backoff_s must be a number"),
                    };
                    Ok(RecoveryPolicy::Respawn { max_retries, backoff_s })
                } else if let Some(e) = j.get("elastic") {
                    let min_workers = match e.get("min_workers") {
                        None | Some(Json::Null) => 2,
                        Some(v) => v.as_usize().ok_or_else(|| {
                            anyhow!("recovery.elastic.min_workers must be a non-negative integer")
                        })?,
                    };
                    Ok(RecoveryPolicy::Elastic { min_workers })
                } else {
                    bail!(
                        "recovery must be \"fail_fast\" | {{\"respawn\": {{...}}}} | \
                         {{\"elastic\": {{...}}}}"
                    )
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint store (the survivable per-layer state)
// ---------------------------------------------------------------------------

/// In-memory survivable-state store shared by every worker of a supervised
/// run: per-(rank, layer) `RematAware` `(o, lse)` artifacts plus per-pass
/// completion marks. After a failure, [`CkptStore::resume_layer`] names
/// the first layer the replay must re-execute — the skip decision is
/// all-or-nothing per layer across ranks, so the replayed comm schedule
/// stays symmetric.
#[derive(Default)]
pub struct CkptStore {
    inner: Mutex<CkptState>,
}

#[derive(Default)]
struct CkptState {
    /// (rank, layer) → checkpointed (o, lse) after that rank's forward.
    fwd: HashMap<(usize, usize), (Tensor, Tensor)>,
    /// (rank, layer) pairs whose backward completed.
    bwd: HashSet<(usize, usize)>,
}

impl CkptStore {
    pub fn new() -> CkptStore {
        CkptStore::default()
    }

    /// Record rank's completed forward for `layer` (saves the `(o, lse)`
    /// pair the ckpt IR names as survivable).
    pub fn record_fwd(&self, rank: usize, layer: usize, o: &Tensor, lse: &Tensor) {
        let mut s = self.inner.lock().expect("ckpt store poisoned");
        s.fwd.insert((rank, layer), (o.clone(), lse.clone()));
    }

    /// Record rank's completed backward for `layer`.
    pub fn record_bwd(&self, rank: usize, layer: usize) {
        let mut s = self.inner.lock().expect("ckpt store poisoned");
        s.bwd.insert((rank, layer));
    }

    /// Number of `(rank, layer)` forward artifacts currently stored.
    pub fn n_artifacts(&self) -> usize {
        self.inner.lock().expect("ckpt store poisoned").fwd.len()
    }

    /// The checkpointed forward artifact for `rank` with the highest
    /// layer index, if any — the verify stage compares replayed outputs
    /// against it.
    pub fn artifact_for(&self, rank: usize) -> Option<(usize, (Tensor, Tensor))> {
        let s = self.inner.lock().expect("ckpt store poisoned");
        s.fwd
            .iter()
            .filter(|((r, _), _)| *r == rank)
            .max_by_key(|((_, l), _)| *l)
            .map(|((_, l), t)| (*l, t.clone()))
    }

    /// Longest layer prefix completed by *every* rank (forward and — when
    /// the run has a backward — backward): the replay starts here. The
    /// caller caps this at `layers - 1` so a replay always re-executes at
    /// least one layer (the gathered results come from the last layer).
    pub fn resume_layer(&self, n_workers: usize, layers: usize, backward: bool) -> usize {
        let s = self.inner.lock().expect("ckpt store poisoned");
        let mut resume = 0;
        'layers: for layer in 0..layers {
            for rank in 0..n_workers {
                if !s.fwd.contains_key(&(rank, layer)) {
                    break 'layers;
                }
                if backward && !s.bwd.contains(&(rank, layer)) {
                    break 'layers;
                }
            }
            resume = layer + 1;
        }
        resume
    }
}

/// Replay context threaded into `execute_plans`: the shared store plus
/// the first layer to (re-)execute. Layers below `start_layer` were
/// completed by every rank and are skipped.
#[derive(Clone)]
pub struct RecoverCtx {
    pub(crate) store: Arc<CkptStore>,
    pub(crate) start_layer: usize,
}

// ---------------------------------------------------------------------------
// Restart plan
// ---------------------------------------------------------------------------

/// What the supervisor decided to do about one failure.
#[derive(Clone, Debug, PartialEq)]
pub enum RestartAction {
    /// Respawn the failed rank on its own device slot and replay.
    Respawn { rank: usize },
    /// The device slot is gone: co-schedule the logical rank on `buddy`
    /// for the replay and move to a re-lowered plan over `survivors`
    /// workers for subsequent steps.
    Remap { lost_rank: usize, buddy: usize, survivors: usize },
    /// Do not restart (fail-fast policy, or survivors below the floor).
    Halt,
}

/// The executable restart plan derived from one [`FailureReport`] —
/// what failed, what already completed, what the restart does, and what
/// the event engine predicts it costs.
#[derive(Clone, Debug)]
pub struct RestartPlan {
    /// Rendered root cause (`FailureReport::root_cause`).
    pub root_cause: String,
    /// Rank the root cause is attributed to.
    pub failed_rank: Option<usize>,
    pub action: RestartAction,
    /// Forward-plan ops with recorded spans in the partial merged trace
    /// (0 when the run was not traced) — replay-skip evidence.
    pub completed_fwd_ops: usize,
    pub completed_bwd_ops: usize,
    /// First layer the replay re-executes (earlier layers are
    /// checkpointed on every rank).
    pub resume_layer: usize,
    /// Layers the replay must re-execute.
    pub replay_layers: usize,
    /// Event-engine prediction for the replay (degraded cluster under
    /// `Remap`: the buddy runs both its own and the lost rank's work).
    pub predicted_restart_s: f64,
}

impl RestartPlan {
    /// Build the restart plan for `report` under `policy`. Pure: no
    /// execution, only trace accounting and event-engine scoring.
    #[allow(clippy::too_many_arguments)]
    pub fn from_failure(
        report: &FailureReport,
        fwd: &Plan,
        bwd: &Plan,
        policy: &RecoveryPolicy,
        cluster: &ClusterSpec,
        fwd_cost: &AttnCost,
        bwd_cost: &AttnCost,
        resume_layer: usize,
        layers: usize,
        backward: bool,
    ) -> RestartPlan {
        let root = report.root_cause();
        let failed_rank = root.map(|c| c.rank());
        let action = match (policy, failed_rank) {
            (RecoveryPolicy::FailFast, _) | (_, None) => RestartAction::Halt,
            (RecoveryPolicy::Respawn { .. }, Some(r)) => RestartAction::Respawn { rank: r },
            (RecoveryPolicy::Elastic { min_workers }, Some(r)) => {
                let survivors = fwd.n_workers.saturating_sub(1);
                if survivors < *min_workers {
                    RestartAction::Halt
                } else {
                    RestartAction::Remap {
                        lost_rank: r,
                        buddy: (r + 1) % fwd.n_workers,
                        survivors,
                    }
                }
            }
        };
        let slowdowns: Vec<(usize, f64)> = match &action {
            // the buddy executes two ranks' kernels: price it 2x slow
            RestartAction::Remap { buddy, .. } => vec![(*buddy, 2.0)],
            _ => Vec::new(),
        };
        let mut per_layer_s = score_plan_slow(fwd, cluster, fwd_cost, &slowdowns);
        if backward {
            per_layer_s += score_plan_slow(bwd, cluster, bwd_cost, &slowdowns);
        }
        let replay_layers = layers.saturating_sub(resume_layer);
        RestartPlan {
            root_cause: root.map(|c| format!("{c}")).unwrap_or_else(|| "unknown".to_string()),
            failed_rank,
            action,
            completed_fwd_ops: report
                .partial_fwd
                .as_ref()
                .map(|t| t.covered.iter().filter(|&&c| c).count())
                .unwrap_or(0),
            completed_bwd_ops: report
                .partial_bwd
                .as_ref()
                .map(|t| t.covered.iter().filter(|&&c| c).count())
                .unwrap_or(0),
            resume_layer,
            replay_layers,
            predicted_restart_s: per_layer_s * replay_layers as f64,
        }
    }
}

fn score_plan_slow(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    slowdowns: &[(usize, f64)],
) -> f64 {
    let mut sim = PlanSim::new(plan, cost);
    for &(w, f) in slowdowns {
        sim.set_worker_slowdown(w, f);
    }
    sim.total_s(cluster, &plan.placement, plan.prefetch_depth)
}

// ---------------------------------------------------------------------------
// Elastic re-lowering
// ---------------------------------------------------------------------------

/// The steady-state plan pair re-lowered over the P−1 survivors after a
/// permanent rank loss: the lost rank's tokens are redistributed through
/// the varlen boundary rebalancer. This pair is *not* executed by the
/// bit-pinned replay (different chunk cuts change the online-softmax
/// merge grouping); it is the plan subsequent steps run on.
#[derive(Clone, Debug)]
pub struct ElasticPlan {
    /// Surviving worker count (original P − 1).
    pub n_workers: usize,
    /// Rebalanced token cuts over the survivors (len `n_workers + 1`).
    pub boundaries: Vec<usize>,
    pub fwd: Arc<Plan>,
    pub bwd: Arc<Plan>,
    /// Event-engine makespan of the re-lowered pair on the survivors.
    pub predicted_s: f64,
    /// Cuts the rebalancer moved off the naive equal split.
    pub moved_boundaries: usize,
}

/// Re-lower the schedule over `survivors` workers, redistributing the
/// full token budget (`doc_lens` keeps the document masking of the
/// original layout; a uniform run is one causal document).
pub fn relower_elastic(
    kind: ScheduleKind,
    varlen: Option<&VarlenSpec>,
    total_tokens: usize,
    survivors: usize,
    ckpt: CkptStrategy,
    cluster: &ClusterSpec,
    fwd_cost: &AttnCost,
    bwd_cost: &AttnCost,
) -> Result<ElasticPlan> {
    if survivors < 2 {
        bail!("elastic re-lowering needs at least 2 surviving workers, got {survivors}");
    }
    let doc_lens = match varlen {
        Some(v) => v.doc_lens.clone(),
        None => vec![total_tokens],
    };
    let spec0 = VarlenSpec::equal_split(doc_lens, survivors);
    spec0
        .validate()
        .map_err(|e| anyhow!("elastic varlen layout invalid: {e}"))?;
    let schedule = Schedule::build(kind, survivors);
    let (fwd, bwd, moved, spec) = if ckpt == CkptStrategy::HfStyle {
        // the rebalancer re-lowers prefix-free candidates and would drop
        // the HfStyle recompute lowering: keep the equal split
        let lopts = LowerOpts {
            varlen: Some(Arc::new(spec0.clone())),
            ckpt: Some(ckpt),
            ..Default::default()
        };
        let fwd = Plan::from_schedule_opts(&schedule, Pass::Forward, &lopts);
        let bwd = Plan::from_schedule_opts(&schedule, Pass::Backward, &lopts);
        (fwd, bwd, 0, spec0)
    } else {
        let opts = OptimizeOpts::default();
        let of = optimize_varlen(&schedule, &spec0, Pass::Forward, cluster, fwd_cost, &opts);
        let bwd_opts = OptimizeOpts { move_boundaries: false, ..opts };
        let ob = optimize_varlen(&schedule, &of.spec, Pass::Backward, cluster, bwd_cost, &bwd_opts);
        let moved = of.moved_boundaries;
        (of.plan, ob.plan, moved, of.spec)
    };
    fwd.validate_lowered()
        .map_err(|e| anyhow!("elastic fwd plan invalid: {e}"))?;
    bwd.validate_lowered()
        .map_err(|e| anyhow!("elastic bwd plan invalid: {e}"))?;
    let predicted_s = score_plan_slow(&fwd, cluster, fwd_cost, &[])
        + score_plan_slow(&bwd, cluster, bwd_cost, &[]);
    Ok(ElasticPlan {
        n_workers: survivors,
        boundaries: spec.boundaries.clone(),
        fwd: Arc::new(fwd),
        bwd: Arc::new(bwd),
        predicted_s,
        moved_boundaries: moved,
    })
}

// ---------------------------------------------------------------------------
// Recovery audit
// ---------------------------------------------------------------------------

/// One supervised restart attempt.
#[derive(Clone, Debug)]
pub struct RecoveryAttempt {
    /// 1-based attempt index (attempt 0 is the original run).
    pub attempt: usize,
    /// `"respawn"` or `"remap"`.
    pub action: &'static str,
    /// Root cause of the failure this attempt recovers from.
    pub root_cause: String,
    pub failed_rank: Option<usize>,
    /// Layer the replay resumed from.
    pub resume_layer: usize,
    /// Backoff slept before this attempt.
    pub backoff_s: f64,
    /// Wall-clock of the attempt itself.
    pub wall_s: f64,
    pub succeeded: bool,
}

/// Audit record of one supervised execution: what failed, what was
/// replayed vs skipped, how long recovery took, and whether the replayed
/// outputs matched the checkpointed artifacts.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// The run completed (possibly without ever failing).
    pub recovered: bool,
    /// Restart attempts, in order; empty when attempt 0 succeeded.
    pub attempts: Vec<RecoveryAttempt>,
    /// Layer the successful replay resumed from.
    pub resume_layer: usize,
    /// Plan ops the successful replay re-executed.
    pub replayed_ops: usize,
    /// Plan ops skipped thanks to the checkpointed layer prefix.
    pub skipped_ops: usize,
    /// First failure detected → failure surfaced (attempt 0 wall).
    pub detect_s: f64,
    /// First failure detected → recovered run completed. 0 when attempt 0
    /// succeeded.
    pub time_to_recover_s: f64,
    /// Replayed per-rank output chunks compared equal against stored
    /// `(o, lse)` artifacts.
    pub verified_chunks: usize,
    /// Every compared chunk matched (and at least one was compared).
    pub verified: bool,
    /// The restart plan derived from the first failure.
    pub restart: Option<RestartPlan>,
    /// The re-lowered survivor plan (elastic policy only).
    pub elastic: Option<ElasticPlan>,
}

impl RecoveryReport {
    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        if self.attempts.is_empty() {
            return "clean run (no recovery needed)".to_string();
        }
        format!(
            "{} after {} attempt(s): resumed at layer {}, replayed {} ops (skipped {}), \
             detect {:.0} ms, recover {:.0} ms{}{}",
            if self.recovered { "recovered" } else { "NOT recovered" },
            self.attempts.len(),
            self.resume_layer,
            self.replayed_ops,
            self.skipped_ops,
            self.detect_s * 1e3,
            self.time_to_recover_s * 1e3,
            if self.verified {
                format!(", {} chunk(s) verified against checkpoints", self.verified_chunks)
            } else {
                String::new()
            },
            match &self.elastic {
                Some(e) => format!(
                    ", re-lowered over {} survivors ({} cuts moved)",
                    e.n_workers, e.moved_boundaries
                ),
                None => String::new(),
            },
        )
    }
}

// ---------------------------------------------------------------------------
// The supervision loop
// ---------------------------------------------------------------------------

impl Session {
    /// [`Session::execute`] wrapped in the recovery supervision loop:
    /// inputs synthesized from the spec's shapes and seed, failures
    /// restarted per `RunSpec::recovery`.
    pub fn execute_supervised(&mut self) -> Result<&mut Session> {
        let (q, k, v, do_) = self.synth_inputs()?;
        self.execute_supervised_with(&q, &k, &v, do_.as_ref())
    }

    /// Execute with caller-supplied tensors under the spec's
    /// [`RecoveryPolicy`]. `FailFast` is byte-for-byte the plain
    /// [`Session::execute_with`] path. Under `Respawn`/`Elastic` the
    /// run's per-layer `(o, lse)` artifacts are checkpointed as it goes;
    /// on failure the supervisor derives a [`RestartPlan`] from the
    /// [`FailureReport`], replays from the last layer boundary completed
    /// by every rank (crash cleared — it already fired; delay/drop/stall
    /// faults stay armed), verifies the replayed chunks against the
    /// checkpoints, and leaves the full audit in
    /// [`Session::recovery_report`]. The recovered outputs are
    /// bit-identical to a fault-free run (pinned by
    /// `rust/tests/recovery_properties.rs`).
    pub fn execute_supervised_with(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        do_: Option<&Tensor>,
    ) -> Result<&mut Session> {
        let policy = self.spec().recovery.clone();
        if policy.is_fail_fast() {
            self.recovery_report = None;
            return self.execute_with(q, k, v, do_);
        }
        let (fwd, bwd) = self.plans()?;
        let p = self.n_workers();
        let layers = self.spec().layers;
        let backward = do_.is_some();
        let ops_per_layer = fwd.n_ops() + if backward { bwd.n_ops() } else { 0 };
        let store = Arc::new(CkptStore::new());
        let armed_faults = self.spec().faults.clone();

        let t0 = Instant::now();
        let first = self.attempt_with(
            q,
            k,
            v,
            do_,
            armed_faults.clone(),
            Some(RecoverCtx { store: store.clone(), start_layer: 0 }),
        );
        let mut last_err = match first {
            Ok(()) => {
                self.recovery_report =
                    Some(RecoveryReport { recovered: true, ..RecoveryReport::default() });
                return Ok(self);
            }
            Err(e) => e,
        };
        let detect_s = t0.elapsed().as_secs_f64();
        let failure = self.failure_report().cloned().unwrap_or_default();
        let mut root_cause = failure
            .root_cause()
            .map(|c| format!("{c}"))
            .unwrap_or_else(|| format!("{last_err}"));
        let failed_rank = failure.root_cause().map(|c| c.rank());

        // crashes are transient one-shot faults: the respawned rank
        // replays with the crash cleared, every other class stays armed
        let retry_faults = armed_faults.map(|f| FaultSpec { crash: None, ..f });

        let resume0 = store.resume_layer(p, layers, backward).min(layers - 1);
        let (cluster, fwd_cost, bwd_cost) = {
            let (fc, bc) = self.costs();
            (self.spec().cluster.clone(), *fc, *bc)
        };
        let mut report = RecoveryReport {
            detect_s,
            restart: Some(RestartPlan::from_failure(
                &failure, &fwd, &bwd, &policy, &cluster, &fwd_cost, &bwd_cost, resume0, layers,
                backward,
            )),
            ..RecoveryReport::default()
        };

        let (max_retries, backoff_s, action): (usize, f64, &'static str) = match &policy {
            RecoveryPolicy::Respawn { max_retries, backoff_s } => {
                (*max_retries, *backoff_s, "respawn")
            }
            RecoveryPolicy::Elastic { min_workers } => {
                let survivors = p - 1;
                if survivors < *min_workers {
                    self.recovery_report = Some(report);
                    return Err(anyhow!(
                        "elastic recovery needs >= {min_workers} surviving workers but only \
                         {survivors} of {p} survive losing rank {failed_rank:?} \
                         (root cause: {root_cause})"
                    ));
                }
                report.elastic = Some(relower_elastic(
                    self.spec().schedule,
                    fwd.varlen.as_deref(),
                    q.shape[1],
                    survivors,
                    self.spec().ckpt,
                    &cluster,
                    &fwd_cost,
                    &bwd_cost,
                )?);
                // the lost device is gone for good: one remapped replay
                (1, 0.0, "remap")
            }
            RecoveryPolicy::FailFast => unreachable!("handled above"),
        };

        for attempt in 1..=max_retries {
            let backoff = backoff_s * (1u64 << (attempt - 1).min(16)) as f64;
            if backoff > 0.0 {
                thread::sleep(Duration::from_secs_f64(backoff.min(5.0)));
            }
            let resume = store.resume_layer(p, layers, backward).min(layers - 1);
            let ta = Instant::now();
            let res = self.attempt_with(
                q,
                k,
                v,
                do_,
                retry_faults.clone(),
                Some(RecoverCtx { store: store.clone(), start_layer: resume }),
            );
            let wall = ta.elapsed().as_secs_f64();
            let ok = res.is_ok();
            report.attempts.push(RecoveryAttempt {
                attempt,
                action,
                root_cause: root_cause.clone(),
                failed_rank,
                resume_layer: resume,
                backoff_s: backoff,
                wall_s: wall,
                succeeded: ok,
            });
            match res {
                Ok(()) => {
                    report.recovered = true;
                    report.resume_layer = resume;
                    report.skipped_ops = resume * ops_per_layer;
                    report.replayed_ops = (layers - resume) * ops_per_layer;
                    report.time_to_recover_s = t0.elapsed().as_secs_f64();
                    // verify: the replayed per-rank output chunks must
                    // equal the checkpointed (o, lse) artifacts bit for bit
                    let chunks = {
                        let o = &self.result()?.o;
                        match fwd.varlen.as_deref() {
                            Some(vs) => o.chunk_axis1_at(&vs.boundaries),
                            None => o.chunk_axis1(p),
                        }
                    };
                    let mut verified = 0;
                    let mut all_ok = true;
                    for (rank, chunk) in chunks.iter().enumerate() {
                        if let Some((_, (so, _))) = store.artifact_for(rank) {
                            if so == *chunk {
                                verified += 1;
                            } else {
                                all_ok = false;
                            }
                        }
                    }
                    report.verified_chunks = verified;
                    report.verified = all_ok && verified > 0;
                    self.recovery_report = Some(report);
                    return Ok(self);
                }
                Err(e) => {
                    if let Some(r) = self.failure_report() {
                        if let Some(c) = r.root_cause() {
                            root_cause = format!("{c}");
                        }
                    }
                    last_err = e;
                }
            }
        }
        report.recovered = false;
        report.time_to_recover_s = t0.elapsed().as_secs_f64();
        self.recovery_report = Some(report);
        Err(anyhow!(
            "recovery exhausted after {max_retries} restart attempt(s): {last_err:#}"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::ExecError;
    use crate::coordinator::schedule::ScheduleKind;
    use crate::coordinator::session::RunSpec;
    use crate::baselines::attn_cost_from_dims;

    #[test]
    fn policy_json_roundtrips() {
        for p in [
            RecoveryPolicy::FailFast,
            RecoveryPolicy::Respawn { max_retries: 5, backoff_s: 0.25 },
            RecoveryPolicy::Elastic { min_workers: 3 },
        ] {
            let j = Json::parse(&p.to_json()).expect("emitted JSON parses");
            assert_eq!(RecoveryPolicy::from_json(&j).unwrap(), p);
        }
        // missing knobs take respawn defaults
        let j = Json::parse(r#"{"respawn": {}}"#).unwrap();
        assert_eq!(
            RecoveryPolicy::from_json(&j).unwrap(),
            RecoveryPolicy::Respawn { max_retries: 3, backoff_s: 0.05 }
        );
        // unknown strings and malformed objects are errors
        assert!(RecoveryPolicy::from_json(&Json::parse("\"retry\"").unwrap()).is_err());
        assert!(RecoveryPolicy::from_json(&Json::parse("{\"other\": 1}").unwrap()).is_err());
    }

    #[test]
    fn policy_validation_pins_messages() {
        let err = RecoveryPolicy::Respawn { max_retries: 0, backoff_s: 0.0 }
            .validate(4)
            .unwrap_err();
        assert!(format!("{err}").contains("max_retries must be >= 1"), "{err}");
        let err = RecoveryPolicy::Respawn { max_retries: 1, backoff_s: f64::NAN }
            .validate(4)
            .unwrap_err();
        assert!(format!("{err}").contains("backoff_s"), "{err}");
        let err = RecoveryPolicy::Elastic { min_workers: 1 }.validate(4).unwrap_err();
        assert!(format!("{err}").contains("min_workers must be >= 2"), "{err}");
        let err = RecoveryPolicy::Elastic { min_workers: 4 }.validate(4).unwrap_err();
        assert!(format!("{err}").contains("must be below the worker count"), "{err}");
        assert!(RecoveryPolicy::Elastic { min_workers: 3 }.validate(4).is_ok());
        // manifest-resolved specs defer the worker-count check
        assert!(RecoveryPolicy::Elastic { min_workers: 64 }.validate(usize::MAX).is_ok());
    }

    #[test]
    fn ckpt_store_resume_is_all_or_nothing_per_layer() {
        let store = CkptStore::new();
        let o = Tensor::zeros(&[1, 2, 1]);
        let lse = Tensor::zeros(&[1, 2]);
        assert_eq!(store.resume_layer(2, 3, true), 0);
        // layer 0 complete on both ranks
        for rank in 0..2 {
            store.record_fwd(rank, 0, &o, &lse);
            store.record_bwd(rank, 0);
        }
        assert_eq!(store.resume_layer(2, 3, true), 1);
        // layer 1 forward complete, but rank 1's backward is missing:
        // the prefix must not extend
        store.record_fwd(0, 1, &o, &lse);
        store.record_fwd(1, 1, &o, &lse);
        store.record_bwd(0, 1);
        assert_eq!(store.resume_layer(2, 3, true), 1);
        // forward-only runs ignore the backward marks
        assert_eq!(store.resume_layer(2, 3, false), 2);
        store.record_bwd(1, 1);
        assert_eq!(store.resume_layer(2, 3, true), 2);
        assert_eq!(store.n_artifacts(), 4);
        assert_eq!(store.artifact_for(0).unwrap().0, 1, "highest layer wins");
    }

    #[test]
    fn restart_plan_names_action_and_replay_window() {
        let p = 4;
        let (fwd, bwd) = Session::new(RunSpec::plans_only(ScheduleKind::Balanced, p))
            .unwrap()
            .plans()
            .unwrap();
        let report = FailureReport {
            failures: vec![ExecError::InjectedCrash { rank: 2, step: 1 }],
            ..FailureReport::default()
        };
        let cluster = ClusterSpec::dgx_1x8();
        let cost = attn_cost_from_dims(&cluster, 64.0, 2, 1, 8);
        let plan = RestartPlan::from_failure(
            &report,
            &fwd,
            &bwd,
            &RecoveryPolicy::respawn(),
            &cluster,
            &cost,
            &cost,
            1,
            3,
            true,
        );
        assert_eq!(plan.action, RestartAction::Respawn { rank: 2 });
        assert_eq!(plan.failed_rank, Some(2));
        assert_eq!(plan.resume_layer, 1);
        assert_eq!(plan.replay_layers, 2);
        assert!(plan.predicted_restart_s > 0.0);
        assert!(plan.root_cause.contains("injected crash"), "{}", plan.root_cause);

        let plan = RestartPlan::from_failure(
            &report,
            &fwd,
            &bwd,
            &RecoveryPolicy::Elastic { min_workers: 2 },
            &cluster,
            &cost,
            &cost,
            0,
            3,
            true,
        );
        assert_eq!(
            plan.action,
            RestartAction::Remap { lost_rank: 2, buddy: 3, survivors: 3 }
        );
        // survivors below the floor: the plan says halt
        let plan = RestartPlan::from_failure(
            &report,
            &fwd,
            &bwd,
            &RecoveryPolicy::Elastic { min_workers: 4 },
            &cluster,
            &cost,
            &cost,
            0,
            3,
            true,
        );
        assert_eq!(plan.action, RestartAction::Halt);
    }

    #[test]
    fn elastic_relower_redistributes_the_lost_chunk() {
        let cluster = ClusterSpec::dgx_1x8();
        let cost = attn_cost_from_dims(&cluster, 64.0, 2, 1, 8);
        let ep = relower_elastic(
            ScheduleKind::Balanced,
            None,
            256,
            3,
            CkptStrategy::RematAware,
            &cluster,
            &cost,
            &cost,
        )
        .unwrap();
        assert_eq!(ep.n_workers, 3);
        assert_eq!(ep.fwd.n_workers, 3);
        assert_eq!(ep.boundaries.len(), 4);
        assert_eq!(*ep.boundaries.last().unwrap(), 256, "every token is covered");
        assert!(ep.predicted_s > 0.0);
        // below two survivors there is nothing distributed to lower
        assert!(relower_elastic(
            ScheduleKind::Balanced,
            None,
            256,
            1,
            CkptStrategy::RematAware,
            &cluster,
            &cost,
            &cost,
        )
        .is_err());
    }
}
