//! Multi-threaded harness: spawn P workers (each with its own PJRT runtime,
//! mirroring one-process-per-GPU) and run a distributed attention call over
//! a full sequence. Used by `repro verify`, the integration tests, and the
//! examples.
//!
//! The harness is where the schedule IR is produced: the chosen
//! [`Schedule`] is lowered to one forward and one backward [`Plan`], both
//! validated (`validate_lowered`), and every worker executes those exact
//! plans — the same objects a simulator would time.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use super::comm::build_network;
use super::executor::{AttnCtx, ATTN_ARTIFACTS};
use super::optimize::{optimize_schedule, OptimizeOpts};
use super::plan::{Pass, Plan};
use super::schedule::{Schedule, ScheduleKind};
use crate::config::ClusterSpec;
use crate::runtime::{Runtime, Tensor};
use crate::simulator::AttnCost;

/// Gathered results of one distributed attention call over N tokens.
#[derive(Debug)]
pub struct DistAttnResult {
    /// Normalized attention output (H, N, D).
    pub o: Tensor,
    /// Logsumexp (H, N).
    pub lse: Tensor,
    /// Gradients, present iff `do_` was supplied.
    pub grads: Option<(Tensor, Tensor, Tensor)>,
    /// Total bytes moved between workers.
    pub comm_bytes: u64,
}

/// Lower and validate the forward/backward plans for a schedule — shared
/// by the harness and the trainer so every consumer runs checked IR.
pub fn build_plans(kind: ScheduleKind, n_workers: usize) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let schedule = Schedule::build(kind, n_workers);
    schedule
        .validate()
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let fwd = Plan::from_schedule(&schedule, Pass::Forward);
    fwd.validate_lowered()
        .map_err(|e| anyhow!("invalid forward plan: {e}"))?;
    let bwd = Plan::from_schedule(&schedule, Pass::Backward);
    bwd.validate_lowered()
        .map_err(|e| anyhow!("invalid backward plan: {e}"))?;
    Ok((Arc::new(fwd), Arc::new(bwd)))
}

/// Optimizer-backed variant of [`build_plans`]: run the full pass pipeline
/// (role flipping, placement, prefetch autotune) against the given cluster
/// and per-pass cost models, and return validated plans the executor can
/// run directly. The flipped op stream changes *which worker computes
/// which pair* — the executor follows it literally — while the placement
/// is timing metadata for the launcher/simulators.
pub fn build_plans_optimized(
    kind: ScheduleKind,
    n_workers: usize,
    cluster: &ClusterSpec,
    fwd_cost: &AttnCost,
    bwd_cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let schedule = Schedule::build(kind, n_workers);
    schedule
        .validate()
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let fwd = optimize_schedule(&schedule, Pass::Forward, cluster, fwd_cost, opts).plan;
    fwd.validate_lowered()
        .map_err(|e| anyhow!("invalid optimized forward plan: {e}"))?;
    let bwd = optimize_schedule(&schedule, Pass::Backward, cluster, bwd_cost, opts).plan;
    bwd.validate_lowered()
        .map_err(|e| anyhow!("invalid optimized backward plan: {e}"))?;
    Ok((Arc::new(fwd), Arc::new(bwd)))
}

/// Run DISTFLASHATTN forward (and optionally backward) over full-sequence
/// tensors: q (H, N, D), k/v (KVH, N, D), do (H, N, D).
///
/// The sequence is split into P chunks along the token axis; P OS threads
/// execute the lowered plans against the AOT artifacts in `artifact_dir`
/// and the per-chunk results are re-concatenated.
pub fn run_dist_attention(
    artifact_dir: &Path,
    kind: ScheduleKind,
    n_workers: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let (fwd_plan, bwd_plan) = build_plans(kind, n_workers)?;
    run_dist_attention_planned(artifact_dir, fwd_plan, bwd_plan, q, k, v, do_)
}

/// Run a distributed attention call over *caller-supplied* lowered plans —
/// the entry point for optimizer-produced plans (`build_plans_optimized`).
/// Both plans must be schedule lowerings for the same worker count and
/// already validated.
pub fn run_dist_attention_planned(
    artifact_dir: &Path,
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let n_workers = fwd_plan.n_workers;
    if bwd_plan.n_workers != n_workers {
        return Err(anyhow!(
            "fwd plan has {n_workers} workers, bwd plan {}",
            bwd_plan.n_workers
        ));
    }

    let qs = q.chunk_axis1(n_workers);
    let ks = k.chunk_axis1(n_workers);
    let vs = v.chunk_axis1(n_workers);
    let dos = do_.map(|d| d.chunk_axis1(n_workers));

    let comms = build_network(n_workers);
    let dir: PathBuf = artifact_dir.to_path_buf();

    struct WorkerOut {
        rank: usize,
        o: Tensor,
        lse: Tensor,
        grads: Option<(Tensor, Tensor, Tensor)>,
        bytes: u64,
    }

    let mut handles = Vec::new();
    for (rank, mut comm) in comms.into_iter().enumerate() {
        let dir = dir.clone();
        let fwd_plan = fwd_plan.clone();
        let bwd_plan = bwd_plan.clone();
        let q = qs[rank].clone();
        let k = ks[rank].clone();
        let v = vs[rank].clone();
        let do_chunk = dos.as_ref().map(|d| d[rank].clone());
        handles.push(thread::spawn(move || -> Result<WorkerOut> {
            let runtime = Runtime::load(&dir)?;
            runtime.precompile(ATTN_ARTIFACTS)?;
            let (o, lse) = {
                let mut ctx = AttnCtx {
                    rank,
                    runtime: &runtime,
                    comm: &mut comm,
                    plan: &fwd_plan,
                    call_id: 0,
                };
                ctx.forward(&q, &k, &v)?
            };
            let grads = match do_chunk {
                Some(d) => {
                    let mut ctx = AttnCtx {
                        rank,
                        runtime: &runtime,
                        comm: &mut comm,
                        plan: &bwd_plan,
                        call_id: 1,
                    };
                    Some(ctx.backward(&q, &k, &v, &o, &lse, &d)?)
                }
                None => None,
            };
            let bytes = comm.bytes_sent();
            Ok(WorkerOut { rank, o, lse, grads, bytes })
        }));
    }

    let mut outs: Vec<Option<WorkerOut>> = (0..n_workers).map(|_| None).collect();
    let mut comm_bytes = 0;
    for h in handles {
        let w = h
            .join()
            .map_err(|_| anyhow!("worker thread panicked"))?
            .context("worker failed")?;
        comm_bytes += w.bytes;
        let rank = w.rank;
        outs[rank] = Some(w);
    }
    let outs: Vec<WorkerOut> = outs.into_iter().map(|o| o.unwrap()).collect();

    let o = Tensor::cat_axis1(&outs.iter().map(|w| w.o.clone()).collect::<Vec<_>>());
    // lse chunks are (H, C): concatenate along axis 1 by reusing the rank-3
    // helper on a (H, C, 1) view.
    let lse = {
        let parts: Vec<Tensor> = outs
            .iter()
            .map(|w| {
                let mut s = w.lse.shape.clone();
                s.push(1);
                Tensor::new(s, w.lse.data.clone())
            })
            .collect();
        let cat = Tensor::cat_axis1(&parts);
        Tensor::new(cat.shape[..2].to_vec(), cat.data)
    };
    let grads = if do_.is_some() {
        let dq = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().0.clone()).collect::<Vec<_>>(),
        );
        let dk = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().1.clone()).collect::<Vec<_>>(),
        );
        let dv = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().2.clone()).collect::<Vec<_>>(),
        );
        Some((dq, dk, dv))
    } else {
        None
    };
    Ok(DistAttnResult { o, lse, grads, comm_bytes })
}
