//! Deprecated free-function front door, kept as thin shims over the
//! [`Session`](super::session::Session) pipeline.
//!
//! Every entry point here predates the spec-driven API: each one
//! hand-threads a different subset of {schedule kind, varlen spec,
//! cluster, backend, tracing} through its own signature. The
//! [`RunSpec`](super::session::RunSpec) + `Session` pipeline replaces all
//! of them with one declarative surface; these shims survive only so
//! out-of-tree callers keep compiling, and each is pinned **bit-identical**
//! to its `RunSpec` translation by `rust/tests/session_golden.rs`.
//!
//! Migration table (see README "Public API" for the full map):
//!
//! | deprecated fn                | `RunSpec` translation                          |
//! |------------------------------|------------------------------------------------|
//! | `build_plans`                | `RunSpec::plans_only(kind, p)` → `plans()`     |
//! | `build_plans_optimized`      | `optimize: Schedule(opts)` + `set_costs`       |
//! | `build_plans_varlen`         | `varlen: Some(spec)` → `plans()`               |
//! | `run_dist_attention`         | `RunSpec::pjrt(dir, kind)` → `execute_with`    |
//! | `run_dist_attention_planned` | `Session::with_plans` (Pjrt) → `execute_with`  |
//! | `run_dist_attention_host`    | `Session::with_plans` (HostRef) → `execute_with` |
//! | `run_dist_attention_exec`    | `Session::with_plans` + trace/deep-copy fields |
//! | `WorkerComm::recv(from, tag)` (pre-0.3, infallible) | `recv_deadline(from, tag, deadline)` → `Result<_, CommError>` (`recv` remains as the alias armed with the session watchdog) |
//! | fail-fast `execute()` + hand-rolled retry loops (pre-0.4) | `recovery: RecoveryPolicy::{Respawn, Elastic}` → `execute_supervised()` + `recovery_report()` (the default `FailFast` keeps `execute()` semantics bit-for-bit) |

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::optimize::OptimizeOpts;
use super::plan::Plan;
use super::schedule::{ScheduleKind, VarlenSpec};
use super::session::{OptimizePolicy, RunSpec, Session, Workload};
use crate::config::ClusterSpec;
use crate::runtime::Tensor;
use crate::simulator::AttnCost;

pub use super::session::{BackendSpec, DistAttnResult, ExecOpts, ExecRun};

/// Lower and validate the forward/backward plans for a schedule.
#[deprecated(
    since = "0.2.0",
    note = "build a RunSpec (RunSpec::plans_only) and call Session::plans()"
)]
pub fn build_plans(kind: ScheduleKind, n_workers: usize) -> Result<(Arc<Plan>, Arc<Plan>)> {
    Session::new(RunSpec::plans_only(kind, n_workers))?.plans()
}

/// Optimizer-backed variant of [`build_plans`]: run the full pass pipeline
/// (role flipping, placement, prefetch autotune) against the given cluster
/// and per-pass cost models.
#[deprecated(
    since = "0.2.0",
    note = "set RunSpec::optimize = OptimizePolicy::Schedule(opts) (plus Session::set_costs \
            for explicit cost models) and call Session::plans()"
)]
pub fn build_plans_optimized(
    kind: ScheduleKind,
    n_workers: usize,
    cluster: &ClusterSpec,
    fwd_cost: &AttnCost,
    bwd_cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let mut spec = RunSpec::plans_only(kind, n_workers);
    spec.cluster = *cluster;
    spec.optimize = OptimizePolicy::Schedule(opts.clone());
    let mut session = Session::new(spec)?;
    session.set_costs(*fwd_cost, *bwd_cost);
    session.plans()
}

/// Varlen (document-packed) variant of [`build_plans`]: token-exact
/// lowering against the given chunk spec.
#[deprecated(
    since = "0.2.0",
    note = "set RunSpec::varlen = Some(spec) and call Session::plans()"
)]
pub fn build_plans_varlen(
    kind: ScheduleKind,
    spec: &VarlenSpec,
) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let mut rs = RunSpec::plans_only(kind, spec.n_chunks());
    rs.varlen = Some(spec.clone());
    Session::new(rs)?.plans()
}

/// Run DISTFLASHATTN forward (and optionally backward) over full-sequence
/// tensors against the AOT artifacts in `artifact_dir`.
#[deprecated(
    since = "0.2.0",
    note = "build a RunSpec (RunSpec::pjrt) and call Session::execute_with()"
)]
pub fn run_dist_attention(
    artifact_dir: &Path,
    kind: ScheduleKind,
    n_workers: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let mut spec = RunSpec::pjrt(artifact_dir, kind);
    spec.workload = Some(Workload::from_tensors(q, k, n_workers));
    spec.n_workers = n_workers;
    let mut session = Session::new(spec)?;
    session.execute_with(q, k, v, do_)?;
    Ok(session.take_run().expect("execute_with stored a run").result)
}

/// Run a distributed attention call over *caller-supplied* lowered plans
/// against PJRT artifacts.
#[deprecated(
    since = "0.2.0",
    note = "use Session::with_plans with a Pjrt backend and call execute_with()"
)]
pub fn run_dist_attention_planned(
    artifact_dir: &Path,
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let opts = ExecOpts {
        backend: BackendSpec::Pjrt(artifact_dir.to_path_buf()),
        ..ExecOpts::host()
    };
    #[allow(deprecated)]
    Ok(run_dist_attention_exec(fwd_plan, bwd_plan, q, k, v, do_, &opts)?.result)
}

/// Host-kernel variant: pure-Rust reference kernels, no PJRT, no
/// artifacts.
#[deprecated(
    since = "0.2.0",
    note = "use Session::with_plans with BackendSpec::HostRef and call execute_with()"
)]
pub fn run_dist_attention_host(
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    #[allow(deprecated)]
    Ok(run_dist_attention_exec(fwd_plan, bwd_plan, q, k, v, do_, &ExecOpts::host())?.result)
}

/// The general executor entry point: backend selection, optional per-op
/// tracing, optional deep-copy send baseline.
#[deprecated(
    since = "0.2.0",
    note = "use Session::with_plans (backend/trace/deep_copy_sends are RunSpec fields) and \
            call execute_with()"
)]
pub fn run_dist_attention_exec(
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
    opts: &ExecOpts,
) -> Result<ExecRun> {
    let mut spec = RunSpec::for_plans(&fwd_plan, opts.backend.clone(), q, k);
    spec.trace = opts.trace;
    spec.deep_copy_sends = opts.deep_copy_sends;
    spec.faults = opts.faults.clone();
    let mut session = Session::with_plans(spec, fwd_plan, bwd_plan)?;
    session.execute_with(q, k, v, do_)?;
    Ok(session.take_run().expect("execute_with stored a run"))
}
