//! Multi-threaded harness: spawn P workers (each with its own kernel
//! backend, mirroring one-process-per-GPU) and run a distributed attention
//! call over a full sequence. Used by `repro verify`, `repro trace`, the
//! integration tests, the executor micro-bench, and the examples.
//!
//! The harness is where the schedule IR is produced: the chosen
//! [`Schedule`] is lowered to one forward and one backward [`Plan`], both
//! validated (`validate_lowered`), and every worker executes those exact
//! plans — the same objects a simulator would time.
//!
//! [`run_dist_attention_exec`] is the general entry point: it picks the
//! kernel backend ([`BackendSpec`]) — PJRT artifacts, the pure-host
//! reference kernels, or the zero-work echo — and optionally records
//! per-op wall-clock traces merged across ranks ([`MergedTrace`]), the
//! measured side of the trace-vs-sim report.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::comm::build_network_placed;
use super::executor::{AttnCtx, MergedTrace, RunTrace, ATTN_ARTIFACTS};
use super::optimize::{optimize_schedule, OptimizeOpts};
use super::plan::{LowerOpts, Pass, Plan};
use super::schedule::{Schedule, ScheduleKind, VarlenSpec};
use crate::config::ClusterSpec;
use crate::runtime::{HostKernels, Kernels, NullKernels, Runtime, Tensor};
use crate::simulator::AttnCost;

/// Gathered results of one distributed attention call over N tokens.
#[derive(Debug)]
pub struct DistAttnResult {
    /// Normalized attention output (H, N, D).
    pub o: Tensor,
    /// Logsumexp (H, N).
    pub lse: Tensor,
    /// Gradients, present iff `do_` was supplied.
    pub grads: Option<(Tensor, Tensor, Tensor)>,
    /// Total bytes moved between workers.
    pub comm_bytes: u64,
}

/// Which kernel backend each harness worker constructs.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Real PJRT artifacts compiled from this directory (needs
    /// `make artifacts` plus the real `xla` bindings).
    Pjrt(PathBuf),
    /// Pure-Rust reference kernels — runs on a bare checkout.
    HostRef,
    /// Zero-work shape echo — transport micro-benchmarks only.
    Null,
}

/// Executor knobs for one distributed call.
#[derive(Clone, Debug)]
pub struct ExecOpts {
    pub backend: BackendSpec,
    /// Record per-op wall-clock spans, merged across ranks in the result.
    pub trace: bool,
    /// Model the pre-zero-copy send path (full-chunk allocation + memcpy
    /// per payload) — the executor micro-bench's baseline arm.
    pub deep_copy_sends: bool,
}

impl ExecOpts {
    pub fn host() -> ExecOpts {
        ExecOpts { backend: BackendSpec::HostRef, trace: false, deep_copy_sends: false }
    }
}

/// One executed distributed call: results plus (when requested) the
/// rank-merged per-op timelines and the harness wall-clock.
#[derive(Debug)]
pub struct ExecRun {
    pub result: DistAttnResult,
    pub fwd_trace: Option<MergedTrace>,
    pub bwd_trace: Option<MergedTrace>,
    /// Wall-clock of the whole call (thread spawn to last join).
    pub wall_s: f64,
}

/// Lower and validate the forward/backward plans for a schedule — shared
/// by the harness and the trainer so every consumer runs checked IR.
pub fn build_plans(kind: ScheduleKind, n_workers: usize) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let schedule = Schedule::build(kind, n_workers);
    schedule
        .validate()
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let fwd = Plan::from_schedule(&schedule, Pass::Forward);
    fwd.validate_lowered()
        .map_err(|e| anyhow!("invalid forward plan: {e}"))?;
    let bwd = Plan::from_schedule(&schedule, Pass::Backward);
    bwd.validate_lowered()
        .map_err(|e| anyhow!("invalid backward plan: {e}"))?;
    Ok((Arc::new(fwd), Arc::new(bwd)))
}

/// Optimizer-backed variant of [`build_plans`]: run the full pass pipeline
/// (role flipping, placement, prefetch autotune) against the given cluster
/// and per-pass cost models, and return validated plans the executor can
/// run directly. The flipped op stream changes *which worker computes
/// which pair* — the executor follows it literally — while the placement
/// binds mailboxes and the autotuned `prefetch_depth` drives the posted
/// receives.
pub fn build_plans_optimized(
    kind: ScheduleKind,
    n_workers: usize,
    cluster: &ClusterSpec,
    fwd_cost: &AttnCost,
    bwd_cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Result<(Arc<Plan>, Arc<Plan>)> {
    let schedule = Schedule::build(kind, n_workers);
    schedule
        .validate()
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let fwd = optimize_schedule(&schedule, Pass::Forward, cluster, fwd_cost, opts).plan;
    fwd.validate_lowered()
        .map_err(|e| anyhow!("invalid optimized forward plan: {e}"))?;
    let bwd = optimize_schedule(&schedule, Pass::Backward, cluster, bwd_cost, opts).plan;
    bwd.validate_lowered()
        .map_err(|e| anyhow!("invalid optimized backward plan: {e}"))?;
    Ok((Arc::new(fwd), Arc::new(bwd)))
}

/// Varlen (document-packed) variant of [`build_plans`]: token-exact
/// lowering against the given chunk spec — every op priced by its ragged
/// slice, chunk pairs sharing no document skipped.
/// [`run_dist_attention_planned`] splits tensors at `spec.boundaries`,
/// but note the current AOT manifests compile fixed chunk shapes: only
/// *uniform* boundaries are executable today (which still exercises the
/// doc-masked plan structure — skipped pairs never communicate); ragged
/// execution needs per-chunk artifacts (see ROADMAP, "Intra-chunk
/// document masking"). The simulators have no such restriction.
pub fn build_plans_varlen(
    kind: ScheduleKind,
    spec: &VarlenSpec,
) -> Result<(Arc<Plan>, Arc<Plan>)> {
    spec.validate().map_err(|e| anyhow!("invalid varlen spec: {e}"))?;
    let schedule = Schedule::build(kind, spec.n_chunks());
    schedule
        .validate()
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let lopts = LowerOpts { varlen: Some(Arc::new(spec.clone())), ..Default::default() };
    let fwd = Plan::from_schedule_opts(&schedule, Pass::Forward, &lopts);
    fwd.validate_lowered()
        .map_err(|e| anyhow!("invalid varlen forward plan: {e}"))?;
    let bwd = Plan::from_schedule_opts(&schedule, Pass::Backward, &lopts);
    bwd.validate_lowered()
        .map_err(|e| anyhow!("invalid varlen backward plan: {e}"))?;
    Ok((Arc::new(fwd), Arc::new(bwd)))
}

/// Run DISTFLASHATTN forward (and optionally backward) over full-sequence
/// tensors: q (H, N, D), k/v (KVH, N, D), do (H, N, D).
///
/// The sequence is split into P chunks along the token axis; P OS threads
/// execute the lowered plans against the AOT artifacts in `artifact_dir`
/// and the per-chunk results are re-concatenated.
pub fn run_dist_attention(
    artifact_dir: &Path,
    kind: ScheduleKind,
    n_workers: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let (fwd_plan, bwd_plan) = build_plans(kind, n_workers)?;
    run_dist_attention_planned(artifact_dir, fwd_plan, bwd_plan, q, k, v, do_)
}

/// Run a distributed attention call over *caller-supplied* lowered plans
/// against PJRT artifacts — the entry point for optimizer-produced plans
/// (`build_plans_optimized`). Both plans must be schedule lowerings for
/// the same worker count and already validated.
pub fn run_dist_attention_planned(
    artifact_dir: &Path,
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    let opts = ExecOpts {
        backend: BackendSpec::Pjrt(artifact_dir.to_path_buf()),
        trace: false,
        deep_copy_sends: false,
    };
    Ok(run_dist_attention_exec(fwd_plan, bwd_plan, q, k, v, do_, &opts)?.result)
}

/// Host-kernel variant: pure-Rust reference kernels, no PJRT, no
/// artifacts — the bare-checkout executor used by the prefetch stress
/// tests and `repro trace`.
pub fn run_dist_attention_host(
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> Result<DistAttnResult> {
    Ok(run_dist_attention_exec(fwd_plan, bwd_plan, q, k, v, do_, &ExecOpts::host())?.result)
}

/// The general executor entry point (see module docs): backend selection,
/// optional per-op tracing, optional deep-copy send baseline.
pub fn run_dist_attention_exec(
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
    opts: &ExecOpts,
) -> Result<ExecRun> {
    let n_workers = fwd_plan.n_workers;
    if bwd_plan.n_workers != n_workers {
        return Err(anyhow!(
            "fwd plan has {n_workers} workers, bwd plan {}",
            bwd_plan.n_workers
        ));
    }
    // both passes must agree on the chunking — a backward plan lowered
    // against different boundaries would expect different shapes and
    // pair structure than the tensors sharded below
    if fwd_plan.varlen.as_deref() != bwd_plan.varlen.as_deref() {
        return Err(anyhow!(
            "fwd and bwd plans carry different varlen chunk specs"
        ));
    }

    // equal chunks by default; ragged token boundaries for varlen plans
    let (qs, ks, vs, dos) = match fwd_plan.varlen.as_deref() {
        Some(spec) => {
            if spec.total_tokens() != q.shape[1] {
                return Err(anyhow!(
                    "varlen spec covers {} tokens but q has {}",
                    spec.total_tokens(),
                    q.shape[1]
                ));
            }
            // the AOT artifacts compile one fixed chunk shape; a ragged
            // chunk would fail the runtime's shape check mid-plan on one
            // worker and deadlock its peers' blocking recvs — reject up
            // front with the honest story instead. (The host backends have
            // no such restriction: they accept any chunk shape.)
            let c0 = spec.chunk_tokens(0);
            let uniform = (1..n_workers).all(|w| spec.chunk_tokens(w) == c0);
            if !uniform && matches!(opts.backend, BackendSpec::Pjrt(_)) {
                return Err(anyhow!(
                    "ragged varlen boundaries need per-chunk AOT artifacts; the fixed-shape \
                     manifest executes uniform chunks only (run the host backend, simulate \
                     ragged plans with the event engine, or rebalance with uniform boundaries)"
                ));
            }
            (
                q.chunk_axis1_at(&spec.boundaries),
                k.chunk_axis1_at(&spec.boundaries),
                v.chunk_axis1_at(&spec.boundaries),
                do_.map(|d| d.chunk_axis1_at(&spec.boundaries)),
            )
        }
        None => (
            q.chunk_axis1(n_workers),
            k.chunk_axis1(n_workers),
            v.chunk_axis1(n_workers),
            do_.map(|d| d.chunk_axis1(n_workers)),
        ),
    };

    // bind rank i's mailbox to slot placement[i] — the in-process
    // analogue of the launcher pinning rank i to that GPU. (A backward
    // plan optimized separately may carry a different placement; messages
    // are addressed by logical rank, so the forward placement binding
    // stays correct for both passes.)
    let comms = build_network_placed(n_workers, &fwd_plan.placement);

    struct WorkerOut {
        rank: usize,
        o: Tensor,
        lse: Tensor,
        grads: Option<(Tensor, Tensor, Tensor)>,
        bytes: u64,
        fwd_trace: RunTrace,
        bwd_trace: RunTrace,
    }

    let epoch = Instant::now();
    let mut handles = Vec::new();
    for (rank, mut comm) in comms.into_iter().enumerate() {
        let backend = opts.backend.clone();
        let trace = opts.trace;
        let deep = opts.deep_copy_sends;
        let fwd_plan = fwd_plan.clone();
        let bwd_plan = bwd_plan.clone();
        let q = qs[rank].clone();
        let k = ks[rank].clone();
        let v = vs[rank].clone();
        let do_chunk = dos.as_ref().map(|d| d[rank].clone());
        handles.push(thread::spawn(move || -> Result<WorkerOut> {
            comm.set_deep_copy_sends(deep);
            let kernels: Box<dyn Kernels> = match &backend {
                BackendSpec::Pjrt(dir) => {
                    let rt = Runtime::load(dir)?;
                    rt.precompile(ATTN_ARTIFACTS)?;
                    Box::new(rt)
                }
                BackendSpec::HostRef => Box::new(HostKernels),
                BackendSpec::Null => Box::new(NullKernels),
            };
            let epoch = trace.then_some(epoch);
            let (o, lse, fwd_trace) = {
                let mut ctx = AttnCtx {
                    rank,
                    runtime: &*kernels,
                    comm: &mut comm,
                    plan: &fwd_plan,
                    call_id: 0,
                    epoch,
                    trace: RunTrace::default(),
                };
                let (o, lse) = ctx.forward(&q, &k, &v)?;
                (o, lse, ctx.trace)
            };
            let (grads, bwd_trace) = match do_chunk {
                Some(d) => {
                    let mut ctx = AttnCtx {
                        rank,
                        runtime: &*kernels,
                        comm: &mut comm,
                        plan: &bwd_plan,
                        call_id: 1,
                        epoch,
                        trace: RunTrace::default(),
                    };
                    let g = ctx.backward(&q, &k, &v, &o, &lse, &d)?;
                    (Some(g), ctx.trace)
                }
                None => (None, RunTrace::default()),
            };
            let bytes = comm.bytes_sent();
            Ok(WorkerOut { rank, o, lse, grads, bytes, fwd_trace, bwd_trace })
        }));
    }

    let mut outs: Vec<Option<WorkerOut>> = (0..n_workers).map(|_| None).collect();
    let mut comm_bytes = 0;
    for h in handles {
        let w = h
            .join()
            .map_err(|_| anyhow!("worker thread panicked"))?
            .context("worker failed")?;
        comm_bytes += w.bytes;
        let rank = w.rank;
        outs[rank] = Some(w);
    }
    let wall_s = epoch.elapsed().as_secs_f64();
    let outs: Vec<WorkerOut> = outs.into_iter().map(|o| o.unwrap()).collect();

    let (fwd_trace, bwd_trace) = if opts.trace {
        let ft: Vec<RunTrace> = outs.iter().map(|w| w.fwd_trace.clone()).collect();
        let bt: Vec<RunTrace> = outs.iter().map(|w| w.bwd_trace.clone()).collect();
        (
            Some(MergedTrace::merge(fwd_plan.n_ops(), &ft)),
            do_.is_some().then(|| MergedTrace::merge(bwd_plan.n_ops(), &bt)),
        )
    } else {
        (None, None)
    };

    let o = Tensor::cat_axis1(&outs.iter().map(|w| w.o.clone()).collect::<Vec<_>>());
    // lse chunks are (H, C): concatenate along axis 1 by reusing the rank-3
    // helper on zero-copy (H, C, 1) views.
    let lse = {
        let parts: Vec<Tensor> = outs
            .iter()
            .map(|w| {
                let mut s = w.lse.shape.clone();
                s.push(1);
                w.lse.reshape(s)
            })
            .collect();
        let cat = Tensor::cat_axis1(&parts);
        let flat = cat.shape[..2].to_vec();
        cat.reshape(flat)
    };
    let grads = if do_.is_some() {
        let dq = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().0.clone()).collect::<Vec<_>>(),
        );
        let dk = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().1.clone()).collect::<Vec<_>>(),
        );
        let dv = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().2.clone()).collect::<Vec<_>>(),
        );
        Some((dq, dk, dv))
    } else {
        None
    };
    Ok(ExecRun {
        result: DistAttnResult { o, lse, grads, comm_bytes },
        fwd_trace,
        bwd_trace,
        wall_s,
    })
}
