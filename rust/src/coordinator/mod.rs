//! L3 coordinator: the paper's system contribution.
//!
//! * `schedule` — ring (Alg. 1) vs load-balanced (Alg. 2) plans + invariants
//! * `plan` — the schedule IR: op DAG (computes, transfers, rescales) that
//!   both the simulators and the real executor consume
//! * `comm` — P2P mailboxes, ring all-reduce (the NCCL substitute)
//! * `executor` — runs a lowered plan with real tensors against PJRT
//!   artifacts
//! * `fault` — seeded fault injection ([`FaultSpec`]) and the structured
//!   failure taxonomy ([`CommError`], [`ExecError`]) the runtime unwinds
//!   into instead of hanging or panicking
//! * `session` — the public front door: a declarative [`RunSpec`] lowered
//!   once and driven through plan → optimize → execute → trace →
//!   calibrate ([`Session`])
//! * `harness` — the pre-`Session` free functions, now thin deprecated
//!   shims pinned bit-identical to their `RunSpec` translations
//! * `checkpoint` — HF-style vs rematerialization-aware strategies (§3.3)
//! * `optimize` — cost-model-driven plan optimizer (placement, GQA role
//!   flipping, prefetch autotuning, token-level varlen rebalancing) over
//!   the lowered IR
//! * `recovery` — supervised recovery: checkpoint-replay, elastic
//!   re-lowering over P−1 survivors, and the [`RecoveryPolicy`] retry
//!   loop that turns a [`FailureReport`] into an executable restart plan

pub mod checkpoint;
pub mod comm;
pub mod executor;
pub mod fault;
pub mod harness;
pub mod optimize;
pub mod plan;
pub mod recovery;
pub mod schedule;
pub mod session;

pub use checkpoint::CkptStrategy;
pub use executor::{AttnCtx, MergedTrace, PlanIndex, RunTrace, ATTN_ARTIFACTS};
pub use fault::{
    CommError, CrashSpec, ExecError, FailureReport, FaultEvent, FaultSpec, RankFaults,
    StallKernels,
};
#[allow(deprecated)]
pub use harness::{
    build_plans, build_plans_optimized, build_plans_varlen, run_dist_attention,
    run_dist_attention_exec, run_dist_attention_host, run_dist_attention_planned,
};
pub use optimize::{
    autotune_depth, optimize_ckpt, optimize_plan, optimize_plan_with_op_costs, optimize_schedule,
    optimize_schedule_ckpt, optimize_varlen, CkptArm, CkptOptimized, OptimizeOpts, Optimized,
    VarlenOptimized,
};
pub use plan::{Kernel, LowerOpts, Pass, Payload, PayloadClass, Plan, PlanNode, PlanOp};
pub use recovery::{
    relower_elastic, CkptStore, ElasticPlan, RecoveryAttempt, RecoveryPolicy, RecoveryReport,
    RestartAction, RestartPlan,
};
pub use schedule::{ChunkSpec, ComputeOp, Schedule, ScheduleKind, StepPlan, VarlenSpec};
pub use session::{
    BackendSpec, DistAttnResult, ExecOpts, ExecRun, OptimizePolicy, RunSpec, Session,
    SessionTrace, StageAudit, Workload,
};
