//! The schedule IR: an explicit dependency-graph (DAG) of compute kernels,
//! transfers, rescale merges, and gradient returns.
//!
//! A [`Plan`] is the single executable description of one distributed
//! attention call. Three producers build plans:
//!
//! * [`Plan::from_schedule`] lowers a per-timestep [`Schedule`] (the
//!   paper's Alg. 1/2 plans) for either pass — this is what both the
//!   threaded executor (`coordinator::executor`) and the simulators run,
//!   so the timing model and the real runtime provably execute the
//!   identical op stream;
//! * [`Plan::ring_attention`] expresses Ring Attention's rotating-kv
//!   pipeline (Liu et al., 2023) directly as a dataflow DAG;
//! * [`Plan::ulysses`] expresses a DeepSpeed-Ulysses-style all-to-all
//!   resharding plan.
//!
//! Op semantics:
//! * [`PlanOp::Compute`] occupies its worker's *compute stream*. The
//!   kernel is a cost class ([`Kernel`]) resolved against an `AttnCost`
//!   at simulation time, and a real PJRT artifact at execution time.
//! * [`PlanOp::Xfer`] occupies one worker's *comm stream*: the receiver's
//!   for prefetchable payloads (kv / q — data that exists at pass start),
//!   the sender's for mid-step products (helper results, kv-grad
//!   returns). `PlanNode::worker` records the stream owner.
//!
//! Lock-step plans (`lockstep = true`, produced by lowering) preserve the
//! BSP step structure via the `step` tags — the event engine inserts a
//! barrier between steps and releases transfers up to `prefetch_depth`
//! steps early. Dataflow plans (`lockstep = false`, the baseline builders)
//! have no barriers at all: overlap emerges purely from the dependency
//! edges.
//!
//! Invariants pinned by [`Plan::validate`] / [`Plan::validate_lowered`]
//! and the property suite (`rust/tests/schedule_properties.rs`): every
//! causal pair `(p, r), r <= p` computed exactly once; every transfer
//! wired to a consumer; dependency ids strictly backward (acyclicity by
//! construction); per-(src, dst) message-tag uniqueness.
//!
//! Two degrees of freedom are left open for the plan optimizer
//! (`coordinator::optimize`): the rank→GPU [`Plan::placement`] (identity
//! by default, priced by the event engine's per-link lookup) and the
//! per-step owner/helper role flip chosen at lowering via [`LowerOpts`].

use std::sync::Arc;

use super::checkpoint::CkptStrategy;
use super::comm::Tag;
use super::schedule::{ComputeOp, Schedule, VarlenSpec};
use crate::simulator::AttnCost;

/// Index into [`Plan::ops`]. Dependencies always point to smaller ids.
pub type OpId = usize;

/// Which pass of one attention call the plan describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    Forward,
    Backward,
    /// Inference decode: one query token per running request per step
    /// against its resident paged KV-cache (see `crate::serving`).
    Decode,
}

impl Pass {
    pub fn name(&self) -> &'static str {
        match self {
            Pass::Forward => "fwd",
            Pass::Backward => "bwd",
            Pass::Decode => "decode",
        }
    }
}

/// Compute cost classes, resolved against an `AttnCost` (or a real kernel).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// Causal diagonal chunk pair (≈ half the FLOPs of a full pair).
    AttnDiag,
    /// Full (non-diagonal) chunk pair — owner-path or helper-path.
    AttnFull,
    /// Token-exact attention block: `scale` multiples of the reference
    /// full pair (`pair_full_s`). Emitted by varlen lowerings, where a
    /// chunk pair's work is the causal same-document token-pair count of
    /// its ragged slices rather than a uniform block.
    AttnTok { scale: f64 },
    /// Merge a helper partial: `rescale(·)` in forward, dq-accumulate in
    /// backward.
    Rescale,
    /// Token-exact rescale: `scale` multiples of the reference merge.
    RescaleTok { scale: f64 },
    /// Zero-cost sink that consumes kv-grad returns at the end of a
    /// backward plan (the executor's gradient drain).
    Accum,
    /// Decode-pass attention: one query row per running request against
    /// its resident paged KV. `scale` is the causal token-pair count of
    /// the batch (Σ context lengths) relative to the reference pair, so
    /// it prices off `pair_full_s` like [`Kernel::AttnTok`].
    DecodeAttn { scale: f64 },
    /// Append new (k, v) rows into paged KV-cache slots. Bandwidth-bound
    /// bookkeeping: priced off the rescale class at `scale` multiples
    /// (tokens appended relative to the reference chunk).
    KvAppend { scale: f64 },
    /// Gather a request batch's page tables into slot lists for the
    /// decode kernel — same bandwidth class as [`Kernel::KvAppend`].
    KvLookup { scale: f64 },
    /// Return a finished request's pages to the free list. Free-list
    /// surgery only; priced at zero like [`Kernel::Accum`].
    KvEvict,
    /// Literal seconds — for baseline plans whose kernels fall outside the
    /// AttnCost classes (e.g. Ulysses' head-parallel full-sequence attn).
    Raw(f64),
}

impl Kernel {
    /// The attention kernel for pair `(q, kv)` at a given token scale.
    /// Collapses to the classic variants at the reference scale so a
    /// uniform varlen spec lowers to exactly the equal-chunk plan.
    pub fn attn(q: usize, kv: usize, scale: f64) -> Kernel {
        if q == kv && scale == 0.5 {
            Kernel::AttnDiag
        } else if q != kv && scale == 1.0 {
            Kernel::AttnFull
        } else {
            Kernel::AttnTok { scale }
        }
    }

    /// The rescale kernel at a given token scale (see [`Kernel::attn`]).
    pub fn rescale(scale: f64) -> Kernel {
        if scale == 1.0 {
            Kernel::Rescale
        } else {
            Kernel::RescaleTok { scale }
        }
    }

    /// Seconds under a cost model — the single cost resolution shared by
    /// the timing engines and the rebalancer's incremental patches.
    pub fn seconds(&self, cost: &AttnCost) -> f64 {
        match self {
            Kernel::AttnDiag => cost.pair_diag_s,
            Kernel::AttnFull => cost.pair_full_s,
            Kernel::AttnTok { scale } => scale * cost.pair_full_s,
            Kernel::Rescale => cost.rescale_s,
            Kernel::RescaleTok { scale } => scale * cost.rescale_s,
            Kernel::Accum => 0.0,
            Kernel::DecodeAttn { scale } => scale * cost.pair_full_s,
            Kernel::KvAppend { scale } | Kernel::KvLookup { scale } => scale * cost.rescale_s,
            Kernel::KvEvict => 0.0,
            Kernel::Raw(s) => *s,
        }
    }
}

/// Transfer payload classes, resolved against an `AttnCost`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Payload {
    /// A (k, v) chunk — prefetchable (exists at pass start).
    Kv,
    /// Owner q (forward) or (q, o, lse, do) bundle (backward) —
    /// prefetchable.
    QBundle,
    /// Helper partial: (o, m, l) forward, dq backward — produced mid-step.
    HelperResult,
    /// (dk, dv) return from an owner to its kv lender — produced mid-step.
    KvGrad,
    /// Token-scaled variants: `scale` multiples of the reference payload,
    /// emitted by varlen lowerings where ragged chunk slices put
    /// token-exact byte counts on the wire.
    KvTok { scale: f64 },
    QBundleTok { scale: f64 },
    HelperResultTok { scale: f64 },
    KvGradTok { scale: f64 },
    /// Literal bytes — for baseline plans (e.g. all-to-all shards).
    Raw(f64),
}

/// Semantic class of a payload, ignoring token scaling — what the
/// executor and the wiring validators key on (a scaled kv chunk is still
/// a kv chunk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadClass {
    Kv,
    QBundle,
    HelperResult,
    KvGrad,
    Raw,
}

impl Payload {
    /// Token-scaled constructors that collapse to the classic variants at
    /// the reference scale (see [`Kernel::attn`]).
    pub fn kv(scale: f64) -> Payload {
        if scale == 1.0 {
            Payload::Kv
        } else {
            Payload::KvTok { scale }
        }
    }

    pub fn q_bundle(scale: f64) -> Payload {
        if scale == 1.0 {
            Payload::QBundle
        } else {
            Payload::QBundleTok { scale }
        }
    }

    pub fn helper_result(scale: f64) -> Payload {
        if scale == 1.0 {
            Payload::HelperResult
        } else {
            Payload::HelperResultTok { scale }
        }
    }

    pub fn kv_grad(scale: f64) -> Payload {
        if scale == 1.0 {
            Payload::KvGrad
        } else {
            Payload::KvGradTok { scale }
        }
    }

    pub fn class(&self) -> PayloadClass {
        match self {
            Payload::Kv | Payload::KvTok { .. } => PayloadClass::Kv,
            Payload::QBundle | Payload::QBundleTok { .. } => PayloadClass::QBundle,
            Payload::HelperResult | Payload::HelperResultTok { .. } => PayloadClass::HelperResult,
            Payload::KvGrad | Payload::KvGradTok { .. } => PayloadClass::KvGrad,
            Payload::Raw(_) => PayloadClass::Raw,
        }
    }

    /// Whether the payload exists at pass start (so it may be prefetched
    /// arbitrarily early) or is produced mid-plan by a compute op.
    pub fn prefetchable(&self) -> bool {
        matches!(
            self.class(),
            PayloadClass::Kv | PayloadClass::QBundle | PayloadClass::Raw
        )
    }

    /// Tag space this payload travels under on the comm fabric.
    pub fn tag_space(&self) -> u32 {
        match self.class() {
            PayloadClass::Kv => Tag::KV,
            PayloadClass::QBundle => Tag::Q_BUNDLE,
            PayloadClass::HelperResult => Tag::HELPER_RESULT,
            PayloadClass::KvGrad => Tag::KV_GRAD,
            PayloadClass::Raw => Tag::RAW_XFER,
        }
    }

    /// Bytes on the wire under a given cost model.
    pub fn bytes(&self, cost: &AttnCost) -> f64 {
        match self {
            Payload::Kv => cost.kv_bytes,
            Payload::QBundle => cost.q_bytes,
            Payload::HelperResult => cost.result_bytes,
            // dk/dv mirror k/v exactly
            Payload::KvGrad => cost.kv_bytes,
            Payload::KvTok { scale } => scale * cost.kv_bytes,
            Payload::QBundleTok { scale } => scale * cost.q_bytes,
            Payload::HelperResultTok { scale } => scale * cost.result_bytes,
            Payload::KvGradTok { scale } => scale * cost.kv_bytes,
            Payload::Raw(b) => *b,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum PlanOp {
    Compute {
        kernel: Kernel,
        /// `(q_chunk, kv_chunk)` for attention kernels; `None` otherwise.
        pair: Option<(usize, usize)>,
    },
    Xfer {
        src: usize,
        dst: usize,
        payload: Payload,
    },
}

#[derive(Clone, Debug, PartialEq)]
pub struct PlanNode {
    pub id: OpId,
    /// Stream owner: executing worker for computes; receiver for
    /// prefetchable transfers, sender for mid-step products.
    pub worker: usize,
    /// Logical step — barrier group for lock-step plans, phase label for
    /// dataflow plans. Nondecreasing in op order.
    pub step: usize,
    pub op: PlanOp,
    /// Data dependencies; every entry is `< id`.
    pub deps: Vec<OpId>,
}

/// Lowering choices made by the plan optimizer (`coordinator::optimize`).
/// Defaults reproduce the paper's schedule exactly.
#[derive(Clone, Debug, Default)]
pub struct LowerOpts {
    /// Steps whose helper pairs are *flipped*: instead of shipping the
    /// owner's q bundle to the helper and a partial result back, the
    /// helper ships its (k, v) chunk to the owner, which computes the
    /// pair itself as a second owner-path kernel. Pays one extra kernel
    /// on the owner's compute stream, saves `q_bytes + result_bytes -
    /// kv_bytes` on the wire — the winning trade for GQA models (small
    /// kv heads) on slow links. Indexed by schedule timestep; missing
    /// entries mean "don't flip".
    pub flip_steps: Vec<bool>,
    /// Per-*pair* role flips, finer than `flip_steps`: bit `step *
    /// n_workers + helper` set means that single helper pair is flipped
    /// even if the step as a whole is not. On a placed plan the q-vs-kv
    /// trade differs per helper pair (intra- vs inter-node owner), which
    /// a per-step decision cannot express. Stored as a packed bitmap;
    /// missing bits mean "don't flip".
    pub flip_pairs: Vec<u64>,
    /// Token-exact lowering for a document-packed batch: every op's cost
    /// payload is scaled by the chunk pair's causal same-document token
    /// count, and zero-weight pairs (chunks sharing no document) are
    /// skipped entirely. `None` reproduces the equal-chunk lowering.
    pub varlen: Option<Arc<VarlenSpec>>,
    /// Search-mode emission for the token-level rebalancer: keep
    /// zero-weight pairs *and* emit both role alternatives (helper-side
    /// and owner-side) for every helper pair, so boundary moves and
    /// per-pair flips become pure cost patches on a fixed DAG that the
    /// incremental rescorer can replay. Dense plans are timing-only —
    /// they deliberately violate the compute-once invariant and must not
    /// be validated or executed.
    pub dense_duals: bool,
    /// Gradient-checkpointing strategy the backward plan is lowered for
    /// (paper §3.3). `Some(HfStyle)` prepends the *recompute subgraph* to
    /// backward plans: a verbatim replay of the forward lowering (its
    /// computes and kv/q/result transfers) on steps `0..T`, with the
    /// backward body shifted to `T..2T+1`, so rebuilding `o`/`lse` from
    /// the layer-boundary checkpoint is priced and executed in the IR.
    /// `Some(RematAware)` and `None` leave the DAG unchanged — `o`/`lse`
    /// are already checkpointed at the FlashAttention output; the memory
    /// engine charges their `extra_saved_floats` bytes instead. Ignored
    /// for forward plans and in `dense_duals` search mode (the
    /// rebalancer's role arithmetic assumes a prefix-free DAG).
    pub ckpt: Option<CkptStrategy>,
}

impl LowerOpts {
    pub fn flip(&self, step: usize) -> bool {
        self.flip_steps.get(step).copied().unwrap_or(false)
    }

    /// Whether the single helper pair `(step, helper)` is flipped.
    pub fn flip_pair(&self, step: usize, helper: usize, n_workers: usize) -> bool {
        let bit = step * n_workers + helper;
        self.flip_pairs
            .get(bit / 64)
            .map(|w| w >> (bit % 64) & 1 == 1)
            .unwrap_or(false)
    }

    pub fn set_flip_pair(&mut self, step: usize, helper: usize, n_workers: usize, v: bool) {
        let bit = step * n_workers + helper;
        if self.flip_pairs.len() <= bit / 64 {
            self.flip_pairs.resize(bit / 64 + 1, 0);
        }
        if v {
            self.flip_pairs[bit / 64] |= 1 << (bit % 64);
        } else {
            self.flip_pairs[bit / 64] &= !(1 << (bit % 64));
        }
    }

    pub fn flipped_pair_count(&self) -> usize {
        self.flip_pairs.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub name: String,
    pub n_workers: usize,
    pub n_steps: usize,
    /// BSP step barriers between `step` groups (schedule lowerings).
    pub lockstep: bool,
    /// Whether the plan must cover each causal pair exactly once.
    pub causal: bool,
    pub pass: Pass,
    pub ops: Vec<PlanNode>,
    /// rank → GPU assignment used by the timing engines' link lookup
    /// (`ClusterSpec::link`). Identity by default; the plan optimizer
    /// permutes it so heavy edges ride fast intra-node links. Purely
    /// timing metadata — the executor's mailbox fabric is placement-
    /// agnostic, but the harness *does* consume this: it binds rank i's
    /// mailbox to slot `placement[i]`, the in-process analogue of the
    /// launcher pinning rank i to that GPU.
    pub placement: Vec<usize>,
    /// Token-level chunk spec this plan was lowered against, if any —
    /// needed by `validate` (zero-weight pairs are legitimately absent)
    /// and by ragged executors splitting tensors at its boundaries.
    pub varlen: Option<Arc<VarlenSpec>>,
    /// Prefetch pipeline depth this plan should run at: the event engine's
    /// `EventOpts::prefetch_depth` default, and the executor's switch for
    /// posting receives ahead of need (`0` = fully blocking point-of-use
    /// receives, `>= 1` = the mailbox is drained into the stash at every
    /// step boundary). Lowering defaults to 1 (the paper's §3.2 pipeline);
    /// the plan optimizer overwrites it with the autotuned knee.
    pub prefetch_depth: usize,
    /// Number of leading ops forming the HfStyle *recompute subgraph*
    /// (`ops[..recompute_ops]`): a replay of the forward lowering that a
    /// backward pass must run first to rebuild `o`/`lse` from a
    /// layer-boundary checkpoint. `0` (the default, and always for
    /// forward plans) means no recompute — the plan body starts at op 0.
    /// `validate` checks the prefix and the body each cover the causal
    /// pair set exactly once; executors run the prefix with forward
    /// semantics before the backward body.
    pub recompute_ops: usize,
}

impl Plan {
    pub(crate) fn new(
        name: &str,
        n_workers: usize,
        n_steps: usize,
        lockstep: bool,
        causal: bool,
        pass: Pass,
    ) -> Plan {
        Plan {
            name: name.to_string(),
            n_workers,
            n_steps,
            lockstep,
            causal,
            pass,
            ops: Vec::new(),
            placement: (0..n_workers).collect(),
            varlen: None,
            prefetch_depth: 1,
            recompute_ops: 0,
        }
    }

    pub(crate) fn push(&mut self, worker: usize, step: usize, op: PlanOp, deps: Vec<OpId>) -> OpId {
        let id = self.ops.len();
        self.ops.push(PlanNode { id, worker, step, op, deps });
        id
    }

    /// Lower a per-timestep [`Schedule`] to the op DAG for one pass.
    ///
    /// Emission order per step — kv transfers, q transfers, computes (each
    /// helper compute immediately followed by its result transfer; each
    /// backward owner compute by its kv-grad return), rescale merges — is
    /// exactly the order the threaded executor issues sends/recvs in, so
    /// the same node sequence drives both the simulator and the runtime.
    pub fn from_schedule(schedule: &Schedule, pass: Pass) -> Plan {
        Self::from_schedule_opts(schedule, pass, &LowerOpts::default())
    }

    /// Lowering with optimizer overrides (see [`LowerOpts`]). With default
    /// options this is exactly [`Plan::from_schedule`]; with flips set
    /// (per step or per pair), the affected helper pairs are computed
    /// owner-side off a kv fetch from the helper instead of helper-side
    /// off a q bundle; with a varlen spec, every op's cost payload is
    /// token-exact and zero-weight pairs vanish. The covered (non-zero)
    /// pair set is identical in every configuration.
    pub fn from_schedule_opts(schedule: &Schedule, pass: Pass, lopts: &LowerOpts) -> Plan {
        let p = schedule.n_workers;
        let t_steps = schedule.n_steps();
        let vl: Option<&VarlenSpec> = lopts.varlen.as_deref();
        let dense = lopts.dense_duals;
        // HfStyle checkpoints only the layer input, so the backward plan
        // must first replay the whole attention forward (steps 0..T) to
        // rebuild o/lse before the backward body (steps T..2T+1) can run.
        // Dense search plans stay prefix-free: the rebalancer's role
        // classification keys on step distances of the original body.
        let recompute = pass == Pass::Backward
            && !dense
            && lopts.ckpt == Some(CkptStrategy::HfStyle);
        let off = if recompute { t_steps } else { 0 };
        let n_steps = match pass {
            Pass::Forward => t_steps,
            // +1: the trailing kv-grad accumulation step
            Pass::Backward => off + t_steps + 1,
            // decode plans are lowered by `crate::serving`, never from a
            // training schedule
            Pass::Decode => unreachable!("decode plans are not schedule lowerings"),
        };
        let suffix = match (vl.is_some(), dense) {
            (true, true) => "-varlen-dense",
            (true, false) => "-varlen",
            (false, true) => "-dense",
            (false, false) => "",
        };
        let suffix = if recompute {
            format!("{suffix}-ckpt-hf")
        } else {
            suffix.to_string()
        };
        // token-exact scales; the reference (equal-chunk) lowering is the
        // special case where every scale collapses to 1 (or 0.5 diag)
        let pscale = |q: usize, kv: usize| {
            vl.map_or(if q == kv { 0.5 } else { 1.0 }, |v| v.pair_scale(q, kv))
        };
        let tscale = |w: usize| vl.map_or(1.0, |v| v.token_scale(w));
        // a pair is live unless its ragged slices share no document
        let live = |q: usize, kv: usize| {
            dense || vl.map_or(true, |v| v.pair_weight(q, kv) > 0.0)
        };
        let mut plan = Plan::new(
            &format!("{:?}-{}{}", schedule.kind, pass.name(), suffix),
            p,
            n_steps,
            true,
            true,
            pass,
        );
        plan.varlen = lopts.varlen.clone();
        // HfStyle recompute subgraph: a verbatim copy of the forward
        // lowering's op list on steps 0..T. Copying (rather than
        // re-emitting) guarantees the replay runs the identical kernel
        // sequence in the identical order as the real forward pass, so
        // the recomputed o/lse are bit-identical to the checkpointed ones
        // on a deterministic backend. Per-worker last prefix compute ids
        // gate the backward q bundles, which carry the rebuilt o/lse.
        let mut prefix_last_compute: Vec<Option<OpId>> = vec![None; p];
        if recompute {
            let fwd_opts = LowerOpts { ckpt: None, ..lopts.clone() };
            let fwd = Plan::from_schedule_opts(schedule, Pass::Forward, &fwd_opts);
            for n in &fwd.ops {
                if matches!(n.op, PlanOp::Compute { .. }) {
                    prefix_last_compute[n.worker] = Some(n.id);
                }
                plan.ops.push(n.clone());
            }
            plan.recompute_ops = plan.ops.len();
        }
        // kv-grad transfers awaiting each lender's trailing Accum
        let mut kvgrad_in: Vec<Vec<OpId>> = vec![Vec::new(); p];
        for (t, row) in schedule.steps.iter().enumerate() {
            // plan step: schedule steps shift past the recompute prefix
            let bt = off + t;
            let step_flip = lopts.flip(t);
            let flip_of = |helper: usize| step_flip || lopts.flip_pair(t, helper, p);
            let mut kv_xfer: Vec<Option<OpId>> = vec![None; p]; // by dst
            let mut q_xfer: Vec<Option<OpId>> = vec![None; p]; // by dst
            let mut result_xfer: Vec<Option<OpId>> = vec![None; p]; // by owner
            // flipped helper kv fetches, by helper (the kv chunk's home)
            let mut flip_kv: Vec<Option<OpId>> = vec![None; p];
            for (w, sp) in row.iter().enumerate() {
                if let Some(dst) = sp.send_kv_to {
                    if live(dst, w) {
                        let id = plan.push(
                            dst,
                            bt,
                            PlanOp::Xfer { src: w, dst, payload: Payload::kv(tscale(w)) },
                            vec![],
                        );
                        kv_xfer[dst] = Some(id);
                    }
                }
            }
            for (w, sp) in row.iter().enumerate() {
                // flipped helper pairs: the helper lends its (k, v) to the
                // owner instead of receiving the owner's q bundle
                if let Some(ComputeOp::Help { owner }) = sp.compute {
                    if (dense || flip_of(w)) && live(owner, w) {
                        let id = plan.push(
                            owner,
                            bt,
                            PlanOp::Xfer { src: w, dst: owner, payload: Payload::kv(tscale(w)) },
                            vec![],
                        );
                        flip_kv[w] = Some(id);
                    }
                }
                // unflipped helper pairs: the owner ships its q bundle
                if let Some(dst) = sp.send_q_to {
                    if (dense || !flip_of(dst)) && live(w, dst) {
                        // under HfStyle recompute the backward bundle's
                        // o/lse only exist once the sender's replay is done
                        let deps: Vec<OpId> = prefix_last_compute[w].into_iter().collect();
                        let id = plan.push(
                            dst,
                            bt,
                            PlanOp::Xfer { src: w, dst, payload: Payload::q_bundle(tscale(w)) },
                            deps,
                        );
                        q_xfer[dst] = Some(id);
                    }
                }
            }
            for (w, sp) in row.iter().enumerate() {
                match sp.compute {
                    Some(ComputeOp::Diag) => {
                        plan.push(
                            w,
                            bt,
                            PlanOp::Compute {
                                kernel: Kernel::attn(w, w, pscale(w, w)),
                                pair: Some((w, w)),
                            },
                            vec![],
                        );
                    }
                    Some(ComputeOp::Own { kv_from }) => {
                        if !live(w, kv_from) {
                            continue;
                        }
                        let kv = kv_xfer[w].expect("validated schedule: kv send matches Own");
                        let id = plan.push(
                            w,
                            bt,
                            PlanOp::Compute {
                                kernel: Kernel::attn(w, kv_from, pscale(w, kv_from)),
                                pair: Some((w, kv_from)),
                            },
                            vec![kv],
                        );
                        if pass == Pass::Backward {
                            let g = plan.push(
                                w,
                                bt,
                                PlanOp::Xfer {
                                    src: w,
                                    dst: kv_from,
                                    payload: Payload::kv_grad(tscale(kv_from)),
                                },
                                vec![id],
                            );
                            kvgrad_in[kv_from].push(g);
                        }
                    }
                    Some(ComputeOp::Help { owner }) => {
                        if !live(owner, w) {
                            continue;
                        }
                        let flip = flip_of(w);
                        if dense || !flip {
                            // helper-side: owner's q against local (k, v),
                            // partial shipped back for the merge
                            let q = q_xfer[w].expect("validated schedule: q send matches Help");
                            let id = plan.push(
                                w,
                                bt,
                                PlanOp::Compute {
                                    kernel: Kernel::attn(owner, w, pscale(owner, w)),
                                    pair: Some((owner, w)),
                                },
                                vec![q],
                            );
                            // result rides the helper's comm stream; it can
                            // leave only once the helper has both received q
                            // and finished the kernel
                            let rid = plan.push(
                                w,
                                bt,
                                PlanOp::Xfer {
                                    src: w,
                                    dst: owner,
                                    payload: Payload::helper_result(tscale(owner)),
                                },
                                vec![id, q],
                            );
                            result_xfer[owner] = Some(rid);
                        }
                        if dense || flip {
                            // owner-side (flipped): the owner computes the
                            // pair itself off the helper's kv
                            let kv = flip_kv[w].expect("flip emitted a kv fetch for every Help");
                            let id = plan.push(
                                owner,
                                bt,
                                PlanOp::Compute {
                                    kernel: Kernel::attn(owner, w, pscale(owner, w)),
                                    pair: Some((owner, w)),
                                },
                                vec![kv],
                            );
                            if pass == Pass::Backward {
                                let g = plan.push(
                                    owner,
                                    bt,
                                    PlanOp::Xfer {
                                        src: owner,
                                        dst: w,
                                        payload: Payload::kv_grad(tscale(w)),
                                    },
                                    vec![id],
                                );
                                kvgrad_in[w].push(g);
                            }
                        }
                    }
                    None => {}
                }
            }
            for (w, sp) in row.iter().enumerate() {
                if let Some(h) = sp.recv_helper_from {
                    if (dense || !flip_of(h)) && live(w, h) {
                        let mut deps = vec![
                            result_xfer[w].expect("validated schedule: helper result present"),
                        ];
                        // the owner's own inbound kv also gates the merge
                        if let Some(kv) = kv_xfer[w] {
                            deps.push(kv);
                        }
                        plan.push(
                            w,
                            bt,
                            PlanOp::Compute { kernel: Kernel::rescale(tscale(w)), pair: None },
                            deps,
                        );
                    }
                }
            }
        }
        if pass == Pass::Backward {
            for (w, deps) in kvgrad_in.into_iter().enumerate() {
                if !deps.is_empty() {
                    plan.push(
                        w,
                        off + t_steps,
                        PlanOp::Compute { kernel: Kernel::Accum, pair: None },
                        deps,
                    );
                }
            }
        }
        plan
    }

    /// Ring Attention (Liu et al., 2023) as a dataflow plan: every worker
    /// computes `P` block pairs (masked pairs included — the causally
    /// unbalanced 2× work) while kv blocks rotate around the ring. Each
    /// hop depends only on the previous hop's arrival, so compute/comm
    /// overlap emerges from the DAG rather than a flag.
    pub fn ring_attention(p: usize) -> Plan {
        assert!(p >= 1);
        let mut plan = Plan::new("ring-attention", p, p, false, false, Pass::Forward);
        // arrival op that delivered the block each worker currently holds
        let mut held: Vec<Option<OpId>> = vec![None; p];
        for t in 0..p {
            let arrivals: Vec<Option<OpId>> = held.clone();
            for w in 0..p {
                let blk = (w + p - t) % p;
                let kernel = if blk == w { Kernel::AttnDiag } else { Kernel::AttnFull };
                let deps: Vec<OpId> = arrivals[w].into_iter().collect();
                plan.push(w, t, PlanOp::Compute { kernel, pair: Some((w, blk)) }, deps);
            }
            if t + 1 < p {
                let mut next: Vec<Option<OpId>> = vec![None; p];
                for w in 0..p {
                    let dst = (w + 1) % p;
                    // forward the held block as soon as it is here — no
                    // need to wait for this step's kernel
                    let deps: Vec<OpId> = arrivals[w].into_iter().collect();
                    let id = plan.push(
                        dst,
                        t,
                        PlanOp::Xfer { src: w, dst, payload: Payload::Kv },
                        deps,
                    );
                    next[dst] = Some(id);
                }
                held = next;
            }
        }
        plan
    }

    /// DeepSpeed-Ulysses-style attention phase: all-to-all reshard in,
    /// head-parallel full-sequence attention, all-to-all reshard out.
    /// `attn_s` is the per-worker attention seconds; `in_msg_bytes` /
    /// `out_msg_bytes` are the *per-pair* shard sizes (q+k+v in, o out).
    pub fn ulysses(p: usize, attn_s: f64, in_msg_bytes: f64, out_msg_bytes: f64) -> Plan {
        assert!(p >= 1);
        let mut plan = Plan::new("ulysses-a2a", p, 3, false, false, Pass::Forward);
        let mut inbound: Vec<Vec<OpId>> = vec![Vec::new(); p];
        for src in 0..p {
            for dst in 0..p {
                if src != dst {
                    let id = plan.push(
                        dst,
                        0,
                        PlanOp::Xfer { src, dst, payload: Payload::Raw(in_msg_bytes) },
                        vec![],
                    );
                    inbound[dst].push(id);
                }
            }
        }
        let mut compute: Vec<OpId> = Vec::with_capacity(p);
        for (w, deps) in inbound.into_iter().enumerate() {
            compute.push(plan.push(
                w,
                1,
                PlanOp::Compute { kernel: Kernel::Raw(attn_s), pair: None },
                deps,
            ));
        }
        for src in 0..p {
            for dst in 0..p {
                if src != dst {
                    plan.push(
                        dst,
                        2,
                        PlanOp::Xfer { src, dst, payload: Payload::Raw(out_msg_bytes) },
                        vec![compute[src]],
                    );
                }
            }
        }
        plan
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Attention pairs `(q_chunk, kv_chunk)` with the `(step, worker)`
    /// slot computing each — the IR-level analogue of
    /// `Schedule::computed_pairs`.
    pub fn computed_pairs(&self) -> Vec<((usize, usize), (usize, usize))> {
        self.ops
            .iter()
            .filter_map(|n| match n.op {
                PlanOp::Compute { pair: Some(pr), .. } => Some((pr, (n.step, n.worker))),
                _ => None,
            })
            .collect()
    }

    /// Total bytes this plan moves under a cost model — by construction
    /// exactly what the simulators charge and (with byte-accurate costs)
    /// what the executor's `bytes_sent_global()` counts.
    pub fn total_bytes(&self, cost: &AttnCost) -> f64 {
        self.ops
            .iter()
            .map(|n| match &n.op {
                PlanOp::Xfer { payload, .. } => payload.bytes(cost),
                _ => 0.0,
            })
            .sum()
    }

    /// Every `(src, dst, Tag)` triple this plan puts on the wire for a
    /// given attention call id — the executor's exact tagging.
    pub fn wire_tags(&self, call_id: u32) -> Vec<(usize, usize, Tag)> {
        self.ops
            .iter()
            .filter_map(|n| match &n.op {
                PlanOp::Xfer { src, dst, payload } => Some((
                    *src,
                    *dst,
                    Tag::new(payload.tag_space(), call_id, n.step as u32),
                )),
                _ => None,
            })
            .collect()
    }

    /// Structural DAG invariants common to every plan: id/index agreement,
    /// backward-pointing deps (acyclicity by construction), nondecreasing
    /// steps, endpoint sanity, stream-owner convention, per-(src, dst)
    /// tag uniqueness, and — for causal plans — each causal pair computed
    /// exactly once with no non-causal pairs.
    pub fn validate(&self) -> Result<(), String> {
        let p = self.n_workers;
        if self.placement.len() != p {
            return Err(format!(
                "placement has {} entries for {p} workers",
                self.placement.len()
            ));
        }
        let mut gpu_seen = std::collections::HashSet::new();
        for (w, &g) in self.placement.iter().enumerate() {
            if !gpu_seen.insert(g) {
                return Err(format!("placement: GPU {g} assigned twice (worker {w})"));
            }
        }
        let mut prev_step = 0usize;
        for (i, n) in self.ops.iter().enumerate() {
            if n.id != i {
                return Err(format!("op {i}: id {} out of sync", n.id));
            }
            if n.worker >= p {
                return Err(format!("op {i}: worker {} out of range", n.worker));
            }
            if n.step >= self.n_steps {
                return Err(format!("op {i}: step {} >= n_steps {}", n.step, self.n_steps));
            }
            if n.step < prev_step {
                return Err(format!("op {i}: step {} decreases (prev {prev_step})", n.step));
            }
            prev_step = n.step;
            for &d in &n.deps {
                if d >= i {
                    return Err(format!("op {i}: dep {d} not strictly earlier"));
                }
            }
            if let PlanOp::Xfer { src, dst, payload } = &n.op {
                if src == dst || *src >= p || *dst >= p {
                    return Err(format!("op {i}: bad endpoints {src}->{dst}"));
                }
                let want = if payload.prefetchable() { *dst } else { *src };
                if n.worker != want {
                    return Err(format!(
                        "op {i}: xfer stream owner {} (want {want} for {payload:?})",
                        n.worker
                    ));
                }
            }
        }
        // tag uniqueness per (src, dst): the mailbox fabric keys messages
        // by (sender, tag) at each receiver
        let mut seen = std::collections::HashSet::new();
        for (src, dst, tag) in self.wire_tags(0) {
            if !seen.insert((src, dst, tag)) {
                return Err(format!("duplicate wire tag {tag:?} on {src}->{dst}"));
            }
        }
        if self.recompute_ops > self.ops.len() {
            return Err(format!(
                "recompute_ops {} exceeds op count {}",
                self.recompute_ops,
                self.ops.len()
            ));
        }
        if self.recompute_ops > 0 && self.pass != Pass::Backward {
            return Err("recompute prefix on a non-backward plan".into());
        }
        if self.causal {
            // separate pair maps for the recompute prefix and the plan
            // body: under HfStyle checkpointing the backward plan replays
            // the whole forward, so the prefix must itself cover the
            // causal set exactly once, independently of the body
            let mut count = vec![vec![0usize; p]; p];
            let mut rcount = vec![vec![0usize; p]; p];
            for n in &self.ops {
                if let PlanOp::Compute { pair: Some((q, kv)), .. } = n.op {
                    let (t, w) = (n.step, n.worker);
                    if q >= p || kv >= p {
                        return Err(format!("pair ({q},{kv}) out of range at t={t} w={w}"));
                    }
                    if kv > q {
                        return Err(format!("non-causal pair ({q},{kv}) at t={t} w={w}"));
                    }
                    if n.id < self.recompute_ops {
                        rcount[q][kv] += 1;
                    } else {
                        count[q][kv] += 1;
                    }
                }
            }
            for q in 0..p {
                for kv in 0..=q {
                    // under a varlen spec, chunk pairs whose ragged slices
                    // share no document carry zero work and are
                    // legitimately absent (the causal-masking win)
                    let required = self
                        .varlen
                        .as_deref()
                        .map_or(true, |v| v.pair_weight(q, kv) > 0.0);
                    match count[q][kv] {
                        1 => {}
                        0 if !required => {}
                        0 => return Err(format!("pair ({q},{kv}) never computed")),
                        n => return Err(format!("pair ({q},{kv}) computed {n} times")),
                    }
                    if self.recompute_ops > 0 {
                        match rcount[q][kv] {
                            1 => {}
                            0 if !required => {}
                            0 => {
                                return Err(format!(
                                    "pair ({q},{kv}) missing from recompute prefix"
                                ))
                            }
                            n => {
                                return Err(format!(
                                    "pair ({q},{kv}) recomputed {n} times in prefix"
                                ))
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Stricter wiring checks for schedule-lowered plans: every owner-path
    /// compute fetches its kv from the chunk's home worker, every
    /// helper-path compute is fed by the owner's q bundle and answered by
    /// a result transfer, every rescale consumes a helper result, and
    /// backward kv-grad returns are all drained by a trailing Accum.
    pub fn validate_lowered(&self) -> Result<(), String> {
        self.validate()?;
        let mut kvgrad_expected = 0usize;
        let mut kvgrad_drained = 0usize;
        let dep_class = |n: &PlanNode, class: PayloadClass, pred: &dyn Fn(usize, usize) -> bool| {
            n.deps.iter().any(|&d| {
                matches!(
                    &self.ops[d].op,
                    PlanOp::Xfer { src, dst, payload }
                        if payload.class() == class && pred(*src, *dst)
                )
            })
        };
        for n in &self.ops {
            match &n.op {
                PlanOp::Compute {
                    kernel: Kernel::AttnFull | Kernel::AttnTok { .. },
                    pair: Some((q, kv)),
                } if q != kv => {
                    if n.worker == *q {
                        // owner path: direct kv fetch from the home worker
                        let ok = dep_class(n, PayloadClass::Kv, &|s, d| s == *kv && d == *q);
                        if !ok {
                            return Err(format!(
                                "op {}: own-path pair ({q},{kv}) lacks kv fetch dep",
                                n.id
                            ));
                        }
                    } else if n.worker == *kv {
                        // helper path: owner's q bundle in, result out
                        let ok = dep_class(n, PayloadClass::QBundle, &|s, d| s == *q && d == *kv);
                        if !ok {
                            return Err(format!(
                                "op {}: helper pair ({q},{kv}) lacks q bundle dep",
                                n.id
                            ));
                        }
                        let answered = self.ops.iter().any(|m| {
                            matches!(
                                &m.op,
                                PlanOp::Xfer { src, dst, payload }
                                    if payload.class() == PayloadClass::HelperResult
                                        && *src == *kv && *dst == *q && m.deps.contains(&n.id)
                            )
                        });
                        if !answered {
                            return Err(format!(
                                "op {}: helper pair ({q},{kv}) never ships its result",
                                n.id
                            ));
                        }
                    } else {
                        return Err(format!(
                            "op {}: pair ({q},{kv}) on uninvolved worker {}",
                            n.id, n.worker
                        ));
                    }
                }
                PlanOp::Compute { kernel: Kernel::Rescale | Kernel::RescaleTok { .. }, .. } => {
                    let ok = dep_class(n, PayloadClass::HelperResult, &|_, d| d == n.worker);
                    if !ok {
                        return Err(format!("op {}: rescale lacks helper-result dep", n.id));
                    }
                }
                PlanOp::Compute { kernel: Kernel::Accum, .. } => {
                    for &d in &n.deps {
                        match &self.ops[d].op {
                            PlanOp::Xfer { dst, payload, .. }
                                if payload.class() == PayloadClass::KvGrad
                                    && *dst == n.worker =>
                            {
                                kvgrad_drained += 1;
                            }
                            other => {
                                return Err(format!(
                                    "op {}: accum dep {d} is not an inbound kv-grad ({other:?})",
                                    n.id
                                ))
                            }
                        }
                    }
                }
                PlanOp::Xfer { payload, .. } if payload.class() == PayloadClass::KvGrad => {
                    kvgrad_expected += 1
                }
                _ => {}
            }
        }
        if kvgrad_expected != kvgrad_drained {
            return Err(format!(
                "{kvgrad_expected} kv-grad returns but {kvgrad_drained} drained by Accum"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::ScheduleKind;

    fn cost() -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6,
            q_bytes: 0.5e6,
            result_bytes: 0.6e6,
            overlap: true,
        }
    }

    #[test]
    fn lowered_plans_validate() {
        for p in 1..=16 {
            for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
                let s = Schedule::build(kind, p);
                for pass in [Pass::Forward, Pass::Backward] {
                    let plan = Plan::from_schedule(&s, pass);
                    plan.validate_lowered()
                        .unwrap_or_else(|e| panic!("{kind:?} P={p} {pass:?}: {e}"));
                }
            }
        }
    }

    #[test]
    fn lowered_pairs_match_schedule() {
        for p in [1usize, 2, 5, 8, 13] {
            let s = Schedule::balanced(p);
            let mut a: Vec<_> = s.computed_pairs().into_iter().map(|(pr, _)| pr).collect();
            let mut b: Vec<_> = Plan::from_schedule(&s, Pass::Forward)
                .computed_pairs()
                .into_iter()
                .map(|(pr, _)| pr)
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b, "P={p}");
        }
    }

    #[test]
    fn backward_adds_grad_returns() {
        let s = Schedule::balanced(8);
        let fwd = Plan::from_schedule(&s, Pass::Forward);
        let bwd = Plan::from_schedule(&s, Pass::Backward);
        let grads = bwd
            .ops
            .iter()
            .filter(|n| matches!(n.op, PlanOp::Xfer { payload: Payload::KvGrad, .. }))
            .count();
        let owns = fwd
            .ops
            .iter()
            .filter(|n| {
                matches!(&n.op, PlanOp::Compute { kernel: Kernel::AttnFull, pair: Some((q, _)) }
                    if n.worker == *q)
            })
            .count();
        assert_eq!(grads, owns, "one (dk,dv) return per owner-path compute");
        assert!(bwd.n_steps == fwd.n_steps + 1);
    }

    #[test]
    fn hf_ckpt_backward_lowers_with_recompute_prefix() {
        for p in [1usize, 2, 5, 8] {
            for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
                let s = Schedule::build(kind, p);
                let lopts =
                    LowerOpts { ckpt: Some(CkptStrategy::HfStyle), ..Default::default() };
                let bwd = Plan::from_schedule_opts(&s, Pass::Backward, &lopts);
                bwd.validate_lowered()
                    .unwrap_or_else(|e| panic!("{kind:?} P={p}: {e}"));
                let fwd = Plan::from_schedule(&s, Pass::Forward);
                // the prefix is a verbatim copy of the forward lowering
                assert_eq!(bwd.recompute_ops, fwd.n_ops(), "{kind:?} P={p}");
                assert_eq!(&bwd.ops[..bwd.recompute_ops], &fwd.ops[..]);
                // the body is the plain backward shifted past the prefix
                let plain = Plan::from_schedule(&s, Pass::Backward);
                assert_eq!(bwd.n_ops() - bwd.recompute_ops, plain.n_ops());
                for (b, o) in bwd.ops[bwd.recompute_ops..].iter().zip(&plain.ops) {
                    assert_eq!(b.step, o.step + fwd.n_steps);
                    assert_eq!(b.worker, o.worker);
                    assert_eq!(b.op, o.op);
                }
                assert_eq!(bwd.n_steps, fwd.n_steps + plain.n_steps);
                assert!(bwd.name.ends_with("-ckpt-hf"), "{}", bwd.name);
            }
        }
    }

    #[test]
    fn remat_aware_lowering_is_unchanged() {
        let s = Schedule::balanced(8);
        let lopts = LowerOpts { ckpt: Some(CkptStrategy::RematAware), ..Default::default() };
        let bwd = Plan::from_schedule_opts(&s, Pass::Backward, &lopts);
        assert_eq!(bwd.recompute_ops, 0);
        assert_eq!(bwd, Plan::from_schedule(&s, Pass::Backward));
        // forward lowering never grows a prefix, whatever the strategy
        let fwd = Plan::from_schedule_opts(&s, Pass::Forward, &LowerOpts {
            ckpt: Some(CkptStrategy::HfStyle),
            ..Default::default()
        });
        assert_eq!(fwd, Plan::from_schedule(&s, Pass::Forward));
    }

    #[test]
    fn ring_attention_plan_shape() {
        let p = 8;
        let plan = Plan::ring_attention(p);
        plan.validate().unwrap();
        // full P^2 pairs (masked ones included — the 2x work)
        assert_eq!(plan.computed_pairs().len(), p * p);
        // each of the P-1 rotation rounds moves P blocks
        let kv = plan
            .ops
            .iter()
            .filter(|n| matches!(n.op, PlanOp::Xfer { payload: Payload::Kv, .. }))
            .count();
        assert_eq!(kv, p * (p - 1));
        // exactly double the causal plan's kv traffic
        let causal = Plan::from_schedule(&Schedule::ring(p), Pass::Forward);
        assert_eq!(plan.total_bytes(&cost()), 2.0 * causal.total_bytes(&cost()));
    }

    #[test]
    fn ulysses_plan_shape() {
        let p = 4;
        let plan = Plan::ulysses(p, 1e-3, 2e6, 1e6);
        plan.validate().unwrap();
        let xfers = plan
            .ops
            .iter()
            .filter(|n| matches!(n.op, PlanOp::Xfer { .. }))
            .count();
        assert_eq!(xfers, 2 * p * (p - 1));
        assert_eq!(plan.total_bytes(&cost()), (p * (p - 1)) as f64 * 3e6);
    }

    #[test]
    fn validate_rejects_mutations() {
        let s = Schedule::balanced(8);
        // drop the kv-fetch dependency of an own-path compute
        let mut plan = Plan::from_schedule(&s, Pass::Forward);
        let victim = plan
            .ops
            .iter()
            .position(|n| {
                matches!(&n.op, PlanOp::Compute { kernel: Kernel::AttnFull, pair: Some((q, _)) }
                    if n.worker == *q)
            })
            .unwrap();
        plan.ops[victim].deps.clear();
        assert!(plan.validate_lowered().is_err());

        // duplicate a pair
        let mut plan = Plan::from_schedule(&s, Pass::Forward);
        if let PlanOp::Compute { pair, .. } = &mut plan.ops[victim].op {
            *pair = Some((0, 0));
        }
        assert!(plan.validate().is_err());

        // forward-pointing dependency
        let mut plan = Plan::from_schedule(&s, Pass::Forward);
        let last = plan.ops.len() - 1;
        plan.ops[0].deps.push(last);
        assert!(plan.validate().is_err());
    }
}
