//! Fault-tolerance layer: seeded fault injection and the structured error
//! taxonomy the runtime unwinds into.
//!
//! A [`FaultSpec`] rides `RunSpec::faults` (JSON like everything else) and
//! describes per-rank fault events: message delay/reorder, message drop
//! with bounded retransmit, worker stall (straggler slowdown), and worker
//! crash at a given step. Every injection decision is drawn from a
//! per-rank deterministic stream (`Rng::new(seed ^ rank)`) keyed only to
//! that rank's own send/step sequence, so a fault scenario reproduces
//! bit-for-bit from its seed — and delay/drop faults must leave the
//! executed *outputs* bit-identical too (tags are unique per message, so
//! at-least-once delivery plus stash dedup gives exactly-once semantics).
//!
//! Detection is layered:
//! * `WorkerComm::recv_deadline` returns [`CommError::Timeout`] instead of
//!   blocking forever (the watchdog budget is derived from the event
//!   engine's predicted makespan — see `Session`);
//! * a failing rank broadcasts an abort poison message, so every peer
//!   unwinds into [`ExecError::PeerFailed`] at its own (step, op) instead
//!   of hanging;
//! * worker panics are captured and named (`ExecError::Panicked`) even
//!   outside chaos mode.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::comm::Tag;
use crate::coordinator::plan::Pass;
use crate::runtime::{Kernels, Tensor, Value};
use crate::util::{Json, Rng};

/// Crash injection point: `rank` dies at the start of op-step `step` of
/// `pass` (before any kernel or transfer of that step runs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrashSpec {
    pub rank: usize,
    pub step: usize,
    pub pass: Pass,
}

/// Deterministic, seeded fault scenario. All probabilities are per
/// message; `stalls` and `crash` are pinned to explicit ranks. A spec with
/// every probability at zero and no stalls/crash still *arms* the
/// instrumented comm path (sequence numbers, watchdog, abort checks) —
/// that is the configuration the zero-fault overhead gate measures.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Root seed; rank r draws its injection stream from `seed ^ r`.
    pub seed: u64,
    /// Probability a message is held back and reordered past later
    /// traffic (released after `delay_sends` subsequent sends, or at the
    /// next flush point — never across a blocking receive).
    pub delay_prob: f64,
    /// Hold-back window for delayed messages, in subsequent sends.
    pub delay_sends: usize,
    /// Probability a message's first transmission is "lost": the sender
    /// retransmits duplicate-flagged copies until one lands, and the
    /// receiver dedups — exactly-once delivery, bit-identical outputs.
    pub drop_prob: f64,
    /// Upper bound on wire copies per dropped message (at least one copy
    /// is always delivered; delivery is guaranteed, duplicates are not).
    pub max_retransmits: usize,
    /// `(rank, factor)` straggler slowdowns: kernels on that rank take
    /// `factor`× their measured time (injected by [`StallKernels`]).
    pub stalls: Vec<(usize, f64)>,
    /// Optional crash injection point.
    pub crash: Option<CrashSpec>,
    /// Explicit recv watchdog budget in seconds. `None` derives one from
    /// the event engine's predicted makespan (generous multiplier).
    pub watchdog_s: Option<f64>,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 0,
            delay_prob: 0.0,
            delay_sends: 2,
            drop_prob: 0.0,
            max_retransmits: 2,
            stalls: Vec::new(),
            crash: None,
            watchdog_s: None,
        }
    }
}

impl FaultSpec {
    /// A delay/reorder + drop/retransmit scenario: message-level chaos
    /// that must leave outputs bit-identical to the fault-free run.
    pub fn chaos(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            delay_prob: 0.3,
            delay_sends: 3,
            drop_prob: 0.25,
            max_retransmits: 3,
            ..FaultSpec::default()
        }
    }

    /// Straggler slowdown for `rank` (1.0 when not pinned).
    pub fn stall_factor(&self, rank: usize) -> f64 {
        self.stalls
            .iter()
            .find(|&&(r, _)| r == rank)
            .map(|&(_, f)| f)
            .unwrap_or(1.0)
    }

    /// Largest pinned slowdown factor (>= 1.0) — scales the watchdog's
    /// sim-derived budget so a deliberate straggler is not misread as a
    /// hang.
    pub fn max_stall_factor(&self) -> f64 {
        self.stalls.iter().map(|&(_, f)| f).fold(1.0, f64::max)
    }

    /// Spec-level sanity, mirrored by `RunSpec::validate`.
    pub fn validate(&self, n_workers: usize) -> Result<()> {
        for p in [self.delay_prob, self.drop_prob] {
            if !(0.0..=1.0).contains(&p) {
                anyhow::bail!("fault probabilities must be in [0, 1], got {p}");
            }
        }
        if self.delay_prob > 0.0 && self.delay_sends == 0 {
            anyhow::bail!("delay_sends must be >= 1 when delay_prob > 0");
        }
        if self.drop_prob > 0.0 && self.max_retransmits == 0 {
            anyhow::bail!("max_retransmits must be >= 1 when drop_prob > 0");
        }
        let mut stalled = std::collections::HashSet::new();
        for &(r, f) in &self.stalls {
            if r >= n_workers {
                anyhow::bail!("stall rank {r} out of range for {n_workers} workers");
            }
            if f < 1.0 || f.is_nan() {
                anyhow::bail!("stall factor must be >= 1.0, got {f}");
            }
            // two entries for one rank would silently apply only the first
            // (`stall_factor` scans front to back) — reject the ambiguity
            if !stalled.insert(r) {
                anyhow::bail!("duplicate stall rank {r} (one slowdown factor per rank)");
            }
        }
        if let Some(c) = &self.crash {
            if c.rank >= n_workers {
                anyhow::bail!("crash rank {} out of range for {n_workers} workers", c.rank);
            }
        }
        if let Some(w) = self.watchdog_s {
            if w <= 0.0 || w.is_nan() {
                anyhow::bail!("watchdog_s must be positive, got {w}");
            }
        }
        Ok(())
    }

    /// One-line JSON object (the `RunSpec::to_json` embedding).
    pub fn to_json(&self) -> String {
        let crash = match &self.crash {
            None => "null".to_string(),
            Some(c) => format!(
                "{{\"rank\": {}, \"step\": {}, \"pass\": \"{}\"}}",
                c.rank,
                c.step,
                c.pass.name()
            ),
        };
        let stalls: Vec<String> =
            self.stalls.iter().map(|&(r, f)| format!("[{r}, {f:?}]")).collect();
        let watchdog = match self.watchdog_s {
            None => "null".to_string(),
            Some(w) => format!("{w:?}"),
        };
        format!(
            "{{\"seed\": {}, \"delay_prob\": {:?}, \"delay_sends\": {}, \"drop_prob\": {:?}, \
             \"max_retransmits\": {}, \"stalls\": [{}], \"crash\": {}, \"watchdog_s\": {}}}",
            self.seed,
            self.delay_prob,
            self.delay_sends,
            self.drop_prob,
            self.max_retransmits,
            stalls.join(", "),
            crash,
            watchdog
        )
    }

    /// Parse the `to_json` form. Missing keys take defaults; present keys
    /// with the wrong type are errors, never silent defaults.
    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        if j.as_obj().is_none() {
            anyhow::bail!("faults must be an object");
        }
        let d = FaultSpec::default();
        let seed = match j.get("seed") {
            None | Some(Json::Null) => 0,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as u64,
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("faults.seed: bad u64 string {s:?}"))?,
            Some(v) => anyhow::bail!("faults.seed must be a non-negative integer, got {v:?}"),
        };
        let watchdog_s = match j.get("watchdog_s") {
            None | Some(Json::Null) => None,
            Some(Json::Num(n)) => Some(*n),
            Some(v) => anyhow::bail!("faults.watchdog_s must be a number or null, got {v:?}"),
        };
        let stalls = match j.get("stalls") {
            None => Vec::new(),
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("faults.stalls must be an array"))?;
                arr.iter()
                    .map(|e| {
                        let pair = e.as_arr().filter(|a| a.len() == 2);
                        let (r, f) = match pair {
                            Some(a) => (a[0].as_usize(), a[1].as_f64()),
                            None => (None, None),
                        };
                        match (r, f) {
                            (Some(r), Some(f)) => Ok((r, f)),
                            _ => anyhow::bail!("faults.stalls entries must be [rank, factor]"),
                        }
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        let crash = match j.get("crash") {
            None | Some(Json::Null) => None,
            Some(c) => {
                let rank = c
                    .at("rank")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("faults.crash.rank must be an integer"))?;
                let step = c
                    .at("step")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("faults.crash.step must be an integer"))?;
                let pass = match c.at("pass").as_str() {
                    Some("fwd") | None => Pass::Forward,
                    Some("bwd") => Pass::Backward,
                    Some(other) => {
                        anyhow::bail!("faults.crash.pass must be \"fwd\" or \"bwd\", got {other:?}")
                    }
                };
                Some(CrashSpec { rank, step, pass })
            }
        };
        Ok(FaultSpec {
            seed,
            delay_prob: opt_f64(j, "delay_prob", d.delay_prob)?,
            delay_sends: opt_usize(j, "delay_sends", d.delay_sends)?,
            drop_prob: opt_f64(j, "drop_prob", d.drop_prob)?,
            max_retransmits: opt_usize(j, "max_retransmits", d.max_retransmits)?,
            stalls,
            crash,
            watchdog_s,
        })
    }
}

fn opt_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Num(n)) => Ok(*n),
        Some(v) => anyhow::bail!("faults.{key} must be a number, got {v:?}"),
    }
}

fn opt_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("faults.{key} must be a non-negative integer")),
    }
}

/// Structured comm-layer failure. `WorkerComm::recv_deadline` and the
/// collectives return these instead of panicking.
#[derive(Clone, Debug, PartialEq)]
pub enum CommError {
    /// The deadline expired with no matching message — the watchdog's
    /// verdict that a peer stalled or died silently.
    Timeout { from: usize, tag: Tag, waited_s: f64 },
    /// The peer's mailbox hung up (its thread unwound and dropped its
    /// channel endpoints).
    Closed { peer: usize },
    /// A peer broadcast an abort: it failed first, with `origin`.
    Aborted { origin: Box<ExecError> },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { from, tag, waited_s } => write!(
                f,
                "recv deadline expired after {waited_s:.3}s waiting on rank {from} tag {tag:?}"
            ),
            CommError::Closed { peer } => write!(f, "channel to rank {peer} closed"),
            CommError::Aborted { origin } => write!(f, "peer aborted: {origin}"),
        }
    }
}

/// Structured executor-level failure, stamped with the failing rank. The
/// vendored `anyhow` carries only display text, so the typed values flow
/// through `Session::failure_report()`, not error downcasting.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecError {
    /// A peer failed; this rank unwound at its own (step, op).
    PeerFailed { rank: usize, step: usize, op: String },
    /// The `FaultSpec` crashed this rank at `step`.
    InjectedCrash { rank: usize, step: usize },
    /// recv deadline expired on this rank waiting for `from`.
    Timeout { rank: usize, from: usize, step: usize, op: String },
    /// Kernel or runtime failure on this rank.
    Failed { rank: usize, msg: String },
    /// This rank's worker thread panicked; the payload text is attached.
    Panicked { rank: usize, msg: String },
}

impl ExecError {
    /// The rank this failure is attributed to: the *origin* rank for
    /// `PeerFailed`, the failing rank otherwise.
    pub fn rank(&self) -> usize {
        match self {
            ExecError::PeerFailed { rank, .. }
            | ExecError::InjectedCrash { rank, .. }
            | ExecError::Timeout { rank, .. }
            | ExecError::Failed { rank, .. }
            | ExecError::Panicked { rank, .. } => *rank,
        }
    }

    /// True for the secondary failures a root cause fans out into.
    pub fn is_collateral(&self) -> bool {
        matches!(self, ExecError::PeerFailed { .. })
    }

    /// Lift a comm failure observed by `rank` at (step, op) into the
    /// executor taxonomy.
    pub fn from_comm(rank: usize, e: CommError, step: usize, op: &str) -> ExecError {
        match e {
            CommError::Timeout { from, .. } => {
                ExecError::Timeout { rank, from, step, op: op.to_string() }
            }
            CommError::Closed { peer } => {
                ExecError::PeerFailed { rank: peer, step, op: op.to_string() }
            }
            CommError::Aborted { origin } => {
                ExecError::PeerFailed { rank: origin.rank(), step, op: op.to_string() }
            }
        }
    }
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::PeerFailed { rank, step, op } => {
                write!(f, "peer rank {rank} failed (observed at step {step}, {op})")
            }
            ExecError::InjectedCrash { rank, step } => {
                write!(f, "rank {rank}: injected crash at step {step}")
            }
            ExecError::Timeout { rank, from, step, op } => write!(
                f,
                "rank {rank}: watchdog timeout waiting on rank {from} at step {step}, {op}"
            ),
            ExecError::Failed { rank, msg } => write!(f, "rank {rank} failed: {msg}"),
            ExecError::Panicked { rank, msg } => write!(f, "rank {rank} panicked: {msg}"),
        }
    }
}

/// One injected fault occurrence. Only rank-deterministic events are
/// logged (sender-side delay/retransmit decisions, rank-local stalls and
/// crashes), so the aggregated per-rank log reproduces exactly from the
/// `FaultSpec` seed; receiver-side dedup discards depend on arrival
/// timing and are deliberately not events.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// `rank` held a message to `to` back for `held_for` subsequent sends.
    Delayed { rank: usize, to: usize, tag: Tag, held_for: usize },
    /// `rank`'s first transmission to `to` was dropped; `copies`
    /// dup-flagged retransmits went on the wire instead.
    Retransmitted { rank: usize, to: usize, tag: Tag, copies: usize },
    /// `rank` runs its kernels `factor`× slower for the whole run.
    Stalled { rank: usize, factor: f64 },
    /// `rank` crashed at `step`.
    Crashed { rank: usize, step: usize },
}

/// Sender-side injection verdict for one outbound message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SendFault {
    /// Hold the message back for this many subsequent sends (0 = send now).
    pub hold_for: usize,
    /// Wire copies to deliver (1 = normal; >1 = dup-flagged retransmits).
    pub copies: usize,
}

/// Per-rank fault-injection state, owned by that rank's `WorkerComm`.
/// Decisions are drawn from `Rng::new(spec.seed ^ rank)` in send/step
/// order, which is deterministic per rank — so the event log is too.
#[derive(Clone, Debug)]
pub struct RankFaults {
    pub rank: usize,
    spec: FaultSpec,
    rng: Rng,
    crash_fired: bool,
    events: Vec<FaultEvent>,
}

impl RankFaults {
    pub fn new(rank: usize, spec: &FaultSpec) -> RankFaults {
        RankFaults {
            rank,
            spec: spec.clone(),
            rng: Rng::new(spec.seed ^ rank as u64),
            crash_fired: false,
            events: Vec::new(),
        }
    }

    /// Draw the injection decision for one outbound message. Exactly two
    /// uniform draws per send regardless of outcome, so the stream stays
    /// aligned across scenarios that share a seed.
    pub fn on_send(&mut self, to: usize, tag: Tag) -> SendFault {
        let (drop_roll, delay_roll) = (self.rng.f32() as f64, self.rng.f32() as f64);
        let mut fault = SendFault { hold_for: 0, copies: 1 };
        if self.spec.drop_prob > 0.0 && drop_roll < self.spec.drop_prob {
            fault.copies = 1 + self.rng.below(self.spec.max_retransmits.max(1));
            self.events.push(FaultEvent::Retransmitted {
                rank: self.rank,
                to,
                tag,
                copies: fault.copies,
            });
        }
        if self.spec.delay_prob > 0.0 && delay_roll < self.spec.delay_prob {
            fault.hold_for = self.spec.delay_sends.max(1);
            self.events.push(FaultEvent::Delayed {
                rank: self.rank,
                to,
                tag,
                held_for: fault.hold_for,
            });
        }
        fault
    }

    /// Crash check at the start of an op-step; fires at most once.
    pub fn crash_due(&mut self, pass: Pass, step: usize) -> bool {
        let hit = matches!(
            self.spec.crash,
            Some(c) if c.rank == self.rank && c.pass == pass && c.step == step
        );
        let due = !self.crash_fired && hit;
        if due {
            self.crash_fired = true;
            self.events.push(FaultEvent::Crashed { rank: self.rank, step });
        }
        due
    }

    /// Record this rank's pinned stall (called once by the session when
    /// wrapping the backend in [`StallKernels`]).
    pub fn note_stall(&mut self, factor: f64) {
        self.events.push(FaultEvent::Stalled { rank: self.rank, factor });
    }

    pub fn stall_factor(&self) -> f64 {
        self.spec.stall_factor(self.rank)
    }

    pub fn take_events(&mut self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.events)
    }
}

/// Straggler injection: a `Kernels` wrapper that sleeps
/// `(factor - 1) × elapsed` after each inner kernel, making the wrapped
/// backend behave `factor`× slower without touching kernel numerics.
pub struct StallKernels {
    pub inner: Box<dyn Kernels>,
    pub factor: f64,
}

impl Kernels for StallKernels {
    fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let start = Instant::now();
        let out = self.inner.run(name, inputs)?;
        if self.factor > 1.0 {
            std::thread::sleep(Duration::from_secs_f64(
                start.elapsed().as_secs_f64() * (self.factor - 1.0),
            ));
        }
        Ok(out)
    }
}

/// Per-rank failure set from one execution, stored on the `Session` for
/// post-mortem (the vendored `anyhow` cannot downcast, so the typed
/// errors travel here). `partial_fwd`/`partial_bwd` hold whatever traced
/// spans the surviving ranks flushed before unwinding.
#[derive(Clone, Debug, Default)]
pub struct FailureReport {
    /// One entry per failed rank, in rank order.
    pub failures: Vec<ExecError>,
    /// Merged forward-pass spans from ranks that produced any (only
    /// populated when the spec traced).
    pub partial_fwd: Option<crate::coordinator::executor::MergedTrace>,
    /// Merged backward-pass spans from ranks that produced any.
    pub partial_bwd: Option<crate::coordinator::executor::MergedTrace>,
}

impl FailureReport {
    /// The failure everything else cascaded from: the first
    /// non-collateral entry (injected crash, timeout, kernel failure,
    /// panic), falling back to the first entry.
    pub fn root_cause(&self) -> Option<&ExecError> {
        self.failures
            .iter()
            .find(|e| !e.is_collateral())
            .or_else(|| self.failures.first())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_spec_json_roundtrips() {
        let spec = FaultSpec {
            stalls: vec![(0, 1.5), (3, 2.25)],
            crash: Some(CrashSpec { rank: 2, step: 5, pass: Pass::Backward }),
            watchdog_s: Some(12.5),
            ..FaultSpec::chaos(42)
        };
        let j = Json::parse(&spec.to_json()).expect("emitted JSON parses");
        assert_eq!(FaultSpec::from_json(&j).unwrap(), spec);
        // defaults: an empty object is the all-zero spec
        let empty = Json::parse("{}").unwrap();
        assert_eq!(FaultSpec::from_json(&empty).unwrap(), FaultSpec::default());
        // wrong-typed fields are errors, never silent defaults
        let bad = Json::parse(r#"{"delay_prob": "high"}"#).unwrap();
        assert!(FaultSpec::from_json(&bad).is_err());
    }

    #[test]
    fn rank_faults_are_deterministic_per_seed() {
        let spec = FaultSpec::chaos(7);
        let mut a = RankFaults::new(3, &spec);
        let mut b = RankFaults::new(3, &spec);
        let tag = Tag::new(1, 0, 0);
        for i in 0..50 {
            assert_eq!(a.on_send(i % 4, tag), b.on_send(i % 4, tag));
        }
        assert_eq!(a.take_events(), b.take_events());
        // a different rank draws a different stream from the same spec
        let mut c = RankFaults::new(4, &spec);
        let c_events: Vec<_> = (0..50).map(|i| c.on_send(i % 4, tag)).collect();
        let a_again: Vec<_> = {
            let mut a2 = RankFaults::new(3, &spec);
            (0..50).map(|i| a2.on_send(i % 4, tag)).collect()
        };
        assert_ne!(c_events, a_again, "per-rank streams must differ");
    }

    #[test]
    fn crash_fires_exactly_once_at_its_step() {
        let spec = FaultSpec {
            crash: Some(CrashSpec { rank: 1, step: 2, pass: Pass::Forward }),
            ..FaultSpec::default()
        };
        let mut f = RankFaults::new(1, &spec);
        assert!(!f.crash_due(Pass::Forward, 0));
        assert!(!f.crash_due(Pass::Backward, 2), "pass must match");
        assert!(f.crash_due(Pass::Forward, 2));
        assert!(!f.crash_due(Pass::Forward, 2), "fires at most once");
        // the wrong rank never fires
        let mut other = RankFaults::new(0, &spec);
        assert!(!other.crash_due(Pass::Forward, 2));
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let ok = FaultSpec::chaos(1);
        assert!(ok.validate(4).is_ok());
        let bad = FaultSpec { delay_prob: 1.5, ..FaultSpec::default() };
        assert!(bad.validate(4).is_err());
        let bad = FaultSpec { stalls: vec![(9, 1.5)], ..FaultSpec::default() };
        assert!(bad.validate(4).is_err());
        let bad = FaultSpec { stalls: vec![(0, 0.5)], ..FaultSpec::default() };
        assert!(bad.validate(4).is_err(), "slowdown < 1 is a speedup, reject");
        let bad = FaultSpec {
            crash: Some(CrashSpec { rank: 4, step: 0, pass: Pass::Forward }),
            ..FaultSpec::default()
        };
        assert!(bad.validate(4).is_err());
        // two stall entries for one rank: only the first would apply
        let bad = FaultSpec { stalls: vec![(1, 2.0), (1, 3.0)], ..FaultSpec::default() };
        let err = bad.validate(4).unwrap_err();
        assert!(
            format!("{err}").contains("duplicate stall rank 1"),
            "pin the rejection message: {err}"
        );
        // distinct ranks with equal factors stay legal
        let ok = FaultSpec { stalls: vec![(1, 2.0), (2, 2.0)], ..FaultSpec::default() };
        assert!(ok.validate(4).is_ok());
    }

    #[test]
    fn root_cause_skips_collateral_failures() {
        let report = FailureReport {
            failures: vec![
                ExecError::PeerFailed { rank: 2, step: 1, op: "recv kv".into() },
                ExecError::InjectedCrash { rank: 2, step: 0 },
                ExecError::PeerFailed { rank: 2, step: 3, op: "send q".into() },
            ],
            ..FailureReport::default()
        };
        assert_eq!(
            report.root_cause(),
            Some(&ExecError::InjectedCrash { rank: 2, step: 0 })
        );
    }
}
