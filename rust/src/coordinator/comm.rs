//! Host-side communication substrate: the NCCL substitute.
//!
//! Each worker thread owns a `WorkerComm`: senders to every peer, its own
//! receiver, and a stash for out-of-order arrivals. Messages are tagged, so
//! eager (non-blocking) sends at the top of a timestep give the same
//! overlap semantics the paper gets from a second CUDA stream: the payload
//! is already in the receiver's mailbox by the time it blocks on `recv`.
//!
//! Sends are **zero-copy**: tensors are `Arc`-backed
//! (`runtime::tensor`), so enqueueing a whole (k, v) chunk is a refcount
//! bump — no allocation, no memcpy (the legacy deep-copy path survives
//! behind [`WorkerComm::set_deep_copy_sends`] for the executor
//! micro-bench's A/B comparison). On the receive side,
//! [`WorkerComm::drain_pending`] sweeps every already-arrived message into
//! the stash without blocking — the prefetch engine's "posted receives" —
//! so a `recv` at compute time is a stash hit. Stash queues are
//! `VecDeque`s: repeated same-tag messages pop FIFO in O(1).
//!
//! **Fault tolerance** (`coordinator::fault`): `recv` is sugar for
//! [`WorkerComm::recv_deadline`], which returns
//! `Result<Vec<Tensor>, CommError>` — a watchdog timeout instead of an
//! unbounded hang. A failing rank calls
//! [`WorkerComm::broadcast_abort`], and the poison message unwinds every
//! peer's blocking receive into [`CommError::Aborted`]. When a seeded
//! [`RankFaults`] is armed, sends pass through an injection pipeline:
//! delayed messages are held back and released after later traffic (or at
//! the next blocking receive — held traffic is always flushed before this
//! rank blocks, so injection cannot deadlock the fabric), and dropped
//! messages are retransmitted as duplicate-flagged copies that the
//! receiver dedups by `(sender, seq)` — at-least-once delivery plus dedup
//! gives exactly-once semantics, so chaos runs stay bit-identical.
//! Per-(sender, tag) FIFO is preserved: a send on a lane first flushes any
//! held traffic on that same lane.
//!
//! Per-worker byte counters feed the communication-volume reports (paper
//! §D) and count *wire* copies (a retransmitted message pays per copy);
//! the ring all-reduce implements the gradient synchronization the
//! trainer needs (the paper trains with FSDP/DDP outside the attention —
//! here parameters are replicated, so a plain ring all-reduce suffices).

use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::fault::{CommError, ExecError, FaultEvent, RankFaults};
use crate::coordinator::plan::Pass;
use crate::runtime::Tensor;

/// Message tag: unique per (semantic space, step, counter). Spaces keep
/// attention steps, gradient returns, and all-reduce rounds from colliding
/// across layers and training steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag {
    pub space: u32,
    pub a: u32,
    pub b: u32,
}

impl Tag {
    pub const KV: u32 = 1;
    pub const Q_BUNDLE: u32 = 2;
    pub const HELPER_RESULT: u32 = 3;
    pub const KV_GRAD: u32 = 4;
    pub const ALL_REDUCE: u32 = 5;
    pub const GATHER: u32 = 6;
    pub const BARRIER: u32 = 7;
    /// Raw plan-IR transfers (baseline plans outside the attention spaces).
    pub const RAW_XFER: u32 = 8;
    /// Abort poison broadcasts. Matched by message kind, not tag — the
    /// space exists only so aborts are recognizable in diagnostics.
    pub const ABORT: u32 = 999;

    pub fn new(space: u32, a: u32, b: u32) -> Tag {
        Tag { space, a, b }
    }
}

/// Wire-level message class. `Data` is the fault-free fast path (no
/// sequence bookkeeping, no dedup lookup on receive). `Dup` marks
/// retransmitted copies of one logical message — the receiver delivers
/// the first `(sender, seq)` it sees and drops the rest. `Abort` is the
/// failure poison: it carries the origin's typed error and matches any
/// pending receive.
enum MsgKind {
    Data,
    Dup(u64),
    Abort(ExecError),
}

struct Message {
    from: usize,
    tag: Tag,
    kind: MsgKind,
    tensors: Vec<Tensor>,
}

/// A delayed logical send: its wire copies, parked until `release_after`
/// more sends age it out (or a flush point releases it early).
struct Held {
    to: usize,
    tag: Tag,
    release_after: usize,
    msgs: Vec<Message>,
}

/// What [`WorkerComm::accept`] made of one inbound message.
enum Accepted {
    Data(usize, Tag, Vec<Tensor>),
    Duplicate,
    Abort(ExecError),
}

/// Build the fully-connected mailbox fabric for `p` workers (identity
/// placement: rank i's mailbox at slot i).
pub fn build_network(p: usize) -> Vec<WorkerComm> {
    let identity: Vec<usize> = (0..p).collect();
    build_network_placed(p, &identity)
}

/// Placement-aware fabric: rank `i`'s mailbox lives at *slot*
/// `placement[i]` — the in-process analogue of a launcher binding rank i
/// to GPU `placement[i]` (`Plan::placement`). Every worker's sender table
/// is permuted identically, so messages stay addressed by logical rank
/// and the executor is placement-agnostic; byte counters stay
/// rank-indexed.
pub fn build_network_placed(p: usize, placement: &[usize]) -> Vec<WorkerComm> {
    assert_eq!(placement.len(), p, "placement must cover every rank");
    let mut slot_senders = Vec::with_capacity(p);
    let mut slot_receivers: Vec<Option<Receiver<Message>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Message>();
        slot_senders.push(tx);
        slot_receivers.push(Some(rx));
    }
    let bytes: Arc<Vec<AtomicU64>> = Arc::new((0..p).map(|_| AtomicU64::new(0)).collect());
    // rank j's mailbox is the channel at slot placement[j]
    let senders: Vec<Sender<Message>> =
        placement.iter().map(|&g| slot_senders[g].clone()).collect();
    (0..p)
        .map(|rank| WorkerComm {
            rank,
            n_workers: p,
            senders: senders.clone(),
            rx: slot_receivers[placement[rank]]
                .take()
                .expect("placement must be a permutation of 0..p"),
            stash: HashMap::new(),
            bytes_sent: bytes.clone(),
            deep_copy_sends: false,
            faults: None,
            deadline: None,
            seq: 0,
            seen_dups: HashSet::new(),
            held: Vec::new(),
            pending_abort: None,
            failure: None,
        })
        .collect()
}

pub struct WorkerComm {
    pub rank: usize,
    pub n_workers: usize,
    senders: Vec<Sender<Message>>,
    rx: Receiver<Message>,
    /// Out-of-order / prefetched arrivals, FIFO per (sender, tag).
    /// Invariant: a present entry's queue is never empty.
    stash: HashMap<(usize, Tag), VecDeque<Vec<Tensor>>>,
    bytes_sent: Arc<Vec<AtomicU64>>,
    /// Legacy pre-zero-copy send path: materialize a private allocation
    /// for every payload tensor before it enters the channel.
    deep_copy_sends: bool,
    /// Seeded fault injection for this rank; `None` is the uninstrumented
    /// fast path (sends go straight to the wire, no rng draws).
    faults: Option<RankFaults>,
    /// Default watchdog budget applied by [`WorkerComm::recv`]; `None`
    /// blocks unboundedly (the pre-fault-tolerance behavior).
    deadline: Option<Duration>,
    /// Logical-send counter backing `MsgKind::Dup` ids.
    seq: u64,
    /// `(sender, seq)` pairs already delivered — retransmit dedup.
    seen_dups: HashSet<(usize, u64)>,
    /// Delay-injected sends parked for reordering, insertion order.
    held: Vec<Held>,
    /// First abort poison observed; every later comm call fails with it.
    pending_abort: Option<ExecError>,
    /// This rank's own typed failure, recorded on the way out so the
    /// session can report it (the vendored `anyhow` cannot downcast).
    failure: Option<ExecError>,
}

impl WorkerComm {
    /// Model the pre-zero-copy executor (every send pays a full-chunk
    /// allocation + memcpy). Only the micro-bench and tests flip this.
    pub fn set_deep_copy_sends(&mut self, on: bool) {
        self.deep_copy_sends = on;
    }

    /// Arm seeded fault injection for this rank.
    pub fn set_faults(&mut self, faults: RankFaults) {
        self.faults = Some(faults);
    }

    /// Install the default watchdog budget [`WorkerComm::recv`] applies.
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    /// The default watchdog budget (see [`WorkerComm::set_deadline`]).
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline
    }

    /// Record this rank's typed failure (first one wins).
    pub fn record_failure(&mut self, e: ExecError) {
        if self.failure.is_none() {
            self.failure = Some(e);
        }
    }

    /// The typed failure recorded on this rank, if any.
    pub fn failure(&self) -> Option<&ExecError> {
        self.failure.as_ref()
    }

    pub fn take_failure(&mut self) -> Option<ExecError> {
        self.failure.take()
    }

    /// Drain the injection event log (empty when faults are unarmed).
    pub fn take_fault_events(&mut self) -> Vec<FaultEvent> {
        self.faults.as_mut().map(|f| f.take_events()).unwrap_or_default()
    }

    /// Executor step-boundary check: injected crash due at this (pass,
    /// step), or a peer's abort already in flight. Two `Option` loads on
    /// the fault-free path.
    pub fn fault_check(&mut self, pass: Pass, step: usize) -> Result<(), ExecError> {
        if self.faults.is_none() && self.pending_abort.is_none() {
            return Ok(());
        }
        if let Some(f) = &mut self.faults {
            if f.crash_due(pass, step) {
                return Err(ExecError::InjectedCrash { rank: self.rank, step });
            }
        }
        if self.pending_abort.is_none() {
            self.drain_pending();
        }
        if let Some(origin) = &self.pending_abort {
            return Err(ExecError::PeerFailed {
                rank: origin.rank(),
                step,
                op: format!("{} step boundary", pass.name()),
            });
        }
        Ok(())
    }

    /// Tell every peer this rank failed, so their blocking receives
    /// unwind into [`CommError::Aborted`] instead of hanging. Best-effort
    /// by design: a peer that already unwound has hung up, and that is
    /// fine. Held (delay-injected) traffic is flushed first so the poison
    /// cannot overtake real payloads this rank still owes.
    pub fn broadcast_abort(&mut self, err: &ExecError) {
        let _ = self.flush_all_held();
        for to in 0..self.n_workers {
            if to != self.rank {
                let _ = self.senders[to].send(Message {
                    from: self.rank,
                    tag: Tag::new(Tag::ABORT, 0, 0),
                    kind: MsgKind::Abort(err.clone()),
                    tensors: Vec::new(),
                });
            }
        }
    }

    /// Non-blocking tagged send (the "second stream": returns immediately).
    /// Zero-copy: the payload enters the channel as refcount bumps. With
    /// faults armed the message may be held back (delay/reorder) or
    /// fanned into duplicate-flagged retransmit copies (drop injection) —
    /// either way delivery is guaranteed and exactly-once.
    pub fn send(&mut self, to: usize, tag: Tag, tensors: Vec<Tensor>) -> Result<(), CommError> {
        let tensors = if self.deep_copy_sends {
            tensors.iter().map(Tensor::deep_clone).collect()
        } else {
            tensors
        };
        let fault = match &mut self.faults {
            None => {
                return self.wire(
                    to,
                    Message { from: self.rank, tag, kind: MsgKind::Data, tensors },
                )
            }
            Some(f) => f.on_send(to, tag),
        };
        // every send ages earlier held traffic by one
        self.age_held()?;
        let msgs: Vec<Message> = if fault.copies == 1 {
            vec![Message { from: self.rank, tag, kind: MsgKind::Data, tensors }]
        } else {
            self.seq += 1;
            let seq = self.seq;
            (0..fault.copies)
                .map(|_| Message {
                    from: self.rank,
                    tag,
                    kind: MsgKind::Dup(seq),
                    tensors: tensors.clone(),
                })
                .collect()
        };
        if fault.hold_for > 0 {
            // joins the park after any same-lane entries: FIFO preserved
            self.held.push(Held { to, tag, release_after: fault.hold_for, msgs });
            Ok(())
        } else {
            // same-lane held traffic must hit the wire first (FIFO)
            self.flush_held_lane(to, tag)?;
            for m in msgs {
                self.wire(to, m)?;
            }
            Ok(())
        }
    }

    /// Put one message on the wire, paying byte accounting per copy.
    fn wire(&self, to: usize, msg: Message) -> Result<(), CommError> {
        let nbytes: usize = msg.tensors.iter().map(|t| t.numel() * 4).sum();
        self.bytes_sent[self.rank].fetch_add(nbytes as u64, Ordering::Relaxed);
        self.senders[to].send(msg).map_err(|_| CommError::Closed { peer: to })
    }

    /// Age held sends by one and release the ones whose hold expired.
    fn age_held(&mut self) -> Result<(), CommError> {
        if self.held.is_empty() {
            return Ok(());
        }
        for h in &mut self.held {
            h.release_after = h.release_after.saturating_sub(1);
        }
        let mut i = 0;
        while i < self.held.len() {
            if self.held[i].release_after == 0 {
                let Held { to, msgs, .. } = self.held.remove(i);
                for m in msgs {
                    self.wire(to, m)?;
                }
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    /// Release held sends on one `(to, tag)` lane, oldest first.
    fn flush_held_lane(&mut self, to: usize, tag: Tag) -> Result<(), CommError> {
        let mut i = 0;
        while i < self.held.len() {
            if self.held[i].to == to && self.held[i].tag == tag {
                let Held { to: dest, msgs, .. } = self.held.remove(i);
                for m in msgs {
                    self.wire(dest, m)?;
                }
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    /// Force every injected-delay message onto the wire now. Blocking
    /// receives do this implicitly; call it at a pass boundary when this
    /// rank will not block again but peers still expect its traffic.
    pub fn flush_sends(&mut self) -> Result<(), CommError> {
        self.flush_all_held()
    }

    /// Release everything parked, oldest first. Called before any
    /// blocking wait (and on drop): a peer may be blocked on exactly this
    /// traffic, so injection must never hold a message across a wait.
    fn flush_all_held(&mut self) -> Result<(), CommError> {
        while !self.held.is_empty() {
            let Held { to, msgs, .. } = self.held.remove(0);
            for m in msgs {
                self.wire(to, m)?;
            }
        }
        Ok(())
    }

    /// Classify one inbound message: abort poison, duplicate to drop, or
    /// data to deliver.
    fn accept(&mut self, msg: Message) -> Accepted {
        match msg.kind {
            MsgKind::Abort(e) => {
                self.pending_abort = Some(e.clone());
                Accepted::Abort(e)
            }
            MsgKind::Dup(seq) => {
                if self.seen_dups.insert((msg.from, seq)) {
                    Accepted::Data(msg.from, msg.tag, msg.tensors)
                } else {
                    Accepted::Duplicate
                }
            }
            MsgKind::Data => Accepted::Data(msg.from, msg.tag, msg.tensors),
        }
    }

    /// Sweep every message already sitting in the mailbox into the stash
    /// without blocking — the prefetch engine "posting receives ahead of
    /// need". Returns how many payloads were staged (deduped retransmits
    /// and abort poisons are absorbed, not staged).
    pub fn drain_pending(&mut self) -> usize {
        let mut n = 0;
        while let Ok(msg) = self.rx.try_recv() {
            match self.accept(msg) {
                Accepted::Data(from, tag, tensors) => {
                    self.stash.entry((from, tag)).or_default().push_back(tensors);
                    n += 1;
                }
                Accepted::Duplicate | Accepted::Abort(_) => {}
            }
        }
        n
    }

    fn stash_pop(&mut self, from: usize, tag: Tag) -> Option<Vec<Tensor>> {
        if let Entry::Occupied(mut e) = self.stash.entry((from, tag)) {
            // invariant violation if empty: entries are removed when drained
            let t = e.get_mut().pop_front().expect("stash entries are never empty");
            if e.get().is_empty() {
                e.remove();
            }
            return Some(t);
        }
        None
    }

    /// Blocking tagged receive under this comm's default deadline (none
    /// unless fault tolerance armed one — then a silent peer surfaces as
    /// [`CommError::Timeout`] instead of a hang). A prefetched or
    /// out-of-order arrival is a single-lookup stash hit.
    pub fn recv(&mut self, from: usize, tag: Tag) -> Result<Vec<Tensor>, CommError> {
        self.recv_deadline(from, tag, self.deadline)
    }

    /// Blocking tagged receive with an explicit watchdog budget.
    /// `deadline: None` waits unboundedly. Fails fast on a peer's abort
    /// poison ([`CommError::Aborted`]) — including one observed by an
    /// earlier call — and flushes this rank's own held traffic before
    /// blocking, so fault injection cannot self-deadlock.
    pub fn recv_deadline(
        &mut self,
        from: usize,
        tag: Tag,
        deadline: Option<Duration>,
    ) -> Result<Vec<Tensor>, CommError> {
        if let Some(origin) = &self.pending_abort {
            return Err(CommError::Aborted { origin: Box::new(origin.clone()) });
        }
        if let Some(t) = self.stash_pop(from, tag) {
            return Ok(t);
        }
        self.flush_all_held()?;
        let start = Instant::now();
        loop {
            let msg = match deadline {
                None => match self.rx.recv() {
                    Ok(m) => m,
                    Err(_) => return Err(CommError::Closed { peer: from }),
                },
                Some(d) => {
                    let remaining = d.saturating_sub(start.elapsed());
                    if remaining.is_zero() {
                        return Err(CommError::Timeout {
                            from,
                            tag,
                            waited_s: start.elapsed().as_secs_f64(),
                        });
                    }
                    match self.rx.recv_timeout(remaining) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => {
                            return Err(CommError::Timeout {
                                from,
                                tag,
                                waited_s: start.elapsed().as_secs_f64(),
                            })
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            return Err(CommError::Closed { peer: from })
                        }
                    }
                }
            };
            match self.accept(msg) {
                Accepted::Abort(origin) => {
                    return Err(CommError::Aborted { origin: Box::new(origin) })
                }
                Accepted::Duplicate => {}
                Accepted::Data(f, t, tensors) => {
                    if f == from && t == tag {
                        return Ok(tensors);
                    }
                    self.stash.entry((f, t)).or_default().push_back(tensors);
                }
            }
        }
    }

    /// Total bytes this worker has sent so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent[self.rank].load(Ordering::Relaxed)
    }

    /// Bytes sent across all workers (global comm volume).
    pub fn bytes_sent_global(&self) -> u64 {
        self.bytes_sent.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather, the standard
    /// 2(P-1)/P · bytes algorithm. `round` must be globally unique per call
    /// site (e.g. derived from train step + param index).
    ///
    /// Segment payloads are materialized copies, deliberately NOT
    /// `flat_view`s: `t` is mutated right after every hop, so a shared
    /// buffer would trigger a whole-tensor copy-on-write per hop — worse
    /// than the n/p segment copy.
    pub fn all_reduce_sum(&mut self, round: u32, t: &mut Tensor) -> Result<(), CommError> {
        let p = self.n_workers;
        if p == 1 {
            return Ok(());
        }
        let n = t.numel();
        // segment boundaries (last segment absorbs the remainder)
        let seg = |i: usize| -> std::ops::Range<usize> {
            let base = n / p;
            let start = i * base;
            let end = if i == p - 1 { n } else { start + base };
            start..end
        };
        let next = (self.rank + 1) % p;
        let prev = (self.rank + p - 1) % p;
        // reduce-scatter: after P-1 hops, segment (rank+1)%p is fully
        // reduced at this rank
        for step in 0..p - 1 {
            let send_seg = (self.rank + p - step) % p;
            let recv_seg = (self.rank + p - step - 1) % p;
            let tag = Tag::new(Tag::ALL_REDUCE, round, step as u32);
            let payload = Tensor::new(
                vec![seg(send_seg).len()],
                t.data()[seg(send_seg)].to_vec(),
            );
            self.send(next, tag, vec![payload])?;
            let got = self.recv(prev, tag)?;
            let r = seg(recv_seg);
            for (dst, src) in t.data_mut()[r].iter_mut().zip(got[0].data()) {
                *dst += src;
            }
        }
        // all-gather the reduced segments
        for step in 0..p - 1 {
            let send_seg = (self.rank + p - step + 1) % p;
            let recv_seg = (self.rank + p - step) % p;
            let tag = Tag::new(Tag::ALL_REDUCE, round, (p + step) as u32);
            let payload = Tensor::new(
                vec![seg(send_seg).len()],
                t.data()[seg(send_seg)].to_vec(),
            );
            self.send(next, tag, vec![payload])?;
            let got = self.recv(prev, tag)?;
            let r = seg(recv_seg);
            t.data_mut()[r].copy_from_slice(got[0].data());
        }
        Ok(())
    }

    /// All-gather a per-worker tensor; returns all P tensors in rank order.
    pub fn all_gather(&mut self, round: u32, t: &Tensor) -> Result<Vec<Tensor>, CommError> {
        let tag = Tag::new(Tag::GATHER, round, 0);
        for to in 0..self.n_workers {
            if to != self.rank {
                self.send(to, tag, vec![t.clone()])?;
            }
        }
        (0..self.n_workers)
            .map(|from| {
                if from == self.rank {
                    Ok(t.clone())
                } else {
                    Ok(self.recv(from, tag)?.remove(0))
                }
            })
            .collect()
    }

    /// Full barrier (used between training steps in tests).
    pub fn barrier(&mut self, round: u32) -> Result<(), CommError> {
        let tag = Tag::new(Tag::BARRIER, round, 0);
        let token = Tensor::scalar(self.rank as f32);
        for to in 0..self.n_workers {
            if to != self.rank {
                self.send(to, tag, vec![token.clone()])?;
            }
        }
        for from in 0..self.n_workers {
            if from != self.rank {
                self.recv(from, tag)?;
            }
        }
        Ok(())
    }
}

impl Drop for WorkerComm {
    fn drop(&mut self) {
        // a held message may be the very thing a peer is blocked on
        let _ = self.flush_all_held();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::FaultSpec;
    use std::thread;

    fn spawn_workers<F, R>(p: usize, f: F) -> Vec<R>
    where
        F: Fn(WorkerComm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let comms = build_network(p);
        let f = Arc::new(f);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_out_of_order_delivery() {
        let res = spawn_workers(2, |mut c| {
            if c.rank == 0 {
                c.send(1, Tag::new(9, 0, 0), vec![Tensor::scalar(1.0)]).unwrap();
                c.send(1, Tag::new(9, 0, 1), vec![Tensor::scalar(2.0)]).unwrap();
                0.0
            } else {
                // receive in reverse order: stash must kick in
                let b = c.recv(0, Tag::new(9, 0, 1)).unwrap()[0].as_scalar();
                let a = c.recv(0, Tag::new(9, 0, 0)).unwrap()[0].as_scalar();
                a * 10.0 + b
            }
        });
        assert_eq!(res[1], 12.0);
    }

    #[test]
    fn sends_are_zero_copy_and_deep_mode_is_not() {
        // channels work without threads: exercise both ends in-line
        let mut comms = build_network(2);
        let t = Tensor::new(vec![4, 4], (0..16).map(|x| x as f32).collect());
        comms[0].send(1, Tag::new(9, 1, 0), vec![t.clone()]).unwrap();
        let got = comms[1].recv(0, Tag::new(9, 1, 0)).unwrap();
        assert!(got[0].shares_buffer(&t), "zero-copy send must share storage");
        assert_eq!(got[0], t);

        comms[0].set_deep_copy_sends(true);
        comms[0].send(1, Tag::new(9, 1, 1), vec![t.clone()]).unwrap();
        let got = comms[1].recv(0, Tag::new(9, 1, 1)).unwrap();
        assert!(!got[0].shares_buffer(&t), "deep mode must materialize");
        assert_eq!(got[0], t);
        // byte accounting identical in both modes
        assert_eq!(comms[0].bytes_sent(), 2 * 16 * 4);
    }

    #[test]
    fn drain_pending_stages_and_recv_hits_fifo() {
        let mut comms = build_network(2);
        let tag = Tag::new(9, 2, 0);
        let other = Tag::new(9, 2, 1);
        // repeated same-tag sends must pop FIFO; interleave another tag
        for i in 0..50 {
            comms[0].send(1, tag, vec![Tensor::scalar(i as f32)]).unwrap();
            comms[0].send(1, other, vec![Tensor::scalar(-(i as f32))]).unwrap();
        }
        let staged = comms[1].drain_pending();
        assert_eq!(staged, 100);
        assert_eq!(comms[1].drain_pending(), 0, "second drain finds nothing");
        for i in 0..50 {
            assert_eq!(comms[1].recv(0, tag).unwrap()[0].as_scalar(), i as f32);
        }
        for i in 0..50 {
            assert_eq!(comms[1].recv(0, other).unwrap()[0].as_scalar(), -(i as f32));
        }
    }

    #[test]
    fn ring_all_reduce_sums() {
        for p in [1, 2, 3, 4, 7] {
            let res = spawn_workers(p, move |mut c| {
                // tensor of length 10 (not divisible by most p): each worker
                // contributes rank+1 everywhere
                let mut t = Tensor::full(&[10], (c.rank + 1) as f32);
                c.all_reduce_sum(1, &mut t).unwrap();
                t
            });
            let want = (p * (p + 1) / 2) as f32;
            for t in res {
                assert!(t.data().iter().all(|&x| x == want), "p={p}");
            }
        }
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let res = spawn_workers(3, |mut c| {
            let t = Tensor::scalar(c.rank as f32 * 5.0);
            let all = c.all_gather(2, &t).unwrap();
            all.iter().map(|x| x.as_scalar()).collect::<Vec<_>>()
        });
        for r in res {
            assert_eq!(r, vec![0.0, 5.0, 10.0]);
        }
    }

    #[test]
    fn byte_accounting() {
        let res = spawn_workers(2, |mut c| {
            if c.rank == 0 {
                c.send(1, Tag::new(8, 0, 0), vec![Tensor::zeros(&[100])]).unwrap();
            } else {
                c.recv(0, Tag::new(8, 0, 0)).unwrap();
            }
            c.barrier(99).unwrap();
            c.bytes_sent_global()
        });
        // 100 f32 payload + 2 barrier scalars
        assert_eq!(res[0], 400 + 8);
    }

    #[test]
    fn held_sends_flush_before_blocking_recv() {
        // force every send to be delayed: a blocked receiver would hang
        // forever unless the sender's own blocking recv flushes its park
        let spec = FaultSpec {
            seed: 3,
            delay_prob: 1.0,
            delay_sends: 100,
            ..FaultSpec::default()
        };
        let res = spawn_workers(2, move |mut c| {
            c.set_faults(RankFaults::new(c.rank, &spec));
            let tag = Tag::new(9, 3, 0);
            let peer = 1 - c.rank;
            c.send(peer, tag, vec![Tensor::scalar(c.rank as f32)]).unwrap();
            // both ranks' payloads are parked; recv must flush ours so the
            // peer can make progress, and symmetrically
            c.recv(peer, tag).unwrap()[0].as_scalar()
        });
        assert_eq!(res, vec![1.0, 0.0]);
    }

    #[test]
    fn same_lane_fifo_survives_delay_injection() {
        let spec = FaultSpec {
            seed: 5,
            delay_prob: 0.5,
            delay_sends: 2,
            ..FaultSpec::default()
        };
        let mut comms = build_network(2);
        comms[0].set_faults(RankFaults::new(0, &spec));
        let tag = Tag::new(9, 4, 0);
        for i in 0..64 {
            comms[0].send(1, tag, vec![Tensor::scalar(i as f32)]).unwrap();
        }
        // sender will not block again in this test: release its park
        comms[0].flush_sends().unwrap();
        // receiver side: repeated same-tag messages must still pop FIFO
        for i in 0..64 {
            assert_eq!(comms[1].recv(0, tag).unwrap()[0].as_scalar(), i as f32);
        }
    }

    #[test]
    fn abort_poison_unwinds_blocked_recv() {
        let res = spawn_workers(2, |mut c| {
            if c.rank == 0 {
                c.broadcast_abort(&ExecError::InjectedCrash { rank: 0, step: 7 });
                Ok(vec![])
            } else {
                // rank 0 never sends data: without the poison this hangs
                c.recv(0, Tag::new(9, 5, 0))
            }
        });
        match &res[1] {
            Err(CommError::Aborted { origin }) => {
                assert_eq!(**origin, ExecError::InjectedCrash { rank: 0, step: 7 });
            }
            other => panic!("expected abort, got {other:?}"),
        }
    }
}
