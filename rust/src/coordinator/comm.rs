//! Host-side communication substrate: the NCCL substitute.
//!
//! Each worker thread owns a `WorkerComm`: senders to every peer, its own
//! receiver, and a stash for out-of-order arrivals. Messages are tagged, so
//! eager (non-blocking) sends at the top of a timestep give the same
//! overlap semantics the paper gets from a second CUDA stream: the payload
//! is already in the receiver's mailbox by the time it blocks on `recv`.
//!
//! Sends are **zero-copy**: tensors are `Arc`-backed
//! (`runtime::tensor`), so enqueueing a whole (k, v) chunk is a refcount
//! bump — no allocation, no memcpy (the legacy deep-copy path survives
//! behind [`WorkerComm::set_deep_copy_sends`] for the executor
//! micro-bench's A/B comparison). On the receive side,
//! [`WorkerComm::drain_pending`] sweeps every already-arrived message into
//! the stash without blocking — the prefetch engine's "posted receives" —
//! so a `recv` at compute time is a stash hit. Stash queues are
//! `VecDeque`s: repeated same-tag messages pop FIFO in O(1).
//!
//! Per-worker byte counters feed the communication-volume reports (paper
//! §D); the ring all-reduce implements the gradient synchronization the
//! trainer needs (the paper trains with FSDP/DDP outside the attention —
//! here parameters are replicated, so a plain ring all-reduce suffices).

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::runtime::Tensor;

/// Message tag: unique per (semantic space, step, counter). Spaces keep
/// attention steps, gradient returns, and all-reduce rounds from colliding
/// across layers and training steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag {
    pub space: u32,
    pub a: u32,
    pub b: u32,
}

impl Tag {
    pub const KV: u32 = 1;
    pub const Q_BUNDLE: u32 = 2;
    pub const HELPER_RESULT: u32 = 3;
    pub const KV_GRAD: u32 = 4;
    pub const ALL_REDUCE: u32 = 5;
    pub const GATHER: u32 = 6;
    pub const BARRIER: u32 = 7;
    /// Raw plan-IR transfers (baseline plans outside the attention spaces).
    pub const RAW_XFER: u32 = 8;

    pub fn new(space: u32, a: u32, b: u32) -> Tag {
        Tag { space, a, b }
    }
}

struct Message {
    from: usize,
    tag: Tag,
    tensors: Vec<Tensor>,
}

/// Build the fully-connected mailbox fabric for `p` workers (identity
/// placement: rank i's mailbox at slot i).
pub fn build_network(p: usize) -> Vec<WorkerComm> {
    let identity: Vec<usize> = (0..p).collect();
    build_network_placed(p, &identity)
}

/// Placement-aware fabric: rank `i`'s mailbox lives at *slot*
/// `placement[i]` — the in-process analogue of a launcher binding rank i
/// to GPU `placement[i]` (`Plan::placement`). Every worker's sender table
/// is permuted identically, so messages stay addressed by logical rank
/// and the executor is placement-agnostic; byte counters stay
/// rank-indexed.
pub fn build_network_placed(p: usize, placement: &[usize]) -> Vec<WorkerComm> {
    assert_eq!(placement.len(), p, "placement must cover every rank");
    let mut slot_senders = Vec::with_capacity(p);
    let mut slot_receivers: Vec<Option<Receiver<Message>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Message>();
        slot_senders.push(tx);
        slot_receivers.push(Some(rx));
    }
    let bytes: Arc<Vec<AtomicU64>> = Arc::new((0..p).map(|_| AtomicU64::new(0)).collect());
    // rank j's mailbox is the channel at slot placement[j]
    let senders: Vec<Sender<Message>> =
        placement.iter().map(|&g| slot_senders[g].clone()).collect();
    (0..p)
        .map(|rank| WorkerComm {
            rank,
            n_workers: p,
            senders: senders.clone(),
            rx: slot_receivers[placement[rank]]
                .take()
                .expect("placement must be a permutation of 0..p"),
            stash: HashMap::new(),
            bytes_sent: bytes.clone(),
            deep_copy_sends: false,
        })
        .collect()
}

pub struct WorkerComm {
    pub rank: usize,
    pub n_workers: usize,
    senders: Vec<Sender<Message>>,
    rx: Receiver<Message>,
    /// Out-of-order / prefetched arrivals, FIFO per (sender, tag).
    /// Invariant: a present entry's queue is never empty.
    stash: HashMap<(usize, Tag), VecDeque<Vec<Tensor>>>,
    bytes_sent: Arc<Vec<AtomicU64>>,
    /// Legacy pre-zero-copy send path: materialize a private allocation
    /// for every payload tensor before it enters the channel.
    deep_copy_sends: bool,
}

impl WorkerComm {
    /// Model the pre-zero-copy executor (every send pays a full-chunk
    /// allocation + memcpy). Only the micro-bench and tests flip this.
    pub fn set_deep_copy_sends(&mut self, on: bool) {
        self.deep_copy_sends = on;
    }

    /// Non-blocking tagged send (the "second stream": returns immediately).
    /// Zero-copy: the payload enters the channel as refcount bumps.
    pub fn send(&self, to: usize, tag: Tag, tensors: Vec<Tensor>) {
        let tensors = if self.deep_copy_sends {
            tensors.iter().map(Tensor::deep_clone).collect()
        } else {
            tensors
        };
        let nbytes: usize = tensors.iter().map(|t| t.numel() * 4).sum();
        self.bytes_sent[self.rank].fetch_add(nbytes as u64, Ordering::Relaxed);
        self.senders[to]
            .send(Message { from: self.rank, tag, tensors })
            .expect("peer hung up");
    }

    /// Sweep every message already sitting in the mailbox into the stash
    /// without blocking — the prefetch engine "posting receives ahead of
    /// need". Returns how many messages were staged.
    pub fn drain_pending(&mut self) -> usize {
        let mut n = 0;
        while let Ok(msg) = self.rx.try_recv() {
            self.stash
                .entry((msg.from, msg.tag))
                .or_default()
                .push_back(msg.tensors);
            n += 1;
        }
        n
    }

    /// Blocking tagged receive; a prefetched or out-of-order arrival is a
    /// single-lookup stash hit.
    pub fn recv(&mut self, from: usize, tag: Tag) -> Vec<Tensor> {
        if let Entry::Occupied(mut e) = self.stash.entry((from, tag)) {
            let t = e.get_mut().pop_front().expect("stash entries are never empty");
            if e.get().is_empty() {
                e.remove();
            }
            return t;
        }
        loop {
            let msg = self.rx.recv().expect("network closed while waiting");
            if msg.from == from && msg.tag == tag {
                return msg.tensors;
            }
            self.stash
                .entry((msg.from, msg.tag))
                .or_default()
                .push_back(msg.tensors);
        }
    }

    /// Total bytes this worker has sent so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent[self.rank].load(Ordering::Relaxed)
    }

    /// Bytes sent across all workers (global comm volume).
    pub fn bytes_sent_global(&self) -> u64 {
        self.bytes_sent.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather, the standard
    /// 2(P-1)/P · bytes algorithm. `round` must be globally unique per call
    /// site (e.g. derived from train step + param index).
    ///
    /// Segment payloads are materialized copies, deliberately NOT
    /// `flat_view`s: `t` is mutated right after every hop, so a shared
    /// buffer would trigger a whole-tensor copy-on-write per hop — worse
    /// than the n/p segment copy.
    pub fn all_reduce_sum(&mut self, round: u32, t: &mut Tensor) {
        let p = self.n_workers;
        if p == 1 {
            return;
        }
        let n = t.numel();
        // segment boundaries (last segment absorbs the remainder)
        let seg = |i: usize| -> std::ops::Range<usize> {
            let base = n / p;
            let start = i * base;
            let end = if i == p - 1 { n } else { start + base };
            start..end
        };
        let next = (self.rank + 1) % p;
        let prev = (self.rank + p - 1) % p;
        // reduce-scatter: after P-1 hops, segment (rank+1)%p is fully
        // reduced at this rank
        for step in 0..p - 1 {
            let send_seg = (self.rank + p - step) % p;
            let recv_seg = (self.rank + p - step - 1) % p;
            let tag = Tag::new(Tag::ALL_REDUCE, round, step as u32);
            let payload = Tensor::new(
                vec![seg(send_seg).len()],
                t.data()[seg(send_seg)].to_vec(),
            );
            self.send(next, tag, vec![payload]);
            let got = self.recv(prev, tag);
            let r = seg(recv_seg);
            for (dst, src) in t.data_mut()[r].iter_mut().zip(got[0].data()) {
                *dst += src;
            }
        }
        // all-gather the reduced segments
        for step in 0..p - 1 {
            let send_seg = (self.rank + p - step + 1) % p;
            let recv_seg = (self.rank + p - step) % p;
            let tag = Tag::new(Tag::ALL_REDUCE, round, (p + step) as u32);
            let payload = Tensor::new(
                vec![seg(send_seg).len()],
                t.data()[seg(send_seg)].to_vec(),
            );
            self.send(next, tag, vec![payload]);
            let got = self.recv(prev, tag);
            let r = seg(recv_seg);
            t.data_mut()[r].copy_from_slice(got[0].data());
        }
    }

    /// All-gather a per-worker tensor; returns all P tensors in rank order.
    pub fn all_gather(&mut self, round: u32, t: &Tensor) -> Vec<Tensor> {
        let tag = Tag::new(Tag::GATHER, round, 0);
        for to in 0..self.n_workers {
            if to != self.rank {
                self.send(to, tag, vec![t.clone()]);
            }
        }
        (0..self.n_workers)
            .map(|from| {
                if from == self.rank {
                    t.clone()
                } else {
                    self.recv(from, tag).remove(0)
                }
            })
            .collect()
    }

    /// Full barrier (used between training steps in tests).
    pub fn barrier(&mut self, round: u32) {
        let tag = Tag::new(Tag::BARRIER, round, 0);
        let token = Tensor::scalar(self.rank as f32);
        for to in 0..self.n_workers {
            if to != self.rank {
                self.send(to, tag, vec![token.clone()]);
            }
        }
        for from in 0..self.n_workers {
            if from != self.rank {
                self.recv(from, tag);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_workers<F, R>(p: usize, f: F) -> Vec<R>
    where
        F: Fn(WorkerComm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let comms = build_network(p);
        let f = Arc::new(f);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_out_of_order_delivery() {
        let res = spawn_workers(2, |mut c| {
            if c.rank == 0 {
                c.send(1, Tag::new(9, 0, 0), vec![Tensor::scalar(1.0)]);
                c.send(1, Tag::new(9, 0, 1), vec![Tensor::scalar(2.0)]);
                0.0
            } else {
                // receive in reverse order: stash must kick in
                let b = c.recv(0, Tag::new(9, 0, 1))[0].as_scalar();
                let a = c.recv(0, Tag::new(9, 0, 0))[0].as_scalar();
                a * 10.0 + b
            }
        });
        assert_eq!(res[1], 12.0);
    }

    #[test]
    fn sends_are_zero_copy_and_deep_mode_is_not() {
        // channels work without threads: exercise both ends in-line
        let mut comms = build_network(2);
        let t = Tensor::new(vec![4, 4], (0..16).map(|x| x as f32).collect());
        comms[0].send(1, Tag::new(9, 1, 0), vec![t.clone()]);
        let got = comms[1].recv(0, Tag::new(9, 1, 0));
        assert!(got[0].shares_buffer(&t), "zero-copy send must share storage");
        assert_eq!(got[0], t);

        comms[0].set_deep_copy_sends(true);
        comms[0].send(1, Tag::new(9, 1, 1), vec![t.clone()]);
        let got = comms[1].recv(0, Tag::new(9, 1, 1));
        assert!(!got[0].shares_buffer(&t), "deep mode must materialize");
        assert_eq!(got[0], t);
        // byte accounting identical in both modes
        assert_eq!(comms[0].bytes_sent(), 2 * 16 * 4);
    }

    #[test]
    fn drain_pending_stages_and_recv_hits_fifo() {
        let mut comms = build_network(2);
        let tag = Tag::new(9, 2, 0);
        let other = Tag::new(9, 2, 1);
        // repeated same-tag sends must pop FIFO; interleave another tag
        for i in 0..50 {
            comms[0].send(1, tag, vec![Tensor::scalar(i as f32)]);
            comms[0].send(1, other, vec![Tensor::scalar(-(i as f32))]);
        }
        let staged = comms[1].drain_pending();
        assert_eq!(staged, 100);
        assert_eq!(comms[1].drain_pending(), 0, "second drain finds nothing");
        for i in 0..50 {
            assert_eq!(comms[1].recv(0, tag)[0].as_scalar(), i as f32);
        }
        for i in 0..50 {
            assert_eq!(comms[1].recv(0, other)[0].as_scalar(), -(i as f32));
        }
    }

    #[test]
    fn ring_all_reduce_sums() {
        for p in [1, 2, 3, 4, 7] {
            let res = spawn_workers(p, move |mut c| {
                // tensor of length 10 (not divisible by most p): each worker
                // contributes rank+1 everywhere
                let mut t = Tensor::full(&[10], (c.rank + 1) as f32);
                c.all_reduce_sum(1, &mut t);
                t
            });
            let want = (p * (p + 1) / 2) as f32;
            for t in res {
                assert!(t.data().iter().all(|&x| x == want), "p={p}");
            }
        }
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let res = spawn_workers(3, |mut c| {
            let t = Tensor::scalar(c.rank as f32 * 5.0);
            let all = c.all_gather(2, &t);
            all.iter().map(|x| x.as_scalar()).collect::<Vec<_>>()
        });
        for r in res {
            assert_eq!(r, vec![0.0, 5.0, 10.0]);
        }
    }

    #[test]
    fn byte_accounting() {
        let res = spawn_workers(2, |mut c| {
            if c.rank == 0 {
                c.send(1, Tag::new(8, 0, 0), vec![Tensor::zeros(&[100])]);
            } else {
                c.recv(0, Tag::new(8, 0, 0));
            }
            c.barrier(99);
            c.bytes_sent_global()
        });
        // 100 f32 payload + 2 barrier scalars
        assert_eq!(res[0], 400 + 8);
    }
}
