//! Distributed attention executor: runs a lowered [`Plan`] with *real*
//! tensors.
//!
//! Each worker thread owns its own PJRT runtime (one process per GPU in the
//! real deployment) and walks the plan's op stream, executing the nodes it
//! owns: transfer nodes it is the source of become eager tagged sends (the
//! paper's second stream), compute nodes pull their inbound data with
//! blocking receives keyed by the node's dependency edges. Because the
//! simulator consumes the *same* plan, the timing model and the runtime
//! provably execute the identical schedule — there is no second
//! description to drift.
//!
//! This is the numerics half of the reproduction: the distributed forward
//! must match the monolithic `full_attn_ref` oracle bit-for-float, and the
//! distributed backward must match the oracle's autodiff. Timing claims
//! live in `simulator`.

use anyhow::{anyhow, bail, Result};

use super::comm::{Tag, WorkerComm};
use super::plan::{Kernel, Pass, PayloadClass, Plan, PlanNode, PlanOp};
use crate::runtime::{Runtime, Tensor, Value};

/// Executable kernel semantics. Token-scaled variants collapse onto their
/// base class — the scale prices the op for the timing engines, while the
/// runtime kernel simply operates on whatever (possibly ragged) chunk
/// shapes arrive. Intra-chunk document masking is the kernel's job (the
/// plan already skips chunk pairs that share no document); the vendored
/// stub artifacts do not implement it, so varlen numerics runs require
/// doc-mask-aware artifacts.
enum ExecKernel {
    Diag,
    Full,
    Rescale,
    Accum,
}

fn exec_kernel(kernel: &Kernel, pair: Option<(usize, usize)>) -> Option<ExecKernel> {
    match kernel {
        Kernel::AttnDiag => Some(ExecKernel::Diag),
        Kernel::AttnFull => Some(ExecKernel::Full),
        Kernel::AttnTok { .. } => match pair {
            Some((q, kv)) if q == kv => Some(ExecKernel::Diag),
            _ => Some(ExecKernel::Full),
        },
        Kernel::Rescale | Kernel::RescaleTok { .. } => Some(ExecKernel::Rescale),
        Kernel::Accum => Some(ExecKernel::Accum),
        Kernel::Raw(_) => None,
    }
}

/// Per-worker view of one distributed attention call.
pub struct AttnCtx<'a> {
    pub rank: usize,
    pub runtime: &'a Runtime,
    pub comm: &'a mut WorkerComm,
    /// The lowered plan for this pass (validated by the harness).
    pub plan: &'a Plan,
    /// Distinguishes concurrent attention calls (layer index + train step).
    pub call_id: u32,
}

fn v(t: &Tensor) -> Value {
    Value::F32(t.clone())
}

/// `(src, step)` of the first dependency of `node` that is a transfer of
/// the given class — how compute nodes locate their inbound mailbox slot.
fn dep_xfer(plan: &Plan, node: &PlanNode, class: PayloadClass) -> Option<(usize, usize)> {
    node.deps.iter().find_map(|&d| match &plan.ops[d].op {
        PlanOp::Xfer { src, payload, .. } if payload.class() == class => {
            Some((*src, plan.ops[d].step))
        }
        _ => None,
    })
}

impl<'a> AttnCtx<'a> {
    fn tag(&self, space: u32, step: usize) -> Tag {
        Tag::new(space, self.call_id, step as u32)
    }

    /// Distributed forward (paper Alg. 1 / Alg. 2): returns the normalized
    /// output `o` (H, C, D) and logsumexp `lse` (H, C) for the local chunk.
    pub fn forward(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        if self.plan.pass != Pass::Forward {
            bail!("forward called with a {:?} plan", self.plan.pass);
        }
        // dataflow plans (ring-attention, ulysses) route payloads multi-hop;
        // the executor's direct tagged recvs would deadlock on them
        if !self.plan.lockstep {
            bail!("executor requires a schedule-lowered plan, got {:?}", self.plan.name);
        }
        let plan = self.plan;
        let h = q.shape[0];
        let c = q.shape[1];
        let d = q.shape[2];
        let mut o = Tensor::zeros(&[h, c, d]);
        let mut m = Tensor::full(&[h, c], f32::NEG_INFINITY);
        let mut l = Tensor::zeros(&[h, c]);
        // helper partial (o, m, l) awaiting its HelperResult transfer node
        let mut helper_out: Option<Vec<Tensor>> = None;

        for node in &plan.ops {
            match &node.op {
                PlanOp::Xfer { src, dst, payload } if *src == self.rank => {
                    match payload.class() {
                        PayloadClass::Kv => self.comm.send(
                            *dst,
                            self.tag(Tag::KV, node.step),
                            vec![k.clone(), v_t.clone()],
                        ),
                        PayloadClass::QBundle => self.comm.send(
                            *dst,
                            self.tag(Tag::Q_BUNDLE, node.step),
                            vec![q.clone()],
                        ),
                        PayloadClass::HelperResult => {
                            let out = helper_out.take().ok_or_else(|| {
                                anyhow!("no helper partial pending at op {}", node.id)
                            })?;
                            self.comm
                                .send(*dst, self.tag(Tag::HELPER_RESULT, node.step), out);
                        }
                        PayloadClass::KvGrad | PayloadClass::Raw => {
                            bail!("payload {payload:?} is not executable in forward")
                        }
                    }
                }
                PlanOp::Compute { kernel, pair } if node.worker == self.rank => {
                    match exec_kernel(kernel, *pair) {
                        Some(ExecKernel::Diag) => {
                            let out = self.runtime.run(
                                "attn_fwd_diag",
                                &[v(q), v(k), v(v_t), v(&o), v(&m), v(&l)],
                            )?;
                            let mut it = out.into_iter();
                            o = it.next().unwrap();
                            m = it.next().unwrap();
                            l = it.next().unwrap();
                        }
                        Some(ExecKernel::Full) => {
                            let (owner, kv_chunk) = pair
                                .ok_or_else(|| anyhow!("attention op {} has no pair", node.id))?;
                            if owner == self.rank {
                                // owner path: fetch the remote (k, v) chunk
                                let mut kv =
                                    self.comm.recv(kv_chunk, self.tag(Tag::KV, node.step));
                                let vr = kv.pop().unwrap();
                                let kr = kv.pop().unwrap();
                                let out = self.runtime.run(
                                    "attn_fwd_full",
                                    &[v(q), v(&kr), v(&vr), v(&o), v(&m), v(&l)],
                                )?;
                                let mut it = out.into_iter();
                                o = it.next().unwrap();
                                m = it.next().unwrap();
                                l = it.next().unwrap();
                            } else {
                                // helper path: owner's q against local
                                // (k, v), fresh accumulators shaped by the
                                // owner's (possibly ragged) chunk, partial
                                // shipped back
                                let qo = self
                                    .comm
                                    .recv(owner, self.tag(Tag::Q_BUNDLE, node.step))
                                    .remove(0);
                                let (ho, co) = (qo.shape[0], qo.shape[1]);
                                let oh = Tensor::zeros(&qo.shape);
                                let mh = Tensor::full(&[ho, co], f32::NEG_INFINITY);
                                let lh = Tensor::zeros(&[ho, co]);
                                let out = self.runtime.run(
                                    "attn_fwd_full",
                                    &[v(&qo), v(k), v(v_t), v(&oh), v(&mh), v(&lh)],
                                )?;
                                helper_out = Some(out);
                            }
                        }
                        Some(ExecKernel::Rescale) => {
                            let (from, step) =
                                dep_xfer(plan, node, PayloadClass::HelperResult).ok_or_else(
                                    || anyhow!("rescale op {} lacks a helper-result dep", node.id),
                                )?;
                            let mut part =
                                self.comm.recv(from, self.tag(Tag::HELPER_RESULT, step));
                            let l2 = part.pop().unwrap();
                            let m2 = part.pop().unwrap();
                            let o2 = part.pop().unwrap();
                            let out = self.runtime.run(
                                "attn_rescale",
                                &[v(&o), v(&m), v(&l), v(&o2), v(&m2), v(&l2)],
                            )?;
                            let mut it = out.into_iter();
                            o = it.next().unwrap();
                            m = it.next().unwrap();
                            l = it.next().unwrap();
                        }
                        Some(ExecKernel::Accum) | None => {
                            bail!("kernel {kernel:?} is not executable in forward")
                        }
                    }
                }
                _ => {}
            }
        }
        // epilogue: the paper's `last=True` — normalize + logsumexp
        let out = self.runtime.run("attn_finalize", &[v(&o), v(&m), v(&l)])?;
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Distributed backward: the backward-lowered plan mirrors the forward
    /// schedule. Owners re-fetch remote (k, v) and return (dk, dv)
    /// partials; helpers receive the owner's (q, o, lse, do) bundle and
    /// return a dq partial; a trailing Accum node drains every lender's
    /// (dk, dv) returns. Thanks to the saved `o`/`lse`
    /// (rematerialization-aware checkpointing, §3.3) NO forward attention
    /// is recomputed here.
    pub fn backward(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
        o: &Tensor,
        lse: &Tensor,
        do_: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        if self.plan.pass != Pass::Backward {
            bail!("backward called with a {:?} plan", self.plan.pass);
        }
        if !self.plan.lockstep {
            bail!("executor requires a schedule-lowered plan, got {:?}", self.plan.name);
        }
        let plan = self.plan;
        let mut dq = Tensor::zeros(&q.shape);
        let mut dk = Tensor::zeros(&k.shape);
        let mut dv = Tensor::zeros(&v_t.shape);
        // helper dq partial awaiting its HelperResult transfer node
        let mut helper_out: Option<Vec<Tensor>> = None;
        // (dk, dv) partial awaiting its KvGrad return node
        let mut grad_out: Option<Vec<Tensor>> = None;

        for node in &plan.ops {
            match &node.op {
                PlanOp::Xfer { src, dst, payload } if *src == self.rank => {
                    match payload.class() {
                        PayloadClass::Kv => self.comm.send(
                            *dst,
                            self.tag(Tag::KV, node.step),
                            vec![k.clone(), v_t.clone()],
                        ),
                        PayloadClass::QBundle => {
                            // helper needs the full owner bundle for the
                            // bwd kernel
                            self.comm.send(
                                *dst,
                                self.tag(Tag::Q_BUNDLE, node.step),
                                vec![q.clone(), o.clone(), lse.clone(), do_.clone()],
                            );
                        }
                        PayloadClass::HelperResult => {
                            let out = helper_out.take().ok_or_else(|| {
                                anyhow!("no dq partial pending at op {}", node.id)
                            })?;
                            self.comm
                                .send(*dst, self.tag(Tag::HELPER_RESULT, node.step), out);
                        }
                        PayloadClass::KvGrad => {
                            let out = grad_out.take().ok_or_else(|| {
                                anyhow!("no (dk, dv) partial pending at op {}", node.id)
                            })?;
                            self.comm.send(*dst, self.tag(Tag::KV_GRAD, node.step), out);
                        }
                        PayloadClass::Raw => bail!("raw payload is not executable in backward"),
                    }
                }
                PlanOp::Compute { kernel, pair } if node.worker == self.rank => {
                    match exec_kernel(kernel, *pair) {
                        Some(ExecKernel::Diag) => {
                            let out = self.runtime.run(
                                "attn_bwd_diag",
                                &[v(q), v(k), v(v_t), v(o), v(lse), v(do_)],
                            )?;
                            let mut it = out.into_iter();
                            dq.add_assign(&it.next().unwrap());
                            dk.add_assign(&it.next().unwrap());
                            dv.add_assign(&it.next().unwrap());
                        }
                        Some(ExecKernel::Full) => {
                            let (owner, kv_chunk) = pair
                                .ok_or_else(|| anyhow!("attention op {} has no pair", node.id))?;
                            if owner == self.rank {
                                let mut kv =
                                    self.comm.recv(kv_chunk, self.tag(Tag::KV, node.step));
                                let vr = kv.pop().unwrap();
                                let kr = kv.pop().unwrap();
                                let out = self.runtime.run(
                                    "attn_bwd_full",
                                    &[v(q), v(&kr), v(&vr), v(o), v(lse), v(do_)],
                                )?;
                                let mut it = out.into_iter();
                                dq.add_assign(&it.next().unwrap());
                                let dkr = it.next().unwrap();
                                let dvr = it.next().unwrap();
                                grad_out = Some(vec![dkr, dvr]);
                            } else {
                                let mut bundle =
                                    self.comm.recv(owner, self.tag(Tag::Q_BUNDLE, node.step));
                                let do_o = bundle.pop().unwrap();
                                let lse_o = bundle.pop().unwrap();
                                let o_o = bundle.pop().unwrap();
                                let q_o = bundle.pop().unwrap();
                                let out = self.runtime.run(
                                    "attn_bwd_full",
                                    &[v(&q_o), v(k), v(v_t), v(&o_o), v(&lse_o), v(&do_o)],
                                )?;
                                let mut it = out.into_iter();
                                let dq_o = it.next().unwrap();
                                dk.add_assign(&it.next().unwrap());
                                dv.add_assign(&it.next().unwrap());
                                helper_out = Some(vec![dq_o]);
                            }
                        }
                        Some(ExecKernel::Rescale) => {
                            let (from, step) =
                                dep_xfer(plan, node, PayloadClass::HelperResult).ok_or_else(
                                    || anyhow!("rescale op {} lacks a helper-result dep", node.id),
                                )?;
                            let part = self.comm.recv(from, self.tag(Tag::HELPER_RESULT, step));
                            dq.add_assign(&part[0]);
                        }
                        Some(ExecKernel::Accum) => {
                            // drain the (dk, dv) returns from every owner
                            // this worker lent kv to
                            for &dref in &node.deps {
                                let dep = &plan.ops[dref];
                                match &dep.op {
                                    PlanOp::Xfer { src, payload, .. }
                                        if payload.class() == PayloadClass::KvGrad =>
                                    {
                                        let mut g = self
                                            .comm
                                            .recv(*src, self.tag(Tag::KV_GRAD, dep.step));
                                        let dvr = g.pop().unwrap();
                                        let dkr = g.pop().unwrap();
                                        dk.add_assign(&dkr);
                                        dv.add_assign(&dvr);
                                    }
                                    other => {
                                        bail!("accum dep {dref} is not a kv-grad ({other:?})")
                                    }
                                }
                            }
                        }
                        None => bail!("raw kernel is not executable in backward"),
                    }
                }
                _ => {}
            }
        }
        Ok((dq, dk, dv))
    }
}

/// Which artifacts an attention worker needs compiled.
pub const ATTN_ARTIFACTS: &[&str] = &[
    "attn_fwd_diag",
    "attn_fwd_full",
    "attn_rescale",
    "attn_finalize",
    "attn_bwd_diag",
    "attn_bwd_full",
];
