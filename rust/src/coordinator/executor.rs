//! Distributed attention executor: runs a lowered [`Plan`] with *real*
//! tensors.
//!
//! Each worker thread owns a kernel backend (a PJRT runtime in the real
//! deployment — one process per GPU — or the pure-host reference kernels)
//! and walks a pre-resolved index of the plan's op stream
//! ([`PlanIndex`]): transfer nodes it is the source of become eager
//! zero-copy tagged sends (the paper's second stream), compute nodes pull
//! their inbound data from the prefetch stash. Because the simulator
//! consumes the *same* plan, the timing model and the runtime provably
//! execute the identical schedule — there is no second description to
//! drift.
//!
//! ## Prefetch engine
//!
//! With `Plan::prefetch_depth >= 1` the executor drains its mailbox into
//! the stash at every step boundary (`WorkerComm::drain_pending`) — the
//! in-process analogue of posting receives on a second CUDA stream ahead
//! of need — so `recv` at compute time is a stash hit whenever the sender
//! kept pace. At depth 0 nothing is drained and every receive blocks at
//! point of use (the legacy serial path, kept as the A/B baseline). Both
//! paths consume identical tensors in identical order, so outputs are
//! bit-identical — pinned by `rust/tests/prefetch_engine.rs`.
//!
//! The *magnitude* of a nonzero depth is deliberately not enforced here:
//! the mpsc mailbox is unbounded and already owns each payload from the
//! moment it is sent, so draining into the stash moves host memory between
//! two queues rather than staging anything new — an in-process drain
//! bounded to `d` steps would bound nothing. The depth magnitude is a
//! *GPU-deployment* constraint (d in-flight staging buffers), priced by
//! the optimizer's memory-capped autotuner and timed by the event engine's
//! early-release semantics; the runtime honors the binary choice
//! (blocking vs posted receives) that is meaningful in-process.
//!
//! ## Fault tolerance
//!
//! Step boundaries double as fault checkpoints: the walk consults
//! `WorkerComm::fault_check` (an injected crash due at this step, or a
//! peer's abort poison already in flight), and every comm call's
//! `CommError` is lifted into the typed `ExecError` taxonomy — recorded
//! on the comm for the session's post-mortem report, broadcast to peers
//! when this rank is the failure's origin, and surfaced as the walk's
//! error. With fault tolerance unarmed the checks cost two `Option` loads
//! per step.
//!
//! ## Tracing
//!
//! When [`AttnCtx::epoch`] is set, every kernel this worker runs and every
//! send it initiates gets an `(op id, start, end)` span recorded into
//! [`AttnCtx::trace`]; the harness merges ranks into a [`MergedTrace`]
//! aligned with the plan's op ids, which `repro trace` compares against
//! the event engine's per-op predictions.
//!
//! This is the numerics half of the reproduction: the distributed forward
//! must match the monolithic `full_attn_ref` oracle, and the distributed
//! backward its saved-statistics FA2 backward. Timing claims live in
//! `simulator`.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::comm::{Tag, WorkerComm};
use super::fault::{CommError, ExecError};
use super::plan::{Kernel, Pass, PayloadClass, Plan, PlanNode, PlanOp};
use crate::runtime::{Kernels, Tensor, Value};

/// Executable kernel semantics. Token-scaled variants collapse onto their
/// base class — the scale prices the op for the timing engines, while the
/// runtime kernel simply operates on whatever (possibly ragged) chunk
/// shapes arrive. Intra-chunk document masking is the kernel's job (the
/// plan already skips chunk pairs that share no document); the vendored
/// stub artifacts do not implement it, so varlen numerics runs require
/// doc-mask-aware artifacts.
enum ExecKernel {
    Diag,
    Full,
    Rescale,
    Accum,
}

fn exec_kernel(kernel: &Kernel, pair: Option<(usize, usize)>) -> Option<ExecKernel> {
    match kernel {
        Kernel::AttnDiag => Some(ExecKernel::Diag),
        Kernel::AttnFull => Some(ExecKernel::Full),
        Kernel::AttnTok { .. } => match pair {
            Some((q, kv)) if q == kv => Some(ExecKernel::Diag),
            _ => Some(ExecKernel::Full),
        },
        Kernel::Rescale | Kernel::RescaleTok { .. } => Some(ExecKernel::Rescale),
        Kernel::Accum => Some(ExecKernel::Accum),
        // decode-pass ops run in the serving executor, not this one
        Kernel::DecodeAttn { .. }
        | Kernel::KvAppend { .. }
        | Kernel::KvLookup { .. }
        | Kernel::KvEvict => None,
        Kernel::Raw(_) => None,
    }
}

/// `(src, step)` of the first dependency of `node` that is a transfer of
/// the given class. Used once per op while building the [`PlanIndex`].
fn dep_xfer(plan: &Plan, node: &PlanNode, class: PayloadClass) -> Option<(usize, usize)> {
    node.deps.iter().find_map(|&d| match &plan.ops[d].op {
        PlanOp::Xfer { src, payload, .. } if payload.class() == class => {
            Some((*src, plan.ops[d].step))
        }
        _ => None,
    })
}

/// What one worker does at one plan op, every wiring lookup pre-resolved.
#[derive(Debug)]
enum Action {
    /// Send the local (k, v) chunk to `dst`.
    SendKv { dst: usize, step: usize },
    /// Send the owner bundle (q forward; q/o/lse/do backward) to `dst`.
    SendQ { dst: usize, step: usize },
    /// Ship the pending helper partial to owner `dst`.
    SendHelperResult { dst: usize, step: usize },
    /// Ship the pending (dk, dv) partial back to lender `dst`.
    SendKvGrad { dst: usize, step: usize },
    /// Diagonal kernel on the local chunk.
    Diag,
    /// Owner-path kernel: fetch the (k, v) chunk sent by `kv_from` first.
    Own { kv_from: usize, step: usize },
    /// Helper-path kernel: receive `owner`'s bundle first.
    Help { owner: usize, step: usize },
    /// Merge the helper partial sent by `from` at `step` (rescale in
    /// forward, dq-accumulate in backward).
    Merge { from: usize, step: usize },
    /// Drain the (dk, dv) returns listed as `(src, step)` pairs.
    Accum { sources: Vec<(usize, usize)> },
}

#[derive(Debug)]
struct IndexedOp {
    /// Plan op id (trace alignment).
    op: usize,
    /// Plan step (prefetch drain boundary).
    step: usize,
    action: Action,
}

/// One worker's pre-resolved walk of a plan: only the ops this rank
/// participates in, with every dependency lookup (which transfer feeds
/// which compute) resolved once per plan execution instead of a per-node
/// linear scan over `plan.ops`.
#[derive(Debug)]
pub struct PlanIndex {
    ops: Vec<IndexedOp>,
    /// Leading indexed ops that belong to the plan's recompute prefix
    /// ([`Plan::recompute_ops`]) — this rank's share of the replayed
    /// attention forward. 0 for plans without a prefix.
    n_prefix: usize,
}

impl PlanIndex {
    /// Pre-resolve `plan` for `rank`, checking it is executable as `pass`
    /// first. Wiring errors (a pass-mismatched or dataflow plan, a rescale
    /// without a helper-result dependency, a raw op) surface here, before
    /// any communication happens — on every path, including callers that
    /// cache the index and skip `check_and_index`.
    pub fn new(plan: &Plan, rank: usize, pass: Pass) -> Result<PlanIndex> {
        if plan.pass != pass {
            bail!("{} called with a {:?} plan", pass.name(), plan.pass);
        }
        // dataflow plans (ring-attention, ulysses) route payloads multi-hop;
        // the executor's direct tagged recvs would deadlock on them
        if !plan.lockstep {
            bail!("executor requires a schedule-lowered plan, got {:?}", plan.name);
        }
        let mut ops = Vec::new();
        let mut n_prefix = 0;
        for node in &plan.ops {
            let action = match &node.op {
                PlanOp::Xfer { src, dst, payload } if *src == rank => {
                    match payload.class() {
                        PayloadClass::Kv => Action::SendKv { dst: *dst, step: node.step },
                        PayloadClass::QBundle => Action::SendQ { dst: *dst, step: node.step },
                        PayloadClass::HelperResult => {
                            Action::SendHelperResult { dst: *dst, step: node.step }
                        }
                        PayloadClass::KvGrad => {
                            Action::SendKvGrad { dst: *dst, step: node.step }
                        }
                        PayloadClass::Raw => {
                            bail!("op {}: raw payloads are not executable", node.id)
                        }
                    }
                }
                PlanOp::Compute { kernel, pair } if node.worker == rank => {
                    match exec_kernel(kernel, *pair) {
                        Some(ExecKernel::Diag) => Action::Diag,
                        Some(ExecKernel::Full) => {
                            let (owner, kv_chunk) = pair.ok_or_else(|| {
                                anyhow!("attention op {} has no pair", node.id)
                            })?;
                            if owner == rank {
                                Action::Own { kv_from: kv_chunk, step: node.step }
                            } else {
                                Action::Help { owner, step: node.step }
                            }
                        }
                        Some(ExecKernel::Rescale) => {
                            let (from, step) = dep_xfer(plan, node, PayloadClass::HelperResult)
                                .ok_or_else(|| {
                                    anyhow!("rescale op {} lacks a helper-result dep", node.id)
                                })?;
                            Action::Merge { from, step }
                        }
                        Some(ExecKernel::Accum) => {
                            let mut sources = Vec::with_capacity(node.deps.len());
                            for &d in &node.deps {
                                match &plan.ops[d].op {
                                    PlanOp::Xfer { src, payload, .. }
                                        if payload.class() == PayloadClass::KvGrad =>
                                    {
                                        sources.push((*src, plan.ops[d].step));
                                    }
                                    other => {
                                        bail!("accum dep {d} is not a kv-grad ({other:?})")
                                    }
                                }
                            }
                            Action::Accum { sources }
                        }
                        None => bail!("op {}: raw kernels are not executable", node.id),
                    }
                }
                _ => continue,
            };
            ops.push(IndexedOp { op: node.id, step: node.step, action });
            // recompute-prefix ops lead the op stream in id order, so the
            // indexed prefix is a leading run of `ops`
            if node.id < plan.recompute_ops {
                n_prefix += 1;
            }
        }
        Ok(PlanIndex { ops, n_prefix })
    }

    /// This rank's share of the plan's recompute prefix (leading indexed
    /// ops that replay the attention forward); 0 without checkpoints.
    pub fn n_recompute(&self) -> usize {
        self.n_prefix
    }
}

/// Per-op wall-clock spans from one worker's walk of one plan: `(op id,
/// start, end)` seconds relative to the harness epoch. Computes are
/// stamped around the kernel invocation (inbound waits excluded), sends
/// around the enqueue.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    pub spans: Vec<(usize, f64, f64)>,
}

/// Rank-merged per-op timeline for one plan execution, indexed by op id.
/// Exactly one worker executes each compute and initiates each transfer,
/// so the merge is a scatter.
#[derive(Clone, Debug)]
pub struct MergedTrace {
    pub start_s: Vec<f64>,
    pub end_s: Vec<f64>,
    pub covered: Vec<bool>,
    /// Plan op count per step group, taken from the traced plan itself.
    /// Training lowerings emit a fixed op count per step, but decode
    /// plans shrink as requests finish — so the per-step counts are
    /// carried explicitly instead of assumed uniform (`ops_per_step[s]`
    /// is the number of plan ops with `step == s`).
    pub ops_per_step: Vec<usize>,
    /// Effective host-kernel thread count the traced run executed with
    /// (after the availability clamp) — so a calibration knows what
    /// machine configuration its durations describe. 1 for backends
    /// without a thread knob.
    pub threads: usize,
    /// Effective `(tile_q, tile_k)` the host kernels ran with, when the
    /// backend has tiles at all (`None` for scalar/null backends) — the
    /// autotune satellite's record of which sweep candidate actually ran.
    pub tiles: Option<(usize, usize)>,
}

impl MergedTrace {
    /// Per-step op counts of `plan` — the explicit replacement for the
    /// old fixed-ops-per-pass assumption.
    pub fn step_counts(plan: &Plan) -> Vec<usize> {
        let len = plan
            .ops
            .iter()
            .map(|n| n.step + 1)
            .max()
            .unwrap_or(0)
            .max(plan.n_steps);
        let mut counts = vec![0usize; len];
        for n in &plan.ops {
            counts[n.step] += 1;
        }
        counts
    }

    pub fn merge(plan: &Plan, traces: &[RunTrace]) -> MergedTrace {
        let n_ops = plan.n_ops();
        let mut m = MergedTrace {
            start_s: vec![0.0; n_ops],
            end_s: vec![0.0; n_ops],
            covered: vec![false; n_ops],
            ops_per_step: Self::step_counts(plan),
            threads: 1,
            tiles: None,
        };
        for t in traces {
            for &(op, s, e) in &t.spans {
                m.start_s[op] = s;
                m.end_s[op] = e;
                m.covered[op] = true;
            }
        }
        m
    }

    pub fn op_duration(&self, op: usize) -> f64 {
        self.end_s[op] - self.start_s[op]
    }

    /// Wall-clock between the first recorded start and the last recorded
    /// end across all ops.
    pub fn makespan_s(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..self.covered.len() {
            if self.covered[i] {
                lo = lo.min(self.start_s[i]);
                hi = hi.max(self.end_s[i]);
            }
        }
        if hi > lo {
            hi - lo
        } else {
            0.0
        }
    }
}

/// Per-worker view of one distributed attention call.
pub struct AttnCtx<'a> {
    pub rank: usize,
    pub runtime: &'a dyn Kernels,
    pub comm: &'a mut WorkerComm,
    /// The lowered plan for this pass (validated by the harness).
    pub plan: &'a Plan,
    /// Distinguishes concurrent attention calls (layer index + train step).
    pub call_id: u32,
    /// Tracing epoch: when set, per-op spans accumulate into `trace`.
    pub epoch: Option<Instant>,
    pub trace: RunTrace,
}

fn v(t: &Tensor) -> Value {
    Value::F32(t.clone())
}

impl<'a> AttnCtx<'a> {
    fn tag(&self, space: u32, step: usize) -> Tag {
        Tag::new(space, self.call_id, step as u32)
    }

    fn stamp(&self) -> f64 {
        match self.epoch {
            Some(e) => e.elapsed().as_secs_f64(),
            None => 0.0,
        }
    }

    fn record(&mut self, op: usize, start: f64) {
        if self.epoch.is_some() {
            let end = self.stamp();
            self.trace.spans.push((op, start, end));
        }
    }

    /// Step-boundary bookkeeping. Post receives (plan depth >= 1): sweep
    /// every already-arrived message into the stash so compute-time
    /// receives hit locally — the in-process second stream. Then the
    /// fault checks: an injected crash due at this step, or a peer's
    /// abort poison, unwinds the walk here instead of mid-op.
    fn step_boundary(&mut self, cur_step: &mut usize, step: usize) -> Result<()> {
        if *cur_step == step {
            return Ok(());
        }
        *cur_step = step;
        if self.plan.prefetch_depth >= 1 {
            self.comm.drain_pending();
        }
        if let Err(e) = self.comm.fault_check(self.plan.pass, step) {
            if !e.is_collateral() {
                self.comm.broadcast_abort(&e);
            }
            self.comm.record_failure(e.clone());
            return Err(anyhow!("{e}"));
        }
        Ok(())
    }

    /// Lift a comm-layer failure into the typed executor taxonomy:
    /// record it on the comm (the session's post-mortem report reads it
    /// back), tell peers if this rank is the failure's origin, and
    /// surface a contextual error.
    fn comm_fail<T>(&mut self, r: Result<T, CommError>, step: usize, op: &str) -> Result<T> {
        match r {
            Ok(t) => Ok(t),
            Err(e) => {
                let err = ExecError::from_comm(self.comm.rank, e, step, op);
                if !err.is_collateral() {
                    self.comm.broadcast_abort(&err);
                }
                self.comm.record_failure(err.clone());
                Err(anyhow!("{err}"))
            }
        }
    }

    /// Distributed forward (paper Alg. 1 / Alg. 2): returns the normalized
    /// output `o` (H, C, D) and logsumexp `lse` (H, C) for the local chunk.
    pub fn forward(&mut self, q: &Tensor, k: &Tensor, v_t: &Tensor) -> Result<(Tensor, Tensor)> {
        let index = self.check_and_index(Pass::Forward)?;
        self.forward_indexed(&index, q, k, v_t)
    }

    /// Validate pass/plan compatibility and pre-resolve the op stream
    /// (thin wrapper over [`PlanIndex::new`], which owns the checks).
    pub fn check_and_index(&self, pass: Pass) -> Result<PlanIndex> {
        PlanIndex::new(self.plan, self.rank, pass)
    }

    /// Forward over a pre-resolved index (see [`PlanIndex::new`]).
    pub fn forward_indexed(
        &mut self,
        index: &PlanIndex,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        self.forward_walk(&index.ops, q, k, v_t)
    }

    /// Forward semantics over a slice of indexed ops — the whole stream
    /// for a forward plan, or a backward plan's recompute prefix.
    fn forward_walk(
        &mut self,
        ops: &[IndexedOp],
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        let h = q.shape[0];
        let c = q.shape[1];
        let d = q.shape[2];
        let mut o = Tensor::zeros(&[h, c, d]);
        let mut m = Tensor::full(&[h, c], f32::NEG_INFINITY);
        let mut l = Tensor::zeros(&[h, c]);
        // helper partial (o, m, l) awaiting its HelperResult transfer node
        let mut helper_out: Option<Vec<Tensor>> = None;
        let mut cur_step = usize::MAX;

        for iop in ops {
            self.step_boundary(&mut cur_step, iop.step)?;
            match &iop.action {
                Action::SendKv { dst, step } => {
                    let t0 = self.stamp();
                    let r = self
                        .comm
                        .send(*dst, self.tag(Tag::KV, *step), vec![k.clone(), v_t.clone()]);
                    self.comm_fail(r, iop.step, "send kv")?;
                    self.record(iop.op, t0);
                }
                Action::SendQ { dst, step } => {
                    let t0 = self.stamp();
                    let r = self
                        .comm
                        .send(*dst, self.tag(Tag::Q_BUNDLE, *step), vec![q.clone()]);
                    self.comm_fail(r, iop.step, "send q bundle")?;
                    self.record(iop.op, t0);
                }
                Action::SendHelperResult { dst, step } => {
                    let out = helper_out
                        .take()
                        .ok_or_else(|| anyhow!("no helper partial pending at op {}", iop.op))?;
                    let t0 = self.stamp();
                    let r = self.comm.send(*dst, self.tag(Tag::HELPER_RESULT, *step), out);
                    self.comm_fail(r, iop.step, "send helper result")?;
                    self.record(iop.op, t0);
                }
                Action::Diag => {
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_fwd_diag",
                        &[v(q), v(k), v(v_t), v(&o), v(&m), v(&l)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    m = it.next().unwrap();
                    l = it.next().unwrap();
                }
                Action::Own { kv_from, step } => {
                    // owner path: fetch the remote (k, v) chunk
                    let r = self.comm.recv(*kv_from, self.tag(Tag::KV, *step));
                    let mut kv = self.comm_fail(r, iop.step, "recv kv")?;
                    let vr = kv.pop().expect("kv payload carries (k, v)");
                    let kr = kv.pop().expect("kv payload carries (k, v)");
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_fwd_full",
                        &[v(q), v(&kr), v(&vr), v(&o), v(&m), v(&l)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    m = it.next().unwrap();
                    l = it.next().unwrap();
                }
                Action::Help { owner, step } => {
                    // helper path: owner's q against local (k, v), fresh
                    // accumulators shaped by the owner's (possibly ragged)
                    // chunk, partial shipped back
                    let r = self.comm.recv(*owner, self.tag(Tag::Q_BUNDLE, *step));
                    let qo = self.comm_fail(r, iop.step, "recv q bundle")?.remove(0);
                    let (ho, co) = (qo.shape[0], qo.shape[1]);
                    let oh = Tensor::zeros(&qo.shape);
                    let mh = Tensor::full(&[ho, co], f32::NEG_INFINITY);
                    let lh = Tensor::zeros(&[ho, co]);
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_fwd_full",
                        &[v(&qo), v(k), v(v_t), v(&oh), v(&mh), v(&lh)],
                    )?;
                    self.record(iop.op, t0);
                    helper_out = Some(out);
                }
                Action::Merge { from, step } => {
                    let r = self.comm.recv(*from, self.tag(Tag::HELPER_RESULT, *step));
                    let mut part = self.comm_fail(r, iop.step, "recv helper result")?;
                    let l2 = part.pop().expect("helper result carries (o, m, l)");
                    let m2 = part.pop().expect("helper result carries (o, m, l)");
                    let o2 = part.pop().expect("helper result carries (o, m, l)");
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_rescale",
                        &[v(&o), v(&m), v(&l), v(&o2), v(&m2), v(&l2)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    m = it.next().unwrap();
                    l = it.next().unwrap();
                }
                Action::SendKvGrad { .. } | Action::Accum { .. } => {
                    bail!("op {}: backward-only action in a forward plan", iop.op)
                }
            }
        }
        // release any injected-delay traffic: peers may still be waiting
        // on it, and this rank might not block again in this walk
        let r = self.comm.flush_sends();
        self.comm_fail(r, cur_step, "flush sends")?;
        // epilogue: the paper's `last=True` — normalize + logsumexp
        let out = self.runtime.run("attn_finalize", &[v(&o), v(&m), v(&l)])?;
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Distributed backward: the backward-lowered plan mirrors the forward
    /// schedule. Owners re-fetch remote (k, v) and return (dk, dv)
    /// partials; helpers receive the owner's (q, o, lse, do) bundle and
    /// return a dq partial; a trailing Accum node drains every lender's
    /// (dk, dv) returns. Whether forward attention is recomputed first is
    /// the *plan's* decision (§3.3): under rematerialization-aware
    /// checkpointing the plan has no recompute prefix and the saved
    /// `o`/`lse` arguments are used directly; under an HF-style lowering
    /// (`Plan::recompute_ops > 0`) the leading ops replay the attention
    /// forward — same kernels, same wire traffic — and the rebuilt
    /// `o`/`lse` supersede the passed-in pair.
    pub fn backward(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
        o: &Tensor,
        lse: &Tensor,
        do_: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let index = self.check_and_index(Pass::Backward)?;
        self.backward_indexed(&index, q, k, v_t, o, lse, do_)
    }

    /// Backward over a pre-resolved index (see [`PlanIndex::new`]).
    #[allow(clippy::too_many_arguments)]
    pub fn backward_indexed(
        &mut self,
        index: &PlanIndex,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
        o: &Tensor,
        lse: &Tensor,
        do_: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        // HF-style recompute prefix: replay this rank's share of the
        // attention forward to rebuild (o, lse) before the backward body
        // touches them — the passed-in pair is ignored, exactly as a
        // layer-boundary checkpoint would not have saved it. Step numbers
        // (and so wire tags) are disjoint from the body's, so the replay's
        // traffic cannot collide with backward traffic.
        let rebuilt: Option<(Tensor, Tensor)> = if index.n_prefix > 0 {
            Some(self.recompute_indexed(index, q, k, v_t)?)
        } else {
            None
        };
        let (o, lse) = match &rebuilt {
            Some((ro, rl)) => (ro, rl),
            None => (o, lse),
        };
        self.backward_body_indexed(index, q, k, v_t, o, lse, do_)
    }

    /// Replay the backward plan's recompute prefix alone, rebuilding
    /// `(o, lse)` — for callers (the trainer) that need the attention
    /// output *before* the upstream gradient exists. Pair with
    /// [`AttnCtx::backward_body_indexed`]; calling [`AttnCtx::backward_indexed`]
    /// afterwards would replay the prefix a second time.
    pub fn recompute_indexed(
        &mut self,
        index: &PlanIndex,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        if index.n_prefix == 0 {
            bail!("plan has no recompute prefix (not an HF-style checkpoint lowering)");
        }
        self.forward_walk(&index.ops[..index.n_prefix], q, k, v_t)
    }

    /// Backward body only — skips the recompute prefix (if any) and trusts
    /// the caller-supplied `o`/`lse`.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_body_indexed(
        &mut self,
        index: &PlanIndex,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
        o: &Tensor,
        lse: &Tensor,
        do_: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let mut dq = Tensor::zeros(&q.shape);
        let mut dk = Tensor::zeros(&k.shape);
        let mut dv = Tensor::zeros(&v_t.shape);
        // helper dq partial awaiting its HelperResult transfer node
        let mut helper_out: Option<Vec<Tensor>> = None;
        // (dk, dv) partial awaiting its KvGrad return node
        let mut grad_out: Option<Vec<Tensor>> = None;
        let mut cur_step = usize::MAX;

        for iop in &index.ops[index.n_prefix..] {
            self.step_boundary(&mut cur_step, iop.step)?;
            match &iop.action {
                Action::SendKv { dst, step } => {
                    let t0 = self.stamp();
                    let r = self
                        .comm
                        .send(*dst, self.tag(Tag::KV, *step), vec![k.clone(), v_t.clone()]);
                    self.comm_fail(r, iop.step, "send kv")?;
                    self.record(iop.op, t0);
                }
                Action::SendQ { dst, step } => {
                    // helper needs the full owner bundle for the bwd kernel
                    let t0 = self.stamp();
                    let r = self.comm.send(
                        *dst,
                        self.tag(Tag::Q_BUNDLE, *step),
                        vec![q.clone(), o.clone(), lse.clone(), do_.clone()],
                    );
                    self.comm_fail(r, iop.step, "send q bundle")?;
                    self.record(iop.op, t0);
                }
                Action::SendHelperResult { dst, step } => {
                    let out = helper_out
                        .take()
                        .ok_or_else(|| anyhow!("no dq partial pending at op {}", iop.op))?;
                    let t0 = self.stamp();
                    let r = self.comm.send(*dst, self.tag(Tag::HELPER_RESULT, *step), out);
                    self.comm_fail(r, iop.step, "send dq partial")?;
                    self.record(iop.op, t0);
                }
                Action::SendKvGrad { dst, step } => {
                    let out = grad_out
                        .take()
                        .ok_or_else(|| anyhow!("no (dk, dv) partial pending at op {}", iop.op))?;
                    let t0 = self.stamp();
                    let r = self.comm.send(*dst, self.tag(Tag::KV_GRAD, *step), out);
                    self.comm_fail(r, iop.step, "send kv grad")?;
                    self.record(iop.op, t0);
                }
                Action::Diag => {
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_bwd_diag",
                        &[v(q), v(k), v(v_t), v(o), v(lse), v(do_)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    dq.add_assign(&it.next().unwrap());
                    dk.add_assign(&it.next().unwrap());
                    dv.add_assign(&it.next().unwrap());
                }
                Action::Own { kv_from, step } => {
                    let r = self.comm.recv(*kv_from, self.tag(Tag::KV, *step));
                    let mut kv = self.comm_fail(r, iop.step, "recv kv")?;
                    let vr = kv.pop().expect("kv payload carries (k, v)");
                    let kr = kv.pop().expect("kv payload carries (k, v)");
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_bwd_full",
                        &[v(q), v(&kr), v(&vr), v(o), v(lse), v(do_)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    dq.add_assign(&it.next().unwrap());
                    let dkr = it.next().unwrap();
                    let dvr = it.next().unwrap();
                    grad_out = Some(vec![dkr, dvr]);
                }
                Action::Help { owner, step } => {
                    let r = self.comm.recv(*owner, self.tag(Tag::Q_BUNDLE, *step));
                    let mut bundle = self.comm_fail(r, iop.step, "recv q bundle")?;
                    let do_o = bundle.pop().expect("bwd bundle carries (q, o, lse, do)");
                    let lse_o = bundle.pop().expect("bwd bundle carries (q, o, lse, do)");
                    let o_o = bundle.pop().expect("bwd bundle carries (q, o, lse, do)");
                    let q_o = bundle.pop().expect("bwd bundle carries (q, o, lse, do)");
                    let t0 = self.stamp();
                    let out = self.runtime.run(
                        "attn_bwd_full",
                        &[v(&q_o), v(k), v(v_t), v(&o_o), v(&lse_o), v(&do_o)],
                    )?;
                    self.record(iop.op, t0);
                    let mut it = out.into_iter();
                    let dq_o = it.next().unwrap();
                    dk.add_assign(&it.next().unwrap());
                    dv.add_assign(&it.next().unwrap());
                    helper_out = Some(vec![dq_o]);
                }
                Action::Merge { from, step } => {
                    let r = self.comm.recv(*from, self.tag(Tag::HELPER_RESULT, *step));
                    let part = self.comm_fail(r, iop.step, "recv dq partial")?;
                    let t0 = self.stamp();
                    dq.add_assign(&part[0]);
                    self.record(iop.op, t0);
                }
                Action::Accum { sources } => {
                    // drain the (dk, dv) returns from every owner this
                    // worker lent kv to
                    for &(src, step) in sources {
                        let r = self.comm.recv(src, self.tag(Tag::KV_GRAD, step));
                        let mut g = self.comm_fail(r, iop.step, "recv kv grad")?;
                        let dvr = g.pop().expect("kv-grad payload carries (dk, dv)");
                        let dkr = g.pop().expect("kv-grad payload carries (dk, dv)");
                        dk.add_assign(&dkr);
                        dv.add_assign(&dvr);
                    }
                }
            }
        }
        // release any injected-delay traffic before handing back: lenders
        // may still be blocked in their own Accum drain
        let r = self.comm.flush_sends();
        self.comm_fail(r, cur_step, "flush sends")?;
        Ok((dq, dk, dv))
    }
}

/// Which artifacts an attention worker needs compiled.
pub const ATTN_ARTIFACTS: &[&str] = &[
    "attn_fwd_diag",
    "attn_fwd_full",
    "attn_rescale",
    "attn_finalize",
    "attn_bwd_diag",
    "attn_bwd_full",
];
