//! Distributed attention executor: runs a `Schedule` with *real* tensors.
//!
//! Each worker thread owns its own PJRT runtime (one process per GPU in the
//! real deployment) and executes the paper's Alg. 1/2 against the AOT
//! attention artifacts, exchanging chunks over the `comm` fabric. This is
//! the numerics half of the reproduction: the distributed forward must match
//! the monolithic `full_attn_ref` oracle bit-for-float, and the distributed
//! backward must match the oracle's autodiff.
//!
//! Timing claims live in `simulator`; this module's job is to prove the
//! *algorithm* (schedules, rescale math, gradient routing) is exact.

use anyhow::Result;

use super::comm::{Tag, WorkerComm};
use super::schedule::{ComputeOp, Schedule};
use crate::runtime::{Runtime, Tensor, Value};

/// Per-worker view of one distributed attention call.
pub struct AttnCtx<'a> {
    pub rank: usize,
    pub runtime: &'a Runtime,
    pub comm: &'a mut WorkerComm,
    pub schedule: &'a Schedule,
    /// Distinguishes concurrent attention calls (layer index + train step).
    pub call_id: u32,
}

fn v(t: &Tensor) -> Value {
    Value::F32(t.clone())
}

impl<'a> AttnCtx<'a> {
    fn tag(&self, space: u32, t: usize) -> Tag {
        Tag::new(space, self.call_id, t as u32)
    }

    /// Distributed forward (paper Alg. 1 / Alg. 2): returns the normalized
    /// output `o` (H, C, D) and logsumexp `lse` (H, C) for the local chunk.
    pub fn forward(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        let h = q.shape[0];
        let c = q.shape[1];
        let d = q.shape[2];
        let mut o = Tensor::zeros(&[h, c, d]);
        let mut m = Tensor::full(&[h, c], f32::NEG_INFINITY);
        let mut l = Tensor::zeros(&[h, c]);

        for (t, row) in self.schedule.steps.iter().enumerate() {
            let plan = &row[self.rank];
            // 1. eager sends (the paper's second stream / prefetch)
            if let Some(to) = plan.send_kv_to {
                self.comm
                    .send(to, self.tag(Tag::KV, t), vec![k.clone(), v_t.clone()]);
            }
            if let Some(to) = plan.send_q_to {
                self.comm
                    .send(to, self.tag(Tag::Q_BUNDLE, t), vec![q.clone()]);
            }
            // 2. compute
            match plan.compute {
                Some(ComputeOp::Diag) => {
                    let out = self.runtime.run(
                        "attn_fwd_diag",
                        &[v(q), v(k), v(v_t), v(&o), v(&m), v(&l)],
                    )?;
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    m = it.next().unwrap();
                    l = it.next().unwrap();
                }
                Some(ComputeOp::Own { kv_from }) => {
                    let mut kv = self.comm.recv(kv_from, self.tag(Tag::KV, t));
                    let vr = kv.pop().unwrap();
                    let kr = kv.pop().unwrap();
                    let out = self.runtime.run(
                        "attn_fwd_full",
                        &[v(q), v(&kr), v(&vr), v(&o), v(&m), v(&l)],
                    )?;
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    m = it.next().unwrap();
                    l = it.next().unwrap();
                }
                Some(ComputeOp::Help { owner }) => {
                    let qo = self
                        .comm
                        .recv(owner, self.tag(Tag::Q_BUNDLE, t))
                        .remove(0);
                    let oh = Tensor::zeros(&[h, c, d]);
                    let mh = Tensor::full(&[h, c], f32::NEG_INFINITY);
                    let lh = Tensor::zeros(&[h, c]);
                    let out = self.runtime.run(
                        "attn_fwd_full",
                        &[v(&qo), v(k), v(v_t), v(&oh), v(&mh), v(&lh)],
                    )?;
                    self.comm
                        .send(owner, self.tag(Tag::HELPER_RESULT, t), out);
                }
                None => {}
            }
            // 3. merge helper partials (rescale)
            if let Some(from) = plan.recv_helper_from {
                let mut part = self.comm.recv(from, self.tag(Tag::HELPER_RESULT, t));
                let l2 = part.pop().unwrap();
                let m2 = part.pop().unwrap();
                let o2 = part.pop().unwrap();
                let out = self.runtime.run(
                    "attn_rescale",
                    &[v(&o), v(&m), v(&l), v(&o2), v(&m2), v(&l2)],
                )?;
                let mut it = out.into_iter();
                o = it.next().unwrap();
                m = it.next().unwrap();
                l = it.next().unwrap();
            }
        }
        // epilogue: the paper's `last=True` — normalize + logsumexp
        let out = self.runtime.run("attn_finalize", &[v(&o), v(&m), v(&l)])?;
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Distributed backward: mirrors the forward schedule. Owners re-fetch
    /// remote (k, v) and return (dk, dv) partials; helpers receive the
    /// owner's (q, o, lse, do) bundle and return a dq partial. Thanks to the
    /// saved `o`/`lse` (rematerialization-aware checkpointing, §3.3) NO
    /// forward attention is recomputed here.
    pub fn backward(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v_t: &Tensor,
        o: &Tensor,
        lse: &Tensor,
        do_: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let mut dq = Tensor::zeros(&q.shape);
        let mut dk = Tensor::zeros(&k.shape);
        let mut dv = Tensor::zeros(&v_t.shape);
        // (step, peer) pairs we expect a (dk, dv) return from
        let mut pending_kv_grads: Vec<(usize, usize)> = Vec::new();

        for (t, row) in self.schedule.steps.iter().enumerate() {
            let plan = &row[self.rank];
            if let Some(to) = plan.send_kv_to {
                self.comm
                    .send(to, self.tag(Tag::KV, t), vec![k.clone(), v_t.clone()]);
                pending_kv_grads.push((t, to));
            }
            if let Some(to) = plan.send_q_to {
                // helper needs the full owner bundle to run the bwd kernel
                self.comm.send(
                    to,
                    self.tag(Tag::Q_BUNDLE, t),
                    vec![q.clone(), o.clone(), lse.clone(), do_.clone()],
                );
            }
            match plan.compute {
                Some(ComputeOp::Diag) => {
                    let out = self.runtime.run(
                        "attn_bwd_diag",
                        &[v(q), v(k), v(v_t), v(o), v(lse), v(do_)],
                    )?;
                    let mut it = out.into_iter();
                    dq.add_assign(&it.next().unwrap());
                    dk.add_assign(&it.next().unwrap());
                    dv.add_assign(&it.next().unwrap());
                }
                Some(ComputeOp::Own { kv_from }) => {
                    let mut kv = self.comm.recv(kv_from, self.tag(Tag::KV, t));
                    let vr = kv.pop().unwrap();
                    let kr = kv.pop().unwrap();
                    let out = self.runtime.run(
                        "attn_bwd_full",
                        &[v(q), v(&kr), v(&vr), v(o), v(lse), v(do_)],
                    )?;
                    let mut it = out.into_iter();
                    dq.add_assign(&it.next().unwrap());
                    let dkr = it.next().unwrap();
                    let dvr = it.next().unwrap();
                    self.comm
                        .send(kv_from, self.tag(Tag::KV_GRAD, t), vec![dkr, dvr]);
                }
                Some(ComputeOp::Help { owner }) => {
                    let mut bundle = self.comm.recv(owner, self.tag(Tag::Q_BUNDLE, t));
                    let do_o = bundle.pop().unwrap();
                    let lse_o = bundle.pop().unwrap();
                    let o_o = bundle.pop().unwrap();
                    let q_o = bundle.pop().unwrap();
                    let out = self.runtime.run(
                        "attn_bwd_full",
                        &[v(&q_o), v(k), v(v_t), v(&o_o), v(&lse_o), v(&do_o)],
                    )?;
                    let mut it = out.into_iter();
                    let dq_o = it.next().unwrap();
                    dk.add_assign(&it.next().unwrap());
                    dv.add_assign(&it.next().unwrap());
                    self.comm
                        .send(owner, self.tag(Tag::HELPER_RESULT, t), vec![dq_o]);
                }
                None => {}
            }
            if let Some(from) = plan.recv_helper_from {
                let dq_part = self.comm.recv(from, self.tag(Tag::HELPER_RESULT, t));
                dq.add_assign(&dq_part[0]);
            }
        }
        // collect (dk, dv) returns from every owner we lent kv to
        for (t, peer) in pending_kv_grads {
            let mut g = self.comm.recv(peer, self.tag(Tag::KV_GRAD, t));
            let dvr = g.pop().unwrap();
            let dkr = g.pop().unwrap();
            dk.add_assign(&dkr);
            dv.add_assign(&dvr);
        }
        Ok((dq, dk, dv))
    }
}

/// Which artifacts an attention worker needs compiled.
pub const ATTN_ARTIFACTS: &[&str] = &[
    "attn_fwd_diag",
    "attn_fwd_full",
    "attn_rescale",
    "attn_finalize",
    "attn_bwd_diag",
    "attn_bwd_full",
];
