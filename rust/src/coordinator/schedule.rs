//! Schedules: the paper's ring (Alg. 1) and load-balanced (Alg. 2) plans,
//! built as explicit per-timestep, per-worker op lists.
//!
//! Workers are 0-indexed here (the paper is 1-indexed). An attention *pair*
//! `(p, r)` with `r <= p` means "q chunk p attends kv chunk r"; causal LM
//! requires every such pair exactly once — that's the invariant the
//! property tests pin down.
//!
//! Ring (unbalanced): timestep t has worker p compute pair `(p, p-t)` if
//! `t <= p`, else idle → idle fraction `(P²-P)/2P²` → ½.
//!
//! Load-balanced: timeline shrinks to `⌊P/2⌋+1` steps. At step t, owners
//! `w >= t` compute distance-t pairs `(w, w-t)`; helpers `w < t` compute the
//! distance-`(P-t)` pairs `(w+P-t, w)` on behalf of their owners and ship
//! the partial `(o, m, l)` back for a `rescale(·)` merge. Helpers sit out
//! only when `2t == P` (P even, where owner and helper distances coincide)
//! → idle fraction `1/2P` (P even) or 0 (P odd), Eq. (2). (The paper's
//! Alg. 2 line 14 writes the skip condition as `t != ⌊P/2⌋`, which would
//! leave distance-⌈P/2⌉ pairs uncovered for odd P; `2t != P` is the version
//! that matches its own Figure 6 and Eq. (2).)

/// What a worker computes at one timestep (at most one attn(·) kernel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeOp {
    /// Causal diagonal block: attn(q_w, k_w, v_w), the `(w, w)` pair.
    Diag,
    /// Owner-path: attn(q_w, k_r, v_r) for pair `(w, kv_from)`.
    Own { kv_from: usize },
    /// Helper-path: attn(q_owner, k_w, v_w) for pair `(owner, w)`, result
    /// shipped back to `owner` for rescale.
    Help { owner: usize },
}

/// One worker's plan for one timestep: its compute op plus the comm ops it
/// must initiate / await. Send ops live on the comm stream and overlap with
/// compute (paper §3.2).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepPlan {
    pub compute: Option<ComputeOp>,
    /// Ship local (k, v) to this worker (it runs `Own{kv_from: me}`).
    /// At most one per step by construction — owners at distance t are
    /// distinct, so a kv chunk has a single consumer per timestep. Using
    /// `Option` (not `Vec`) keeps plan construction allocation-free
    /// (EXPERIMENTS.md §Perf: 157 ms -> ~8 ms at P=1024).
    pub send_kv_to: Option<usize>,
    /// Ship local q (and in backward: do, o, lse) to this helper.
    pub send_q_to: Option<usize>,
    /// Await a helper partial from this worker and `rescale(·)`-merge.
    pub recv_helper_from: Option<usize>,
}

impl StepPlan {
    pub fn is_idle(&self) -> bool {
        self.compute.is_none()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Ring,
    Balanced,
}

/// A complete schedule: `steps[t][w]` is worker w's plan at timestep t.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub n_workers: usize,
    pub steps: Vec<Vec<StepPlan>>,
}

impl Schedule {
    pub fn ring(p: usize) -> Schedule {
        assert!(p >= 1);
        let mut steps = vec![vec![StepPlan::default(); p]; p];
        for w in 0..p {
            steps[0][w].compute = Some(ComputeOp::Diag);
        }
        for t in 1..p {
            for w in 0..p {
                if t <= w {
                    steps[t][w].compute = Some(ComputeOp::Own { kv_from: w - t });
                    steps[t][w - t].send_kv_to = Some(w);
                }
            }
        }
        Schedule { kind: ScheduleKind::Ring, n_workers: p, steps }
    }

    pub fn balanced(p: usize) -> Schedule {
        assert!(p >= 1);
        let t_max = p / 2;
        let mut steps = vec![vec![StepPlan::default(); p]; t_max + 1];
        for w in 0..p {
            steps[0][w].compute = Some(ComputeOp::Diag);
        }
        for t in 1..=t_max {
            for w in 0..p {
                if w >= t {
                    // owner path: distance-t pair (w, w-t)
                    steps[t][w].compute = Some(ComputeOp::Own { kv_from: w - t });
                    steps[t][w - t].send_kv_to = Some(w);
                } else if 2 * t != p {
                    // helper path: distance-(P-t) pair (w + P - t, w)
                    let owner = w + p - t;
                    steps[t][w].compute = Some(ComputeOp::Help { owner });
                    steps[t][owner].send_q_to = Some(w);
                    steps[t][owner].recv_helper_from = Some(w);
                }
            }
        }
        Schedule { kind: ScheduleKind::Balanced, n_workers: p, steps }
    }

    pub fn build(kind: ScheduleKind, p: usize) -> Schedule {
        match kind {
            ScheduleKind::Ring => Schedule::ring(p),
            ScheduleKind::Balanced => Schedule::balanced(p),
        }
    }

    /// Lower to the op-DAG IR ([`crate::coordinator::plan::Plan`]) for one
    /// pass — what the executor and the event-driven simulator consume.
    pub fn lower(&self, pass: super::plan::Pass) -> super::plan::Plan {
        super::plan::Plan::from_schedule(self, pass)
    }

    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// All attention pairs `(owner, kv)` this schedule computes, with the
    /// `(t, executing_worker)` slot that computes each.
    pub fn computed_pairs(&self) -> Vec<((usize, usize), (usize, usize))> {
        let mut out = Vec::new();
        for (t, row) in self.steps.iter().enumerate() {
            for (w, plan) in row.iter().enumerate() {
                match plan.compute {
                    Some(ComputeOp::Diag) => out.push(((w, w), (t, w))),
                    Some(ComputeOp::Own { kv_from }) => out.push(((w, kv_from), (t, w))),
                    Some(ComputeOp::Help { owner }) => out.push(((owner, w), (t, w))),
                    None => {}
                }
            }
        }
        out
    }

    /// Number of idle (worker, timestep) slots.
    pub fn idle_slots(&self) -> usize {
        self.steps
            .iter()
            .flat_map(|row| row.iter())
            .filter(|p| p.is_idle())
            .count()
    }

    /// Idle fraction over this schedule's own timeline (`T·P` slots) —
    /// what Figure 4's speedup analysis uses.
    pub fn idle_fraction(&self) -> f64 {
        self.idle_slots() as f64 / (self.n_steps() * self.n_workers) as f64
    }

    /// Speedup over a single worker executing all `P(P+1)/2` pair units
    /// sequentially, assuming one pair per step (Figure 4 left's model).
    pub fn ideal_speedup(&self) -> f64 {
        let work = self.n_workers * (self.n_workers + 1) / 2;
        work as f64 / self.n_steps() as f64
    }

    /// Validate the causal-coverage invariant; returns an error message on
    /// the first violation. Cheap enough to run at executor startup.
    pub fn validate(&self) -> Result<(), String> {
        let p = self.n_workers;
        let mut seen = vec![vec![0usize; p]; p];
        for ((owner, kv), (t, w)) in self.computed_pairs() {
            if kv > owner {
                return Err(format!("non-causal pair ({owner},{kv}) at t={t} w={w}"));
            }
            seen[owner][kv] += 1;
        }
        for owner in 0..p {
            for kv in 0..=owner {
                match seen[owner][kv] {
                    1 => {}
                    0 => return Err(format!("pair ({owner},{kv}) never computed")),
                    n => return Err(format!("pair ({owner},{kv}) computed {n} times")),
                }
            }
        }
        // every send has a consumer in the same step and vice versa
        for (t, row) in self.steps.iter().enumerate() {
            for (w, plan) in row.iter().enumerate() {
                if let Some(to) = plan.send_kv_to {
                    if row[to].compute != Some(ComputeOp::Own { kv_from: w }) {
                        return Err(format!("dangling kv send {w}->{to} at t={t}"));
                    }
                }
                if let Some(to) = plan.send_q_to {
                    if row[to].compute != Some(ComputeOp::Help { owner: w }) {
                        return Err(format!("dangling q send {w}->{to} at t={t}"));
                    }
                }
                if let Some(from) = plan.recv_helper_from {
                    if row[from].compute != Some(ComputeOp::Help { owner: w }) {
                        return Err(format!("dangling helper recv {from}->{w} at t={t}"));
                    }
                }
                if let Some(ComputeOp::Own { kv_from }) = plan.compute {
                    if row[kv_from].send_kv_to != Some(w) {
                        return Err(format!("missing kv send {kv_from}->{w} at t={t}"));
                    }
                }
                if let Some(ComputeOp::Help { owner }) = plan.compute {
                    if row[owner].send_q_to != Some(w) {
                        return Err(format!("missing q send {owner}->{w} at t={t}"));
                    }
                    if row[owner].recv_helper_from != Some(w) {
                        return Err(format!("missing helper recv {w}->{owner} at t={t}"));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Per-chunk shape of a document-packed (variable-length) batch: how many
/// tokens the chunk holds and how many packed documents overlap it. This
/// is the first-class generalization of the `Kernel::Raw`/`Payload::Raw`
/// escape hatch: every compute/transfer op lowered from a varlen schedule
/// carries a token-exact cost derived from these counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkSpec {
    /// Tokens assigned to this chunk (contiguous slice of the packed batch).
    pub tokens: usize,
    /// Packed documents overlapping this chunk.
    pub docs: usize,
}

/// A document-packed batch split into `P` contiguous token chunks.
///
/// `doc_lens` are the packed document lengths in order; `boundaries` are
/// the `P + 1` monotone token offsets of the chunk cuts (`boundaries[0] =
/// 0`, `boundaries[P] = total`). Attention never crosses a document
/// boundary, so the *token-exact* work of a chunk pair `(q, kv)` is the
/// number of causal same-document token pairs between the two slices —
/// that is what [`VarlenSpec::pair_weight`] computes and what the varlen
/// lowering scales every op by. Chunk pairs that share no document carry
/// zero weight and are skipped entirely (the causal-masking win of packing
/// over padding).
///
/// All scales are expressed relative to the *reference chunk* `c_ref =
/// total / P` — the chunk size an `AttnCost` is resolved at — so a uniform
/// single-document spec lowers to exactly the classic equal-chunk plan.
#[derive(Clone, Debug, PartialEq)]
pub struct VarlenSpec {
    pub doc_lens: Vec<usize>,
    pub boundaries: Vec<usize>,
}

impl VarlenSpec {
    /// Equal-token boundaries over the given packed documents.
    pub fn equal_split(doc_lens: Vec<usize>, p: usize) -> VarlenSpec {
        assert!(p >= 1 && !doc_lens.is_empty());
        let total: usize = doc_lens.iter().sum();
        assert!(total >= p, "need at least one token per chunk");
        let boundaries: Vec<usize> = (0..=p).map(|i| i * total / p).collect();
        VarlenSpec { doc_lens, boundaries }
    }

    /// One document spanning the whole batch, equal chunks — the
    /// degenerate spec whose lowering bit-matches the classic equal-chunk
    /// plan.
    pub fn uniform(tokens_per_chunk: usize, p: usize) -> VarlenSpec {
        VarlenSpec::equal_split(vec![tokens_per_chunk * p], p)
    }

    /// Deterministic Zipf-skewed packed batch: `n_docs` documents with
    /// lengths ∝ `1 / rank^alpha` normalized to `total_tokens`, shuffled
    /// into packing order by `seed`. This is the harness's stand-in for a
    /// real document-packed pretraining batch (a few huge documents, a
    /// long tail of short ones).
    pub fn pack_zipf(n_docs: usize, total_tokens: usize, alpha: f64, seed: u64, p: usize) -> VarlenSpec {
        assert!(n_docs >= 1 && total_tokens >= n_docs.max(p));
        let weights: Vec<f64> = (1..=n_docs).map(|r| (r as f64).powf(-alpha)).collect();
        let wsum: f64 = weights.iter().sum();
        let mut lens: Vec<usize> = weights
            .iter()
            .map(|w| ((total_tokens as f64) * w / wsum).round().max(1.0) as usize)
            .collect();
        // absorb the rounding error into the largest document
        let assigned: usize = lens.iter().sum();
        if assigned > total_tokens {
            let mut excess = assigned - total_tokens;
            for l in lens.iter_mut() {
                let take = excess.min(l.saturating_sub(1));
                *l -= take;
                excess -= take;
                if excess == 0 {
                    break;
                }
            }
        } else {
            lens[0] += total_tokens - assigned;
        }
        // deterministic Fisher–Yates shuffle into packing order
        let mut rng = crate::util::Rng::new(seed ^ 0xda7a_9acc_ed00_0001);
        for k in (1..lens.len()).rev() {
            let j = rng.below(k + 1);
            lens.swap(k, j);
        }
        VarlenSpec::equal_split(lens, p)
    }

    pub fn n_chunks(&self) -> usize {
        self.boundaries.len() - 1
    }

    pub fn total_tokens(&self) -> usize {
        *self.boundaries.last().unwrap()
    }

    /// The reference chunk size the cost classes are resolved at.
    pub fn ref_tokens(&self) -> f64 {
        self.total_tokens() as f64 / self.n_chunks() as f64
    }

    pub fn chunk_tokens(&self, w: usize) -> usize {
        self.boundaries[w + 1] - self.boundaries[w]
    }

    /// Tokens of document `d` falling inside chunk `w`.
    fn overlap(&self, doc_span: (usize, usize), w: usize) -> usize {
        let lo = doc_span.0.max(self.boundaries[w]);
        let hi = doc_span.1.min(self.boundaries[w + 1]);
        hi.saturating_sub(lo)
    }

    fn doc_spans(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.doc_lens.iter().scan(0usize, |off, &l| {
            let s = *off;
            *off += l;
            Some((s, s + l))
        })
    }

    /// Per-chunk `(tokens, docs)` summary.
    pub fn chunk(&self, w: usize) -> ChunkSpec {
        let docs = self
            .doc_spans()
            .filter(|&span| self.overlap(span, w) > 0)
            .count();
        ChunkSpec { tokens: self.chunk_tokens(w), docs }
    }

    /// Token-exact work of chunk pair `(q, kv)`, `kv <= q`: causal
    /// same-document token pairs between the two slices. Off-diagonal
    /// pairs contribute `q_overlap × kv_overlap` per shared document (all
    /// such pairs are causal — every kv token precedes every q token);
    /// the diagonal uses the continuous triangle model `t²/2`, matching
    /// the equal-chunk convention that a diagonal block costs half a full
    /// block.
    pub fn pair_weight(&self, q: usize, kv: usize) -> f64 {
        assert!(kv <= q);
        let mut w = 0.0f64;
        for span in self.doc_spans() {
            let qo = self.overlap(span, q) as f64;
            if qo == 0.0 {
                continue;
            }
            if kv == q {
                w += qo * qo / 2.0;
            } else {
                w += qo * self.overlap(span, kv) as f64;
            }
        }
        w
    }

    /// Compute scale of pair `(q, kv)` relative to the reference full
    /// block (`c_ref²` token pairs). Exactly `1.0` (off-diagonal) / `0.5`
    /// (diagonal) on a uniform single-document spec.
    pub fn pair_scale(&self, q: usize, kv: usize) -> f64 {
        let c = self.ref_tokens();
        self.pair_weight(q, kv) / (c * c)
    }

    /// Transfer scale of chunk `w`'s token span relative to the reference
    /// chunk — kv / q-bundle / result payload bytes all scale linearly.
    pub fn token_scale(&self, w: usize) -> f64 {
        self.chunk_tokens(w) as f64 / self.ref_tokens()
    }

    /// FLOP inflation of the pad-to-max baseline: every document padded to
    /// the longest, then equal-chunked. Returns the padded-to-real chunk
    /// ratio (so padded pair time = ratio² × reference pair time).
    pub fn pad_factor(&self) -> f64 {
        let max = *self.doc_lens.iter().max().unwrap();
        (self.doc_lens.len() * max) as f64 / self.total_tokens() as f64
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.boundaries.len() < 2 {
            return Err("need at least one chunk".into());
        }
        if self.boundaries[0] != 0 {
            return Err("boundaries must start at 0".into());
        }
        for w in self.boundaries.windows(2) {
            if w[1] <= w[0] {
                return Err(format!("empty or inverted chunk at offset {}", w[0]));
            }
        }
        let total: usize = self.doc_lens.iter().sum();
        if total != self.total_tokens() {
            return Err(format!(
                "doc lens sum to {total} but boundaries end at {}",
                self.total_tokens()
            ));
        }
        if self.doc_lens.iter().any(|&l| l == 0) {
            return Err("zero-length document".into());
        }
        Ok(())
    }
}

/// Closed-form ring idle fraction over the P×P timeline: `(P²-P)/2P²`.
pub fn ring_idle_fraction(p: usize) -> f64 {
    ((p * p - p) as f64) / ((2 * p * p) as f64)
}

/// Paper Eq. (2): balanced idle fraction, normalized like the ring timeline
/// (idle slots over P² — the convention under which the paper states 1/2P).
pub fn balanced_idle_fraction_eq2(p: usize) -> f64 {
    if p % 2 == 0 {
        1.0 / (2 * p) as f64
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_small() {
        for p in 1..=9 {
            let s = Schedule::ring(p);
            s.validate().unwrap();
            assert_eq!(s.n_steps(), p);
            assert_eq!(s.idle_slots(), (p * p - p) / 2);
            assert!((s.idle_fraction() - ring_idle_fraction(p)).abs() < 1e-12);
        }
    }

    #[test]
    fn balanced_small() {
        for p in 1..=9 {
            let s = Schedule::balanced(p);
            s.validate().unwrap();
            assert_eq!(s.n_steps(), p / 2 + 1);
            if p % 2 == 1 {
                assert_eq!(s.idle_slots(), 0, "P odd must be idle-free (Eq. 2)");
            } else if p > 1 {
                // only the 2t == P step idles, and exactly P/2 slots
                assert_eq!(s.idle_slots(), p / 2);
            }
        }
    }

    #[test]
    fn eq2_matches_schedule_idle_slots() {
        // Eq. 2 normalizes idle slots by the ring's P² timeline.
        for p in 2..=16 {
            let s = Schedule::balanced(p);
            let got = s.idle_slots() as f64 / ((p * p) as f64);
            assert!(
                (got - balanced_idle_fraction_eq2(p)).abs() < 1e-12,
                "P={p}: {got} vs {}",
                balanced_idle_fraction_eq2(p)
            );
        }
    }

    #[test]
    fn fig4_speedups() {
        // Paper Fig. 4 (8 workers): unbalanced saturates at 4.5x, balanced 7.2x.
        assert!((Schedule::ring(8).ideal_speedup() - 4.5).abs() < 1e-12);
        assert!((Schedule::balanced(8).ideal_speedup() - 7.2).abs() < 1e-12);
    }

    #[test]
    fn helpers_skip_only_even_midpoint() {
        let s = Schedule::balanced(8);
        let mid = &s.steps[4];
        assert!(mid[0].is_idle() && mid[3].is_idle());
        assert!(!mid[4].is_idle());
        let s = Schedule::balanced(7);
        for row in &s.steps[1..] {
            assert!(row.iter().all(|p| !p.is_idle()));
        }
    }

    // property sweeps (exhaustive over P — proptest unavailable offline;
    // an exhaustive sweep over every P in range is strictly stronger anyway)

    #[test]
    fn prop_valid_for_all_p() {
        for p in 1..64 {
            Schedule::ring(p).validate().unwrap();
            Schedule::balanced(p).validate().unwrap();
        }
    }

    #[test]
    fn prop_balanced_covers_exactly_like_ring() {
        for p in 1..48 {
            let mut a: Vec<_> = Schedule::ring(p)
                .computed_pairs()
                .into_iter()
                .map(|(pair, _)| pair)
                .collect();
            let mut b: Vec<_> = Schedule::balanced(p)
                .computed_pairs()
                .into_iter()
                .map(|(pair, _)| pair)
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b, "P={p}");
        }
    }

    #[test]
    fn prop_balanced_timeline_halves() {
        for p in 2..64 {
            let ring = Schedule::ring(p).n_steps();
            let bal = Schedule::balanced(p).n_steps();
            assert_eq!(bal, p / 2 + 1);
            assert!(bal <= ring / 2 + 1, "P={p}");
        }
    }

    #[test]
    fn prop_odd_p_idle_free() {
        for p in (1..128).step_by(2) {
            assert_eq!(Schedule::balanced(p).idle_slots(), 0, "P={p}");
        }
    }

    #[test]
    fn prop_pair_count_triangular() {
        for p in 1..48 {
            let s = Schedule::balanced(p);
            assert_eq!(s.computed_pairs().len(), p * (p + 1) / 2, "P={p}");
        }
    }

    #[test]
    fn varlen_uniform_is_reference_scale() {
        let spec = VarlenSpec::uniform(128, 8);
        spec.validate().unwrap();
        for w in 0..8 {
            assert_eq!(spec.token_scale(w), 1.0);
            assert_eq!(spec.pair_scale(w, w), 0.5);
            for kv in 0..w {
                assert_eq!(spec.pair_scale(w, kv), 1.0);
            }
        }
        assert_eq!(spec.pad_factor(), 1.0);
    }

    #[test]
    fn varlen_weights_conserve_doc_work() {
        // sum of causal pair weights == Σ_d t_d²/2 (the continuous model),
        // independent of where the chunk boundaries fall
        let spec = VarlenSpec::equal_split(vec![37, 5, 100, 18, 64], 7);
        spec.validate().unwrap();
        let total: f64 = (0..7)
            .flat_map(|q| (0..=q).map(move |kv| (q, kv)))
            .map(|(q, kv)| spec.pair_weight(q, kv))
            .sum();
        let want: f64 = spec.doc_lens.iter().map(|&t| (t * t) as f64 / 2.0).sum();
        assert!((total - want).abs() < 1e-9, "{total} vs {want}");
    }

    #[test]
    fn varlen_doc_disjoint_pairs_have_zero_weight() {
        // two docs of 64 tokens, 4 chunks of 32: chunks 0-1 hold doc 0,
        // chunks 2-3 hold doc 1 — cross-doc pairs carry no work
        let spec = VarlenSpec::equal_split(vec![64, 64], 4);
        assert_eq!(spec.pair_weight(2, 0), 0.0);
        assert_eq!(spec.pair_weight(3, 1), 0.0);
        assert!(spec.pair_weight(1, 0) > 0.0);
        assert!(spec.pair_weight(3, 2) > 0.0);
        assert_eq!(spec.chunk(1).docs, 1);
    }

    #[test]
    fn zipf_pack_is_deterministic_and_conserves_tokens() {
        let a = VarlenSpec::pack_zipf(32, 16384, 1.1, 7, 16);
        let b = VarlenSpec::pack_zipf(32, 16384, 1.1, 7, 16);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert_eq!(a.doc_lens.iter().sum::<usize>(), 16384);
        assert_eq!(a.doc_lens.len(), 32);
        // zipf skew: padding to the max doc must inflate noticeably
        assert!(a.pad_factor() > 1.5, "pad factor {}", a.pad_factor());
    }

    #[test]
    fn prop_helper_always_earlier_worker() {
        // helpers are always lighter-loaded (smaller index) than owners
        for p in 2..48 {
            for (t, row) in Schedule::balanced(p).steps.iter().enumerate() {
                for (w, plan) in row.iter().enumerate() {
                    if let Some(ComputeOp::Help { owner }) = plan.compute {
                        assert!(w < t && owner > w, "P={p} helper {w} owner {owner} t={t}");
                    }
                }
            }
        }
    }
}
