//! The `Session` pipeline: one spec-driven path from a declared workload
//! to a planned, optimized, executed, traced — and *calibrated* — run.
//!
//! DISTFLASHATTN's contribution is a composition: balanced scheduling,
//! overlapped KV communication, and checkpointing working as one system.
//! [`RunSpec`] declares every axis of one distributed attention run —
//! workload shape (heads / GQA / varlen packing), cluster topology,
//! schedule kind, kernel backend, optimization policy, prefetch, tracing —
//! and [`Session`] lowers it exactly once into the `(fwd, bwd)` plan pair
//! that the executor, the simulators, and the reports all consume.
//!
//! Typed stages, each idempotent and each returning the session for
//! chaining:
//!
//! ```text
//! RunSpec ──Session::new──▶ plan() ──▶ optimize() ──▶ execute() ──▶ trace()
//!                              ▲                          │
//!                              └────── calibrate() ◀──────┘
//! ```
//!
//! * [`Session::plan`] — lower the schedule to validated forward/backward
//!   plans (token-exact when the spec carries a [`VarlenSpec`]).
//! * [`Session::optimize`] — run the cost-model-driven pass pipeline
//!   (role flips, placement, memory-capped prefetch depth; token-level
//!   rebalancing for varlen specs) under the session's *current* cost
//!   model, keeping a candidate only when it scores no worse than the
//!   plan it would replace.
//! * [`Session::execute`] / [`Session::execute_with`] — launch the placed
//!   worker network and run the plans with real tensors on the chosen
//!   backend (PJRT artifacts, pure-host reference kernels, or the
//!   zero-work echo).
//! * [`Session::trace`] — the merged per-op timelines of the last run,
//!   aligned against the event engine's predictions.
//! * [`Session::calibrate`] — fit the cost model's kernel classes from the
//!   last run's own measured trace (transfer classes keep their modeled
//!   byte sizes — the in-process fabric measures no wire), so a second
//!   `optimize()` tunes against *measured* rather than modeled kernel
//!   times. This closes the measure→model loop the ROADMAP asked for.
//!
//! The legacy free functions in [`super::harness`] survive as thin
//! deprecated shims over this pipeline; the golden-equivalence suite
//! (`rust/tests/session_golden.rs`) pins each one bit-identical to its
//! `RunSpec` translation.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::checkpoint::CkptStrategy;
use super::comm::build_network_placed;
use super::executor::{AttnCtx, MergedTrace, RunTrace, ATTN_ARTIFACTS};
use super::fault::{ExecError, FailureReport, FaultEvent, FaultSpec, RankFaults, StallKernels};
use super::optimize::{
    optimize_plan_with_op_costs, optimize_schedule_ckpt, optimize_varlen, OptimizeOpts,
};
use super::plan::{LowerOpts, Pass, Plan};
use super::recovery::{RecoverCtx, RecoveryPolicy, RecoveryReport};
use super::schedule::{Schedule, ScheduleKind, VarlenSpec};
use crate::baselines::{attn_cost_from_dims, bwd_cost_from_fwd};
use crate::config::ClusterSpec;
use crate::report::trace as trace_report;
use crate::runtime::{HostKernels, Kernels, NullKernels, Runtime, Tensor};
use crate::simulator::{AttnCost, PlanSim};
use crate::util::json::Json;
use crate::util::Rng;

// ---------------------------------------------------------------------------
// Declarative spec
// ---------------------------------------------------------------------------

/// Attention workload geometry for one distributed call. Shapes only — the
/// token axis layout (uniform vs document-packed) lives in
/// [`RunSpec::varlen`].
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Tokens per worker chunk — the reference chunk size the cost classes
    /// are resolved at. With a varlen spec this is the *mean* chunk
    /// (`total / P`); the ragged per-chunk sizes come from the spec.
    pub chunk_tokens: usize,
}

impl Workload {
    pub fn new(
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
        chunk_tokens: usize,
    ) -> Workload {
        Workload { n_heads, n_kv_heads, head_dim, chunk_tokens }
    }

    /// Infer the workload from full-sequence tensors: q is `(H, N, D)`,
    /// k is `(KVH, N, D)`, split over `n_workers` chunks.
    pub fn from_tensors(q: &Tensor, k: &Tensor, n_workers: usize) -> Workload {
        Workload {
            n_heads: q.shape[0],
            n_kv_heads: k.shape[0],
            head_dim: q.shape[2],
            chunk_tokens: (q.shape[1] / n_workers.max(1)).max(1),
        }
    }
}

/// Which optimizer pipeline [`Session::optimize`] runs.
#[derive(Clone, Debug, PartialEq)]
pub enum OptimizePolicy {
    /// Keep the default lowering. An *explicit* `optimize()` call still
    /// runs the schedule pipeline with default knobs; `execute()` does not
    /// auto-optimize.
    Off,
    /// `optimize_schedule` passes: GQA role flipping, placement, prefetch
    /// depth.
    Schedule(OptimizeOpts),
    /// Token-level varlen rebalancing (`optimize_varlen`): boundary moves +
    /// per-pair flips, then placement and depth. Requires
    /// [`RunSpec::varlen`]. Boundaries are rebalanced on the forward pass
    /// and shared with the backward lowering (one sharding feeds both
    /// passes), which re-optimizes flips/placement/depth at fixed cuts.
    Varlen(OptimizeOpts),
}

impl OptimizePolicy {
    pub fn is_off(&self) -> bool {
        matches!(self, OptimizePolicy::Off)
    }
}

/// Which kernel backend each worker constructs.
#[derive(Clone, Debug, PartialEq)]
pub enum BackendSpec {
    /// Real PJRT artifacts compiled from this directory (needs
    /// `make artifacts` plus the real `xla` bindings).
    Pjrt(PathBuf),
    /// Pure-Rust reference kernels — runs on a bare checkout.
    HostRef,
    /// Zero-work shape echo — transport micro-benchmarks only.
    Null,
}

/// Everything one distributed attention run depends on, declared up front.
/// Construct with one of the presets ([`RunSpec::host`],
/// [`RunSpec::plans_only`], [`RunSpec::pjrt`]) and override fields with
/// struct-update syntax; serialize with [`RunSpec::to_json`] /
/// [`RunSpec::from_json`] (the `repro run --spec` contract).
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Head geometry + chunk size. `None` = resolve from the PJRT artifact
    /// manifest (requires a [`BackendSpec::Pjrt`] backend).
    pub workload: Option<Workload>,
    /// Worker count. `0` = resolve from the PJRT artifact manifest.
    pub n_workers: usize,
    pub schedule: ScheduleKind,
    /// Document-packed token layout; `None` = uniform equal chunks.
    pub varlen: Option<VarlenSpec>,
    /// Topology the cost models and the optimizer price links against.
    pub cluster: ClusterSpec,
    pub backend: BackendSpec,
    pub optimize: OptimizePolicy,
    /// Pin the executed prefetch depth; `None` = the plan's own depth
    /// (1 by default, the autotuned knee after `optimize()`).
    pub prefetch_depth: Option<usize>,
    /// Stacked attention calls per `execute()` (fwd + bwd each, distinct
    /// call ids) — the per-layer timeline harness. 1 = one call.
    pub layers: usize,
    /// Run the backward pass in `execute()` (synthesized-input runs).
    pub backward: bool,
    /// Record per-op wall-clock spans, merged across ranks.
    pub trace: bool,
    /// Model the pre-zero-copy send path (executor bench baseline arm).
    pub deep_copy_sends: bool,
    /// Host-kernel worker threads per rank (HostRef backend). Clamped to
    /// the machine's available parallelism at execution; the effective
    /// count is recorded in the run's [`MergedTrace::threads`]. The tiled
    /// kernels are bit-identical across thread counts, so this trades
    /// wall-clock only — 1 (the default) pins single-threaded execution
    /// for reproducible traces. 0 is rejected by [`RunSpec::validate`].
    pub threads: usize,
    /// Pick the host-kernel tile geometry with the cached startup sweep
    /// (`kernel::tiled::autotune`) instead of the compile-time default.
    /// Different tile shapes reorder the blocked softmax and are *not*
    /// bit-identical to each other, so the sweep is opt-in; the
    /// effective pick is recorded in the run's [`MergedTrace::tiles`].
    pub autotune_tiles: bool,
    /// Gradient-checkpointing strategy lowered into the backward plan.
    /// [`CkptStrategy::RematAware`] (the default) keeps the lowering
    /// unchanged and instead saves the per-layer `(o, lse)` pair;
    /// [`CkptStrategy::HfStyle`] prepends the attention forward's op
    /// stream as a recompute prefix to the backward plan.
    pub ckpt: CkptStrategy,
    /// Seeded fault scenario injected into the run (delay/reorder, drop
    /// with retransmit, stalls, a crash — see [`FaultSpec`]). Arming any
    /// spec, even an all-zero one, instruments the comm path and turns on
    /// the sim-derived recv watchdog; a failed run then surfaces through
    /// [`Session::failure_report`]. `None` (the default) is the
    /// uninstrumented fast path.
    pub faults: Option<FaultSpec>,
    /// What [`Session::execute_supervised`] does about a failed run:
    /// surface it unchanged ([`RecoveryPolicy::FailFast`], the default —
    /// the PR 8 contract), respawn the failed rank and replay from the
    /// last checkpointed layer boundary, or re-lower over the P−1
    /// survivors. Plain `execute*()` calls ignore this field.
    pub recovery: RecoveryPolicy,
    /// Seed for synthesized inputs (`execute()` without tensors).
    pub seed: u64,
}

impl RunSpec {
    fn base(
        schedule: ScheduleKind,
        n_workers: usize,
        workload: Option<Workload>,
        backend: BackendSpec,
    ) -> RunSpec {
        RunSpec {
            workload,
            n_workers,
            schedule,
            varlen: None,
            cluster: ClusterSpec::dgx_1x8(),
            backend,
            optimize: OptimizePolicy::Off,
            prefetch_depth: None,
            layers: 1,
            backward: true,
            trace: false,
            deep_copy_sends: false,
            threads: 1,
            autotune_tiles: false,
            ckpt: CkptStrategy::RematAware,
            faults: None,
            recovery: RecoveryPolicy::FailFast,
            seed: 0,
        }
    }

    /// Pure-host run: reference kernels, no artifacts needed.
    pub fn host(schedule: ScheduleKind, n_workers: usize, workload: Workload) -> RunSpec {
        RunSpec::base(schedule, n_workers, Some(workload), BackendSpec::HostRef)
    }

    /// Minimal spec for plan-structure work (lowering, simulation): Null
    /// backend, unit workload — cost classes never matter until
    /// `optimize()`/`execute()` price or run them.
    pub fn plans_only(schedule: ScheduleKind, n_workers: usize) -> RunSpec {
        RunSpec::base(schedule, n_workers, Some(Workload::new(1, 1, 1, 1)), BackendSpec::Null)
    }

    /// Artifact-backed run; workload and worker count resolve from the
    /// manifest at session construction.
    pub fn pjrt(artifact_dir: &Path, schedule: ScheduleKind) -> RunSpec {
        RunSpec::base(schedule, 0, None, BackendSpec::Pjrt(artifact_dir.to_path_buf()))
    }

    /// Spec matching already-lowered plans (the deprecated-shim path):
    /// worker count, varlen layout, and depth come from the plan, head
    /// geometry from the tensors.
    pub fn for_plans(plan: &Plan, backend: BackendSpec, q: &Tensor, k: &Tensor) -> RunSpec {
        let mut spec = RunSpec::base(
            ScheduleKind::Balanced,
            plan.n_workers,
            Some(Workload::from_tensors(q, k, plan.n_workers)),
            backend,
        );
        spec.varlen = plan.varlen.as_deref().cloned();
        spec
    }

    pub fn validate(&self) -> Result<()> {
        if self.layers == 0 {
            bail!("layers must be >= 1");
        }
        if self.threads == 0 {
            bail!("threads must be >= 1 (1 pins single-threaded host kernels)");
        }
        if (self.workload.is_none() || self.n_workers == 0)
            && !matches!(self.backend, BackendSpec::Pjrt(_))
        {
            bail!(
                "workload and n_workers can only be manifest-resolved with a Pjrt backend; \
                 set them explicitly for HostRef/Null runs"
            );
        }
        if let Some(w) = &self.workload {
            if w.n_heads == 0 || w.n_kv_heads == 0 || w.head_dim == 0 || w.chunk_tokens == 0 {
                bail!("workload dimensions must all be >= 1");
            }
            if w.n_heads % w.n_kv_heads != 0 {
                bail!(
                    "n_heads ({}) must be a multiple of n_kv_heads ({}) for GQA grouping",
                    w.n_heads,
                    w.n_kv_heads
                );
            }
        }
        if let Some(v) = &self.varlen {
            v.validate().map_err(|e| anyhow!("invalid varlen spec: {e}"))?;
            if self.n_workers != 0 && v.n_chunks() != self.n_workers {
                bail!(
                    "varlen spec has {} chunks but the run declares {} workers",
                    v.n_chunks(),
                    self.n_workers
                );
            }
        }
        if matches!(self.optimize, OptimizePolicy::Varlen(_)) && self.varlen.is_none() {
            bail!("OptimizePolicy::Varlen requires RunSpec::varlen");
        }
        // the schedule pipeline re-lowers *without* the varlen spec, so its
        // candidates could never execute against a doc-masked plan pair —
        // a packed layout must optimize through the varlen pipeline
        if matches!(self.optimize, OptimizePolicy::Schedule(_)) && self.varlen.is_some() {
            bail!(
                "OptimizePolicy::Schedule ignores the declared varlen layout; use \
                 OptimizePolicy::Varlen for document-packed runs"
            );
        }
        // the varlen rebalancer re-lowers prefix-free candidate plans, so an
        // HfStyle recompute prefix would be silently dropped on acceptance
        if matches!(self.optimize, OptimizePolicy::Varlen(_)) && self.ckpt == CkptStrategy::HfStyle
        {
            bail!(
                "OptimizePolicy::Varlen rebalances prefix-free plans and would drop the \
                 HfStyle recompute lowering; use CkptStrategy::RematAware with the varlen \
                 pipeline (or OptimizePolicy::Schedule for HfStyle runs)"
            );
        }
        if let Some(f) = &self.faults {
            // manifest-resolved runs (n_workers == 0) re-validate rank
            // targets in `Session::new` once the worker count is known
            let n = if self.n_workers > 0 { self.n_workers } else { usize::MAX };
            f.validate(n)?;
            // a crash step past the plan's last step would never fire:
            // reject it here instead of letting it silently no-op mid-run
            if let Some(c) = &f.crash {
                if self.n_workers > 0 {
                    let t = Schedule::build(self.schedule, self.n_workers).n_steps();
                    let last = match c.pass {
                        Pass::Forward => t - 1,
                        // HfStyle prepends a T-step recompute replay; the
                        // trailing dkv Accum sits one step past the body
                        Pass::Backward => {
                            if self.ckpt == CkptStrategy::HfStyle {
                                2 * t
                            } else {
                                t
                            }
                        }
                        Pass::Decode => {
                            bail!("crash injection targets training passes, not decode")
                        }
                    };
                    if c.step > last {
                        bail!(
                            "crash step {} is past the {:?}-pass plan's last step {} \
                             ({:?} schedule, {} workers)",
                            c.step,
                            c.pass,
                            last,
                            self.schedule,
                            self.n_workers
                        );
                    }
                }
            }
        }
        let n = if self.n_workers > 0 { self.n_workers } else { usize::MAX };
        self.recovery.validate(n)?;
        if let OptimizePolicy::Schedule(o) | OptimizePolicy::Varlen(o) = &self.optimize {
            for &(w, factor) in &o.slowdowns {
                if self.n_workers > 0 && w >= self.n_workers {
                    bail!(
                        "optimize.slowdowns pins rank {w} but the run declares {} workers",
                        self.n_workers
                    );
                }
                if factor < 1.0 || factor.is_nan() {
                    bail!("optimize.slowdowns factor for rank {w} must be >= 1.0 (got {factor})");
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Execution results (moved from `harness`, which now re-exports them)
// ---------------------------------------------------------------------------

/// Gathered results of one distributed attention call over N tokens.
#[derive(Debug)]
pub struct DistAttnResult {
    /// Normalized attention output (H, N, D).
    pub o: Tensor,
    /// Logsumexp (H, N).
    pub lse: Tensor,
    /// Gradients, present iff `do_` was supplied.
    pub grads: Option<(Tensor, Tensor, Tensor)>,
    /// Total bytes moved between workers.
    pub comm_bytes: u64,
}

/// Executor knobs for one distributed call — the imperative subset of a
/// [`RunSpec`], kept for the deprecated `run_dist_attention_exec` shim.
#[derive(Clone, Debug)]
pub struct ExecOpts {
    pub backend: BackendSpec,
    /// Record per-op wall-clock spans, merged across ranks in the result.
    pub trace: bool,
    /// Model the pre-zero-copy send path (full-chunk allocation + memcpy
    /// per payload) — the executor micro-bench's baseline arm.
    pub deep_copy_sends: bool,
    /// Host-kernel worker threads per rank (clamped to 1..=available
    /// parallelism at execution; see [`RunSpec::threads`]).
    pub threads: usize,
    /// Autotune host-kernel tiles at first use (see
    /// [`RunSpec::autotune_tiles`]).
    pub autotune_tiles: bool,
    /// Seeded fault scenario to inject (see [`FaultSpec`]). `None` leaves
    /// the comm path uninstrumented.
    pub faults: Option<FaultSpec>,
    /// Per-`recv` watchdog budget in seconds, armed together with
    /// `faults`. `Session::execute_with` derives it from the event
    /// engine's predicted makespan (stall-adjusted) when the spec does
    /// not pin one.
    pub watchdog_s: Option<f64>,
}

impl ExecOpts {
    pub fn host() -> ExecOpts {
        ExecOpts {
            backend: BackendSpec::HostRef,
            trace: false,
            deep_copy_sends: false,
            threads: 1,
            autotune_tiles: false,
            faults: None,
            watchdog_s: None,
        }
    }
}

/// One executed distributed call: results plus (when requested) the
/// rank-merged per-op timelines and the harness wall-clock.
#[derive(Debug)]
pub struct ExecRun {
    pub result: DistAttnResult,
    /// Last layer's merged forward timeline (when tracing).
    pub fwd_trace: Option<MergedTrace>,
    /// Last layer's merged backward timeline (when tracing a backward).
    pub bwd_trace: Option<MergedTrace>,
    /// Per-layer merged `(fwd, bwd)` timelines when tracing a stacked
    /// (`layers > 1`) run; empty otherwise.
    pub layer_traces: Vec<(Option<MergedTrace>, Option<MergedTrace>)>,
    /// Wall-clock of the whole call (thread spawn to last join).
    pub wall_s: f64,
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// Audit record of one `optimize()` stage for one pass — what the
/// optimizer found and whether the session kept it.
#[derive(Clone, Debug)]
pub struct StageAudit {
    pub pass: Pass,
    /// Simulated seconds of the default lowering under the stage's cost
    /// model (pad/equal baselines for varlen live in `pad_s`/`equal_s`).
    pub default_s: f64,
    /// Simulated seconds of the candidate at its placement and depth.
    pub optimized_s: f64,
    pub prefetch_depth: usize,
    /// Flipped schedule steps (schedule pipeline; empty for varlen).
    pub flipped_steps: Vec<usize>,
    /// Flipped helper pairs (varlen pipeline).
    pub flipped_pairs: usize,
    pub moved_ranks: usize,
    /// Chunk cuts moved off the incoming boundaries (varlen pipeline).
    pub moved_boundaries: usize,
    /// Event-engine passes this stage spent, *including* the session's
    /// acceptance scoring — the per-stage audits sum to
    /// [`Session::sim_calls`], so every published budget is attributable.
    pub sim_calls: usize,
    /// Dirty-suffix incremental rescores the varlen rebalancer's candidate
    /// scoring reused a checkpointed prefix for (0 for other pipelines).
    pub incremental_rescores: usize,
    /// Whether the candidate replaced the session's current plan.
    pub accepted: bool,
    /// Whether the stage ran under a trace-calibrated cost model.
    pub calibrated: bool,
    /// Pad-to-max baseline seconds (varlen pipeline; 0 otherwise).
    pub pad_s: f64,
    /// Equal-token baseline seconds (varlen pipeline; 0 otherwise).
    pub equal_s: f64,
}

/// Merged traces of the last executed run plus their event-engine
/// alignment — the `trace()` stage's view.
pub struct SessionTrace<'a> {
    pub fwd: &'a MergedTrace,
    pub bwd: Option<&'a MergedTrace>,
    pub fwd_cmp: trace_report::TraceComparison,
    pub bwd_cmp: Option<trace_report::TraceComparison>,
    /// Per-layer `(fwd, bwd)` timelines for stacked runs.
    pub layers: &'a [(Option<MergedTrace>, Option<MergedTrace>)],
}

impl<'a> SessionTrace<'a> {
    /// The standard trace-vs-sim table (see [`trace_report::render`]).
    pub fn render(&self, title: &str) -> String {
        let mut rows: Vec<(&str, &trace_report::TraceComparison)> = vec![("fwd", &self.fwd_cmp)];
        if let Some(b) = &self.bwd_cmp {
            rows.push(("bwd", b));
        }
        trace_report::render(title, &rows)
    }

    /// Per-layer timeline rows (stacked runs); `None` when the run had a
    /// single layer.
    pub fn layer_timeline(&self, title: &str) -> Option<String> {
        if self.layers.len() <= 1 {
            return None;
        }
        let mut rows: Vec<(String, &MergedTrace)> = Vec::new();
        for (l, (f, b)) in self.layers.iter().enumerate() {
            if let Some(f) = f {
                rows.push((format!("L{l} fwd"), f));
            }
            if let Some(b) = b {
                rows.push((format!("L{l} bwd"), b));
            }
        }
        Some(trace_report::layer_timeline(title, &rows))
    }
}

/// Score a finished plan under a cost model at its own placement/depth.
fn score_plan(plan: &Plan, cluster: &ClusterSpec, cost: &AttnCost) -> f64 {
    PlanSim::new(plan, cost).total_s(cluster, &plan.placement, plan.prefetch_depth)
}

/// One spec-driven run pipeline (see module docs).
pub struct Session {
    spec: RunSpec,
    /// Resolved geometry (manifest-filled when the spec left it blank).
    workload: Workload,
    n_workers: usize,
    fwd_cost: AttnCost,
    bwd_cost: AttnCost,
    calibrated: bool,
    plans: Option<(Arc<Plan>, Arc<Plan>)>,
    optimized: bool,
    /// Plans were supplied by the caller (`with_plans`): `optimize()`
    /// must tune them in place rather than re-lower a schedule.
    caller_plans: bool,
    last_run: Option<ExecRun>,
    sim_calls: usize,
    audits: Vec<StageAudit>,
    /// Per-op traced durations from the last `calibrate()` (when the
    /// policy opts into `per_op_costs`), keyed by the exact plan they were
    /// measured against *and* the worker thread count they were measured
    /// at — the overlay only applies while a plan's op stream still
    /// matches op-for-op and the run would execute with the same
    /// effective thread count (kernel durations scale with threads, so a
    /// mismatched overlay would mis-price every compute op).
    fwd_op_costs: Option<(Arc<Plan>, usize, Vec<(usize, f64)>)>,
    bwd_op_costs: Option<(Arc<Plan>, usize, Vec<(usize, f64)>)>,
    /// Structured post-mortem of the last failed `execute*()` (typed
    /// per-rank failures + partial traces); `None` after a clean run.
    last_failure: Option<FailureReport>,
    /// Sender-side fault events the last `execute*()` injected, in rank
    /// order — deterministic for a given [`FaultSpec`] seed.
    fault_events: Vec<FaultEvent>,
    /// Audit of the last `execute_supervised*()` (attempts, replayed ops,
    /// time-to-recover); `None` for plain executions and `FailFast` runs.
    pub(crate) recovery_report: Option<RecoveryReport>,
}

impl Session {
    /// Validate the spec, resolve the workload (from the artifact manifest
    /// when blank), and resolve the modeled cost classes.
    pub fn new(spec: RunSpec) -> Result<Session> {
        spec.validate()?;
        let (workload, n_workers) = match (&spec.workload, spec.n_workers) {
            (Some(w), n) if n > 0 => (w.clone(), n),
            _ => {
                let BackendSpec::Pjrt(dir) = &spec.backend else {
                    unreachable!("validate() requires Pjrt for manifest resolution");
                };
                let rt = Runtime::load(dir)
                    .context("resolving the workload from the artifact manifest")?;
                let c = rt.manifest().config.clone();
                let w = spec.workload.clone().unwrap_or_else(|| {
                    Workload::new(c.n_heads, c.n_kv_heads, c.head_dim, c.chunk_len)
                });
                let n = if spec.n_workers > 0 { spec.n_workers } else { c.n_workers };
                (w, n)
            }
        };
        if let Some(v) = &spec.varlen {
            if v.n_chunks() != n_workers {
                bail!(
                    "varlen spec has {} chunks but the run resolved to {} workers",
                    v.n_chunks(),
                    n_workers
                );
            }
        }
        let c_ref = match &spec.varlen {
            Some(v) => v.ref_tokens(),
            None => workload.chunk_tokens as f64,
        };
        let fwd_cost = attn_cost_from_dims(
            &spec.cluster,
            c_ref,
            workload.n_heads,
            workload.n_kv_heads,
            workload.head_dim,
        );
        let bwd_cost = bwd_cost_from_fwd(&fwd_cost, workload.head_dim);
        if let Some(f) = &spec.faults {
            // re-check rank targets against the resolved worker count
            // (validate() skipped them for manifest-resolved specs)
            f.validate(n_workers)?;
        }
        Ok(Session {
            spec,
            workload,
            n_workers,
            fwd_cost,
            bwd_cost,
            calibrated: false,
            plans: None,
            optimized: false,
            caller_plans: false,
            last_run: None,
            sim_calls: 0,
            audits: Vec::new(),
            fwd_op_costs: None,
            bwd_op_costs: None,
            last_failure: None,
            fault_events: Vec::new(),
            recovery_report: None,
        })
    }

    /// Session over caller-supplied lowered plans (the deprecated shims'
    /// path): the spec must carry an explicit workload and worker count.
    /// `plan()` keeps the given plans as-is; an explicit `optimize()`
    /// tunes them *in place* (placement + prefetch depth via
    /// [`super::optimize::optimize_plan`]) — it never re-lowers a
    /// schedule over them, so the caller's op stream is preserved.
    pub fn with_plans(spec: RunSpec, fwd: Arc<Plan>, bwd: Arc<Plan>) -> Result<Session> {
        if spec.workload.is_none() || spec.n_workers == 0 {
            bail!("Session::with_plans needs an explicit workload and worker count");
        }
        let mut s = Session::new(spec)?;
        s.plans = Some((fwd, bwd));
        s.optimized = true;
        s.caller_plans = true;
        Ok(s)
    }

    /// Run a serving workload through the same plan → simulate →
    /// execute → trace spine ([`crate::serving::serve`]): the
    /// continuous-batching scheduler lowers to a `Pass::Decode` plan,
    /// the event engine scores it, and the hostref backend replays it
    /// against per-rank paged KV-caches with a full-prefill oracle
    /// check. Associated (not `&self`): serving owns its whole pipeline
    /// through [`crate::serving::ServeSpec`].
    pub fn serve(spec: &crate::serving::ServeSpec) -> Result<crate::serving::ServeOutcome> {
        crate::serving::serve(spec)
    }

    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Current `(fwd, bwd)` cost models — modeled at construction,
    /// measured after [`Session::calibrate`].
    pub fn costs(&self) -> (&AttnCost, &AttnCost) {
        (&self.fwd_cost, &self.bwd_cost)
    }

    /// Replace the cost models (externally measured classes, exotic
    /// hardware). [`Session::calibrate`] is the trace-fitted version.
    pub fn set_costs(&mut self, fwd: AttnCost, bwd: AttnCost) -> &mut Session {
        self.fwd_cost = fwd;
        self.bwd_cost = bwd;
        self
    }

    pub fn calibrated(&self) -> bool {
        self.calibrated
    }

    /// Event-engine passes spent across every `optimize()` stage so far —
    /// the search budget the acceptance criteria report.
    pub fn sim_calls(&self) -> usize {
        self.sim_calls
    }

    /// Audit trail of every `optimize()` stage (one record per pass).
    pub fn audits(&self) -> &[StageAudit] {
        &self.audits
    }

    /// Lower the schedule to validated forward/backward plans. Idempotent;
    /// does nothing when plans already exist (lowered, optimized, or
    /// caller-supplied).
    pub fn plan(&mut self) -> Result<&mut Session> {
        if self.plans.is_some() {
            return Ok(self);
        }
        let schedule = Schedule::build(self.spec.schedule, self.n_workers);
        schedule
            .validate()
            .map_err(|e| anyhow!("invalid schedule: {e}"))?;
        let lopts = match &self.spec.varlen {
            Some(v) => LowerOpts {
                varlen: Some(Arc::new(v.clone())),
                ckpt: Some(self.spec.ckpt),
                ..Default::default()
            },
            None => LowerOpts { ckpt: Some(self.spec.ckpt), ..Default::default() },
        };
        let mut fwd = Plan::from_schedule_opts(&schedule, Pass::Forward, &lopts);
        fwd.validate_lowered()
            .map_err(|e| anyhow!("invalid forward plan: {e}"))?;
        let mut bwd = Plan::from_schedule_opts(&schedule, Pass::Backward, &lopts);
        bwd.validate_lowered()
            .map_err(|e| anyhow!("invalid backward plan: {e}"))?;
        if let Some(d) = self.spec.prefetch_depth {
            fwd.prefetch_depth = d;
            bwd.prefetch_depth = d;
        }
        self.plans = Some((Arc::new(fwd), Arc::new(bwd)));
        Ok(self)
    }

    /// Run the optimizer pass pipeline under the current cost model and
    /// keep each candidate only if it scores no worse than the plan it
    /// would replace (so repeated calls — e.g. after [`Session::calibrate`]
    /// — are monotone under the model in force). Appends one
    /// [`StageAudit`] per pass.
    pub fn optimize(&mut self) -> Result<&mut Session> {
        self.plan()?;
        let opts = match &self.spec.optimize {
            OptimizePolicy::Schedule(o) | OptimizePolicy::Varlen(o) => o.clone(),
            OptimizePolicy::Off => OptimizeOpts::default(),
        };
        if self.caller_plans {
            // caller-supplied plans: tune placement + depth in place,
            // never re-lower (the op stream is the caller's contract)
            self.optimize_given_stage(Pass::Forward, &opts)?;
            self.optimize_given_stage(Pass::Backward, &opts)?;
            self.optimized = true;
            return Ok(self);
        }
        let varlen_mode = match &self.spec.optimize {
            OptimizePolicy::Varlen(_) => true,
            OptimizePolicy::Schedule(_) => false,
            OptimizePolicy::Off => self.spec.varlen.is_some(),
        };
        let schedule = Schedule::build(self.spec.schedule, self.n_workers);
        if varlen_mode {
            self.optimize_varlen_stage(&schedule, &opts)?;
        } else {
            self.optimize_schedule_stage(&schedule, Pass::Forward, &opts)?;
            self.optimize_schedule_stage(&schedule, Pass::Backward, &opts)?;
        }
        self.optimized = true;
        Ok(self)
    }

    fn cost_for(&self, pass: Pass) -> AttnCost {
        match pass {
            Pass::Forward | Pass::Decode => self.fwd_cost,
            Pass::Backward => self.bwd_cost,
        }
    }

    fn per_op_enabled(&self) -> bool {
        match &self.spec.optimize {
            OptimizePolicy::Schedule(o) | OptimizePolicy::Varlen(o) => o.per_op_costs,
            OptimizePolicy::Off => false,
        }
    }

    /// Pinned per-worker slowdown factors from the optimize policy
    /// ([`OptimizeOpts::slowdowns`]) — applied to every acceptance score
    /// so "best plan under a stuck straggler" queries are consistent with
    /// the optimizer's own search.
    fn policy_slowdowns(&self) -> &[(usize, f64)] {
        match &self.spec.optimize {
            OptimizePolicy::Schedule(o) | OptimizePolicy::Varlen(o) => &o.slowdowns,
            OptimizePolicy::Off => &[],
        }
    }

    /// The thread count host kernels would actually run with — the spec's
    /// request clamped to the machine, mirroring `execute_plans`.
    fn effective_threads(&self) -> usize {
        let avail = thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(1);
        self.spec.threads.clamp(1, avail)
    }

    /// The calibrated per-op overlay for `pass` — only when the policy
    /// opts in ([`OptimizeOpts::per_op_costs`]), `plan` still matches
    /// the traced plan's op stream op-for-op (the overlay indexes ops
    /// positionally, so a re-lowered candidate must fall back to the
    /// fitted class means), and the run would still execute with the
    /// thread count the overlay was measured at (durations measured at
    /// `threads = t` mis-price every compute op at a different count).
    fn op_overlay_for(&self, pass: Pass, plan: &Plan) -> &[(usize, f64)] {
        if !self.per_op_enabled() {
            return &[];
        }
        let stored = match pass {
            Pass::Forward => &self.fwd_op_costs,
            Pass::Backward => &self.bwd_op_costs,
            Pass::Decode => return &[],
        };
        match stored {
            Some((traced, threads, ocs))
                if traced.ops == plan.ops && *threads == self.effective_threads() =>
            {
                ocs
            }
            _ => &[],
        }
    }

    /// [`score_plan`] with the per-op overlay and any pinned straggler
    /// slowdowns applied where valid.
    fn score_plan_overlayed(&self, pass: Pass, plan: &Plan, cost: &AttnCost) -> f64 {
        let overlay = self.op_overlay_for(pass, plan);
        let slowdowns = self.policy_slowdowns();
        if overlay.is_empty() && slowdowns.is_empty() {
            return score_plan(plan, &self.spec.cluster, cost);
        }
        let mut sim = PlanSim::new(plan, cost);
        for &(op, s) in overlay {
            sim.set_op_cost(op, s);
        }
        for &(w, f) in slowdowns {
            sim.set_worker_slowdown(w, f);
        }
        sim.total_s(&self.spec.cluster, &plan.placement, plan.prefetch_depth)
    }

    /// The shared acceptance tail: score `cand` against the current plan
    /// for `pass` under `cost`, keep whichever is not worse, and drop the
    /// recorded run on a swap (a trace no longer aligns with changed
    /// plans op-for-op). Returns `(accepted, kept score, kept depth)` —
    /// the audit's `optimized_s`/`prefetch_depth`, describing the plan
    /// the session actually holds.
    fn accept_candidate(
        &mut self,
        pass: Pass,
        mut cand: Plan,
        cost: &AttnCost,
    ) -> (bool, f64, usize) {
        if let Some(d) = self.spec.prefetch_depth {
            cand.prefetch_depth = d;
        }
        let (cur_fwd, cur_bwd) = self.plans.as_ref().expect("plan() ran").clone();
        let current = match pass {
            Pass::Forward => cur_fwd.clone(),
            Pass::Backward => cur_bwd.clone(),
            Pass::Decode => unreachable!("decode plans are not optimizer stages"),
        };
        let cur_s = self.score_plan_overlayed(pass, &current, cost);
        let cand_s = self.score_plan_overlayed(pass, &cand, cost);
        self.sim_calls += 2;
        let accepted = cand_s <= cur_s;
        if accepted && cand != *current {
            // the plan actually changed: a recorded trace no longer aligns
            // with it op-for-op (an identical candidate keeps the run)
            self.last_run = None;
        }
        let chosen = if accepted { Arc::new(cand) } else { current };
        let kept_depth = chosen.prefetch_depth;
        self.plans = Some(match pass {
            Pass::Forward => (chosen, cur_bwd),
            Pass::Backward => (cur_fwd, chosen),
            Pass::Decode => unreachable!("decode plans are not optimizer stages"),
        });
        (accepted, if accepted { cand_s } else { cur_s }, kept_depth)
    }

    fn optimize_schedule_stage(
        &mut self,
        schedule: &Schedule,
        pass: Pass,
        opts: &OptimizeOpts,
    ) -> Result<()> {
        let cost = self.cost_for(pass);
        let o =
            optimize_schedule_ckpt(schedule, pass, &self.spec.cluster, &cost, opts, Some(self.spec.ckpt));
        self.sim_calls += o.sim_calls;
        o.plan
            .validate_lowered()
            .map_err(|e| anyhow!("optimized {} plan invalid: {e}", pass.name()))?;
        let (accepted, kept_s, kept_depth) = self.accept_candidate(pass, o.plan, &cost);
        self.audits.push(StageAudit {
            pass,
            default_s: o.default_s,
            optimized_s: kept_s,
            prefetch_depth: kept_depth,
            flipped_steps: o.flipped_steps,
            flipped_pairs: 0,
            moved_ranks: o.moved_ranks,
            moved_boundaries: 0,
            sim_calls: o.sim_calls + 2,
            incremental_rescores: 0,
            accepted,
            calibrated: self.calibrated,
            pad_s: 0.0,
            equal_s: 0.0,
        });
        Ok(())
    }

    /// Caller-plan stage: placement + memory-capped depth over the given
    /// plan ([`optimize_plan_with_op_costs`] — no re-lowering, per-op
    /// calibrated costs when the policy opts in), with the same
    /// accept-only-if-not-worse rule as the schedule stage.
    fn optimize_given_stage(&mut self, pass: Pass, opts: &OptimizeOpts) -> Result<()> {
        let cost = self.cost_for(pass);
        let current = {
            let (cur_fwd, cur_bwd) = self.plans.as_ref().expect("plan() ran");
            match pass {
                Pass::Forward => cur_fwd.clone(),
                Pass::Backward => cur_bwd.clone(),
                Pass::Decode => unreachable!("decode plans are not optimizer stages"),
            }
        };
        let o = optimize_plan_with_op_costs(
            &current,
            &self.spec.cluster,
            &cost,
            opts,
            self.op_overlay_for(pass, &current),
        );
        self.sim_calls += o.sim_calls;
        let (accepted, kept_s, kept_depth) = self.accept_candidate(pass, o.plan, &cost);
        self.audits.push(StageAudit {
            pass,
            default_s: o.default_s,
            optimized_s: kept_s,
            prefetch_depth: kept_depth,
            flipped_steps: Vec::new(),
            flipped_pairs: 0,
            moved_ranks: o.moved_ranks,
            moved_boundaries: 0,
            sim_calls: o.sim_calls + 2,
            incremental_rescores: 0,
            accepted,
            calibrated: self.calibrated,
            pad_s: 0.0,
            equal_s: 0.0,
        });
        Ok(())
    }

    /// Varlen stage: rebalance boundaries on the forward pass, then
    /// re-optimize the backward at the chosen cuts (flips, placement,
    /// depth), and accept or reject the `(fwd, bwd)` pair *jointly* so the
    /// two passes always share one chunking.
    fn optimize_varlen_stage(&mut self, schedule: &Schedule, opts: &OptimizeOpts) -> Result<()> {
        if self.spec.ckpt == CkptStrategy::HfStyle {
            bail!(
                "varlen rebalancing re-lowers prefix-free candidate plans and would drop \
                 the HfStyle recompute lowering; run with CkptStrategy::RematAware"
            );
        }
        let (cur_fwd, cur_bwd) = self.plans.as_ref().expect("plan() ran").clone();
        // continue from wherever the current plans' boundaries are
        let spec0: VarlenSpec = cur_fwd
            .varlen
            .as_deref()
            .cloned()
            .or_else(|| self.spec.varlen.clone())
            .ok_or_else(|| anyhow!("varlen optimization needs a varlen spec"))?;
        let of = optimize_varlen(
            schedule,
            &spec0,
            Pass::Forward,
            &self.spec.cluster,
            &self.fwd_cost,
            opts,
        );
        self.sim_calls += of.sim_calls;
        let bwd_opts = OptimizeOpts { move_boundaries: false, ..opts.clone() };
        let ob = optimize_varlen(
            schedule,
            &of.spec,
            Pass::Backward,
            &self.spec.cluster,
            &self.bwd_cost,
            &bwd_opts,
        );
        self.sim_calls += ob.sim_calls;
        let mut cand_fwd = of.plan.clone();
        let mut cand_bwd = ob.plan.clone();
        cand_fwd
            .validate_lowered()
            .map_err(|e| anyhow!("rebalanced fwd plan invalid: {e}"))?;
        cand_bwd
            .validate_lowered()
            .map_err(|e| anyhow!("rebalanced bwd plan invalid: {e}"))?;
        if let Some(d) = self.spec.prefetch_depth {
            cand_fwd.prefetch_depth = d;
            cand_bwd.prefetch_depth = d;
        }
        let cur_f = self.score_plan_overlayed(Pass::Forward, &cur_fwd, &self.fwd_cost);
        let cur_b = self.score_plan_overlayed(Pass::Backward, &cur_bwd, &self.bwd_cost);
        let cand_f = self.score_plan_overlayed(Pass::Forward, &cand_fwd, &self.fwd_cost);
        let cand_b = self.score_plan_overlayed(Pass::Backward, &cand_bwd, &self.bwd_cost);
        self.sim_calls += 4;
        let accepted = cand_f + cand_b <= cur_f + cur_b;
        // audit the score and depth of whichever pair the session keeps
        let (audit_f, audit_b) = if accepted { (cand_f, cand_b) } else { (cur_f, cur_b) };
        let (depth_f, depth_b) = if accepted {
            (cand_fwd.prefetch_depth, cand_bwd.prefetch_depth)
        } else {
            (cur_fwd.prefetch_depth, cur_bwd.prefetch_depth)
        };
        for (o, pass, own_s, depth) in [
            (&of, Pass::Forward, audit_f, depth_f),
            (&ob, Pass::Backward, audit_b, depth_b),
        ] {
            self.audits.push(StageAudit {
                pass,
                default_s: o.equal_s,
                optimized_s: own_s,
                prefetch_depth: depth,
                flipped_steps: Vec::new(),
                flipped_pairs: o.flipped_pairs,
                moved_ranks: o.moved_ranks,
                moved_boundaries: o.moved_boundaries,
                sim_calls: o.sim_calls + 2,
                incremental_rescores: o.incremental_rescores,
                accepted,
                calibrated: self.calibrated,
                pad_s: o.pad_s,
                equal_s: o.equal_s,
            });
        }
        if accepted {
            if cand_fwd != *cur_fwd || cand_bwd != *cur_bwd {
                // rebalanced boundaries change the skipped-pair set (and
                // so the op count): a recorded trace cannot describe the
                // new plans (an identical pair keeps the run)
                self.last_run = None;
            }
            self.plans = Some((Arc::new(cand_fwd), Arc::new(cand_bwd)));
        }
        Ok(())
    }

    fn ensure_ready(&mut self) -> Result<()> {
        self.plan()?;
        if !self.optimized && !self.spec.optimize.is_off() {
            self.optimize()?;
        }
        Ok(())
    }

    /// The `(fwd, bwd)` plan pair, lowering (and optimizing, per policy)
    /// on demand.
    pub fn plans(&mut self) -> Result<(Arc<Plan>, Arc<Plan>)> {
        self.ensure_ready()?;
        Ok(self.plans.as_ref().expect("ensure_ready built plans").clone())
    }

    /// Execute with inputs synthesized from the spec's shapes and seed
    /// (q, k, v, and — when `spec.backward` — do, drawn in that order).
    pub fn execute(&mut self) -> Result<&mut Session> {
        let (q, k, v, do_) = self.synth_inputs()?;
        self.execute_with(&q, &k, &v, do_.as_ref())
    }

    /// The `execute()` input contract, shared with the supervised path:
    /// q, k, v, and — when `spec.backward` — do, drawn from the spec's
    /// seed in that order.
    pub(crate) fn synth_inputs(&mut self) -> Result<(Tensor, Tensor, Tensor, Option<Tensor>)> {
        self.ensure_ready()?;
        let w = &self.workload;
        let n = match &self.spec.varlen {
            Some(v) => v.total_tokens(),
            None => w.chunk_tokens * self.n_workers,
        };
        let (h, kvh, d) = (w.n_heads, w.n_kv_heads, w.head_dim);
        let mut rng = Rng::new(self.spec.seed);
        let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
        let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
        let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
        let do_ = self
            .spec
            .backward
            .then(|| Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d)));
        Ok((q, k, v, do_))
    }

    /// Execute with caller-supplied full-sequence tensors: q `(H, N, D)`,
    /// k/v `(KVH, N, D)`, do `(H, N, D)`. Plans are built (and optimized,
    /// per policy) on demand; the placed worker network is launched from
    /// the forward plan's rank→GPU binding.
    pub fn execute_with(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        do_: Option<&Tensor>,
    ) -> Result<&mut Session> {
        self.ensure_ready()?;
        let faults = self.spec.faults.clone();
        self.attempt_with(q, k, v, do_, faults, None)?;
        Ok(self)
    }

    /// One execution attempt: run the plan pair with `faults` armed —
    /// which may differ from the spec's (a respawned replay clears the
    /// already-fired crash) — and, when `recover` is set, skip the
    /// checkpointed layer prefix and record per-layer `(o, lse)`
    /// artifacts into its store as the run progresses.
    pub(crate) fn attempt_with(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        do_: Option<&Tensor>,
        faults: Option<FaultSpec>,
        recover: Option<RecoverCtx>,
    ) -> Result<()> {
        self.ensure_ready()?;
        self.recovery_report = None;
        let (fwd, bwd) = self.plans.as_ref().expect("ensure_ready built plans").clone();
        let watchdog_s = match &faults {
            Some(f) => Some(match f.watchdog_s {
                Some(w) => w,
                None => self.watchdog_budget_s(&fwd, &bwd, f),
            }),
            None => None,
        };
        let opts = ExecOpts {
            backend: self.spec.backend.clone(),
            trace: self.spec.trace,
            deep_copy_sends: self.spec.deep_copy_sends,
            threads: self.spec.threads,
            autotune_tiles: self.spec.autotune_tiles,
            faults,
            watchdog_s,
        };
        let attempt = execute_plans(fwd, bwd, q, k, v, do_, &opts, self.spec.layers, recover);
        self.fault_events = attempt.fault_events;
        self.last_failure = attempt.report;
        match attempt.run {
            Ok(run) => {
                self.last_run = Some(run);
                Ok(())
            }
            Err(e) => {
                // a stale trace from a previous clean run must not pass
                // for this run's post-mortem
                self.last_run = None;
                Err(e)
            }
        }
    }

    /// Per-`recv` watchdog budget: the event engine's predicted makespan
    /// for the plan pair — with the fault spec's stall factors applied to
    /// the simulated workers — scaled by a deliberately generous
    /// host-vs-model multiplier and clamped to a sane band. A hung peer
    /// trips it within seconds; a merely slow host run does not.
    fn watchdog_budget_s(&self, fwd: &Plan, bwd: &Plan, faults: &FaultSpec) -> f64 {
        let mut sim_total = 0.0;
        let mut passes: Vec<(&Plan, &AttnCost)> = vec![(fwd, &self.fwd_cost)];
        if self.spec.backward {
            passes.push((bwd, &self.bwd_cost));
        }
        for &(plan, cost) in &passes {
            let mut sim = PlanSim::new(plan, cost);
            for &(w, f) in &faults.stalls {
                sim.set_worker_slowdown(w, f);
            }
            sim_total += sim.total_s(&self.spec.cluster, &plan.placement, plan.prefetch_depth);
        }
        // modeled seconds are GPU-class; host-kernel execution runs orders
        // of magnitude slower, hence the 2e4 scale
        (sim_total * self.spec.layers as f64 * 2e4).clamp(5.0, 120.0)
    }

    /// The last executed run.
    pub fn run(&self) -> Result<&ExecRun> {
        self.last_run
            .as_ref()
            .ok_or_else(|| anyhow!("no run yet — call execute() first"))
    }

    /// Structured post-mortem of the last failed `execute*()`: typed
    /// per-rank failures in rank order plus whatever partial traces the
    /// surviving ranks flushed. `None` after a clean run.
    pub fn failure_report(&self) -> Option<&FailureReport> {
        self.last_failure.as_ref()
    }

    /// Audit of the last `execute_supervised*()`: restart attempts,
    /// replayed vs skipped ops, time-to-recover, artifact verification.
    /// `None` for plain executions and `FailFast` supervised runs.
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.recovery_report.as_ref()
    }

    /// Sender-side fault events the last `execute*()` injected, in rank
    /// order. Reproducible: the same [`FaultSpec`] seed yields the same
    /// sequence.
    pub fn fault_events(&self) -> &[FaultEvent] {
        &self.fault_events
    }

    /// The last executed run's gathered results.
    pub fn result(&self) -> Result<&DistAttnResult> {
        Ok(&self.run()?.result)
    }

    /// Take ownership of the last executed run (the shims' return path).
    pub fn take_run(&mut self) -> Option<ExecRun> {
        self.last_run.take()
    }

    /// The `trace()` stage: merged per-op timelines of the last run plus
    /// their event-engine alignment. Requires `spec.trace`. An
    /// `optimize()` that swaps plans drops the recorded run (the trace no
    /// longer aligns with the plans op-for-op) — re-`execute()` first.
    pub fn trace(&self) -> Result<SessionTrace<'_>> {
        let run = self.run()?;
        let ft = run.fwd_trace.as_ref().ok_or_else(|| {
            anyhow!("the last run was not traced — set RunSpec::trace before execute()")
        })?;
        let (fwd_plan, bwd_plan) = self.plans.as_ref().expect("a run implies plans");
        let fwd_cmp = trace_report::compare(fwd_plan, ft);
        let bwd_cmp = run.bwd_trace.as_ref().map(|bt| trace_report::compare(bwd_plan, bt));
        Ok(SessionTrace {
            fwd: ft,
            bwd: run.bwd_trace.as_ref(),
            fwd_cmp,
            bwd_cmp,
            layers: &run.layer_traces,
        })
    }

    /// Fit the cost model's kernel classes from the last run's own
    /// measured trace (per-class means; transfer classes keep their
    /// modeled byte sizes — the in-process fabric has no measurable wire).
    /// A subsequent [`Session::optimize`] then tunes against measured
    /// rather than modeled kernel times.
    pub fn calibrate(&mut self) -> Result<&mut Session> {
        let (ft, bt) = {
            let run = self
                .last_run
                .as_ref()
                .ok_or_else(|| anyhow!("nothing to calibrate from — call execute() first"))?;
            let ft = run.fwd_trace.as_ref().ok_or_else(|| {
                anyhow!("the last run was not traced — set RunSpec::trace before execute()")
            })?;
            (ft.clone(), run.bwd_trace.clone())
        };
        let (fwd_plan, bwd_plan) = self.plans.as_ref().expect("a run implies plans").clone();
        self.fwd_cost = trace_report::calibrate_cost_with_bytes(&fwd_plan, &ft, &self.fwd_cost);
        if self.per_op_enabled() {
            // stamp the overlay with the thread count it was measured at
            // (the trace records the executor's effective count) — a later
            // optimize() under a different RunSpec::threads must fall back
            // to the fitted class means rather than mis-priced op times
            self.fwd_op_costs =
                Some((fwd_plan.clone(), ft.threads, trace_report::per_op_costs(&fwd_plan, &ft)));
        }
        if let Some(bt) = bt {
            self.bwd_cost = trace_report::calibrate_cost_with_bytes(&bwd_plan, &bt, &self.bwd_cost);
            if self.per_op_enabled() {
                self.bwd_op_costs = Some((
                    bwd_plan.clone(),
                    bt.threads,
                    trace_report::per_op_costs(&bwd_plan, &bt),
                ));
            }
        }
        self.calibrated = true;
        Ok(self)
    }

    /// Human-readable pipeline summary: spec, plans, optimizer audit,
    /// last run.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let backend = match &self.spec.backend {
            BackendSpec::Pjrt(d) => format!("pjrt:{}", d.display()),
            BackendSpec::HostRef => "hostref".into(),
            BackendSpec::Null => "null".into(),
        };
        out.push_str(&format!(
            "session: {:?} P={} heads {}/{} d{} chunk {}{} backend={backend} layers={}\n",
            self.spec.schedule,
            self.n_workers,
            self.workload.n_heads,
            self.workload.n_kv_heads,
            self.workload.head_dim,
            self.workload.chunk_tokens,
            if self.spec.varlen.is_some() { " (varlen)" } else { "" },
            self.spec.layers,
        ));
        if let Some((f, b)) = &self.plans {
            out.push_str(&format!(
                "plans: fwd {} ops / bwd {} ops, depth {}/{}, placement moved {}\n",
                f.n_ops(),
                b.n_ops(),
                f.prefetch_depth,
                b.prefetch_depth,
                f.placement.iter().enumerate().filter(|&(i, &g)| i != g).count(),
            ));
        }
        for a in &self.audits {
            out.push_str(&format!(
                "optimize[{}{}]: {:.3} -> {:.3} ms ({:.2}x, {} sims{}{})\n",
                a.pass.name(),
                if a.calibrated { ", calibrated" } else { "" },
                a.default_s * 1e3,
                a.optimized_s * 1e3,
                if a.optimized_s > 0.0 { a.default_s / a.optimized_s } else { 1.0 },
                a.sim_calls,
                if a.accepted { "" } else { ", rejected" },
                if a.moved_boundaries > 0 {
                    format!(", {} cuts moved", a.moved_boundaries)
                } else {
                    String::new()
                },
            ));
        }
        if let Some(run) = &self.last_run {
            out.push_str(&format!(
                "executed: wall {:.2} ms, comm {:.2} MB{}\n",
                run.wall_s * 1e3,
                run.result.comm_bytes as f64 / 1e6,
                if run.fwd_trace.is_some() { ", traced" } else { "" },
            ));
        }
        out.push_str(&format!(
            "budget: {} sim calls{}\n",
            self.sim_calls,
            if self.calibrated { ", cost model calibrated from trace" } else { "" },
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// The executor engine (moved from `harness::run_dist_attention_exec`)
// ---------------------------------------------------------------------------

/// What one `execute_plans` call produced: the run (or the error that
/// stopped it), the typed per-rank post-mortem when anything failed, and
/// the injected fault events — separated from `run` because the vendored
/// `anyhow` cannot carry (or downcast to) structured payloads.
pub(crate) struct ExecAttempt {
    pub(crate) run: Result<ExecRun>,
    pub(crate) report: Option<FailureReport>,
    pub(crate) fault_events: Vec<FaultEvent>,
}

impl ExecAttempt {
    /// An attempt that failed before any worker launched.
    fn fail(e: anyhow::Error) -> ExecAttempt {
        ExecAttempt { run: Err(e), report: None, fault_events: Vec::new() }
    }
}

/// Launch the placed worker network and run `layers` stacked attention
/// calls (fwd + optional bwd each) over the given plans — the engine
/// behind [`Session::execute_with`] and the deprecated harness shims.
///
/// Worker threads run inside a panic guard: a panicking or failing rank
/// broadcasts a typed abort to its peers (so their blocking recvs unwind
/// instead of hanging) and surfaces in the attempt's [`FailureReport`]
/// with its rank attached — `join()` never propagates a bare panic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_plans(
    fwd_plan: Arc<Plan>,
    bwd_plan: Arc<Plan>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
    opts: &ExecOpts,
    layers: usize,
    recover: Option<RecoverCtx>,
) -> ExecAttempt {
    let n_workers = fwd_plan.n_workers;
    if layers == 0 {
        return ExecAttempt::fail(anyhow!("layers must be >= 1"));
    }
    // supervised replay: layers below start_layer completed on every rank
    // (their (o, lse) artifacts sit in the store) and are skipped — the
    // skip is global, so the replayed comm schedule stays symmetric
    let start_layer = recover.as_ref().map(|r| r.start_layer).unwrap_or(0);
    if start_layer >= layers {
        return ExecAttempt::fail(anyhow!(
            "replay start layer {start_layer} out of range for {layers} layer(s)"
        ));
    }
    if bwd_plan.n_workers != n_workers {
        return ExecAttempt::fail(anyhow!(
            "fwd plan has {n_workers} workers, bwd plan {}",
            bwd_plan.n_workers
        ));
    }
    // both passes must agree on the chunking — a backward plan lowered
    // against different boundaries would expect different shapes and
    // pair structure than the tensors sharded below
    if fwd_plan.varlen.as_deref() != bwd_plan.varlen.as_deref() {
        return ExecAttempt::fail(anyhow!(
            "fwd and bwd plans carry different varlen chunk specs"
        ));
    }

    // equal chunks by default; ragged token boundaries for varlen plans
    let (qs, ks, vs, dos) = match fwd_plan.varlen.as_deref() {
        Some(spec) => {
            if spec.total_tokens() != q.shape[1] {
                return ExecAttempt::fail(anyhow!(
                    "varlen spec covers {} tokens but q has {}",
                    spec.total_tokens(),
                    q.shape[1]
                ));
            }
            // the AOT artifacts compile one fixed chunk shape; a ragged
            // chunk would fail the runtime's shape check mid-plan on one
            // worker and deadlock its peers' blocking recvs — reject up
            // front with the honest story instead. (The host backends have
            // no such restriction: they accept any chunk shape.)
            let c0 = spec.chunk_tokens(0);
            let uniform = (1..n_workers).all(|w| spec.chunk_tokens(w) == c0);
            if !uniform && matches!(opts.backend, BackendSpec::Pjrt(_)) {
                return ExecAttempt::fail(anyhow!(
                    "ragged varlen boundaries need per-chunk AOT artifacts; the fixed-shape \
                     manifest executes uniform chunks only (run the host backend, simulate \
                     ragged plans with the event engine, or rebalance with uniform boundaries)"
                ));
            }
            (
                q.chunk_axis1_at(&spec.boundaries),
                k.chunk_axis1_at(&spec.boundaries),
                v.chunk_axis1_at(&spec.boundaries),
                do_.map(|d| d.chunk_axis1_at(&spec.boundaries)),
            )
        }
        None => (
            q.chunk_axis1(n_workers),
            k.chunk_axis1(n_workers),
            v.chunk_axis1(n_workers),
            do_.map(|d| d.chunk_axis1(n_workers)),
        ),
    };

    // bind rank i's mailbox to slot placement[i] — the in-process
    // analogue of the launcher pinning rank i to that GPU. (A backward
    // plan optimized separately may carry a different placement; messages
    // are addressed by logical rank, so the forward placement binding
    // stays correct for both passes.)
    let comms = build_network_placed(n_workers, &fwd_plan.placement);

    struct WorkerOut {
        rank: usize,
        o: Tensor,
        lse: Tensor,
        grads: Option<(Tensor, Tensor, Tensor)>,
        bytes: u64,
    }

    /// What each worker thread hands back: its result (or rank-attributed
    /// error), the typed failure it recorded, the fault events its sender
    /// injected, and the per-layer `(fwd, bwd)` traces it flushed —
    /// traces ride outside `WorkerOut` so a failing rank still surfaces
    /// the spans it completed before unwinding.
    type WorkerRet = (
        Result<WorkerOut>,
        Option<ExecError>,
        Vec<FaultEvent>,
        Vec<(RunTrace, RunTrace)>,
    );

    // Host-kernel worker threads, clamped to the machine (threads=1 pins
    // the single-threaded deterministic baseline; the tiled kernels are
    // bit-identical across counts regardless). The effective value is
    // recorded in every merged trace for provenance.
    let eff_threads = opts
        .threads
        .clamp(1, thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(1));

    // Host-kernel tile geometry: the compile-time default, or the cached
    // startup sweep when the spec opted in. Resolved once here (not per
    // rank) so every worker runs the same shape, and recorded in every
    // merged trace the same way `threads` is.
    let eff_tiles = if opts.autotune_tiles {
        crate::runtime::kernel::tiled::autotune()
    } else {
        crate::runtime::Tiles::default()
    };
    let host_tiles =
        matches!(opts.backend, BackendSpec::HostRef).then_some((eff_tiles.q, eff_tiles.k));

    let deadline = opts.watchdog_s.map(Duration::from_secs_f64);
    let epoch = Instant::now();
    let mut handles = Vec::new();
    for (rank, mut comm) in comms.into_iter().enumerate() {
        let backend = opts.backend.clone();
        let trace = opts.trace;
        let deep = opts.deep_copy_sends;
        let faults = opts.faults.clone();
        let fwd_plan = fwd_plan.clone();
        let bwd_plan = bwd_plan.clone();
        let q = qs[rank].clone();
        let k = ks[rank].clone();
        let v = vs[rank].clone();
        let do_chunk = dos.as_ref().map(|d| d[rank].clone());
        let ckpt_store = recover.as_ref().map(|r| r.store.clone());
        handles.push(thread::spawn(move || -> WorkerRet {
            comm.set_deep_copy_sends(deep);
            let mut stall = 1.0_f64;
            if let Some(fs) = &faults {
                stall = fs.stall_factor(rank);
                let mut rf = RankFaults::new(rank, fs);
                if stall > 1.0 {
                    rf.note_stall(stall);
                }
                comm.set_faults(rf);
                comm.set_deadline(deadline);
            }
            let mut layer_traces: Vec<(RunTrace, RunTrace)> =
                Vec::with_capacity(if trace { layers } else { 0 });
            // the guard keeps a panicking rank from tearing down the join
            // loop unannounced; comm and the trace buffer live outside it
            // so the post-mortem (typed failure, events, partial spans)
            // survives the unwind
            let body = catch_unwind(AssertUnwindSafe(|| -> Result<WorkerOut> {
                let mut kernels: Box<dyn Kernels> = match &backend {
                    BackendSpec::Pjrt(dir) => {
                        let rt = Runtime::load(dir)?;
                        rt.precompile(ATTN_ARTIFACTS)?;
                        Box::new(rt)
                    }
                    BackendSpec::HostRef => {
                        Box::new(HostKernels::with_tiles(eff_threads, eff_tiles))
                    }
                    BackendSpec::Null => Box::new(NullKernels),
                };
                if stall > 1.0 {
                    kernels = Box::new(StallKernels { inner: kernels, factor: stall });
                }
                let epoch = trace.then_some(epoch);
                let mut last: Option<(Tensor, Tensor, Option<(Tensor, Tensor, Tensor)>)> = None;
                for layer in start_layer..layers {
                    let mut ctx = AttnCtx {
                        rank,
                        runtime: &*kernels,
                        comm: &mut comm,
                        plan: &fwd_plan,
                        call_id: (2 * layer) as u32,
                        epoch,
                        trace: RunTrace::default(),
                    };
                    let fwd_res = ctx.forward(&q, &k, &v);
                    let fwd_trace = ctx.trace;
                    let (o, lse) = match fwd_res {
                        Ok(x) => x,
                        Err(e) => {
                            if trace {
                                layer_traces.push((fwd_trace, RunTrace::default()));
                            }
                            return Err(e);
                        }
                    };
                    if let Some(s) = &ckpt_store {
                        s.record_fwd(rank, layer, &o, &lse);
                    }
                    let (grads, bwd_trace) = match do_chunk.as_ref() {
                        Some(d) => {
                            let mut ctx = AttnCtx {
                                rank,
                                runtime: &*kernels,
                                comm: &mut comm,
                                plan: &bwd_plan,
                                call_id: (2 * layer + 1) as u32,
                                epoch,
                                trace: RunTrace::default(),
                            };
                            let bwd_res = ctx.backward(&q, &k, &v, &o, &lse, d);
                            let bwd_trace = ctx.trace;
                            match bwd_res {
                                Ok(g) => (Some(g), bwd_trace),
                                Err(e) => {
                                    if trace {
                                        layer_traces.push((fwd_trace, bwd_trace));
                                    }
                                    return Err(e);
                                }
                            }
                        }
                        None => (None, RunTrace::default()),
                    };
                    if trace {
                        layer_traces.push((fwd_trace, bwd_trace));
                    }
                    if grads.is_some() {
                        if let Some(s) = &ckpt_store {
                            s.record_bwd(rank, layer);
                        }
                    }
                    last = Some((o, lse, grads));
                }
                let (o, lse, grads) = last.expect("layers >= 1");
                let bytes = comm.bytes_sent();
                Ok(WorkerOut { rank, o, lse, grads, bytes })
            }));
            let result: Result<WorkerOut> = match body {
                Ok(Ok(w)) => Ok(w),
                Ok(Err(e)) => {
                    // the executor records + broadcasts typed causes it
                    // surfaces itself; anything else (kernel setup, shape
                    // checks) is this rank's own failure — poison peers so
                    // their blocking recvs unwind instead of hanging
                    if comm.failure().is_none() {
                        let err = ExecError::Failed { rank, msg: format!("{e}") };
                        comm.broadcast_abort(&err);
                        comm.record_failure(err);
                    }
                    Err(e.context(format!("rank {rank} failed")))
                }
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    let err = ExecError::Panicked { rank, msg: msg.clone() };
                    comm.broadcast_abort(&err);
                    comm.record_failure(err);
                    Err(anyhow!("rank {rank} panicked: {msg}"))
                }
            };
            (result, comm.take_failure(), comm.take_fault_events(), layer_traces)
        }));
    }

    let mut outs: Vec<Option<WorkerOut>> = (0..n_workers).map(|_| None).collect();
    let mut comm_bytes = 0;
    let mut failures: Vec<ExecError> = Vec::new();
    let mut fault_events: Vec<FaultEvent> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    let mut trace_by_rank: Vec<Vec<(RunTrace, RunTrace)>> = Vec::with_capacity(n_workers);
    for (rank, h) in handles.into_iter().enumerate() {
        // the in-thread guard converts panics; a join error here means the
        // thread died outside it (unwind during the guard's own teardown)
        let (result, failure, events, traces) = match h.join() {
            Ok(ret) => ret,
            Err(_) => (
                Err(anyhow!("rank {rank} worker thread died outside its panic guard")),
                Some(ExecError::Panicked {
                    rank,
                    msg: "worker thread died outside its panic guard".to_string(),
                }),
                Vec::new(),
                Vec::new(),
            ),
        };
        fault_events.extend(events);
        trace_by_rank.push(traces);
        if let Some(f) = failure {
            failures.push(f);
        }
        match result {
            Ok(w) => {
                comm_bytes += w.bytes;
                let r = w.rank;
                outs[r] = Some(w);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    let wall_s = epoch.elapsed().as_secs_f64();

    if first_err.is_some() || !failures.is_empty() {
        // post-mortem: merge whatever final-layer spans each rank flushed
        // before unwinding (possibly mid-layer, possibly from different
        // layers — these answer "where was everyone when it died")
        let (partial_fwd, partial_bwd) = if opts.trace {
            let merge_last = |pick: &dyn Fn(&(RunTrace, RunTrace)) -> RunTrace, plan: &Plan| {
                let rts: Vec<RunTrace> =
                    trace_by_rank.iter().filter_map(|t| t.last().map(pick)).collect();
                if rts.is_empty() {
                    return None;
                }
                let mut m = MergedTrace::merge(plan, &rts);
                m.threads = eff_threads;
                m.tiles = host_tiles;
                Some(m)
            };
            (
                merge_last(&|p| p.0.clone(), &fwd_plan),
                merge_last(&|p| p.1.clone(), &bwd_plan),
            )
        } else {
            (None, None)
        };
        let report = FailureReport { failures, partial_fwd, partial_bwd };
        let run = Err(match report.root_cause() {
            Some(root) => anyhow!(
                "{} of {n_workers} rank(s) failed; root cause: {root}",
                report.failures.len()
            ),
            None => first_err.unwrap_or_else(|| anyhow!("execution failed")),
        });
        return ExecAttempt { run, report: Some(report), fault_events };
    }

    let outs: Vec<WorkerOut> =
        outs.into_iter().map(|o| o.expect("every rank joined clean")).collect();

    // a replay records traces only for the layers it re-executed
    let recorded_layers = layers - start_layer;
    let (fwd_trace, bwd_trace, layer_traces) = if opts.trace {
        let mut lt: Vec<(Option<MergedTrace>, Option<MergedTrace>)> =
            Vec::with_capacity(recorded_layers);
        for l in 0..recorded_layers {
            let ft: Vec<RunTrace> = trace_by_rank.iter().map(|t| t[l].0.clone()).collect();
            let bt: Vec<RunTrace> = trace_by_rank.iter().map(|t| t[l].1.clone()).collect();
            let mut mf = MergedTrace::merge(&fwd_plan, &ft);
            mf.threads = eff_threads;
            mf.tiles = host_tiles;
            let mb = do_.is_some().then(|| {
                let mut m = MergedTrace::merge(&bwd_plan, &bt);
                m.threads = eff_threads;
                m.tiles = host_tiles;
                m
            });
            lt.push((Some(mf), mb));
        }
        let (lf, lb) = lt.last().cloned().expect("layers >= 1");
        (lf, lb, lt)
    } else {
        (None, None, Vec::new())
    };

    let o = Tensor::cat_axis1(&outs.iter().map(|w| w.o.clone()).collect::<Vec<_>>());
    // lse chunks are (H, C): concatenate along axis 1 by reusing the rank-3
    // helper on zero-copy (H, C, 1) views.
    let lse = {
        let parts: Vec<Tensor> = outs
            .iter()
            .map(|w| {
                let mut s = w.lse.shape.clone();
                s.push(1);
                w.lse.reshape(s)
            })
            .collect();
        let cat = Tensor::cat_axis1(&parts);
        let flat = cat.shape[..2].to_vec();
        cat.reshape(flat)
    };
    let grads = if do_.is_some() {
        let dq = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().0.clone()).collect::<Vec<_>>(),
        );
        let dk = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().1.clone()).collect::<Vec<_>>(),
        );
        let dv = Tensor::cat_axis1(
            &outs.iter().map(|w| w.grads.as_ref().unwrap().2.clone()).collect::<Vec<_>>(),
        );
        Some((dq, dk, dv))
    } else {
        None
    };
    ExecAttempt {
        run: Ok(ExecRun {
            result: DistAttnResult { o, lse, grads, comm_bytes },
            fwd_trace,
            bwd_trace,
            layer_traces,
            wall_s,
        }),
        report: None,
        fault_events,
    }
}

// ---------------------------------------------------------------------------
// JSON (de)serialization — the `repro run --spec` contract
// ---------------------------------------------------------------------------

use crate::util::json::escape as json_escape;

pub(crate) fn usize_list(xs: &[usize]) -> String {
    let parts: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", parts.join(", "))
}

/// Largest integer a JSON number (f64-backed in the in-tree parser) can
/// carry exactly.
const JSON_SAFE_INT: u64 = 1 << 53;

/// Seeds serialize as plain numbers when exactly representable and as
/// decimal strings from 2^53 up — so the round trip is exact for every
/// u64 (the parse side refuses numbers in the inexact range).
pub(crate) fn u64_to_json(x: u64) -> String {
    if x >= JSON_SAFE_INT {
        format!("\"{x}\"")
    } else {
        x.to_string()
    }
}

/// Accept both forms; `None` for a missing/null field.
pub(crate) fn u64_from_json(j: &Json, what: &str) -> Result<Option<u64>> {
    match j {
        Json::Null => Ok(None),
        Json::Str(s) => s
            .parse::<u64>()
            .map(Some)
            .map_err(|_| anyhow!("{what} must be a u64 (got {s:?})")),
        Json::Num(_) => {
            let v = j
                .as_usize()
                .ok_or_else(|| anyhow!("{what} must be a non-negative integer"))?;
            // the f64-backed parser may have rounded anything at or above
            // 2^53 (2^53 + 1 already lands *on* 2^53) — refuse rather
            // than run with a silently different value
            if v as u64 >= JSON_SAFE_INT {
                bail!(
                    "{what} is 2^53 or larger and cannot ride a JSON number exactly; \
                     write it as a decimal string"
                );
            }
            Ok(Some(v as u64))
        }
        _ => Err(anyhow!("{what} must be a u64 (number or decimal string)")),
    }
}

// Optional-field getters: missing/null falls back to the default, but a
// present field of the wrong type is an ERROR — a spec must never silently
// run with a knob other than the one it declares.
pub(crate) fn opt_usize(j: &Json, k: &str, what: &str, dv: usize) -> Result<usize> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(dv),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| anyhow!("{what}{k} must be a non-negative integer")),
    }
}

pub(crate) fn opt_f64(j: &Json, k: &str, what: &str, dv: f64) -> Result<f64> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(dv),
        Some(v) => v.as_f64().ok_or_else(|| anyhow!("{what}{k} must be a number")),
    }
}

pub(crate) fn opt_bool(j: &Json, k: &str, what: &str, dv: bool) -> Result<bool> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(dv),
        Some(v) => v.as_bool().ok_or_else(|| anyhow!("{what}{k} must be a boolean")),
    }
}

/// Serialize a cluster to the spec-JSON object form — shared between
/// [`RunSpec::to_json`] and [`crate::serving::ServeSpec::to_json`].
pub(crate) fn cluster_to_json(c: &ClusterSpec) -> String {
    format!(
        "{{\"n_nodes\": {}, \"gpus_per_node\": {}, \"gpu\": {{\"peak_flops\": {}, \
         \"mfu_attn\": {}, \"mfu_gemm\": {}, \"mem_bytes\": {}}}, \"intra_bw\": {}, \
         \"intra_lat\": {}, \"inter_bw\": {}, \"inter_lat\": {}}}",
        c.n_nodes,
        c.gpus_per_node,
        c.gpu.peak_flops,
        c.gpu.mfu_attn,
        c.gpu.mfu_gemm,
        c.gpu.mem_bytes,
        c.intra_bw,
        c.intra_lat,
        c.inter_bw,
        c.inter_lat,
    )
}

/// Parse a spec-JSON cluster field: missing/null falls back to `default`,
/// a string is a preset name (`"1x8"`, `"2x8"`, `"dev"`), an object is
/// the full [`cluster_to_json`] form.
pub(crate) fn cluster_from_json(v: Option<&Json>, default: ClusterSpec) -> Result<ClusterSpec> {
    match v {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Str(name)) => ClusterSpec::by_name(name)
            .ok_or_else(|| anyhow!("unknown cluster preset {name:?}")),
        Some(c) => {
            let gpu = c.at("gpu");
            let base = crate::config::GpuSpec::a100_80g();
            Ok(ClusterSpec {
                n_nodes: c
                    .at("n_nodes")
                    .as_usize()
                    .ok_or_else(|| anyhow!("cluster.n_nodes must be an integer"))?,
                gpus_per_node: c
                    .at("gpus_per_node")
                    .as_usize()
                    .ok_or_else(|| anyhow!("cluster.gpus_per_node must be an integer"))?,
                gpu: crate::config::GpuSpec {
                    peak_flops: opt_f64(gpu, "peak_flops", "cluster.gpu.", base.peak_flops)?,
                    mfu_attn: opt_f64(gpu, "mfu_attn", "cluster.gpu.", base.mfu_attn)?,
                    mfu_gemm: opt_f64(gpu, "mfu_gemm", "cluster.gpu.", base.mfu_gemm)?,
                    mem_bytes: opt_f64(gpu, "mem_bytes", "cluster.gpu.", base.mem_bytes)?,
                },
                intra_bw: c
                    .at("intra_bw")
                    .as_f64()
                    .ok_or_else(|| anyhow!("cluster.intra_bw must be a number"))?,
                intra_lat: opt_f64(c, "intra_lat", "cluster.", 0.0)?,
                inter_bw: c
                    .at("inter_bw")
                    .as_f64()
                    .ok_or_else(|| anyhow!("cluster.inter_bw must be a number"))?,
                inter_lat: opt_f64(c, "inter_lat", "cluster.", 0.0)?,
            })
        }
    }
}

fn opts_to_json(o: &OptimizeOpts) -> String {
    let slowdowns = {
        let parts: Vec<String> =
            o.slowdowns.iter().map(|&(w, f)| format!("[{w}, {f}]")).collect();
        format!("[{}]", parts.join(", "))
    };
    format!(
        "{{\"seed\": {}, \"swap_rounds\": {}, \"depths\": {}, \"knee_rel_tol\": {}, \
         \"stage_mem_frac\": {}, \"flip\": {}, \"placement\": {}, \"rebalance_rounds\": {}, \
         \"align_doc_cuts\": {}, \"move_boundaries\": {}, \"per_op_costs\": {}, \
         \"slowdowns\": {slowdowns}}}",
        u64_to_json(o.seed),
        o.swap_rounds,
        usize_list(&o.depths),
        o.knee_rel_tol,
        o.stage_mem_frac,
        o.flip,
        o.placement,
        o.rebalance_rounds,
        o.align_doc_cuts,
        o.move_boundaries,
        o.per_op_costs,
    )
}

fn opts_from_json(j: &Json) -> Result<OptimizeOpts> {
    let d = OptimizeOpts::default();
    let w = "optimize.";
    let depths = match j.get("depths") {
        None | Some(Json::Null) => d.depths.clone(),
        Some(v) => v
            .as_usize_vec()
            .ok_or_else(|| anyhow!("optimize.depths must be an array of integers"))?,
    };
    let slowdowns = match j.get("slowdowns") {
        None | Some(Json::Null) => d.slowdowns.clone(),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("optimize.slowdowns must be an array of [rank, factor]"))?;
            let mut out = Vec::with_capacity(arr.len());
            for e in arr {
                let pair = e.as_arr().filter(|a| a.len() == 2);
                let parsed = pair.and_then(|a| Some((a[0].as_usize()?, a[1].as_f64()?)));
                match parsed {
                    Some(p) => out.push(p),
                    None => bail!("optimize.slowdowns entries must be [rank, factor] pairs"),
                }
            }
            out
        }
    };
    Ok(OptimizeOpts {
        seed: u64_from_json(j.at("seed"), "optimize.seed")?.unwrap_or(d.seed),
        swap_rounds: opt_usize(j, "swap_rounds", w, d.swap_rounds)?,
        depths,
        knee_rel_tol: opt_f64(j, "knee_rel_tol", w, d.knee_rel_tol)?,
        stage_mem_frac: opt_f64(j, "stage_mem_frac", w, d.stage_mem_frac)?,
        flip: opt_bool(j, "flip", w, d.flip)?,
        placement: opt_bool(j, "placement", w, d.placement)?,
        rebalance_rounds: opt_usize(j, "rebalance_rounds", w, d.rebalance_rounds)?,
        align_doc_cuts: opt_bool(j, "align_doc_cuts", w, d.align_doc_cuts)?,
        move_boundaries: opt_bool(j, "move_boundaries", w, d.move_boundaries)?,
        per_op_costs: opt_bool(j, "per_op_costs", w, d.per_op_costs)?,
        slowdowns,
    })
}

impl RunSpec {
    /// Serialize to the `repro run --spec` JSON document. Floats print in
    /// Rust's shortest round-trip form, so `from_json(to_json(s)) == s`
    /// exactly (pinned by `rust/tests/session_spec.rs`).
    pub fn to_json(&self) -> String {
        let workload = match &self.workload {
            None => "null".to_string(),
            Some(w) => format!(
                "{{\"n_heads\": {}, \"n_kv_heads\": {}, \"head_dim\": {}, \"chunk_tokens\": {}}}",
                w.n_heads, w.n_kv_heads, w.head_dim, w.chunk_tokens
            ),
        };
        let varlen = match &self.varlen {
            None => "null".to_string(),
            Some(v) => format!(
                "{{\"doc_lens\": {}, \"boundaries\": {}}}",
                usize_list(&v.doc_lens),
                usize_list(&v.boundaries)
            ),
        };
        let cluster = cluster_to_json(&self.cluster);
        let backend = match &self.backend {
            BackendSpec::Pjrt(p) => {
                format!("{{\"pjrt\": \"{}\"}}", json_escape(&p.display().to_string()))
            }
            BackendSpec::HostRef => "\"hostref\"".to_string(),
            BackendSpec::Null => "\"null\"".to_string(),
        };
        let optimize = match &self.optimize {
            OptimizePolicy::Off => "\"off\"".to_string(),
            OptimizePolicy::Schedule(o) => format!("{{\"schedule\": {}}}", opts_to_json(o)),
            OptimizePolicy::Varlen(o) => format!("{{\"varlen\": {}}}", opts_to_json(o)),
        };
        let schedule = match self.schedule {
            ScheduleKind::Ring => "ring",
            ScheduleKind::Balanced => "balanced",
        };
        let depth = match self.prefetch_depth {
            None => "null".to_string(),
            Some(d) => d.to_string(),
        };
        let seed = u64_to_json(self.seed);
        let ckpt = self.ckpt.name();
        let faults = match &self.faults {
            None => "null".to_string(),
            Some(f) => f.to_json(),
        };
        let recovery = self.recovery.to_json();
        format!(
            "{{\n  \"workload\": {workload},\n  \"n_workers\": {},\n  \"schedule\": \"{schedule}\",\n  \
             \"varlen\": {varlen},\n  \"cluster\": {cluster},\n  \"backend\": {backend},\n  \
             \"optimize\": {optimize},\n  \"prefetch_depth\": {depth},\n  \"layers\": {},\n  \
             \"backward\": {},\n  \"trace\": {},\n  \"deep_copy_sends\": {},\n  \
             \"threads\": {},\n  \"autotune_tiles\": {},\n  \"ckpt\": \"{ckpt}\",\n  \
             \"faults\": {faults},\n  \"recovery\": {recovery},\n  \"seed\": {seed}\n}}\n",
            self.n_workers,
            self.layers,
            self.backward,
            self.trace,
            self.deep_copy_sends,
            self.threads,
            self.autotune_tiles,
        )
    }

    /// Parse a `repro run --spec` document. The `cluster` field also
    /// accepts a preset name (`"1x8"`, `"2x8"`, `"dev"`); missing optional
    /// fields fall back to [`RunSpec::plans_only`]-style defaults.
    pub fn from_json(s: &str) -> Result<RunSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("bad RunSpec JSON: {e}"))?;
        let workload = match j.get("workload") {
            None | Some(Json::Null) => None,
            Some(w) => Some(Workload {
                n_heads: w
                    .at("n_heads")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.n_heads must be an integer"))?,
                n_kv_heads: w
                    .at("n_kv_heads")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.n_kv_heads must be an integer"))?,
                head_dim: w
                    .at("head_dim")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.head_dim must be an integer"))?,
                chunk_tokens: w
                    .at("chunk_tokens")
                    .as_usize()
                    .ok_or_else(|| anyhow!("workload.chunk_tokens must be an integer"))?,
            }),
        };
        let varlen = match j.get("varlen") {
            None | Some(Json::Null) => None,
            Some(v) => Some(VarlenSpec {
                doc_lens: v
                    .at("doc_lens")
                    .as_usize_vec()
                    .ok_or_else(|| anyhow!("varlen.doc_lens must be an integer array"))?,
                boundaries: v
                    .at("boundaries")
                    .as_usize_vec()
                    .ok_or_else(|| anyhow!("varlen.boundaries must be an integer array"))?,
            }),
        };
        let cluster = cluster_from_json(j.get("cluster"), ClusterSpec::dgx_1x8())?;
        let backend = match j.get("backend") {
            None | Some(Json::Null) => BackendSpec::HostRef,
            Some(Json::Str(s)) => match s.as_str() {
                "hostref" | "host" => BackendSpec::HostRef,
                "null" => BackendSpec::Null,
                other => bail!("unknown backend {other:?} (hostref | null | {{\"pjrt\": dir}})"),
            },
            Some(b) => match b.at("pjrt").as_str() {
                Some(dir) => BackendSpec::Pjrt(PathBuf::from(dir)),
                None => bail!("backend object must be {{\"pjrt\": \"<artifact dir>\"}}"),
            },
        };
        let optimize = match j.get("optimize") {
            None | Some(Json::Null) => OptimizePolicy::Off,
            Some(Json::Str(s)) if s == "off" => OptimizePolicy::Off,
            Some(Json::Str(s)) if s == "schedule" => {
                OptimizePolicy::Schedule(OptimizeOpts::default())
            }
            Some(Json::Str(s)) if s == "varlen" => OptimizePolicy::Varlen(OptimizeOpts::default()),
            Some(o) => {
                if let Some(inner) = o.get("schedule") {
                    OptimizePolicy::Schedule(opts_from_json(inner)?)
                } else if let Some(inner) = o.get("varlen") {
                    OptimizePolicy::Varlen(opts_from_json(inner)?)
                } else {
                    bail!("optimize must be \"off\" | {{\"schedule\": ...}} | {{\"varlen\": ...}}")
                }
            }
        };
        let schedule = match j.get("schedule") {
            None | Some(Json::Null) => ScheduleKind::Balanced,
            Some(Json::Str(s)) => match s.as_str() {
                "balanced" => ScheduleKind::Balanced,
                "ring" | "unbalanced" => ScheduleKind::Ring,
                other => bail!("unknown schedule {other:?} (ring | balanced)"),
            },
            Some(_) => bail!("schedule must be a string (ring | balanced)"),
        };
        let prefetch_depth = match j.get("prefetch_depth") {
            None | Some(Json::Null) => None,
            Some(d) => Some(
                d.as_usize()
                    .ok_or_else(|| anyhow!("prefetch_depth must be an integer or null"))?,
            ),
        };
        let ckpt = match j.get("ckpt") {
            None | Some(Json::Null) => CkptStrategy::RematAware,
            Some(Json::Str(s)) => s
                .parse::<CkptStrategy>()
                .map_err(|e| anyhow!("ckpt: {e}"))?,
            Some(_) => bail!("ckpt must be a string checkpoint-strategy name or null"),
        };
        Ok(RunSpec {
            workload,
            n_workers: opt_usize(&j, "n_workers", "", 0)?,
            schedule,
            varlen,
            cluster,
            backend,
            optimize,
            prefetch_depth,
            layers: opt_usize(&j, "layers", "", 1)?,
            backward: opt_bool(&j, "backward", "", true)?,
            trace: opt_bool(&j, "trace", "", false)?,
            deep_copy_sends: opt_bool(&j, "deep_copy_sends", "", false)?,
            threads: opt_usize(&j, "threads", "", 1)?,
            autotune_tiles: opt_bool(&j, "autotune_tiles", "", false)?,
            ckpt,
            faults: match j.get("faults") {
                None | Some(Json::Null) => None,
                Some(f) => Some(FaultSpec::from_json(f)?),
            },
            recovery: match j.get("recovery") {
                None | Some(Json::Null) => RecoveryPolicy::FailFast,
                Some(r) => RecoveryPolicy::from_json(r)?,
            },
            seed: u64_from_json(j.at("seed"), "seed")?.unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::CrashSpec;

    #[test]
    fn plan_stage_matches_direct_lowering() {
        for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
            for p in [2usize, 5, 8] {
                let mut s = Session::new(RunSpec::plans_only(kind, p)).unwrap();
                let (fwd, bwd) = s.plans().unwrap();
                let sched = Schedule::build(kind, p);
                assert_eq!(*fwd, Plan::from_schedule(&sched, Pass::Forward));
                assert_eq!(*bwd, Plan::from_schedule(&sched, Pass::Backward));
            }
        }
    }

    #[test]
    fn spec_validation_rejects_inconsistent_runs() {
        // manifest resolution requires a Pjrt backend
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.workload = None;
        assert!(spec.validate().is_err());
        // varlen chunk count must match the worker count
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.varlen = Some(VarlenSpec::uniform(8, 2));
        assert!(spec.validate().is_err());
        // varlen policy without a varlen layout
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.optimize = OptimizePolicy::Varlen(OptimizeOpts::default());
        assert!(spec.validate().is_err());
        // schedule policy over a varlen layout (would discard the masking)
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.varlen = Some(VarlenSpec::uniform(8, 4));
        spec.optimize = OptimizePolicy::Schedule(OptimizeOpts::default());
        assert!(spec.validate().is_err());
        // GQA grouping must divide
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.workload = Some(Workload::new(4, 3, 8, 16));
        assert!(spec.validate().is_err());
        // fault targets must name real ranks
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.faults = Some(FaultSpec {
            crash: Some(CrashSpec { rank: 4, step: 0, pass: Pass::Forward }),
            ..FaultSpec::default()
        });
        assert!(spec.validate().is_err());
        // fault probabilities must be probabilities
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.faults = Some(FaultSpec { drop_prob: 1.5, ..FaultSpec::default() });
        assert!(spec.validate().is_err());
    }

    #[test]
    fn prefetch_depth_override_pins_both_plans() {
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.prefetch_depth = Some(3);
        let (fwd, bwd) = Session::new(spec).unwrap().plans().unwrap();
        assert_eq!(fwd.prefetch_depth, 3);
        assert_eq!(bwd.prefetch_depth, 3);
    }

    #[test]
    fn host_execute_runs_and_traces() {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, 4, Workload::new(2, 1, 8, 12));
        spec.trace = true;
        let mut s = Session::new(spec).unwrap();
        s.execute().unwrap();
        let run = s.run().unwrap();
        assert_eq!(run.result.o.shape, vec![2, 48, 8]);
        assert!(run.result.grads.is_some());
        assert!(run.fwd_trace.is_some() && run.bwd_trace.is_some());
        let tr = s.trace().unwrap();
        assert!(tr.fwd_cmp.n_ops_compared > 0);
        assert!(tr.render("t").contains("total err"));
    }

    #[test]
    fn stacked_layers_produce_per_layer_traces() {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, 4, Workload::new(2, 1, 8, 12));
        spec.trace = true;
        spec.layers = 3;
        let mut s = Session::new(spec).unwrap();
        s.execute().unwrap();
        let run = s.run().unwrap();
        assert_eq!(run.layer_traces.len(), 3);
        let tr = s.trace().unwrap();
        let timeline = tr.layer_timeline("layers").expect("stacked run has a timeline");
        assert!(timeline.contains("L0 fwd") && timeline.contains("L2 bwd"));
    }

    #[test]
    fn calibrate_requires_a_traced_run() {
        let spec = RunSpec::host(ScheduleKind::Balanced, 2, Workload::new(2, 1, 8, 8));
        let mut s = Session::new(spec).unwrap();
        assert!(s.calibrate().is_err());
        s.execute().unwrap();
        // trace was off — still an error, with a pointer to the knob
        let err = format!("{}", s.calibrate().unwrap_err());
        assert!(err.contains("trace"), "{err}");
    }
}
