//! Cost-model-driven plan optimizer: search the space of legal plans for
//! one schedule and return the fastest under the event engine's timing
//! model.
//!
//! PR 1's lowering emits exactly one plan per schedule — rank→GPU
//! placement is the identity, owner/helper roles follow the paper's Alg. 2
//! verbatim, and the prefetch depth is whatever the caller passes. But the
//! event engine prices every edge individually (`ClusterSpec::link`), so
//! each of those choices is *scoreable*. This module turns the simulator
//! into an optimizer with three passes, applied in order and each accepted
//! only when it strictly improves the simulated makespan (so the result is
//! never worse than the default lowering):
//!
//! 1. **GQA-aware role flipping** (`flip` pass, schedule lowerings only) —
//!    per step, re-lower with [`LowerOpts::flip_steps`] set so helper
//!    pairs are computed owner-side off a kv fetch instead of helper-side
//!    off a q bundle. Trades one extra kernel on the owner's compute
//!    stream for `q_bytes + result_bytes - kv_bytes` off the wire; wins
//!    exactly when the q bundle dwarfs the kv chunk — grouped-query models
//!    (`n_kv_heads < n_heads`) on slow links, and every backward pass,
//!    whose q bundle carries (q, o, lse, do).
//! 2. **Topology-aware placement** — permute the plan's rank→GPU
//!    [`Plan::placement`] so heavy edges ride fast intra-node links:
//!    greedy traffic-affinity seed (heaviest-communicating ranks packed
//!    per node) followed by local-swap hill climbing over node-crossing
//!    rank pairs, each candidate scored by a full event-engine pass.
//! 3. **Prefetch-depth autotuning** — sweep `EventOpts::prefetch_depth`
//!    candidates and pick the *knee*: the smallest depth within
//!    `knee_rel_tol` of the best, since depth is monotone (never slower)
//!    but deeper prefetch costs real staging memory on the GPU.
//!
//! ## Search budget
//!
//! Scoring reuses one pre-resolved [`PlanSim`] per plan shape, so a
//! candidate costs one allocation-free O(ops) pass (~µs at P = 16). The
//! flip pass re-lowers once per helper step (≤ ⌊P/2⌋ candidates); the
//! placement pass scores the identity, the greedy seed, and at most
//! `swap_rounds · P(P-1)/2` swaps (same-node swaps are skipped — links
//! only see nodes); the depth pass scores `|depths|` candidates. The
//! default budget at P = 16 is a few hundred simulator passes — well under
//! a millisecond of search per (schedule, cluster, cost) configuration,
//! bounded and benchmarked in `benches/hot_paths.rs`.
//!
//! Everything here is deterministic given `OptimizeOpts::seed`: the only
//! randomness is the hill climb's swap visiting order (`util::Rng`).

use crate::config::ClusterSpec;
use crate::coordinator::plan::{LowerOpts, Pass, Plan, PlanOp};
use crate::coordinator::schedule::{ComputeOp, Schedule};
use crate::simulator::{AttnCost, PlanSim};
use crate::util::Rng;

/// Knobs for the optimization passes. Defaults are the benchmarked budget.
#[derive(Clone, Debug)]
pub struct OptimizeOpts {
    /// Seed for the hill climb's swap visiting order.
    pub seed: u64,
    /// Maximum full sweeps over rank pairs in the placement hill climb
    /// (stops early on a sweep with no accepted swap).
    pub swap_rounds: usize,
    /// Candidate prefetch depths; depth 1 (the paper's §3.2 default) is
    /// always considered even if absent.
    pub depths: Vec<usize>,
    /// Knee tolerance: pick the smallest depth within this relative
    /// distance of the best sweep time.
    pub knee_rel_tol: f64,
    /// Enable the role-flipping pass (schedule lowerings only).
    pub flip: bool,
    /// Enable the placement search.
    pub placement: bool,
}

impl Default for OptimizeOpts {
    fn default() -> Self {
        OptimizeOpts {
            seed: 0,
            swap_rounds: 3,
            depths: vec![1, 2, 3, 4, 6, 8],
            knee_rel_tol: 0.01,
            flip: true,
            placement: true,
        }
    }
}

/// Accept only strict improvements (relative margin so fp noise can't
/// oscillate the hill climb).
fn improves(candidate: f64, best: f64) -> bool {
    candidate < best * (1.0 - 1e-12)
}

/// Result of an optimizer run: the chosen plan plus the audit trail the
/// reports print.
#[derive(Clone, Debug)]
pub struct Optimized {
    /// Final plan: flips applied in the op stream, placement set.
    pub plan: Plan,
    /// Autotuned prefetch depth (the knee).
    pub prefetch_depth: usize,
    /// Simulated seconds of the default lowering (identity placement, no
    /// flips, prefetch depth 1).
    pub default_s: f64,
    /// Simulated seconds of the optimized plan at the chosen depth.
    pub optimized_s: f64,
    /// Schedule steps whose helper pairs were flipped owner-side.
    pub flipped_steps: Vec<usize>,
    /// Ranks whose GPU differs from the identity placement.
    pub moved_ranks: usize,
    /// Event-engine passes spent searching (budget accounting).
    pub sim_calls: usize,
}

impl Optimized {
    pub fn speedup(&self) -> f64 {
        if self.optimized_s > 0.0 {
            self.default_s / self.optimized_s
        } else {
            1.0
        }
    }
}

/// Sorted, deduped depth candidates with the default depth 1 guaranteed.
fn depth_candidates(opts: &OptimizeOpts) -> Vec<usize> {
    let mut ds: Vec<usize> = opts.depths.iter().copied().filter(|&d| d >= 1).collect();
    ds.push(1);
    ds.sort_unstable();
    ds.dedup();
    ds
}

/// Depth knee on a prepared simulator. Returns `(depth, total_s, calls)`.
fn autotune_depth_sim(
    sim: &mut PlanSim,
    cluster: &ClusterSpec,
    placement: &[usize],
    opts: &OptimizeOpts,
) -> (usize, f64, usize) {
    let ds = depth_candidates(opts);
    let totals: Vec<f64> = ds
        .iter()
        .map(|&d| sim.total_s(cluster, placement, d))
        .collect();
    let best = totals.iter().cloned().fold(f64::INFINITY, f64::min);
    for (i, &d) in ds.iter().enumerate() {
        if totals[i] <= best * (1.0 + opts.knee_rel_tol) {
            return (d, totals[i], ds.len());
        }
    }
    // unreachable: the minimum itself always satisfies the bound
    (1, totals[0], ds.len())
}

/// Standalone depth autotune for a finished plan: `(knee depth, total_s at
/// that depth)`. Used by the executed-schedules report to stop timing
/// depth 1 only.
pub fn autotune_depth(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> (usize, f64) {
    let mut sim = PlanSim::new(plan, cost);
    let (d, s, _) = autotune_depth_sim(&mut sim, cluster, &plan.placement, opts);
    (d, s)
}

/// Greedy placement seed: pack the heaviest-communicating ranks onto the
/// same node. Deterministic (ties resolve to the lowest index).
fn greedy_seed(plan: &Plan, cost: &AttnCost, cluster: &ClusterSpec) -> Vec<usize> {
    let p = plan.n_workers;
    let gpn = cluster.gpus_per_node.max(1);
    let n_nodes = p.div_ceil(gpn);
    // symmetric rank-to-rank traffic in bytes
    let mut w = vec![0.0f64; p * p];
    for n in &plan.ops {
        if let PlanOp::Xfer { src, dst, payload } = &n.op {
            let b = payload.bytes(cost);
            w[src * p + dst] += b;
            w[dst * p + src] += b;
        }
    }
    let tot: Vec<f64> = (0..p).map(|i| w[i * p..(i + 1) * p].iter().sum()).collect();
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_by(|&a, &b| tot[b].partial_cmp(&tot[a]).unwrap().then(a.cmp(&b)));
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
    let mut node_of_rank = vec![0usize; p];
    for &r in &order {
        let mut best_node = usize::MAX;
        let mut best_aff = f64::NEG_INFINITY;
        for (nd, m) in members.iter().enumerate() {
            if m.len() >= gpn {
                continue;
            }
            let aff: f64 = m.iter().map(|&o| w[r * p + o]).sum();
            if aff > best_aff {
                best_aff = aff;
                best_node = nd;
            }
        }
        node_of_rank[r] = best_node;
        members[best_node].push(r);
    }
    let mut place = vec![0usize; p];
    let mut next_slot = vec![0usize; n_nodes];
    for r in 0..p {
        let nd = node_of_rank[r];
        place[r] = nd * gpn + next_slot[nd];
        next_slot[nd] += 1;
    }
    place
}

/// Placement search at depth 1: the caller's starting placement vs the
/// greedy seed, then local-swap hill climbing. Returns
/// `(placement, total_s, calls)`; never worse than `init`.
fn placement_pass(
    plan: &Plan,
    sim: &mut PlanSim,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
    init: &[usize],
) -> (Vec<usize>, f64, usize) {
    let p = plan.n_workers;
    let mut calls = 0usize;
    let mut place: Vec<usize> = init.to_vec();
    let mut best = sim.total_s(cluster, &place, 1);
    calls += 1;
    let seeded = greedy_seed(plan, cost, cluster);
    let s = sim.total_s(cluster, &seeded, 1);
    calls += 1;
    if improves(s, best) {
        best = s;
        place = seeded;
    }
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(p * (p - 1) / 2);
    for i in 0..p {
        for j in i + 1..p {
            pairs.push((i, j));
        }
    }
    let mut rng = Rng::new(opts.seed ^ 0x9e37_79b9_7f4a_7c15);
    for _ in 0..opts.swap_rounds {
        // Fisher–Yates with the deterministic rng
        for k in (1..pairs.len()).rev() {
            let j = rng.below(k + 1);
            pairs.swap(k, j);
        }
        let mut improved = false;
        for &(i, j) in &pairs {
            // links only distinguish nodes: same-node swaps are no-ops
            if cluster.node_of(place[i]) == cluster.node_of(place[j]) {
                continue;
            }
            place.swap(i, j);
            let s = sim.total_s(cluster, &place, 1);
            calls += 1;
            if improves(s, best) {
                best = s;
                improved = true;
            } else {
                place.swap(i, j);
            }
        }
        if !improved {
            break;
        }
    }
    (place, best, calls)
}

/// Optimize an already-lowered (or dataflow) plan: placement + depth only.
/// Role flipping needs the schedule; use [`optimize_schedule`] for that.
pub fn optimize_plan(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Optimized {
    let mut sim = PlanSim::new(plan, cost);
    // the baseline is the plan *as given* — including any placement it
    // already carries — so default_s matches what simulate_plan reports
    let default_s = sim.total_s(cluster, &plan.placement, 1);
    let mut sim_calls = 1usize;
    // placement_pass scores that starting placement first and only
    // accepts strict improvements, so its result is never worse
    let mut place = plan.placement.clone();
    if opts.placement {
        let (pl, _s, calls) =
            placement_pass(plan, &mut sim, cluster, cost, opts, &plan.placement);
        sim_calls += calls;
        place = pl;
    }
    let (depth, total, calls) = autotune_depth_sim(&mut sim, cluster, &place, opts);
    sim_calls += calls;
    let moved_ranks = place.iter().enumerate().filter(|&(i, &g)| i != g).count();
    let mut out = plan.clone();
    out.placement = place;
    Optimized {
        plan: out,
        prefetch_depth: depth,
        default_s,
        optimized_s: total,
        flipped_steps: Vec::new(),
        moved_ranks,
        sim_calls,
    }
}

/// Full pass pipeline over a schedule lowering: role flipping, placement,
/// depth. The returned plan always validates (`validate_lowered`), covers
/// the same pair set as the default lowering, and its `optimized_s` is
/// never above `default_s`.
pub fn optimize_schedule(
    schedule: &Schedule,
    pass: Pass,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Optimized {
    let p = schedule.n_workers;
    let identity: Vec<usize> = (0..p).collect();
    let base = Plan::from_schedule(schedule, pass);
    let mut sim = PlanSim::new(&base, cost);
    let default_s = sim.total_s(cluster, &identity, 1);
    let mut sim_calls = 1usize;
    let mut best_plan = base;
    let mut best = default_s;
    let mut flips = vec![false; schedule.n_steps()];
    if opts.flip {
        for t in 0..schedule.n_steps() {
            let has_help = schedule.steps[t]
                .iter()
                .any(|sp| matches!(sp.compute, Some(ComputeOp::Help { .. })));
            if !has_help {
                continue;
            }
            flips[t] = true;
            let cand =
                Plan::from_schedule_opts(schedule, pass, &LowerOpts { flip_steps: flips.clone() });
            let mut cand_sim = PlanSim::new(&cand, cost);
            let s = cand_sim.total_s(cluster, &identity, 1);
            sim_calls += 1;
            if improves(s, best) {
                best = s;
                best_plan = cand;
                sim = cand_sim;
            } else {
                flips[t] = false;
            }
        }
    }
    // `best` is the depth-1 identity-placement time of `best_plan`;
    // placement_pass rescores that baseline itself and only accepts
    // strict improvements, so it is not threaded further
    let mut place = identity;
    if opts.placement {
        let (pl, _s, calls) =
            placement_pass(&best_plan, &mut sim, cluster, cost, opts, &best_plan.placement);
        sim_calls += calls;
        place = pl;
    }
    let (depth, total, calls) = autotune_depth_sim(&mut sim, cluster, &place, opts);
    sim_calls += calls;
    let moved_ranks = place.iter().enumerate().filter(|&(i, &g)| i != g).count();
    best_plan.placement = place;
    Optimized {
        plan: best_plan,
        prefetch_depth: depth,
        default_s,
        optimized_s: total,
        flipped_steps: flips
            .iter()
            .enumerate()
            .filter_map(|(t, &f)| if f { Some(t) } else { None })
            .collect(),
        moved_ranks,
        sim_calls,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(kv_over_q: f64) -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6 * kv_over_q,
            q_bytes: 1e6,
            result_bytes: 1.1e6,
            overlap: true,
        }
    }

    #[test]
    fn depth_candidates_always_include_default() {
        let opts = OptimizeOpts { depths: vec![8, 4], ..Default::default() };
        assert_eq!(depth_candidates(&opts), vec![1, 4, 8]);
        let opts = OptimizeOpts { depths: vec![], ..Default::default() };
        assert_eq!(depth_candidates(&opts), vec![1]);
    }

    #[test]
    fn greedy_seed_is_a_permutation() {
        let cluster = ClusterSpec::dgx_2x8();
        for p in [4usize, 8, 16] {
            let plan = Plan::from_schedule(&Schedule::balanced(p), Pass::Forward);
            let mut place = greedy_seed(&plan, &cost(0.25), &cluster);
            place.sort_unstable();
            place.dedup();
            assert_eq!(place.len(), p, "P={p}: duplicate GPU assignment");
        }
    }

    #[test]
    fn optimize_never_worse_and_validates() {
        let cluster = ClusterSpec::dgx_2x8();
        let s = Schedule::balanced(16);
        for pass in [Pass::Forward, Pass::Backward] {
            let o = optimize_schedule(&s, pass, &cluster, &cost(0.25), &OptimizeOpts::default());
            assert!(o.optimized_s <= o.default_s * (1.0 + 1e-9), "{pass:?}");
            o.plan.validate_lowered().unwrap();
        }
    }

    #[test]
    fn flip_fires_when_q_dwarfs_kv() {
        // comm-bound GQA-style regime: q bundle 4x the kv chunk, kernels
        // cheap relative to the inter-node wire
        let cluster = ClusterSpec::dgx_2x8();
        let c = AttnCost { pair_full_s: 1e-5, pair_diag_s: 0.5e-5, ..cost(0.25) };
        let o = optimize_schedule(
            &Schedule::balanced(16),
            Pass::Forward,
            &cluster,
            &c,
            &OptimizeOpts::default(),
        );
        assert!(!o.flipped_steps.is_empty(), "expected flips in the GQA regime");
        assert!(o.optimized_s < o.default_s, "flips must strictly improve here");
    }
}
