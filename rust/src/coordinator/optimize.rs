//! Cost-model-driven plan optimizer: search the space of legal plans for
//! one schedule and return the fastest under the event engine's timing
//! model.
//!
//! PR 1's lowering emits exactly one plan per schedule — rank→GPU
//! placement is the identity, owner/helper roles follow the paper's Alg. 2
//! verbatim, and the prefetch depth is whatever the caller passes. But the
//! event engine prices every edge individually (`ClusterSpec::link`), so
//! each of those choices is *scoreable*. This module turns the simulator
//! into an optimizer with three passes, applied in order and each accepted
//! only when it strictly improves the simulated makespan (so the result is
//! never worse than the default lowering):
//!
//! 1. **GQA-aware role flipping** (`flip` pass, schedule lowerings only) —
//!    per step, re-lower with [`LowerOpts::flip_steps`] set so helper
//!    pairs are computed owner-side off a kv fetch instead of helper-side
//!    off a q bundle. Trades one extra kernel on the owner's compute
//!    stream for `q_bytes + result_bytes - kv_bytes` off the wire; wins
//!    exactly when the q bundle dwarfs the kv chunk — grouped-query models
//!    (`n_kv_heads < n_heads`) on slow links, and every backward pass,
//!    whose q bundle carries (q, o, lse, do).
//! 2. **Topology-aware placement** — permute the plan's rank→GPU
//!    [`Plan::placement`] so heavy edges ride fast intra-node links:
//!    greedy traffic-affinity seed (heaviest-communicating ranks packed
//!    per node) followed by local-swap hill climbing over node-crossing
//!    rank pairs, each candidate scored by a full event-engine pass.
//! 3. **Prefetch-depth autotuning** — sweep `EventOpts::prefetch_depth`
//!    candidates and pick the *knee*: the smallest depth within
//!    `knee_rel_tol` of the best, since depth is monotone (never slower)
//!    but deeper prefetch costs real staging memory on the GPU.
//!
//! ## Search budget
//!
//! Scoring reuses one pre-resolved [`PlanSim`] per plan shape, so a
//! candidate costs one allocation-free O(ops) pass (~µs at P = 16). The
//! flip pass re-lowers once per helper step (≤ ⌊P/2⌋ candidates); the
//! placement pass scores the identity, the greedy seed, and at most
//! `swap_rounds · P(P-1)/2` swaps (same-node swaps are skipped — links
//! only see nodes); the depth pass scores `|depths|` candidates. The
//! default budget at P = 16 is a few hundred simulator passes — well under
//! a millisecond of search per (schedule, cluster, cost) configuration,
//! bounded and benchmarked in `benches/hot_paths.rs`.
//!
//! Everything here is deterministic given `OptimizeOpts::seed`: the only
//! randomness is the hill climb's swap visiting order (`util::Rng`).

use std::sync::Arc;

use crate::config::ClusterSpec;
use crate::coordinator::checkpoint::CkptStrategy;
use crate::coordinator::plan::{Kernel, LowerOpts, Pass, Payload, PayloadClass, Plan, PlanOp};
use crate::coordinator::schedule::{ComputeOp, Schedule, VarlenSpec};
use crate::simulator::{AttnCost, PlanSim};
use crate::util::Rng;

/// Knobs for the optimization passes. Defaults are the benchmarked budget.
#[derive(Clone, Debug, PartialEq)]
pub struct OptimizeOpts {
    /// Seed for the hill climb's swap visiting order.
    pub seed: u64,
    /// Maximum full sweeps over rank pairs in the placement hill climb
    /// (stops early on a sweep with no accepted swap).
    pub swap_rounds: usize,
    /// Candidate prefetch depths; depth 1 (the paper's §3.2 default) is
    /// always considered even if absent.
    pub depths: Vec<usize>,
    /// Knee tolerance: pick the smallest depth within this relative
    /// distance of the best sweep time.
    pub knee_rel_tol: f64,
    /// Fraction of `GpuSpec::mem_bytes` the prefetch pipeline may stage:
    /// depth `d` holds `d` in-flight kv chunks, so candidates with
    /// `d * kv_stage_bytes` beyond this headroom are rejected outright —
    /// the memory charge the knee tolerance used to proxy for.
    pub stage_mem_frac: f64,
    /// Enable the role-flipping pass (schedule lowerings only).
    pub flip: bool,
    /// Enable the placement search.
    pub placement: bool,
    /// Maximum boundary+flip sweeps of the token-level rebalancer
    /// (stops early on a sweep with no accepted move).
    pub rebalance_rounds: usize,
    /// Align varlen boundary-move candidates to document edges — the kinks
    /// of the pair-weight function, where the token-exact cost model is
    /// non-smooth and the optimum tends to sit. When a cut's move window
    /// contains document edges, they *replace* the grid candidates for
    /// that cut (fewer, better-aimed sims on doc-heavy mixes); windows
    /// without any edge keep the `c_ref/16` grid as the fallback.
    pub align_doc_cuts: bool,
    /// Enable the boundary-move half of the varlen rebalancer (per-pair
    /// flip sweeps always run). `Session` shares one chunking between the
    /// forward and backward plans by rebalancing boundaries on one pass
    /// and re-optimizing the other at fixed cuts with this switched off.
    pub move_boundaries: bool,
    /// Opt in to *per-op* calibrated costs: after `Session::calibrate`,
    /// searches over an unchanged op stream score each compute op at its
    /// own traced duration ([`crate::simulator::PlanSim::set_op_cost`])
    /// instead of the three per-class means — so per-pair skew (GQA
    /// grouping, ragged chunks, cache effects) is visible to acceptance.
    /// Off by default: per-op overlays only apply where the traced plan's
    /// op stream is preserved (caller-plan tuning and acceptance scoring),
    /// and class means remain the honest model for re-lowered candidates.
    pub per_op_costs: bool,
    /// Pinned per-worker compute slowdowns `(rank, factor)` — degraded
    /// hardware the search must plan *around* rather than assume away
    /// (factor `1.5` = every kernel on that rank runs 50% longer). Every
    /// scoring sim applies them ([`PlanSim::set_worker_slowdown`]), so
    /// placement, flips, and depth all answer "best plan given this
    /// straggler". Empty (all healthy) by default.
    pub slowdowns: Vec<(usize, f64)>,
}

impl Default for OptimizeOpts {
    fn default() -> Self {
        OptimizeOpts {
            seed: 0,
            swap_rounds: 3,
            depths: vec![1, 2, 3, 4, 6, 8],
            knee_rel_tol: 0.01,
            stage_mem_frac: 0.05,
            flip: true,
            placement: true,
            rebalance_rounds: 3,
            align_doc_cuts: true,
            move_boundaries: true,
            per_op_costs: false,
            slowdowns: Vec::new(),
        }
    }
}

/// Apply the opts' pinned straggler factors to a scoring sim — every
/// `PlanSim` the optimizer consults goes through here so search and
/// acceptance price the same degraded cluster.
fn apply_slowdowns(sim: &mut PlanSim, opts: &OptimizeOpts) {
    for &(w, f) in &opts.slowdowns {
        sim.set_worker_slowdown(w, f);
    }
}

/// Accept only strict improvements (relative margin so fp noise can't
/// oscillate the hill climb).
fn improves(candidate: f64, best: f64) -> bool {
    candidate < best * (1.0 - 1e-12)
}

/// Result of an optimizer run: the chosen plan plus the audit trail the
/// reports print.
#[derive(Clone, Debug)]
pub struct Optimized {
    /// Final plan: flips applied in the op stream, placement set.
    pub plan: Plan,
    /// Autotuned prefetch depth (the knee).
    pub prefetch_depth: usize,
    /// Simulated seconds of the default lowering (identity placement, no
    /// flips, prefetch depth 1).
    pub default_s: f64,
    /// Simulated seconds of the optimized plan at the chosen depth.
    pub optimized_s: f64,
    /// Schedule steps whose helper pairs were flipped owner-side.
    pub flipped_steps: Vec<usize>,
    /// Ranks whose GPU differs from the identity placement.
    pub moved_ranks: usize,
    /// Event-engine passes spent searching (budget accounting).
    pub sim_calls: usize,
}

impl Optimized {
    pub fn speedup(&self) -> f64 {
        if self.optimized_s > 0.0 {
            self.default_s / self.optimized_s
        } else {
            1.0
        }
    }
}

/// Sorted, deduped depth candidates with the default depth 1 guaranteed.
fn depth_candidates(opts: &OptimizeOpts) -> Vec<usize> {
    let mut ds: Vec<usize> = opts.depths.iter().copied().filter(|&d| d >= 1).collect();
    ds.push(1);
    ds.sort_unstable();
    ds.dedup();
    ds
}

/// Depth knee on a prepared simulator. Returns `(depth, total_s, calls)`.
/// Depth `d` stages `d` in-flight kv chunks on the receiving GPU, so
/// candidates whose staging footprint exceeds the configured share of
/// `GpuSpec::mem_bytes` are dropped before timing (depth 1, the paper's
/// baseline pipeline, is always kept).
fn autotune_depth_sim(
    sim: &mut PlanSim,
    cluster: &ClusterSpec,
    placement: &[usize],
    opts: &OptimizeOpts,
) -> (usize, f64, usize) {
    autotune_depth_sim_reserved(sim, cluster, placement, opts, 0.0)
}

/// Depth knee with part of the staging budget already spoken for:
/// `reserve_bytes` is per-GPU memory a checkpoint strategy holds resident
/// (RematAware's `extra_saved_floats`), which comes out of the same
/// `stage_mem_frac` headroom the prefetch pipeline stages into — the
/// joint §3.2 × §3.3 trade.
fn autotune_depth_sim_reserved(
    sim: &mut PlanSim,
    cluster: &ClusterSpec,
    placement: &[usize],
    opts: &OptimizeOpts,
    reserve_bytes: f64,
) -> (usize, f64, usize) {
    let budget = (opts.stage_mem_frac * cluster.gpu.mem_bytes - reserve_bytes).max(0.0);
    let stage = sim.stage_bytes();
    let ds: Vec<usize> = depth_candidates(opts)
        .into_iter()
        .filter(|&d| d == 1 || d as f64 * stage <= budget)
        .collect();
    let totals: Vec<f64> = ds
        .iter()
        .map(|&d| sim.total_s(cluster, placement, d))
        .collect();
    let best = totals.iter().cloned().fold(f64::INFINITY, f64::min);
    for (i, &d) in ds.iter().enumerate() {
        if totals[i] <= best * (1.0 + opts.knee_rel_tol) {
            return (d, totals[i], ds.len());
        }
    }
    // unreachable: the minimum itself always satisfies the bound
    (1, totals[0], ds.len())
}

/// Standalone depth autotune for a finished plan: `(knee depth, total_s at
/// that depth)`. Used by the executed-schedules report to stop timing
/// depth 1 only.
pub fn autotune_depth(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> (usize, f64) {
    let mut sim = PlanSim::new(plan, cost);
    apply_slowdowns(&mut sim, opts);
    let (d, s, _) = autotune_depth_sim(&mut sim, cluster, &plan.placement, opts);
    (d, s)
}

/// Greedy placement seed: pack the heaviest-communicating ranks onto the
/// same node. Deterministic (ties resolve to the lowest index).
fn greedy_seed(plan: &Plan, cost: &AttnCost, cluster: &ClusterSpec) -> Vec<usize> {
    let p = plan.n_workers;
    let gpn = cluster.gpus_per_node.max(1);
    let n_nodes = p.div_ceil(gpn);
    // symmetric rank-to-rank traffic in bytes
    let mut w = vec![0.0f64; p * p];
    for n in &plan.ops {
        if let PlanOp::Xfer { src, dst, payload } = &n.op {
            let b = payload.bytes(cost);
            w[src * p + dst] += b;
            w[dst * p + src] += b;
        }
    }
    let tot: Vec<f64> = (0..p).map(|i| w[i * p..(i + 1) * p].iter().sum()).collect();
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_by(|&a, &b| tot[b].partial_cmp(&tot[a]).unwrap().then(a.cmp(&b)));
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
    let mut node_of_rank = vec![0usize; p];
    for &r in &order {
        let mut best_node = usize::MAX;
        let mut best_aff = f64::NEG_INFINITY;
        for (nd, m) in members.iter().enumerate() {
            if m.len() >= gpn {
                continue;
            }
            let aff: f64 = m.iter().map(|&o| w[r * p + o]).sum();
            if aff > best_aff {
                best_aff = aff;
                best_node = nd;
            }
        }
        node_of_rank[r] = best_node;
        members[best_node].push(r);
    }
    let mut place = vec![0usize; p];
    let mut next_slot = vec![0usize; n_nodes];
    for r in 0..p {
        let nd = node_of_rank[r];
        place[r] = nd * gpn + next_slot[nd];
        next_slot[nd] += 1;
    }
    place
}

/// Placement search at depth 1: the caller's starting placement vs the
/// greedy seed, then local-swap hill climbing. Returns
/// `(placement, total_s, calls)`; never worse than `init`.
fn placement_pass(
    plan: &Plan,
    sim: &mut PlanSim,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
    init: &[usize],
) -> (Vec<usize>, f64, usize) {
    let p = plan.n_workers;
    let mut calls = 0usize;
    let mut place: Vec<usize> = init.to_vec();
    let mut best = sim.total_s(cluster, &place, 1);
    calls += 1;
    let seeded = greedy_seed(plan, cost, cluster);
    let s = sim.total_s(cluster, &seeded, 1);
    calls += 1;
    if improves(s, best) {
        best = s;
        place = seeded;
    }
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(p * (p - 1) / 2);
    for i in 0..p {
        for j in i + 1..p {
            pairs.push((i, j));
        }
    }
    let mut rng = Rng::new(opts.seed ^ 0x9e37_79b9_7f4a_7c15);
    for _ in 0..opts.swap_rounds {
        // Fisher–Yates with the deterministic rng
        for k in (1..pairs.len()).rev() {
            let j = rng.below(k + 1);
            pairs.swap(k, j);
        }
        let mut improved = false;
        for &(i, j) in &pairs {
            // links only distinguish nodes: same-node swaps are no-ops
            if cluster.node_of(place[i]) == cluster.node_of(place[j]) {
                continue;
            }
            place.swap(i, j);
            let s = sim.total_s(cluster, &place, 1);
            calls += 1;
            if improves(s, best) {
                best = s;
                improved = true;
            } else {
                place.swap(i, j);
            }
        }
        if !improved {
            break;
        }
    }
    (place, best, calls)
}

/// Optimize an already-lowered (or dataflow) plan: placement + depth only.
/// Role flipping needs the schedule; use [`optimize_schedule`] for that.
pub fn optimize_plan(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Optimized {
    optimize_plan_with_op_costs(plan, cluster, cost, opts, &[])
}

/// [`optimize_plan`] with a per-op cost overlay: each `(op, seconds)`
/// entry replaces that op's class-priced cost in the scoring simulator
/// before the search runs, so placement and depth are tuned against the
/// ops' *measured* durations (`OptimizeOpts::per_op_costs` +
/// `Session::calibrate`). The overlay indexes `plan.ops`, so it is only
/// valid while the op stream matches the traced plan's — callers must
/// pass `&[]` for any re-lowered candidate.
pub fn optimize_plan_with_op_costs(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
    op_costs: &[(usize, f64)],
) -> Optimized {
    let mut sim = PlanSim::new(plan, cost);
    apply_slowdowns(&mut sim, opts);
    for &(op, s) in op_costs {
        sim.set_op_cost(op, s);
    }
    // the baseline is the plan *as given* — including any placement it
    // already carries — so default_s matches what simulate_plan reports
    let default_s = sim.total_s(cluster, &plan.placement, 1);
    let mut sim_calls = 1usize;
    // placement_pass scores that starting placement first and only
    // accepts strict improvements, so its result is never worse
    let mut place = plan.placement.clone();
    if opts.placement {
        let (pl, _s, calls) =
            placement_pass(plan, &mut sim, cluster, cost, opts, &plan.placement);
        sim_calls += calls;
        place = pl;
    }
    let (depth, total, calls) = autotune_depth_sim(&mut sim, cluster, &place, opts);
    sim_calls += calls;
    let moved_ranks = place.iter().enumerate().filter(|&(i, &g)| i != g).count();
    let mut out = plan.clone();
    out.placement = place;
    out.prefetch_depth = depth;
    Optimized {
        plan: out,
        prefetch_depth: depth,
        default_s,
        optimized_s: total,
        flipped_steps: Vec::new(),
        moved_ranks,
        sim_calls,
    }
}

/// Full pass pipeline over a schedule lowering: role flipping, placement,
/// depth. The returned plan always validates (`validate_lowered`), covers
/// the same pair set as the default lowering, and its `optimized_s` is
/// never above `default_s`.
pub fn optimize_schedule(
    schedule: &Schedule,
    pass: Pass,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> Optimized {
    optimize_schedule_ckpt(schedule, pass, cluster, cost, opts, None)
}

/// [`optimize_schedule`] with an explicit checkpoint strategy: every
/// lowering in the flip search (and the baseline) carries `ckpt`, so an
/// HfStyle backward plan is optimized *with* its recompute prefix priced
/// in rather than having checkpointing bolted on afterwards.
pub fn optimize_schedule_ckpt(
    schedule: &Schedule,
    pass: Pass,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
    ckpt: Option<CkptStrategy>,
) -> Optimized {
    let p = schedule.n_workers;
    let identity: Vec<usize> = (0..p).collect();
    let base = Plan::from_schedule_opts(
        schedule,
        pass,
        &LowerOpts { ckpt, ..Default::default() },
    );
    let mut sim = PlanSim::new(&base, cost);
    apply_slowdowns(&mut sim, opts);
    let default_s = sim.total_s(cluster, &identity, 1);
    let mut sim_calls = 1usize;
    let mut best_plan = base;
    let mut best = default_s;
    let mut flips = vec![false; schedule.n_steps()];
    if opts.flip {
        for t in 0..schedule.n_steps() {
            let has_help = schedule.steps[t]
                .iter()
                .any(|sp| matches!(sp.compute, Some(ComputeOp::Help { .. })));
            if !has_help {
                continue;
            }
            flips[t] = true;
            let cand =
                Plan::from_schedule_opts(
                    schedule,
                    pass,
                    &LowerOpts { flip_steps: flips.clone(), ckpt, ..Default::default() },
                );
            let mut cand_sim = PlanSim::new(&cand, cost);
            apply_slowdowns(&mut cand_sim, opts);
            let s = cand_sim.total_s(cluster, &identity, 1);
            sim_calls += 1;
            if improves(s, best) {
                best = s;
                best_plan = cand;
                sim = cand_sim;
            } else {
                flips[t] = false;
            }
        }
    }
    // `best` is the depth-1 identity-placement time of `best_plan`;
    // placement_pass rescores that baseline itself and only accepts
    // strict improvements, so it is not threaded further
    let mut place = identity;
    if opts.placement {
        let (pl, _s, calls) =
            placement_pass(&best_plan, &mut sim, cluster, cost, opts, &best_plan.placement);
        sim_calls += calls;
        place = pl;
    }
    let (depth, total, calls) = autotune_depth_sim(&mut sim, cluster, &place, opts);
    sim_calls += calls;
    let moved_ranks = place.iter().enumerate().filter(|&(i, &g)| i != g).count();
    best_plan.placement = place;
    best_plan.prefetch_depth = depth;
    Optimized {
        plan: best_plan,
        prefetch_depth: depth,
        default_s,
        optimized_s: total,
        flipped_steps: flips
            .iter()
            .enumerate()
            .filter_map(|(t, &f)| if f { Some(t) } else { None })
            .collect(),
        moved_ranks,
        sim_calls,
    }
}

/// One strategy's audited outcome inside the joint checkpoint × prefetch
/// search (`optimize_ckpt`).
#[derive(Clone, Debug)]
pub struct CkptArm {
    pub strategy: CkptStrategy,
    /// Depth knee under the strategy's remaining staging headroom.
    pub prefetch_depth: usize,
    /// Simulated backward makespan at that depth (recompute prefix
    /// included for HfStyle).
    pub total_s: f64,
    /// Memory-timeline high-water mark: resident floor (+ checkpoint
    /// bytes for RematAware) plus live staged payloads.
    pub peak_bytes: f64,
    /// Whether the peak fits in `GpuSpec::mem_bytes`.
    pub fits: bool,
}

/// Result of the joint §3.2 × §3.3 search: both strategies priced with
/// the event engine's memory timeline, the faster *feasible* one chosen.
#[derive(Clone, Debug)]
pub struct CkptOptimized {
    /// The winning strategy's backward plan (recompute prefix included
    /// under HfStyle), placement and depth applied.
    pub plan: Plan,
    pub choice: CkptStrategy,
    /// Audit of both arms, `HfStyle` first.
    pub arms: Vec<CkptArm>,
    pub sim_calls: usize,
}

impl CkptOptimized {
    pub fn arm(&self, s: CkptStrategy) -> &CkptArm {
        self.arms.iter().find(|a| a.strategy == s).expect("both arms present")
    }
}

/// Search checkpoint strategy *jointly* with prefetch depth for one
/// backward pass. Both knobs spend the same per-GPU memory headroom the
/// depth autotuner budgets via `stage_mem_frac`: RematAware's
/// `ckpt_extra_bytes` (its `o`/`lse` floats, per layer, per worker) is
/// reserved out of the staging budget before the depth sweep, while
/// HfStyle keeps the full budget but pays the recompute prefix in time.
/// Each arm's peak (resident floor + checkpoint bytes + staged payloads,
/// from [`PlanSim::mem_timeline`]) is then priced against
/// `GpuSpec::mem_bytes`; arms that do not fit are rejected, and the
/// faster feasible arm wins (ties to RematAware, the paper's default).
/// `resident_bytes` is the per-worker floor both strategies share
/// (weights slice + layer-input activations).
pub fn optimize_ckpt(
    schedule: &Schedule,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
    resident_bytes: f64,
    ckpt_extra_bytes: f64,
) -> CkptOptimized {
    let mut sim_calls = 0usize;
    let mut arms: Vec<CkptArm> = Vec::with_capacity(2);
    let mut plans: Vec<Plan> = Vec::with_capacity(2);
    for strategy in [CkptStrategy::HfStyle, CkptStrategy::RematAware] {
        let lopts = LowerOpts { ckpt: Some(strategy), ..Default::default() };
        let mut plan = Plan::from_schedule_opts(schedule, Pass::Backward, &lopts);
        let mut sim = PlanSim::new(&plan, cost);
        apply_slowdowns(&mut sim, opts);
        let mut place = plan.placement.clone();
        if opts.placement {
            let (pl, _s, calls) =
                placement_pass(&plan, &mut sim, cluster, cost, opts, &place);
            sim_calls += calls;
            place = pl;
        }
        let reserve = match strategy {
            CkptStrategy::HfStyle => 0.0,
            CkptStrategy::RematAware => ckpt_extra_bytes,
        };
        let (depth, total_s, calls) =
            autotune_depth_sim_reserved(&mut sim, cluster, &place, opts, reserve);
        sim_calls += calls;
        // re-run at the chosen depth so the memory sweep sees its timeline
        sim.total_s(cluster, &place, depth);
        sim_calls += 1;
        let peak_bytes = sim.mem_timeline(resident_bytes + reserve).max_peak();
        arms.push(CkptArm {
            strategy,
            prefetch_depth: depth,
            total_s,
            peak_bytes,
            fits: peak_bytes <= cluster.gpu.mem_bytes,
        });
        plan.placement = place;
        plan.prefetch_depth = depth;
        plans.push(plan);
    }
    // faster feasible arm wins; with no feasible arm, the smaller peak.
    // `<=` on the second (RematAware) arm sends ties to the paper's
    // default.
    let mut pick = 0usize;
    for i in 1..arms.len() {
        let better = match (arms[i].fits, arms[pick].fits) {
            (true, false) => true,
            (false, true) => false,
            (true, true) => arms[i].total_s <= arms[pick].total_s,
            (false, false) => arms[i].peak_bytes <= arms[pick].peak_bytes,
        };
        if better {
            pick = i;
        }
    }
    CkptOptimized {
        plan: plans.swap_remove(pick),
        choice: arms[pick].strategy,
        arms,
        sim_calls,
    }
}

// ---------------------------------------------------------------------------
// Token-level workload rebalancing for document-packed (varlen) batches
// ---------------------------------------------------------------------------

/// How one dense-plan op's cost is derived from the current chunk
/// boundaries — the rebalancer's patch table. `live_pair` gates the op to
/// zero when its chunk pair shares no document.
#[derive(Clone, Copy, Debug)]
enum OpCost {
    /// Attention block `(q, kv)`: `Kernel::attn` at the pair's token scale.
    AttnPair { q: usize, kv: usize },
    /// Helper merge on `owner`: `Kernel::rescale` at the owner's scale.
    Merge { owner: usize },
    /// Transfer sized by one chunk's token span, of a payload class.
    Bytes { chunk: usize, class: PayloadClass },
    /// Cost never touched by boundary moves or flips (Accum).
    Fixed,
}

/// Which role alternative of a helper pair an op belongs to. The dense
/// lowering emits both; exactly one is active at a time and the other is
/// costed at zero (zero-cost ops never extend the makespan — they start
/// and finish at already-reached times).
#[derive(Clone, Copy, Debug, PartialEq)]
enum Side {
    Common,
    Unflipped { step: usize, helper: usize },
    Flipped { step: usize, helper: usize },
}

struct DenseOp {
    cost: OpCost,
    side: Side,
    live_pair: Option<(usize, usize)>,
}

/// Classify every op of a dense-dual varlen plan (see
/// [`LowerOpts::dense_duals`]) so moves become pure cost patches.
///
/// Roles are recovered from step-distance arithmetic: at step `t` an
/// own-path pair sits at distance `t`, a helper pair at distance
/// `P - t` — distinct because the balanced builder emits no helpers when
/// `2t == P`. That invariant is load-bearing for the flip toggles, so
/// every Flipped classification asserts it rather than trusting future
/// schedule kinds.
fn classify_dense_ops(plan: &Plan) -> Vec<DenseOp> {
    let p = plan.n_workers;
    let helper_dist = |dist: usize, t: usize, id: usize| {
        assert!(
            dist == p - t && dist != t,
            "op {id}: helper-pair distance {dist} at step {t} breaks the P-t role \
             invariant the rebalancer's flip toggles rely on"
        );
    };
    plan.ops
        .iter()
        .map(|n| {
            let t = n.step;
            match &n.op {
                PlanOp::Compute { kernel, pair } => match (kernel, pair) {
                    (Kernel::Accum, _) | (Kernel::Raw(_), _) => {
                        DenseOp { cost: OpCost::Fixed, side: Side::Common, live_pair: None }
                    }
                    (Kernel::Rescale | Kernel::RescaleTok { .. }, _) => {
                        // the merge belongs to the unflipped side; its
                        // helper is the source of the result dep
                        let helper = n
                            .deps
                            .iter()
                            .find_map(|&d| match &plan.ops[d].op {
                                PlanOp::Xfer { src, payload, .. }
                                    if payload.class() == PayloadClass::HelperResult =>
                                {
                                    Some(*src)
                                }
                                _ => None,
                            })
                            .expect("rescale has a helper-result dep");
                        DenseOp {
                            cost: OpCost::Merge { owner: n.worker },
                            side: Side::Unflipped { step: t, helper },
                            live_pair: Some((n.worker, helper)),
                        }
                    }
                    (_, Some((q, kv))) => {
                        let (q, kv) = (*q, *kv);
                        let side = if q == kv || (n.worker == q && q - kv == t) {
                            Side::Common // diagonal or own-path block
                        } else if n.worker == kv {
                            Side::Unflipped { step: t, helper: kv }
                        } else {
                            helper_dist(q - kv, t, n.id);
                            Side::Flipped { step: t, helper: kv }
                        };
                        DenseOp { cost: OpCost::AttnPair { q, kv }, side, live_pair: Some((q, kv)) }
                    }
                    _ => DenseOp { cost: OpCost::Fixed, side: Side::Common, live_pair: None },
                },
                PlanOp::Xfer { src, dst, payload } => {
                    let (s, d) = (*src, *dst);
                    match payload.class() {
                        PayloadClass::Kv => {
                            // own-path fetch (dst - src == t) vs flipped lend
                            if d > s && d - s == t {
                                DenseOp {
                                    cost: OpCost::Bytes { chunk: s, class: PayloadClass::Kv },
                                    side: Side::Common,
                                    live_pair: Some((d, s)),
                                }
                            } else {
                                helper_dist(d - s, t, n.id);
                                DenseOp {
                                    cost: OpCost::Bytes { chunk: s, class: PayloadClass::Kv },
                                    side: Side::Flipped { step: t, helper: s },
                                    live_pair: Some((d, s)),
                                }
                            }
                        }
                        PayloadClass::QBundle => DenseOp {
                            cost: OpCost::Bytes { chunk: s, class: PayloadClass::QBundle },
                            side: Side::Unflipped { step: t, helper: d },
                            live_pair: Some((s, d)),
                        },
                        PayloadClass::HelperResult => DenseOp {
                            cost: OpCost::Bytes { chunk: d, class: PayloadClass::HelperResult },
                            side: Side::Unflipped { step: t, helper: s },
                            live_pair: Some((d, s)),
                        },
                        PayloadClass::KvGrad => {
                            if s > d && s - d == t {
                                // own-path (dk, dv) return to the lender
                                DenseOp {
                                    cost: OpCost::Bytes { chunk: d, class: PayloadClass::KvGrad },
                                    side: Side::Common,
                                    live_pair: Some((s, d)),
                                }
                            } else {
                                helper_dist(s - d, t, n.id);
                                DenseOp {
                                    cost: OpCost::Bytes { chunk: d, class: PayloadClass::KvGrad },
                                    side: Side::Flipped { step: t, helper: d },
                                    live_pair: Some((s, d)),
                                }
                            }
                        }
                        PayloadClass::Raw => {
                            DenseOp { cost: OpCost::Fixed, side: Side::Common, live_pair: None }
                        }
                    }
                }
            }
        })
        .collect()
}

/// Result of the token-level varlen optimizer: rebalanced chunk
/// boundaries, per-pair role flips, placement, and prefetch depth for one
/// document-packed attention call.
#[derive(Clone, Debug)]
pub struct VarlenOptimized {
    /// Final sparse lowering (token-exact payloads, zero-weight pairs
    /// skipped, flips applied, placement set) — validated, executable.
    pub plan: Plan,
    /// Final chunk boundaries.
    pub spec: VarlenSpec,
    pub prefetch_depth: usize,
    /// Pad-to-max baseline: every document padded to the longest, equal
    /// chunks, classic lowering (depth 1, identity placement).
    pub pad_s: f64,
    /// Equal-token varlen boundaries, default roles (depth 1, identity).
    pub equal_s: f64,
    /// The optimized plan at the chosen depth and placement.
    pub optimized_s: f64,
    pub flipped_pairs: usize,
    /// Chunk boundaries that moved off the equal-token split.
    pub moved_boundaries: usize,
    pub moved_ranks: usize,
    /// Event-engine scoring passes (full or incremental).
    pub sim_calls: usize,
    /// How many of those were answered by a dirty-suffix replay instead
    /// of a full re-simulation.
    pub incremental_rescores: usize,
}

impl VarlenOptimized {
    pub fn speedup_vs_pad(&self) -> f64 {
        if self.optimized_s > 0.0 { self.pad_s / self.optimized_s } else { 1.0 }
    }

    pub fn speedup_vs_equal(&self) -> f64 {
        if self.optimized_s > 0.0 { self.equal_s / self.optimized_s } else { 1.0 }
    }
}

/// Search state over the dense dual plan: current boundaries, per-pair
/// flip choices, and the incremental simulator they are priced on.
struct Rebalancer<'a> {
    sim: PlanSim,
    roles: Vec<DenseOp>,
    /// Ops whose cost depends on chunk `c`'s boundaries.
    ops_of_chunk: Vec<Vec<usize>>,
    /// Helper-pair keys `(step, helper)` with their (dual) op lists.
    pairs: Vec<((usize, usize), Vec<usize>)>,
    spec: VarlenSpec,
    lopts: LowerOpts,
    cost: &'a AttnCost,
}

impl<'a> Rebalancer<'a> {
    fn new(plan: &Plan, spec: VarlenSpec, cost: &'a AttnCost) -> Rebalancer<'a> {
        let roles = classify_dense_ops(plan);
        let p = plan.n_workers;
        let mut ops_of_chunk: Vec<Vec<usize>> = vec![Vec::new(); p];
        let mut pair_map: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, r) in roles.iter().enumerate() {
            match r.cost {
                OpCost::AttnPair { q, kv } => {
                    ops_of_chunk[q].push(i);
                    if kv != q {
                        ops_of_chunk[kv].push(i);
                    }
                }
                OpCost::Merge { owner } => {
                    ops_of_chunk[owner].push(i);
                    if let Some((_, h)) = r.live_pair {
                        if h != owner {
                            ops_of_chunk[h].push(i);
                        }
                    }
                }
                OpCost::Bytes { chunk, .. } => {
                    ops_of_chunk[chunk].push(i);
                    // liveness also depends on the pair's other chunk
                    if let Some((q, kv)) = r.live_pair {
                        let other = if q == chunk { kv } else { q };
                        if other != chunk {
                            ops_of_chunk[other].push(i);
                        }
                    }
                }
                OpCost::Fixed => {}
            }
            match r.side {
                Side::Unflipped { step, helper } | Side::Flipped { step, helper } => {
                    pair_map.entry((step, helper)).or_default().push(i);
                }
                Side::Common => {}
            }
        }
        let mut reb = Rebalancer {
            sim: PlanSim::new(plan, cost),
            roles,
            ops_of_chunk,
            pairs: pair_map.into_iter().collect(),
            spec,
            lopts: LowerOpts::default(),
            cost,
        };
        // bring every op to its target: dormant flipped sides and dead
        // pairs to zero, live ops to the current boundaries' scales
        for i in 0..reb.roles.len() {
            let c = reb.target_cost(i);
            reb.sim.set_op_cost(i, c);
        }
        reb
    }

    fn flipped(&self, step: usize, helper: usize) -> bool {
        self.lopts.flip_pair(step, helper, self.spec.n_chunks())
    }

    /// The cost this op should carry under the current boundaries and
    /// flip choices — resolved through the exact same `Kernel`/`Payload`
    /// constructors the sparse lowering uses, so the search proxy and the
    /// final plan price identically.
    fn target_cost(&self, i: usize) -> f64 {
        let r = &self.roles[i];
        let active = match r.side {
            Side::Common => true,
            Side::Unflipped { step, helper } => !self.flipped(step, helper),
            Side::Flipped { step, helper } => self.flipped(step, helper),
        };
        let live = r
            .live_pair
            .map_or(true, |(q, kv)| self.spec.pair_weight(q, kv) > 0.0);
        if !active || !live {
            return 0.0;
        }
        match r.cost {
            OpCost::AttnPair { q, kv } => {
                Kernel::attn(q, kv, self.spec.pair_scale(q, kv)).seconds(self.cost)
            }
            OpCost::Merge { owner } => {
                Kernel::rescale(self.spec.token_scale(owner)).seconds(self.cost)
            }
            OpCost::Bytes { chunk, class } => {
                let s = self.spec.token_scale(chunk);
                match class {
                    PayloadClass::Kv => Payload::kv(s).bytes(self.cost),
                    PayloadClass::QBundle => Payload::q_bundle(s).bytes(self.cost),
                    PayloadClass::HelperResult => Payload::helper_result(s).bytes(self.cost),
                    PayloadClass::KvGrad => Payload::kv_grad(s).bytes(self.cost),
                    PayloadClass::Raw => self.sim.op_cost(i),
                }
            }
            OpCost::Fixed => self.sim.op_cost(i),
        }
    }

    /// Patch the given ops to their target costs, remembering the old
    /// values for a cheap revert.
    fn patch(&mut self, ops: &[usize], undo: &mut Vec<(usize, f64)>) {
        undo.clear();
        for &i in ops {
            let old = self.sim.op_cost(i);
            let new = self.target_cost(i);
            if old != new {
                undo.push((i, old));
                self.sim.set_op_cost(i, new);
            }
        }
    }

    fn revert(&mut self, undo: &[(usize, f64)]) {
        for &(i, v) in undo {
            self.sim.set_op_cost(i, v);
        }
    }
}

/// Token-level workload balancing for a document-packed batch: greedy
/// chunk-boundary moves plus per-pair owner/helper role flips, every
/// candidate priced by the incremental rescorer on a fixed dense DAG, then
/// the standard placement and (memory-capped) prefetch-depth passes on the
/// final sparse lowering. Accepts only strict improvements, so the result
/// is never worse than the equal-token varlen default — and on skewed
/// document mixes it beats the pad-to-max baseline by construction of the
/// token-exact cost model.
pub fn optimize_varlen(
    schedule: &Schedule,
    spec0: &VarlenSpec,
    pass: Pass,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &OptimizeOpts,
) -> VarlenOptimized {
    let p = schedule.n_workers;
    assert_eq!(spec0.n_chunks(), p, "spec chunks must match schedule workers");
    let identity: Vec<usize> = (0..p).collect();
    let mut sim_calls = 0usize;
    let mut incremental = 0usize;

    // pad-to-max baseline: linear payloads and quadratic kernels inflate
    // by the padded-to-real chunk ratio
    let r = spec0.pad_factor();
    let pad_cost = AttnCost {
        pair_full_s: cost.pair_full_s * r * r,
        pair_diag_s: cost.pair_diag_s * r * r,
        rescale_s: cost.rescale_s * r,
        kv_bytes: cost.kv_bytes * r,
        q_bytes: cost.q_bytes * r,
        result_bytes: cost.result_bytes * r,
        overlap: cost.overlap,
    };
    let pad_plan = Plan::from_schedule(schedule, pass);
    let mut pad_sim = PlanSim::new(&pad_plan, &pad_cost);
    apply_slowdowns(&mut pad_sim, opts);
    let pad_s = pad_sim.total_s(cluster, &identity, 1);
    sim_calls += 1;

    // equal-token varlen default (the honest sparse lowering)
    let equal_opts = LowerOpts { varlen: Some(Arc::new(spec0.clone())), ..Default::default() };
    let equal_plan = Plan::from_schedule_opts(schedule, pass, &equal_opts);
    let mut equal_sim = PlanSim::new(&equal_plan, cost);
    apply_slowdowns(&mut equal_sim, opts);
    let equal_s = equal_sim.total_s(cluster, &identity, 1);
    sim_calls += 1;

    // dense dual plan: fixed DAG over which every boundary move and flip
    // toggle is a cost patch
    let dense_opts = LowerOpts {
        varlen: Some(Arc::new(spec0.clone())),
        dense_duals: true,
        ..Default::default()
    };
    let dense_plan = Plan::from_schedule_opts(schedule, pass, &dense_opts);
    let mut reb = Rebalancer::new(&dense_plan, spec0.clone(), cost);
    apply_slowdowns(&mut reb.sim, opts);
    let mut best = reb.sim.rescore(cluster, &identity, 1);
    sim_calls += 1;

    let grain = (spec0.ref_tokens() / 16.0).max(1.0) as i64;
    let deltas: [i64; 6] = [-4 * grain, -2 * grain, -grain, grain, 2 * grain, 4 * grain];
    // document edges (token prefix sums) — the kinks of the pair-weight
    // function, where boundary moves change slope; candidate cuts snap to
    // them when `align_doc_cuts` is set and any fall inside the window
    let kinks: Vec<usize> = {
        let mut off = 0usize;
        spec0
            .doc_lens
            .iter()
            .map(|&l| {
                off += l;
                off
            })
            .collect()
    };
    let mut undo: Vec<(usize, f64)> = Vec::new();
    let mut touched: Vec<usize> = Vec::new();
    // candidate buffer: absolute positions (aligned) or deltas (grid)
    let mut cands: Vec<i64> = Vec::new();
    for _ in 0..opts.rebalance_rounds {
        let mut improved = false;
        // boundary moves: shift the cut between chunks b-1 and b
        for b in 1..p {
            if !opts.move_boundaries {
                break;
            }
            // candidate moves for this cut: absolute document-edge
            // positions (nearest first, capped at the grid size) when
            // alignment is on and any edge sits strictly inside the
            // window; otherwise the legacy relative grid, each delta
            // chaining off the then-current position
            cands.clear();
            let cur = reb.spec.boundaries[b];
            let (lo, hi) = (reb.spec.boundaries[b - 1], reb.spec.boundaries[b + 1]);
            if opts.align_doc_cuts {
                cands.extend(
                    kinks
                        .iter()
                        .filter(|&&t| t > lo && t < hi && t != cur)
                        .map(|&t| t as i64),
                );
                cands.sort_by_key(|&t| t.abs_diff(cur as i64));
                cands.truncate(deltas.len());
            }
            let aligned = !cands.is_empty();
            if !aligned {
                cands.extend_from_slice(&deltas);
            }
            for &mv in &cands {
                let old_b = reb.spec.boundaries[b];
                let nb = if aligned { mv } else { old_b as i64 + mv };
                if nb <= reb.spec.boundaries[b - 1] as i64
                    || nb >= reb.spec.boundaries[b + 1] as i64
                    || nb == old_b as i64
                {
                    continue; // every chunk keeps at least one token
                }
                touched.clear();
                touched.extend_from_slice(&reb.ops_of_chunk[b - 1]);
                touched.extend_from_slice(&reb.ops_of_chunk[b]);
                reb.spec.boundaries[b] = nb as usize;
                reb.patch(&touched, &mut undo);
                if reb.sim.dirty_from() > 0 {
                    incremental += 1;
                }
                sim_calls += 1;
                let t = reb.sim.rescore(cluster, &identity, 1);
                if improves(t, best) {
                    best = t;
                    improved = true;
                } else {
                    reb.spec.boundaries[b] = old_b;
                    reb.revert(&undo);
                }
            }
        }
        // per-pair role flips
        for k in 0..reb.pairs.len() {
            let (step, helper) = reb.pairs[k].0;
            let was = reb.flipped(step, helper);
            reb.lopts.set_flip_pair(step, helper, p, !was);
            let ops = std::mem::take(&mut reb.pairs[k].1);
            reb.patch(&ops, &mut undo);
            reb.pairs[k].1 = ops;
            if reb.sim.dirty_from() > 0 {
                incremental += 1;
            }
            sim_calls += 1;
            let t = reb.sim.rescore(cluster, &identity, 1);
            if improves(t, best) {
                best = t;
                improved = true;
            } else {
                reb.lopts.set_flip_pair(step, helper, p, was);
                reb.revert(&undo);
            }
        }
        if !improved {
            break;
        }
    }

    // final sparse lowering with the chosen boundaries and flips, then the
    // standard placement + depth passes on the real plan
    let final_spec = reb.spec.clone();
    let final_opts = LowerOpts {
        flip_pairs: reb.lopts.flip_pairs.clone(),
        varlen: Some(Arc::new(final_spec.clone())),
        ..Default::default()
    };
    let mut final_plan = Plan::from_schedule_opts(schedule, pass, &final_opts);
    let mut fsim = PlanSim::new(&final_plan, cost);
    apply_slowdowns(&mut fsim, opts);
    let mut place = identity.clone();
    if opts.placement {
        let (pl, _s, calls) =
            placement_pass(&final_plan, &mut fsim, cluster, cost, opts, &identity);
        sim_calls += calls;
        place = pl;
    }
    let (depth, total, calls) = autotune_depth_sim(&mut fsim, cluster, &place, opts);
    sim_calls += calls;
    let moved_ranks = place.iter().enumerate().filter(|&(i, &g)| i != g).count();
    let moved_boundaries = final_spec
        .boundaries
        .iter()
        .zip(&spec0.boundaries)
        .filter(|(a, b)| a != b)
        .count();
    final_plan.placement = place;
    final_plan.prefetch_depth = depth;
    VarlenOptimized {
        plan: final_plan,
        spec: final_spec,
        prefetch_depth: depth,
        pad_s,
        equal_s,
        optimized_s: total,
        flipped_pairs: reb.lopts.flipped_pair_count(),
        moved_boundaries,
        moved_ranks,
        sim_calls,
        incremental_rescores: incremental,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(kv_over_q: f64) -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6 * kv_over_q,
            q_bytes: 1e6,
            result_bytes: 1.1e6,
            overlap: true,
        }
    }

    #[test]
    fn depth_candidates_always_include_default() {
        let opts = OptimizeOpts { depths: vec![8, 4], ..Default::default() };
        assert_eq!(depth_candidates(&opts), vec![1, 4, 8]);
        let opts = OptimizeOpts { depths: vec![], ..Default::default() };
        assert_eq!(depth_candidates(&opts), vec![1]);
    }

    #[test]
    fn greedy_seed_is_a_permutation() {
        let cluster = ClusterSpec::dgx_2x8();
        for p in [4usize, 8, 16] {
            let plan = Plan::from_schedule(&Schedule::balanced(p), Pass::Forward);
            let mut place = greedy_seed(&plan, &cost(0.25), &cluster);
            place.sort_unstable();
            place.dedup();
            assert_eq!(place.len(), p, "P={p}: duplicate GPU assignment");
        }
    }

    #[test]
    fn optimize_never_worse_and_validates() {
        let cluster = ClusterSpec::dgx_2x8();
        let s = Schedule::balanced(16);
        for pass in [Pass::Forward, Pass::Backward] {
            let o = optimize_schedule(&s, pass, &cluster, &cost(0.25), &OptimizeOpts::default());
            assert!(o.optimized_s <= o.default_s * (1.0 + 1e-9), "{pass:?}");
            o.plan.validate_lowered().unwrap();
        }
    }

    #[test]
    fn depth_cap_charges_staging_memory() {
        // comm-bound regime where the knee is deep — but depth d stages
        // d kv chunks, so a starved staging budget must pin depth to 1
        let cluster = ClusterSpec::dgx_2x8();
        let c = AttnCost { kv_bytes: 60e6, ..cost(1.0) };
        let plan = Plan::from_schedule(&Schedule::ring(16), Pass::Forward);
        let (d_free, _) = autotune_depth(&plan, &cluster, &c, &OptimizeOpts::default());
        assert!(d_free > 1, "default headroom should allow a deep knee");
        let starved = OptimizeOpts { stage_mem_frac: 1e-12, ..Default::default() };
        let (d_cap, _) = autotune_depth(&plan, &cluster, &c, &starved);
        assert_eq!(d_cap, 1, "staging charge must cap the depth");
    }

    #[test]
    fn flip_fires_when_q_dwarfs_kv() {
        // comm-bound GQA-style regime: q bundle 4x the kv chunk, kernels
        // cheap relative to the inter-node wire
        let cluster = ClusterSpec::dgx_2x8();
        let c = AttnCost { pair_full_s: 1e-5, pair_diag_s: 0.5e-5, ..cost(0.25) };
        let o = optimize_schedule(
            &Schedule::balanced(16),
            Pass::Forward,
            &cluster,
            &c,
            &OptimizeOpts::default(),
        );
        assert!(!o.flipped_steps.is_empty(), "expected flips in the GQA regime");
        assert!(o.optimized_s < o.default_s, "flips must strictly improve here");
    }
}
