//! Memory accounting utilities shared by the baselines and the
//! max-sequence experiments (Tables 2, 3, 6).
//!
//! The per-system peak models live with each baseline (they are strategy
//! specific); this module provides the generic solver plus human-readable
//! breakdown helpers.

use crate::baselines::SystemModel;
use crate::config::{ClusterSpec, PaperModel};

/// Search granularity the paper's tables use (sequence lengths are powers
/// of two times 1K).
pub const SEQ_GRANULARITY: usize = 1024;

/// Max *total* sequence length for a system on a cluster, rounded down to
/// the nearest power of two (how the paper reports Table 2/3 entries).
pub fn max_total_seq_pow2(
    sys: &dyn SystemModel,
    model: &PaperModel,
    cluster: &ClusterSpec,
) -> usize {
    let per_gpu = sys.max_seq_per_gpu(model, cluster, SEQ_GRANULARITY, 4 << 20);
    let total = per_gpu * cluster.n_gpus();
    if total == 0 {
        return 0;
    }
    let mut p = 1usize;
    while p * 2 <= total {
        p *= 2;
    }
    p
}

/// Pretty-print byte counts the way the paper's tables do.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.1}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{:.0}B", b)
    }
}

/// Sequence lengths as the paper writes them (64K, 512K, ...).
pub fn fmt_seq(tokens: usize) -> String {
    if tokens >= 1024 && tokens % 1024 == 0 {
        format!("{}K", tokens / 1024)
    } else {
        format!("{tokens}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::distflash::DistFlashAttn;

    #[test]
    fn pow2_rounding() {
        let model = PaperModel::llama_7b();
        let cluster = ClusterSpec::dgx_1x8();
        let total = max_total_seq_pow2(&DistFlashAttn::default(), &model, &cluster);
        assert!(total.is_power_of_two());
        assert!(total >= 256 * 1024, "{total}");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_seq(64 * 1024), "64K");
        assert_eq!(fmt_seq(1000), "1000");
        assert_eq!(fmt_bytes(31.5e9), "31.5GB");
        assert_eq!(fmt_bytes(2.5e6), "2.5MB");
        // the [1e3, 1e6) band used to fall through to raw byte counts
        assert_eq!(fmt_bytes(500_000.0), "500.0KB");
        assert_eq!(fmt_bytes(1_000.0), "1.0KB");
        assert_eq!(fmt_bytes(999.0), "999B");
    }
}
