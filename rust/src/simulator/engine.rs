//! Lock-step (BSP) timing simulation of a DISTFLASHATTN schedule.
//!
//! The schedule executes in synchronized timesteps (exactly how the real
//! executor behaves); per step each worker has a compute kernel and a set
//! of incoming transfers. With overlap ON (paper §3.2), prefetchable
//! transfers (kv/q — data that exists at step start) hide under the
//! compute of the same step: cost = max(compute, comm). With overlap OFF
//! they serialize: cost = compute + comm. Helper results are *not*
//! prefetchable (produced mid-step): the owner's completion waits for
//! helper compute + transfer, then pays the rescale.
//!
//! This reproduces the analysis behind Figure 4 and Figure 2 and gives the
//! per-(worker, step) trace used for the Fig. 2-style timeline.
//!
//! This engine is kept as the closed-form *reference*: the event-driven
//! engine (`simulator::event`) over the lowered schedule IR reproduces it
//! exactly at prefetch depth 1 (overlap) / depth 0 (serialized) — pinned
//! by `rust/tests/cross_engine.rs` — and generalizes it to dataflow plans
//! and deeper prefetch.

use crate::config::ClusterSpec;
use crate::coordinator::schedule::{ComputeOp, Schedule};

/// Per-call cost parameters (seconds / bytes), typically derived from a
/// `PaperModel` + `ClusterSpec` by the baselines.
#[derive(Clone, Copy, Debug)]
pub struct AttnCost {
    /// Seconds to compute one full (non-diagonal) chunk pair.
    pub pair_full_s: f64,
    /// Seconds for the causal diagonal chunk (≈ half the FLOPs).
    pub pair_diag_s: f64,
    /// Seconds for one rescale merge (elementwise, tiny but non-zero).
    pub rescale_s: f64,
    /// Bytes of a kv chunk transfer.
    pub kv_bytes: f64,
    /// Bytes of a q (forward) or q-bundle (backward) transfer.
    pub q_bytes: f64,
    /// Bytes of a helper partial result (o, m, l) or dq partial.
    pub result_bytes: f64,
    /// Overlap communication with computation (paper §3.2 optimization).
    pub overlap: bool,
}

/// One worker's accounting for one timestep.
#[derive(Clone, Copy, Debug, Default)]
pub struct SlotTrace {
    pub compute_s: f64,
    /// Communication time NOT hidden under compute.
    pub exposed_comm_s: f64,
    pub idle_s: f64,
}

#[derive(Clone, Debug)]
pub struct SimResult {
    /// Wall-clock of the whole call.
    pub total_s: f64,
    /// Duration of each lock step.
    pub step_s: Vec<f64>,
    /// trace[t][w].
    pub trace: Vec<Vec<SlotTrace>>,
    /// Total bytes moved.
    pub comm_bytes: f64,
    /// Sum over workers of busy compute time.
    pub busy_s: f64,
}

impl SimResult {
    /// Fraction of worker-slots spent idle (Fig. 1 / Fig. 4 metric).
    pub fn idle_fraction(&self) -> f64 {
        let total: f64 = self.total_s * self.trace[0].len() as f64;
        if total == 0.0 {
            return 0.0;
        }
        1.0 - self.busy_s / total
    }

    /// Communication overhead relative to pure compute (Fig. 4 right).
    pub fn comm_overhead(&self, compute_only_s: f64) -> f64 {
        (self.total_s - compute_only_s) / compute_only_s
    }
}

/// Simulate one distributed attention call (forward or backward — pass the
/// corresponding costs) over `cluster`, mapping worker i to GPU i.
pub fn simulate_attention(schedule: &Schedule, cluster: &ClusterSpec, cost: &AttnCost) -> SimResult {
    let p = schedule.n_workers;
    let mut step_s = Vec::with_capacity(schedule.n_steps());
    let mut trace = Vec::with_capacity(schedule.n_steps());
    let mut comm_bytes = 0.0;
    let mut busy_s = 0.0;

    for row in &schedule.steps {
        // per-worker compute duration and prefetchable incoming bytes
        let mut compute = vec![0.0f64; p];
        let mut inbound = vec![0.0f64; p]; // seconds of prefetchable comm
        for (w, plan) in row.iter().enumerate() {
            compute[w] = match plan.compute {
                Some(ComputeOp::Diag) => cost.pair_diag_s,
                Some(ComputeOp::Own { .. }) => cost.pair_full_s,
                Some(ComputeOp::Help { .. }) => cost.pair_full_s,
                None => 0.0,
            };
            if let Some(ComputeOp::Own { kv_from }) = plan.compute {
                let (bw, lat) = cluster.link(kv_from, w);
                inbound[w] += lat + cost.kv_bytes / bw;
                comm_bytes += cost.kv_bytes;
            }
            if let Some(ComputeOp::Help { owner }) = plan.compute {
                let (bw, lat) = cluster.link(owner, w);
                inbound[w] += lat + cost.q_bytes / bw;
                comm_bytes += cost.q_bytes;
            }
        }
        // completion time per worker within this step
        let mut finish = vec![0.0f64; p];
        let mut slot = vec![SlotTrace::default(); p];
        for (w, plan) in row.iter().enumerate() {
            let (ready, exposed) = if cost.overlap {
                // prefetched on the comm stream; exposed only beyond compute
                (inbound[w].max(0.0), (inbound[w] - compute[w]).max(0.0))
            } else {
                (inbound[w], inbound[w])
            };
            finish[w] = if cost.overlap {
                compute[w].max(ready)
            } else {
                compute[w] + ready
            };
            slot[w].compute_s = compute[w];
            slot[w].exposed_comm_s = exposed;
            let _ = &plan;
        }
        // helper results: the owner can only rescale once the helper has
        // computed. With overlap ON, the result transfer rides the comm
        // stream and pipelines into the owner's next compute (Fig. 2's
        // schedule overlaps result sends too); with overlap OFF the owner
        // stalls for the wire time as well.
        for (w, plan) in row.iter().enumerate() {
            if let Some(h) = plan.recv_helper_from {
                let (bw, lat) = cluster.link(h, w);
                comm_bytes += cost.result_bytes;
                let arrive = if cost.overlap {
                    finish[h]
                } else {
                    finish[h] + lat + cost.result_bytes / bw
                };
                let start_rescale = finish[w].max(arrive);
                let extra_wait = (arrive - finish[w]).max(0.0);
                finish[w] = start_rescale + cost.rescale_s;
                slot[w].exposed_comm_s += extra_wait;
                slot[w].compute_s += cost.rescale_s;
            }
        }
        let dur = finish.iter().cloned().fold(0.0, f64::max);
        for (w, s) in slot.iter_mut().enumerate() {
            s.idle_s = dur - s.compute_s - s.exposed_comm_s;
            busy_s += s.compute_s;
            let _ = w;
        }
        step_s.push(dur);
        trace.push(slot);
    }

    SimResult {
        total_s: step_s.iter().sum(),
        step_s,
        trace,
        comm_bytes,
        busy_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::coordinator::Schedule;

    fn cost(overlap: bool) -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6,
            q_bytes: 0.5e6,
            result_bytes: 0.6e6,
            overlap,
        }
    }

    #[test]
    fn balanced_faster_than_ring() {
        let cluster = ClusterSpec::dgx_1x8();
        let ring = simulate_attention(&Schedule::ring(8), &cluster, &cost(true));
        let bal = simulate_attention(&Schedule::balanced(8), &cluster, &cost(true));
        assert!(
            bal.total_s < ring.total_s * 0.7,
            "balanced {} vs ring {}",
            bal.total_s,
            ring.total_s
        );
    }

    #[test]
    fn overlap_helps_when_comm_significant() {
        // put the ring across two nodes so kv transfers are expensive
        let cluster = ClusterSpec::dgx_2x8();
        let s = Schedule::balanced(16);
        let with = simulate_attention(&s, &cluster, &cost(true));
        let without = simulate_attention(&s, &cluster, &cost(false));
        assert!(with.total_s < without.total_s);
    }

    #[test]
    fn overlap_fully_hides_cheap_comm() {
        // intra-node: kv transfer ≈ 4 µs << 1 ms compute → overlap should
        // make comm overhead negligible (paper: 8% / 1% in Fig. 4 right)
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::ring(8);
        let res = simulate_attention(&s, &cluster, &cost(true));
        let compute_only = simulate_attention(
            &s,
            &cluster,
            &AttnCost { kv_bytes: 0.0, q_bytes: 0.0, result_bytes: 0.0, ..cost(true) },
        );
        assert!(res.comm_overhead(compute_only.total_s) < 0.05);
    }

    #[test]
    fn idle_fraction_matches_schedule_theory() {
        // uniform pair costs, no comm: idle fraction of the simulated ring
        // approaches the analytic (P²-P)/2P² with diag counted at half
        let cluster = ClusterSpec::dgx_1x8();
        let c = AttnCost {
            pair_diag_s: 1e-3, // make diag == full so theory is exact
            kv_bytes: 0.0,
            q_bytes: 0.0,
            result_bytes: 0.0,
            rescale_s: 0.0,
            ..cost(true)
        };
        let res = simulate_attention(&Schedule::ring(8), &cluster, &c);
        let got = res.idle_fraction();
        let want = crate::coordinator::schedule::ring_idle_fraction(8);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn trace_shape_and_bytes() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let res = simulate_attention(&s, &cluster, &cost(true));
        assert_eq!(res.trace.len(), s.n_steps());
        assert_eq!(res.trace[0].len(), 8);
        // kv transfers: all owner pairs except diag; q+result per help pair
        let pairs = 8 * 9 / 2 - 8;
        let helps = s
            .computed_pairs()
            .iter()
            .filter(|((o, kv), (_, w))| o != kv && w != o)
            .count();
        let expect = (pairs - helps) as f64 * 1e6 + helps as f64 * (0.5e6 + 0.6e6);
        assert!((res.comm_bytes - expect).abs() < 1.0);
    }
}
