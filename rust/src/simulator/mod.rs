//! Cluster timing simulators: analytic collective costs, the legacy
//! lock-step BSP engine, and the event-driven engine over the schedule IR.
//!
//! This is the substrate behind every wall-clock number in the paper-table
//! reproductions; the real-numerics executor (`coordinator::executor`)
//! proves correctness, this proves the *performance shape*.
//!
//! Two engines, one contract:
//! * [`engine`] — the original lock-step model over a `Schedule`'s
//!   per-timestep rows (kept as the closed-form reference);
//! * [`event`] — the event-driven engine over a lowered [`Plan`]
//!   (per-worker compute/comm streams, per-link bandwidth/latency,
//!   configurable prefetch depth). At `prefetch_depth = 1` it reproduces
//!   the lock-step engine exactly (pinned by `rust/tests/cross_engine.rs`)
//!   and it additionally runs dataflow baseline plans (Ring Attention,
//!   Ulysses) the lock-step engine cannot express.
//!
//! [`Plan`]: crate::coordinator::plan::Plan

pub mod collective;
pub mod engine;
pub mod event;

pub use engine::{simulate_attention, AttnCost, SimResult, SlotTrace};
pub use event::{simulate_plan, EventOpts, EventResult, MemTimeline, PlanSim};
