//! Cluster timing simulator: analytic collective costs + a lock-step BSP
//! simulation of DISTFLASHATTN schedules on modeled A100 clusters.
//!
//! This is the substrate behind every wall-clock number in the paper-table
//! reproductions; the real-numerics executor (`coordinator::executor`)
//! proves correctness, this proves the *performance shape*.

pub mod collective;
pub mod engine;

pub use engine::{simulate_attention, AttnCost, SimResult, SlotTrace};
