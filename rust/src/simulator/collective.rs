//! Analytic cost models for the collectives the baselines use (ring
//! algorithms, the NCCL default at these scales).
//!
//! Conventions: `bytes` is the *full* tensor size being gathered/reduced
//! (per participating GPU where noted), `g` the group size, `(bw, lat)` the
//! bottleneck link. Formulas are the standard ring-collective costs
//! (e.g. NCCL docs / Korthikanti et al. appendix).

/// Point-to-point: one message over one link.
pub fn p2p(bytes: f64, bw: f64, lat: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    lat + bytes / bw
}

/// Ring all-gather of a `bytes`-sized shard from each of `g` ranks
/// (total output g·bytes): (g-1) steps shipping `bytes` each.
pub fn all_gather(bytes_per_rank: f64, g: usize, bw: f64, lat: f64) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    (g - 1) as f64 * (lat + bytes_per_rank / bw)
}

/// Ring reduce-scatter of a `bytes`-sized input per rank down to
/// bytes/g shards: (g-1) steps shipping bytes/g each.
pub fn reduce_scatter(bytes: f64, g: usize, bw: f64, lat: f64) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    (g - 1) as f64 * (lat + bytes / g as f64 / bw)
}

/// Ring all-reduce = reduce-scatter + all-gather: 2(g-1)/g · bytes / bw.
pub fn all_reduce(bytes: f64, g: usize, bw: f64, lat: f64) -> f64 {
    reduce_scatter(bytes, g, bw, lat) + all_gather(bytes / g as f64, g, bw, lat)
}

/// All-to-all: each rank exchanges bytes·(g-1)/g of its data (pairwise).
pub fn all_to_all(bytes: f64, g: usize, bw: f64, lat: f64) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    (g - 1) as f64 * lat + bytes * (g - 1) as f64 / g as f64 / bw
}

#[cfg(test)]
mod tests {
    use super::*;

    const BW: f64 = 100e9;
    const LAT: f64 = 1e-6;

    #[test]
    fn degenerate_groups_cost_nothing() {
        assert_eq!(all_gather(1e9, 1, BW, LAT), 0.0);
        assert_eq!(reduce_scatter(1e9, 1, BW, LAT), 0.0);
        assert_eq!(all_reduce(1e9, 1, BW, LAT), 0.0);
        assert_eq!(all_to_all(1e9, 1, BW, LAT), 0.0);
        assert_eq!(p2p(0.0, BW, LAT), 0.0);
    }

    #[test]
    fn all_reduce_is_twice_reduce_scatter_volume() {
        // classic identity: AR ≈ 2·(g-1)/g · bytes / bw for small latency
        let g = 8;
        let bytes = 1e9;
        let ar = all_reduce(bytes, g, BW, 0.0);
        let expect = 2.0 * (g - 1) as f64 / g as f64 * bytes / BW;
        assert!((ar - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn bigger_groups_cost_more_latency() {
        let t4 = all_gather(1e6, 4, BW, LAT);
        let t8 = all_gather(1e6, 8, BW, LAT);
        assert!(t8 > t4);
    }

    #[test]
    fn p2p_scales_linearly() {
        let a = p2p(1e9, BW, 0.0);
        let b = p2p(2e9, BW, 0.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
