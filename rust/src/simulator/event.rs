//! Event-driven timing engine over the schedule IR ([`Plan`]).
//!
//! Each worker owns two streams — compute and comm — mirroring the
//! kernel/copy CUDA streams of the real system. Ops are scheduled by a
//! single deterministic pass in dependency order: an op starts at the max
//! of its release time, its honored dependencies' finishes, and its
//! stream's tail; streams are FIFO in plan order. That fixed-priority
//! discipline makes the simulation reproducible and *monotone in the
//! prefetch depth* (releasing a transfer earlier can only move every
//! start earlier), which is what the cross-engine tests pin.
//!
//! Transfer timing uses the per-link `(bandwidth, latency)` from
//! [`ClusterSpec::link`], so NVLink-vs-InfiniBand placement of every edge
//! matters — unlike the closed-form collectives, topology is emergent.
//!
//! ## Lock-step plans (schedule lowerings)
//!
//! Plans lowered from a [`Schedule`] carry `lockstep = true`: a barrier
//! separates consecutive `step` groups (the BSP semantics of the threaded
//! executor). [`EventOpts::prefetch_depth`] then controls communication:
//!
//! * `depth = 0` — no overlap: transfers are released at the *previous*
//!   step's barrier (the step window they execute in) and computes wait
//!   for their inbound data, so transfer and kernel serialize within the
//!   window; helper results pay their wire time. Reproduces the lock-step
//!   engine (`engine::simulate_attention`) with `overlap = false`
//!   *exactly*.
//! * `depth = d >= 1` — prefetch: a transfer consumed at step `t` may be
//!   issued up to `d` steps early (release at barrier `t - d`); computes
//!   treat prefetchable inbound data (kv / q) as already resident, per
//!   the paper's §3.2 second-stream model, and helper results pipeline
//!   into the next kernel at zero exposed wire time. `depth = 1`
//!   reproduces the lock-step engine with `overlap = true` exactly;
//!   larger depths are never slower and hide more latency when a link is
//!   slow relative to a kernel.
//!
//! ## Dataflow plans (baselines)
//!
//! Plans with `lockstep = false` (Ring Attention's rotating pipeline,
//! Ulysses' all-to-all) have no barriers and no prefetch convention:
//! every dependency edge is honored and overlap *emerges* from the DAG —
//! a transfer runs concurrently with any compute it does not gate.
//!
//! [`Schedule`]: crate::coordinator::schedule::Schedule

use crate::config::ClusterSpec;
use crate::coordinator::plan::{Kernel, PayloadClass, Plan, PlanOp};
use crate::simulator::engine::AttnCost;

/// Event-engine knobs. `prefetch_depth` only affects lock-step plans.
#[derive(Clone, Copy, Debug)]
pub struct EventOpts {
    pub prefetch_depth: usize,
}

impl Default for EventOpts {
    fn default() -> Self {
        EventOpts { prefetch_depth: 1 }
    }
}

impl EventOpts {
    /// Run the plan at its own carried depth (`Plan::prefetch_depth`) —
    /// what the executor does, so trace-vs-sim comparisons line up.
    pub fn for_plan(plan: &Plan) -> EventOpts {
        EventOpts { prefetch_depth: plan.prefetch_depth }
    }
}

/// Per-op timing plus the aggregate accounting the reports use.
#[derive(Clone, Debug)]
pub struct EventResult {
    /// Wall-clock of the whole plan.
    pub total_s: f64,
    /// Total bytes moved (every transfer, even fully hidden ones).
    pub comm_bytes: f64,
    /// Sum over workers of compute-stream busy time.
    pub busy_s: f64,
    /// Start time of each op, indexed by `OpId`.
    pub op_start: Vec<f64>,
    /// Finish time of each op, indexed by `OpId`.
    pub op_finish: Vec<f64>,
    pub n_workers: usize,
}

impl EventResult {
    /// Predicted duration of one op (trace-vs-sim alignment).
    pub fn op_duration(&self, op: usize) -> f64 {
        self.op_finish[op] - self.op_start[op]
    }

    /// Fraction of worker-slots spent neither computing (Fig. 1 metric).
    pub fn idle_fraction(&self) -> f64 {
        let denom = self.total_s * self.n_workers as f64;
        if denom == 0.0 {
            return 0.0;
        }
        1.0 - self.busy_s / denom
    }
}

/// Everything `ClusterSpec::link` prices by — two plans checkpointed on
/// clusters with equal fingerprints time identically.
fn cluster_fingerprint(c: &ClusterSpec) -> [f64; 5] {
    [c.intra_bw, c.intra_lat, c.inter_bw, c.inter_lat, c.gpus_per_node as f64]
}

/// Per-worker memory timeline of one simulated pass: a constant resident
/// floor (weights slice, activations, checkpointed floats — including the
/// strategy's `extra_saved_floats`) plus every inbound transfer payload,
/// alive from the moment its bytes start arriving until its last consumer
/// finishes. This is what prices a plan against `GpuSpec::mem_bytes`: the
/// §3.2 prefetch pipeline and §3.3 checkpoint placement spend the same
/// headroom, so the optimizer trades them jointly.
#[derive(Clone, Debug)]
pub struct MemTimeline {
    /// The caller-supplied per-worker resident floor the sweep started at.
    pub resident_bytes: f64,
    /// Peak resident bytes per worker (floor + live staged payloads).
    pub peak_bytes: Vec<f64>,
}

impl MemTimeline {
    /// The plan's memory high-water mark: max per-worker peak.
    pub fn max_peak(&self) -> f64 {
        self.peak_bytes.iter().copied().fold(0.0, f64::max)
    }

    /// Peak *staged* bytes on one worker — the dynamic component above
    /// the resident floor (kv chunks, q bundles, helper results, grad
    /// returns held between arrival and consumption).
    pub fn staged_peak(&self, w: usize) -> f64 {
        self.peak_bytes[w] - self.resident_bytes
    }
}

/// Pre-resolved simulation state for one `(Plan, AttnCost)` pair — the
/// plan optimizer's hot path. Kernel seconds and payload bytes are
/// resolved once into flat per-op arrays; dependency lists are flattened
/// into a single index buffer; and every scratch vector is owned by the
/// struct and reused, so repeated [`PlanSim::total_s`] calls (hundreds per
/// optimizer configuration, varying only placement and prefetch depth) do
/// no per-call allocation and no enum matching.
///
/// ## Incremental rescoring
///
/// The op stream is partitioned into *segments* (maximal runs of one
/// `step` value, in plan order), and every pass records a checkpoint of
/// the scheduler state (stream tails, running max) at each segment entry.
/// [`PlanSim::set_op_cost`] patches a single op's resolved cost and marks
/// the earliest segment it touches dirty; [`PlanSim::rescore`] then
/// replays only the ops from that segment onward, reusing the clean
/// prefix. A candidate move that touches step `t` of a `T`-step plan costs
/// `(T - t) / T` of a full pass — the token-level rebalancer's per-pair
/// flip toggles and late-boundary moves exploit exactly this.
pub struct PlanSim {
    n_workers: usize,
    n_steps: usize,
    lockstep: bool,
    // per-op static resolution (struct-of-arrays)
    worker: Vec<u32>,
    step: Vec<u32>,
    /// Kernel seconds for computes; payload bytes for transfers.
    val: Vec<f64>,
    /// `u32::MAX` for computes; endpoint ranks for transfers.
    src: Vec<u32>,
    dst: Vec<u32>,
    /// Transfer is prefetchable (kv / q / raw).
    prefetchable: Vec<bool>,
    /// Flattened dependency lists: op i's deps are
    /// `dep_idx[dep_off[i]..dep_off[i+1]]`.
    dep_off: Vec<u32>,
    dep_idx: Vec<u32>,
    /// Aligned with `dep_idx`: edge is skipped under overlap (attention
    /// compute gated by a prefetchable transfer in a lock-step plan).
    dep_skip_overlap: Vec<bool>,
    comm_bytes: f64,
    busy_s: f64,
    /// Largest prefetchable kv-class transfer — what one extra unit of
    /// prefetch depth stages in GPU memory (the autotuner's charge).
    kv_stage_bytes: f64,
    // segment structure: maximal runs of one step value, in plan order
    seg_start: Vec<u32>,
    seg_step: Vec<u32>,
    seg_of_op: Vec<u32>,
    // per-segment checkpoints from the most recent pass
    ck_compute: Vec<f64>,
    ck_comm: Vec<f64>,
    ck_run_max: Vec<f64>,
    /// Segments whose checkpoints and `op_finish` prefix reflect the
    /// current cost array (monotonically lowered by `set_op_cost`).
    valid_segs: usize,
    /// Configuration the checkpoints were taken under.
    ck_depth: usize,
    ck_placement: Vec<usize>,
    /// Link-pricing fingerprint of the checkpointed cluster — a replayed
    /// prefix is only valid if every link prices identically.
    ck_cluster: [f64; 5],
    have_ck: bool,
    last_total: f64,
    /// Per-worker compute slowdown factors (degradation-aware planning:
    /// a pinned straggler runs every kernel `factor`× longer). All 1.0
    /// by default; transfers and the `busy_s` aggregate stay unscaled —
    /// `busy_s` reports the healthy-hardware kernel budget, the makespan
    /// reports the degraded schedule.
    slowdown: Vec<f64>,
    // reusable scratch
    compute_tail: Vec<f64>,
    comm_tail: Vec<f64>,
    barrier: Vec<f64>,
    op_start: Vec<f64>,
    op_finish: Vec<f64>,
}

impl PlanSim {
    pub fn new(plan: &Plan, cost: &AttnCost) -> PlanSim {
        let p = plan.n_workers;
        let n_ops = plan.ops.len();
        let mut sim = PlanSim {
            n_workers: p,
            n_steps: plan.n_steps.max(1),
            lockstep: plan.lockstep,
            worker: Vec::with_capacity(n_ops),
            step: Vec::with_capacity(n_ops),
            val: Vec::with_capacity(n_ops),
            src: Vec::with_capacity(n_ops),
            dst: Vec::with_capacity(n_ops),
            prefetchable: Vec::with_capacity(n_ops),
            dep_off: Vec::with_capacity(n_ops + 1),
            dep_idx: Vec::new(),
            dep_skip_overlap: Vec::new(),
            comm_bytes: 0.0,
            busy_s: 0.0,
            kv_stage_bytes: 0.0,
            seg_start: Vec::new(),
            seg_step: Vec::new(),
            seg_of_op: Vec::with_capacity(n_ops),
            ck_compute: Vec::new(),
            ck_comm: Vec::new(),
            ck_run_max: Vec::new(),
            valid_segs: 0,
            ck_depth: usize::MAX,
            ck_placement: Vec::new(),
            ck_cluster: [0.0; 5],
            have_ck: false,
            last_total: 0.0,
            slowdown: vec![1.0; p],
            compute_tail: vec![0.0; p],
            comm_tail: vec![0.0; p],
            barrier: vec![0.0; plan.n_steps.max(1)],
            op_start: vec![0.0; n_ops],
            op_finish: vec![0.0; n_ops],
        };
        for node in &plan.ops {
            sim.worker.push(node.worker as u32);
            sim.step.push(node.step as u32);
            if sim.seg_step.last() != Some(&(node.step as u32)) {
                sim.seg_start.push(sim.seg_of_op.len() as u32);
                sim.seg_step.push(node.step as u32);
            }
            sim.seg_of_op.push(sim.seg_step.len() as u32 - 1);
            sim.dep_off.push(sim.dep_idx.len() as u32);
            let is_attn = matches!(
                node.op,
                PlanOp::Compute {
                    kernel: Kernel::AttnDiag | Kernel::AttnFull | Kernel::AttnTok { .. },
                    ..
                }
            );
            for &d in &node.deps {
                sim.dep_idx.push(d as u32);
                let dep_prefetch_xfer = matches!(
                    &plan.ops[d].op,
                    PlanOp::Xfer { payload, .. } if payload.prefetchable()
                );
                sim.dep_skip_overlap
                    .push(plan.lockstep && is_attn && dep_prefetch_xfer);
            }
            match &node.op {
                PlanOp::Compute { kernel, .. } => {
                    let s = kernel.seconds(cost);
                    sim.busy_s += s;
                    sim.val.push(s);
                    sim.src.push(u32::MAX);
                    sim.dst.push(u32::MAX);
                    sim.prefetchable.push(false);
                }
                PlanOp::Xfer { src, dst, payload } => {
                    let bytes = payload.bytes(cost);
                    sim.comm_bytes += bytes;
                    if payload.prefetchable()
                        && payload.class() == PayloadClass::Kv
                        && bytes > sim.kv_stage_bytes
                    {
                        sim.kv_stage_bytes = bytes;
                    }
                    sim.val.push(bytes);
                    sim.src.push(*src as u32);
                    sim.dst.push(*dst as u32);
                    sim.prefetchable.push(payload.prefetchable());
                }
            }
        }
        sim.dep_off.push(sim.dep_idx.len() as u32);
        let n_segs = sim.seg_start.len();
        sim.ck_compute = vec![0.0; n_segs * p];
        sim.ck_comm = vec![0.0; n_segs * p];
        sim.ck_run_max = vec![0.0; n_segs];
        sim
    }

    /// Total bytes every transfer moves (placement/depth-independent).
    pub fn comm_bytes(&self) -> f64 {
        self.comm_bytes
    }

    /// Sum of kernel seconds across workers (placement/depth-independent).
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    /// Bytes one extra unit of prefetch depth stages on a GPU (the largest
    /// prefetchable kv-class transfer in the plan).
    pub fn stage_bytes(&self) -> f64 {
        self.kv_stage_bytes
    }

    /// Resolved cost of one op (kernel seconds / payload bytes).
    pub fn op_cost(&self, op: usize) -> f64 {
        self.val[op]
    }

    /// First dirty segment index — equals the segment count when the
    /// scratch fully reflects the current costs (nothing to replay).
    pub fn dirty_from(&self) -> usize {
        self.valid_segs
    }

    /// Pin a compute slowdown factor on one worker (`1.0` = healthy;
    /// `1.5` = every kernel on `w` runs 50% longer). Drops the
    /// checkpointed prefix — the next score is a full pass — since a
    /// factor change invalidates every segment's timing.
    pub fn set_worker_slowdown(&mut self, w: usize, factor: f64) {
        assert!(
            w < self.n_workers,
            "slowdown target rank {w} out of range (plan has {} workers)",
            self.n_workers
        );
        assert!(factor >= 1.0, "slowdown factor must be >= 1.0 (got {factor})");
        if self.slowdown[w] != factor {
            self.slowdown[w] = factor;
            self.valid_segs = 0;
            self.have_ck = false;
        }
    }

    /// Patch one op's resolved cost in place (the incremental rescorer's
    /// entry point — a boundary move or role toggle is a handful of these
    /// followed by one [`PlanSim::rescore`]). Aggregates stay consistent;
    /// everything from the op's segment onward is marked dirty.
    pub fn set_op_cost(&mut self, op: usize, val: f64) {
        let old = self.val[op];
        if old == val {
            return;
        }
        if self.src[op] != u32::MAX {
            self.comm_bytes += val - old;
        } else {
            self.busy_s += val - old;
        }
        self.val[op] = val;
        self.valid_segs = self.valid_segs.min(self.seg_of_op[op] as usize);
    }

    /// One scheduling pass from segment `from_seg` (0 = full pass),
    /// reusing the checkpointed prefix; fills `op_start`/`op_finish`
    /// scratch, refreshes checkpoints, and returns the makespan.
    /// `placement[w]` is the GPU rank `w` runs on.
    fn pass_from(
        &mut self,
        cluster: &ClusterSpec,
        placement: &[usize],
        depth: usize,
        from_seg: usize,
    ) -> f64 {
        debug_assert_eq!(placement.len(), self.n_workers);
        let p = self.n_workers;
        let overlap = depth >= 1;
        let back_prefetch = depth.max(1) as u32;
        let mut cur_step;
        let mut running_max;
        if from_seg == 0 {
            self.compute_tail.iter_mut().for_each(|x| *x = 0.0);
            self.comm_tail.iter_mut().for_each(|x| *x = 0.0);
            self.barrier.iter_mut().for_each(|x| *x = 0.0);
            cur_step = 0u32;
            running_max = 0.0f64;
        } else {
            self.compute_tail
                .copy_from_slice(&self.ck_compute[from_seg * p..(from_seg + 1) * p]);
            self.comm_tail
                .copy_from_slice(&self.ck_comm[from_seg * p..(from_seg + 1) * p]);
            running_max = self.ck_run_max[from_seg];
            cur_step = self.seg_step[from_seg - 1];
        }

        let n_segs = self.seg_start.len();
        for k in from_seg..n_segs {
            // checkpoint the state at segment entry (before the barrier
            // crossing, which a resume replays identically)
            self.ck_compute[k * p..(k + 1) * p].copy_from_slice(&self.compute_tail);
            self.ck_comm[k * p..(k + 1) * p].copy_from_slice(&self.comm_tail);
            self.ck_run_max[k] = running_max;
            let step = self.seg_step[k];
            if self.lockstep && step > cur_step {
                for t in cur_step..step {
                    self.barrier[t as usize] = running_max;
                }
                cur_step = step;
            }
            let seg_end = if k + 1 < n_segs {
                self.seg_start[k + 1] as usize
            } else {
                self.worker.len()
            };
            for i in self.seg_start[k] as usize..seg_end {
                let is_xfer = self.src[i] != u32::MAX;
                // release barrier: computes and mid-step products bind to
                // the previous step; prefetchable transfers run up to
                // `depth` early
                let mut ready = if self.lockstep {
                    let b = if is_xfer && self.prefetchable[i] { back_prefetch } else { 1 };
                    if step >= b { self.barrier[(step - b) as usize] } else { 0.0 }
                } else {
                    0.0
                };
                let lo = self.dep_off[i] as usize;
                let hi = self.dep_off[i + 1] as usize;
                for j in lo..hi {
                    if !(overlap && self.dep_skip_overlap[j]) {
                        let f = self.op_finish[self.dep_idx[j] as usize];
                        if f > ready {
                            ready = f;
                        }
                    }
                }
                let w = self.worker[i] as usize;
                let (dur, tail) = if is_xfer {
                    let bytes = self.val[i];
                    let s = if bytes <= 0.0
                        || (self.lockstep && overlap && !self.prefetchable[i])
                    {
                        // mid-step products pipeline into the next kernel
                        // on the copy stream under overlap (§3.2): no
                        // exposed wire time. Dataflow plans always pay
                        // real time.
                        0.0
                    } else {
                        let (bw, lat) = cluster.link(
                            placement[self.src[i] as usize],
                            placement[self.dst[i] as usize],
                        );
                        lat + bytes / bw
                    };
                    (s, &mut self.comm_tail[w])
                } else {
                    (self.val[i] * self.slowdown[w], &mut self.compute_tail[w])
                };
                let start = ready.max(*tail);
                let finish = start + dur;
                *tail = finish;
                self.op_start[i] = start;
                self.op_finish[i] = finish;
                if finish > running_max {
                    running_max = finish;
                }
            }
        }
        self.valid_segs = n_segs;
        self.ck_depth = depth;
        self.ck_placement.clear();
        self.ck_placement.extend_from_slice(placement);
        self.ck_cluster = cluster_fingerprint(cluster);
        self.have_ck = true;
        self.last_total = running_max;
        running_max
    }

    /// Allocation-free makespan — the optimizer's scoring call.
    pub fn total_s(&mut self, cluster: &ClusterSpec, placement: &[usize], depth: usize) -> f64 {
        self.pass_from(cluster, placement, depth, 0)
    }

    /// Makespan after [`PlanSim::set_op_cost`] patches, replaying only the
    /// dirty suffix of the op stream. Falls back to a full pass when the
    /// cluster, placement, or depth differs from the checkpointed
    /// configuration; returns the cached total when nothing is dirty.
    /// Bit-identical to a full re-simulation (pinned by
    /// `varlen_properties`).
    pub fn rescore(&mut self, cluster: &ClusterSpec, placement: &[usize], depth: usize) -> f64 {
        if !self.have_ck
            || depth != self.ck_depth
            || placement != self.ck_placement.as_slice()
            || cluster_fingerprint(cluster) != self.ck_cluster
        {
            return self.pass_from(cluster, placement, depth, 0);
        }
        if self.valid_segs >= self.seg_start.len() {
            return self.last_total;
        }
        let from = self.valid_segs;
        self.pass_from(cluster, placement, depth, from)
    }

    /// Full per-op accounting (allocates the returned vectors).
    pub fn run(&mut self, cluster: &ClusterSpec, placement: &[usize], depth: usize) -> EventResult {
        let total_s = self.pass_from(cluster, placement, depth, 0);
        EventResult {
            total_s,
            comm_bytes: self.comm_bytes,
            busy_s: self.busy_s,
            op_start: self.op_start.clone(),
            op_finish: self.op_finish.clone(),
            n_workers: self.n_workers,
        }
    }

    /// Per-worker memory timeline of the most recent pass (alloc/free
    /// sweep over the `op_start`/`op_finish` scratch — call after
    /// [`PlanSim::total_s`] / [`PlanSim::run`]). Every inbound transfer
    /// payload is allocated on its destination worker when the transfer
    /// starts (prefetched bytes are resident from first arrival) and
    /// freed when its last consuming op finishes; `resident_bytes` is the
    /// constant per-worker floor (weights slice, activations, checkpoint
    /// floats) the sweep adds staging on top of.
    pub fn mem_timeline(&self, resident_bytes: f64) -> MemTimeline {
        assert!(
            self.have_ck,
            "mem_timeline needs a completed pass (call total_s/run first)"
        );
        let p = self.n_workers;
        let n = self.worker.len();
        // free time per transfer: the last consumer's finish — never
        // before the transfer itself lands (skipped-for-timing prefetch
        // edges still consume the staged bytes)
        let mut free_at: Vec<f64> = self.op_finish[..n].to_vec();
        for i in 0..n {
            let lo = self.dep_off[i] as usize;
            let hi = self.dep_off[i + 1] as usize;
            for j in lo..hi {
                let d = self.dep_idx[j] as usize;
                if self.src[d] != u32::MAX && self.op_finish[i] > free_at[d] {
                    free_at[d] = self.op_finish[i];
                }
            }
        }
        let mut events: Vec<(u32, f64, f64)> = Vec::new(); // (worker, time, delta)
        for i in 0..n {
            if self.src[i] == u32::MAX || self.val[i] <= 0.0 {
                continue;
            }
            events.push((self.dst[i], self.op_start[i], self.val[i]));
            events.push((self.dst[i], free_at[i], -self.val[i]));
        }
        // per worker, in time order; frees drain before same-instant
        // allocations (a barrier hand-off is not double-resident)
        events.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(a.1.total_cmp(&b.1))
                .then(a.2.total_cmp(&b.2))
        });
        let mut peak = vec![resident_bytes; p];
        let mut cur = resident_bytes;
        let mut cur_w = u32::MAX;
        for &(w, _, delta) in &events {
            if w != cur_w {
                cur = resident_bytes;
                cur_w = w;
            }
            cur += delta;
            if cur > peak[w as usize] {
                peak[w as usize] = cur;
            }
        }
        MemTimeline { resident_bytes, peak_bytes: peak }
    }
}

/// Simulate a plan on a cluster. `cost` resolves the kernel/payload cost
/// classes; its `overlap` flag is ignored here — overlap is the plan DAG
/// plus `opts.prefetch_depth`. Links are looked up through the plan's
/// rank→GPU `placement` (identity unless optimized). One-shot convenience
/// over [`PlanSim`]; for repeated scoring build a `PlanSim` once.
pub fn simulate_plan(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &EventOpts,
) -> EventResult {
    PlanSim::new(plan, cost).run(cluster, &plan.placement, opts.prefetch_depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::Pass;
    use crate::coordinator::Schedule;
    use crate::simulator::engine::simulate_attention;

    fn cost(overlap: bool) -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6,
            q_bytes: 0.5e6,
            result_bytes: 0.6e6,
            overlap,
        }
    }

    fn rel_close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-30)
    }

    #[test]
    fn matches_lockstep_engine_small() {
        let cluster = ClusterSpec::dgx_2x8();
        for p in [1usize, 2, 3, 8, 16] {
            for kind in [
                crate::coordinator::ScheduleKind::Ring,
                crate::coordinator::ScheduleKind::Balanced,
            ] {
                let s = Schedule::build(kind, p);
                let plan = Plan::from_schedule(&s, Pass::Forward);
                let with = simulate_attention(&s, &cluster, &cost(true));
                let ev =
                    simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 });
                assert!(
                    rel_close(ev.total_s, with.total_s),
                    "{kind:?} P={p} overlap: {} vs {}",
                    ev.total_s,
                    with.total_s
                );
                let without = simulate_attention(&s, &cluster, &cost(false));
                let ev0 =
                    simulate_plan(&plan, &cluster, &cost(false), &EventOpts { prefetch_depth: 0 });
                assert!(
                    rel_close(ev0.total_s, without.total_s),
                    "{kind:?} P={p} serial: {} vs {}",
                    ev0.total_s,
                    without.total_s
                );
            }
        }
    }

    #[test]
    fn deeper_prefetch_never_slower() {
        let cluster = ClusterSpec::dgx_2x8();
        let s = Schedule::balanced(16);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let base =
            simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 }).total_s;
        let mut prev = base;
        for d in [2usize, 4, 8] {
            let t =
                simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: d })
                    .total_s;
            assert!(t <= prev + 1e-12, "depth {d}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn deep_prefetch_hides_slow_links() {
        // make kv transfers expensive relative to kernels: depth 1 is
        // comm-bound, a deeper pipeline pulls transfers forward
        let cluster = ClusterSpec::dgx_2x8();
        let c = AttnCost { kv_bytes: 60e6, ..cost(true) };
        let plan = Plan::from_schedule(&Schedule::ring(16), Pass::Forward);
        let d1 = simulate_plan(&plan, &cluster, &c, &EventOpts { prefetch_depth: 1 }).total_s;
        let d8 = simulate_plan(&plan, &cluster, &c, &EventOpts { prefetch_depth: 8 }).total_s;
        assert!(d8 < d1 * 0.95, "depth 8 {d8} should beat depth 1 {d1}");
    }

    #[test]
    fn dataflow_ring_attention_overlaps() {
        // compute-bound regime: wall-clock ~= diag + (P-1) * full per
        // worker; the rotating transfers hide entirely
        let cluster = ClusterSpec::dgx_1x8();
        let p = 8;
        let c = AttnCost { kv_bytes: 1e3, ..cost(true) };
        let plan = Plan::ring_attention(p);
        let r = simulate_plan(&plan, &cluster, &c, &EventOpts::default());
        let expect = c.pair_diag_s + (p - 1) as f64 * c.pair_full_s;
        assert!(rel_close(r.total_s, expect), "{} vs {expect}", r.total_s);
        // comm-bound regime: the hop chain dominates
        let cc = AttnCost { kv_bytes: 1e9, pair_full_s: 1e-6, pair_diag_s: 1e-6, ..cost(true) };
        let r2 = simulate_plan(&plan, &cluster, &cc, &EventOpts::default());
        assert!(r2.total_s > (p - 1) as f64 * (1e9 / cluster.intra_bw));
    }

    #[test]
    fn mem_timeline_counts_staged_payloads() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let c = cost(true);
        let mut sim = PlanSim::new(&plan, &c);
        sim.total_s(&cluster, &plan.placement, 1);
        let tl = sim.mem_timeline(1e9);
        assert_eq!(tl.peak_bytes.len(), 8);
        // every worker starts from the resident floor, and at least one
        // worker stages a full kv chunk on top of it
        assert!(tl.peak_bytes.iter().all(|&b| b >= 1e9));
        assert!(tl.max_peak() >= 1e9 + c.kv_bytes);
        assert!(tl.staged_peak(7) >= 0.0);
    }

    #[test]
    fn worker_slowdown_degrades_makespan_monotonically() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        // compute-bound regime so the straggler's kernels dominate
        let c = AttnCost { kv_bytes: 1e3, ..cost(true) };
        let mut sim = PlanSim::new(&plan, &c);
        let healthy = sim.total_s(&cluster, &plan.placement, 1);
        sim.set_worker_slowdown(3, 1.5);
        let degraded = sim.total_s(&cluster, &plan.placement, 1);
        sim.set_worker_slowdown(3, 3.0);
        let worse = sim.total_s(&cluster, &plan.placement, 1);
        assert!(degraded > healthy, "{degraded} vs {healthy}");
        assert!(worse > degraded, "{worse} vs {degraded}");
        // busy_s reports the healthy kernel budget regardless
        assert!(rel_close(sim.busy_s(), PlanSim::new(&plan, &c).busy_s()));
        // resetting to 1.0 restores the healthy makespan exactly
        sim.set_worker_slowdown(3, 1.0);
        assert!(rel_close(sim.total_s(&cluster, &plan.placement, 1), healthy));
    }

    #[test]
    fn worker_slowdown_invalidates_checkpoints() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let c = cost(true);
        let mut sim = PlanSim::new(&plan, &c);
        sim.total_s(&cluster, &plan.placement, 1);
        sim.set_worker_slowdown(0, 2.0);
        // rescore must replay from scratch, matching a fresh sim
        let rescored = sim.rescore(&cluster, &plan.placement, 1);
        let mut fresh = PlanSim::new(&plan, &c);
        fresh.set_worker_slowdown(0, 2.0);
        let expect = fresh.total_s(&cluster, &plan.placement, 1);
        assert!(rel_close(rescored, expect), "{rescored} vs {expect}");
    }

    #[test]
    fn accounting_shape() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let r = simulate_plan(&plan, &cluster, &cost(true), &EventOpts::default());
        assert_eq!(r.op_start.len(), plan.n_ops());
        assert!(r.busy_s > 0.0 && r.total_s > 0.0);
        assert!((0.0..1.0).contains(&r.idle_fraction()));
        // starts never precede deps' finishes for honored edges: spot
        // check rescales (always honored)
        for n in &plan.ops {
            if matches!(n.op, PlanOp::Compute { kernel: Kernel::Rescale, .. }) {
                for &d in &n.deps {
                    assert!(r.op_start[n.id] >= r.op_finish[d] - 1e-15);
                }
            }
        }
    }
}
